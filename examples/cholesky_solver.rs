//! Domain example: solve the SPD system `A x = b` end-to-end with REAP —
//! the workload sparse Cholesky exists for (the paper's §III-B motivation:
//! "Cholesky factorization is an important method to solve systems of
//! equations, Ax = b").
//!
//! Pipeline: synthesize an FEM-style SPD system → REAP factorization
//! (CPU symbolic + FPGA-model numeric) → forward/backward triangular
//! solves → residual check against a manufactured solution.
//!
//!     cargo run --release --example cholesky_solver [n] [nnz]

use reap::coordinator::ReapCholesky;
use reap::fpga::FpgaConfig;
use reap::kernels::triangular;
use reap::sparse::gen::{self, Family};
use reap::sparse::Dense;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(800);
    let nnz: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(n * 8);

    println!("== cholesky_solver: A x = b with REAP ==");
    let spd = gen::spd(Family::BandedFem, n, nnz, 2024);
    let lower = spd.lower_triangle();
    println!(
        "system: {0}x{0} SPD (FEM pattern), lower nnz {1}",
        spd.nrows,
        lower.nnz()
    );

    // manufactured solution -> rhs
    let x_true: Vec<f32> = (0..n).map(|i| ((i % 11) as f32 - 5.0) * 0.25).collect();
    let b = Dense::from_csr(&spd.to_csr()).matvec(&x_true);

    // REAP factorization (REAP-64 Cholesky design point)
    let coord = ReapCholesky::new(FpgaConfig::reap64_cholesky());
    let rep = coord.run(&lower)?;
    println!(
        "factorization: nnz(L) {} (fill-in {}), symbolic {:.3} ms, fpga {:.3} ms",
        rep.factor.l.nnz(),
        rep.factor.pattern.fill_in(&lower),
        rep.cpu_symbolic_s * 1e3,
        rep.fpga_s * 1e3,
    );
    println!(
        "sim: {} cycles, pipeline util {:.1}%, {:.2} GB/s read achieved",
        rep.fpga_sim.cycles,
        rep.fpga_sim.pipeline_utilization() * 100.0,
        rep.fpga_sim.achieved_read_gbps(&FpgaConfig::reap64_cholesky()),
    );

    // triangular solves (CHOLMOD's cholmod_solve counterpart)
    let x = triangular::solve_spd(&rep.factor.l, &b);

    // residual + solution error
    let ax = Dense::from_csr(&spd.to_csr()).matvec(&x);
    let res = ax
        .iter()
        .zip(&b)
        .map(|(p, q)| (p - q).abs() as f64)
        .fold(0.0, f64::max);
    let err = x
        .iter()
        .zip(&x_true)
        .map(|(p, q)| (p - q).abs() as f64)
        .fold(0.0, f64::max);
    let bmax = b.iter().map(|v| v.abs() as f64).fold(0.0, f64::max);
    println!("solve: max residual {res:.3e} (rhs scale {bmax:.3e}), max solution error {err:.3e}");
    anyhow::ensure!(res <= 1e-2 * bmax.max(1.0), "residual too large");
    println!("cholesky_solver OK");
    Ok(())
}
