//! Domain example: where does the CPU→FPGA handoff pay off?
//!
//! Runs the Fig-9 experiment — the evaluation suite scattered by density
//! plus a controlled density sweep — and prints the REAP-32 speedup over
//! the single-core CPU baseline for both kernels, marking the crossover
//! where the CPU starts winning: the design-space question a prospective
//! REAP adopter asks first.
//!
//!     cargo run --release --example sensitivity [max_rows]

use reap::harness::{fig9, RunConfig};

fn main() {
    let max_rows: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1200);
    let cfg = RunConfig { max_rows, budget_s: 0.1, csv_dir: None, ..Default::default() };
    println!("== sensitivity: REAP-32 speedup vs density (max_rows = {max_rows}) ==");
    let (points, table) = fig9::run(&cfg);
    print!("{}", table.render());

    let sweep: Vec<_> = points.iter().filter(|p| p.kernel == "SpGEMM-sweep").collect();
    match sweep.iter().find(|p| p.speedup < 1.0) {
        Some(p) => println!(
            "SpGEMM sweep crossover: CPU wins from density ~{:.2}% (paper: only the densest inputs)",
            p.density * 100.0
        ),
        None => println!("no SpGEMM crossover in the swept range — REAP wins throughout"),
    }
    println!(
        "dense-end degradation holds: {}",
        fig9::headline_holds(&points)
    );
}
