//! Domain example: Conjugate Gradients on REAP SpMV — the iterative
//! workload where the extension kernel's preprocessing actually amortizes
//! (see EXPERIMENTS.md §Extension).
//!
//! CG needs one y = A p per iteration with the *same* matrix: the RIR
//! encode/schedule runs once, and every iteration streams the prebuilt
//! bundles — exactly the coarse-grained split REAP was designed around.
//! Reports per-iteration FPGA time vs the measured CPU SpMV, plus the
//! solve's convergence.
//!
//!     cargo run --release --example cg_solver [n] [nnz]

use reap::coordinator::ReapSpmv;
use reap::fpga::FpgaConfig;
use reap::kernels::spmv::spmv;
use reap::sparse::gen::{self, Family};
use reap::sparse::{Csr, Dense};
use reap::util::timer::measure_budgeted;

/// Plain CG over a CSR SPD matrix, multiplying through `mul`.
fn conjugate_gradient(
    a: &Csr,
    b: &[f32],
    tol: f64,
    max_iters: usize,
    mut mul: impl FnMut(&[f32]) -> Vec<f32>,
) -> (Vec<f32>, usize, f64) {
    let n = b.len();
    let mut x = vec![0f32; n];
    let mut r: Vec<f64> = b.iter().map(|&v| v as f64).collect();
    let mut p: Vec<f32> = b.to_vec();
    let mut rs_old: f64 = r.iter().map(|v| v * v).sum();
    let b_norm = rs_old.sqrt().max(1e-30);
    let mut iters = 0;
    while iters < max_iters && rs_old.sqrt() / b_norm > tol {
        let ap = mul(&p);
        let p_ap: f64 = p.iter().zip(&ap).map(|(&pi, &qi)| pi as f64 * qi as f64).sum();
        let alpha = rs_old / p_ap;
        for i in 0..n {
            x[i] += (alpha * p[i] as f64) as f32;
            r[i] -= alpha * ap[i] as f64;
        }
        let rs_new: f64 = r.iter().map(|v| v * v).sum();
        let beta = rs_new / rs_old;
        for i in 0..n {
            p[i] = (r[i] + beta * p[i] as f64) as f32;
        }
        rs_old = rs_new;
        iters += 1;
    }
    (x, iters, rs_old.sqrt() / b_norm)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1000);
    let nnz: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(n * 10);

    println!("== cg_solver: conjugate gradients over REAP SpMV ==");
    let spd_csc = gen::spd(Family::BandedFem, n, nnz, 77);
    let a = spd_csc.to_csr();
    let x_true: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.011).cos()).collect();
    let b = Dense::from_csr(&a).matvec(&x_true);
    println!("system: {0}x{0} SPD, nnz {1}", a.nrows, a.nnz());

    // Preprocess ONCE (the coordinator rebuilds per run(); emulate the
    // amortized deployment by timing the pieces separately).
    let coord = ReapSpmv::new(FpgaConfig::reap64_spgemm());
    let probe = coord.run(&a, &b)?;
    println!(
        "REAP pass: preprocess {:.3} ms once | fpga(sim) {:.3} ms / iteration",
        probe.cpu_preprocess_s * 1e3,
        probe.fpga_s * 1e3
    );
    let cpu_iter = measure_budgeted(0.2, 3, || spmv(&a, &b)).min_s;
    println!("CPU SpMV: {:.3} ms / iteration", cpu_iter * 1e3);

    // Solve with REAP as the multiply engine (numerics bit-match the
    // coordinator's bundle-ordered path).
    let (x, iters, rel) =
        conjugate_gradient(&a, &b, 1e-6, 4 * n, |p| coord.run(&a, p).unwrap().y);
    let err = x
        .iter()
        .zip(&x_true)
        .map(|(g, w)| (g - w).abs() as f64)
        .fold(0.0, f64::max);
    println!("CG converged in {iters} iterations, rel residual {rel:.2e}, max err {err:.2e}");

    let reap_amortized = probe.cpu_preprocess_s + iters as f64 * probe.fpga_s;
    let cpu_total = iters as f64 * cpu_iter;
    println!(
        "amortized multiply time over the solve: CPU {:.2} ms vs REAP-64 {:.2} ms -> {:.2}x",
        cpu_total * 1e3,
        reap_amortized * 1e3,
        cpu_total / reap_amortized
    );
    anyhow::ensure!(rel < 1e-5, "CG failed to converge");
    println!("cg_solver OK");
    Ok(())
}
