//! Domain example: design-space exploration for a new FPGA target.
//!
//! A hardware team porting REAP asks: how many pipelines should we
//! provision, at what bandwidth, for our workload mix? This example sweeps
//! pipeline count and DRAM bandwidth on a fixed workload, printing
//! simulated throughput, utilization, the compute/DRAM bound split, and
//! the area model's frequency/logic cost — the paper's hardware-
//! scalability analysis (Fig 8 right) turned into a tool.
//!
//!     cargo run --release --example design_space [n] [nnz]

use reap::fpga::spgemm_sim::{simulate_spgemm, Style};
use reap::fpga::{AreaModel, FpgaConfig};
use reap::rir::schedule::schedule_spgemm;
use reap::sparse::gen::{self, Family};
use reap::util::table::{f2, pct, Table};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1500);
    let nnz: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(n * 20);
    let a = gen::generate(Family::BandedFem, n, nnz, 99);
    println!(
        "== design_space: SpGEMM C=A^2, {}x{} nnz {} ==",
        a.nrows,
        a.ncols,
        a.nnz()
    );

    let mut t = Table::new(
        "pipeline / bandwidth sweep (REAP SpGEMM)",
        &["pipelines", "freq MHz", "logic", "BW GB/s", "time ms", "GFLOP/s", "util", "DRAM-bound"],
    );
    for &pipes in &[8usize, 16, 32, 64, 128] {
        for &bw in &[2.0f64, 6.0, 14.0, 147.0] {
            let mut cfg = FpgaConfig::reap32_spgemm();
            cfg.pipelines = pipes;
            cfg.freq_mhz = AreaModel::freq_mhz(pipes);
            cfg.dram.read_gbps = bw;
            cfg.dram.write_gbps = bw / 2.0;
            let sched = schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
            let sim = simulate_spgemm(&a, &a, &sched, &cfg, Style::HandCoded);
            t.row(vec![
                pipes.to_string(),
                f2(cfg.freq_mhz),
                pct(AreaModel::logic_utilization(pipes)),
                format!("{bw:.0}"),
                f2(sim.stats.seconds(&cfg) * 1e3),
                f2(sim.stats.gflops(&cfg)),
                pct(sim.stats.pipeline_utilization()),
                pct(sim.stats.dram_bound_fraction()),
            ]);
        }
    }
    print!("{}", t.render());
    println!(
        "reading: scaling pipelines without bandwidth strands them \
         (the paper's key finding); the knee marks the balanced design."
    );
}
