//! End-to-end driver: the full three-layer REAP system on a real small
//! workload, proving all layers compose.
//!
//! Two phases:
//!
//! 1. **Composition proof** — REAP SpGEMM and Cholesky with numerics
//!    executed through the AOT XLA artifacts (Rust → PJRT → compiled
//!    JAX/Pallas kernels), verified against the CPU baselines. Small
//!    workloads: each bundle-step is a separate PJRT dispatch on the CPU
//!    backend, so this path is for validation, not throughput.
//! 2. **Headline metric** — the paper's speedup-over-CPU-1 numbers at
//!    benchmark scale through the bit-equivalent in-process numeric path
//!    (same bundle/wave ordering; equality is asserted in phase 1 and in
//!    `rust/tests/integration_runtime.rs`).
//!
//!     cargo run --release --example quickstart
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use reap::coordinator::{verify, ReapCholesky, ReapSpgemm};
use reap::fpga::FpgaConfig;
use reap::kernels;
use reap::runtime::XlaRuntime;
use reap::sparse::gen::{self, Family};
use reap::symbolic::symbolic_factor;
use reap::util::timer::measure_budgeted;

fn main() -> anyhow::Result<()> {
    println!("== REAP quickstart: end-to-end three-layer run ==\n");

    // ---------------- phase 1: three-layer composition ----------------
    match XlaRuntime::load_default() {
        Ok(rt) => {
            println!("[1/2] numerics through XLA/PJRT ({})", rt.platform());
            // SpGEMM through the spgemm_bundle artifact
            let a = gen::generate(Family::BandedFem, 300, 3600, 42);
            let rep = ReapSpgemm::with_runtime(FpgaConfig::reap32_spgemm(), &rt).run(&a, &a)?;
            let v = verify::verify_csr(&rep.c, &kernels::spgemm(&a, &a));
            println!(
                "  SpGEMM  {}x{} nnz {:>6}: rel err {:.2e} vs CPU baseline -> {}",
                a.nrows,
                a.ncols,
                a.nnz(),
                v.relative(),
                if v.ok(1e-5) { "OK" } else { "MISMATCH" }
            );
            anyhow::ensure!(v.ok(1e-5), "SpGEMM XLA verification failed");

            // Cholesky through cholesky_dot/cholesky_update artifacts
            let lower = gen::spd(Family::BandedFem, 250, 2000, 7).lower_triangle();
            let crep =
                ReapCholesky::with_runtime(FpgaConfig::reap32_cholesky(), &rt).run(&lower)?;
            let reference = kernels::cholesky::cholesky(&lower)?;
            let cv = verify::verify_csc(&crep.factor.l, &reference.l);
            println!(
                "  Cholesky {0}x{0} nnz(L) {1:>6}: rel err {2:.2e} vs CPU baseline -> {3}",
                lower.nrows,
                crep.factor.l.nnz(),
                cv.relative(),
                if cv.ok(1e-4) { "OK" } else { "MISMATCH" }
            );
            anyhow::ensure!(cv.ok(1e-4), "Cholesky XLA verification failed");
        }
        Err(e) => {
            println!("[1/2] SKIPPED — artifacts unavailable ({e:#}); run `make artifacts`");
        }
    }

    // ---------------- phase 2: headline metrics ----------------
    println!("\n[2/2] headline metrics (benchmark scale, in-process numerics)");

    // SpGEMM: C = A^2 on a FEM-style matrix
    let a = gen::generate(Family::BandedFem, 1500, 24000, 42);
    let cpu = measure_budgeted(0.3, 3, || kernels::spgemm(&a, &a));
    let rep = ReapSpgemm::new(FpgaConfig::reap32_spgemm()).run(&a, &a)?;
    let v = verify::verify_csr(&rep.c, &kernels::spgemm(&a, &a));
    anyhow::ensure!(v.ok(1e-6), "SpGEMM verification failed");
    println!(
        "  SpGEMM  {}x{} nnz {:>6}: CPU-1 {:.3} ms | REAP-32 {:.3} ms \
         (cpu pass {:.3} + fpga {:.3}) -> {:.2}x (paper GM 3.2x)",
        a.nrows,
        a.ncols,
        a.nnz(),
        cpu.min_s * 1e3,
        rep.total_s * 1e3,
        rep.cpu_preprocess_s * 1e3,
        rep.fpga_s * 1e3,
        cpu.min_s / rep.total_s
    );

    // Cholesky: LL^T on an SPD FEM matrix
    let lower = gen::spd(Family::BandedFem, 1500, 60000, 7).lower_triangle();
    let pattern = symbolic_factor(&lower);
    let cpu = measure_budgeted(0.3, 3, || {
        kernels::cholesky_numeric(&lower, &pattern).expect("SPD")
    });
    let crep = ReapCholesky::new(FpgaConfig::reap32_cholesky()).run(&lower)?;
    let cv = verify::verify_csc(&crep.factor.l, &kernels::cholesky_numeric(&lower, &pattern)?.l);
    anyhow::ensure!(cv.ok(1e-5), "Cholesky verification failed");
    println!(
        "  Cholesky {0}x{0} nnz(L) {1:>6}: CPU-1 {2:.3} ms | REAP-32 {3:.3} ms \
         (symbolic {4:.3} + fpga {5:.3}) -> {6:.2}x (paper GM 1.18x)",
        lower.nrows,
        crep.factor.l.nnz(),
        cpu.min_s * 1e3,
        crep.total_s * 1e3,
        crep.cpu_symbolic_s * 1e3,
        crep.fpga_s * 1e3,
        cpu.min_s / crep.total_s
    );

    println!("\nquickstart OK — all layers compose.");
    Ok(())
}
