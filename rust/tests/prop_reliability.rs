//! Fault-tolerance properties end to end: the checksummed wire format
//! must turn every single-bit corruption into a typed decode error (no
//! silent wrong answers), truncation and garbage must never panic, and
//! the engine's wave-retry ledger must be exact at every channel depth.
//!
//! The exhaustive flip test runs over a hand-pinned two-bundle stream
//! whose CRC words are literals; all 512 single-bit flips of that stream
//! were verified off-line to fail wire-level validation (header-count and
//! CHECKSUM-flag flips included), so `is_err()` is asserted outright.

use reap::fpga::engine::{execute_waves_at_depth, execute_waves_with_faults, WaveFault};
use reap::fpga::spgemm_sim::{simulate_spgemm, Style};
use reap::fpga::FpgaConfig;
use reap::reliability::draw_wave_faults;
use reap::rir::decode::{try_words_panel_to_dense, try_words_segment_to_csr, try_words_to_csr};
use reap::rir::encode::BundleStream;
use reap::rir::layout::{crc32_words, serialize_stream_checksummed};
use reap::rir::schedule::schedule_spgemm;
use reap::sparse::{gen, Csr};
use reap::util::rng::Pcg64;

/// A 2×10 matrix small enough to pin its entire checksummed wire image.
fn pinned_matrix() -> Csr {
    let mut m = Csr::new(2, 10);
    m.cols = vec![2, 5, 9, 0, 4];
    m.vals = vec![0.5, 1.5, -2.0, 3.25, -0.75];
    m.row_ptr = vec![0, 3, 5];
    m.validate().unwrap();
    m
}

/// The checksummed serialization of [`pinned_matrix`], written out as
/// literals (CRC words included) so the test is independent of the
/// encoder. Layout per ARCHITECTURE.md §3: header `(count << 8) | flags`,
/// shared word, `(index, value-bits)` pairs, trailing CRC32.
fn pinned_words() -> Vec<u32> {
    vec![
        0x0311, 0, // count 3, END_OF_ROW|CHECKSUM; row 0
        2, 0x3F00_0000, // (2, 0.5)
        5, 0x3FC0_0000, // (5, 1.5)
        9, 0xC000_0000, // (9, -2.0)
        0xB7AF_56EF, // CRC32 of the 8 words above
        0x0211, 1, // count 2, END_OF_ROW|CHECKSUM; row 1
        0, 0x4050_0000, // (0, 3.25)
        4, 0xBF40_0000, // (4, -0.75)
        0x9D15_5238, // CRC32 of the 6 words above
    ]
}

#[test]
fn pinned_stream_decodes_and_its_crc_literals_match_the_implementation() {
    let w = pinned_words();
    assert_eq!(crc32_words(&w[0..8]), w[8], "bundle 0 CRC literal");
    assert_eq!(crc32_words(&w[9..15]), w[15], "bundle 1 CRC literal");
    assert_eq!(try_words_to_csr(&w, 2, 10).unwrap(), pinned_matrix());
}

#[test]
fn every_single_bit_flip_of_a_checksummed_stream_is_detected() {
    let words = pinned_words();
    for wi in 0..words.len() {
        for bit in 0..32 {
            let mut fl = words.clone();
            fl[wi] ^= 1u32 << bit;
            assert!(
                try_words_to_csr(&fl, 2, 10).is_err(),
                "flip of word {wi} bit {bit} decoded successfully"
            );
        }
    }
}

#[test]
fn the_unprotected_form_of_the_same_stream_corrupts_silently() {
    // strip the CRC words and clear the CHECKSUM flag: the exact damage
    // the checksummed test detects 100% of now sails through
    let w = pinned_words();
    let mut plain = vec![0x0301, w[1]];
    plain.extend_from_slice(&w[2..8]);
    plain.push(0x0201);
    plain.extend_from_slice(&w[10..15]);
    assert_eq!(try_words_to_csr(&plain, 2, 10).unwrap(), pinned_matrix());
    let mut fl = plain.clone();
    fl[3] ^= 1 << 22; // 0.5 -> 0.75: a one-bit value corruption
    let d = try_words_to_csr(&fl, 2, 10).unwrap();
    assert_ne!(d, pinned_matrix(), "unprotected flip must decode to wrong data");
    assert_eq!(d.vals[0], 0.75);
}

#[test]
fn random_bit_flips_on_random_checksummed_streams_never_decode_wrong() {
    for seed in 0..10u64 {
        let m = gen::power_law(20, 200, seed);
        let s = BundleStream::from_csr(&m, 6);
        let words = serialize_stream_checksummed(&s);
        let mut rng = Pcg64::with_stream(0xB1F0, seed);
        let mut detected = 0usize;
        for _ in 0..64 {
            let mut fl = words.clone();
            let wi = rng.next_below(fl.len() as u64) as usize;
            fl[wi] ^= 1u32 << rng.next_below(32);
            match try_words_to_csr(&fl, m.nrows, m.ncols) {
                Err(_) => detected += 1,
                // a flip may only pass validation if it was semantically
                // invisible — a wrong matrix is silent corruption
                Ok(d) => assert_eq!(d, m, "seed {seed}: silent corruption at word {wi}"),
            }
            match try_words_segment_to_csr(&fl, 0, s.n_bundles(), m.nrows, m.ncols) {
                Err(_) => {}
                Ok(d) => assert_eq!(d, m, "seed {seed}: silent segment corruption"),
            }
        }
        assert!(detected > 0, "seed {seed}: the checksum never fired");
    }
}

#[test]
fn truncation_and_garbage_never_panic_any_decoder() {
    // a combined sparse+panel stream exercises all three decoders
    let m = gen::random_uniform(8, 8, 30, 91);
    let k = 4usize;
    let x: Vec<f32> = (0..m.ncols * k).map(|i| i as f32 * 0.5 - 3.0).collect();
    let mut s = BundleStream::new();
    let boundary = s.encode_csr_with_panel(&m, &x, k, 4);
    let words = serialize_stream_checksummed(&s);
    for cut in 0..=words.len() {
        let w = &words[..cut];
        let _ = try_words_to_csr(w, m.nrows, m.ncols);
        let _ = try_words_segment_to_csr(w, 0, boundary, m.nrows, m.ncols);
        let _ = try_words_panel_to_dense(w, boundary, s.n_bundles(), m.ncols, k);
    }
    // arbitrary word garbage of arbitrary length
    let mut rng = Pcg64::new(0x6A5B);
    for _ in 0..200 {
        let len = rng.next_below(96) as usize;
        let g: Vec<u32> = (0..len).map(|_| rng.next_u64() as u32).collect();
        let _ = try_words_to_csr(&g, 16, 16);
        let _ = try_words_segment_to_csr(&g, 1, 3, 16, 16);
        let _ = try_words_panel_to_dense(&g, 0, 2, 16, 3);
    }
}

/// Emit a real SpGEMM wave-cost sequence to drive the engine properties.
fn spgemm_costs(cfg: &FpgaConfig) -> Vec<reap::fpga::engine::WaveCost> {
    let a = gen::power_law(120, 1800, 3);
    let b = gen::random_uniform(120, 120, 1500, 4);
    let s = schedule_spgemm(&a, &b, cfg.pipelines, cfg.bundle_size);
    simulate_spgemm(&a, &b, &s, cfg, Style::HandCoded).costs
}

#[test]
fn retry_ledger_is_exact_at_every_depth_with_exact_attribution() {
    let cfg = FpgaConfig::reap64_spgemm();
    let costs = spgemm_costs(&cfg);
    assert!(costs.len() >= 8, "workload too small to exercise retries");
    // a deterministic hand-built fault slice: every retry count in range,
    // a sprinkling of exhausted waves
    let faults: Vec<WaveFault> = (0..costs.len())
        .map(|k| WaveFault {
            retries: (k % (cfg.max_wave_retries + 1)) as u64,
            failed: k % 7 == 0,
        })
        .collect();
    let expected_retry: u64 = costs
        .iter()
        .zip(&faults)
        .map(|(c, f)| f.retries * c.serial_cycles(&cfg))
        .sum();
    let expected_failed: Vec<usize> =
        faults.iter().enumerate().filter(|(_, f)| f.failed).map(|(k, _)| k).collect();
    let base1 = execute_waves_at_depth(&costs, &cfg, 1);
    for depth in [1usize, 2, 3] {
        let plain = execute_waves_at_depth(&costs, &cfg, depth);
        let r = execute_waves_with_faults(&costs, &cfg, depth, Some(&faults));
        assert_eq!(r.stats.retry_cycles, expected_retry, "depth {depth}: retry ledger");
        assert_eq!(
            r.stats.cycles,
            plain.stats.cycles + expected_retry,
            "depth {depth}: cycles(faults) == cycles(no faults) + retry_cycles"
        );
        assert_eq!(r.failed_waves, expected_failed, "depth {depth}: attribution");
        // DRAM traffic, flops and wave counts are fault-invariant: time
        // is charged for replays, refetched bytes are not re-counted
        assert_eq!(r.stats.bytes_read, plain.stats.bytes_read, "depth {depth}");
        assert_eq!(r.stats.bytes_written, plain.stats.bytes_written, "depth {depth}");
        assert_eq!(r.stats.flops, plain.stats.flops, "depth {depth}");
        assert_eq!(r.stats.waves, plain.stats.waves, "depth {depth}");
        // the depth ledger holds under a fixed fault slice too
        let base_f = execute_waves_with_faults(&costs, &cfg, 1, Some(&faults));
        assert_eq!(
            r.stats.cycles + r.stats.prefetch_hidden_cycles,
            base_f.stats.cycles,
            "depth {depth}: hidden-cycle ledger under faults"
        );
        assert_eq!(
            r.stats.prefetch_hidden_cycles, plain.stats.prefetch_hidden_cycles,
            "depth {depth}: hidden cycles are fault-invariant"
        );
        assert_eq!(base_f.stats.cycles, base1.stats.cycles + expected_retry);
    }
}

#[test]
fn zero_fault_rate_draw_reproduces_the_plain_engine_at_every_depth() {
    let cfg = FpgaConfig::reap64_spgemm();
    let costs = spgemm_costs(&cfg);
    let faults = draw_wave_faults(0xFEED, costs.len(), 0.0, cfg.max_wave_retries);
    assert!(faults.iter().all(|f| *f == WaveFault::default()));
    for depth in [1usize, 2, 3] {
        let plain = execute_waves_at_depth(&costs, &cfg, depth);
        let r = execute_waves_with_faults(&costs, &cfg, depth, Some(&faults));
        assert_eq!(r.stats, plain.stats, "depth {depth}");
        assert_eq!(r.item_cycles, plain.item_cycles, "depth {depth}");
        assert!(r.failed_waves.is_empty(), "depth {depth}");
    }
}

#[test]
fn total_fault_rate_exhausts_every_wave_deterministically() {
    let cfg = FpgaConfig::reap64_spgemm();
    let costs = spgemm_costs(&cfg);
    let max = cfg.max_wave_retries as u64;
    let faults = draw_wave_faults(0xFEED, costs.len(), 1.0, cfg.max_wave_retries);
    assert!(faults.iter().all(|f| f.retries == max && f.failed));
    let plain = execute_waves_at_depth(&costs, &cfg, 1);
    let r = execute_waves_with_faults(&costs, &cfg, 1, Some(&faults));
    let expected_retry: u64 = costs.iter().map(|c| max * c.serial_cycles(&cfg)).sum();
    assert_eq!(r.stats.retry_cycles, expected_retry);
    assert_eq!(r.stats.cycles, plain.stats.cycles + expected_retry);
    assert_eq!(r.failed_waves, (0..costs.len()).collect::<Vec<_>>());
}
