//! Integration: the RIR contract end-to-end — the compress/layout/schedule
//! path the CPU runs, consumed by both the simulator and the decoder, with
//! malformed-stream failure injection (what a hardened input controller
//! must reject).

use reap::rir::bundle::{Bundle, BundleFlags};
use reap::rir::{decode, encode, layout, schedule};
use reap::sparse::gen::{self, Family};
use reap::sparse::{Csc, Csr};

#[test]
fn csr_and_csc_encodings_are_consistent() {
    let m = gen::random_uniform(40, 40, 500, 1);
    let csc: Csc = m.to_csc();
    let row_bundles = encode::csr_to_bundles(&m, 32);
    let col_bundles = encode::csc_to_bundles(&csc, 32);
    // same total element count, transposed shared features
    let row_elems: usize = row_bundles.iter().map(|b| b.len()).sum();
    let col_elems: usize = col_bundles.iter().map(|b| b.len()).sum();
    assert_eq!(row_elems, m.nnz());
    assert_eq!(col_elems, m.nnz());
}

#[test]
fn stream_words_match_schedule_accounting() {
    // the schedule's a_words must equal the actual serialized A stream
    let a = gen::power_law(60, 900, 2);
    let b = gen::random_uniform(60, 60, 700, 3);
    let s = schedule::schedule_spgemm(&a, &b, 8, 32);
    let a_bundles = encode::csr_to_bundles(&a, 32);
    let a_stream_words: usize = a_bundles.iter().map(layout::bundle_words).sum();
    // schedule skips empty rows; csr_to_bundles emits a header for them
    let empty_rows = (0..a.nrows).filter(|&i| a.row_nnz(i) == 0).count();
    assert_eq!(s.a_words + 2 * empty_rows, a_stream_words);
}

#[test]
fn wave_b_streams_reassemble_to_b_rows() {
    // decode each wave's B stream and check it delivers exactly the rows
    // the wave needs, in ascending order
    let a = gen::random_uniform(30, 30, 250, 4);
    let b = gen::random_uniform(30, 30, 300, 5);
    let s = schedule::schedule_spgemm(&a, &b, 4, 16);
    for w in &s.waves {
        let bundles = encode::csr_rows_to_bundles(&b, &w.b_rows, 16);
        // every chain ends with END_OF_ROW; shared features = b_rows order
        let mut rows_seen = Vec::new();
        for bu in &bundles {
            if bu.flags.end_of_row() {
                rows_seen.push(bu.shared);
            }
        }
        assert_eq!(rows_seen, w.b_rows);
        // and the elements match the source rows
        let total: usize = bundles.iter().map(|bu| bu.len()).sum();
        let expect: usize = w.b_rows.iter().map(|&r| b.row_nnz(r as usize)).sum();
        assert_eq!(total, expect);
    }
}

#[test]
fn corrupted_streams_rejected() {
    let m = gen::random_uniform(10, 10, 40, 6);
    let bundles = encode::csr_to_bundles(&m, 8);
    let words = layout::serialize(&bundles);

    // truncation
    assert!(layout::deserialize(&words[..words.len() - 1]).is_err());

    // inflated element count in a header
    let mut bad = words.clone();
    bad[0] = bad[0].wrapping_add(200 << 8);
    assert!(layout::deserialize(&bad).is_err());

    // decode-level: out-of-bounds column index
    let evil = vec![Bundle::data(
        0,
        vec![10_000],
        vec![1.0],
        BundleFlags::default().with(BundleFlags::END_OF_ROW),
    )];
    assert!(decode::bundles_to_csr(&evil, 10, 10).is_err());

    // decode-level: row index beyond matrix
    let evil = vec![Bundle::data(
        99,
        vec![0],
        vec![1.0],
        BundleFlags::default().with(BundleFlags::END_OF_ROW),
    )];
    assert!(decode::bundles_to_csr(&evil, 10, 10).is_err());
}

#[test]
fn bundle_size_sweep_preserves_roundtrip_and_traffic_monotonicity() {
    let m = gen::banded_fem(80, 1200, 7);
    let mut prev_words = usize::MAX;
    for bundle in [1usize, 2, 4, 8, 16, 32, 64] {
        let bundles = encode::csr_to_bundles(&m, bundle);
        let words = layout::serialize(&bundles);
        let back =
            decode::bundles_to_csr(&layout::deserialize(&words).unwrap(), m.nrows, m.ncols)
                .unwrap();
        assert_eq!(back, m, "bundle {bundle}");
        // larger bundles amortize headers: stream never grows
        assert!(words.len() <= prev_words, "bundle {bundle} grew the stream");
        prev_words = words.len();
    }
}

#[test]
fn empty_matrix_stream_is_headers_only() {
    let m = Csr::new(5, 5);
    let bundles = encode::csr_to_bundles(&m, 32);
    assert_eq!(bundles.len(), 5);
    assert!(bundles.iter().all(|b| b.is_empty() && b.flags.end_of_row()));
    let words = layout::serialize(&bundles);
    assert_eq!(words.len(), 10); // 2 words per empty chain
    let back = decode::bundles_to_csr(&bundles, 5, 5).unwrap();
    assert_eq!(back, m);
}
