//! Determinism suite for the serving runtime: fixed seeds pin the
//! arrival trace, the admission decisions and the latency percentiles
//! *exactly* — two runs of one spec are bitwise identical, every thread
//! count produces the same report, and turning the schedule cache on
//! changes timing only (schedules and numerics replay bit-identically).

use reap::fpga::FpgaConfig;
use reap::serving::{
    generate_workload, run_serving, ArrivalProcess, ServingConfig, ServingReport, WorkloadSpec,
};

fn spec(seed: u64, n_jobs: usize, repeat_ratio: f64) -> WorkloadSpec {
    WorkloadSpec::poisson(seed, n_jobs, 30_000.0, repeat_ratio)
}

fn run(cfg: &ServingConfig, spec: &WorkloadSpec) -> ServingReport {
    run_serving(cfg, &generate_workload(spec)).expect("serving run")
}

/// Bitwise equality of everything a report pins: per-job latencies in
/// order, both digests, cycle totals and the full admission log.
fn assert_reports_identical(a: &ServingReport, b: &ServingReport) {
    assert_eq!(a.latencies_s, b.latencies_s, "per-job latencies must be bit-identical");
    assert_eq!(a.schedule_digest, b.schedule_digest);
    assert_eq!(a.output_digest, b.output_digest);
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.cycles_serial, b.cycles_serial);
    assert_eq!(a.cycles_db, b.cycles_db);
    assert_eq!(a.log, b.log, "admission log must be bit-identical");
    assert_eq!(a.p50_s, b.p50_s);
    assert_eq!(a.p95_s, b.p95_s);
    assert_eq!(a.p99_s, b.p99_s);
}

#[test]
fn fixed_seed_pins_the_arrival_trace() {
    let s = spec(0x5EA9_0001, 50, 0.6);
    let w1 = generate_workload(&s);
    let w2 = generate_workload(&s);
    assert_eq!(w1.len(), w2.len());
    for (a, b) in w1.iter().zip(&w2) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tenant, b.tenant);
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits(), "job {}", a.id);
        assert_eq!(a.a, b.a, "job {}: operand A must regenerate exactly", a.id);
        assert_eq!(a.b, b.b, "job {}: operand B must regenerate exactly", a.id);
    }
}

#[test]
fn two_identical_runs_are_bitwise_identical() {
    let s = spec(0x5EA9_0002, 40, 0.7);
    let mut cfg = ServingConfig::new(FpgaConfig::reap64_spgemm());
    cfg.verify_numerics = true;
    assert_reports_identical(&run(&cfg, &s), &run(&cfg, &s));
}

#[test]
fn reports_are_invariant_across_thread_counts() {
    let s = spec(0x5EA9_0003, 36, 0.5);
    let mut base = ServingConfig::new(FpgaConfig::reap64_spgemm());
    base.verify_numerics = true;
    base.threads = 1;
    let reference = run(&base, &s);
    assert!(reference.log.admitted > 0, "premise: the workload admits jobs");
    for threads in [2, 4, 8] {
        let mut cfg = base.clone();
        cfg.threads = threads;
        let rep = run(&cfg, &s);
        assert_reports_identical(&reference, &rep);
    }
}

#[test]
fn cache_replays_bit_identically_and_strictly_faster_on_wide_designs() {
    let s = spec(0x5EA9_0004, 48, 0.9);
    for fpga in [FpgaConfig::reap64_spgemm(), FpgaConfig::reap128_spgemm()] {
        let name = fpga.name;
        let mut on = ServingConfig::new(fpga);
        on.verify_numerics = true;
        let mut off = on.clone();
        off.use_cache = false;
        let r_on = run(&on, &s);
        let r_off = run(&off, &s);
        assert_eq!(r_on.schedule_digest, r_off.schedule_digest, "{name}: schedules must match");
        assert_eq!(r_on.output_digest, r_off.output_digest, "{name}: numerics must match");
        assert_eq!(r_on.cycles, r_off.cycles, "{name}: cache must not change FPGA work");
        assert_eq!(r_on.log.admitted, r_off.log.admitted, "{name}: admission is cache-blind");
        assert!(r_on.hits > 0, "{name}: a 0.9 repeat ratio must produce hits");
        assert!(
            r_on.mean_s < r_off.mean_s,
            "{name}: hit-path latency must be strictly lower ({} vs {})",
            r_on.mean_s,
            r_off.mean_s
        );
        assert!(r_on.p50_s <= r_off.p50_s, "{name}: p50 must not regress under caching");
    }
}

#[test]
fn admission_decisions_are_pinned_by_the_budget() {
    let s = spec(0x5EA9_0005, 20, 0.5);
    // a budget no job can meet: everything is shed, nothing executes
    let mut strangled = ServingConfig::new(FpgaConfig::reap64_spgemm());
    strangled.admission.latency_budget_s = 1e-9;
    let rep = run(&strangled, &s);
    assert_eq!(rep.log.admitted, 0);
    assert_eq!(rep.log.rejected, 20);
    assert!(rep.log.batches.is_empty());

    // a generous budget: everything is admitted, nothing is shed
    let mut generous = ServingConfig::new(FpgaConfig::reap64_spgemm());
    generous.admission.latency_budget_s = 10.0;
    let rep = run(&generous, &s);
    assert_eq!(rep.log.admitted, 20);
    assert_eq!(rep.log.rejected, 0);
    assert_eq!(rep.log.queued, 0);
    assert_eq!(rep.latencies_s.len(), 20);
    assert!(rep.latencies_s.iter().all(|&(_, l)| l > 0.0), "latency is always positive");
}

#[test]
fn bursty_and_replayed_traces_run_deterministically() {
    for process in [
        ArrivalProcess::BurstyOnOff { rate_hz: 50_000.0, burst: 6, idle_s: 5e-4 },
        ArrivalProcess::Trace { inter_arrival_s: vec![3e-5, 8e-5, 2e-4] },
    ] {
        let s = WorkloadSpec { process, ..spec(0x5EA9_0006, 30, 0.6) };
        let cfg = ServingConfig::new(FpgaConfig::reap64_spgemm());
        let r1 = run(&cfg, &s);
        let r2 = run(&cfg, &s);
        assert_reports_identical(&r1, &r2);
        assert_eq!(
            r1.log.admitted + r1.log.rejected + r1.log.queued,
            r1.log.arrived,
            "conservation"
        );
    }
}
