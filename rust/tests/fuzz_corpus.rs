//! Replays the checked-in libFuzzer seed corpus (`fuzz/corpus/<target>/`)
//! through the same `reap::reliability::fuzz_decode_*` drivers the fuzz
//! targets call — so the corpus is exercised on every stable-toolchain
//! test run, not only when the nightly fuzz job fires. Each driver must
//! simply return on every input; any panic fails the test.

use std::fs;
use std::path::PathBuf;

fn corpus_dir(target: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("fuzz")
        .join("corpus")
        .join(target)
}

fn replay(target: &str, driver: fn(&[u8])) {
    let dir = corpus_dir(target);
    let entries =
        fs::read_dir(&dir).unwrap_or_else(|e| panic!("corpus dir {}: {e}", dir.display()));
    let mut n = 0usize;
    for entry in entries {
        let path = entry.expect("corpus entry").path();
        if !path.is_file() {
            continue;
        }
        let bytes = fs::read(&path).expect("corpus file");
        driver(&bytes);
        // the drivers must also hold on every prefix of a seed (cheap
        // truncation sweep — the corpus files are tiny)
        for cut in 0..bytes.len().min(64) {
            driver(&bytes[..cut]);
        }
        n += 1;
    }
    assert!(n > 0, "empty corpus for `{target}` — seeds must be checked in");
}

#[test]
fn corpus_decode_stream_never_panics() {
    replay("decode_stream", reap::reliability::fuzz_decode_stream);
}

#[test]
fn corpus_decode_segment_never_panics() {
    replay("decode_segment", reap::reliability::fuzz_decode_segment);
}

#[test]
fn corpus_decode_panel_never_panics() {
    replay("decode_panel", reap::reliability::fuzz_decode_panel);
}
