//! Replays the checked-in libFuzzer seed corpus (`fuzz/corpus/<target>/`)
//! through the same `reap::reliability::fuzz_decode_*` /
//! `fuzz_lint_stream` drivers the fuzz targets call — so the corpus is
//! exercised on every stable-toolchain test run, not only when the
//! nightly fuzz job fires. Each driver must simply return on every
//! input; any panic fails the test.
//!
//! The corpus covers every wire layout: raw pairs, checksummed bundles,
//! BITMAP index sections, FIXED_POINT value lanes, and the combined
//! BITMAP+FIXED_POINT+CHECKSUM form. The `seed_*` files for the
//! compressed encodings are additionally pinned to *decode successfully*
//! (not merely not panic) so mutation always starts from inputs that
//! reach the expander, and a refactor that breaks sectioned decoding
//! can't hide behind the no-panic contract.

use std::fs;
use std::path::PathBuf;

fn corpus_dir(target: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("fuzz")
        .join("corpus")
        .join(target)
}

fn replay(target: &str, driver: fn(&[u8])) {
    let dir = corpus_dir(target);
    let entries =
        fs::read_dir(&dir).unwrap_or_else(|e| panic!("corpus dir {}: {e}", dir.display()));
    let mut n = 0usize;
    for entry in entries {
        let path = entry.expect("corpus entry").path();
        if !path.is_file() {
            continue;
        }
        let bytes = fs::read(&path).expect("corpus file");
        driver(&bytes);
        // the drivers must also hold on every prefix of a seed (cheap
        // truncation sweep — the corpus files are tiny)
        for cut in 0..bytes.len().min(64) {
            driver(&bytes[..cut]);
        }
        n += 1;
    }
    assert!(n > 0, "empty corpus for `{target}` — seeds must be checked in");
}

#[test]
fn corpus_decode_stream_never_panics() {
    replay("decode_stream", reap::reliability::fuzz_decode_stream);
}

#[test]
fn corpus_decode_segment_never_panics() {
    replay("decode_segment", reap::reliability::fuzz_decode_segment);
}

#[test]
fn corpus_decode_panel_never_panics() {
    replay("decode_panel", reap::reliability::fuzz_decode_panel);
}

/// The static stream auditor (`reap lint`'s RIR pass) shares the
/// decoder corpus: it walks the same wire layouts without touching
/// values, and must be total — diagnostics out, never a panic.
#[test]
fn corpus_lint_stream_never_panics() {
    replay("lint_stream", reap::reliability::fuzz_lint_stream);
}

/// Little-endian u32 words of a corpus file (the drivers' framing).
fn seed_words(target: &str, name: &str) -> Vec<u32> {
    let bytes = fs::read(corpus_dir(target).join(name))
        .unwrap_or_else(|e| panic!("seed {target}/{name}: {e}"));
    assert_eq!(bytes.len() % 4, 0, "seed {target}/{name} is not word-aligned");
    bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// The compressed-encoding seeds must *decode successfully* — they exist
/// to put the BITMAP expander and Q1.15 lane on the mutation frontier,
/// which only works if the unmutated seed reaches those paths.
#[test]
fn compressed_seeds_decode_successfully() {
    use reap::rir::decode::{try_words_panel_to_dense, try_words_segment_to_csr, try_words_to_csr};
    use reap::rir::layout::fx_max_abs_error;

    // BITMAP bundle: cols {4..=7, 36..=39}, raw f32 values 1.0..=8.0.
    let w = seed_words("decode_stream", "seed_bitmap");
    let m = try_words_to_csr(&w, 0x821, 100).expect("seed_bitmap decodes");
    assert_eq!(m.cols, vec![4, 5, 6, 7, 36, 37, 38, 39]);
    assert_eq!(m.vals, (1..=8).map(|i| i as f32).collect::<Vec<_>>());

    // FIXED_POINT bundle: cols [0,5,9], values [0.5, -1.0, 0.25] @ scale 1.
    let w = seed_words("decode_stream", "seed_fx");
    let m = try_words_to_csr(&w, 0x341, 50).expect("seed_fx decodes");
    assert_eq!(m.cols, vec![0, 5, 9]);
    let bound = fx_max_abs_error(1.0);
    for (got, want) in m.vals.iter().zip([0.5f32, -1.0, 0.25]) {
        assert!((f64::from(*got) - f64::from(want)).abs() <= bound);
    }

    // BITMAP + FIXED_POINT + CHECKSUM: same column set, values i @ scale 8.
    let w = seed_words("decode_stream", "seed_bitmap_fx_crc");
    let m = try_words_to_csr(&w, 0x871, 100).expect("seed_bitmap_fx_crc decodes");
    assert_eq!(m.cols, vec![4, 5, 6, 7, 36, 37, 38, 39]);
    let bound = fx_max_abs_error(8.0);
    for (i, got) in m.vals.iter().enumerate() {
        assert!((f64::from(*got) - (i as f64 + 1.0)).abs() <= bound);
    }

    // Segment seed: bundles [2,4) hold an fx row (5) and a bitmap row (6);
    // the four leading parameter words double as two benign empty bundles.
    let w = seed_words("decode_segment", "seed_bitmap_fx");
    let m = try_words_segment_to_csr(&w, 2, 4, 8, 64).expect("segment seed decodes");
    assert_eq!(m.row_ptr[5..=7], [0, 3, 11]);
    assert_eq!(&m.cols[3..], &[4, 5, 6, 7, 36, 37, 38, 39]);

    // Panel seed: one DENSE_PANEL fx bundle, row 0, lanes 0..4 of k=4.
    let w = seed_words("decode_panel", "seed_fx_panel");
    let d = try_words_panel_to_dense(&w, 2, 3, 8, 4).expect("panel seed decodes");
    assert_eq!(d.len(), 8 * 4);
    let bound = fx_max_abs_error(1.0);
    for (got, want) in d[..4].iter().zip([0.5f32, -1.0, 0.25, 1.0]) {
        assert!((f64::from(*got) - f64::from(want)).abs() <= bound);
    }
    assert!(d[4..].iter().all(|v| *v == 0.0));
}
