//! Golden regression tests for the unified wave engine
//! ([`reap::fpga::engine`]): the depth-1 channel must reproduce the
//! pre-refactor serial accounting **bit-identically** for all four
//! workloads (SpGEMM, SpMV, SpMM, Cholesky) plus the batched path, and
//! the depth-2 channel must be monotonically no slower with identical
//! DRAM traffic.
//!
//! The fault-aware entry point (`execute_waves_with_faults`) with an
//! all-zero fault slice must also collapse to the plain path
//! bit-identically — fault tolerance is free when nothing faults.
//!
//! The pre-refactor model is pinned *independently* of the engine: each
//! simulator's emitted [`WaveCost`] sequence is re-priced here with the
//! raw serial formula `max(setup + compute, max(read, write))` (at least
//! one cycle per compute wave) straight from [`DramModel`], and the
//! depth-1 engine output must match it wave for wave. That formula is,
//! by construction, exactly what `spgemm_sim`/`spmv_sim`/`spmm_sim`/
//! `cholesky_sim` hand-rolled before the engine existed.

use reap::fpga::cholesky_sim::simulate_cholesky;
use reap::fpga::dram::DramModel;
use reap::fpga::engine::{
    execute_waves_at_depth, execute_waves_with_faults, WaveCost, WaveFault, WaveKind,
};
use reap::fpga::spgemm_sim::{simulate_spgemm, simulate_spgemm_batch, Style};
use reap::fpga::spmm_sim::simulate_spmm;
use reap::fpga::spmv_sim::simulate_spmv;
use reap::fpga::{FpgaConfig, SimStats};
use reap::rir::schedule::{schedule_spgemm, schedule_spgemm_batch};
use reap::sparse::{gen, Csr};
use reap::symbolic::CholeskySymbolic;
use reap::testing::prop;

const WORD_BYTES: u64 = reap::rir::layout::WORD_BYTES as u64;

/// The pre-refactor serial wave cost, re-derived from first principles.
fn serial_cost(c: &WaveCost, cfg: &FpgaConfig) -> u64 {
    let read = DramModel::read_cycles(cfg, c.stream_words * WORD_BYTES);
    let write = DramModel::write_cycles(cfg, c.writeback_words * WORD_BYTES);
    let cy = (c.setup_cycles + c.compute_cycles).max(read.max(write));
    match c.kind {
        WaveKind::Compute => cy.max(1),
        WaveKind::Load => cy,
    }
}

/// Assert the full depth-1 ≡ serial contract and the depth-2 laws for one
/// emitted cost sequence whose depth-1 stats are `stats_d1`.
fn check_contract(costs: &[WaveCost], cfg: &FpgaConfig, stats_d1: &SimStats, what: &str) {
    assert_eq!(cfg.dram_buffer_depth, 1, "{what}: golden configs are serial");
    // depth 1: bit-identical to the independent serial formula, per wave
    let d1 = execute_waves_at_depth(costs, cfg, 1);
    let serial: Vec<u64> = costs.iter().map(|c| serial_cost(c, cfg)).collect();
    assert_eq!(d1.item_cycles, serial, "{what}: depth-1 wave costs");
    assert_eq!(&d1.stats, stats_d1, "{what}: simulate() must report depth-1 stats");
    assert_eq!(d1.stats.cycles, serial.iter().sum::<u64>(), "{what}: totals");
    assert_eq!(d1.stats.prefetch_hidden_cycles, 0, "{what}: depth 1 hides nothing");

    // depth 2+: monotone cycles, exact hidden-cycle ledger, invariant
    // traffic/flops/waves
    let mut prev = d1.stats.cycles;
    for depth in [2usize, 3] {
        let r = execute_waves_at_depth(costs, cfg, depth);
        assert!(r.stats.cycles <= prev, "{what}: depth {depth} regressed");
        assert_eq!(
            r.stats.cycles + r.stats.prefetch_hidden_cycles,
            d1.stats.cycles,
            "{what}: depth {depth} hidden-cycle ledger"
        );
        assert_eq!(r.stats.bytes_read, d1.stats.bytes_read, "{what}: read traffic");
        assert_eq!(r.stats.bytes_written, d1.stats.bytes_written, "{what}: write traffic");
        assert_eq!(r.stats.flops, d1.stats.flops, "{what}: flops");
        assert_eq!(r.stats.waves, d1.stats.waves, "{what}: waves");
        prev = r.stats.cycles;
    }

    // the fault-aware entry point with a present-but-all-zero fault slice
    // must collapse to the plain path bit-identically at every depth,
    // with an empty retry ledger
    let zeros = vec![WaveFault::default(); costs.len()];
    for depth in [1usize, 2, 3] {
        let plain = execute_waves_at_depth(costs, cfg, depth);
        let faulted = execute_waves_with_faults(costs, cfg, depth, Some(&zeros));
        assert_eq!(faulted.stats, plain.stats, "{what}: zero-fault stats, depth {depth}");
        assert_eq!(faulted.item_cycles, plain.item_cycles, "{what}: zero-fault waves, d{depth}");
        assert!(faulted.failed_waves.is_empty(), "{what}: zero-fault failures, depth {depth}");
        assert_eq!(faulted.stats.retry_cycles, 0, "{what}: zero-fault ledger, depth {depth}");
    }
}

fn spgemm_designs() -> [FpgaConfig; 2] {
    [FpgaConfig::reap64_spgemm(), FpgaConfig::reap128_spgemm()]
}

#[test]
fn spgemm_depth1_is_the_serial_model_and_depth2_strictly_wins() {
    for seed in [7u64, 1959] {
        let a = gen::power_law(300, 5400, seed);
        let b = gen::random_uniform(300, 300, 4200, seed + 1);
        for cfg in spgemm_designs() {
            let s = schedule_spgemm(&a, &b, cfg.pipelines, cfg.bundle_size);
            let r = simulate_spgemm(&a, &b, &s, &cfg, Style::HandCoded);
            check_contract(&r.costs, &cfg, &r.stats, cfg.name);
            // multi-wave run: the per-wave CAM setup hides -> strict win
            let d2 = execute_waves_at_depth(&r.costs, &cfg, 2).stats;
            assert!(
                d2.cycles < r.stats.cycles && d2.prefetch_hidden_cycles > 0,
                "{} seed {seed}: depth 2 must strictly win ({} !< {})",
                cfg.name,
                d2.cycles,
                r.stats.cycles
            );
        }
    }
}

#[test]
fn batch_depth1_is_the_serial_model_and_depth2_strictly_wins() {
    let jobs: Vec<(Csr, Csr)> = (0..10u64)
        .map(|j| {
            let n = 30 + (j as usize * 13) % 50;
            (
                gen::power_law(n, n * 6, 400 + j),
                gen::random_uniform(n, n, n * 6, 500 + j),
            )
        })
        .collect();
    for cfg in spgemm_designs() {
        let s = schedule_spgemm_batch(&jobs, cfg.pipelines, cfg.bundle_size);
        let r = simulate_spgemm_batch(&jobs, &s, &cfg, Style::HandCoded);
        check_contract(&r.costs, &cfg, &r.stats, cfg.name);
        let d2 = execute_waves_at_depth(&r.costs, &cfg, 2).stats;
        assert!(
            d2.cycles < r.stats.cycles && d2.prefetch_hidden_cycles > 0,
            "{}: batched depth 2 must strictly win",
            cfg.name
        );
    }
}

#[test]
fn spmv_depth1_is_the_serial_model() {
    let a = gen::banded_fem(500, 4500, 11);
    for cfg in spgemm_designs() {
        let s = schedule_spgemm(&a, &Csr::new(a.ncols, a.ncols), cfg.pipelines, cfg.bundle_size);
        let r = simulate_spmv(&a, &s, &cfg, Style::HandCoded);
        check_contract(&r.costs, &cfg, &r.stats, cfg.name);
    }
}

#[test]
fn spmm_depth1_is_the_serial_model_and_depth2_strictly_wins() {
    let a = gen::banded_fem(400, 3600, 13);
    for cfg in spgemm_designs() {
        let s = schedule_spgemm(&a, &Csr::new(a.ncols, a.ncols), cfg.pipelines, cfg.bundle_size);
        for k in [4usize, 8, 20] {
            let r = simulate_spmm(&a, &s, &cfg, Style::HandCoded, k);
            check_contract(&r.costs, &cfg, &r.stats, cfg.name);
            let d2 = execute_waves_at_depth(&r.costs, &cfg, 2).stats;
            assert!(
                d2.cycles < r.stats.cycles && d2.prefetch_hidden_cycles > 0,
                "{} k {k}: depth 2 must strictly win",
                cfg.name
            );
        }
    }
}

#[test]
fn cholesky_depth1_is_the_serial_model() {
    let spd = gen::spd(gen::Family::BandedFem, 120, 900, 17);
    let lower = spd.lower_triangle();
    for cfg in [FpgaConfig::reap32_cholesky(), FpgaConfig::reap64_cholesky()] {
        let sym = CholeskySymbolic::analyze(&lower, cfg.bundle_size);
        let r = simulate_cholesky(&sym, &cfg, Style::HandCoded);
        check_contract(&r.costs, &cfg, &r.stats, cfg.name);
        // column k+1's L-row reads include column k's writeback (RAW
        // through DRAM), so the Cholesky stream marks itself
        // `dependent_stream` and gains nothing from prefetch: depth 2 is
        // exactly depth 1, not merely monotone
        let d2 = execute_waves_at_depth(&r.costs, &cfg, 2).stats;
        assert_eq!(d2, r.stats);
        assert!(r.costs.iter().all(|c| c.dependent_stream));
    }
}

#[test]
fn prop_depth1_serial_equivalence_and_depth2_laws_all_workloads() {
    prop::quickcheck("engine depth laws over random workloads", |rng, size| {
        let n = 16 + size.0 * 6;
        let nnz = n * (3 + (rng.next_below(5) as usize));
        let seed = rng.next_u64();
        let a = match rng.next_below(3) {
            0 => gen::random_uniform(n, n, nnz, seed),
            1 => gen::power_law(n, nnz, seed),
            _ => gen::banded_fem(n, nnz, seed),
        };
        let cfg = if rng.next_below(2) == 0 {
            FpgaConfig::reap64_spgemm()
        } else {
            FpgaConfig::reap128_spgemm()
        };
        let style = if rng.next_below(4) == 0 { Style::HlsPreprocessed } else { Style::HandCoded };

        // SpGEMM (C = A^2)
        let s = schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
        let r = simulate_spgemm(&a, &a, &s, &cfg, style);
        check_contract(&r.costs, &cfg, &r.stats, "prop spgemm");

        // SpMV / SpMM over the surrogate schedule
        let sv = schedule_spgemm(&a, &Csr::new(n, n), cfg.pipelines, cfg.bundle_size);
        let rv = simulate_spmv(&a, &sv, &cfg, style);
        check_contract(&rv.costs, &cfg, &rv.stats, "prop spmv");
        let k = 1 + rng.next_below(17) as usize;
        let rm = simulate_spmm(&a, &sv, &cfg, style, k);
        check_contract(&rm.costs, &cfg, &rm.stats, "prop spmm");

        // Cholesky on an SPD-ified clone
        let spd = gen::spd(gen::Family::BandedFem, n, nnz, seed ^ 0xC0DE);
        let sym = CholeskySymbolic::analyze(&spd.lower_triangle(), cfg.bundle_size);
        let rc = simulate_cholesky(&sym, &FpgaConfig::reap64_cholesky(), style);
        check_contract(&rc.costs, &FpgaConfig::reap64_cholesky(), &rc.stats, "prop cholesky");
    });
}

#[test]
fn single_job_batch_matches_plain_sim_at_every_depth() {
    let a = gen::random_uniform(80, 80, 900, 77);
    let b = gen::random_uniform(80, 80, 900, 78);
    for depth in [1usize, 2, 3] {
        let cfg = FpgaConfig { dram_buffer_depth: depth, ..FpgaConfig::reap64_spgemm() };
        let jobs = vec![(a.clone(), b.clone())];
        let bs = schedule_spgemm_batch(&jobs, cfg.pipelines, cfg.bundle_size);
        let solo = schedule_spgemm(&a, &b, cfg.pipelines, cfg.bundle_size);
        let rb = simulate_spgemm_batch(&jobs, &bs, &cfg, Style::HandCoded);
        let rs = simulate_spgemm(&a, &b, &solo, &cfg, Style::HandCoded);
        assert_eq!(rb.stats, rs.stats, "depth {depth}");
        assert_eq!(rb.wave_cycles, rs.wave_cycles, "depth {depth}");
        assert_eq!(rb.costs, rs.costs, "depth {depth}");
    }
}
