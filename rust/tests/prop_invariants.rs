//! Property-based invariants over the coordinator substrates (routing,
//! batching, state) — the randomized counterpart of the unit suites, run
//! through the in-tree `testing::prop` framework. Replay any failure with
//! `PROP_SEED=<seed> cargo test --test prop_invariants`.

use reap::coordinator::spgemm::numeric_scheduled;
use reap::coordinator::ReapSpgemm;
use reap::fpga::spgemm_sim::{simulate_spgemm, Style};
use reap::fpga::FpgaConfig;
use reap::kernels::{spgemm, spgemm_parallel};
use reap::rir::{decode, encode, layout, schedule};
use reap::sparse::gen::{self, Family};
use reap::sparse::{Csr, Idx};
use reap::symbolic::{symbolic_factor, CholeskySymbolic};
use reap::testing::{check, Config, Size};
use reap::util::Pcg64;

fn random_family(rng: &mut Pcg64) -> Family {
    match rng.next_below(5) {
        0 => Family::RandomUniform,
        1 => Family::BandedFem,
        2 => Family::PowerLaw,
        3 => Family::BlockRandom,
        _ => Family::ZipfAdversarial,
    }
}

/// A skew-heavy family — the inputs where static band partitions are most
/// wrong, hence where work-stealing determinism needs the hardest pinning.
fn skewed_family(rng: &mut Pcg64) -> Family {
    if rng.range(0, 2) == 0 {
        Family::PowerLaw
    } else {
        Family::ZipfAdversarial
    }
}

fn random_matrix(rng: &mut Pcg64, size: Size) -> Csr {
    let n = 4 + rng.range(0, 4 * size.0 + 4);
    let nnz = rng.range(0, (n * n / 2).max(2));
    gen::generate(random_family(rng), n, nnz.max(1), rng.next_u64())
}

/// RIR compress → DRAM layout → decompress is the identity on CSR.
#[test]
fn prop_rir_roundtrip_through_dram_words() {
    check("rir roundtrip", Config::default(), |rng, size| {
        let m = random_matrix(rng, size);
        let bundle = 1 + rng.range(0, 40);
        let bundles = encode::csr_to_bundles(&m, bundle);
        let words = layout::serialize(&bundles);
        let back = decode::bundles_to_csr(&layout::deserialize(&words).unwrap(), m.nrows, m.ncols)
            .unwrap();
        assert_eq!(back, m);
    });
}

/// Every `BundleStream` encoder round-trips through the serialized DRAM
/// word layout: single-matrix, job-segmented (multi-tenant) and
/// sparse + dense-panel (SpMM) streams all deserialize back to their
/// sources — including empty matrices, empty jobs and zero-width panels.
#[test]
fn prop_stream_encoders_roundtrip_through_dram_words() {
    check("stream encoders roundtrip", Config { cases: 24, ..Config::default() }, |rng, size| {
        let bundle = 1 + rng.range(0, 40);

        // ---- single-matrix encode (empty matrix at case boundary) ----
        let m = if rng.range(0, 8) == 0 {
            Csr::new(0, 3)
        } else {
            random_matrix(rng, size)
        };
        let s = encode::BundleStream::from_csr(&m, bundle);
        let back = decode::bundles_to_csr(
            &layout::deserialize(&layout::serialize_stream(&s)).unwrap(),
            m.nrows,
            m.ncols,
        )
        .unwrap();
        assert_eq!(back, m, "single-matrix");
        assert_eq!(decode::stream_to_csr(&s, m.nrows, m.ncols).unwrap(), m);

        // ---- job-segmented encode (with a possibly-empty tenant) ----
        let mut jobs: Vec<Csr> = (0..1 + rng.range(0, 3))
            .map(|_| random_matrix(rng, size))
            .collect();
        if rng.range(0, 2) == 1 {
            jobs.insert(rng.range(0, jobs.len() + 1), Csr::new(0, 2)); // empty job
        }
        let refs: Vec<&Csr> = jobs.iter().collect();
        let mut seg = encode::BundleStream::new();
        let bounds = seg.encode_csr_jobs(&refs, bundle);
        let words = layout::serialize_stream(&seg);
        assert_eq!(words.len(), layout::stream_arena_words(&seg));
        assert_eq!(layout::deserialize(&words).unwrap(), seg.to_bundles());
        for (j, m) in jobs.iter().enumerate() {
            let back =
                decode::stream_segment_to_csr(&seg, bounds[j], bounds[j + 1], m.nrows, m.ncols)
                    .unwrap();
            assert_eq!(&back, m, "job {j}");
        }

        // ---- sparse + dense-panel encode (zero-width panel included) ----
        let a = random_matrix(rng, size);
        let k = rng.range(0, 12);
        let x: Vec<f32> = (0..a.ncols * k)
            .map(|i| ((i * 7 + 3) % 19) as f32 - 9.0)
            .collect();
        let mut ps = encode::BundleStream::new();
        let boundary = ps.encode_csr_with_panel(&a, &x, k, bundle);
        let pwords = layout::serialize_stream(&ps);
        let pback = decode::bundles_to_csr(
            &layout::deserialize(&pwords).unwrap(),
            a.nrows,
            a.ncols,
        )
        .unwrap();
        assert_eq!(pback, a, "panel stream: sparse half");
        assert_eq!(decode::stream_to_csr(&ps, a.nrows, a.ncols).unwrap(), a);
        assert_eq!(
            decode::stream_panel_to_dense(&ps, boundary, ps.n_bundles(), a.ncols, k).unwrap(),
            x,
            "panel stream: dense half"
        );
        assert_eq!(
            layout::segment_arena_words(&ps, boundary, ps.n_bundles()),
            layout::dense_panel_words(a.ncols, k, bundle)
        );
    });
}

/// Every negotiated [`layout::StreamEncoding`] round-trips through the
/// serialized DRAM words: lossless encodings (Raw, Bitmap) are the
/// identity on CSR; Fx encodings preserve the sparsity structure exactly
/// and every value to within the documented per-bundle Q1.15 bound
/// [`layout::fx_max_abs_error`]. Serialized length is exactly
/// [`layout::encoded_stream_words`] (+1 CRC word per bundle when
/// checksummed) — including empty matrices and dense-panel streams.
#[test]
fn prop_encoded_streams_roundtrip_through_dram_words() {
    use reap::rir::layout::{
        encoded_stream_words, fx_max_abs_error, serialize_stream_encoded, StreamEncoding,
    };
    const ENCODINGS: [StreamEncoding; 4] =
        [StreamEncoding::Raw, StreamEncoding::Bitmap, StreamEncoding::Fx, StreamEncoding::BitmapFx];
    check("encoded roundtrip", Config { cases: 16, ..Config::default() }, |rng, size| {
        let bundle = 1 + rng.range(0, 40);
        let m = if rng.range(0, 8) == 0 {
            Csr::new(0, 3)
        } else {
            random_matrix(rng, size)
        };
        let s = encode::BundleStream::from_csr(&m, bundle);
        // per-element error bound: each bundle's scale is its max |value|
        let bounds: Vec<f64> = s
            .iter()
            .flat_map(|b| {
                let scale = b.vals.iter().fold(0f32, |acc, v| acc.max(v.abs()));
                std::iter::repeat(fx_max_abs_error(scale)).take(b.vals.len())
            })
            .collect();
        for enc in ENCODINGS {
            for checksummed in [false, true] {
                let words = serialize_stream_encoded(&s, enc, checksummed);
                assert_eq!(
                    words.len(),
                    encoded_stream_words(&s, enc)
                        + if checksummed { s.n_bundles() } else { 0 },
                    "{enc:?} accounting"
                );
                let back = decode::bundles_to_csr(
                    &layout::try_deserialize(&words).unwrap(),
                    m.nrows,
                    m.ncols,
                )
                .unwrap();
                assert_eq!(back.row_ptr, m.row_ptr, "{enc:?} structure");
                assert_eq!(back.cols, m.cols, "{enc:?} structure");
                if enc.fx() {
                    for (i, (got, want)) in back.vals.iter().zip(&m.vals).enumerate() {
                        let err = (f64::from(*got) - f64::from(*want)).abs();
                        assert!(err <= bounds[i], "{enc:?} elem {i}: err {err} > {}", bounds[i]);
                    }
                } else {
                    assert_eq!(back.vals, m.vals, "{enc:?} is lossless");
                }
            }
        }

        // dense-panel stream: structure exact, fx values within the global
        // bound (each bundle's scale ≤ the panel's max |value|)
        let a = random_matrix(rng, size);
        let k = rng.range(0, 12);
        let x: Vec<f32> = (0..a.ncols * k)
            .map(|i| ((i * 7 + 3) % 19) as f32 - 9.0)
            .collect();
        let mut ps = encode::BundleStream::new();
        let boundary = ps.encode_csr_with_panel(&a, &x, k, bundle);
        let xmax = x.iter().fold(0f32, |acc, v| acc.max(v.abs()));
        for enc in ENCODINGS {
            let words = serialize_stream_encoded(&ps, enc, false);
            assert_eq!(words.len(), encoded_stream_words(&ps, enc), "{enc:?} panel accounting");
            let d = decode::try_words_panel_to_dense(&words, boundary, ps.n_bundles(), a.ncols, k)
                .unwrap();
            assert_eq!(d.len(), x.len(), "{enc:?} panel shape");
            let bound = if enc.fx() { fx_max_abs_error(xmax) } else { 0.0 };
            for (i, (got, want)) in d.iter().zip(&x).enumerate() {
                let err = (f64::from(*got) - f64::from(*want)).abs();
                assert!(err <= bound, "{enc:?} panel elem {i}: err {err} > {bound}");
            }
        }
    });
}

/// The encoder's per-bundle raw-vs-bitmap choice is exactly the byte
/// accounting rule: the wire bundle carries the BITMAP flag iff
/// [`layout::bitmap_index_words`] prices strictly below `count` raw index
/// words — and [`layout::encoded_data_bundle_words`] matches the wire
/// bundle-by-bundle (the walk ends exactly at the stream's last word).
#[test]
fn prop_bitmap_choice_matches_byte_accounting() {
    use reap::rir::layout::{
        bitmap_index_words, encoded_data_bundle_words, serialize_stream_encoded, StreamEncoding,
    };
    use reap::rir::BundleFlags;
    check("bitmap byte accounting", Config { cases: 24, ..Config::default() }, |rng, size| {
        let m = random_matrix(rng, size);
        let bundle = 1 + rng.range(0, 40);
        let s = encode::BundleStream::from_csr(&m, bundle);
        for enc in [StreamEncoding::Bitmap, StreamEncoding::BitmapFx] {
            let words = serialize_stream_encoded(&s, enc, false);
            let mut p = 0usize;
            for b in s.iter() {
                let wire_bitmap = words[p] & BundleFlags::BITMAP as u32 != 0;
                let wins = matches!(bitmap_index_words(b.cols), Some(n) if n < b.cols.len());
                assert_eq!(wire_bitmap, wins, "{enc:?} bundle at word {p}");
                p += encoded_data_bundle_words(b.cols, enc);
            }
            assert_eq!(p, words.len(), "{enc:?} per-bundle accounting drift");
        }
    });
}

/// SpMM invariants: every column of the scheduled multi-vector replay is
/// bit-identical to an independent SpMV, for arbitrary k, geometry and
/// worker counts; the simulator conserves flops = 2·nnz·k.
#[test]
fn prop_spmm_columns_bit_identical_to_spmv() {
    use reap::coordinator::spmm::numeric_spmm;
    use reap::fpga::spmm_sim::simulate_spmm;
    check("spmm == k spmvs", Config { cases: 20, ..Config::default() }, |rng, size| {
        let a = random_matrix(rng, size);
        let k = 1 + rng.range(0, 12);
        let x: Vec<f32> = (0..a.ncols * k)
            .map(|i| ((i * 5 + 1) % 13) as f32 - 6.0)
            .collect();
        let mut cfg = FpgaConfig::reap32_spgemm();
        cfg.pipelines = 1 + rng.range(0, 48);
        cfg.bundle_size = 1 + rng.range(0, 40);
        cfg.vector_lanes = 1 + rng.range(0, 10);
        let s = schedule::schedule_spgemm(
            &a,
            &Csr::new(a.ncols, a.ncols),
            cfg.pipelines,
            cfg.bundle_size,
        );
        let c = numeric_spmm(&a, &x, k, &s, 1 + rng.range(0, 8));
        for j in 0..k {
            let xj: Vec<f32> = x.iter().skip(j).step_by(k).copied().collect();
            let yj = reap::kernels::spmv(&a, &xj);
            for i in 0..a.nrows {
                assert_eq!(c[i * k + j], yj[i], "col {j} row {i}");
            }
        }
        let r = simulate_spmm(&a, &s, &cfg, Style::HandCoded, k);
        assert_eq!(r.stats.flops as usize, 2 * a.nnz() * k);
        assert_eq!(r.wave_cycles.len(), r.n_blocks * s.n_waves());
        assert_eq!(
            r.panel_load_cycles + r.wave_cycles.iter().sum::<u64>(),
            r.stats.cycles
        );
    });
}

/// Scheduling covers every nonzero exactly once, never overfills a wave,
/// and every wave's B-stream is exactly the union of its A columns.
#[test]
fn prop_schedule_partition_invariants() {
    check("schedule partition", Config::default(), |rng, size| {
        let a = random_matrix(rng, size);
        let b = gen::generate(random_family(rng), a.ncols, (a.ncols * 3).max(1), rng.next_u64());
        let pipelines = 1 + rng.range(0, 64);
        let bundle = 1 + rng.range(0, 40);
        let s = schedule::schedule_spgemm(&a, &b, pipelines, bundle);
        let mut covered = vec![false; a.nnz()];
        for w in &s.waves {
            assert!(!w.assignments.is_empty());
            assert!(w.assignments.len() <= pipelines);
            let mut expect: Vec<Idx> = Vec::new();
            for asg in &w.assignments {
                assert!(asg.len <= bundle && asg.len > 0);
                for e in asg.start..asg.start + asg.len {
                    assert!(!covered[e], "element {e} scheduled twice");
                    covered[e] = true;
                }
                expect.extend_from_slice(asg.a_cols(&a));
            }
            expect.sort_unstable();
            expect.dedup();
            assert_eq!(w.b_rows, expect, "B stream != union of A cols");
        }
        assert!(covered.iter().all(|&c| c), "element never scheduled");
    });
}

/// The coordinator's bundle-ordered numeric path equals the Gustavson
/// baseline bit-for-bit, for every design geometry.
#[test]
fn prop_coordinator_matches_baseline() {
    check("coordinator == baseline", Config { cases: 24, ..Config::default() }, |rng, size| {
        let a = random_matrix(rng, size);
        let b = gen::generate(random_family(rng), a.ncols, (a.ncols * 2).max(1), rng.next_u64());
        let mut cfg = FpgaConfig::reap32_spgemm();
        cfg.pipelines = 1 + rng.range(0, 48);
        cfg.bundle_size = 1 + rng.range(0, 33);
        let rep = ReapSpgemm::new(cfg).run(&a, &b).unwrap();
        rep.c.validate().unwrap();
        assert_eq!(rep.c, spgemm(&a, &b));
    });
}

/// The sharded scheduling pass is bit-identical to the serial one for
/// thread counts 1/2/4/8 — waves, traffic words, everything the FPGA sees.
#[test]
fn prop_parallel_schedule_bit_identical() {
    check("parallel schedule == serial", Config { cases: 24, ..Config::default() }, |rng, size| {
        let a = random_matrix(rng, size);
        let b = gen::generate(random_family(rng), a.ncols, (a.ncols * 2).max(1), rng.next_u64());
        let pipelines = 1 + rng.range(0, 32);
        let bundle = 1 + rng.range(0, 40);
        let base = schedule::schedule_spgemm_with_threads(&a, &b, pipelines, bundle, 1);
        for threads in [2usize, 4, 8] {
            let par = schedule::schedule_spgemm_with_threads(&a, &b, pipelines, bundle, threads);
            assert_eq!(par.waves, base.waves, "threads={threads}");
            assert_eq!(par.a_words, base.a_words, "threads={threads}");
            assert_eq!(par.b_words, base.b_words, "threads={threads}");
            assert_eq!(par.wave_cpu_s.len(), par.waves.len());
        }
    });
}

/// The parallel scheduled numeric path is bit-identical to the serial
/// scheduled path (and to the Gustavson baseline) for thread counts
/// 1/2/4/8 on random CSR inputs.
#[test]
fn prop_parallel_numeric_bit_identical() {
    check("parallel numeric == serial", Config { cases: 20, ..Config::default() }, |rng, size| {
        let a = random_matrix(rng, size);
        let b = gen::generate(random_family(rng), a.ncols, (a.ncols * 2).max(1), rng.next_u64());
        let pipelines = 1 + rng.range(0, 48);
        let bundle = 1 + rng.range(0, 33);
        let s = schedule::schedule_spgemm_with_threads(&a, &b, pipelines, bundle, 1);
        let serial = numeric_scheduled(&a, &b, &s, 1);
        for threads in [2usize, 4, 8] {
            assert_eq!(numeric_scheduled(&a, &b, &s, threads), serial, "threads={threads}");
        }
        serial.validate().unwrap();
        assert_eq!(serial, spgemm(&a, &b));
    });
}

/// Deterministic edge cases for the parallel pipeline: empty rows (skipped
/// by the scheduler, present in the output) and oversized rows (split
/// across many chunks/waves), across thread counts 1/2/4/8.
#[test]
fn parallel_paths_handle_empty_and_oversized_rows() {
    // rows: empty, 100-nnz (≫ bundle), empty, singleton, empty
    let n = 5usize;
    let ncols = 120usize;
    let mut a = Csr::new(n, ncols);
    a.cols = (0..100).chain([7]).collect();
    a.vals = (0..101).map(|i| (i as f32) * 0.25 - 3.0).collect();
    a.row_ptr = vec![0, 0, 100, 100, 101, 101];
    a.validate().unwrap();
    let b = gen::generate(Family::PowerLaw, ncols, 900, 77);

    let base_sched = schedule::schedule_spgemm_with_threads(&a, &b, 4, 32, 1);
    let base_num = numeric_scheduled(&a, &b, &base_sched, 1);
    assert_eq!(base_num, spgemm(&a, &b));
    let base_enc = encode::BundleStream::from_csr_with_threads(&a, 32, 1);
    for threads in [2usize, 4, 8] {
        let s = schedule::schedule_spgemm_with_threads(&a, &b, 4, 32, threads);
        assert_eq!(s.waves, base_sched.waves, "threads={threads}");
        assert_eq!(numeric_scheduled(&a, &b, &base_sched, threads), base_num);
        assert_eq!(encode::BundleStream::from_csr_with_threads(&a, 32, threads), base_enc);
    }
}

/// The parallel SoA encode is bit-identical to the serial encode and to
/// the boxed-bundle encoder for thread counts 1/2/4/8.
#[test]
fn prop_parallel_encode_bit_identical() {
    check("parallel encode == serial", Config { cases: 24, ..Config::default() }, |rng, size| {
        let m = random_matrix(rng, size);
        let bundle = 1 + rng.range(0, 40);
        let base = encode::BundleStream::from_csr_with_threads(&m, bundle, 1);
        for threads in [2usize, 4, 8] {
            let par = encode::BundleStream::from_csr_with_threads(&m, bundle, threads);
            assert_eq!(par, base, "threads={threads}");
        }
        assert_eq!(base.to_bundles(), encode::csr_to_bundles(&m, bundle));
        let back = decode::stream_to_csr(&base, m.nrows, m.ncols).unwrap();
        assert_eq!(back, m);
    });
}

/// The multi-tenant batch schedule of N jobs decomposes bit-identically
/// into the N single-job schedules (waves, traffic words), is itself
/// thread-count-invariant (1/2/4/8 workers), and its numeric replay
/// matches every job's Gustavson baseline — including empty jobs.
#[test]
fn prop_batch_schedule_decomposes_bit_identically() {
    use reap::coordinator::batch::numeric_batch;
    check("batch decompose == single-job", Config { cases: 16, ..Config::default() }, |rng, size| {
        let n_jobs = 1 + rng.range(0, 5);
        let mut jobs: Vec<(Csr, Csr)> = Vec::new();
        for _ in 0..n_jobs {
            let a = random_matrix(rng, size);
            let b = gen::generate(random_family(rng), a.ncols, (a.ncols * 2).max(1), rng.next_u64());
            jobs.push((a, b));
        }
        if rng.range(0, 2) == 1 {
            jobs.push((Csr::new(3, 4), Csr::new(4, 2))); // empty tenant
        }
        let pipelines = 1 + rng.range(0, 48);
        let bundle = 1 + rng.range(0, 33);

        // thread-count invariance of the shared-wave schedule
        let base = schedule::schedule_spgemm_batch_with_threads(&jobs, pipelines, bundle, 1);
        for threads in [2usize, 4, 8] {
            let par =
                schedule::schedule_spgemm_batch_with_threads(&jobs, pipelines, bundle, threads);
            assert_eq!(par.waves, base.waves, "threads={threads}");
            assert_eq!(par.a_words, base.a_words, "threads={threads}");
            assert_eq!(par.b_words, base.b_words, "threads={threads}");
            assert_eq!(par.wave_cpu_s.len(), par.waves.len());
        }

        // decomposition: per-job waves and traffic equal the single-job pass
        let singles = base.decompose(&jobs);
        for (j, (a, b)) in jobs.iter().enumerate() {
            let solo = schedule::schedule_spgemm_with_threads(a, b, pipelines, bundle, 1);
            assert_eq!(singles[j].waves, solo.waves, "job {j}");
            assert_eq!(singles[j].a_words, solo.a_words, "job {j}");
            assert_eq!(singles[j].b_words, solo.b_words, "job {j}");
        }

        // numeric replay: bit-identical to each job's baseline, for an
        // arbitrary worker count
        let outs = numeric_batch(&jobs, &base, 1 + rng.range(0, 8));
        assert_eq!(outs.len(), jobs.len());
        for (j, (a, b)) in jobs.iter().enumerate() {
            outs[j].validate().unwrap();
            assert_eq!(outs[j], spgemm(a, b), "job {j}");
        }
    });
}

/// Parallel SpGEMM equals serial for arbitrary thread counts.
#[test]
fn prop_parallel_spgemm_thread_invariance() {
    check("parallel == serial", Config { cases: 24, ..Config::default() }, |rng, size| {
        let a = random_matrix(rng, size);
        let b = gen::generate(random_family(rng), a.ncols, (a.ncols * 2).max(1), rng.next_u64());
        let threads = 1 + rng.range(0, 9);
        assert_eq!(spgemm_parallel(&a, &b, threads), spgemm(&a, &b));
    });
}

/// Simulator conservation laws: flops equal the analytic count, wave log
/// sums to total cycles, busy+idle = pipelines × cycles… for any geometry.
#[test]
fn prop_sim_conservation() {
    check("sim conservation", Config { cases: 32, ..Config::default() }, |rng, size| {
        let a = random_matrix(rng, size);
        let mut cfg = FpgaConfig::reap32_spgemm();
        cfg.pipelines = 1 + rng.range(0, 64);
        cfg.bundle_size = 1 + rng.range(0, 40);
        let s = schedule::schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
        let r = simulate_spgemm(&a, &a, &s, &cfg, Style::HandCoded);
        assert_eq!(r.stats.flops as usize, reap::kernels::spgemm::spgemm_flops(&a, &a));
        assert_eq!(r.stats.cycles, r.wave_cycles.iter().sum::<u64>());
        assert_eq!(
            r.stats.busy_pipeline_cycles + r.stats.idle_pipeline_cycles,
            cfg.pipelines as u64 * r.stats.cycles,
        );
        assert_eq!(
            r.stats.compute_bound_cycles + r.stats.dram_bound_cycles,
            r.stats.cycles
        );
        // DRAM traffic matches the schedule's word accounting on the read
        // side (A bundles + B streams)
        assert_eq!(r.stats.bytes_read as usize, s.input_bytes());
    });
}

/// SpMV conservation: flops = 2·nnz, coordinator matches the baseline
/// bitwise on arbitrary geometry.
#[test]
fn prop_spmv_conservation_and_equality() {
    use reap::coordinator::ReapSpmv;
    use reap::fpga::spmv_sim::simulate_spmv;
    check("spmv invariants", Config { cases: 24, ..Config::default() }, |rng, size| {
        let a = random_matrix(rng, size);
        let x: Vec<f32> = (0..a.ncols).map(|i| ((i % 9) as f32) - 4.0).collect();
        let mut cfg = FpgaConfig::reap32_spgemm();
        cfg.pipelines = 1 + rng.range(0, 48);
        cfg.bundle_size = 1 + rng.range(0, 40);
        let s = schedule::schedule_spgemm(
            &a,
            &Csr::new(a.ncols, a.ncols),
            cfg.pipelines,
            cfg.bundle_size,
        );
        let r = simulate_spmv(&a, &s, &cfg, Style::HandCoded);
        assert_eq!(r.stats.flops as usize, 2 * a.nnz());
        assert_eq!(
            r.stats.compute_bound_cycles + r.stats.dram_bound_cycles,
            r.stats.cycles
        );
        let rep = ReapSpmv::new(cfg).run(&a, &x).unwrap();
        let want = reap::kernels::spmv(&a, &x);
        for (g, w) in rep.y.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-3 * w.abs().max(1.0));
        }
    });
}

/// Cholesky sim conservation: per-column log sums to the total, cycle
/// attribution partitions, and flops scale with the pattern.
#[test]
fn prop_cholesky_sim_conservation() {
    use reap::fpga::cholesky_sim::simulate_cholesky;
    check("cholesky sim invariants", Config { cases: 16, ..Config::default() }, |rng, size| {
        let n = 4 + rng.range(0, 2 * size.0 + 4);
        let base = gen::generate(random_family(rng), n, (n * 3).max(2), rng.next_u64());
        let lower = reap::sparse::ops::make_spd(&base).lower_triangle();
        let sym = CholeskySymbolic::analyze(&lower, 1 + rng.range(0, 40));
        let mut cfg = FpgaConfig::reap32_cholesky();
        cfg.pipelines = 1 + rng.range(0, 64);
        let r = simulate_cholesky(&sym, &cfg, Style::HandCoded);
        assert_eq!(r.column_cycles.len(), n);
        assert_eq!(r.stats.cycles, r.column_cycles.iter().sum::<u64>());
        assert_eq!(
            r.stats.compute_bound_cycles + r.stats.dram_bound_cycles,
            r.stats.cycles
        );
        assert!(r.stats.flops as usize >= sym.pattern.nnz());
    });
}

/// Symbolic pattern invariants: diagonal-first ascending columns, fill-in
/// only grows the pattern, storage map is an exact transpose.
#[test]
fn prop_symbolic_pattern_invariants() {
    check("symbolic invariants", Config { cases: 24, ..Config::default() }, |rng, size| {
        let n = 4 + rng.range(0, 2 * size.0 + 4);
        let base = gen::generate(random_family(rng), n, (n * 3).max(2), rng.next_u64());
        let lower = reap::sparse::ops::make_spd(&base).lower_triangle();
        let lp = symbolic_factor(&lower);
        assert!(lp.nnz() >= lower.nnz(), "symbolic pattern lost entries");
        for j in 0..lp.n {
            let rows = lp.col_rows(j);
            assert_eq!(rows[0] as usize, j, "diagonal must lead column {j}");
            assert!(rows.windows(2).all(|w| w[0] < w[1]));
        }
        // A's lower pattern is contained in L's
        for j in 0..n {
            for &r in lower.col_rows(j) {
                assert!(lp.col_rows(j).contains(&r), "A({r},{j}) missing from L");
            }
        }
        let sym = CholeskySymbolic::analyze(&lower, 1 + rng.range(0, 40));
        assert_eq!(sym.storage.len(), lp.nnz());
    });
}

/// The RL metadata stream is decodable and its triples point at exact row
/// extents of the storage map (what the FPGA's address generation needs).
#[test]
fn prop_rl_stream_addresses_valid() {
    check("rl stream addresses", Config { cases: 24, ..Config::default() }, |rng, size| {
        let n = 4 + rng.range(0, 2 * size.0 + 4);
        let base = gen::generate(random_family(rng), n, (n * 3).max(2), rng.next_u64());
        let lower = reap::sparse::ops::make_spd(&base).lower_triangle();
        let bundle = 1 + rng.range(0, 40);
        let sym = CholeskySymbolic::analyze(&lower, bundle);
        let decoded = layout::deserialize(&sym.rl_words).unwrap();
        let mut per_col = vec![0usize; n];
        for b in &decoded {
            assert!(b.flags.metadata_only());
            assert!(b.len() <= bundle);
            for t in b.triples() {
                let r = t.row as usize;
                assert_eq!(t.start as usize, sym.storage.row_ptr[r]);
                assert_eq!(t.end as usize, sym.storage.row_ptr[r + 1]);
            }
            per_col[b.shared as usize] += b.len();
        }
        for k in 0..n {
            assert_eq!(per_col[k], sym.pattern.col_nnz(k), "column {k} triple count");
        }
    });
}

/// The deterministic work-stealing contract (ARCHITECTURE.md §10), pinned
/// on the adversarial inputs: every grain-claimed pass — SpGEMM schedule,
/// batch schedule, all three numerics, bundle encode and the parallel
/// Cholesky symbolic phase — is bit-identical across thread counts
/// 1/2/4/8 AND grain sizes (1, 4, effectively-one-grain) on power-law and
/// Zipf-adversarial matrices, and the retired static-band partitioners
/// still agree with the stealing executor bit for bit.
#[test]
fn prop_workstealing_bit_identity_on_skewed_inputs() {
    use reap::coordinator::batch::{
        numeric_batch, numeric_batch_static_bands, numeric_batch_with_grain,
    };
    use reap::coordinator::spgemm::{numeric_scheduled_static_bands, numeric_scheduled_with_grain};
    use reap::coordinator::spmm::{numeric_spmm, numeric_spmm_with_grain};
    use reap::symbolic::{symbolic_factor_with_grain, symbolic_factor_with_threads, LevelSchedule};
    const THREADS: [usize; 3] = [2, 4, 8];
    const GRAINS: [usize; 3] = [1, 4, 1 << 20];
    check("work-stealing determinism", Config { cases: 8, ..Config::default() }, |rng, size| {
        let fam = skewed_family(rng);
        let n = 8 + rng.range(0, 4 * size.0 + 8);
        let a = gen::generate(fam, n, (n * 6).max(4), rng.next_u64());
        let b = gen::generate(skewed_family(rng), n, (n * 4).max(2), rng.next_u64());
        let pipelines = 1 + rng.range(0, 32);
        let bundle = 1 + rng.range(0, 40);

        // --- SpGEMM wave schedule ---
        let s0 = schedule::schedule_spgemm_with_threads(&a, &b, pipelines, bundle, 1);
        for t in THREADS {
            let st = schedule::schedule_spgemm_with_threads(&a, &b, pipelines, bundle, t);
            assert_eq!(st.waves, s0.waves, "schedule t={t}");
            let stat = schedule::schedule_spgemm_static_bands(&a, &b, pipelines, bundle, t);
            assert_eq!(stat.waves, s0.waves, "static schedule t={t}");
            for g in GRAINS {
                let sg = schedule::schedule_spgemm_with_grain(&a, &b, pipelines, bundle, t, g);
                assert_eq!(sg.waves, s0.waves, "schedule t={t} grain={g}");
                assert_eq!(sg.a_words, s0.a_words, "schedule t={t} grain={g}");
                assert_eq!(sg.b_words, s0.b_words, "schedule t={t} grain={g}");
            }
        }

        // --- batch wave schedule ---
        let jobs = vec![(a.clone(), b.clone()), (b.clone(), a.clone())];
        let bs0 = schedule::schedule_spgemm_batch_with_threads(&jobs, pipelines, bundle, 1);
        for t in THREADS {
            let bt = schedule::schedule_spgemm_batch_with_threads(&jobs, pipelines, bundle, t);
            assert_eq!(bt.waves, bs0.waves, "batch schedule t={t}");
            let bstat = schedule::schedule_spgemm_batch_static_bands(&jobs, pipelines, bundle, t);
            assert_eq!(bstat.waves, bs0.waves, "static batch schedule t={t}");
            for g in GRAINS {
                let bg = schedule::schedule_spgemm_batch_with_grain(&jobs, pipelines, bundle, t, g);
                assert_eq!(bg.waves, bs0.waves, "batch schedule t={t} grain={g}");
            }
        }

        // --- scheduled numeric, batch numeric, SpMM numeric ---
        let c0 = numeric_scheduled(&a, &b, &s0, 1);
        assert_eq!(c0, spgemm(&a, &b));
        let outs0 = numeric_batch(&jobs, &bs0, 1);
        let k = 1 + rng.range(0, 6);
        let x: Vec<f32> = (0..a.ncols * k)
            .map(|i| ((i * 5 + 1) % 13) as f32 - 6.0)
            .collect();
        let y0 = numeric_spmm(&a, &x, k, &s0, 1);
        for t in THREADS {
            assert_eq!(numeric_scheduled(&a, &b, &s0, t), c0, "numeric t={t}");
            assert_eq!(numeric_scheduled_static_bands(&a, &b, &s0, t), c0, "static numeric t={t}");
            assert_eq!(numeric_batch(&jobs, &bs0, t), outs0, "batch numeric t={t}");
            assert_eq!(
                numeric_batch_static_bands(&jobs, &bs0, t),
                outs0,
                "static batch numeric t={t}"
            );
            assert_eq!(numeric_spmm(&a, &x, k, &s0, t), y0, "spmm t={t}");
            for g in GRAINS {
                assert_eq!(
                    numeric_scheduled_with_grain(&a, &b, &s0, t, g),
                    c0,
                    "numeric t={t} grain={g}"
                );
                assert_eq!(
                    numeric_batch_with_grain(&jobs, &bs0, t, g),
                    outs0,
                    "batch numeric t={t} grain={g}"
                );
                assert_eq!(
                    numeric_spmm_with_grain(&a, &x, k, &s0, t, g),
                    y0,
                    "spmm t={t} grain={g}"
                );
            }
        }

        // --- bundle encode ---
        let e0 = encode::BundleStream::from_csr_with_threads(&a, bundle, 1);
        for t in THREADS {
            assert_eq!(encode::BundleStream::from_csr_with_threads(&a, bundle, t), e0, "enc t={t}");
            for g in GRAINS {
                assert_eq!(
                    encode::BundleStream::from_csr_with_grain(&a, bundle, t, g),
                    e0,
                    "enc t={t} grain={g}"
                );
            }
        }

        // --- parallel Cholesky symbolic + level sets ---
        let lower = reap::sparse::ops::make_spd(&a).lower_triangle();
        let lp0 = symbolic_factor_with_threads(&lower, 1);
        let lv0 = LevelSchedule::build_with_threads(&lp0, 1);
        for t in THREADS {
            assert_eq!(symbolic_factor_with_threads(&lower, t), lp0, "symbolic t={t}");
            assert_eq!(LevelSchedule::build_with_threads(&lp0, t).levels, lv0.levels, "lv t={t}");
            for g in GRAINS {
                assert_eq!(
                    symbolic_factor_with_grain(&lower, t, g),
                    lp0,
                    "symbolic t={t} grain={g}"
                );
                assert_eq!(
                    LevelSchedule::build_with_grain(&lp0, t, g).levels,
                    lv0.levels,
                    "lv t={t} grain={g}"
                );
            }
        }
    });
}
