//! Property-based invariants over the coordinator substrates (routing,
//! batching, state) — the randomized counterpart of the unit suites, run
//! through the in-tree `testing::prop` framework. Replay any failure with
//! `PROP_SEED=<seed> cargo test --test prop_invariants`.

use reap::coordinator::ReapSpgemm;
use reap::fpga::spgemm_sim::{simulate_spgemm, Style};
use reap::fpga::FpgaConfig;
use reap::kernels::{spgemm, spgemm_parallel};
use reap::rir::{decode, encode, layout, schedule};
use reap::sparse::gen::{self, Family};
use reap::sparse::{Csr, Idx};
use reap::symbolic::{symbolic_factor, CholeskySymbolic};
use reap::testing::{check, Config, Size};
use reap::util::Pcg64;

fn random_family(rng: &mut Pcg64) -> Family {
    match rng.next_below(4) {
        0 => Family::RandomUniform,
        1 => Family::BandedFem,
        2 => Family::PowerLaw,
        _ => Family::BlockRandom,
    }
}

fn random_matrix(rng: &mut Pcg64, size: Size) -> Csr {
    let n = 4 + rng.range(0, 4 * size.0 + 4);
    let nnz = rng.range(0, (n * n / 2).max(2));
    gen::generate(random_family(rng), n, nnz.max(1), rng.next_u64())
}

/// RIR compress → DRAM layout → decompress is the identity on CSR.
#[test]
fn prop_rir_roundtrip_through_dram_words() {
    check("rir roundtrip", Config::default(), |rng, size| {
        let m = random_matrix(rng, size);
        let bundle = 1 + rng.range(0, 40);
        let bundles = encode::csr_to_bundles(&m, bundle);
        let words = layout::serialize(&bundles);
        let back = decode::bundles_to_csr(&layout::deserialize(&words).unwrap(), m.nrows, m.ncols)
            .unwrap();
        assert_eq!(back, m);
    });
}

/// Scheduling covers every nonzero exactly once, never overfills a wave,
/// and every wave's B-stream is exactly the union of its A columns.
#[test]
fn prop_schedule_partition_invariants() {
    check("schedule partition", Config::default(), |rng, size| {
        let a = random_matrix(rng, size);
        let b = gen::generate(random_family(rng), a.ncols, (a.ncols * 3).max(1), rng.next_u64());
        let pipelines = 1 + rng.range(0, 64);
        let bundle = 1 + rng.range(0, 40);
        let s = schedule::schedule_spgemm(&a, &b, pipelines, bundle);
        let mut covered = vec![false; a.nnz()];
        for w in &s.waves {
            assert!(!w.assignments.is_empty());
            assert!(w.assignments.len() <= pipelines);
            let mut expect: Vec<Idx> = Vec::new();
            for asg in &w.assignments {
                assert!(asg.len <= bundle && asg.len > 0);
                for e in asg.start..asg.start + asg.len {
                    assert!(!covered[e], "element {e} scheduled twice");
                    covered[e] = true;
                }
                expect.extend_from_slice(asg.a_cols(&a));
            }
            expect.sort_unstable();
            expect.dedup();
            assert_eq!(w.b_rows, expect, "B stream != union of A cols");
        }
        assert!(covered.iter().all(|&c| c), "element never scheduled");
    });
}

/// The coordinator's bundle-ordered numeric path equals the Gustavson
/// baseline bit-for-bit, for every design geometry.
#[test]
fn prop_coordinator_matches_baseline() {
    check("coordinator == baseline", Config { cases: 24, ..Config::default() }, |rng, size| {
        let a = random_matrix(rng, size);
        let b = gen::generate(random_family(rng), a.ncols, (a.ncols * 2).max(1), rng.next_u64());
        let mut cfg = FpgaConfig::reap32_spgemm();
        cfg.pipelines = 1 + rng.range(0, 48);
        cfg.bundle_size = 1 + rng.range(0, 33);
        let rep = ReapSpgemm::new(cfg).run(&a, &b).unwrap();
        rep.c.validate().unwrap();
        assert_eq!(rep.c, spgemm(&a, &b));
    });
}

/// Parallel SpGEMM equals serial for arbitrary thread counts.
#[test]
fn prop_parallel_spgemm_thread_invariance() {
    check("parallel == serial", Config { cases: 24, ..Config::default() }, |rng, size| {
        let a = random_matrix(rng, size);
        let b = gen::generate(random_family(rng), a.ncols, (a.ncols * 2).max(1), rng.next_u64());
        let threads = 1 + rng.range(0, 9);
        assert_eq!(spgemm_parallel(&a, &b, threads), spgemm(&a, &b));
    });
}

/// Simulator conservation laws: flops equal the analytic count, wave log
/// sums to total cycles, busy+idle = pipelines × cycles… for any geometry.
#[test]
fn prop_sim_conservation() {
    check("sim conservation", Config { cases: 32, ..Config::default() }, |rng, size| {
        let a = random_matrix(rng, size);
        let mut cfg = FpgaConfig::reap32_spgemm();
        cfg.pipelines = 1 + rng.range(0, 64);
        cfg.bundle_size = 1 + rng.range(0, 40);
        let s = schedule::schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
        let r = simulate_spgemm(&a, &a, &s, &cfg, Style::HandCoded);
        assert_eq!(r.stats.flops as usize, reap::kernels::spgemm::spgemm_flops(&a, &a));
        assert_eq!(r.stats.cycles, r.wave_cycles.iter().sum::<u64>());
        assert_eq!(
            r.stats.busy_pipeline_cycles + r.stats.idle_pipeline_cycles,
            cfg.pipelines as u64 * r.stats.cycles,
        );
        assert_eq!(
            r.stats.compute_bound_cycles + r.stats.dram_bound_cycles,
            r.stats.cycles
        );
        // DRAM traffic matches the schedule's word accounting on the read
        // side (A bundles + B streams)
        assert_eq!(r.stats.bytes_read as usize, s.input_bytes());
    });
}

/// SpMV conservation: flops = 2·nnz, coordinator matches the baseline
/// bitwise on arbitrary geometry.
#[test]
fn prop_spmv_conservation_and_equality() {
    use reap::coordinator::ReapSpmv;
    use reap::fpga::spmv_sim::simulate_spmv;
    check("spmv invariants", Config { cases: 24, ..Config::default() }, |rng, size| {
        let a = random_matrix(rng, size);
        let x: Vec<f32> = (0..a.ncols).map(|i| ((i % 9) as f32) - 4.0).collect();
        let mut cfg = FpgaConfig::reap32_spgemm();
        cfg.pipelines = 1 + rng.range(0, 48);
        cfg.bundle_size = 1 + rng.range(0, 40);
        let s = schedule::schedule_spgemm(
            &a,
            &Csr::new(a.ncols, a.ncols),
            cfg.pipelines,
            cfg.bundle_size,
        );
        let r = simulate_spmv(&a, &s, &cfg, Style::HandCoded);
        assert_eq!(r.stats.flops as usize, 2 * a.nnz());
        assert_eq!(
            r.stats.compute_bound_cycles + r.stats.dram_bound_cycles,
            r.stats.cycles
        );
        let rep = ReapSpmv::new(cfg).run(&a, &x).unwrap();
        let want = reap::kernels::spmv(&a, &x);
        for (g, w) in rep.y.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-3 * w.abs().max(1.0));
        }
    });
}

/// Cholesky sim conservation: per-column log sums to the total, cycle
/// attribution partitions, and flops scale with the pattern.
#[test]
fn prop_cholesky_sim_conservation() {
    use reap::fpga::cholesky_sim::simulate_cholesky;
    check("cholesky sim invariants", Config { cases: 16, ..Config::default() }, |rng, size| {
        let n = 4 + rng.range(0, 2 * size.0 + 4);
        let base = gen::generate(random_family(rng), n, (n * 3).max(2), rng.next_u64());
        let lower = reap::sparse::ops::make_spd(&base).lower_triangle();
        let sym = CholeskySymbolic::analyze(&lower, 1 + rng.range(0, 40));
        let mut cfg = FpgaConfig::reap32_cholesky();
        cfg.pipelines = 1 + rng.range(0, 64);
        let r = simulate_cholesky(&sym, &cfg, Style::HandCoded);
        assert_eq!(r.column_cycles.len(), n);
        assert_eq!(r.stats.cycles, r.column_cycles.iter().sum::<u64>());
        assert_eq!(
            r.stats.compute_bound_cycles + r.stats.dram_bound_cycles,
            r.stats.cycles
        );
        assert!(r.stats.flops as usize >= sym.pattern.nnz());
    });
}

/// Symbolic pattern invariants: diagonal-first ascending columns, fill-in
/// only grows the pattern, storage map is an exact transpose.
#[test]
fn prop_symbolic_pattern_invariants() {
    check("symbolic invariants", Config { cases: 24, ..Config::default() }, |rng, size| {
        let n = 4 + rng.range(0, 2 * size.0 + 4);
        let base = gen::generate(random_family(rng), n, (n * 3).max(2), rng.next_u64());
        let lower = reap::sparse::ops::make_spd(&base).lower_triangle();
        let lp = symbolic_factor(&lower);
        assert!(lp.nnz() >= lower.nnz(), "symbolic pattern lost entries");
        for j in 0..lp.n {
            let rows = lp.col_rows(j);
            assert_eq!(rows[0] as usize, j, "diagonal must lead column {j}");
            assert!(rows.windows(2).all(|w| w[0] < w[1]));
        }
        // A's lower pattern is contained in L's
        for j in 0..n {
            for &r in lower.col_rows(j) {
                assert!(lp.col_rows(j).contains(&r), "A({r},{j}) missing from L");
            }
        }
        let sym = CholeskySymbolic::analyze(&lower, 1 + rng.range(0, 40));
        assert_eq!(sym.storage.len(), lp.nnz());
    });
}

/// The RL metadata stream is decodable and its triples point at exact row
/// extents of the storage map (what the FPGA's address generation needs).
#[test]
fn prop_rl_stream_addresses_valid() {
    check("rl stream addresses", Config { cases: 24, ..Config::default() }, |rng, size| {
        let n = 4 + rng.range(0, 2 * size.0 + 4);
        let base = gen::generate(random_family(rng), n, (n * 3).max(2), rng.next_u64());
        let lower = reap::sparse::ops::make_spd(&base).lower_triangle();
        let bundle = 1 + rng.range(0, 40);
        let sym = CholeskySymbolic::analyze(&lower, bundle);
        let decoded = layout::deserialize(&sym.rl_words).unwrap();
        let mut per_col = vec![0usize; n];
        for b in &decoded {
            assert!(b.flags.metadata_only());
            assert!(b.len() <= bundle);
            for t in b.triples() {
                let r = t.row as usize;
                assert_eq!(t.start as usize, sym.storage.row_ptr[r]);
                assert_eq!(t.end as usize, sym.storage.row_ptr[r + 1]);
            }
            per_col[b.shared as usize] += b.len();
        }
        for k in 0..n {
            assert_eq!(per_col[k], sym.pattern.col_nnz(k), "column {k} triple count");
        }
    });
}
