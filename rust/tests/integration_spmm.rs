//! Integration tests for the SpMM multi-vector path: the acceptance
//! criteria of the workload — bit-identity to k independent SpMVs across
//! thread counts, strictly-fewer simulated cycles than k serial SpMV runs
//! on the wide designs for k ∈ {4, 8}, the per-wave trace contract, and
//! the combined sparse + dense-panel RIR stream.

use reap::coordinator::spmm::numeric_spmm;
use reap::coordinator::{ReapSpmm, ReapSpmv};
use reap::fpga::spgemm_sim::Style;
use reap::fpga::spmm_sim::simulate_spmm;
use reap::fpga::spmv_sim::simulate_spmv;
use reap::fpga::FpgaConfig;
use reap::kernels::{spmm, spmv};
use reap::rir::schedule::schedule_spgemm;
use reap::rir::{decode, layout, BundleStream};
use reap::sparse::{gen, Csr, Val};

fn panel(ncols: usize, k: usize, seed: u64) -> Vec<Val> {
    (0..ncols * k)
        .map(|i| (((i as u64).wrapping_mul(seed | 1) % 29) as f32 - 14.0) * 0.125)
        .collect()
}

#[test]
fn spmm_bit_identical_to_k_spmvs_across_thread_counts() {
    let a = gen::power_law(300, 5000, 71);
    for k in [4usize, 8] {
        let x = panel(a.ncols, k, 71);
        let cfg = FpgaConfig::reap64_spgemm();
        let schedule =
            schedule_spgemm(&a, &Csr::new(a.ncols, a.ncols), cfg.pipelines, cfg.bundle_size);
        let base = numeric_spmm(&a, &x, k, &schedule, 1);
        for t in [2usize, 4, 8] {
            assert_eq!(numeric_spmm(&a, &x, k, &schedule, t), base, "k {k} threads {t}");
        }
        // column j == independent SpMV, bit for bit
        for j in 0..k {
            let xj: Vec<Val> = x.iter().skip(j).step_by(k).copied().collect();
            let yj = spmv(&a, &xj);
            for i in 0..a.nrows {
                assert_eq!(base[i * k + j], yj[i], "k {k} col {j} row {i}");
            }
        }
        // the kernel reference agrees too
        assert_eq!(base, spmm(&a, &x, k), "k {k} kernel");
    }
}

#[test]
fn spmm_sim_strictly_beats_k_spmv_runs_on_wide_designs() {
    let a = gen::banded_fem(800, 7200, 73);
    for cfg in [FpgaConfig::reap64_spgemm(), FpgaConfig::reap128_spgemm()] {
        let schedule =
            schedule_spgemm(&a, &Csr::new(a.ncols, a.ncols), cfg.pipelines, cfg.bundle_size);
        let one = simulate_spmv(&a, &schedule, &cfg, Style::HandCoded);
        for k in [4usize, 8] {
            let wide = simulate_spmm(&a, &schedule, &cfg, Style::HandCoded, k);
            assert!(
                wide.stats.cycles < one.stats.cycles * k as u64,
                "{} k {k}: {} cycles !< {}",
                cfg.name,
                wide.stats.cycles,
                one.stats.cycles * k as u64
            );
            assert!(
                wide.stats.bytes_read < one.stats.bytes_read * k as u64,
                "{} k {k}: A-stream traffic must amortize",
                cfg.name
            );
        }
    }
}

#[test]
fn spmm_coordinator_end_to_end_matches_spmv_coordinator() {
    let a = gen::random_uniform(250, 250, 3500, 79);
    let k = 8usize;
    let x = panel(a.ncols, k, 79);
    for cfg in [FpgaConfig::reap64_spgemm(), FpgaConfig::reap128_spgemm()] {
        let rep = ReapSpmm::new(cfg.clone()).run(&a, &x, k).unwrap();
        let mut serial_total = 0.0f64;
        for j in 0..k {
            let xj: Vec<Val> = x.iter().skip(j).step_by(k).copied().collect();
            let solo = ReapSpmv::new(cfg.clone()).run(&a, &xj).unwrap();
            serial_total += solo.total_s;
            for i in 0..a.nrows {
                assert_eq!(rep.c[i * k + j], solo.y[i], "{} col {j}", cfg.name);
            }
        }
        assert!(rep.total_s > 0.0 && serial_total > 0.0);
        assert!(rep.fpga_s > 0.0);
    }
}

// the per-wave trace contract (see tests/integration_batch.rs for the
// other coordinators): the SpMM coordinator pads the CPU trace with zeros
// for replayed blocks, so both traces are block-major and equal-length
#[test]
fn spmm_coordinator_traces_equal_length() {
    let a = gen::power_law(150, 2000, 83);
    let cfg = FpgaConfig::reap64_spgemm();
    let schedule =
        schedule_spgemm(&a, &Csr::new(a.ncols, a.ncols), cfg.pipelines, cfg.bundle_size);
    for k in [4usize, 8, 20] {
        let sim = simulate_spmm(&a, &schedule, &cfg, Style::HandCoded, k);
        let n_blocks = k.div_ceil(cfg.vector_lanes);
        assert_eq!(sim.wave_cycles.len(), n_blocks * schedule.n_waves(), "k {k}");
        // the padded CPU trace the coordinator builds has the same length
        let mut cpu = schedule.wave_cpu_s.clone();
        cpu.resize(sim.wave_cycles.len(), 0.0);
        assert_eq!(cpu.len(), sim.wave_cycles.len(), "k {k}");
    }
}

#[test]
fn combined_sparse_and_panel_stream_roundtrips_through_dram_words() {
    let a = gen::power_law(40, 500, 89);
    let k = 5usize;
    let x = panel(a.ncols, k, 89);
    let mut s = BundleStream::new();
    let boundary = s.encode_csr_with_panel(&a, &x, k, 8);
    // byte accounting: sparse prefix + panel segment partition the stream
    assert_eq!(
        layout::segment_arena_words(&s, boundary, s.n_bundles()),
        layout::dense_panel_words(a.ncols, k, 8)
    );
    // through the DRAM word layout and back: the sparse half is A, the
    // panel half is X, both exact
    let words = layout::serialize_stream(&s);
    let bundles = layout::deserialize(&words).unwrap();
    assert_eq!(decode::bundles_to_csr(&bundles, a.nrows, a.ncols).unwrap(), a);
    assert_eq!(decode::stream_to_csr(&s, a.nrows, a.ncols).unwrap(), a);
    assert_eq!(
        decode::stream_panel_to_dense(&s, boundary, s.n_bundles(), a.ncols, k).unwrap(),
        x
    );
}
