//! Three-layer composition test: JAX/Pallas (L1/L2) → HLO artifacts →
//! PJRT runtime (L3) — the request path with Python out of the loop.
//!
//! Requires `make artifacts` to have produced `artifacts/`; the tests
//! skip (with a loud message) when artifacts are absent so `cargo test`
//! stays runnable before the first build.

use reap::coordinator::{verify, ReapCholesky, ReapSpgemm};
use reap::fpga::FpgaConfig;
use reap::kernels::spgemm;
use reap::runtime::{Manifest, XlaRuntime};
use reap::sparse::{gen, Dense};

fn runtime() -> Option<XlaRuntime> {
    if cfg!(not(feature = "xla")) {
        eprintln!("SKIP: built without the `xla` feature — PJRT path untested");
        return None;
    }
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        return None;
    }
    Some(XlaRuntime::load(&dir).expect("loading artifacts"))
}

#[test]
fn manifest_exposes_all_entries() {
    let Some(rt) = runtime() else { return };
    for entry in ["spgemm_bundle", "cholesky_dot", "cholesky_update"] {
        rt.manifest().entry(entry).unwrap();
    }
    assert!(!rt.platform().is_empty());
}

#[test]
fn spgemm_through_xla_matches_cpu_baseline() {
    let Some(rt) = runtime() else { return };
    for seed in 0..2u64 {
        let a = gen::random_uniform(24, 24, 140, seed);
        let b = gen::random_uniform(24, 24, 160, seed + 7);
        let coord = ReapSpgemm::with_runtime(FpgaConfig::reap32_spgemm(), &rt);
        let rep = coord.run(&a, &b).expect("xla spgemm");
        rep.c.validate().unwrap();
        let reference = spgemm(&a, &b);
        let v = verify::verify_csr(&rep.c, &reference);
        assert!(v.ok(1e-5), "seed {seed}: rel err {}", v.relative());
    }
}

#[test]
fn spgemm_through_xla_handles_bundle_overflow_rows() {
    let Some(rt) = runtime() else { return };
    // rows wider than one bundle (32) force chunk-pair accumulation
    let a = gen::random_uniform(4, 120, 300, 3);
    let b = gen::random_uniform(120, 40, 900, 4);
    let coord = ReapSpgemm::with_runtime(FpgaConfig::reap32_spgemm(), &rt);
    let rep = coord.run(&a, &b).expect("xla spgemm");
    let v = verify::verify_csr(&rep.c, &spgemm(&a, &b));
    assert!(v.ok(1e-5), "rel err {}", v.relative());
}

#[test]
fn spmv_through_xla_matches_cpu_baseline() {
    let Some(rt) = runtime() else { return };
    use reap::coordinator::ReapSpmv;
    let a = gen::random_uniform(60, 500, 2000, 8); // wide rows, many tiles
    let x: Vec<f32> = (0..500).map(|i| (i as f32 * 0.013).sin()).collect();
    let rep = ReapSpmv::with_runtime(FpgaConfig::reap32_spgemm(), &rt)
        .run(&a, &x)
        .expect("xla spmv");
    let want = reap::kernels::spmv(&a, &x);
    let err = rep
        .y
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0f32, f32::max);
    assert!(err < 1e-3, "max err {err}");
}

#[test]
fn cholesky_through_xla_matches_dense_oracle() {
    let Some(rt) = runtime() else { return };
    let spd = gen::spd(gen::Family::BandedFem, 24, 120, 5);
    let lower = spd.lower_triangle();
    let coord = ReapCholesky::with_runtime(FpgaConfig::reap32_cholesky(), &rt);
    let rep = coord.run(&lower).expect("xla cholesky");
    let expect = Dense::from_csr(&spd.to_csr()).cholesky();
    let got = Dense::from_csr(&rep.factor.l.to_csr());
    let diff = got.max_abs_diff(&expect);
    assert!(diff < 1e-3, "max abs diff {diff}");
}

#[test]
fn cholesky_xla_and_rust_paths_agree() {
    let Some(rt) = runtime() else { return };
    let spd = gen::spd(gen::Family::BlockRandom, 30, 180, 6);
    let lower = spd.lower_triangle();
    let xla = ReapCholesky::with_runtime(FpgaConfig::reap32_cholesky(), &rt)
        .run(&lower)
        .unwrap();
    let rust = ReapCholesky::new(FpgaConfig::reap32_cholesky()).run(&lower).unwrap();
    let v = verify::verify_csc(&xla.factor.l, &rust.factor.l);
    assert!(v.ok(1e-4), "rel err {}", v.relative());
}
