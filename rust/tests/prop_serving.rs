//! Property tests for the serving runtime: randomized workloads and
//! cache traffic, with the invariants that hold for *every* draw —
//! cache hits replay bit-identically to cold scheduling (schedules and
//! numeric outputs), fingerprint collisions between structurally
//! different matrices are rejected, percentiles are monotone, and
//! arrivals are conserved across admitted/rejected/queued.

use reap::coordinator::batch::numeric_batch;
use reap::fpga::FpgaConfig;
use reap::rir::schedule::{compose_batch, schedule_spgemm_with_threads};
use reap::serving::{
    generate_workload, run_serving, ArrivalProcess, ScheduleCache, ServingConfig, WorkloadSpec,
};
use reap::sparse::{gen, Csr};
use reap::util::rng::Pcg64;

const PIPELINES: usize = 8;
const BUNDLE: usize = 16;

fn random_pair(rng: &mut Pcg64) -> (Csr, Csr) {
    let n = 20 + rng.next_below(30) as usize;
    let nnz = n * (3 + rng.next_below(4) as usize);
    let seed = rng.next_u64();
    (gen::random_uniform(n, n, nnz, seed), gen::random_uniform(n, n, nnz, seed ^ 0xABCD))
}

#[test]
fn cache_hits_replay_bit_identically_to_cold_scheduling() {
    for seed in 0..8u64 {
        let mut rng = Pcg64::new(0xCAC4E ^ seed);
        let pairs: Vec<(Csr, Csr)> = (0..3).map(|_| random_pair(&mut rng)).collect();
        let mut cache = ScheduleCache::new(PIPELINES, BUNDLE);
        // prime the cache, then look every pattern up again
        for (a, b) in &pairs {
            let (_, hit) = cache.get_or_schedule(a, b, 1);
            assert!(!hit, "seed {seed}: first sight must miss");
        }
        let mut warm = Vec::new();
        let mut cold = Vec::new();
        for (a, b) in &pairs {
            let (s, hit) = cache.get_or_schedule(a, b, 1);
            assert!(hit, "seed {seed}: second sight must hit");
            warm.push(s);
            let direct = schedule_spgemm_with_threads(a, b, PIPELINES, BUNDLE, 1);
            cold.push(direct);
        }
        for ((w, c), (a, _)) in warm.iter().zip(&cold).zip(&pairs) {
            assert_eq!(w.waves, c.waves, "seed {seed}, {} rows: wave-identical replay", a.nrows);
            assert_eq!(w.a_words, c.a_words, "seed {seed}");
            assert_eq!(w.b_words, c.b_words, "seed {seed}");
            assert_eq!(w.prep_cpu_s, 0.0, "seed {seed}: cached timing is stripped");
            assert!(w.wave_cpu_s.iter().all(|&t| t == 0.0), "seed {seed}");
        }
        // the composed batches — and their numerics — are bit-identical too
        let batch_warm = compose_batch(&warm, PIPELINES, BUNDLE);
        let batch_cold = compose_batch(&cold, PIPELINES, BUNDLE);
        assert_eq!(batch_warm.waves, batch_cold.waves, "seed {seed}");
        let out_warm = numeric_batch(&pairs, &batch_warm, 1);
        let out_cold = numeric_batch(&pairs, &batch_cold, 1);
        assert_eq!(out_warm, out_cold, "seed {seed}: numeric outputs must be bit-identical");
    }
}

#[test]
fn masked_fingerprint_collisions_are_always_rejected() {
    for seed in 0..6u64 {
        // mask 0 maps every pattern to one bucket: all-pairs collisions
        let mut cache = ScheduleCache::with_mask(PIPELINES, BUNDLE, 0);
        // strictly growing dimension guarantees distinct structures
        let pairs: Vec<(Csr, Csr)> = (0..5u64)
            .map(|i| {
                let n = 20 + i as usize;
                let s = 0xC011 ^ (seed << 8) ^ i;
                (gen::random_uniform(n, n, n * 4, s), gen::random_uniform(n, n, n * 4, s ^ 1))
            })
            .collect();
        for (i, (a, b)) in pairs.iter().enumerate() {
            let (schedule, hit) = cache.get_or_schedule(a, b, 1);
            assert!(!hit, "seed {seed}: structurally new pattern {i} must never hit");
            let direct = schedule_spgemm_with_threads(a, b, PIPELINES, BUNDLE, 1);
            assert_eq!(schedule.waves, direct.waves, "seed {seed}: collision must not alias");
        }
        assert_eq!(cache.collisions(), pairs.len() as u64 - 1, "one collision per re-probe");
        assert_eq!(cache.len(), pairs.len(), "every pattern is cached despite colliding");
        for (a, b) in &pairs {
            assert!(cache.get_or_schedule(a, b, 1).1, "seed {seed}: exact key still hits");
        }
    }
}

#[test]
fn percentiles_monotone_and_arrivals_conserved_under_random_traffic() {
    for seed in 0..8u64 {
        let mut rng = Pcg64::new(0x7AFF1C ^ seed);
        let process = match rng.next_below(3) {
            0 => ArrivalProcess::Poisson { rate_hz: 10_000.0 + rng.next_f64() * 90_000.0 },
            1 => ArrivalProcess::BurstyOnOff {
                rate_hz: 20_000.0 + rng.next_f64() * 80_000.0,
                burst: 2 + rng.next_below(6) as usize,
                idle_s: 1e-4 + rng.next_f64() * 1e-3,
            },
            _ => ArrivalProcess::Trace {
                inter_arrival_s: (0..4).map(|_| rng.next_f64() * 2e-4).collect(),
            },
        };
        let spec = WorkloadSpec {
            seed: rng.next_u64(),
            n_jobs: 20 + rng.next_below(20) as usize,
            tenants: 1 + rng.next_below(4) as u32,
            pool_per_tenant: 1 + rng.next_below(5) as usize,
            repeat_ratio: rng.next_f64(),
            dim: 20 + rng.next_below(20) as usize,
            process,
        };
        let mut cfg = ServingConfig::new(FpgaConfig::reap64_spgemm());
        cfg.use_cache = rng.chance(0.5);
        cfg.admission.latency_budget_s = [2e-4, 1e-3, 5e-3][rng.next_below(3) as usize];
        if rng.chance(0.3) {
            cfg.max_windows = Some(1 + rng.next_below(5) as usize);
        }
        let rep = run_serving(&cfg, &generate_workload(&spec)).expect("serving run");
        assert!(
            rep.p50_s <= rep.p95_s && rep.p95_s <= rep.p99_s,
            "seed {seed}: percentiles must be monotone ({}, {}, {})",
            rep.p50_s,
            rep.p95_s,
            rep.p99_s
        );
        assert_eq!(
            rep.log.admitted + rep.log.rejected + rep.log.queued,
            rep.log.arrived,
            "seed {seed}: conservation"
        );
        if cfg.max_windows.is_none() {
            assert_eq!(rep.log.arrived, spec.n_jobs, "seed {seed}: an unbounded run drains");
            assert_eq!(rep.log.queued, 0, "seed {seed}");
        }
        assert_eq!(rep.latencies_s.len(), rep.log.admitted, "seed {seed}");
        assert!((0.0..=1.0).contains(&rep.hit_rate), "seed {seed}");
        assert!(rep.latencies_s.iter().all(|&(_, l)| l >= 0.0), "seed {seed}");
    }
}
