//! Integration: the REAP Cholesky flow across modules (sparse → symbolic →
//! coordinator → fpga sim → triangular solve), with edge cases and failure
//! injection.

use reap::coordinator::{verify, ReapCholesky};
use reap::fpga::FpgaConfig;
use reap::kernels::{cholesky, triangular};
use reap::sparse::gen::{self, Family};
use reap::sparse::{ops, Coo, Dense};

#[test]
fn full_flow_on_every_family() {
    for fam in [Family::RandomUniform, Family::BandedFem, Family::PowerLaw, Family::BlockRandom] {
        let lower = gen::spd(fam, 120, 700, 1).lower_triangle();
        let rep = ReapCholesky::new(FpgaConfig::reap32_cholesky()).run(&lower).unwrap();
        let reference = cholesky::cholesky(&lower).unwrap();
        let v = verify::verify_csc(&rep.factor.l, &reference.l);
        assert!(v.ok(1e-5), "{fam}: rel err {}", v.relative());
    }
}

#[test]
fn factor_solves_systems() {
    let spd = gen::spd(Family::BandedFem, 200, 1600, 2);
    let lower = spd.lower_triangle();
    let rep = ReapCholesky::new(FpgaConfig::reap64_cholesky()).run(&lower).unwrap();
    let x_true: Vec<f32> = (0..200).map(|i| (i as f32 * 0.37).sin()).collect();
    let b = Dense::from_csr(&spd.to_csr()).matvec(&x_true);
    let x = triangular::solve_spd(&rep.factor.l, &b);
    let err = x
        .iter()
        .zip(&x_true)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(err < 1e-2, "solution error {err}");
}

#[test]
fn identity_and_diagonal_edge_cases() {
    // pure diagonal SPD: L = sqrt(D), no dependencies at all
    let mut coo = Coo::new(30, 30);
    for i in 0..30 {
        coo.push(i, i, (i + 1) as f32);
    }
    let lower = coo.to_csr().to_csc().lower_triangle();
    let rep = ReapCholesky::new(FpgaConfig::reap32_cholesky()).run(&lower).unwrap();
    for i in 0..30 {
        let want = ((i + 1) as f32).sqrt();
        assert!((rep.factor.l.get(i, i) - want).abs() < 1e-5);
    }
    assert_eq!(rep.factor.l.nnz(), 30);
}

#[test]
fn dense_column_worst_case() {
    // arrowhead with dense first column: maximal fill, deep dependencies
    let n = 60;
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, n as f32);
        if i > 0 {
            coo.push(i, 0, 1.0);
            coo.push(0, i, 1.0);
        }
    }
    let lower = coo.to_csr().to_csc().lower_triangle();
    let rep = ReapCholesky::new(FpgaConfig::reap32_cholesky()).run(&lower).unwrap();
    // L fully dense lower triangular
    assert_eq!(rep.factor.l.nnz(), n * (n + 1) / 2);
    let expect = Dense::from_csr(&ops::make_spd(&coo.to_csr()).to_csr());
    let _ = expect; // pattern check above is the point; numerics:
    let reference = cholesky::cholesky(&lower).unwrap();
    let v = verify::verify_csc(&rep.factor.l, &reference.l);
    assert!(v.ok(1e-5));
}

#[test]
fn indefinite_matrix_fails_cleanly() {
    let mut coo = Coo::new(3, 3);
    coo.push(0, 0, 1.0);
    coo.push(1, 1, -5.0); // negative pivot
    coo.push(2, 2, 1.0);
    let lower = coo.to_csr().to_csc().lower_triangle();
    let err = ReapCholesky::new(FpgaConfig::reap32_cholesky()).run(&lower).unwrap_err();
    assert!(format!("{err:#}").contains("positive definite"));
}

#[test]
fn breakdown_and_sim_accounting_consistent() {
    let lower = gen::spd(Family::BandedFem, 150, 1100, 3).lower_triangle();
    let rep = ReapCholesky::new(FpgaConfig::reap32_cholesky()).run(&lower).unwrap();
    // per-column pipelined overlap: bounded by the serial sum and by the
    // larger side (the symbolic analysis prologue cannot overlap)
    assert!(rep.total_s <= rep.cpu_symbolic_s + rep.fpga_s + 1e-9);
    assert!(rep.total_s >= rep.cpu_symbolic_s.max(rep.fpga_s) - 1e-9);
    assert_eq!(
        rep.fpga_sim.compute_bound_cycles + rep.fpga_sim.dram_bound_cycles,
        rep.fpga_sim.cycles
    );
    assert!(rep.fpga_sim.flops > 0);
    assert!(rep.fpga_sim.bytes_read > 0);
    assert!(rep.fpga_sim.bytes_written > 0);
}

#[test]
fn reap64_dominates_reap32_on_wide_columns() {
    // block pattern → columns with many nonzeros → pipeline parallelism
    let lower = gen::spd(Family::BlockRandom, 300, 4000, 4).lower_triangle();
    let r32 = ReapCholesky::new(FpgaConfig::reap32_cholesky()).run(&lower).unwrap();
    let r64 = ReapCholesky::new(FpgaConfig::reap64_cholesky()).run(&lower).unwrap();
    assert!(r64.fpga_s <= r32.fpga_s * 1.05, "{} vs {}", r64.fpga_s, r32.fpga_s);
}
