//! Integration: the REAP SpGEMM flow across modules (sparse → rir →
//! coordinator → fpga sim → verify), including edge cases and failure
//! injection.

use reap::coordinator::{verify, ReapSpgemm};
use reap::fpga::FpgaConfig;
use reap::kernels::spgemm;
use reap::sparse::gen::{self, Family};
use reap::sparse::{mm, Csr, Dense};

#[test]
fn full_flow_on_every_family() {
    for fam in [Family::RandomUniform, Family::BandedFem, Family::PowerLaw, Family::BlockRandom] {
        let a = gen::generate(fam, 300, 4000, 1);
        let rep = ReapSpgemm::new(FpgaConfig::reap32_spgemm()).run(&a, &a).unwrap();
        assert_eq!(rep.c, spgemm(&a, &a), "{fam}");
        assert!(rep.fpga_sim.cycles > 0);
        assert!(rep.total_s > 0.0);
    }
}

#[test]
fn all_design_points_agree_numerically() {
    let a = gen::generate(Family::PowerLaw, 200, 3000, 2);
    let expect = spgemm(&a, &a);
    for cfg in [
        FpgaConfig::reap32_spgemm(),
        FpgaConfig::reap64_spgemm(),
        FpgaConfig::reap128_spgemm(),
    ] {
        let rep = ReapSpgemm::new(cfg).run(&a, &a).unwrap();
        assert_eq!(rep.c, expect);
    }
}

#[test]
fn rectangular_chain_through_mm_roundtrip() {
    // A(40x70) * B(70x25) written+read through MatrixMarket then multiplied
    let a = gen::random_uniform(40, 70, 600, 3);
    let b = gen::random_uniform(70, 25, 500, 4);
    let dir = std::env::temp_dir().join(format!("reap_it_{}", std::process::id()));
    mm::write_csr(&dir.join("a.mtx"), &a).unwrap();
    mm::write_csr(&dir.join("b.mtx"), &b).unwrap();
    let a2 = mm::read_csr(&dir.join("a.mtx")).unwrap();
    let b2 = mm::read_csr(&dir.join("b.mtx")).unwrap();
    let rep = ReapSpgemm::new(FpgaConfig::reap32_spgemm()).run(&a2, &b2).unwrap();
    let dense = Dense::from_csr(&a).matmul(&Dense::from_csr(&b));
    assert!(Dense::from_csr(&rep.c).max_abs_diff(&dense) < 1e-3);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pathological_shapes() {
    // single row, single column, fully dense row, all-empty
    let cfg = FpgaConfig::reap32_spgemm();

    let dense_row = gen::random_uniform(1, 500, 500, 5); // one 500-nnz row
    let b = gen::random_uniform(500, 30, 2000, 6);
    let rep = ReapSpgemm::new(cfg.clone()).run(&dense_row, &b).unwrap();
    assert_eq!(rep.c, spgemm(&dense_row, &b));

    let col = gen::random_uniform(60, 1, 40, 7);
    let row = gen::random_uniform(1, 60, 30, 8);
    let rep = ReapSpgemm::new(cfg.clone()).run(&col, &row).unwrap();
    assert_eq!(rep.c, spgemm(&col, &row)); // outer product, 60x60

    let empty = Csr::new(50, 50);
    let rep = ReapSpgemm::new(cfg).run(&empty, &empty).unwrap();
    assert_eq!(rep.c.nnz(), 0);
    assert_eq!(rep.fpga_sim.cycles, 0);
}

#[test]
#[should_panic(expected = "inner dimensions")]
fn dimension_mismatch_rejected() {
    let a = gen::random_uniform(4, 5, 8, 9);
    let b = gen::random_uniform(6, 4, 8, 10);
    let _ = ReapSpgemm::new(FpgaConfig::reap32_spgemm()).run(&a, &b);
}

#[test]
fn verification_detects_corruption() {
    let a = gen::random_uniform(50, 50, 400, 11);
    let good = spgemm(&a, &a);
    let mut bad = good.clone();
    let mid = bad.vals.len() / 2;
    bad.vals[mid] += 0.5;
    let v = verify::verify_csr(&bad, &good);
    assert!(!v.ok(1e-9), "corruption must be detected");
    assert!(verify::verify_csr(&good, &good).ok(0.0));
}

#[test]
fn speedup_shape_reap64_beats_reap32_on_big_work() {
    let a = gen::generate(Family::BandedFem, 800, 16000, 12);
    let r32 = ReapSpgemm::new(FpgaConfig::reap32_spgemm()).run(&a, &a).unwrap();
    let r64 = ReapSpgemm::new(FpgaConfig::reap64_spgemm()).run(&a, &a).unwrap();
    assert!(
        r64.fpga_s < r32.fpga_s,
        "REAP-64 must beat REAP-32 on FPGA time: {} vs {}",
        r64.fpga_s,
        r32.fpga_s
    );
}
