//! End-to-end tests of the `reap lint` subcommand: exit 0 with a clean
//! report on every shipped workload/design/encoding combination, and a
//! non-zero exit with machine-readable JSON naming the violated invariant
//! when an artifact is corrupted via `--seed-violation`.

use std::process::{Command, Output};

fn reap(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_reap")).args(args).output().expect("run reap binary")
}

#[test]
fn lint_is_clean_on_shipped_workloads() {
    for v in ["reap32", "reap64"] {
        for e in ["raw", "bitmap+fx32"] {
            let args = ["lint", "--n", "100", "--nnz", "1200", "--variant", v, "--encoding", e];
            let out = reap(&args);
            let stdout = String::from_utf8_lossy(&out.stdout);
            assert!(
                out.status.success(),
                "{v}/{e} must lint clean:\n{stdout}\n{}",
                String::from_utf8_lossy(&out.stderr)
            );
            assert!(stdout.contains("0 error(s), 0 warning(s)"), "{v}/{e}: {stdout}");
        }
    }
}

#[test]
fn seeded_violations_fail_with_machine_readable_json() {
    let cases = [("schedule", "SCH-CHUNK-DUP"), ("stream", "STR-CRC"), ("wave", "WAV-OVERFULL")];
    for (kind, code) in cases {
        let args = ["lint", "--n", "100", "--nnz", "1200", "--seed-violation", kind, "--json"];
        let out = reap(&args);
        assert!(!out.status.success(), "a seeded {kind} violation must fail the lint");
        let stdout = String::from_utf8_lossy(&out.stdout);
        let json = stdout.trim();
        assert!(json.starts_with('{') && json.ends_with('}'), "not one JSON object: {stdout}");
        assert!(json.contains(code), "expected {code} in: {stdout}");
        assert!(json.contains("\"errors\": "), "summary fields missing: {stdout}");
    }
}

#[test]
fn human_report_names_the_location() {
    let args = ["lint", "--n", "100", "--nnz", "1200", "--seed-violation", "wave"];
    let out = reap(&args);
    assert!(!out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    // diagnostics carry the workload-qualified location prefix
    assert!(stdout.contains("spgemm waves"), "{stdout}");
    assert!(stdout.contains("error[WAV-OVERFULL]"), "{stdout}");
}
