//! Integration tests for the multi-tenant batched SpGEMM path, plus the
//! per-wave trace-wiring contracts every coordinator relies on: the
//! overlap model ([`reap::coordinator::overlap::pipelined_total`])
//! tolerates mismatched CPU/FPGA traces with a logged warning, so these
//! tests pin the invariant that no coordinator actually produces skewed
//! traces.

use reap::coordinator::batch::numeric_batch;
use reap::coordinator::{ReapBatch, ReapSpgemm};
use reap::fpga::spgemm_sim::{simulate_spgemm, simulate_spgemm_batch, Style};
use reap::fpga::spmv_sim::simulate_spmv;
use reap::fpga::cholesky_sim::simulate_cholesky;
use reap::fpga::FpgaConfig;
use reap::kernels::spgemm;
use reap::rir::schedule::{schedule_spgemm, schedule_spgemm_batch};
use reap::sparse::{gen, Csr};
use reap::symbolic::CholeskySymbolic;

fn small_jobs(n_jobs: usize, seed: u64) -> Vec<(Csr, Csr)> {
    (0..n_jobs)
        .map(|j| {
            let s = seed + j as u64 * 7;
            let n = 20 + (j * 9) % 40;
            (
                gen::power_law(n, n * 5, s),
                gen::random_uniform(n, n, n * 5, s + 1),
            )
        })
        .collect()
}

#[test]
fn batched_run_bit_identical_to_independent_runs() {
    let mut jobs = small_jobs(8, 500);
    jobs.push((Csr::new(6, 9), Csr::new(9, 4))); // empty tenant
    for design in [FpgaConfig::reap64_spgemm(), FpgaConfig::reap128_spgemm()] {
        let batch = ReapBatch::new(design.clone()).run(&jobs).unwrap();
        for (j, (a, b)) in jobs.iter().enumerate() {
            let solo = ReapSpgemm::new(design.clone()).run(a, b).unwrap();
            assert_eq!(batch.outputs[j], solo.c, "{} job {j}", design.name);
            assert_eq!(batch.outputs[j], spgemm(a, b), "{} job {j} baseline", design.name);
        }
    }
}

#[test]
fn batched_occupancy_beats_serial_on_wide_designs() {
    let jobs = small_jobs(12, 900);
    for design in [FpgaConfig::reap64_spgemm(), FpgaConfig::reap128_spgemm()] {
        let batch = ReapBatch::new(design.clone()).run(&jobs).unwrap();
        let mut busy = 0u64;
        let mut slots = 0u64;
        let mut cycles = 0u64;
        for (a, b) in &jobs {
            let rep = ReapSpgemm::new(design.clone()).run(a, b).unwrap();
            busy += rep.fpga_sim.busy_pipeline_cycles;
            slots += rep.fpga_sim.busy_pipeline_cycles + rep.fpga_sim.idle_pipeline_cycles;
            cycles += rep.fpga_sim.cycles;
        }
        let serial_occ = busy as f64 / slots as f64;
        assert!(
            batch.fpga_sim.pipeline_utilization() > serial_occ,
            "{}: batched {:.3} vs serial {:.3}",
            design.name,
            batch.fpga_sim.pipeline_utilization(),
            serial_occ
        );
        assert!(batch.fpga_sim.cycles < cycles, "{}: batched cycles must win", design.name);
    }
}

#[test]
fn batch_numeric_thread_invariance_across_counts() {
    let jobs = small_jobs(6, 1300);
    let s = schedule_spgemm_batch(&jobs, 64, 32);
    let base = numeric_batch(&jobs, &s, 1);
    for t in [2usize, 4, 8] {
        assert_eq!(numeric_batch(&jobs, &s, t), base, "threads={t}");
    }
}

// ---- per-wave trace wiring: every coordinator emits equal-length
// CPU/FPGA traces (the overlap model warns on skew; these pin it) ----

#[test]
fn spgemm_coordinator_traces_equal_length() {
    let a = gen::power_law(120, 2400, 31);
    let b = gen::random_uniform(120, 120, 1800, 32);
    let cfg = FpgaConfig::reap32_spgemm();
    let schedule = schedule_spgemm(&a, &b, cfg.pipelines, cfg.bundle_size);
    let sim = simulate_spgemm(&a, &b, &schedule, &cfg, Style::HandCoded);
    assert_eq!(schedule.wave_cpu_s.len(), sim.wave_cycles.len());
}

#[test]
fn spmv_coordinator_traces_equal_length() {
    let a = gen::power_law(150, 2000, 41);
    let cfg = FpgaConfig::reap32_spgemm();
    let surrogate = Csr::new(a.ncols, a.ncols);
    let schedule = schedule_spgemm(&a, &surrogate, cfg.pipelines, cfg.bundle_size);
    let sim = simulate_spmv(&a, &schedule, &cfg, Style::HandCoded);
    assert_eq!(schedule.wave_cpu_s.len(), sim.wave_cycles.len());
}

#[test]
fn cholesky_coordinator_traces_equal_length() {
    let spd = gen::spd(gen::Family::BandedFem, 60, 400, 51);
    let lower = spd.lower_triangle();
    let cfg = FpgaConfig::reap32_cholesky();
    let sym = CholeskySymbolic::analyze(&lower, cfg.bundle_size);
    let sim = simulate_cholesky(&sym, &cfg, Style::HandCoded);
    assert_eq!(sym.encode_col_s().len(), sim.column_cycles.len());
}

#[test]
fn batch_coordinator_traces_equal_length() {
    let jobs = small_jobs(5, 61);
    let cfg = FpgaConfig::reap64_spgemm();
    let schedule = schedule_spgemm_batch(&jobs, cfg.pipelines, cfg.bundle_size);
    let sim = simulate_spgemm_batch(&jobs, &schedule, &cfg, Style::HandCoded);
    assert_eq!(schedule.wave_cpu_s.len(), sim.wave_cycles.len());
    assert_eq!(schedule.n_waves(), sim.wave_cycles.len());
}
