//! Mutation tests for `reap::analysis`: take a *valid* artifact from the
//! real scheduler / encoder / simulator, corrupt it in one targeted way,
//! and pin the diagnostic code the audit must produce. Each test first
//! asserts the unmutated artifact is clean, so an audit pass that
//! silently stopped checking anything cannot keep these green. Where the
//! corruption is surgical the test pins *exactly one* diagnostic; where
//! it legitimately cascades (a bad extent also breaks coverage) the test
//! pins the primary code and, when it matters, the suppression contract.

use reap::analysis::{
    audit_batch_schedule, audit_serving, audit_spgemm_schedule, audit_stream, audit_wave_costs,
    codes, Diagnostic, Severity,
};
use reap::fpga::engine::{Occupancy, WaveKind};
use reap::fpga::spgemm_sim::{simulate_spgemm, Style};
use reap::fpga::spmv_sim::simulate_spmv;
use reap::fpga::FpgaConfig;
use reap::rir::layout::{
    bitmap_index_words, fx_value_words, serialize_stream, serialize_stream_checksummed,
};
use reap::rir::schedule::{schedule_spgemm, schedule_spgemm_batch, BatchSchedule, SpgemmSchedule};
use reap::rir::{BundleFlags, BundleStream};
use reap::serving::{generate_workload, run_serving, ServingConfig, ServingLog, WorkloadSpec};
use reap::sparse::{gen, Csr};

fn assert_single(diags: &[Diagnostic], code: &str, severity: Severity) {
    assert_eq!(diags.len(), 1, "expected exactly one diagnostic: {diags:?}");
    assert_eq!(diags[0].code, code, "{diags:?}");
    assert_eq!(diags[0].severity, severity, "{diags:?}");
}

fn assert_has(diags: &[Diagnostic], code: &str) {
    assert!(diags.iter().any(|d| d.code == code), "missing {code}: {diags:?}");
}

// ---------------------------------------------------------------------------
// ScheduleAudit — single-job
// ---------------------------------------------------------------------------

/// A clean single-job schedule plus its source matrix. 60 single-chunk
/// rows on 8 pipelines at bundle 16: seven full waves and one spare-slot
/// tail wave.
fn spgemm_base() -> (Csr, SpgemmSchedule) {
    let a = gen::random_uniform(60, 60, 900, 11);
    let s = schedule_spgemm(&a, &a, 8, 16);
    assert!(audit_spgemm_schedule(&a, &a, &s).is_empty(), "premise: base schedule is clean");
    (a, s)
}

#[test]
fn duplicated_chunk_is_pinned_to_chunk_dup() {
    let (a, mut s) = spgemm_base();
    let wid = s
        .waves
        .iter()
        .position(|w| !w.assignments.is_empty() && w.assignments.len() < s.pipelines)
        .expect("a wave with spare capacity");
    let asg = s.waves[wid].assignments[0];
    // same wave, so the B-row union is unchanged; repair the traffic
    // accounting so duplication is the *only* violation left
    s.waves[wid].assignments.push(asg);
    s.a_words += 2 + 2 * asg.len;
    assert_single(&audit_spgemm_schedule(&a, &a, &s), codes::SCH_CHUNK_DUP, Severity::Error);
}

#[test]
fn capacity_cut_flags_every_overfull_wave() {
    let (a, mut s) = spgemm_base();
    s.pipelines = 4; // the scheduler packed waves for 8
    let diags = audit_spgemm_schedule(&a, &a, &s);
    assert!(!diags.is_empty(), "waves packed for 8 pipelines cannot fit 4");
    assert!(diags.iter().all(|d| d.code == codes::SCH_WAVE_OVERFULL), "{diags:?}");
}

#[test]
fn oversized_chunk_is_reported_and_suppresses_word_accounting() {
    let (a, mut s) = spgemm_base();
    s.waves[0].assignments[0].len = s.bundle_size + 1;
    let diags = audit_spgemm_schedule(&a, &a, &s);
    assert_has(&diags, codes::SCH_CHUNK_LEN);
    // a bad extent makes the recomputed traffic meaningless — SCH-WORDS
    // must stay quiet rather than pile a bogus mismatch on top
    assert!(diags.iter().all(|d| d.code != codes::SCH_WORDS), "{diags:?}");
}

#[test]
fn flipped_last_chunk_flag_is_pinned() {
    let (a, mut s) = spgemm_base();
    let asg = &mut s.waves[0].assignments[0];
    asg.last_chunk = !asg.last_chunk;
    assert_single(&audit_spgemm_schedule(&a, &a, &s), codes::SCH_LAST_CHUNK, Severity::Error);
}

#[test]
fn dropped_chunk_breaks_coverage() {
    let (a, mut s) = spgemm_base();
    let wid = s.waves.len() - 1;
    let asg = s.waves[wid].assignments.pop().expect("non-empty tail wave");
    s.a_words -= 2 + 2 * asg.len; // keep the accounting honest
    assert_has(&audit_spgemm_schedule(&a, &a, &s), codes::SCH_COVERAGE);
}

#[test]
fn unsorted_b_rows_are_pinned() {
    let (a, mut s) = spgemm_base();
    let wid = s
        .waves
        .iter()
        .position(|w| w.b_rows.len() >= 2)
        .expect("a wave streaming at least two B rows");
    s.waves[wid].b_rows.swap(0, 1); // same multiset, wrong order
    assert_single(&audit_spgemm_schedule(&a, &a, &s), codes::SCH_B_ROWS, Severity::Error);
}

#[test]
fn word_accounting_drift_is_pinned() {
    let (a, mut s) = spgemm_base();
    s.a_words += 2;
    assert_single(&audit_spgemm_schedule(&a, &a, &s), codes::SCH_WORDS, Severity::Error);
}

#[test]
fn truncated_cpu_trace_is_pinned() {
    let (a, mut s) = spgemm_base();
    s.wave_cpu_s.pop();
    assert_single(&audit_spgemm_schedule(&a, &a, &s), codes::SCH_TRACE, Severity::Error);
}

// ---------------------------------------------------------------------------
// ScheduleAudit — batch
// ---------------------------------------------------------------------------

/// Four 30-row jobs on 8 pipelines: 30 chunks per job, so waves straddle
/// job boundaries and carry multiple segments.
fn batch_base() -> (Vec<(Csr, Csr)>, BatchSchedule) {
    let jobs: Vec<(Csr, Csr)> = (0..4u64)
        .map(|j| {
            (gen::random_uniform(30, 30, 200, 40 + j), gen::random_uniform(30, 30, 200, 50 + j))
        })
        .collect();
    let s = schedule_spgemm_batch(&jobs, 8, 16);
    assert!(audit_batch_schedule(&jobs, &s).is_empty(), "premise: base batch schedule is clean");
    (jobs, s)
}

#[test]
fn out_of_range_job_tag_is_reported() {
    let (jobs, mut s) = batch_base();
    let bad = s.n_jobs as u32;
    s.waves[0].assignments[0].0 = bad;
    assert_has(&audit_batch_schedule(&jobs, &s), codes::SCH_JOB_TAG);
}

#[test]
fn swapped_segments_are_reported() {
    let (jobs, mut s) = batch_base();
    let wid = s
        .waves
        .iter()
        .position(|w| w.segments.len() >= 2)
        .expect("a wave straddling a job boundary");
    s.waves[wid].segments.swap(0, 1);
    assert_has(&audit_batch_schedule(&jobs, &s), codes::SCH_SEGMENT);
}

#[test]
fn cross_job_slot_swap_breaks_job_major_order() {
    let (jobs, mut s) = batch_base();
    let wid = s
        .waves
        .iter()
        .position(|w| w.assignments.first().map(|a| a.0) != w.assignments.last().map(|a| a.0))
        .expect("a wave carrying two jobs");
    let n = s.waves[wid].assignments.len();
    s.waves[wid].assignments.swap(0, n - 1);
    assert_has(&audit_batch_schedule(&jobs, &s), codes::SCH_JOB_ORDER);
}

// ---------------------------------------------------------------------------
// StreamAudit
// ---------------------------------------------------------------------------

/// Header positions of a plain (non-checksummed) serialized stream.
/// Metadata-only bundles carry 3-word entries, data bundles 2-word pairs.
fn bundle_headers(words: &[u32]) -> Vec<usize> {
    let md = u32::from(BundleFlags::METADATA_ONLY);
    let mut headers = Vec::new();
    let mut p = 0usize;
    while p < words.len() {
        headers.push(p);
        let count = (words[p] >> 8) as usize;
        let per = if words[p] & md != 0 { 3 } else { 2 };
        p += 2 + per * count;
    }
    assert_eq!(p, words.len(), "premise: the walk stays bundle-aligned");
    headers
}

#[test]
fn cleared_final_eos_on_a_segmented_stream_is_an_error() {
    let a = gen::random_uniform(20, 20, 150, 5);
    let b = gen::random_uniform(25, 25, 200, 6);
    let mut s = BundleStream::new();
    s.encode_csr_jobs(&[&a, &b], 16);
    let mut words = serialize_stream(&s);
    assert!(audit_stream(&words).is_empty(), "premise: the job stream is clean");
    let eos = u32::from(BundleFlags::END_OF_STREAM);
    let headers = bundle_headers(&words);
    let terminators = headers.iter().filter(|&&h| words[h] & eos != 0).count();
    assert!(terminators >= 2, "premise: every job segment carries a terminator");
    let last = *headers.last().unwrap();
    words[last] &= !eos;
    let diags = audit_stream(&words);
    assert_single(&diags, codes::STR_EOS, Severity::Error);
}

#[test]
fn truncation_is_reported() {
    let a = gen::random_uniform(40, 40, 500, 7);
    let mut words = serialize_stream(&BundleStream::from_csr(&a, 16));
    assert!(audit_stream(&words).is_empty(), "premise: the stream is clean");
    words.pop();
    assert_has(&audit_stream(&words), codes::STR_TRUNCATED);
}

#[test]
fn damage_under_a_crc_trailer_is_reported() {
    let a = gen::random_uniform(40, 40, 500, 8);
    let mut words = serialize_stream_checksummed(&BundleStream::from_csr(&a, 16));
    assert!(audit_stream(&words).is_empty(), "premise: the stream is clean");
    words[2] ^= 1; // first payload word of bundle 0, covered by its CRC
    assert_has(&audit_stream(&words), codes::STR_CRC);
}

#[test]
fn wasteful_bitmap_section_warns() {
    // two far-apart indices: the canonical bitmap section (base, span,
    // two L0 words, two L1 words) is 6 words — worse than the 2 raw index
    // words it replaces, so the encoder's negotiation never emits this
    let cols = [0u32, 2000];
    let idx_words = bitmap_index_words(&cols).expect("ascending cols have a canonical form");
    assert_eq!(idx_words, 6, "premise: section accounting");
    let flags = BundleFlags::BITMAP | BundleFlags::END_OF_STREAM;
    let mut words = vec![(2u32 << 8) | u32::from(flags), 0];
    words.extend_from_slice(&[0, 2001, 1, 1 << 30, 1, 1 << 16]); // index section
    words.extend_from_slice(&[1.5f32.to_bits(), 2.5f32.to_bits()]); // raw value section
    let diags = audit_stream(&words);
    assert_single(&diags, codes::STR_BITMAP_WASTE, Severity::Warning);
}

#[test]
fn nonfinite_fx_scale_is_an_error() {
    assert_eq!(fx_value_words(2), 2, "premise: scale word + one packed word");
    let flags = BundleFlags::FIXED_POINT | BundleFlags::END_OF_STREAM;
    let words = [
        (2u32 << 8) | u32::from(flags),
        0,
        1, // raw index section
        5,
        f32::NAN.to_bits(), // scale word
        0x4000_2000,        // packed Q1.15 pair
    ];
    let diags = audit_stream(&words);
    assert_single(&diags, codes::STR_FX_SCALE, Severity::Error);
}

#[test]
fn descending_raw_indices_warn() {
    let flags = BundleFlags::END_OF_STREAM;
    let words = [
        (2u32 << 8) | u32::from(flags),
        0,
        9, // index 9 then index 3: not strictly ascending
        1.0f32.to_bits(),
        3,
        2.0f32.to_bits(),
    ];
    let diags = audit_stream(&words);
    assert_single(&diags, codes::STR_INDEX_ORDER, Severity::Warning);
}

// ---------------------------------------------------------------------------
// WaveCostAudit
// ---------------------------------------------------------------------------

fn wave_base(seed: u64) -> (FpgaConfig, Vec<reap::fpga::WaveCost>) {
    let a = gen::random_uniform(50, 50, 700, seed);
    let cfg = FpgaConfig::reap32_spgemm();
    let s = schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
    let costs = simulate_spgemm(&a, &a, &s, &cfg, Style::HandCoded).costs;
    assert!(audit_wave_costs(&costs, &cfg).is_empty(), "premise: simulated costs are clean");
    (cfg, costs)
}

#[test]
fn occupancy_bump_on_simulated_costs_is_pinned() {
    let (cfg, mut costs) = wave_base(21);
    let k = costs.iter().position(|c| c.kind == WaveKind::Compute).expect("a compute wave");
    costs[k].occupancy = Occupancy::ActivePipelines(cfg.pipelines as u64 + 1);
    assert_single(&audit_wave_costs(&costs, &cfg), codes::WAV_OVERFULL, Severity::Error);
}

#[test]
fn zeroed_wave_contribution_is_pinned() {
    let (cfg, mut costs) = wave_base(24);
    let k = costs.iter().position(|c| c.kind == WaveKind::Compute).expect("a compute wave");
    costs[k].waves = 0;
    assert_single(&audit_wave_costs(&costs, &cfg), codes::WAV_ZERO_WAVES, Severity::Error);
}

#[test]
fn dependent_stream_after_a_pure_load_is_pinned() {
    let a = gen::random_uniform(50, 50, 700, 22);
    let cfg = FpgaConfig::reap32_spgemm();
    let s = schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
    let mut costs = simulate_spmv(&a, &s, &cfg, Style::HandCoded).costs;
    assert!(audit_wave_costs(&costs, &cfg).is_empty(), "premise: simulated costs are clean");
    assert_eq!(costs[0].kind, WaveKind::Load, "premise: SpMV leads with an x-vector load");
    assert_eq!(costs[0].writeback_words, 0, "premise: a pure load writes nothing back");
    costs[1].dependent_stream = true;
    assert_single(&audit_wave_costs(&costs, &cfg), codes::WAV_DEP_NO_PRODUCER, Severity::Error);
}

#[test]
fn load_smuggling_flops_is_pinned() {
    let a = gen::random_uniform(50, 50, 700, 23);
    let cfg = FpgaConfig::reap32_spgemm();
    let s = schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
    let mut costs = simulate_spmv(&a, &s, &cfg, Style::HandCoded).costs;
    assert!(audit_wave_costs(&costs, &cfg).is_empty(), "premise: simulated costs are clean");
    assert_eq!(costs[0].kind, WaveKind::Load, "premise: SpMV leads with an x-vector load");
    costs[0].flops = 7;
    assert_single(&audit_wave_costs(&costs, &cfg), codes::WAV_LOAD, Severity::Error);
}

// ---------------------------------------------------------------------------
// ServingAudit
// ---------------------------------------------------------------------------

/// A clean serving log straight from the event loop (which audits it
/// itself in debug builds — the mutations below corrupt a copy).
fn serving_base() -> ServingLog {
    let jobs = generate_workload(&WorkloadSpec::poisson(21, 24, 30_000.0, 0.5));
    let cfg = ServingConfig::new(FpgaConfig::reap64_spgemm());
    let log = run_serving(&cfg, &jobs).expect("serving run").log;
    assert!(audit_serving(&log).is_empty(), "premise: live log is clean");
    assert!(!log.batches.is_empty(), "premise: the workload admits batches");
    log
}

#[test]
fn budget_violating_admitted_job_is_pinned() {
    let mut log = serving_base();
    // age one admitted job past the latency budget at its window close:
    // the shed rule says the controller was required to reject it
    log.batches[0].jobs[0].arrival_s -= log.latency_budget_s + 1e-3;
    assert_single(&audit_serving(&log), codes::SRV_BUDGET, Severity::Error);
}

#[test]
fn batch_starting_before_its_window_close_is_pinned() {
    let mut log = serving_base();
    log.batches[0].start_s = log.batches[0].window_close_s - 1e-4;
    assert_single(&audit_serving(&log), codes::SRV_TIMELINE, Severity::Error);
}

#[test]
fn conservation_drift_is_pinned() {
    let mut log = serving_base();
    log.queued += 1; // claims a stranded job the batches/arrivals disprove
    assert_single(&audit_serving(&log), codes::SRV_CONSERVE, Severity::Error);
}
