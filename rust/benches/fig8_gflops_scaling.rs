//! Regenerates Fig 8: GFLOPS per FP unit (left) and the area/frequency
//! scaling of the FPGA design (right).

mod common;

fn main() {
    let cfg = common::bench_config();
    let (series, left, right) = reap::harness::fig8::run(&cfg);
    print!("{}", left.render());
    print!("{}", right.render());
    common::verdict(
        "REAP achieves higher GFLOPS per FP unit than the CPU at matched counts",
        reap::harness::fig8::headline_holds(&series),
    );
    cfg.dump_csv("fig8_left", &left).expect("csv");
    cfg.dump_csv("fig8_right", &right).expect("csv");
}
