//! Regenerates Fig 9: REAP speedup vs matrix density (SpGEMM + Cholesky),
//! the sparsity-sensitivity sweep with the CPU-crossover.

mod common;

fn main() {
    let cfg = common::bench_config();
    let (points, table) = reap::harness::fig9::run(&cfg);
    print!("{}", table.render());
    common::verdict(
        "REAP favors sparse matrices (speedup falls as density rises)",
        reap::harness::fig9::headline_holds(&points),
    );
    cfg.dump_csv("fig9", &table).expect("csv");
}
