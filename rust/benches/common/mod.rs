//! Shared plumbing for the custom bench binaries (criterion is not in the
//! offline cache; these benches print the paper-figure tables directly).

use reap::harness::RunConfig;

/// Bench-run configuration from environment (so `cargo bench` needs no
/// argument plumbing): `REAP_BENCH_MAX_ROWS` (default 1500),
/// `REAP_BENCH_BUDGET` seconds (default 0.1), `REAP_BENCH_SEED`.
pub fn bench_config() -> RunConfig {
    let env_usize = |k: &str, d: usize| {
        std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
    };
    let env_f64 = |k: &str, d: f64| {
        std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
    };
    RunConfig {
        max_rows: env_usize("REAP_BENCH_MAX_ROWS", 1500),
        seed: env_usize("REAP_BENCH_SEED", 0x5EA9) as u64,
        budget_s: env_f64("REAP_BENCH_BUDGET", 0.1),
        csv_dir: Some(std::path::PathBuf::from("results")),
    }
}

/// Print a headline verdict line.
pub fn verdict(paper_claim: &str, holds: bool) {
    println!(
        "paper: {paper_claim} -> headline {}",
        if holds { "HOLDS" } else { "DIFFERS" }
    );
}
