//! Regenerates §V-C: preprocessing benefit under the OpenCL-HLS variant
//! (REAP-HLS vs plain HLS) for SpGEMM and Cholesky.

mod common;

fn main() {
    let cfg = common::bench_config();
    let (report, table) = reap::harness::hls_cmp::run(&cfg);
    print!("{}", table.render());
    common::verdict(
        "+16% SpGEMM / +35% Cholesky geomean, positive everywhere",
        reap::harness::hls_cmp::headline_holds(&report),
    );
    cfg.dump_csv("hls", &table).expect("csv");
}
