//! Regenerates Fig 10: sparse Cholesky speedups of REAP-32/64 over the
//! CHOLMOD-class single-core numeric baseline.

mod common;

fn main() {
    let cfg = common::bench_config();
    let (rows, table) = reap::harness::fig10::run(&cfg);
    print!("{}", table.render());
    common::verdict(
        "REAP-32 GM ~1.18x; REAP-64 GM ~1.85x and wins everywhere",
        reap::harness::fig10::headline_holds(&rows),
    );
    cfg.dump_csv("fig10", &table).expect("csv");
    println!("perf records: results/BENCH_cholesky.json");
}
