//! Regenerates Fig 7 and Fig 11: the CPU-vs-FPGA time breakdowns for
//! REAP-32 SpGEMM (preprocessing) and Cholesky (symbolic analysis).

mod common;

fn main() {
    let cfg = common::bench_config();
    let (_, t7) = reap::harness::fig7::run(&cfg);
    print!("{}", t7.render());
    cfg.dump_csv("fig7", &t7).expect("csv");
    println!();
    let (rows11, t11) = reap::harness::fig11::run(&cfg);
    print!("{}", t11.render());
    common::verdict(
        "FPGA dominates the Cholesky breakdown",
        reap::harness::fig11::headline_holds(&rows11),
    );
    cfg.dump_csv("fig11", &t11).expect("csv");
}
