//! Micro-benchmarks of the L3 hot paths — the profile targets of the
//! performance pass (EXPERIMENTS.md §Perf): RIR encoding, scheduling,
//! symbolic analysis, the CPU baselines, and the simulators.

mod common;

use reap::fpga::spgemm_sim::{simulate_spgemm, Style};
use reap::fpga::cholesky_sim::simulate_cholesky;
use reap::fpga::FpgaConfig;
use reap::kernels::{cholesky, spgemm};
use reap::rir::{encode, layout, schedule};
use reap::sparse::gen;
use reap::symbolic::{symbolic_factor, CholeskySymbolic};
use reap::util::timer::measure_budgeted;

fn report(name: &str, per_call_s: f64, unit_count: f64, unit: &str) {
    println!(
        "{name:<34} {:>10.3} ms/call  {:>9.1} M{unit}/s",
        per_call_s * 1e3,
        unit_count / per_call_s / 1e6
    );
}

fn main() {
    let cfg = common::bench_config();
    let budget = cfg.budget_s;
    let n = cfg.max_rows;
    let a = gen::banded_fem(n, n * 16, cfg.seed);
    let nnz = a.nnz() as f64;
    println!("micro: n={n} nnz={nnz} budget={budget}s\n");

    let m = measure_budgeted(budget, 3, || encode::csr_to_bundles(&a, 32));
    report("rir_encode (csr->bundles)", m.min_s, nnz, "elem");

    let bundles = encode::csr_to_bundles(&a, 32);
    let m = measure_budgeted(budget, 3, || layout::serialize(&bundles));
    report("rir_serialize (bundles->words)", m.min_s, nnz, "elem");

    let words = layout::serialize(&bundles);
    let m = measure_budgeted(budget, 3, || layout::deserialize(&words).unwrap());
    report("rir_deserialize", m.min_s, nnz, "elem");

    let m = measure_budgeted(budget, 3, || schedule::schedule_spgemm(&a, &a, 32, 32));
    report("spgemm_schedule (CPU pass)", m.min_s, nnz, "elem");

    let m = measure_budgeted(budget, 3, || spgemm(&a, &a));
    let flops = reap::kernels::spgemm::spgemm_flops(&a, &a) as f64;
    report("spgemm_cpu_baseline", m.min_s, flops, "flop");

    let sched = schedule::schedule_spgemm(&a, &a, 32, 32);
    let fc = FpgaConfig::reap32_spgemm();
    let m = measure_budgeted(budget, 3, || simulate_spgemm(&a, &a, &sched, &fc, Style::HandCoded));
    report("spgemm_sim (cycle model)", m.min_s, flops, "flop");

    // Cholesky side on an SPD clone
    let spd = gen::spd(gen::Family::BandedFem, n.min(1200), n.min(1200) * 8, cfg.seed);
    let lower = spd.lower_triangle();
    let lnnz = lower.nnz() as f64;

    let m = measure_budgeted(budget, 3, || symbolic_factor(&lower));
    report("cholesky_symbolic (etree+pattern)", m.min_s, lnnz, "elem");

    let pattern = symbolic_factor(&lower);
    let m = measure_budgeted(budget, 3, || {
        cholesky::cholesky_numeric(&lower, &pattern).unwrap()
    });
    let cflops = cholesky::cholesky_flops(&pattern) as f64;
    report("cholesky_cpu_baseline (numeric)", m.min_s, cflops, "flop");

    let sym = CholeskySymbolic::analyze(&lower, 32);
    let cc = FpgaConfig::reap32_cholesky();
    let m = measure_budgeted(budget, 3, || simulate_cholesky(&sym, &cc, Style::HandCoded));
    report("cholesky_sim (cycle model)", m.min_s, cflops, "flop");
}
