//! Micro-benchmarks of the L3 hot paths — the profile targets of the
//! performance pass (EXPERIMENTS.md §Perf): RIR encoding, scheduling,
//! symbolic analysis, the CPU baselines, and the simulators.

mod common;

use reap::fpga::spgemm_sim::{simulate_spgemm, Style};
use reap::fpga::cholesky_sim::simulate_cholesky;
use reap::fpga::FpgaConfig;
use reap::kernels::{cholesky, spgemm};
use reap::rir::{encode, layout, schedule};
use reap::sparse::gen;
use reap::symbolic::{symbolic_factor, CholeskySymbolic};
use reap::util::timer::measure_budgeted;

fn report(name: &str, per_call_s: f64, unit_count: f64, unit: &str) {
    println!(
        "{name:<34} {:>10.3} ms/call  {:>9.1} M{unit}/s",
        per_call_s * 1e3,
        unit_count / per_call_s / 1e6
    );
}

fn main() {
    let cfg = common::bench_config();
    let budget = cfg.budget_s;
    let n = cfg.max_rows;
    let a = gen::banded_fem(n, n * 16, cfg.seed);
    let nnz = a.nnz() as f64;
    println!("micro: n={n} nnz={nnz} budget={budget}s\n");

    let m = measure_budgeted(budget, 3, || encode::csr_to_bundles(&a, 32));
    report("rir_encode (csr->bundles)", m.min_s, nnz, "elem");

    // zero-allocation arena encode: buffers retained across calls
    let mut arena = encode::BundleStream::new();
    let m = measure_budgeted(budget, 3, || {
        arena.encode_csr(&a, 32);
        arena.n_bundles()
    });
    report("rir_encode (SoA arena, reused)", m.min_s, nnz, "elem");

    let bundles = encode::csr_to_bundles(&a, 32);
    let m = measure_budgeted(budget, 3, || layout::serialize(&bundles));
    report("rir_serialize (bundles->words)", m.min_s, nnz, "elem");

    let words = layout::serialize(&bundles);
    let m = measure_budgeted(budget, 3, || layout::deserialize(&words).unwrap());
    report("rir_deserialize", m.min_s, nnz, "elem");

    let m = measure_budgeted(budget, 3, || schedule::schedule_spgemm(&a, &a, 32, 32));
    report("spgemm_schedule (CPU pass)", m.min_s, nnz, "elem");

    let m = measure_budgeted(budget, 3, || spgemm(&a, &a));
    let flops = reap::kernels::spgemm::spgemm_flops(&a, &a) as f64;
    report("spgemm_cpu_baseline", m.min_s, flops, "flop");

    let sched = schedule::schedule_spgemm(&a, &a, 32, 32);
    let fc = FpgaConfig::reap32_spgemm();
    let m = measure_budgeted(budget, 3, || simulate_spgemm(&a, &a, &sched, &fc, Style::HandCoded));
    report("spgemm_sim (cycle model)", m.min_s, flops, "flop");

    // Cholesky side on an SPD clone
    let spd = gen::spd(gen::Family::BandedFem, n.min(1200), n.min(1200) * 8, cfg.seed);
    let lower = spd.lower_triangle();
    let lnnz = lower.nnz() as f64;

    let m = measure_budgeted(budget, 3, || symbolic_factor(&lower));
    report("cholesky_symbolic (etree+pattern)", m.min_s, lnnz, "elem");

    let pattern = symbolic_factor(&lower);
    let m = measure_budgeted(budget, 3, || {
        cholesky::cholesky_numeric(&lower, &pattern).unwrap()
    });
    let cflops = cholesky::cholesky_flops(&pattern) as f64;
    report("cholesky_cpu_baseline (numeric)", m.min_s, cflops, "flop");

    let sym = CholeskySymbolic::analyze(&lower, 32);
    let cc = FpgaConfig::reap32_cholesky();
    let m = measure_budgeted(budget, 3, || simulate_cholesky(&sym, &cc, Style::HandCoded));
    report("cholesky_sim (cycle model)", m.min_s, cflops, "flop");

    // ---- combined CPU pass (schedule + RIR encode) thread scaling ----
    // The acceptance target of the parallel-preprocessing PR: ≥2x at 4
    // threads over the single-threaded pass on a large uniform-random
    // matrix, with zero per-bundle allocations in the encode loop.
    let big_n = n.max(1500);
    let big = gen::random_uniform(big_n, big_n, big_n * 16, cfg.seed);
    let bnnz = big.nnz() as f64;
    println!(
        "\ncombined CPU pass (schedule + encode), uniform-random n={big_n} nnz={}:",
        big.nnz()
    );
    let mut arena = encode::BundleStream::new();
    let mut serial_s = 0.0;
    for threads in [1usize, 2, 4, 8] {
        let m = measure_budgeted(budget, 3, || {
            let s = schedule::schedule_spgemm_with_threads(&big, &big, 32, 32, threads);
            let st = encode::BundleStream::from_csr_with_threads(&big, 32, threads);
            (s.n_waves(), st.n_bundles())
        });
        if threads == 1 {
            serial_s = m.min_s;
        }
        println!(
            "  threads={threads}: {:>8.3} ms/pass  {:>8.1} Melem/s  speedup {:.2}x",
            m.min_s * 1e3,
            bnnz / m.min_s / 1e6,
            serial_s / m.min_s
        );
    }
    // allocation-free steady state: the reused arena encodes with no
    // per-bundle (or per-call, after warmup) heap traffic
    let m = measure_budgeted(budget, 3, || {
        arena.encode_csr(&big, 32);
        arena.n_bundles()
    });
    println!(
        "  encode-only (reused arena, 1 thread): {:.3} ms/pass  {:.1} Melem/s",
        m.min_s * 1e3,
        bnnz / m.min_s / 1e6
    );
}
