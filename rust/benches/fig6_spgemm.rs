//! Regenerates Fig 6: SpGEMM speedups of REAP-32/64/128 and CPU-2/CPU-16
//! over the MKL-class single-core baseline, across the Table-I suite.

mod common;

fn main() {
    let cfg = common::bench_config();
    println!(
        "fig6: suite max_rows={} budget={}s seed={:#x}",
        cfg.max_rows, cfg.budget_s, cfg.seed
    );
    let (rows, table) = reap::harness::fig6::run(&cfg);
    print!("{}", table.render());
    common::verdict(
        "REAP-32 geomean ~3.2x and beats CPU-1 on all matrices",
        reap::harness::fig6::headline_holds(&rows),
    );
    cfg.dump_csv("fig6", &table).expect("csv");
    println!("perf records: results/BENCH_spgemm.json");
}
