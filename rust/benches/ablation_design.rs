//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * **bundle size** (the paper fixes 32: CAM frequency vs header
//!   amortization) — sweep 4..128 and report simulated time + stream size;
//! * **wave-shared B streaming** (the scheduler dedupes B rows within a
//!   wave) — compare against a no-dedup schedule;
//! * **CPU/FPGA overlap** (the paper overlaps after the first round) —
//!   overlapped vs serial totals;
//! * **cross-column pipelining** in the Cholesky model — with vs without
//!   the div/sqrt drain overlap (HandCoded vs HLS style isolates it).

mod common;

use reap::coordinator::{overlap, ReapSpgemm};
use reap::fpga::spgemm_sim::{simulate_spgemm, Style};
use reap::fpga::FpgaConfig;
use reap::rir::schedule::schedule_spgemm;
use reap::sparse::gen::{self, Family};
use reap::util::table::{f2, Table};

fn main() {
    let cfg = common::bench_config();
    let a = gen::generate(Family::BandedFem, cfg.max_rows, cfg.max_rows * 16, cfg.seed);
    println!("ablation workload: {}x{} nnz {}\n", a.nrows, a.ncols, a.nnz());

    // ---- bundle size sweep ----
    let mut t = Table::new(
        "ablation: RIR bundle size (paper design point: 32)",
        &["bundle", "sim ms", "input MB", "waves"],
    );
    for bundle in [4usize, 8, 16, 32, 64, 128] {
        let mut fc = FpgaConfig::reap32_spgemm();
        fc.bundle_size = bundle;
        let s = schedule_spgemm(&a, &a, fc.pipelines, bundle);
        let r = simulate_spgemm(&a, &a, &s, &fc, Style::HandCoded);
        t.row(vec![
            bundle.to_string(),
            f2(r.stats.seconds(&fc) * 1e3),
            f2(s.input_bytes() as f64 / 1e6),
            r.stats.waves.to_string(),
        ]);
    }
    print!("{}", t.render());
    cfg.dump_csv("ablation_bundle", &t).expect("csv");

    // ---- wave sharing: pipelines widen the shared B stream ----
    let mut t = Table::new(
        "ablation: wave-shared B streaming (wider waves dedupe B rows)",
        &["pipelines", "B-stream MB", "sim ms"],
    );
    for pipes in [1usize, 4, 16, 32, 64] {
        let mut fc = FpgaConfig::reap32_spgemm();
        fc.pipelines = pipes;
        let s = schedule_spgemm(&a, &a, pipes, fc.bundle_size);
        let r = simulate_spgemm(&a, &a, &s, &fc, Style::HandCoded);
        t.row(vec![
            pipes.to_string(),
            f2(s.b_words as f64 * 4.0 / 1e6),
            f2(r.stats.seconds(&fc) * 1e3),
        ]);
    }
    print!("{}", t.render());
    cfg.dump_csv("ablation_wave_sharing", &t).expect("csv");

    // ---- overlap model ----
    let rep = ReapSpgemm::new(FpgaConfig::reap32_spgemm()).run(&a, &a).unwrap();
    let serial = rep.cpu_preprocess_s + rep.fpga_s;
    let scalar = overlap::overlapped_total(rep.cpu_preprocess_s, rep.fpga_s, rep.fpga_sim.waves);
    println!(
        "ablation: CPU/FPGA overlap — serial {:.3} ms vs scalar model {:.3} ms \
         vs per-wave pipeline {:.3} ms ({:.1}% saved)",
        serial * 1e3,
        scalar * 1e3,
        rep.total_s * 1e3,
        (1.0 - rep.total_s / serial) * 100.0
    );

    // ---- dependency wall: sequential columns vs level-schedule bound ----
    {
        use reap::fpga::cholesky_sim::simulate_cholesky;
        use reap::symbolic::{CholeskySymbolic, LevelSchedule};
        // block-diagonal SPD: independent subsystems = the best case for
        // dependency-breaking (each diagonal block is a separate etree)
        let (blocks, bn) = (10usize, 60usize);
        let mut coo = reap::sparse::Coo::new(blocks * bn, blocks * bn);
        for b in 0..blocks {
            let sub = gen::spd(Family::BandedFem, bn, bn * 6, cfg.seed + b as u64);
            let sub_csr = sub.to_csr();
            for i in 0..bn {
                for (c, v) in sub_csr.row_cols(i).iter().zip(sub_csr.row_vals(i)) {
                    coo.push(b * bn + i, b * bn + *c as usize, *v);
                }
            }
        }
        let lower = coo.to_csr().to_csc().lower_triangle();
        let sym = CholeskySymbolic::analyze(&lower, 32);
        let cc = reap::fpga::FpgaConfig::reap32_cholesky();
        let r = simulate_cholesky(&sym, &cc, Style::HandCoded);
        let ls = LevelSchedule::build(&sym.pattern);
        let bound = ls.level_bound_cycles(&r.column_cycles);
        println!(
            "ablation: Cholesky dependency wall — sequential {} cycles vs level-scheduled bound {} cycles ({:.2}x headroom; critical path {} levels, mean width {:.1})",
            r.stats.cycles,
            bound,
            r.stats.cycles as f64 / bound.max(1) as f64,
            ls.critical_path(),
            ls.mean_width(),
        );
    }

    // ---- pipelined vs serialized datapath stages ----
    let s = schedule_spgemm(&a, &a, 32, 32);
    let fc = FpgaConfig::reap32_spgemm();
    let hand = simulate_spgemm(&a, &a, &s, &fc, Style::HandCoded);
    let hls = simulate_spgemm(&a, &a, &s, &fc, Style::HlsPreprocessed);
    println!(
        "ablation: stage pipelining — pipelined {} cycles vs serialized {} cycles ({:.2}x)",
        hand.stats.cycles,
        hls.stats.cycles,
        hls.stats.cycles as f64 / hand.stats.cycles as f64
    );
}
