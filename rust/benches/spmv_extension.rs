//! Extension bench (not a paper figure): the SpMV kernel through the REAP
//! flow over the Table-I SpGEMM suite — the paper's §II future-work claim
//! ("many other sparse linear algebra kernels can be accelerated with the
//! same approach") made measurable.
//!
//! SpMV has no data reuse, so the one-shot case is preprocessing-bound
//! (the CPU pass costs as much as the whole multiply). The honest win is
//! the *iterative* case every solver lives in: RIR-encode once, stream
//! every iteration — reported as the amortized column (100 iterations).

mod common;

use reap::coordinator::ReapSpmv;
use reap::fpga::FpgaConfig;
use reap::harness::suite::spgemm_suite;
use reap::kernels::spmv::{spmv, spmv_flops};
use reap::util::stats::geomean;
use reap::util::table::{f2, speedup, Table};
use reap::util::timer::measure_budgeted;

fn main() {
    let cfg = common::bench_config();
    let mut table = Table::new(
        "extension — SpMV (y = A x) speedup vs CPU-1, REAP-32/64",
        &["id", "matrix", "one-shot-32", "amortized-32", "amortized-64", "sim GFLOP/s (32)"],
    );
    let mut s32 = Vec::new();
    let mut s64 = Vec::new();
    for spec in spgemm_suite() {
        let a = spec.instantiate(cfg.max_rows, cfg.seed);
        let x: Vec<f32> = (0..a.ncols).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let cpu = measure_budgeted(cfg.budget_s, 3, || spmv(&a, &x)).min_s;
        let r32 = ReapSpmv::new(FpgaConfig::reap32_spgemm()).run(&a, &x).unwrap();
        let r64 = ReapSpmv::new(FpgaConfig::reap64_spgemm()).run(&a, &x).unwrap();
        // one-shot: preprocessing + one streamed multiply
        let one32 = cpu / r32.total_s;
        // amortized over ITERS solver iterations: encode once, stream many
        const ITERS: f64 = 100.0;
        let am32 = (ITERS * cpu) / (r32.cpu_preprocess_s + ITERS * r32.fpga_s);
        let am64 = (ITERS * cpu) / (r64.cpu_preprocess_s + ITERS * r64.fpga_s);
        s32.push(am32);
        s64.push(am64);
        let gf = spmv_flops(&a) as f64 / r32.fpga_s / 1e9;
        table.row(vec![
            spec.spgemm_id.unwrap().into(),
            spec.name.into(),
            speedup(one32),
            speedup(am32),
            speedup(am64),
            f2(gf),
        ]);
    }
    table.row(vec![
        "GM".into(),
        "geomean".into(),
        "".into(),
        speedup(geomean(&s32).unwrap_or(0.0)),
        speedup(geomean(&s64).unwrap_or(0.0)),
        "".into(),
    ]);
    print!("{}", table.render());
    cfg.dump_csv("spmv_extension", &table).expect("csv");
}
