//! Packaging the symbolic analysis for the FPGA (paper Fig 4(c)/(d)).
//!
//! The CPU ships two things per column k of L:
//!
//! * the **RA stream** — column k of A in RIR form (data bundles), and
//! * the **RL stream** — metadata-only bundles with one `(r, start, end)`
//!   triple per nonzero row of column k of L, telling the FPGA where row r
//!   of L lives in its own memory ("As L resides in FPGA's memory, the CPU
//!   also provides information about where a particular row R1 of L starts
//!   and ends").
//!
//! Both are written directly in the flat Fig-3(d) word layout (the
//! bundle-object path exists for tests/decoding; the streaming writers are
//! what the measured CPU pass runs — EXPERIMENTS.md §Perf iteration 3).
//! L is laid out **row-major** in FPGA memory because the dot-product PEs
//! consume rows of L (`L(r, 0:k-1) · L(k, 0:k-1)`).

use crate::rir::bundle::{Bundle, BundleFlags, RlTriple};
use crate::rir::layout::{self, WORD_BYTES};
use crate::sparse::{Csc, Idx};

use super::pattern::{symbolic_factor, LPattern};

/// Row-major storage map of L in FPGA memory: element offsets of each row.
#[derive(Clone, Debug, PartialEq)]
pub struct LStorageMap {
    /// `row_ptr[r]..row_ptr[r+1]` = element offsets of row r of L.
    pub row_ptr: Vec<usize>,
    /// Column indices within each row (ascending; ends with the diagonal).
    pub cols: Vec<Idx>,
}

impl LStorageMap {
    /// Columns of row r.
    pub fn row_cols(&self, r: usize) -> &[Idx] {
        &self.cols[self.row_ptr[r]..self.row_ptr[r + 1]]
    }

    /// Element count of row r.
    pub fn row_len(&self, r: usize) -> usize {
        self.row_ptr[r + 1] - self.row_ptr[r]
    }

    /// Total stored elements (= nnz(L)).
    pub fn len(&self) -> usize {
        self.cols.len()
    }

    /// True when the map holds no rows.
    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }
}

/// Everything the CPU's symbolic pass produces for one factorization.
#[derive(Clone, Debug)]
pub struct CholeskySymbolic {
    /// Column-wise pattern of L (diagonal-first per column).
    pub pattern: LPattern,
    /// Row-major storage map of L in FPGA memory.
    pub storage: LStorageMap,
    /// RA data stream (flat Fig-3(d) words) and words-per-column.
    pub ra_words: Vec<u32>,
    pub ra_col_words: Vec<u32>,
    /// RL metadata stream and words-per-column.
    pub rl_words: Vec<u32>,
    pub rl_col_words: Vec<u32>,
    /// Measured seconds of the global analysis phase (etree + pattern +
    /// storage map) — produces the schedule, so it cannot overlap the
    /// FPGA's numeric phase. The pattern pass runs on the work-stealing
    /// preprocessing pool, so this wall-clock figure (and everything
    /// downstream: `cpu_symbolic_s`, fig10/fig11 totals) reflects the
    /// parallel symbolic prologue.
    pub analysis_s: f64,
    /// Measured seconds of the per-column RA/RL stream encoding — the part
    /// the coordinator pipelines against the FPGA's column processing
    /// (attributed per column ∝ stream words; see EXPERIMENTS.md §Perf).
    pub encode_s: f64,
}

impl CholeskySymbolic {
    /// Run the full CPU-side symbolic pass on the lower triangle of A.
    pub fn analyze(a_lower: &Csc, bundle_size: usize) -> Self {
        let t_analysis = std::time::Instant::now();
        let pattern = symbolic_factor(a_lower);
        let storage = row_storage_map(&pattern);
        let analysis_s = t_analysis.elapsed().as_secs_f64();
        let t_encode = std::time::Instant::now();
        let mut ra_words = Vec::with_capacity(2 * a_lower.nnz() + 2 * a_lower.ncols);
        let mut ra_col_words = Vec::new();
        layout::write_csc_stream(a_lower, bundle_size, &mut ra_words, &mut ra_col_words);
        let mut rl_words = Vec::with_capacity(3 * pattern.nnz() + 2 * pattern.n);
        let mut rl_col_words = Vec::new();
        layout::write_rl_stream(&pattern, &storage, bundle_size, &mut rl_words, &mut rl_col_words);
        let encode_s = t_encode.elapsed().as_secs_f64();
        CholeskySymbolic {
            pattern,
            storage,
            ra_words,
            ra_col_words,
            rl_words,
            rl_col_words,
            analysis_s,
            encode_s,
        }
    }

    /// The per-column CPU encode cost: the measured encode wall time
    /// attributed to each column proportional to its RA+RL stream words.
    pub fn encode_col_s(&self) -> Vec<f64> {
        let total_words: u64 = self
            .ra_col_words
            .iter()
            .zip(&self.rl_col_words)
            .map(|(&a, &l)| a as u64 + l as u64)
            .sum();
        if total_words == 0 {
            return vec![0.0; self.pattern.n];
        }
        self.ra_col_words
            .iter()
            .zip(&self.rl_col_words)
            .map(|(&a, &l)| self.encode_s * (a as u64 + l as u64) as f64 / total_words as f64)
            .collect()
    }

    /// Bytes of metadata+data streamed from CPU to FPGA (the coarse-grained
    /// communication the paper contrasts with fine-grained PCIe chatter).
    pub fn stream_bytes(&self) -> usize {
        (self.ra_words.len() + self.rl_words.len()) * WORD_BYTES
    }

    /// Bytes of the RA chain of column k.
    pub fn ra_col_bytes(&self, k: usize) -> u64 {
        self.ra_col_words[k] as u64 * WORD_BYTES as u64
    }

    /// Bytes of the RL chain of column k.
    pub fn rl_col_bytes(&self, k: usize) -> u64 {
        self.rl_col_words[k] as u64 * WORD_BYTES as u64
    }
}

/// Build the row-major storage map from the column-wise pattern.
///
/// Row r of L holds every column j ≤ r with L(r,j) != 0; ascending column
/// order, so the diagonal is last — the dot-product PE streams the row and
/// the div/sqrt PE consumes the diagonal at the end.
pub fn row_storage_map(pattern: &LPattern) -> LStorageMap {
    let n = pattern.n;
    let mut row_ptr = vec![0usize; n + 1];
    for j in 0..n {
        for &r in pattern.col_rows(j) {
            row_ptr[r as usize + 1] += 1;
        }
    }
    for r in 0..n {
        row_ptr[r + 1] += row_ptr[r];
    }
    let mut cols = vec![0 as Idx; row_ptr[n]];
    let mut next = row_ptr.clone();
    // columns ascend ⇒ each row receives its columns in ascending order
    for j in 0..n {
        for &r in pattern.col_rows(j) {
            cols[next[r as usize]] = j as Idx;
            next[r as usize] += 1;
        }
    }
    LStorageMap { row_ptr, cols }
}

/// Reference (allocating) builder for the per-column RL metadata bundles —
/// kept as the specification the streaming writer is tested against.
pub fn rl_metadata_bundles(
    pattern: &LPattern,
    storage: &LStorageMap,
    bundle_size: usize,
) -> Vec<Bundle> {
    assert!(bundle_size > 0);
    let mut out = Vec::new();
    for k in 0..pattern.n {
        let rows = pattern.col_rows(k);
        let triples: Vec<RlTriple> = rows
            .iter()
            .map(|&r| RlTriple {
                row: r,
                start: storage.row_ptr[r as usize] as u32,
                end: storage.row_ptr[r as usize + 1] as u32,
            })
            .collect();
        let nchunks = triples.len().div_ceil(bundle_size).max(1);
        for (ci, chunk) in triples.chunks(bundle_size.max(1)).enumerate() {
            let mut flags = BundleFlags::default();
            if ci + 1 == nchunks {
                flags = flags.with(BundleFlags::END_OF_ROW);
            }
            out.push(Bundle::schedule(k as Idx, chunk.to_vec(), flags));
        }
    }
    if let Some(last) = out.last_mut() {
        last.flags = last.flags.with(BundleFlags::END_OF_STREAM);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::encode::csc_to_bundles;
    use crate::sparse::{gen, ops};

    fn spd(seed: u64) -> Csc {
        ops::make_spd(&gen::banded_fem(24, 150, seed))
    }

    #[test]
    fn storage_map_is_transpose_of_pattern() {
        let lower = spd(1).lower_triangle();
        let sym = CholeskySymbolic::analyze(&lower, 32);
        assert_eq!(sym.storage.len(), sym.pattern.nnz());
        // every column entry appears in exactly one row list
        for j in 0..sym.pattern.n {
            for &r in sym.pattern.col_rows(j) {
                assert!(
                    sym.storage.row_cols(r as usize).contains(&(j as Idx)),
                    "entry ({r},{j}) missing from row map"
                );
            }
        }
        // rows ascend and end with the diagonal
        for r in 0..sym.pattern.n {
            let cols = sym.storage.row_cols(r);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
            assert_eq!(*cols.last().unwrap() as usize, r);
        }
    }

    #[test]
    fn ra_stream_matches_bundle_reference() {
        let lower = spd(2).lower_triangle();
        let sym = CholeskySymbolic::analyze(&lower, 8);
        let expect = layout::serialize(&csc_to_bundles(&lower, 8));
        assert_eq!(sym.ra_words, expect);
        assert_eq!(
            sym.ra_col_words.iter().map(|&w| w as usize).sum::<usize>(),
            sym.ra_words.len()
        );
    }

    #[test]
    fn rl_stream_matches_bundle_reference() {
        let lower = spd(3).lower_triangle();
        let sym = CholeskySymbolic::analyze(&lower, 8);
        let reference = rl_metadata_bundles(&sym.pattern, &sym.storage, 8);
        let expect = layout::serialize(&reference);
        assert_eq!(sym.rl_words, expect);
        // triples point at row extents
        let decoded = layout::deserialize(&sym.rl_words).unwrap();
        for b in &decoded {
            assert!(b.flags.metadata_only());
            for t in b.triples() {
                let r = t.row as usize;
                assert_eq!(t.start as usize, sym.storage.row_ptr[r]);
                assert_eq!(t.end as usize, sym.storage.row_ptr[r + 1]);
            }
        }
    }

    #[test]
    fn rl_bundles_split_like_data_bundles() {
        // dense-first-column arrow matrix => column 0 of L has n rows
        let n = 40;
        let mut coo = crate::sparse::Coo::new(n, n);
        for i in 0..n {
            coo.push(i, i, 4.0);
            if i > 0 {
                coo.push(i, 0, 0.5);
                coo.push(0, i, 0.5);
            }
        }
        let lower = coo.to_csr().to_csc().lower_triangle();
        let sym = CholeskySymbolic::analyze(&lower, 8);
        let decoded = layout::deserialize(&sym.rl_words).unwrap();
        let col0: Vec<_> = decoded.iter().filter(|b| b.shared == 0).collect();
        assert_eq!(col0.len(), 5); // ceil(40/8)
        assert!(col0[..4].iter().all(|b| !b.flags.end_of_row()));
        assert!(col0[4].flags.end_of_row());
    }

    #[test]
    fn stream_bytes_positive_and_consistent() {
        let lower = spd(4).lower_triangle();
        let sym = CholeskySymbolic::analyze(&lower, 32);
        let total = sym.stream_bytes();
        assert!(total > 0);
        let per_col: usize = (0..sym.pattern.n)
            .map(|k| (sym.ra_col_bytes(k) + sym.rl_col_bytes(k)) as usize)
            .sum();
        assert_eq!(total, per_col);
    }
}
