//! Elimination-tree level scheduling — quantifying the dependency wall.
//!
//! The paper observes that Cholesky's column dependencies cap REAP's
//! scaling ("as we increase the number of pipelines, the idle cycles
//! increase almost linearly … adding more resources is not going to help")
//! and points at dependency-breaking research as orthogonal work. This
//! module computes the elimination-tree **level sets** — columns whose
//! subtree dependencies are complete may factor concurrently — giving
//! (a) the critical-path length (the serial floor any schedule faces) and
//! (b) the width profile (how much column-level parallelism a
//! level-scheduled design could actually harvest). The ablation bench
//! compares the paper's sequential-column model against this bound.

use super::etree::depths;
use super::pattern::LPattern;
use crate::util::{grains, preprocess_threads};

/// Level schedule: columns grouped by elimination-tree height (leaves
/// first — a column's level is 1 + max level of its children; columns in
/// the same level are mutually independent).
#[derive(Clone, Debug)]
pub struct LevelSchedule {
    /// `levels[l]` = columns factorable in step `l` (ascending levels).
    pub levels: Vec<Vec<u32>>,
}

impl LevelSchedule {
    /// Build from the symbolic pattern. The level-bucket fill runs on the
    /// work-stealing preprocessing pool; the result is bit-identical to the
    /// serial construction for every thread count (ARCHITECTURE.md §10).
    pub fn build(pattern: &LPattern) -> Self {
        Self::build_with_threads(pattern, preprocess_threads())
    }

    /// [`LevelSchedule::build`] with an explicit worker count (1 = serial).
    pub fn build_with_threads(pattern: &LPattern, nthreads: usize) -> Self {
        let grain = grains::default_grain(pattern.n, nthreads);
        Self::build_with_grain(pattern, nthreads, grain)
    }

    /// [`LevelSchedule::build`] with explicit worker count and grain size —
    /// exposed so the property suite can pin grain-size invariance.
    pub fn build_with_grain(pattern: &LPattern, nthreads: usize, grain: usize) -> Self {
        let n = pattern.n;
        // height above the leaves = depth measured from each subtree's
        // deepest leaf; compute as max-over-children + 1 via reverse pass.
        // Children have smaller indices than parents in an etree, so this
        // pass is inherently sequential — and O(n), too cheap to matter.
        let mut height = vec![0u32; n];
        for j in 0..n {
            if let Some(p) = pattern.parent[j] {
                let h = height[j] + 1;
                if height[p] < h {
                    height[p] = h;
                }
            }
        }
        let max_h = height.iter().copied().max().unwrap_or(0) as usize;
        let nthreads = nthreads.clamp(1, n.max(1));
        if nthreads <= 1 || n < 2 * nthreads {
            let mut levels = vec![Vec::new(); max_h + 1];
            for j in 0..n {
                levels[height[j] as usize].push(j as u32);
            }
            return LevelSchedule { levels };
        }
        // Parallel bucket fill over column grains: each grain buckets its
        // own ascending column range locally; concatenating the local
        // buckets in grain order preserves ascending column order within
        // every level, so the result matches the serial fill exactly.
        let height_ref = &height;
        let grain_buckets: Vec<Vec<Vec<u32>>> =
            grains::run_grains(n, grain, nthreads, |_g, j_lo, j_hi| {
                let mut local = vec![Vec::new(); max_h + 1];
                for j in j_lo..j_hi {
                    local[height_ref[j] as usize].push(j as u32);
                }
                local
            });
        let mut levels = vec![Vec::new(); max_h + 1];
        for local in grain_buckets {
            for (l, cols) in local.into_iter().enumerate() {
                levels[l].extend(cols);
            }
        }
        LevelSchedule { levels }
    }

    /// Critical-path length (number of serial steps).
    pub fn critical_path(&self) -> usize {
        self.levels.len()
    }

    /// Mean level width (average exploitable column parallelism).
    pub fn mean_width(&self) -> f64 {
        if self.levels.is_empty() {
            return 0.0;
        }
        let total: usize = self.levels.iter().map(|l| l.len()).sum();
        total as f64 / self.levels.len() as f64
    }

    /// Maximum level width.
    pub fn max_width(&self) -> usize {
        self.levels.iter().map(|l| l.len()).max().unwrap_or(0)
    }

    /// Ideal level-scheduled cycle bound: per level, the widest column's
    /// sequential cost; levels execute serially. `col_cycles[j]` is the
    /// per-column cost from the simulator's column log.
    pub fn level_bound_cycles(&self, col_cycles: &[u64]) -> u64 {
        self.levels
            .iter()
            .map(|level| level.iter().map(|&j| col_cycles[j as usize]).max().unwrap_or(0))
            .sum()
    }
}

/// Consistency check: no column may share a level with its etree parent.
pub fn validate(schedule: &LevelSchedule, pattern: &LPattern) -> bool {
    let mut level_of = vec![0usize; pattern.n];
    for (l, cols) in schedule.levels.iter().enumerate() {
        for &j in cols {
            level_of[j as usize] = l;
        }
    }
    (0..pattern.n).all(|j| match pattern.parent[j] {
        Some(p) => level_of[j] < level_of[p],
        None => true,
    })
}

/// Depth-based alternative view (distance from the root), exposed for
/// diagnostics parity with [`depths`].
pub fn depth_histogram(pattern: &LPattern) -> Vec<usize> {
    let d = depths(&pattern.parent);
    let max = d.iter().copied().max().unwrap_or(0);
    let mut hist = vec![0usize; max + 1];
    for &x in &d {
        hist[x] += 1;
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, ops};
    use crate::symbolic::symbolic_factor;

    fn pattern(seed: u64) -> LPattern {
        let spd = ops::make_spd(&gen::banded_fem(60, 400, seed));
        symbolic_factor(&spd.lower_triangle())
    }

    #[test]
    fn levels_partition_columns_and_respect_dependencies() {
        let lp = pattern(1);
        let ls = LevelSchedule::build(&lp);
        let total: usize = ls.levels.iter().map(|l| l.len()).sum();
        assert_eq!(total, lp.n);
        assert!(validate(&ls, &lp));
    }

    #[test]
    fn parallel_levels_bit_identical_to_serial() {
        let spd = ops::make_spd(&gen::power_law(90, 900, 5));
        let lp = symbolic_factor(&spd.lower_triangle());
        let base = LevelSchedule::build_with_threads(&lp, 1);
        for t in [2usize, 4, 8] {
            assert_eq!(LevelSchedule::build_with_threads(&lp, t).levels, base.levels, "t={t}");
            for grain in [1usize, 4, 1 << 20] {
                assert_eq!(
                    LevelSchedule::build_with_grain(&lp, t, grain).levels,
                    base.levels,
                    "t={t} grain={grain}"
                );
            }
        }
    }

    #[test]
    fn tridiagonal_is_fully_serial() {
        let mut coo = crate::sparse::Coo::new(8, 8);
        for i in 0..8 {
            coo.push(i, i, 4.0);
            if i > 0 {
                coo.push(i, i - 1, 1.0);
                coo.push(i - 1, i, 1.0);
            }
        }
        let lp = symbolic_factor(&coo.to_csr().to_csc().lower_triangle());
        let ls = LevelSchedule::build(&lp);
        assert_eq!(ls.critical_path(), 8); // a path: zero parallelism
        assert_eq!(ls.max_width(), 1);
    }

    #[test]
    fn diagonal_is_fully_parallel() {
        let mut coo = crate::sparse::Coo::new(10, 10);
        for i in 0..10 {
            coo.push(i, i, 2.0);
        }
        let lp = symbolic_factor(&coo.to_csr().to_csc().lower_triangle());
        let ls = LevelSchedule::build(&lp);
        assert_eq!(ls.critical_path(), 1);
        assert_eq!(ls.max_width(), 10);
    }

    #[test]
    fn level_bound_never_exceeds_serial_sum() {
        let lp = pattern(2);
        let ls = LevelSchedule::build(&lp);
        let col_cycles: Vec<u64> = (0..lp.n as u64).map(|j| 10 + j % 7).collect();
        let serial: u64 = col_cycles.iter().sum();
        let bound = ls.level_bound_cycles(&col_cycles);
        assert!(bound <= serial);
        assert!(bound > 0);
    }

    #[test]
    fn depth_histogram_counts_all_columns() {
        let lp = pattern(3);
        let hist = depth_histogram(&lp);
        assert_eq!(hist.iter().sum::<usize>(), lp.n);
    }
}
