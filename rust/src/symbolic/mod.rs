//! Cholesky symbolic analysis — the CPU-side pass of REAP's Cholesky design
//! (paper §III-B, Fig 4).
//!
//! "An interesting aspect of Cholesky factorization is that it is possible
//! to identify the non-zero elements in a column of L from a pure symbolic
//! analysis … CPU performs the symbolic analysis based on the construction
//! of the elimination tree."
//!
//! * [`etree`] — elimination tree (Liu's ancestor-compression algorithm).
//! * [`pattern`] — per-row reach (`ereach`) and the full pattern of L.
//! * [`analysis`] — packaging: per-column RL metadata bundles (Fig 4(c))
//!   plus the L storage map the FPGA uses.
//!
//! The etree stays serial (near-linear, cheap); the expensive row-pattern
//! and level-set construction run on the deterministic work-stealing pool
//! ([`crate::util::grains`]), so the symbolic prologue scales with CPU
//! threads while producing bit-identical output at any worker count.

pub mod analysis;
pub mod etree;
pub mod levels;
pub mod pattern;

pub use analysis::{CholeskySymbolic, LStorageMap};
pub use levels::LevelSchedule;
pub use etree::{elimination_tree, elimination_tree_from_upper};
pub use pattern::{
    ereach, symbolic_factor, symbolic_factor_with_grain, symbolic_factor_with_threads, LPattern,
};
