//! Nonzero pattern of the Cholesky factor L (paper Fig 4(b)).
//!
//! `ereach(A, k, parent)` gives the pattern of **row k** of L — the columns
//! `j < k` with `L(k,j) != 0` — by walking the elimination tree from each
//! entry of column k of (lower) A toward the root, stopping at marked
//! nodes (Davis, *Direct Methods for Sparse Linear Systems*, §4).
//! [`symbolic_factor`] assembles the full column-wise pattern of L that the
//! CPU ships to the FPGA as metadata.
//!
//! The per-column reach computations are independent once the elimination
//! tree is fixed, so [`symbolic_factor`] keeps Liu's etree pass serial
//! (it is O(nnz·α) and cheap) and fans the `ereach` loop out over
//! deterministic work-stealing column grains ([`crate::util::grains`],
//! ARCHITECTURE.md §10): every grain's reach vectors are merged back in
//! column order, so the pattern is bit-identical for any thread count and
//! grain size.

use crate::sparse::{Csc, Idx};
use crate::util::{grains, preprocess_threads};

use super::etree::elimination_tree_from_upper;

/// Column-wise pattern of L (indices only; values come later).
#[derive(Clone, Debug, PartialEq)]
pub struct LPattern {
    pub n: usize,
    /// `col_ptr[j]..col_ptr[j+1]` indexes `rows` for column j. The first
    /// entry of every column is the diagonal `j`.
    pub col_ptr: Vec<usize>,
    pub rows: Vec<Idx>,
    /// Elimination-tree parent vector (kept for scheduling/diagnostics).
    pub parent: Vec<Option<usize>>,
}

impl LPattern {
    /// nnz(L) including the diagonal.
    pub fn nnz(&self) -> usize {
        self.rows.len()
    }

    /// Rows of column j (diagonal first, then ascending).
    pub fn col_rows(&self, j: usize) -> &[Idx] {
        &self.rows[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// Number of nonzeros in column j.
    pub fn col_nnz(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// Fill-in: nnz(L) minus nnz(lower triangle of A).
    pub fn fill_in(&self, a_lower: &Csc) -> usize {
        self.nnz().saturating_sub(a_lower.nnz())
    }
}

/// Pattern of row `k` of L, ascending — the columns `j < k` with
/// `L(k,j) != 0`.
///
/// `a_upper` is the **strictly upper** triangle of A in CSC (so column k
/// lists exactly the `j < k` with `A(j,k) = A(k,j) != 0`); build it once
/// with [`strict_upper_from_lower`]. `marked` is caller-provided n-sized
/// scratch stamped with `stamp`, so the per-row cost is O(|reach| log) —
/// never O(n).
pub fn ereach(
    a_upper: &Csc,
    k: usize,
    parent: &[Option<usize>],
    marked: &mut [u32],
    stamp: u32,
    out: &mut Vec<Idx>,
) {
    out.clear();
    marked[k] = stamp;
    for &j0 in a_upper.col_rows(k) {
        // climb the etree from j toward k, collecting unmarked nodes
        let mut j = j0 as usize;
        while marked[j] != stamp {
            marked[j] = stamp;
            out.push(j as Idx);
            match parent[j] {
                Some(p) if p < k => j = p,
                _ => break,
            }
        }
    }
    // individual tree paths ascend, but distinct paths interleave
    out.sort_unstable();
}

/// Full symbolic factorization: the column-wise pattern of L for the SPD
/// matrix whose **lower triangle** is `a_lower`.
///
/// Complexity O(nnz(L)) plus the etree cost — same approach as
/// CHOLMOD's simplicial symbolic phase (which the paper's CPU runs). The
/// row-reach loop runs on the work-stealing preprocessing pool
/// ([`preprocess_threads`] workers); output is identical to the serial
/// result bit for bit.
pub fn symbolic_factor(a_lower: &Csc) -> LPattern {
    symbolic_factor_with_threads(a_lower, preprocess_threads())
}

/// [`symbolic_factor`] with an explicit worker count (1 = serial).
pub fn symbolic_factor_with_threads(a_lower: &Csc, nthreads: usize) -> LPattern {
    let grain = grains::default_grain(a_lower.ncols, nthreads);
    symbolic_factor_with_grain(a_lower, nthreads, grain)
}

/// [`symbolic_factor`] with an explicit worker count and wave-range grain
/// size — exposed so the property suite can pin grain-size invariance.
pub fn symbolic_factor_with_grain(a_lower: &Csc, nthreads: usize, grain: usize) -> LPattern {
    let n = a_lower.ncols;
    // strictly-upper CSC = transpose of strictly-lower part; built once and
    // shared with the etree construction (profiling showed the transpose
    // and per-row reach vectors dominating symbolic time on low-density
    // inputs — EXPERIMENTS.md §Perf iteration 2).
    let a_upper = strict_upper_from_lower(a_lower);
    // Liu's etree pass is near-linear and stays serial; it fixes the tree
    // every parallel reach below walks.
    let parent = elimination_tree_from_upper(&a_upper);

    let mut reach_flat: Vec<Idx> = Vec::with_capacity(a_lower.nnz() * 2);
    let mut reach_ptr = vec![0usize; n + 1];
    let mut col_counts = vec![1usize; n]; // diagonal
    let nthreads = nthreads.clamp(1, n.max(1));
    if nthreads <= 1 || n < 2 * nthreads {
        // Serial: row reaches into one flat arena (no per-row Vec).
        let mut marked = vec![u32::MAX; n];
        let mut scratch: Vec<Idx> = Vec::new();
        for k in 0..n {
            ereach(&a_upper, k, &parent, &mut marked, k as u32, &mut scratch);
            for &j in &scratch {
                col_counts[j as usize] += 1;
            }
            reach_flat.extend_from_slice(&scratch);
            reach_ptr[k + 1] = reach_flat.len();
        }
    } else {
        // Work-stealing column grains. The stamp for column k is k itself —
        // globally unique — so a worker's `marked` scratch is reusable
        // across whichever (possibly stolen, out-of-order) columns it
        // processes. Grain results merge in column order: bit-identical to
        // the serial arena for every thread count and grain size.
        let a_upper_ref = &a_upper;
        let parent_ref = &parent;
        let grain_outs: Vec<(Vec<Idx>, Vec<usize>)> = grains::run_grains_with(
            n,
            grain,
            nthreads,
            || (vec![u32::MAX; n], Vec::<Idx>::new()),
            |(marked, scratch), _g, k_lo, k_hi| {
                let mut flat: Vec<Idx> = Vec::new();
                let mut lens: Vec<usize> = Vec::with_capacity(k_hi - k_lo);
                for k in k_lo..k_hi {
                    ereach(a_upper_ref, k, parent_ref, marked, k as u32, scratch);
                    flat.extend_from_slice(scratch);
                    lens.push(scratch.len());
                }
                (flat, lens)
            },
        );
        let mut k = 0usize;
        for (flat, lens) in grain_outs {
            for len in lens {
                reach_ptr[k + 1] = reach_ptr[k] + len;
                k += 1;
            }
            for &j in &flat {
                col_counts[j as usize] += 1;
            }
            reach_flat.extend_from_slice(&flat);
        }
        debug_assert_eq!(k, n);
    }

    let mut col_ptr = vec![0usize; n + 1];
    for j in 0..n {
        col_ptr[j + 1] = col_ptr[j] + col_counts[j];
    }
    let mut rows = vec![0 as Idx; col_ptr[n]];
    let mut next = col_ptr.clone();
    // diagonal first in every column
    for j in 0..n {
        rows[next[j]] = j as Idx;
        next[j] += 1;
    }
    // row k contributes entry (k, j) for each j in its reach; k ascends, so
    // each column's below-diagonal rows land ascending automatically.
    for k in 0..n {
        for &j in &reach_flat[reach_ptr[k]..reach_ptr[k + 1]] {
            let dst = &mut next[j as usize];
            rows[*dst] = k as Idx;
            *dst += 1;
        }
    }
    LPattern { n, col_ptr, rows, parent }
}

/// Transpose the strictly-lower part of `a_lower` into a strictly-upper CSC
/// (column k lists j < k with A(j,k) != 0).
pub fn strict_upper_from_lower(a_lower: &Csc) -> Csc {
    let n = a_lower.ncols;
    let mut col_ptr = vec![0usize; n + 1];
    for j in 0..n {
        for &r in a_lower.col_rows(j) {
            if (r as usize) > j {
                col_ptr[r as usize + 1] += 1;
            }
        }
    }
    for j in 0..n {
        col_ptr[j + 1] += col_ptr[j];
    }
    let mut rows = vec![0 as Idx; col_ptr[n]];
    let mut vals = vec![0f32; col_ptr[n]];
    let mut next = col_ptr.clone();
    for j in 0..n {
        for (&r, &v) in a_lower.col_rows(j).iter().zip(a_lower.col_vals(j)) {
            let r = r as usize;
            if r > j {
                rows[next[r]] = j as Idx;
                vals[next[r]] = v;
                next[r] += 1;
            }
        }
    }
    Csc { nrows: n, ncols: n, col_ptr, rows, vals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, ops, Dense};

    /// Dense symbolic factorization oracle: pattern of L via elimination.
    fn brute_pattern(a: &Dense) -> Vec<Vec<usize>> {
        let n = a.nrows;
        let mut pat = vec![vec![false; n]; n];
        for i in 0..n {
            for j in 0..=i {
                if a[(i, j)] != 0.0 {
                    pat[i][j] = true;
                }
            }
        }
        for j in 0..n {
            for i in (j + 1)..n {
                if pat[i][j] {
                    for k in (j + 1)..=i {
                        if pat[k][j] {
                            pat[i][k] = true;
                        }
                    }
                }
            }
        }
        (0..n)
            .map(|j| (j..n).filter(|&i| pat[i][j]).collect())
            .collect()
    }

    #[test]
    fn pattern_matches_dense_oracle() {
        for seed in 0..6u64 {
            let spd = ops::make_spd(&gen::random_uniform(18, 18, 50, seed));
            let lower = spd.lower_triangle();
            let lp = symbolic_factor(&lower);
            let brute = brute_pattern(&Dense::from_csr(&spd.to_csr()));
            for j in 0..lp.n {
                let got: Vec<usize> = lp.col_rows(j).iter().map(|&r| r as usize).collect();
                assert_eq!(got, brute[j], "seed {seed} column {j}");
            }
        }
    }

    #[test]
    fn diagonal_first_and_ascending() {
        let spd = ops::make_spd(&gen::banded_fem(30, 200, 1));
        let lp = symbolic_factor(&spd.lower_triangle());
        for j in 0..lp.n {
            let rows = lp.col_rows(j);
            assert_eq!(rows[0] as usize, j);
            assert!(rows.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn parallel_symbolic_bit_identical_to_serial() {
        for seed in 0..3u64 {
            let spd = ops::make_spd(&gen::power_law(80, 800, seed));
            let lower = spd.lower_triangle();
            let base = symbolic_factor_with_threads(&lower, 1);
            for t in [2usize, 4, 8] {
                assert_eq!(symbolic_factor_with_threads(&lower, t), base, "seed {seed} t={t}");
                for grain in [1usize, 4, 1 << 20] {
                    assert_eq!(
                        symbolic_factor_with_grain(&lower, t, grain),
                        base,
                        "seed {seed} t={t} grain={grain}"
                    );
                }
            }
        }
    }

    #[test]
    fn tridiagonal_has_no_fill() {
        let mut coo = crate::sparse::Coo::new(6, 6);
        for i in 0..6 {
            coo.push(i, i, 4.0);
            if i > 0 {
                coo.push(i, i - 1, 1.0);
                coo.push(i - 1, i, 1.0);
            }
        }
        let lower = coo.to_csr().to_csc().lower_triangle();
        let lp = symbolic_factor(&lower);
        assert_eq!(lp.fill_in(&lower), 0);
        assert_eq!(lp.nnz(), lower.nnz());
    }

    #[test]
    fn arrow_matrix_fills_last_column_only() {
        // arrowhead pointing down-right: dense last row/col + diagonal.
        // No fill-in when the dense row is last.
        let n = 8;
        let mut coo = crate::sparse::Coo::new(n, n);
        for i in 0..n {
            coo.push(i, i, 4.0);
            if i + 1 < n {
                coo.push(n - 1, i, 1.0);
                coo.push(i, n - 1, 1.0);
            }
        }
        let lower = coo.to_csr().to_csc().lower_triangle();
        let lp = symbolic_factor(&lower);
        assert_eq!(lp.fill_in(&lower), 0);
        // reversed arrow (dense FIRST row/col) fills everything below
        let mut coo2 = crate::sparse::Coo::new(n, n);
        for i in 0..n {
            coo2.push(i, i, 4.0);
            if i > 0 {
                coo2.push(i, 0, 1.0);
                coo2.push(0, i, 1.0);
            }
        }
        let lower2 = coo2.to_csr().to_csc().lower_triangle();
        let lp2 = symbolic_factor(&lower2);
        // L becomes fully dense lower triangular
        assert_eq!(lp2.nnz(), n * (n + 1) / 2);
    }
}
