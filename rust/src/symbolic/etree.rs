//! Elimination tree construction (George/Heath/Ng/Liu; paper refs \[14\],\[15\]).
//!
//! `parent[j]` is the parent of column `j` in the elimination tree of the
//! SPD matrix `A`: the smallest row index `i > j` such that `L(i,j) != 0`.
//! Implemented with Liu's ancestor path compression — O(nnz · α(n)).

use crate::sparse::Csc;

/// Parent vector of the elimination tree; `None` marks a root.
///
/// Input is the **lower triangle** (including diagonal) of A in CSC. Only
/// the pattern is consulted. The algorithm walks column k's *above-diagonal*
/// entries (A(i,k), i < k), which with lower-triangular storage live in the
/// transposed strict-upper view built first (O(nnz)).
pub fn elimination_tree(a_lower: &Csc) -> Vec<Option<usize>> {
    let a_upper = super::pattern::strict_upper_from_lower(a_lower);
    elimination_tree_from_upper(&a_upper)
}

/// As [`elimination_tree`] but taking the prebuilt strict-upper view —
/// callers that already hold it (the symbolic factorization) avoid a
/// second transpose pass.
pub fn elimination_tree_from_upper(a_upper: &Csc) -> Vec<Option<usize>> {
    let n = a_upper.ncols;
    let mut parent: Vec<Option<usize>> = vec![None; n];
    let mut ancestor: Vec<Option<usize>> = vec![None; n];
    for k in 0..n {
        for &r in a_upper.col_rows(k) {
            // walk from row index up to k, compressing ancestors
            let mut i = r as usize;
            while i < k {
                let next = ancestor[i];
                ancestor[i] = Some(k);
                match next {
                    None => {
                        parent[i] = Some(k);
                        break;
                    }
                    Some(a) => i = a,
                }
            }
        }
    }
    parent
}

/// Children lists from a parent vector (postorder/analysis helper).
pub fn children(parent: &[Option<usize>]) -> Vec<Vec<usize>> {
    let mut ch = vec![Vec::new(); parent.len()];
    for (j, p) in parent.iter().enumerate() {
        if let Some(p) = p {
            ch[*p].push(j);
        }
    }
    ch
}

/// Depth of each node (root depth 0), memoized along root paths; panics on
/// cycles (which would indicate a malformed tree).
pub fn depths(parent: &[Option<usize>]) -> Vec<usize> {
    let n = parent.len();
    let mut depth = vec![usize::MAX; n];
    let mut chain = Vec::new();
    for start in 0..n {
        let mut j = start;
        chain.clear();
        // climb until a memoized node or a root
        while depth[j] == usize::MAX {
            chain.push(j);
            assert!(chain.len() <= n, "cycle in elimination tree");
            match parent[j] {
                None => break,
                Some(p) => j = p,
            }
        }
        // depth of the node we stopped at (unvisited root => 0)
        let mut d = if depth[j] == usize::MAX { 0 } else { depth[j] };
        // unwind the chain: last pushed node is nearest the stop point
        for &node in chain.iter().rev() {
            if depth[node] == usize::MAX {
                if node == j {
                    depth[node] = 0; // the root itself
                } else {
                    d += 1;
                    depth[node] = d;
                }
            }
        }
    }
    depth
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, ops, Dense};

    /// Brute-force etree: parent[j] = min{i > j : L(i,j) != 0} from a dense
    /// symbolic factorization.
    fn brute_etree(a: &Dense) -> Vec<Option<usize>> {
        let n = a.nrows;
        // symbolic dense cholesky: pattern-only elimination
        let mut pat = vec![vec![false; n]; n];
        for i in 0..n {
            for j in 0..=i {
                if a[(i, j)] != 0.0 {
                    pat[i][j] = true;
                }
            }
        }
        for j in 0..n {
            for i in (j + 1)..n {
                if pat[i][j] {
                    // row i gets fill from column j at all k in (j, i]
                    for k in (j + 1)..=i {
                        if pat[k][j] {
                            pat[i][k] = true;
                        }
                    }
                }
            }
        }
        (0..n)
            .map(|j| ((j + 1)..n).find(|&i| pat[i][j]))
            .collect()
    }

    #[test]
    fn matches_brute_force_on_random_spd() {
        for seed in 0..6u64 {
            let base = gen::random_uniform(16, 16, 40, seed);
            let spd = ops::make_spd(&base);
            let lower = spd.lower_triangle();
            let fast = elimination_tree(&lower);
            let brute = brute_etree(&Dense::from_csr(&spd.to_csr()));
            assert_eq!(fast, brute, "seed {seed}");
        }
    }

    #[test]
    fn tridiagonal_is_a_path() {
        // tridiagonal SPD: parent[j] = j+1
        let mut coo = crate::sparse::Coo::new(5, 5);
        for i in 0..5 {
            coo.push(i, i, 4.0);
            if i > 0 {
                coo.push(i, i - 1, 1.0);
                coo.push(i - 1, i, 1.0);
            }
        }
        let lower = coo.to_csr().to_csc().lower_triangle();
        let parent = elimination_tree(&lower);
        assert_eq!(parent, vec![Some(1), Some(2), Some(3), Some(4), None]);
    }

    #[test]
    fn diagonal_matrix_is_forest_of_roots() {
        let mut coo = crate::sparse::Coo::new(4, 4);
        for i in 0..4 {
            coo.push(i, i, 1.0);
        }
        let lower = coo.to_csr().to_csc().lower_triangle();
        assert_eq!(elimination_tree(&lower), vec![None; 4]);
    }

    #[test]
    fn children_and_depths_consistent() {
        let parent = vec![Some(2), Some(2), Some(3), None];
        let ch = children(&parent);
        assert_eq!(ch[2], vec![0, 1]);
        assert_eq!(ch[3], vec![2]);
        let d = depths(&parent);
        assert_eq!(d, vec![2, 2, 1, 0]);
    }
}
