//! Serial row-by-row (Gustavson) SpGEMM — the measured MKL stand-in.
//!
//! For each row i of A, partial products over the referenced rows of B are
//! accumulated; the accumulator adapts to the expected row density:
//!
//! * **sparse accumulator (SPA)** — dense value + stamp arrays over the
//!   column space with a touched-list; O(flops) with no per-row clearing
//!   cost. Used when the column dimension fits comfortably in cache.
//! * **hash accumulator** — open-addressing table sized to the upper bound
//!   of the row's nnz; used for very wide B where a dense SPA would thrash.
//!
//! This hybrid is the standard high-performance CPU formulation (MKL,
//! Kokkos, IA-SpGEMM all use variants of it), which is what the paper's
//! CPU-1 baseline measures.

use crate::sparse::{Csr, Idx, Val};

/// Threshold on ncols(B) above which the hash accumulator is used.
/// 1 M f32 values + 1 M u32 stamps ≈ 8 MiB — roughly L2/L3 territory;
/// beyond that the SPA's random scatter misses dominate.
const SPA_MAX_COLS: usize = 1 << 20;

/// C = A × B.
pub fn spgemm(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.ncols, b.nrows, "inner dimensions disagree");
    if b.ncols <= SPA_MAX_COLS {
        spgemm_spa(a, b)
    } else {
        spgemm_hash(a, b)
    }
}

/// Row-by-row with a stamped dense accumulator.
pub(crate) fn spgemm_spa(a: &Csr, b: &Csr) -> Csr {
    let n = a.nrows;
    let mut row_ptr = vec![0usize; n + 1];
    let mut cols: Vec<Idx> = Vec::new();
    let mut vals: Vec<Val> = Vec::new();

    let mut acc: Vec<Val> = vec![0.0; b.ncols];
    let mut stamp: Vec<u32> = vec![u32::MAX; b.ncols];
    let mut touched: Vec<Idx> = Vec::new();

    for i in 0..n {
        let tick = i as u32;
        touched.clear();
        for (&ca, &va) in a.row_cols(i).iter().zip(a.row_vals(i)) {
            let r = ca as usize;
            for (&cb, &vb) in b.row_cols(r).iter().zip(b.row_vals(r)) {
                let j = cb as usize;
                // `cb < b.ncols` is a CSR structural invariant (enforced
                // by `Csr::validate`, maintained by every constructor) and
                // `acc`/`stamp` are sized to `b.ncols`, so these checked
                // accesses never fail; the crate-wide safe-code policy
                // rules out the unchecked variant, and the checks are in
                // the noise next to the accumulator's cache traffic.
                if stamp[j] != tick {
                    stamp[j] = tick;
                    acc[j] = va * vb;
                    touched.push(cb);
                } else {
                    acc[j] += va * vb;
                }
            }
        }
        touched.sort_unstable();
        cols.reserve(touched.len());
        vals.reserve(touched.len());
        for &c in &touched {
            cols.push(c);
            vals.push(acc[c as usize]);
        }
        row_ptr[i + 1] = cols.len();
    }
    Csr { nrows: n, ncols: b.ncols, row_ptr, cols, vals }
}

/// Row-by-row with an open-addressing hash accumulator.
pub(crate) fn spgemm_hash(a: &Csr, b: &Csr) -> Csr {
    let n = a.nrows;
    let mut row_ptr = vec![0usize; n + 1];
    let mut cols: Vec<Idx> = Vec::new();
    let mut vals: Vec<Val> = Vec::new();
    let mut table: HashAccumulator = HashAccumulator::new();

    for i in 0..n {
        // upper bound on the row's nnz(C): sum of referenced B-row lengths
        let bound: usize = a.row_cols(i).iter().map(|&c| b.row_nnz(c as usize)).sum();
        table.reset(bound);
        for (&ca, &va) in a.row_cols(i).iter().zip(a.row_vals(i)) {
            let r = ca as usize;
            for (&cb, &vb) in b.row_cols(r).iter().zip(b.row_vals(r)) {
                table.add(cb, va * vb);
            }
        }
        table.drain_sorted(&mut cols, &mut vals);
        row_ptr[i + 1] = cols.len();
    }
    Csr { nrows: n, ncols: b.ncols, row_ptr, cols, vals }
}

/// Open-addressing (linear probing) accumulator keyed by column index.
struct HashAccumulator {
    keys: Vec<Idx>,
    vals: Vec<Val>,
    mask: usize,
    used: Vec<u32>, // occupied slots, for sorted drain
}

const EMPTY: Idx = Idx::MAX;

impl HashAccumulator {
    fn new() -> Self {
        HashAccumulator { keys: Vec::new(), vals: Vec::new(), mask: 0, used: Vec::new() }
    }

    /// Size for at least `bound` distinct keys at ≤ 50% load.
    fn reset(&mut self, bound: usize) {
        let cap = (bound.max(4) * 2).next_power_of_two();
        if self.keys.len() < cap {
            self.keys.resize(cap, EMPTY);
            self.vals.resize(cap, 0.0);
        }
        for &slot in &self.used {
            self.keys[slot as usize] = EMPTY;
        }
        self.used.clear();
        self.mask = cap - 1;
    }

    #[inline]
    fn add(&mut self, key: Idx, v: Val) {
        // Fibonacci hashing spreads consecutive columns well
        let mut slot = (key as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) as usize
            >> (64 - self.mask.count_ones() as usize).min(63);
        slot &= self.mask;
        loop {
            let k = self.keys[slot];
            if k == key {
                self.vals[slot] += v;
                return;
            }
            if k == EMPTY {
                self.keys[slot] = key;
                self.vals[slot] = v;
                self.used.push(slot as u32);
                return;
            }
            slot = (slot + 1) & self.mask;
        }
    }

    /// Move contents (sorted by key) into the output arrays.
    fn drain_sorted(&mut self, cols: &mut Vec<Idx>, vals: &mut Vec<Val>) {
        self.used.sort_unstable_by_key(|&s| self.keys[s as usize]);
        cols.reserve(self.used.len());
        vals.reserve(self.used.len());
        for &slot in &self.used {
            cols.push(self.keys[slot as usize]);
            vals.push(self.vals[slot as usize]);
            self.keys[slot as usize] = EMPTY;
        }
        self.used.clear();
    }
}

/// Flop count of C = A×B (2 × matched multiplies — the number the paper's
/// GFLOPS figure normalizes; matches the "useful flops" convention).
pub fn spgemm_flops(a: &Csr, b: &Csr) -> usize {
    let mut mults = 0usize;
    for i in 0..a.nrows {
        for &c in a.row_cols(i) {
            mults += b.row_nnz(c as usize);
        }
    }
    2 * mults
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, Dense};

    fn check_against_dense(a: &Csr, b: &Csr, f: impl Fn(&Csr, &Csr) -> Csr) {
        let c = f(a, b);
        c.validate().unwrap();
        let expect = Dense::from_csr(a).matmul(&Dense::from_csr(b));
        let diff = Dense::from_csr(&c).max_abs_diff(&expect);
        assert!(diff < 1e-3, "max diff {diff}");
    }

    #[test]
    fn spa_matches_dense_random() {
        for seed in 0..5u64 {
            let a = gen::random_uniform(30, 25, 120, seed);
            let b = gen::random_uniform(25, 40, 150, seed + 100);
            check_against_dense(&a, &b, spgemm_spa);
        }
    }

    #[test]
    fn hash_matches_dense_random() {
        for seed in 0..5u64 {
            let a = gen::random_uniform(30, 25, 120, seed);
            let b = gen::random_uniform(25, 40, 150, seed + 100);
            check_against_dense(&a, &b, spgemm_hash);
        }
    }

    #[test]
    fn spa_and_hash_agree_exactly() {
        // identical FP-add ordering (both sorted per-row) -> bitwise equal
        let a = gen::power_law(60, 800, 1);
        let b = gen::power_law(60, 800, 2);
        let c1 = spgemm_spa(&a, &b);
        let c2 = spgemm_hash(&a, &b);
        assert_eq!(c1.row_ptr, c2.row_ptr);
        assert_eq!(c1.cols, c2.cols);
        // values may differ in add order inside a (col) cell? no: both add
        // in B-stream order per column. Require exact equality.
        assert_eq!(c1.vals, c2.vals);
    }

    #[test]
    fn squaring_matches_paper_protocol() {
        // the paper evaluates C = A^2
        let a = gen::banded_fem(40, 300, 3);
        check_against_dense(&a, &a, spgemm);
    }

    #[test]
    fn empty_and_identity_edges() {
        let z = Csr::new(4, 4);
        let c = spgemm(&z, &z);
        assert_eq!(c.nnz(), 0);
        let i4 = Dense::eye(4).to_csr();
        let a = gen::random_uniform(4, 4, 8, 9);
        assert_eq!(spgemm(&a, &i4), a);
        assert_eq!(spgemm(&i4, &a), a);
    }

    #[test]
    fn flop_count_matches_brute() {
        let a = gen::random_uniform(20, 20, 60, 5);
        let b = gen::random_uniform(20, 20, 60, 6);
        let mut mults = 0usize;
        for i in 0..20 {
            for &c in a.row_cols(i) {
                mults += b.row_nnz(c as usize);
            }
        }
        assert_eq!(spgemm_flops(&a, &b), 2 * mults);
    }

    #[test]
    fn rectangular_shapes() {
        let a = gen::random_uniform(7, 13, 30, 7);
        let b = gen::random_uniform(13, 5, 25, 8);
        check_against_dense(&a, &b, spgemm);
    }
}
