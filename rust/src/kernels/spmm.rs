//! CSR SpMM baselines — sparse matrix × dense multi-vector, `C = A · X`
//! with `k` right-hand-side columns.
//!
//! SpMM is the SpMV extension's natural scale-up (Sparse Stream Semantic
//! Registers motivates exactly this: amortize one stream schedule across
//! many dense right-hand sides). Both X and C are **row-major** dense
//! panels: `X[r*k + j]` is row `r`, column `j`. That layout keeps each
//! gathered X row contiguous, which is what the FPGA's k-wide vector
//! lanes consume per streamed A element (see `fpga::spmm_sim`).
//!
//! Accumulation discipline: each output column accumulates in f64 over the
//! row's elements in CSR order — exactly [`super::spmv::spmv`]'s order —
//! so every column of the result is **bit-identical** to an independent
//! SpMV with that column of X (property-tested). Column blocking and row
//! banding never change per-column op order; they only change which
//! columns share a pass.

use crate::sparse::{Csr, Val};

/// Default column-block width for the blocked CPU reference — matches the
/// FPGA design's per-pipeline vector lanes
/// (`fpga::FpgaConfig::vector_lanes`) so the reference walks memory the
/// way the datapath does.
pub const DEFAULT_COL_BLOCK: usize = 8;

/// C = A X, serial, column-blocked with a reused accumulator scratch
/// (the SpaScratch discipline: one f64 buffer of block width, zeroed per
/// row, no per-row allocation).
pub fn spmm(a: &Csr, x: &[Val], k: usize) -> Vec<Val> {
    spmm_blocked(a, x, k, DEFAULT_COL_BLOCK)
}

/// C = A X with an explicit column-block width. Any block width yields the
/// same bits: columns accumulate independently.
pub fn spmm_blocked(a: &Csr, x: &[Val], k: usize, col_block: usize) -> Vec<Val> {
    assert_eq!(x.len(), a.ncols * k, "X panel shape mismatch");
    assert!(col_block > 0, "column block must be positive");
    let mut c = vec![0 as Val; a.nrows * k];
    if k > 0 {
        spmm_rows(a, x, k, col_block, 0, &mut c);
    }
    c
}

/// C = A X with row-band threading (the CPU-N series). Bands own disjoint
/// output rows and run the same row-range body as the serial path, so the
/// result is bit-identical for every thread count.
pub fn spmm_parallel(a: &Csr, x: &[Val], k: usize, nthreads: usize) -> Vec<Val> {
    assert_eq!(x.len(), a.ncols * k, "X panel shape mismatch");
    if k == 0 {
        return Vec::new();
    }
    let nthreads = nthreads.max(1);
    if nthreads == 1 || a.nrows < 2 * nthreads {
        return spmm(a, x, k);
    }
    let rows_per = a.nrows.div_ceil(nthreads);
    let mut c = vec![0 as Val; a.nrows * k];
    std::thread::scope(|scope| {
        for (band, out) in c.chunks_mut(rows_per * k).enumerate() {
            let a = &*a;
            let x = &*x;
            scope.spawn(move || {
                spmm_rows(a, x, k, DEFAULT_COL_BLOCK, band * rows_per, out);
            });
        }
    });
    c
}

/// Compute rows `[row_lo, row_lo + out.len() / k)` of `C = A X` into `out`
/// (row-major, `out[0..k]` is row `row_lo`), column-blocked with one
/// reused f64 accumulator — the single implementation the serial and the
/// row-banded parallel paths share, so their per-column accumulation
/// sequences are identical by construction. Requires `k > 0`.
fn spmm_rows(a: &Csr, x: &[Val], k: usize, col_block: usize, row_lo: usize, out: &mut [Val]) {
    let nrows = out.len() / k;
    let mut acc = vec![0f64; col_block.min(k)];
    let mut j0 = 0usize;
    while j0 < k {
        let j1 = (j0 + col_block).min(k);
        let kb = j1 - j0;
        for li in 0..nrows {
            let i = row_lo + li;
            acc[..kb].fill(0.0);
            for (&col, &v) in a.row_cols(i).iter().zip(a.row_vals(i)) {
                let xrow = &x[col as usize * k + j0..col as usize * k + j1];
                for (t, &xv) in xrow.iter().enumerate() {
                    acc[t] += (v as f64) * (xv as f64);
                }
            }
            for (t, &a_t) in acc[..kb].iter().enumerate() {
                out[li * k + j0 + t] = a_t as Val;
            }
        }
        j0 = j1;
    }
}

/// Flop count: 2 per stored element per right-hand-side column.
pub fn spmm_flops(a: &Csr, k: usize) -> usize {
    2 * a.nnz() * k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::spmv::spmv;
    use crate::sparse::gen;

    fn panel(ncols: usize, k: usize, seed: u64) -> Vec<Val> {
        (0..ncols * k)
            .map(|i| (((i as u64).wrapping_mul(seed + 3) % 17) as f32 - 8.0) * 0.25)
            .collect()
    }

    /// Column j of the panel, extracted as an SpMV input vector.
    fn col(x: &[Val], k: usize, j: usize) -> Vec<Val> {
        x.iter().skip(j).step_by(k).copied().collect()
    }

    #[test]
    fn bit_identical_to_k_independent_spmvs() {
        for seed in 0..3u64 {
            let a = gen::power_law(80, 1200, seed);
            for k in [1usize, 3, 4, 8, 11] {
                let x = panel(a.ncols, k, seed);
                let c = spmm(&a, &x, k);
                for j in 0..k {
                    let yj = spmv(&a, &col(&x, k, j));
                    for i in 0..a.nrows {
                        assert_eq!(c[i * k + j], yj[i], "seed {seed} k {k} col {j} row {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn block_width_never_changes_bits() {
        let a = gen::random_uniform(50, 60, 500, 5);
        let k = 10usize;
        let x = panel(a.ncols, k, 5);
        let base = spmm_blocked(&a, &x, k, 1);
        for block in [2usize, 3, 8, 10, 64] {
            assert_eq!(spmm_blocked(&a, &x, k, block), base, "block {block}");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let a = gen::power_law(150, 2400, 9);
        let k = 6usize;
        let x = panel(a.ncols, k, 9);
        let serial = spmm(&a, &x, k);
        for t in [2usize, 3, 4, 8] {
            assert_eq!(spmm_parallel(&a, &x, k, t), serial, "threads {t}");
        }
    }

    #[test]
    fn empty_matrix_and_identity() {
        let z = Csr::new(4, 4);
        assert_eq!(spmm(&z, &[1.0; 8], 2), vec![0.0; 8]);
        let i = crate::sparse::Dense::eye(3).to_csr();
        let x: Vec<f32> = (0..6).map(|v| v as f32).collect();
        assert_eq!(spmm(&i, &x, 2), x);
    }

    #[test]
    fn zero_width_panel_is_legal() {
        let a = gen::random_uniform(5, 5, 10, 1);
        assert_eq!(spmm(&a, &[], 0), Vec::<Val>::new());
        assert_eq!(spmm_parallel(&a, &[], 0, 4), Vec::<Val>::new());
    }

    #[test]
    fn flops_count() {
        let a = gen::random_uniform(10, 10, 37, 2);
        assert_eq!(spmm_flops(&a, 4), 2 * 37 * 4);
    }
}
