//! Simplicial sparse Cholesky LL^T — the measured CHOLMOD stand-in.
//!
//! Up-looking numeric factorization over the precomputed symbolic pattern
//! (CSparse `cs_chol` style): for each row k, solve the triangular system
//! over the row's ereach pattern, then form the diagonal. This is the
//! `simplicial, LL^T, no-ordering` configuration the paper compares
//! against, with symbolic analysis excluded from the timed region exactly
//! as the paper excludes it ("We have not included the time spent to build
//! the elimination tree").
//!
//! f64 accumulation inside dot products, f32 storage — matching both
//! CHOLMOD's robustness practice and the FPGA's single-precision DSPs.

use anyhow::{bail, Result};

use crate::sparse::{Csc, Idx, Val};
use crate::symbolic::pattern::{ereach, strict_upper_from_lower, LPattern};
use crate::symbolic::symbolic_factor;

/// The numeric factor L in CSC (diagonal first per column, rows ascending —
/// same layout as the symbolic pattern).
#[derive(Clone, Debug)]
pub struct CholeskyFactor {
    pub l: Csc,
    /// The symbolic pattern used (kept for the solver and the simulator).
    pub pattern: LPattern,
}

/// Numeric factorization of the SPD matrix whose **lower triangle**
/// (diagonal included) is `a_lower`, over a precomputed symbolic pattern.
///
/// Errors on a non-positive pivot (matrix not positive definite).
pub fn cholesky_numeric(a_lower: &Csc, pattern: &LPattern) -> Result<CholeskyFactor> {
    let n = a_lower.ncols;
    let a_upper = strict_upper_from_lower(a_lower);

    // L stored column-wise with the symbolic pattern's exact layout.
    let col_ptr = pattern.col_ptr.clone();
    let rows = pattern.rows.clone();
    let mut vals: Vec<Val> = vec![0.0; rows.len()];

    // next free slot per column (diagonal occupies slot 0)
    let mut fill: Vec<usize> = (0..n).map(|j| col_ptr[j] + 1).collect();
    // x: dense scratch row of L (values of row k during its solve)
    let mut x: Vec<f64> = vec![0.0; n];
    let mut marked: Vec<u32> = vec![u32::MAX; n];
    let mut reach: Vec<Idx> = Vec::new();
    // position index: for binary search-free dot products we walk columns
    // sequentially; col_cursor[j] is not needed because reach is ascending.

    for k in 0..n {
        // scatter row k of A (entries A(k, j), j < k, from the upper view)
        ereach(&a_upper, k, &pattern.parent, &mut marked, k as u32, &mut reach);
        for &j in a_upper.col_rows(k) {
            x[j as usize] = 0.0;
        }
        for &j in &reach {
            x[j as usize] = 0.0;
        }
        for (&j, &v) in a_upper.col_rows(k).iter().zip(a_upper.col_vals(k)) {
            x[j as usize] = v as f64;
        }
        let mut d = a_lower.get(k, k) as f64; // A(k,k)

        // Solve L(0:k-1,0:k-1) * x = A(0:k-1,k) over the reach, ascending.
        for &j in &reach {
            let j = j as usize;
            let ljj = vals[col_ptr[j]] as f64; // diagonal of column j
            let lkj = x[j] / ljj;
            // saxpy: x -= lkj * L(:,j) for rows in (j, k)
            // and accumulate the diagonal update
            let lo = col_ptr[j] + 1;
            let hi = pattern.col_ptr[j + 1];
            for p in lo..hi {
                let r = rows[p] as usize;
                if r < k {
                    x[r] -= (vals[p] as f64) * lkj;
                } else if r == k {
                    // skip: this is the slot L(k,j) we are producing
                } else {
                    break; // rows ascend; nothing below k matters for row k
                }
            }
            d -= lkj * lkj;
            // store L(k,j) into column j's next slot (rows of the pattern
            // column ascend, and we visit k in ascending order globally, so
            // the slot order is exactly the fill order)
            let slot = fill[j];
            debug_assert_eq!(rows[slot] as usize, k, "pattern/fill drift");
            vals[slot] = lkj as Val;
            fill[j] += 1;
        }

        if d <= 0.0 || !d.is_finite() {
            bail!("matrix not positive definite at column {k} (d={d})");
        }
        vals[col_ptr[k]] = d.sqrt() as Val; // L(k,k), slot 0 of column k
    }

    let l = Csc { nrows: n, ncols: n, col_ptr, rows, vals };
    Ok(CholeskyFactor { l, pattern: pattern.clone() })
}

/// Convenience: symbolic + numeric in one call.
pub fn cholesky(a_lower: &Csc) -> Result<CholeskyFactor> {
    let pattern = symbolic_factor(a_lower);
    cholesky_numeric(a_lower, &pattern)
}

/// Flop count of the numeric factorization: Σ_k (1 sqrt + Σ_{j∈reach(k)}
/// (2·|col j ∩ rows<k| + 2)) — the convention used for the paper's
/// GFLOPS-per-FPU comparison.
pub fn cholesky_flops(pattern: &LPattern) -> usize {
    let n = pattern.n;
    // column j contributes 2*(len below diag) flops each time it appears in
    // a later row's reach = (col_nnz - 1) appearances.
    let mut flops = 0usize;
    for j in 0..n {
        let below = pattern.col_nnz(j) - 1;
        // each row k > j in the column: dot-product contribution of length
        // ~below plus the div; count 2*below + 2 per appearance.
        flops += below * (2 * below + 2);
        flops += 2; // sqrt + diagonal update amortized
    }
    flops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, ops, Dense};

    fn check(a_csc: &Csc, tol: f64) {
        let lower = a_csc.lower_triangle();
        let f = cholesky(&lower).unwrap();
        f.l.validate().unwrap();
        let dense_a = Dense::from_csr(&a_csc.to_csr());
        let expect = dense_a.cholesky();
        let got = Dense::from_csr(&f.l.to_csr());
        let diff = got.max_abs_diff(&expect);
        assert!(diff < tol, "max diff {diff}");
    }

    #[test]
    fn matches_dense_on_random_spd() {
        for seed in 0..6u64 {
            let spd = ops::make_spd(&gen::random_uniform(20, 20, 60, seed));
            check(&spd, 1e-4);
        }
    }

    #[test]
    fn matches_dense_on_fem_patterns() {
        for seed in 0..3u64 {
            let spd = gen::spd(gen::Family::BandedFem, 40, 300, seed);
            check(&spd, 1e-3);
        }
    }

    #[test]
    fn tridiagonal_known_factor() {
        // A = tridiag(1,4,1), n=3: L known in closed form
        let mut coo = crate::sparse::Coo::new(3, 3);
        for i in 0..3 {
            coo.push(i, i, 4.0);
            if i > 0 {
                coo.push(i, i - 1, 1.0);
                coo.push(i - 1, i, 1.0);
            }
        }
        let a = coo.to_csr().to_csc();
        let f = cholesky(&a.lower_triangle()).unwrap();
        let l00 = 2.0f64;
        let l10 = 1.0 / l00;
        let l11 = (4.0 - l10 * l10).sqrt();
        let l21 = 1.0 / l11;
        let l22 = (4.0 - l21 * l21).sqrt();
        assert!((f.l.get(0, 0) as f64 - l00).abs() < 1e-6);
        assert!((f.l.get(1, 0) as f64 - l10).abs() < 1e-6);
        assert!((f.l.get(1, 1) as f64 - l11).abs() < 1e-6);
        assert!((f.l.get(2, 1) as f64 - l21).abs() < 1e-6);
        assert!((f.l.get(2, 2) as f64 - l22).abs() < 1e-6);
        assert_eq!(f.l.get(2, 0), 0.0);
    }

    #[test]
    fn rejects_indefinite() {
        // [[1, 2], [2, 1]] has a negative eigenvalue
        let mut coo = crate::sparse::Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(1, 1, 1.0);
        coo.push(1, 0, 2.0);
        coo.push(0, 1, 2.0);
        let a = coo.to_csr().to_csc().lower_triangle();
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn ll_t_reconstructs_a() {
        let spd = gen::spd(gen::Family::BlockRandom, 32, 250, 4);
        let lower = spd.lower_triangle();
        let f = cholesky(&lower).unwrap();
        let l = Dense::from_csr(&f.l.to_csr());
        let mut lt = Dense::zeros(l.nrows, l.ncols);
        for i in 0..l.nrows {
            for j in 0..l.ncols {
                lt[(i, j)] = l[(j, i)];
            }
        }
        let a = Dense::from_csr(&spd.to_csr());
        assert!(l.matmul(&lt).max_abs_diff(&a) < 1e-3);
    }

    #[test]
    fn factor_nnz_equals_symbolic_nnz() {
        let spd = gen::spd(gen::Family::PowerLaw, 30, 200, 5);
        let lower = spd.lower_triangle();
        let pattern = symbolic_factor(&lower);
        let f = cholesky_numeric(&lower, &pattern).unwrap();
        assert_eq!(f.l.nnz(), pattern.nnz());
    }

    #[test]
    fn flops_positive_and_grow_with_fill() {
        let spd = gen::spd(gen::Family::BandedFem, 50, 400, 6);
        let p = symbolic_factor(&spd.lower_triangle());
        assert!(cholesky_flops(&p) > 0);
    }
}
