//! CSR SpMV baselines — the measured CPU side of the SpMV extension
//! (the paper's §II future-work direction, built through the same REAP
//! flow as SpGEMM/Cholesky).

use crate::sparse::{Csr, Val};

/// y = A x, serial CSR row dot products (f64 accumulation).
pub fn spmv(a: &Csr, x: &[Val]) -> Vec<Val> {
    assert_eq!(x.len(), a.ncols, "x length mismatch");
    let mut y = vec![0 as Val; a.nrows];
    for i in 0..a.nrows {
        let mut acc = 0f64;
        for (&c, &v) in a.row_cols(i).iter().zip(a.row_vals(i)) {
            acc += (v as f64) * (x[c as usize] as f64);
        }
        y[i] = acc as Val;
    }
    y
}

/// y = A x with row-band threading (the CPU-N series).
pub fn spmv_parallel(a: &Csr, x: &[Val], nthreads: usize) -> Vec<Val> {
    assert_eq!(x.len(), a.ncols);
    let nthreads = nthreads.max(1);
    if nthreads == 1 || a.nrows < 2 * nthreads {
        return spmv(a, x);
    }
    let rows_per = a.nrows.div_ceil(nthreads);
    let mut y = vec![0 as Val; a.nrows];
    std::thread::scope(|scope| {
        for (band, out) in y.chunks_mut(rows_per).enumerate() {
            let a = &*a;
            let x = &*x;
            scope.spawn(move || {
                let lo = band * rows_per;
                for (k, yo) in out.iter_mut().enumerate() {
                    let i = lo + k;
                    let mut acc = 0f64;
                    for (&c, &v) in a.row_cols(i).iter().zip(a.row_vals(i)) {
                        acc += (v as f64) * (x[c as usize] as f64);
                    }
                    *yo = acc as Val;
                }
            });
        }
    });
    y
}

/// Flop count (2 per stored element).
pub fn spmv_flops(a: &Csr) -> usize {
    2 * a.nnz()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, Dense};

    #[test]
    fn matches_dense_matvec() {
        for seed in 0..4u64 {
            let a = gen::random_uniform(40, 30, 300, seed);
            let x: Vec<f32> = (0..30).map(|i| (i as f32 * 0.3).sin()).collect();
            let y = spmv(&a, &x);
            let want = Dense::from_csr(&a).matvec(&x);
            for (g, w) in y.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "seed {seed}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let a = gen::power_law(200, 3000, 1);
        let x: Vec<f32> = (0..200).map(|i| 1.0 / (i + 1) as f32).collect();
        let serial = spmv(&a, &x);
        for t in [2usize, 3, 8] {
            assert_eq!(spmv_parallel(&a, &x, t), serial, "threads {t}");
        }
    }

    #[test]
    fn empty_and_identity() {
        let z = Csr::new(5, 5);
        assert_eq!(spmv(&z, &[1.0; 5]), vec![0.0; 5]);
        let i = Dense::eye(4).to_csr();
        let x = vec![3.0, -1.0, 0.5, 2.0];
        assert_eq!(spmv(&i, &x), x);
    }

    #[test]
    fn flops_count() {
        let a = gen::random_uniform(10, 10, 37, 2);
        assert_eq!(spmv_flops(&a), 74);
    }
}
