//! Measured CPU baselines — the libraries the paper compares against.
//!
//! The evaluation image has no MKL or CHOLMOD, so the same algorithmic
//! classes are implemented here and *measured* (not simulated), exactly as
//! the paper measures its CPU baselines:
//!
//! * [`spgemm()`] — Gustavson/row-by-row sparse GEMM with a dense/hash hybrid
//!   accumulator (MKL's `mkl_sparse_sp2m` is in this class), serial.
//! * [`spgemm_parallel()`] — the multithreaded variant behind the paper's
//!   CPU-2 … CPU-16 series.
//! * [`cholesky`] — simplicial up-looking sparse LL^T (CHOLMOD's
//!   `simplicial, LL^T, no-ordering` configuration, numeric phase).
//! * [`triangular`] — sparse triangular solves (the solver examples'
//!   forward/backward substitution).
//! * [`spmm()`] — sparse × dense multi-vector (`C = A·X`), column-blocked;
//!   each column is bit-identical to an independent [`spmv()`].

pub mod cholesky;
pub mod spgemm;
pub mod spgemm_parallel;
pub mod spmm;
pub mod spmv;
pub mod triangular;

pub use cholesky::{cholesky_numeric, CholeskyFactor};
pub use spgemm::spgemm;
pub use spgemm_parallel::{
    flop_balanced_ranges, spgemm_parallel, spgemm_parallel_with_scratch, SpaScratch,
};
pub use spmm::{spmm, spmm_parallel};
pub use spmv::{spmv, spmv_parallel};
