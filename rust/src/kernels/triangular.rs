//! Sparse triangular solves — forward/backward substitution with the
//! Cholesky factor (the `cholesky_solver` example's back end; CHOLMOD's
//! `cholmod_solve` counterpart).

use crate::sparse::{Csc, Val};

/// Solve `L x = b` (forward substitution), L lower-triangular CSC with
/// diagonal-first columns — the layout produced by the factorization.
pub fn solve_lower(l: &Csc, b: &[Val]) -> Vec<Val> {
    assert_eq!(l.nrows, l.ncols);
    assert_eq!(b.len(), l.nrows);
    let mut x: Vec<f64> = b.iter().map(|&v| v as f64).collect();
    for j in 0..l.ncols {
        let rows = l.col_rows(j);
        let vals = l.col_vals(j);
        debug_assert_eq!(rows[0] as usize, j, "diagonal must lead column {j}");
        let xj = x[j] / vals[0] as f64;
        x[j] = xj;
        for (r, v) in rows.iter().zip(vals).skip(1) {
            x[*r as usize] -= (*v as f64) * xj;
        }
    }
    x.into_iter().map(|v| v as Val).collect()
}

/// Solve `L^T x = b` (backward substitution) without materializing L^T:
/// column j of L is row j of L^T.
pub fn solve_lower_transpose(l: &Csc, b: &[Val]) -> Vec<Val> {
    assert_eq!(l.nrows, l.ncols);
    assert_eq!(b.len(), l.nrows);
    let mut x: Vec<f64> = b.iter().map(|&v| v as f64).collect();
    for j in (0..l.ncols).rev() {
        let rows = l.col_rows(j);
        let vals = l.col_vals(j);
        let mut acc = x[j];
        for (r, v) in rows.iter().zip(vals).skip(1) {
            acc -= (*v as f64) * x[*r as usize];
        }
        x[j] = acc / vals[0] as f64;
    }
    x.into_iter().map(|v| v as Val).collect()
}

/// Solve `A x = b` given the Cholesky factor L of A (two triangular
/// solves).
pub fn solve_spd(l: &Csc, b: &[Val]) -> Vec<Val> {
    let y = solve_lower(l, b);
    solve_lower_transpose(l, &y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::cholesky::cholesky;
    use crate::sparse::{gen, Dense};

    #[test]
    fn forward_solve_known() {
        // L = [[2,0],[1,3]]; b = [4, 11] => x = [2, 3]
        let l = Dense::from_rows(2, 2, &[2.0, 0.0, 1.0, 3.0]).to_csr().to_csc();
        let x = solve_lower(&l, &[4.0, 11.0]);
        assert!((x[0] - 2.0).abs() < 1e-6 && (x[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn backward_solve_known() {
        // L^T = [[2,1],[0,3]]; b = [7, 9] => x = [2, 3]
        let l = Dense::from_rows(2, 2, &[2.0, 0.0, 1.0, 3.0]).to_csr().to_csc();
        let x = solve_lower_transpose(&l, &[7.0, 9.0]);
        assert!((x[0] - 2.0).abs() < 1e-6 && (x[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn spd_solve_recovers_rhs() {
        for seed in 0..4u64 {
            let spd = gen::spd(gen::Family::BandedFem, 30, 180, seed);
            let lower = spd.lower_triangle();
            let f = cholesky(&lower).unwrap();
            // manufacture solution, compute b = A x
            let n = spd.nrows;
            let x_true: Vec<f32> = (0..n).map(|i| ((i % 7) as f32) - 3.0).collect();
            let b = Dense::from_csr(&spd.to_csr()).matvec(&x_true);
            let x = solve_spd(&f.l, &b);
            let err = x
                .iter()
                .zip(&x_true)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(err < 1e-2, "seed {seed}: max err {err}");
        }
    }

    #[test]
    fn identity_factor_is_identity_solve() {
        let l = Dense::eye(5).to_csr().to_csc();
        let b = vec![1.0, -2.0, 3.0, 0.0, 5.0];
        assert_eq!(solve_spd(&l, &b), b);
    }
}
