//! Multithreaded row-by-row SpGEMM — the paper's CPU-2 … CPU-16 series.
//!
//! Row-range parallelism over `std::thread` with per-thread accumulators
//! (the same decomposition MKL uses under OpenMP). Rows are distributed in
//! contiguous blocks balanced by *flop count*, not row count — power-law
//! suites make plain row-splitting badly skewed.

use crate::sparse::{Csr, Idx, Val};

/// C = A × B using `nthreads` worker threads.
pub fn spgemm_parallel(a: &Csr, b: &Csr, nthreads: usize) -> Csr {
    assert_eq!(a.ncols, b.nrows, "inner dimensions disagree");
    let nthreads = nthreads.max(1);
    if nthreads == 1 || a.nrows < 2 * nthreads {
        return super::spgemm::spgemm(a, b);
    }

    // Flop-balanced contiguous row ranges.
    let bounds = flop_balanced_ranges(a, b, nthreads);

    // Each worker computes its row band into its own arrays.
    struct Band {
        row_ptr: Vec<usize>, // local, rebased later
        cols: Vec<Idx>,
        vals: Vec<Val>,
    }

    let bands: Vec<Band> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(bounds.len() - 1);
        for w in 0..bounds.len() - 1 {
            let (lo, hi) = (bounds[w], bounds[w + 1]);
            let a_ref = &*a;
            let b_ref = &*b;
            handles.push(scope.spawn(move || {
                let mut row_ptr = vec![0usize; hi - lo + 1];
                let mut cols: Vec<Idx> = Vec::new();
                let mut vals: Vec<Val> = Vec::new();
                let mut acc: Vec<Val> = vec![0.0; b_ref.ncols];
                let mut stamp: Vec<u32> = vec![u32::MAX; b_ref.ncols];
                let mut touched: Vec<Idx> = Vec::new();
                for (li, i) in (lo..hi).enumerate() {
                    let tick = li as u32;
                    touched.clear();
                    for (&ca, &va) in a_ref.row_cols(i).iter().zip(a_ref.row_vals(i)) {
                        let r = ca as usize;
                        for (&cb, &vb) in b_ref.row_cols(r).iter().zip(b_ref.row_vals(r)) {
                            let j = cb as usize;
                            if stamp[j] != tick {
                                stamp[j] = tick;
                                acc[j] = va * vb;
                                touched.push(cb);
                            } else {
                                acc[j] += va * vb;
                            }
                        }
                    }
                    touched.sort_unstable();
                    for &c in &touched {
                        cols.push(c);
                        vals.push(acc[c as usize]);
                    }
                    row_ptr[li + 1] = cols.len();
                }
                Band { row_ptr, cols, vals }
            }));
        }
        handles.into_iter().map(|h| h.join().expect("spgemm worker panicked")).collect()
    });

    // Stitch bands together.
    let mut row_ptr = vec![0usize; a.nrows + 1];
    let total: usize = bands.iter().map(|b| b.cols.len()).sum();
    let mut cols = Vec::with_capacity(total);
    let mut vals = Vec::with_capacity(total);
    for (w, band) in bands.into_iter().enumerate() {
        let lo = bounds[w];
        let base = cols.len();
        for (li, p) in band.row_ptr.iter().enumerate().skip(1) {
            row_ptr[lo + li] = base + p;
        }
        cols.extend_from_slice(&band.cols);
        vals.extend_from_slice(&band.vals);
    }
    Csr { nrows: a.nrows, ncols: b.ncols, row_ptr, cols, vals }
}

/// Split `0..a.nrows` into ≤ `nthreads` contiguous ranges with roughly
/// equal multiply counts. Returns range boundaries (len = ranges + 1).
fn flop_balanced_ranges(a: &Csr, b: &Csr, nthreads: usize) -> Vec<usize> {
    let mut row_flops = vec![0usize; a.nrows];
    for i in 0..a.nrows {
        row_flops[i] = a.row_cols(i).iter().map(|&c| b.row_nnz(c as usize)).sum();
    }
    let total: usize = row_flops.iter().sum();
    let per = total.div_ceil(nthreads).max(1);
    let mut bounds = vec![0usize];
    let mut acc = 0usize;
    for (i, f) in row_flops.iter().enumerate() {
        acc += f;
        if acc >= per && bounds.len() < nthreads {
            bounds.push(i + 1);
            acc = 0;
        }
    }
    bounds.push(a.nrows);
    bounds.dedup();
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::spgemm::spgemm;
    use crate::sparse::gen;

    #[test]
    fn matches_serial_exactly() {
        for threads in [2usize, 3, 4, 8] {
            for seed in 0..3u64 {
                let a = gen::power_law(120, 2500, seed);
                let b = gen::random_uniform(120, 120, 2000, seed + 50);
                let serial = spgemm(&a, &b);
                let par = spgemm_parallel(&a, &b, threads);
                assert_eq!(par, serial, "threads={threads} seed={seed}");
            }
        }
    }

    #[test]
    fn single_thread_delegates() {
        let a = gen::random_uniform(20, 20, 80, 1);
        assert_eq!(spgemm_parallel(&a, &a, 1), spgemm(&a, &a));
    }

    #[test]
    fn more_threads_than_rows() {
        let a = gen::random_uniform(4, 4, 8, 2);
        assert_eq!(spgemm_parallel(&a, &a, 64), spgemm(&a, &a));
    }

    #[test]
    fn flop_ranges_cover_and_ascend() {
        let a = gen::power_law(200, 4000, 3);
        let b = gen::random_uniform(200, 200, 3000, 4);
        let bounds = flop_balanced_ranges(&a, &b, 8);
        assert_eq!(*bounds.first().unwrap(), 0);
        assert_eq!(*bounds.last().unwrap(), 200);
        assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        assert!(bounds.len() <= 9);
    }

    #[test]
    fn empty_matrix() {
        let z = Csr::new(64, 64);
        let c = spgemm_parallel(&z, &z, 4);
        assert_eq!(c.nnz(), 0);
        c.validate().unwrap();
    }
}
