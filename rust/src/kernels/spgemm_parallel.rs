//! Multithreaded row-by-row SpGEMM — the paper's CPU-2 … CPU-16 series.
//!
//! Row-range parallelism over `std::thread` with per-thread accumulators
//! (the same decomposition MKL uses under OpenMP). Rows are distributed in
//! contiguous blocks balanced by *flop count*, not row count — power-law
//! suites make plain row-splitting badly skewed.
//!
//! Per-worker state lives in a reusable [`SpaScratch`] (stamped sparse
//! accumulator); callers that invoke the kernel repeatedly (the measured
//! harness, the coordinator's scheduled numeric path) pass a scratch pool
//! so steady-state calls perform no accumulator allocations.

use crate::sparse::{Csr, Idx, Val};

/// Reusable stamped-SPA worker state: dense value + stamp arrays over the
/// output column space plus the touched-column list. The stamp discipline
/// makes `clear` O(1) — a row is "reset" by bumping the tick.
#[derive(Debug, Default)]
pub struct SpaScratch {
    acc: Vec<Val>,
    stamp: Vec<u32>,
    touched: Vec<Idx>,
    tick: u32,
}

impl SpaScratch {
    /// Fresh, empty scratch (arrays grow on first [`Self::ensure`]).
    pub fn new() -> Self {
        SpaScratch { acc: Vec::new(), stamp: Vec::new(), touched: Vec::new(), tick: u32::MAX }
    }

    /// Grow the accumulator to cover `ncols` output columns. Existing
    /// stamps stay valid: ticks are monotone, so stale entries never
    /// collide with a future tick (the wrap case refreshes every stamp).
    pub fn ensure(&mut self, ncols: usize) {
        if self.acc.len() < ncols {
            self.acc.resize(ncols, 0.0);
            self.stamp.resize(ncols, u32::MAX);
        }
    }

    /// Start accumulating a new output row; returns the row's tick.
    #[inline]
    pub fn begin_row(&mut self) -> u32 {
        self.tick = self.tick.wrapping_add(1);
        if self.tick == u32::MAX {
            // wrapped into the sentinel: refresh stamps once per 2^32 rows
            self.stamp.iter_mut().for_each(|s| *s = u32::MAX);
            self.tick = 0;
        }
        self.touched.clear();
        self.tick
    }

    /// Accumulate `v` into output column `j` under the current row's tick.
    #[inline]
    pub fn add(&mut self, j: Idx, v: Val) {
        let tick = self.tick;
        let ji = j as usize;
        if self.stamp[ji] != tick {
            self.stamp[ji] = tick;
            self.acc[ji] = v;
            self.touched.push(j);
        } else {
            self.acc[ji] += v;
        }
    }

    /// Sort the touched columns and append the row to `cols`/`vals`.
    pub fn drain_row(&mut self, cols: &mut Vec<Idx>, vals: &mut Vec<Val>) {
        self.touched.sort_unstable();
        cols.reserve(self.touched.len());
        vals.reserve(self.touched.len());
        for &c in &self.touched {
            cols.push(c);
            vals.push(self.acc[c as usize]);
        }
    }
}

/// One worker's output band, stitched into the final CSR afterwards.
pub(crate) struct Band {
    pub row_ptr: Vec<usize>, // local, rebased later
    pub cols: Vec<Idx>,
    pub vals: Vec<Val>,
}

/// Stitch per-band outputs (bands cover `bounds` row ranges in order) into
/// one CSR. Deterministic: pure concatenation plus pointer rebasing.
pub(crate) fn stitch_bands(
    nrows: usize,
    ncols: usize,
    bounds: &[usize],
    bands: Vec<Band>,
) -> Csr {
    let mut row_ptr = vec![0usize; nrows + 1];
    let total: usize = bands.iter().map(|b| b.cols.len()).sum();
    let mut cols = Vec::with_capacity(total);
    let mut vals = Vec::with_capacity(total);
    for (w, band) in bands.into_iter().enumerate() {
        let lo = bounds[w];
        let base = cols.len();
        for (li, p) in band.row_ptr.iter().enumerate().skip(1) {
            row_ptr[lo + li] = base + p;
        }
        cols.extend_from_slice(&band.cols);
        vals.extend_from_slice(&band.vals);
    }
    Csr { nrows, ncols, row_ptr, cols, vals }
}

/// C = A × B using `nthreads` worker threads.
pub fn spgemm_parallel(a: &Csr, b: &Csr, nthreads: usize) -> Csr {
    let mut pool = Vec::new();
    spgemm_parallel_with_scratch(a, b, nthreads, &mut pool)
}

/// C = A × B using `nthreads` workers drawing their accumulators from
/// `pool` (grown to the worker count on first use, reused afterwards —
/// repeated calls perform no SPA allocations).
pub fn spgemm_parallel_with_scratch(
    a: &Csr,
    b: &Csr,
    nthreads: usize,
    pool: &mut Vec<SpaScratch>,
) -> Csr {
    assert_eq!(a.ncols, b.nrows, "inner dimensions disagree");
    let nthreads = nthreads.max(1);
    if nthreads == 1 || a.nrows < 2 * nthreads {
        return super::spgemm::spgemm(a, b);
    }

    // Flop-balanced contiguous row ranges.
    let bounds = flop_balanced_ranges(a, b, nthreads);
    let nbands = bounds.len() - 1;
    while pool.len() < nbands {
        pool.push(SpaScratch::new());
    }
    for s in pool.iter_mut().take(nbands) {
        s.ensure(b.ncols);
    }

    let bands: Vec<Band> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nbands);
        for (w, scratch) in pool.iter_mut().take(nbands).enumerate() {
            let (lo, hi) = (bounds[w], bounds[w + 1]);
            let a_ref = &*a;
            let b_ref = &*b;
            handles.push(scope.spawn(move || spgemm_band(a_ref, b_ref, lo, hi, scratch)));
        }
        handles.into_iter().map(|h| h.join().expect("spgemm worker panicked")).collect()
    });

    stitch_bands(a.nrows, b.ncols, &bounds, bands)
}

/// Compute rows `[lo, hi)` of C = A × B into a local band.
fn spgemm_band(a: &Csr, b: &Csr, lo: usize, hi: usize, scratch: &mut SpaScratch) -> Band {
    let mut row_ptr = vec![0usize; hi - lo + 1];
    let mut cols: Vec<Idx> = Vec::new();
    let mut vals: Vec<Val> = Vec::new();
    for (li, i) in (lo..hi).enumerate() {
        scratch.begin_row();
        for (&ca, &va) in a.row_cols(i).iter().zip(a.row_vals(i)) {
            let r = ca as usize;
            for (&cb, &vb) in b.row_cols(r).iter().zip(b.row_vals(r)) {
                scratch.add(cb, va * vb);
            }
        }
        scratch.drain_row(&mut cols, &mut vals);
        row_ptr[li + 1] = cols.len();
    }
    Band { row_ptr, cols, vals }
}

/// Split `0..a.nrows` into ≤ `nthreads` contiguous ranges with roughly
/// equal multiply counts. Returns range boundaries (len = ranges + 1).
pub fn flop_balanced_ranges(a: &Csr, b: &Csr, nthreads: usize) -> Vec<usize> {
    let mut row_flops = vec![0usize; a.nrows];
    for i in 0..a.nrows {
        row_flops[i] = a.row_cols(i).iter().map(|&c| b.row_nnz(c as usize)).sum();
    }
    let total: usize = row_flops.iter().sum();
    let per = total.div_ceil(nthreads).max(1);
    let mut bounds = vec![0usize];
    let mut acc = 0usize;
    for (i, f) in row_flops.iter().enumerate() {
        acc += f;
        if acc >= per && bounds.len() < nthreads {
            bounds.push(i + 1);
            acc = 0;
        }
    }
    bounds.push(a.nrows);
    bounds.dedup();
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::spgemm::spgemm;
    use crate::sparse::gen;

    #[test]
    fn matches_serial_exactly() {
        for threads in [2usize, 3, 4, 8] {
            for seed in 0..3u64 {
                let a = gen::power_law(120, 2500, seed);
                let b = gen::random_uniform(120, 120, 2000, seed + 50);
                let serial = spgemm(&a, &b);
                let par = spgemm_parallel(&a, &b, threads);
                assert_eq!(par, serial, "threads={threads} seed={seed}");
            }
        }
    }

    #[test]
    fn single_thread_delegates() {
        let a = gen::random_uniform(20, 20, 80, 1);
        assert_eq!(spgemm_parallel(&a, &a, 1), spgemm(&a, &a));
    }

    #[test]
    fn more_threads_than_rows() {
        let a = gen::random_uniform(4, 4, 8, 2);
        assert_eq!(spgemm_parallel(&a, &a, 64), spgemm(&a, &a));
    }

    #[test]
    fn scratch_pool_reuse_across_calls() {
        let a = gen::power_law(100, 2000, 4);
        let b = gen::random_uniform(100, 100, 1500, 5);
        let serial = spgemm(&a, &b);
        let mut pool = Vec::new();
        for _ in 0..3 {
            assert_eq!(spgemm_parallel_with_scratch(&a, &b, 4, &mut pool), serial);
        }
        assert!(!pool.is_empty());
        // the pool also survives a differently-shaped product
        let c = gen::random_uniform(100, 40, 800, 6);
        assert_eq!(spgemm_parallel_with_scratch(&a, &c, 4, &mut pool), spgemm(&a, &c));
    }

    #[test]
    fn scratch_tick_survives_many_rows() {
        let mut s = SpaScratch::new();
        s.ensure(8);
        let mut last = None;
        for _ in 0..1000 {
            let t = s.begin_row();
            if let Some(prev) = last {
                assert_ne!(t, prev);
            }
            assert_ne!(t, u32::MAX);
            last = Some(t);
        }
    }

    #[test]
    fn flop_ranges_cover_and_ascend() {
        let a = gen::power_law(200, 4000, 3);
        let b = gen::random_uniform(200, 200, 3000, 4);
        let bounds = flop_balanced_ranges(&a, &b, 8);
        assert_eq!(*bounds.first().unwrap(), 0);
        assert_eq!(*bounds.last().unwrap(), 200);
        assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        assert!(bounds.len() <= 9);
    }

    #[test]
    fn empty_matrix() {
        let z = Csr::new(64, 64);
        let c = spgemm_parallel(&z, &z, 4);
        assert_eq!(c.nnz(), 0);
        c.validate().unwrap();
    }
}
