//! `reap` — the Layer-3 coordinator binary.
//!
//! Subcommands:
//!
//! * `spgemm`   — run REAP SpGEMM on a synthetic or Matrix-Market matrix.
//! * `spmv` / `spmm` — run the SpMV extension / its multi-vector (SpMM)
//!                scale-up likewise.
//! * `cholesky` — run REAP sparse Cholesky likewise.
//! * `bench`    — regenerate the paper's tables/figures plus the batch,
//!                SpMM, reliability, stream-compression, online-serving
//!                and CPU-scaling studies (`table1 table2 fig6 fig7 fig8
//!                fig9 fig10 fig11 hls batch spmm reliability compression
//!                serving scaling all`).
//! * `lint`     — statically audit schedules, RIR streams and wave costs
//!                ([`reap::analysis`]); exits non-zero on any diagnostic.
//! * `gen-matrix` — write a synthetic matrix as Matrix-Market.
//! * `info`     — platform, artifact and design-point status.
//!
//! Run `reap <cmd> --help` for per-command options.

use anyhow::{bail, Context, Result};

use reap::analysis::{self, Diagnostic};
use reap::coordinator::{verify, ReapCholesky, ReapSpgemm, ReapSpmm, ReapSpmv};
use reap::fpga::cholesky_sim::simulate_cholesky;
use reap::fpga::engine::Occupancy;
use reap::fpga::spgemm_sim::{simulate_spgemm, simulate_spgemm_batch, Style};
use reap::fpga::spmm_sim::simulate_spmm;
use reap::fpga::spmv_sim::simulate_spmv;
use reap::fpga::FpgaConfig;
use reap::harness::{self, RunConfig};
use reap::rir::layout::serialize_stream_encoded;
use reap::rir::schedule::{schedule_spgemm, schedule_spgemm_batch};
use reap::rir::BundleStream;
use reap::runtime::{Manifest, XlaRuntime};
use reap::sparse::gen::Family;
use reap::sparse::{gen, mm, ops, Csr};
use reap::symbolic::CholeskySymbolic;
use reap::util::cli::{usage, Args, OptSpec};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print_help();
        return;
    }
    let cmd = argv.remove(0);
    let result = match cmd.as_str() {
        "spgemm" => cmd_spgemm(argv),
        "spmv" => cmd_spmv(argv),
        "spmm" => cmd_spmm(argv),
        "cholesky" => cmd_cholesky(argv),
        "bench" => cmd_bench(argv),
        "lint" => cmd_lint(argv),
        "gen-matrix" => cmd_gen_matrix(argv),
        "info" => cmd_info(argv),
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown command `{other}`"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "reap — synergistic CPU-FPGA sparse linear algebra (REAP reproduction)\n\n\
         usage: reap <command> [options]\n\n\
         commands:\n  \
           spgemm      run REAP SpGEMM (C = A*B or A^2)\n  \
           spmv        run REAP SpMV (y = A x, extension kernel)\n  \
           spmm        run REAP SpMM (C = A X, k dense right-hand sides)\n  \
           cholesky    run REAP sparse Cholesky factorization\n  \
           bench       regenerate paper tables/figures\n  \
           lint        statically audit schedules, RIR streams, wave costs\n  \
           gen-matrix  write a synthetic matrix (.mtx)\n  \
           info        platform / artifact status\n"
    );
}

fn matrix_opts() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "n", takes_value: true, help: "dimension (synthetic)" },
        OptSpec { name: "nnz", takes_value: true, help: "nonzeros (synthetic)" },
        OptSpec { name: "family", takes_value: true, help: "random|fem|powerlaw|block" },
        OptSpec { name: "seed", takes_value: true, help: "PRNG seed" },
        OptSpec { name: "mtx", takes_value: true, help: "MatrixMarket file instead" },
    ]
}

fn parse_family(s: &str) -> Result<Family> {
    Ok(match s {
        "random" => Family::RandomUniform,
        "fem" => Family::BandedFem,
        "powerlaw" => Family::PowerLaw,
        "block" => Family::BlockRandom,
        other => bail!("unknown family `{other}` (random|fem|powerlaw|block)"),
    })
}

fn load_matrix(args: &Args) -> Result<Csr> {
    if let Some(path) = args.get("mtx") {
        return mm::read_csr(std::path::Path::new(path));
    }
    let n = args.get_parsed::<usize>("n", 1000)?;
    let nnz = args.get_parsed::<usize>("nnz", n * 8)?;
    let family = parse_family(args.get("family").unwrap_or("random"))?;
    let seed = args.get_parsed::<u64>("seed", 42)?;
    Ok(gen::generate(family, n, nnz, seed))
}

fn variant_spgemm(name: &str) -> Result<FpgaConfig> {
    Ok(match name {
        "reap32" => FpgaConfig::reap32_spgemm(),
        "reap64" => FpgaConfig::reap64_spgemm(),
        "reap128" => FpgaConfig::reap128_spgemm(),
        other => bail!("unknown variant `{other}` (reap32|reap64|reap128)"),
    })
}

fn dram_depth_opt() -> OptSpec {
    OptSpec {
        name: "dram-depth",
        takes_value: true,
        help: "DRAM stream buffer depth: 1 serial, 2 double-buffered prefetch (default 1)",
    }
}

/// Apply `--dram-depth` to a design point (validated by the coordinator).
fn apply_dram_depth(args: &Args, mut cfg: FpgaConfig) -> Result<FpgaConfig> {
    cfg.dram_buffer_depth = args.get_parsed("dram-depth", cfg.dram_buffer_depth)?;
    Ok(cfg)
}

fn encoding_opt() -> OptSpec {
    OptSpec {
        name: "encoding",
        takes_value: true,
        help: "RIR stream encoding: raw|bitmap|fx32|bitmap+fx32 (default raw)",
    }
}

/// Apply `--encoding` to a design point (the negotiated per-stream wire
/// format the cycle models price; Cholesky ignores it — its RA/RL streams
/// are baked raw at analyze time).
fn apply_encoding(args: &Args, mut cfg: FpgaConfig) -> Result<FpgaConfig> {
    if let Some(tok) = args.get("encoding") {
        cfg.encoding = reap::rir::layout::StreamEncoding::parse(tok)
            .with_context(|| format!("unknown encoding `{tok}` (raw|bitmap|fx32|bitmap+fx32)"))?;
    }
    Ok(cfg)
}

fn cmd_spgemm(argv: Vec<String>) -> Result<()> {
    let mut specs = matrix_opts();
    specs.extend([
        OptSpec { name: "variant", takes_value: true, help: "reap32|reap64|reap128" },
        dram_depth_opt(),
        encoding_opt(),
        OptSpec { name: "xla", takes_value: false, help: "numerics via AOT XLA artifacts" },
        OptSpec { name: "verify", takes_value: false, help: "check vs CPU baseline" },
        OptSpec { name: "help", takes_value: false, help: "show usage" },
    ]);
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!("{}", usage("spgemm", "run REAP SpGEMM (C = A^2)", &specs));
        return Ok(());
    }
    let a = load_matrix(&args)?;
    let cfg = apply_encoding(
        &args,
        apply_dram_depth(&args, variant_spgemm(args.get("variant").unwrap_or("reap32"))?)?,
    )?;
    println!(
        "matrix: {}x{}, nnz {}, density {:.5}%",
        a.nrows,
        a.ncols,
        a.nnz(),
        a.density() * 100.0
    );
    if !cfg.encoding.is_raw() {
        println!("stream encoding: {}", cfg.encoding);
    }

    let rt;
    let coord = if args.flag("xla") {
        rt = XlaRuntime::load_default().context("loading artifacts (run `make artifacts`)")?;
        println!("numerics: XLA/PJRT ({})", rt.platform());
        ReapSpgemm::with_runtime(cfg.clone(), &rt).strict(true)
    } else {
        ReapSpgemm::new(cfg.clone()).strict(true)
    };
    let rep = coord.run(&a, &a)?;
    println!(
        "{}: cpu preprocess {:.3} ms | fpga(sim) {:.3} ms ({} cycles, {} waves) | total {:.3} ms",
        cfg.name,
        rep.cpu_preprocess_s * 1e3,
        rep.fpga_s * 1e3,
        rep.fpga_sim.cycles,
        rep.fpga_sim.waves,
        rep.total_s * 1e3,
    );
    println!(
        "  result nnz {} | {:.2} sim-GFLOP/s | pipeline util {:.1}% | dram-bound {:.1}%",
        rep.c.nnz(),
        rep.fpga_sim.gflops(&cfg),
        rep.fpga_sim.pipeline_utilization() * 100.0,
        rep.fpga_sim.dram_bound_fraction() * 100.0,
    );
    println!(
        "  dram channel: depth-1 {} cycles | depth-2 {} cycles ({} hidden by prefetch)",
        rep.fpga_sim_serial.cycles,
        rep.fpga_sim_db.cycles,
        rep.fpga_sim_db.prefetch_hidden_cycles,
    );
    if args.flag("verify") {
        let reference = reap::kernels::spgemm(&a, &a);
        let v = verify::verify_csr(&rep.c, &reference);
        println!("  verify vs CPU baseline: rel err {:.2e} -> {}", v.relative(), if v.ok(1e-5) { "OK" } else { "MISMATCH" });
        if !v.ok(1e-5) {
            bail!("verification failed");
        }
    }
    Ok(())
}

fn cmd_spmv(argv: Vec<String>) -> Result<()> {
    let mut specs = matrix_opts();
    specs.extend([
        OptSpec { name: "variant", takes_value: true, help: "reap32|reap64|reap128" },
        dram_depth_opt(),
        encoding_opt(),
        OptSpec { name: "xla", takes_value: false, help: "numerics via AOT XLA artifacts" },
        OptSpec { name: "verify", takes_value: false, help: "check vs CPU baseline" },
        OptSpec { name: "help", takes_value: false, help: "show usage" },
    ]);
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!("{}", usage("spmv", "run REAP SpMV (y = A x, extension)", &specs));
        return Ok(());
    }
    let a = load_matrix(&args)?;
    let x: Vec<f32> = (0..a.ncols).map(|i| ((i % 17) as f32 - 8.0) * 0.125).collect();
    let cfg = apply_encoding(
        &args,
        apply_dram_depth(&args, variant_spgemm(args.get("variant").unwrap_or("reap32"))?)?,
    )?;
    println!(
        "matrix: {}x{}, nnz {}, density {:.5}%",
        a.nrows, a.ncols, a.nnz(), a.density() * 100.0
    );
    if !cfg.encoding.is_raw() {
        println!("stream encoding: {}", cfg.encoding);
    }
    let rt;
    let coord = if args.flag("xla") {
        rt = XlaRuntime::load_default().context("loading artifacts (run `make artifacts`)")?;
        println!("numerics: XLA/PJRT ({})", rt.platform());
        ReapSpmv::with_runtime(cfg.clone(), &rt).strict(true)
    } else {
        ReapSpmv::new(cfg.clone()).strict(true)
    };
    let rep = coord.run(&a, &x)?;
    println!(
        "{}: cpu preprocess {:.3} ms | fpga(sim) {:.3} ms ({} cycles) | total {:.3} ms | {:.2} sim-GFLOP/s",
        cfg.name,
        rep.cpu_preprocess_s * 1e3,
        rep.fpga_s * 1e3,
        rep.fpga_sim.cycles,
        rep.total_s * 1e3,
        rep.fpga_sim.gflops(&cfg),
    );
    if args.flag("verify") {
        let want = reap::kernels::spmv(&a, &x);
        let err = rep.y.iter().zip(&want).map(|(g, w)| (g - w).abs()).fold(0f32, f32::max);
        println!("  verify vs CPU baseline: max err {err:.2e} -> {}", if err < 1e-3 { "OK" } else { "MISMATCH" });
        if err >= 1e-3 {
            bail!("verification failed");
        }
    }
    Ok(())
}

fn cmd_spmm(argv: Vec<String>) -> Result<()> {
    let mut specs = matrix_opts();
    specs.extend([
        OptSpec { name: "variant", takes_value: true, help: "reap32|reap64|reap128" },
        OptSpec { name: "k", takes_value: true, help: "dense right-hand-side columns (default 8)" },
        dram_depth_opt(),
        encoding_opt(),
        OptSpec { name: "verify", takes_value: false, help: "check vs CPU baseline" },
        OptSpec { name: "help", takes_value: false, help: "show usage" },
    ]);
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!("{}", usage("spmm", "run REAP SpMM (C = A X, multi-vector extension)", &specs));
        return Ok(());
    }
    let a = load_matrix(&args)?;
    let k = args.get_parsed::<usize>("k", 8)?;
    let x: Vec<f32> = (0..a.ncols * k).map(|i| ((i % 17) as f32 - 8.0) * 0.125).collect();
    let cfg = apply_encoding(
        &args,
        apply_dram_depth(&args, variant_spgemm(args.get("variant").unwrap_or("reap32"))?)?,
    )?;
    println!(
        "matrix: {}x{}, nnz {}, density {:.5}% | panel: {} columns",
        a.nrows, a.ncols, a.nnz(), a.density() * 100.0, k
    );
    if !cfg.encoding.is_raw() {
        println!("stream encoding: {}", cfg.encoding);
    }
    let rep = ReapSpmm::new(cfg.clone()).strict(true).run(&a, &x, k)?;
    println!(
        "{}: cpu preprocess {:.3} ms (once) | fpga(sim) {:.3} ms ({} cycles, {} blocks) | total {:.3} ms | {:.2} sim-GFLOP/s",
        cfg.name,
        rep.cpu_preprocess_s * 1e3,
        rep.fpga_s * 1e3,
        rep.fpga_sim.cycles,
        rep.n_blocks,
        rep.total_s * 1e3,
        rep.fpga_sim.gflops(&cfg),
    );
    if args.flag("verify") {
        let want = reap::kernels::spmm(&a, &x, k);
        let err = rep.c.iter().zip(&want).map(|(g, w)| (g - w).abs()).fold(0f32, f32::max);
        println!("  verify vs CPU baseline: max err {err:.2e} -> {}", if err == 0.0 { "OK" } else { "MISMATCH" });
        if err != 0.0 {
            bail!("verification failed (SpMM must be bit-identical to the CPU reference)");
        }
    }
    Ok(())
}

fn cmd_cholesky(argv: Vec<String>) -> Result<()> {
    let mut specs = matrix_opts();
    specs.extend([
        OptSpec { name: "variant", takes_value: true, help: "reap32|reap64" },
        dram_depth_opt(),
        encoding_opt(),
        OptSpec { name: "xla", takes_value: false, help: "numerics via AOT XLA artifacts" },
        OptSpec { name: "verify", takes_value: false, help: "check LL^T ~= A" },
        OptSpec { name: "help", takes_value: false, help: "show usage" },
    ]);
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!("{}", usage("cholesky", "run REAP sparse Cholesky (SPD-ified input)", &specs));
        return Ok(());
    }
    let base = load_matrix(&args)?;
    let spd = ops::make_spd(&base);
    let lower = spd.lower_triangle();
    let cfg = apply_encoding(
        &args,
        apply_dram_depth(
            &args,
            match args.get("variant").unwrap_or("reap32") {
                "reap32" => FpgaConfig::reap32_cholesky(),
                "reap64" => FpgaConfig::reap64_cholesky(),
                other => bail!("unknown variant `{other}` (reap32|reap64)"),
            },
        )?,
    )?;
    if !cfg.encoding.is_raw() {
        println!(
            "note: Cholesky streams are baked raw at analyze time; --encoding {} is ignored",
            cfg.encoding
        );
    }
    println!(
        "SPD matrix: {}x{}, lower nnz {}",
        spd.nrows,
        spd.ncols,
        lower.nnz()
    );

    let rt;
    let coord = if args.flag("xla") {
        rt = XlaRuntime::load_default().context("loading artifacts (run `make artifacts`)")?;
        println!("numerics: XLA/PJRT ({})", rt.platform());
        ReapCholesky::with_runtime(cfg.clone(), &rt).strict(true)
    } else {
        ReapCholesky::new(cfg.clone()).strict(true)
    };
    let rep = coord.run(&lower)?;
    println!(
        "{}: cpu symbolic {:.3} ms | fpga(sim) {:.3} ms ({} cycles) | total {:.3} ms",
        cfg.name,
        rep.cpu_symbolic_s * 1e3,
        rep.fpga_s * 1e3,
        rep.fpga_sim.cycles,
        rep.total_s * 1e3,
    );
    println!(
        "  nnz(L) {} (fill-in {}) | pipeline util {:.1}%",
        rep.factor.l.nnz(),
        rep.factor.pattern.fill_in(&lower),
        rep.fpga_sim.pipeline_utilization() * 100.0,
    );
    if args.flag("verify") {
        let reference = reap::kernels::cholesky::cholesky(&lower)?;
        let v = verify::verify_csc(&rep.factor.l, &reference.l);
        println!("  verify vs CPU baseline: rel err {:.2e} -> {}", v.relative(), if v.ok(1e-4) { "OK" } else { "MISMATCH" });
        if !v.ok(1e-4) {
            bail!("verification failed");
        }
    }
    Ok(())
}

fn cmd_bench(argv: Vec<String>) -> Result<()> {
    let specs = vec![
        OptSpec { name: "max-rows", takes_value: true, help: "matrix scale cap (default 2000)" },
        OptSpec { name: "full", takes_value: false, help: "paper-scale matrices (slow)" },
        OptSpec { name: "budget", takes_value: true, help: "seconds per measurement (default 0.2)" },
        OptSpec { name: "seed", takes_value: true, help: "suite seed" },
        dram_depth_opt(),
        OptSpec { name: "no-csv", takes_value: false, help: "skip results/*.csv dumps" },
        OptSpec { name: "help", takes_value: false, help: "show usage" },
    ];
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") || args.positionals().is_empty() {
        print!(
            "{}\ntargets: table1 table2 fig6 fig7 fig8 fig9 fig10 fig11 hls batch spmm reliability compression serving scaling all\n",
            usage("bench <target>", "regenerate a paper table/figure", &specs)
        );
        return Ok(());
    }
    let mut cfg = RunConfig {
        max_rows: args.get_parsed("max-rows", 2000)?,
        seed: args.get_parsed("seed", 0x5EA9)?,
        budget_s: args.get_parsed("budget", 0.2)?,
        dram_buffer_depth: args.get_parsed("dram-depth", 1)?,
        ..Default::default()
    };
    // fail like the per-kernel commands do, not via a harness panic
    if cfg.dram_buffer_depth == 0 {
        bail!("--dram-depth must be >= 1 (1 = serial, 2 = double-buffered)");
    }
    if args.flag("full") {
        cfg.max_rows = usize::MAX;
    }
    if args.flag("no-csv") {
        cfg.csv_dir = None;
    }
    for target in args.positionals() {
        run_bench_target(target, &cfg)?;
    }
    Ok(())
}

fn run_bench_target(target: &str, cfg: &RunConfig) -> Result<()> {
    match target {
        "table1" => {
            let t = harness::tables::table1(cfg);
            print!("{}", t.render());
            cfg.dump_csv("table1", &t)?;
        }
        "table2" => {
            let t = harness::tables::table2();
            print!("{}", t.render());
            cfg.dump_csv("table2", &t)?;
        }
        "fig6" => {
            let (rows, t) = harness::fig6::run(cfg);
            print!("{}", t.render());
            println!(
                "paper: REAP-32 geomean 3.2x, beats CPU-1 everywhere -> headline {}",
                if harness::fig6::headline_holds(&rows) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("fig6", &t)?;
        }
        "fig7" => {
            let (_, t) = harness::fig7::run(cfg);
            print!("{}", t.render());
            cfg.dump_csv("fig7", &t)?;
        }
        "fig8" => {
            let (series, left, right) = harness::fig8::run(cfg);
            print!("{}", left.render());
            print!("{}", right.render());
            println!(
                "paper: REAP per-FPU GFLOPS above CPU at matched units -> headline {}",
                if harness::fig8::headline_holds(&series) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("fig8_left", &left)?;
            cfg.dump_csv("fig8_right", &right)?;
        }
        "fig9" => {
            let (points, t) = harness::fig9::run(cfg);
            print!("{}", t.render());
            println!(
                "paper: REAP favors sparse matrices -> headline {}",
                if harness::fig9::headline_holds(&points) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("fig9", &t)?;
        }
        "fig10" => {
            let (rows, t) = harness::fig10::run(cfg);
            print!("{}", t.render());
            println!(
                "paper: REAP-32 GM 1.18x, REAP-64 GM 1.85x (all wins) -> headline {}",
                if harness::fig10::headline_holds(&rows) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("fig10", &t)?;
        }
        "fig11" => {
            let (rows, t) = harness::fig11::run(cfg);
            print!("{}", t.render());
            println!(
                "paper: FPGA dominates the Cholesky breakdown -> headline {}",
                if harness::fig11::headline_holds(&rows) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("fig11", &t)?;
        }
        "hls" => {
            let (rep, t) = harness::hls_cmp::run(cfg);
            print!("{}", t.render());
            println!(
                "paper: +16% SpGEMM / +35% Cholesky geomean -> headline {}",
                if harness::hls_cmp::headline_holds(&rep) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("hls", &t)?;
        }
        "batch" => {
            let (rows, t) = harness::batch::run(cfg);
            print!("{}", t.render());
            println!(
                "multi-tenant: shared waves beat serial occupancy on 64/128 -> headline {}",
                if harness::batch::headline_holds(&rows) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("batch", &t)?;
        }
        "spmm" => {
            let (rows, t) = harness::spmm::run(cfg);
            print!("{}", t.render());
            println!(
                "multi-vector: one schedule + k-wide lanes beat k serial SpMVs on 64/128 -> headline {}",
                if harness::spmm::headline_holds(&rows) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("spmm", &t)?;
        }
        "reliability" => {
            let (rows, t) = harness::reliability::run(cfg);
            print!("{}", t.render());
            println!(
                "fault tolerance: zero silent corruption + exact retry ledger -> headline {}",
                if harness::reliability::headline_holds(&rows) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("reliability", &t)?;
        }
        "compression" => {
            let (rows, t) = harness::compression::run(cfg);
            print!("{}", t.render());
            println!(
                "compressed streams: fewer bytes AND fewer cycles on 64/128, error within bound -> headline {}",
                if harness::compression::headline_holds(&rows) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("compression", &t)?;
        }
        "serving" => {
            let (rows, t) = harness::serving::run(cfg);
            print!("{}", t.render());
            println!(
                "online serving: cache replays bit-identical, strictly faster on 64/128 -> headline {}",
                if harness::serving::headline_holds(&rows) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("serving", &t)?;
        }
        "scaling" => {
            let (rows, t) = harness::scaling::run(cfg);
            print!("{}", t.render());
            println!(
                "work-stealing >= static on uniform, strictly faster on skew at 4+ workers -> headline {}",
                if harness::scaling::headline_holds(&rows) { "HOLDS" } else { "DIFFERS" }
            );
            cfg.dump_csv("scaling", &t)?;
        }
        "all" => {
            for t in [
                "table1", "table2", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "hls",
                "batch", "spmm", "reliability", "compression", "serving", "scaling",
            ] {
                run_bench_target(t, cfg)?;
                println!();
            }
        }
        other => bail!("unknown bench target `{other}`"),
    }
    Ok(())
}

/// Which artifact `lint --seed-violation` deliberately corrupts before
/// auditing (the tool's own negative fixture — lint must then fail).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Violation {
    Schedule,
    Stream,
    Wave,
}

/// Prefix every diagnostic's location with the artifact it came from and
/// append it to the report.
fn collect(diags: &mut Vec<Diagnostic>, what: &str, found: Vec<Diagnostic>) {
    for mut d in found {
        d.location = format!("{what}: {}", d.location);
        diags.push(d);
    }
}

/// Audit the SpGEMM artifacts for `C = A * A`: the wave schedule, the
/// serialized A-side RIR stream (plain and checksummed, in the negotiated
/// encoding) and the simulated wave costs.
fn lint_spgemm(
    a: &Csr,
    cfg: &FpgaConfig,
    violation: Option<Violation>,
    diags: &mut Vec<Diagnostic>,
) {
    let mut schedule = schedule_spgemm(a, a, cfg.pipelines, cfg.bundle_size);
    if violation == Some(Violation::Schedule) {
        // re-assign the first chunk a second time in the final wave
        let dup = schedule.waves.first().and_then(|w| w.assignments.first()).copied();
        if let (Some(asg), Some(last)) = (dup, schedule.waves.last_mut()) {
            last.assignments.push(asg);
        }
    }
    collect(diags, "spgemm schedule", analysis::audit_spgemm_schedule(a, a, &schedule));

    let stream = BundleStream::from_csr(a, cfg.bundle_size);
    for checksummed in [false, true] {
        let mut words = serialize_stream_encoded(&stream, cfg.encoding, checksummed);
        if checksummed && violation == Some(Violation::Stream) && words.len() > 2 {
            words[2] ^= 1; // damage a word under the CRC
        }
        let what = if checksummed { "A stream (checksummed)" } else { "A stream" };
        collect(diags, what, analysis::audit_stream(&words));
    }

    let mut costs = simulate_spgemm(a, a, &schedule, cfg, Style::HandCoded).costs;
    if violation == Some(Violation::Wave) {
        if let Some(c) = costs.first_mut() {
            c.occupancy = Occupancy::ActivePipelines(cfg.pipelines as u64 + 1);
        }
    }
    collect(diags, "spgemm waves", analysis::audit_wave_costs(&costs, cfg));
}

/// Audit the SpMV schedule (B surrogate, as the coordinator builds it)
/// and its simulated wave costs.
fn lint_spmv(a: &Csr, cfg: &FpgaConfig, diags: &mut Vec<Diagnostic>) {
    let surrogate = Csr::new(a.ncols, a.ncols);
    let schedule = schedule_spgemm(a, &surrogate, cfg.pipelines, cfg.bundle_size);
    collect(diags, "spmv schedule", analysis::audit_spgemm_schedule(a, &surrogate, &schedule));
    let sim = simulate_spmv(a, &schedule, cfg, Style::HandCoded);
    collect(diags, "spmv waves", analysis::audit_wave_costs(&sim.costs, cfg));
}

/// Audit the SpMM schedule and its simulated wave costs (k = 8 panel).
fn lint_spmm(a: &Csr, cfg: &FpgaConfig, diags: &mut Vec<Diagnostic>) {
    let surrogate = Csr::new(a.ncols, a.ncols);
    let schedule = schedule_spgemm(a, &surrogate, cfg.pipelines, cfg.bundle_size);
    collect(diags, "spmm schedule", analysis::audit_spgemm_schedule(a, &surrogate, &schedule));
    let sim = simulate_spmm(a, &schedule, cfg, Style::HandCoded, 8);
    collect(diags, "spmm waves", analysis::audit_wave_costs(&sim.costs, cfg));
}

/// Audit a two-job batch built from the workload matrix: the shared-wave
/// schedule, the job-segmented RIR stream (mid-stream EOS terminators)
/// and the simulated wave costs.
fn lint_batch(a: &Csr, cfg: &FpgaConfig, diags: &mut Vec<Diagnostic>) {
    let jobs = vec![(a.clone(), a.clone()), (a.clone(), a.clone())];
    let schedule = schedule_spgemm_batch(&jobs, cfg.pipelines, cfg.bundle_size);
    collect(diags, "batch schedule", analysis::audit_batch_schedule(&jobs, &schedule));
    let mut s = BundleStream::new();
    s.encode_csr_jobs(&[a, a], cfg.bundle_size);
    let words = serialize_stream_encoded(&s, cfg.encoding, true);
    collect(diags, "batch job stream", analysis::audit_stream(&words));
    let sim = simulate_spgemm_batch(&jobs, &schedule, cfg, Style::HandCoded);
    collect(diags, "batch waves", analysis::audit_wave_costs(&sim.costs, cfg));
}

/// Audit the Cholesky wave costs (the symbolic pass owns the column
/// order, so there is no chunk schedule to check) on the Cholesky design
/// point nearest the requested variant, at the requested channel depth.
fn lint_cholesky(a: &Csr, cfg: &FpgaConfig, diags: &mut Vec<Diagnostic>) {
    let mut ccfg = if cfg.pipelines <= 32 {
        FpgaConfig::reap32_cholesky()
    } else {
        FpgaConfig::reap64_cholesky()
    };
    ccfg.dram_buffer_depth = cfg.dram_buffer_depth;
    let lower = ops::make_spd(a).lower_triangle();
    let sym = CholeskySymbolic::analyze(&lower, ccfg.bundle_size);
    let sim = simulate_cholesky(&sym, &ccfg, Style::HandCoded);
    collect(diags, "cholesky waves", analysis::audit_wave_costs(&sim.costs, &ccfg));
}

fn cmd_lint(argv: Vec<String>) -> Result<()> {
    let mut specs = matrix_opts();
    specs.extend([
        OptSpec { name: "variant", takes_value: true, help: "reap32|reap64|reap128" },
        dram_depth_opt(),
        encoding_opt(),
        OptSpec {
            name: "workload",
            takes_value: true,
            help: "spgemm|batch|spmv|spmm|cholesky|all (default all)",
        },
        OptSpec { name: "json", takes_value: false, help: "one machine-readable JSON object" },
        OptSpec {
            name: "seed-violation",
            takes_value: true,
            help: "corrupt the SpGEMM artifact before auditing: schedule|stream|wave",
        },
        OptSpec { name: "help", takes_value: false, help: "show usage" },
    ]);
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!(
            "{}",
            usage("lint", "statically audit schedules, RIR streams and wave costs", &specs)
        );
        return Ok(());
    }
    let a = load_matrix(&args)?;
    let cfg = apply_encoding(
        &args,
        apply_dram_depth(&args, variant_spgemm(args.get("variant").unwrap_or("reap32"))?)?,
    )?;
    cfg.validate()?;
    let violation = match args.get("seed-violation") {
        None => None,
        Some("schedule") => Some(Violation::Schedule),
        Some("stream") => Some(Violation::Stream),
        Some("wave") => Some(Violation::Wave),
        Some(other) => bail!("unknown violation `{other}` (schedule|stream|wave)"),
    };
    // a seeded violation lives in the SpGEMM artifacts — lint only those
    let workload = if violation.is_some() {
        "spgemm"
    } else {
        args.get("workload").unwrap_or("all")
    };

    let mut diags: Vec<Diagnostic> = Vec::new();
    match workload {
        "spgemm" => lint_spgemm(&a, &cfg, violation, &mut diags),
        "spmv" => lint_spmv(&a, &cfg, &mut diags),
        "spmm" => lint_spmm(&a, &cfg, &mut diags),
        "batch" => lint_batch(&a, &cfg, &mut diags),
        "cholesky" => lint_cholesky(&a, &cfg, &mut diags),
        "all" => {
            lint_spgemm(&a, &cfg, None, &mut diags);
            lint_spmv(&a, &cfg, &mut diags);
            lint_spmm(&a, &cfg, &mut diags);
            lint_batch(&a, &cfg, &mut diags);
            lint_cholesky(&a, &cfg, &mut diags);
        }
        other => bail!("unknown workload `{other}` (spgemm|batch|spmv|spmm|cholesky|all)"),
    }

    if args.flag("json") {
        println!("{}", analysis::render_json(&diags));
    } else {
        print!("{}", analysis::render_human(&diags));
    }
    if !diags.is_empty() {
        bail!("lint found {} diagnostic(s)", diags.len());
    }
    Ok(())
}

fn cmd_gen_matrix(argv: Vec<String>) -> Result<()> {
    let mut specs = matrix_opts();
    specs.push(OptSpec { name: "out", takes_value: true, help: "output .mtx path (required)" });
    specs.push(OptSpec { name: "spd", takes_value: false, help: "SPD-ify the pattern" });
    specs.push(OptSpec { name: "help", takes_value: false, help: "show usage" });
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        print!("{}", usage("gen-matrix", "write a synthetic matrix", &specs));
        return Ok(());
    }
    let out = args.get("out").context("--out is required")?;
    let mut m = load_matrix(&args)?;
    if args.flag("spd") {
        m = ops::make_spd(&m).to_csr();
    }
    mm::write_csr(std::path::Path::new(out), &m)?;
    println!("wrote {out}: {}x{}, nnz {}", m.nrows, m.ncols, m.nnz());
    Ok(())
}

fn cmd_info(argv: Vec<String>) -> Result<()> {
    let specs = vec![OptSpec { name: "help", takes_value: false, help: "show usage" }];
    let _ = Args::parse(argv, &specs)?;
    println!("reap {} — REAP reproduction (DCS-TR-750)", env!("CARGO_PKG_VERSION"));
    println!(
        "host threads: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} ({} entries)", dir.display(), m.entries.len());
            for (name, e) in &m.entries {
                let shapes: Vec<String> = e
                    .args
                    .iter()
                    .map(|(s, d)| format!("{d}{s:?}"))
                    .collect();
                println!("  {name}: {}", shapes.join(", "));
            }
            match XlaRuntime::load(&dir) {
                Ok(rt) => println!("PJRT platform: {}", rt.platform()),
                Err(e) => println!("PJRT unavailable: {e:#}"),
            }
        }
        Err(e) => println!("artifacts missing: {e:#}"),
    }
    for c in [
        FpgaConfig::reap32_spgemm(),
        FpgaConfig::reap64_spgemm(),
        FpgaConfig::reap128_spgemm(),
        FpgaConfig::reap32_cholesky(),
        FpgaConfig::reap64_cholesky(),
    ] {
        println!(
            "design {}: {} pipelines @ {} MHz, {} mult/PE, DRAM {}/{} GB/s",
            c.name, c.pipelines, c.freq_mhz, c.dot_multipliers, c.dram.read_gbps, c.dram.write_gbps
        );
    }
    Ok(())
}
