//! Artifact discovery and the build manifest.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing each
//! lowered entry point (file, sha256, fixed shapes). The runtime reads it
//! to locate HLO files and to know the padding geometry the buffers must
//! match — a shape mismatch is a build-system bug and fails loudly here
//! rather than inside XLA.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

/// Fixed geometry of one AOT entry point.
#[derive(Clone, Debug, PartialEq)]
pub struct EntryGeometry {
    pub file: PathBuf,
    /// `(shape, dtype)` per argument, in call order.
    pub args: Vec<(Vec<usize>, String)>,
    /// Kernel parameters (bundle, tile_w, batch, pipes — as present).
    pub params: std::collections::BTreeMap<String, usize>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: std::collections::BTreeMap<String, EntryGeometry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts`)", mpath.display()))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        ensure!(
            json.at(&["format"])?.as_str() == Some("hlo-text"),
            "unsupported artifact format"
        );
        let mut entries = std::collections::BTreeMap::new();
        for (name, e) in json.at(&["entries"])?.as_obj().context("entries")? {
            let file = dir.join(e.at(&["file"])?.as_str().context("file")?);
            ensure!(file.exists(), "artifact missing: {}", file.display());
            let mut args = Vec::new();
            for a in e.at(&["args"])?.as_arr().context("args")? {
                let shape = a
                    .at(&["shape"])?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<Vec<_>>>()?;
                let dtype = a.at(&["dtype"])?.as_str().context("dtype")?.to_string();
                args.push((shape, dtype));
            }
            let mut params = std::collections::BTreeMap::new();
            if let Some(obj) = e.at(&["params"])?.as_obj() {
                for (k, v) in obj {
                    if let Some(u) = v.as_usize() {
                        params.insert(k.clone(), u);
                    }
                }
            }
            entries.insert(name.clone(), EntryGeometry { file, args, params });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Default artifact directory: `$REAP_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("REAP_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Geometry of a named entry.
    pub fn entry(&self, name: &str) -> Result<&EntryGeometry> {
        self.entries
            .get(name)
            .with_context(|| format!("entry `{name}` not in manifest (stale artifacts?)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_artifacts() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("reap_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("k.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": "hlo-text", "return_tuple": true, "entries": {
                "k": {"file": "k.hlo.txt", "sha256": "x",
                       "params": {"bundle": 32},
                       "args": [{"shape": [4, 32], "dtype": "float32"}]}}}"#,
        )
        .unwrap();
        dir
    }

    #[test]
    fn loads_and_validates() {
        let dir = write_fake_artifacts();
        let m = Manifest::load(&dir).unwrap();
        let e = m.entry("k").unwrap();
        assert_eq!(e.args[0].0, vec![4, 32]);
        assert_eq!(e.args[0].1, "float32");
        assert_eq!(e.params["bundle"], 32);
        assert!(m.entry("missing").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_file_is_an_error() {
        let dir = write_fake_artifacts();
        std::fs::remove_file(dir.join("k.hlo.txt")).unwrap();
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
