//! The request-path runtime: loads the AOT-compiled HLO artifacts through
//! the PJRT C API (`xla` crate) and executes them from the coordinator.
//!
//! This is the boundary that keeps Python off the request path: `make
//! artifacts` runs JAX once at build time; afterwards the `reap` binary is
//! self-contained — [`artifacts`] locates and fingerprints the HLO text,
//! [`client`] compiles it on the PJRT CPU client, and [`exec`] marshals
//! RIR-padded buffers in and results out (the role the FPGA's input/output
//! controllers play in the paper).

pub mod artifacts;
pub mod client;
pub mod exec;

pub use artifacts::Manifest;
pub use client::XlaRuntime;
pub use exec::{CholeskyStepIo, SpgemmWaveIo, SpmvWaveIo};
