//! The request-path runtime: loads the AOT-compiled HLO artifacts through
//! the PJRT C API (`xla` crate) and executes them from the coordinator.
//!
//! This is the boundary that keeps Python off the request path: `make
//! artifacts` runs JAX once at build time; afterwards the `reap` binary is
//! self-contained — [`artifacts`] locates and fingerprints the HLO text,
//! `client` (compiled only with the `xla` feature) compiles it on the
//! PJRT CPU client, and [`exec`] marshals
//! RIR-padded buffers in and results out (the role the FPGA's input/output
//! controllers play in the paper).
//!
//! The PJRT path needs the `xla` crate (native `xla_extension` closure),
//! which only the full offline image carries, so it is gated behind the
//! `xla` cargo feature. Without the feature the staging/marshaling layer
//! still compiles (and is tested), but [`XlaRuntime::load`] and the
//! `execute*` entry points return an error directing the user to rebuild
//! with `--features xla`; the coordinators' in-process numeric path is
//! unaffected.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod client;
pub mod exec;
#[cfg(not(feature = "xla"))]
mod stub;

pub use artifacts::Manifest;
#[cfg(feature = "xla")]
pub use client::XlaRuntime;
#[cfg(not(feature = "xla"))]
pub use stub::XlaRuntime;
pub use exec::{CholeskyStepIo, SpgemmWaveIo, SpmvWaveIo};
