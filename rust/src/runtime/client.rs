//! PJRT client wrapper: HLO text → compiled executables, cached by name.
//!
//! Follows the `/opt/xla-example/load_hlo` pattern: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`. The
//! text parser reassigns instruction ids, which is what makes jax ≥ 0.5
//! output loadable on xla_extension 0.5.1 (see `python/compile/aot.py`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::artifacts::Manifest;

/// A PJRT CPU client plus the compiled executables of every manifest entry.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: Manifest,
}

impl XlaRuntime {
    /// Create a CPU client and compile all artifacts in `dir`.
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for (name, entry) in &manifest.entries {
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .with_context(|| format!("parsing HLO text {}", entry.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact `{name}`"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(XlaRuntime { client, executables, manifest })
    }

    /// Load from the default artifact directory.
    pub fn load_default() -> Result<XlaRuntime> {
        Self::load(&Manifest::default_dir())
    }

    /// The manifest the runtime was built from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute a named entry with literal arguments; returns the elements
    /// of the result tuple (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("no compiled executable `{name}`"))?;
        let result = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing `{name}`"))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaRuntime")
            .field("platform", &self.platform())
            .field("entries", &self.executables.keys().collect::<Vec<_>>())
            .finish()
    }
}
