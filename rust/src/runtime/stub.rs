//! Stand-in for `super::client` when the crate is built without the
//! `xla` feature: the same API surface, every entry point failing with a
//! clear message instead of reaching PJRT. Keeps the coordinators, CLI and
//! tests compiling on images whose crate cache lacks the `xla` closure.

use std::path::Path;

use anyhow::{bail, Result};

use super::artifacts::Manifest;

/// API-compatible placeholder for the PJRT runtime. Never constructible:
/// [`XlaRuntime::load`] always errors, so the accessor methods exist only
/// to satisfy callers that hold an (unreachable) instance.
pub struct XlaRuntime {
    manifest: Manifest,
}

impl XlaRuntime {
    /// Always fails: the binary was built without the `xla` feature.
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        bail!(
            "XLA/PJRT runtime unavailable: built without the `xla` cargo \
             feature (artifacts dir: {}). On an image that carries the xla \
             crate closure, add `xla` to [dependencies] in rust/Cargo.toml \
             (see the [features] note there) and rebuild with `cargo build \
             --features xla`.",
            dir.display()
        )
    }

    /// Always fails (see [`XlaRuntime::load`]).
    pub fn load_default() -> Result<XlaRuntime> {
        Self::load(&Manifest::default_dir())
    }

    /// The manifest the runtime was built from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable (built without `xla` feature)".to_string()
    }
}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaRuntime").field("platform", &self.platform()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = XlaRuntime::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("without the `xla`"));
        assert!(XlaRuntime::load_default().is_err());
    }
}
