//! Padded-buffer marshaling between RIR bundles and the AOT entry points.
//!
//! The artifacts have *fixed* shapes (recorded in the manifest); this
//! module owns the reusable staging buffers, the padding discipline
//! (column sentinel −1, value 0 — identical to the Python side) and the
//! literal construction, playing the role of the FPGA's input/output
//! controllers.

use anyhow::{ensure, Result};

use crate::sparse::{Idx, Val};

use super::XlaRuntime;

/// Column padding sentinel (matches `kernels/*.py::PAD_COL`).
pub const PAD_COL: i32 = -1;

/// Staging buffers for one `spgemm_bundle` invocation batch.
#[derive(Clone, Debug)]
#[cfg_attr(not(feature = "xla"), allow(dead_code))] // staging fields are read by the gated execute path
pub struct SpgemmWaveIo {
    pub batch: usize,
    pub bundle: usize,
    pub tile_w: usize,
    tile_start: Vec<i32>,
    a_vals: Vec<f32>,
    b_cols: Vec<i32>,
    b_vals: Vec<f32>,
    steps: usize,
}

impl SpgemmWaveIo {
    /// Allocate from the runtime's manifest geometry.
    pub fn new(rt: &XlaRuntime) -> Result<Self> {
        let e = rt.manifest().entry("spgemm_bundle")?;
        let batch = e.params["batch"];
        let bundle = e.params["bundle"];
        let tile_w = e.params["tile_w"];
        Ok(Self::with_geometry(batch, bundle, tile_w))
    }

    /// Allocate with explicit geometry (tests).
    pub fn with_geometry(batch: usize, bundle: usize, tile_w: usize) -> Self {
        SpgemmWaveIo {
            batch,
            bundle,
            tile_w,
            tile_start: vec![0; batch],
            a_vals: vec![0.0; batch * bundle],
            b_cols: vec![PAD_COL; batch * bundle * bundle],
            b_vals: vec![0.0; batch * bundle * bundle],
            steps: 0,
        }
    }

    /// Reset to an empty batch (buffers retained).
    pub fn clear(&mut self) {
        self.tile_start.iter_mut().for_each(|x| *x = 0);
        self.a_vals.iter_mut().for_each(|x| *x = 0.0);
        self.b_cols.iter_mut().for_each(|x| *x = PAD_COL);
        self.b_vals.iter_mut().for_each(|x| *x = 0.0);
        self.steps = 0;
    }

    /// Number of steps currently staged.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// True when another step no longer fits.
    pub fn is_full(&self) -> bool {
        self.steps == self.batch
    }

    /// Stage one bundle-step: an A-chunk (`a_vals[i]` per CAM slot) and,
    /// per slot, the referenced B-row chunk (cols/vals), for the column
    /// tile starting at `tile_start`. Returns the step index.
    ///
    /// `b_rows[i]` is `(cols, vals)` of the B chunk for A slot `i`; both
    /// may be shorter than the bundle (padded here). Slots beyond
    /// `a_chunk.len()` stay padding.
    pub fn push_step(
        &mut self,
        tile_start: u32,
        a_chunk_vals: &[Val],
        b_rows: &[(&[Idx], &[Val])],
    ) -> Result<usize> {
        ensure!(!self.is_full(), "wave batch full ({} steps)", self.batch);
        ensure!(a_chunk_vals.len() <= self.bundle, "A chunk exceeds bundle");
        ensure!(b_rows.len() == a_chunk_vals.len(), "slot arity mismatch");
        let s = self.steps;
        self.tile_start[s] = tile_start as i32;
        let a_base = s * self.bundle;
        self.a_vals[a_base..a_base + a_chunk_vals.len()].copy_from_slice(a_chunk_vals);
        for (i, (cols, vals)) in b_rows.iter().enumerate() {
            ensure!(cols.len() == vals.len(), "B chunk cols/vals mismatch");
            ensure!(cols.len() <= self.bundle, "B chunk exceeds bundle");
            let base = (s * self.bundle + i) * self.bundle;
            for (k, (&c, &v)) in cols.iter().zip(vals.iter()).enumerate() {
                self.b_cols[base + k] = c as i32;
                self.b_vals[base + k] = v;
            }
        }
        self.steps += 1;
        Ok(s)
    }

    /// Execute the staged batch; returns the dense accumulator tiles
    /// (`steps` rows of `tile_w` values).
    #[cfg(feature = "xla")]
    pub fn execute(&self, rt: &XlaRuntime) -> Result<Vec<Vec<f32>>> {
        let (n, b, w) = (self.batch as i64, self.bundle as i64, self.tile_w as i64);
        let args = [
            xla::Literal::vec1(&self.tile_start),
            xla::Literal::vec1(&self.a_vals).reshape(&[n, b])?,
            xla::Literal::vec1(&self.b_cols).reshape(&[n, b, b])?,
            xla::Literal::vec1(&self.b_vals).reshape(&[n, b, b])?,
        ];
        let out = rt.execute("spgemm_bundle", &args)?;
        ensure!(out.len() == 1, "spgemm_bundle must return one tuple element");
        let flat: Vec<f32> = out[0].to_vec()?;
        ensure!(flat.len() == (n * w) as usize, "unexpected output size");
        Ok(flat
            .chunks(self.tile_w)
            .take(self.steps)
            .map(|c| c.to_vec())
            .collect())
    }

    /// Built without the `xla` feature: staging works, execution errors.
    #[cfg(not(feature = "xla"))]
    pub fn execute(&self, _rt: &XlaRuntime) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!("spgemm_bundle execution requires the `xla` feature")
    }
}

/// Staging buffers for one `spmv_bundle` invocation batch (the SpMV
/// extension kernel).
#[derive(Clone, Debug)]
#[cfg_attr(not(feature = "xla"), allow(dead_code))] // staging fields are read by the gated execute path
pub struct SpmvWaveIo {
    pub batch: usize,
    pub bundle: usize,
    pub tile_w: usize,
    tile_start: Vec<i32>,
    cols: Vec<i32>,
    vals: Vec<f32>,
    x_tiles: Vec<f32>,
    steps: usize,
}

impl SpmvWaveIo {
    /// Allocate from the runtime's manifest geometry.
    pub fn new(rt: &XlaRuntime) -> Result<Self> {
        let e = rt.manifest().entry("spmv_bundle")?;
        Ok(Self::with_geometry(e.params["batch"], e.params["bundle"], e.params["tile_w"]))
    }

    /// Allocate with explicit geometry (tests).
    pub fn with_geometry(batch: usize, bundle: usize, tile_w: usize) -> Self {
        SpmvWaveIo {
            batch,
            bundle,
            tile_w,
            tile_start: vec![0; batch],
            cols: vec![PAD_COL; batch * bundle],
            vals: vec![0.0; batch * bundle],
            x_tiles: vec![0.0; batch * tile_w],
            steps: 0,
        }
    }

    /// Reset to an empty batch.
    pub fn clear(&mut self) {
        self.tile_start.iter_mut().for_each(|x| *x = 0);
        self.cols.iter_mut().for_each(|x| *x = PAD_COL);
        self.vals.iter_mut().for_each(|x| *x = 0.0);
        self.x_tiles.iter_mut().for_each(|x| *x = 0.0);
        self.steps = 0;
    }

    /// Number of staged steps.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// True when another step no longer fits.
    pub fn is_full(&self) -> bool {
        self.steps == self.batch
    }

    /// Stage one (row chunk, x tile) step. `x_tile` may be shorter than
    /// `tile_w` at the vector's tail (zero-padded).
    pub fn push_step(
        &mut self,
        tile_start: u32,
        chunk_cols: &[Idx],
        chunk_vals: &[Val],
        x_tile: &[Val],
    ) -> Result<usize> {
        ensure!(!self.is_full(), "spmv batch full ({} steps)", self.batch);
        ensure!(chunk_cols.len() == chunk_vals.len(), "chunk arity");
        ensure!(chunk_cols.len() <= self.bundle, "chunk exceeds bundle");
        ensure!(x_tile.len() <= self.tile_w, "x tile too wide");
        let s = self.steps;
        self.tile_start[s] = tile_start as i32;
        let base = s * self.bundle;
        for (k, (&c, &v)) in chunk_cols.iter().zip(chunk_vals).enumerate() {
            self.cols[base + k] = c as i32;
            self.vals[base + k] = v;
        }
        let xbase = s * self.tile_w;
        self.x_tiles[xbase..xbase + x_tile.len()].copy_from_slice(x_tile);
        self.steps += 1;
        Ok(s)
    }

    /// Execute the staged batch; returns the partial products
    /// (`steps` values).
    #[cfg(feature = "xla")]
    pub fn execute(&self, rt: &XlaRuntime) -> Result<Vec<f32>> {
        let (n, b, w) = (self.batch as i64, self.bundle as i64, self.tile_w as i64);
        let args = [
            xla::Literal::vec1(&self.tile_start),
            xla::Literal::vec1(&self.cols).reshape(&[n, b])?,
            xla::Literal::vec1(&self.vals).reshape(&[n, b])?,
            xla::Literal::vec1(&self.x_tiles).reshape(&[n, w])?,
        ];
        let out = rt.execute("spmv_bundle", &args)?;
        ensure!(out.len() == 1, "spmv_bundle must return one tuple element");
        let flat: Vec<f32> = out[0].to_vec()?;
        Ok(flat[..self.steps].to_vec())
    }

    /// Built without the `xla` feature: staging works, execution errors.
    #[cfg(not(feature = "xla"))]
    pub fn execute(&self, _rt: &XlaRuntime) -> Result<Vec<f32>> {
        anyhow::bail!("spmv_bundle execution requires the `xla` feature")
    }
}

/// Staging buffers for the Cholesky entry points.
#[derive(Clone, Debug)]
#[cfg_attr(not(feature = "xla"), allow(dead_code))] // staging fields are read by the gated execute path
pub struct CholeskyStepIo {
    pub bundle: usize,
    pub pipes: usize,
    rowk_cols: Vec<i32>,
    rowk_vals: Vec<f32>,
    rowr_cols: Vec<i32>,
    rowr_vals: Vec<f32>,
    a_vals: Vec<f32>,
    a_diag: [f32; 1],
}

impl CholeskyStepIo {
    /// Allocate from the runtime's manifest geometry.
    pub fn new(rt: &XlaRuntime) -> Result<Self> {
        let e = rt.manifest().entry("cholesky_update")?;
        Ok(Self::with_geometry(e.params["bundle"], e.params["pipes"]))
    }

    /// Allocate with explicit geometry (tests).
    pub fn with_geometry(bundle: usize, pipes: usize) -> Self {
        CholeskyStepIo {
            bundle,
            pipes,
            rowk_cols: vec![PAD_COL; bundle],
            rowk_vals: vec![0.0; bundle],
            rowr_cols: vec![PAD_COL; pipes * bundle],
            rowr_vals: vec![0.0; pipes * bundle],
            a_vals: vec![0.0; pipes],
            a_diag: [0.0],
        }
    }

    /// Reset all staging to padding.
    pub fn clear(&mut self) {
        self.rowk_cols.iter_mut().for_each(|x| *x = PAD_COL);
        self.rowk_vals.iter_mut().for_each(|x| *x = 0.0);
        self.rowr_cols.iter_mut().for_each(|x| *x = PAD_COL);
        self.rowr_vals.iter_mut().for_each(|x| *x = 0.0);
        self.a_vals.iter_mut().for_each(|x| *x = 0.0);
        self.a_diag[0] = 0.0;
    }

    /// Stage the row-k broadcast chunk.
    pub fn set_rowk(&mut self, cols: &[Idx], vals: &[Val]) -> Result<()> {
        ensure!(cols.len() == vals.len() && cols.len() <= self.bundle, "rowk chunk");
        self.rowk_cols.iter_mut().for_each(|x| *x = PAD_COL);
        self.rowk_vals.iter_mut().for_each(|x| *x = 0.0);
        for (k, (&c, &v)) in cols.iter().zip(vals).enumerate() {
            self.rowk_cols[k] = c as i32;
            self.rowk_vals[k] = v;
        }
        Ok(())
    }

    /// Stage pipeline `p`'s row-r chunk.
    pub fn set_rowr(&mut self, p: usize, cols: &[Idx], vals: &[Val]) -> Result<()> {
        ensure!(p < self.pipes, "pipeline index");
        ensure!(cols.len() == vals.len() && cols.len() <= self.bundle, "rowr chunk");
        let base = p * self.bundle;
        for k in 0..self.bundle {
            self.rowr_cols[base + k] = PAD_COL;
            self.rowr_vals[base + k] = 0.0;
        }
        for (k, (&c, &v)) in cols.iter().zip(vals).enumerate() {
            self.rowr_cols[base + k] = c as i32;
            self.rowr_vals[base + k] = v;
        }
        Ok(())
    }

    /// Stage the A-column values: `a_vals[p] = A(r_p, k)`, `a_diag = A(k,k)`.
    pub fn set_a(&mut self, a_vals: &[Val], a_diag: Val) -> Result<()> {
        ensure!(a_vals.len() <= self.pipes, "a_vals length");
        self.a_vals.iter_mut().for_each(|x| *x = 0.0);
        self.a_vals[..a_vals.len()].copy_from_slice(a_vals);
        self.a_diag[0] = a_diag;
        Ok(())
    }

    #[cfg(feature = "xla")]
    fn common_literals(&self) -> Result<[xla::Literal; 4]> {
        let (p, b) = (self.pipes as i64, self.bundle as i64);
        Ok([
            xla::Literal::vec1(&self.rowk_cols),
            xla::Literal::vec1(&self.rowk_vals),
            xla::Literal::vec1(&self.rowr_cols).reshape(&[p, b])?,
            xla::Literal::vec1(&self.rowr_vals).reshape(&[p, b])?,
        ])
    }

    /// Execute `cholesky_dot`: partial matched dots for the staged chunk
    /// pair (used when rows exceed one bundle).
    #[cfg(feature = "xla")]
    pub fn execute_dot(&self, rt: &XlaRuntime) -> Result<Vec<f32>> {
        let [kc, kv, rc, rv] = self.common_literals()?;
        let out = rt.execute("cholesky_dot", &[kc, kv, rc, rv])?;
        ensure!(out.len() == 1, "cholesky_dot must return one element");
        Ok(out[0].to_vec()?)
    }

    /// Built without the `xla` feature: staging works, execution errors.
    #[cfg(not(feature = "xla"))]
    pub fn execute_dot(&self, _rt: &XlaRuntime) -> Result<Vec<f32>> {
        anyhow::bail!("cholesky_dot execution requires the `xla` feature")
    }

    /// Execute `cholesky_update`: returns `(l_rk[pipes], l_kk)`.
    #[cfg(feature = "xla")]
    pub fn execute_update(&self, rt: &XlaRuntime) -> Result<(Vec<f32>, f32)> {
        let [kc, kv, rc, rv] = self.common_literals()?;
        let av = xla::Literal::vec1(&self.a_vals);
        let ad = xla::Literal::vec1(&self.a_diag);
        let out = rt.execute("cholesky_update", &[kc, kv, rc, rv, av, ad])?;
        ensure!(out.len() == 2, "cholesky_update must return two elements");
        let l_rk: Vec<f32> = out[0].to_vec()?;
        let l_kk: Vec<f32> = out[1].to_vec()?;
        Ok((l_rk, l_kk[0]))
    }

    /// Built without the `xla` feature: staging works, execution errors.
    #[cfg(not(feature = "xla"))]
    pub fn execute_update(&self, _rt: &XlaRuntime) -> Result<(Vec<f32>, f32)> {
        anyhow::bail!("cholesky_update execution requires the `xla` feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spgemm_staging_pads_and_counts() {
        let mut io = SpgemmWaveIo::with_geometry(2, 4, 16);
        assert!(!io.is_full());
        let cols: &[Idx] = &[3, 5];
        let vals: &[Val] = &[1.0, 2.0];
        let s = io.push_step(16, &[0.5, -1.0], &[(cols, vals), (&[], &[])]).unwrap();
        assert_eq!(s, 0);
        assert_eq!(io.steps(), 1);
        assert_eq!(io.tile_start[0], 16);
        assert_eq!(io.a_vals[0..2], [0.5, -1.0]);
        assert_eq!(io.b_cols[0], 3);
        assert_eq!(io.b_cols[2], PAD_COL); // padded suffix
        io.push_step(0, &[], &[]).unwrap();
        assert!(io.is_full());
        assert!(io.push_step(0, &[], &[]).is_err());
        io.clear();
        assert_eq!(io.steps(), 0);
        assert_eq!(io.b_cols[0], PAD_COL);
    }

    #[test]
    fn spgemm_staging_rejects_oversize() {
        let mut io = SpgemmWaveIo::with_geometry(1, 2, 8);
        let cols: &[Idx] = &[0, 1, 2];
        let vals: &[Val] = &[1.0, 1.0, 1.0];
        assert!(io.push_step(0, &[1.0, 1.0, 1.0], &[(cols, vals); 3]).is_err());
    }

    #[test]
    fn cholesky_staging_layout() {
        let mut io = CholeskyStepIo::with_geometry(4, 2);
        io.set_rowk(&[1, 2], &[0.5, 0.25]).unwrap();
        io.set_rowr(1, &[2], &[4.0]).unwrap();
        io.set_a(&[7.0, 8.0], 9.0).unwrap();
        assert_eq!(io.rowk_cols, vec![1, 2, PAD_COL, PAD_COL]);
        assert_eq!(io.rowr_cols[4..6], [2, PAD_COL]);
        assert_eq!(io.a_diag[0], 9.0);
        assert!(io.set_rowr(5, &[], &[]).is_err());
        io.clear();
        assert_eq!(io.rowk_cols, vec![PAD_COL; 4]);
    }
}
