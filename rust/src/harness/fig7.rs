//! Fig 7: percentage of time in CPU preprocessing vs FPGA computation for
//! REAP-32 SpGEMM ("the sum of the two should add up to 100%").
//!
//! Paper shape: FPGA dominates for most matrices; CPU preprocessing
//! exceeds FPGA only on the lowest-density inputs, "where the time spent
//! to extract and organize the non-zero elements is more than the
//! computation time".

use crate::coordinator::{overlap, ReapSpgemm};
use crate::fpga::FpgaConfig;
use crate::util::table::{pct, Table};

use super::report::RunConfig;
use super::suite::spgemm_suite;

/// One matrix row of the figure.
#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub id: String,
    pub name: String,
    pub density: f64,
    pub cpu_pct: f64,
    pub fpga_pct: f64,
    /// End-to-end seconds under per-wave pipelined overlap (the breakdown
    /// percentages describe the *unoverlapped* work split; this column is
    /// what the pipeline actually achieves).
    pub total_s: f64,
    /// Serial (no-overlap) seconds: cpu + fpga.
    pub serial_s: f64,
}

/// Run the figure; also dumps `BENCH_spgemm_fig7.json` when output is
/// enabled (the REAP-32 per-matrix triples behind the percentages).
pub fn run(cfg: &RunConfig) -> (Vec<Fig7Row>, Table) {
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for spec in spgemm_suite() {
        let a = spec.instantiate(cfg.max_rows, cfg.seed);
        let rep = ReapSpgemm::new(cfg.design(FpgaConfig::reap32_spgemm()))
            .strict(true)
            .run(&a, &a)
            .unwrap();
        let cpu_frac = overlap::cpu_fraction(rep.cpu_preprocess_s, rep.fpga_s);
        let id = spec.spgemm_id.unwrap().to_string();
        records.push(super::json::BenchRecord {
            matrix: format!("{} {}", id, spec.name),
            config: "REAP-32".to_string(),
            cpu_s: rep.cpu_preprocess_s,
            fpga_s: rep.fpga_s,
            total_s: rep.total_s,
            waves: rep.fpga_sim.waves,
            cycles_serial: rep.fpga_sim_serial.cycles,
            cycles_db: rep.fpga_sim_db.cycles,
            prefetch_hidden_cycles: rep.fpga_sim_db.prefetch_hidden_cycles,
        });
        rows.push(Fig7Row {
            id,
            name: spec.name.to_string(),
            density: a.density(),
            cpu_pct: cpu_frac,
            fpga_pct: 1.0 - cpu_frac,
            total_s: rep.total_s,
            serial_s: rep.cpu_preprocess_s + rep.fpga_s,
        });
    }
    cfg.dump_bench_json("BENCH_spgemm_fig7", &records).expect("BENCH_spgemm_fig7.json");
    let mut table = Table::new(
        "Fig 7 — REAP-32 SpGEMM time breakdown (CPU preprocess vs FPGA)",
        &["id", "matrix", "density", "CPU %", "FPGA %", "overlapped(ms)", "serial(ms)"],
    );
    for r in &rows {
        table.row(vec![
            r.id.clone(),
            r.name.clone(),
            format!("{:.4}%", r.density * 100.0),
            pct(r.cpu_pct),
            pct(r.fpga_pct),
            format!("{:.3}", r.total_s * 1e3),
            format!("{:.3}", r.serial_s * 1e3),
        ]);
    }
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentages_sum_to_one() {
        let (rows, _) = run(&RunConfig::quick());
        assert_eq!(rows.len(), 20);
        for r in &rows {
            assert!((r.cpu_pct + r.fpga_pct - 1.0).abs() < 1e-9, "{}", r.id);
            assert!((0.0..=1.0).contains(&r.cpu_pct));
            // per-wave pipelining never loses to serial execution
            assert!(r.total_s <= r.serial_s + 1e-9, "{}", r.id);
        }
    }
}
