//! Fig 7: percentage of time in CPU preprocessing vs FPGA computation for
//! REAP-32 SpGEMM ("the sum of the two should add up to 100%").
//!
//! Paper shape: FPGA dominates for most matrices; CPU preprocessing
//! exceeds FPGA only on the lowest-density inputs, "where the time spent
//! to extract and organize the non-zero elements is more than the
//! computation time".

use crate::coordinator::{overlap, ReapSpgemm};
use crate::fpga::FpgaConfig;
use crate::util::table::{pct, Table};

use super::report::RunConfig;
use super::suite::spgemm_suite;

/// One matrix row of the figure.
#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub id: String,
    pub name: String,
    pub density: f64,
    pub cpu_pct: f64,
    pub fpga_pct: f64,
}

/// Run the figure.
pub fn run(cfg: &RunConfig) -> (Vec<Fig7Row>, Table) {
    let mut rows = Vec::new();
    for spec in spgemm_suite() {
        let a = spec.instantiate(cfg.max_rows, cfg.seed);
        let rep = ReapSpgemm::new(FpgaConfig::reap32_spgemm()).run(&a, &a).unwrap();
        let cpu_frac = overlap::cpu_fraction(rep.cpu_preprocess_s, rep.fpga_s);
        rows.push(Fig7Row {
            id: spec.spgemm_id.unwrap().to_string(),
            name: spec.name.to_string(),
            density: a.density(),
            cpu_pct: cpu_frac,
            fpga_pct: 1.0 - cpu_frac,
        });
    }
    let mut table = Table::new(
        "Fig 7 — REAP-32 SpGEMM time breakdown (CPU preprocess vs FPGA)",
        &["id", "matrix", "density", "CPU %", "FPGA %"],
    );
    for r in &rows {
        table.row(vec![
            r.id.clone(),
            r.name.clone(),
            format!("{:.4}%", r.density * 100.0),
            pct(r.cpu_pct),
            pct(r.fpga_pct),
        ]);
    }
    (rows, table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentages_sum_to_one() {
        let (rows, _) = run(&RunConfig::quick());
        assert_eq!(rows.len(), 20);
        for r in &rows {
            assert!((r.cpu_pct + r.fpga_pct - 1.0).abs() < 1e-9, "{}", r.id);
            assert!((0.0..=1.0).contains(&r.cpu_pct));
        }
    }
}
