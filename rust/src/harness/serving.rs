//! Online serving sweep: latency percentiles, sustained throughput and
//! schedule-cache hit rate per design point (the production north-star's
//! online scenario — no paper figure corresponds; EXPERIMENTS.md §Serving
//! documents the methodology).
//!
//! For each design point and repeat ratio the harness generates one
//! Poisson tenant workload and runs it twice through
//! [`run_serving`] — cold (every job pays the CPU scheduling pass) and
//! cached (repeat sparsity patterns hit the fingerprint-keyed
//! [`ScheduleCache`](crate::serving::ScheduleCache)) — and reports
//! p50/p95/p99 latency, jobs/sec, queue depth and hit rate. The headline
//! CI asserts: the cached run replays **bit-identical** schedules (equal
//! digests, equal cycles) while its latency is strictly lower on the wide
//! designs at a high repeat ratio.

use crate::fpga::FpgaConfig;
use crate::serving::{generate_workload, run_serving, ServingConfig, WorkloadSpec};
use crate::util::table::Table;

use super::report::RunConfig;

/// Jobs per workload trace (shared by every design point and mode).
const N_JOBS: usize = 60;
/// Poisson arrival rate, jobs per second.
const RATE_HZ: f64 = 30_000.0;
/// Repeat-ratio sweep: fraction of jobs resubmitting a pool pattern.
const RATIOS: [f64; 3] = [0.0, 0.5, 0.9];

/// One (design point × repeat ratio × cache mode) serving run.
#[derive(Clone, Debug)]
pub struct ServingRow {
    pub config: String,
    pub repeat_ratio: f64,
    /// `true` = schedule cache on; `false` = cold baseline.
    pub cached: bool,
    pub arrived: usize,
    pub admitted: usize,
    pub rejected: usize,
    pub queued: usize,
    /// Nearest-rank latency percentiles over admitted jobs, seconds.
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_s: f64,
    pub jobs_per_s: f64,
    pub queue_depth_mean: f64,
    pub queue_depth_max: usize,
    pub hits: u64,
    pub misses: u64,
    pub hit_rate: f64,
    /// Modeled CPU scheduling seconds summed over batches.
    pub cpu_s: f64,
    /// Simulated FPGA seconds summed over batches.
    pub fpga_s: f64,
    /// Cycle totals at the configured depth / depth 1 / depth 2.
    pub cycles: u64,
    pub cycles_serial: u64,
    pub cycles_db: u64,
    pub prefetch_hidden: u64,
    pub waves: u64,
    /// Structure digest of every composed batch schedule, in order —
    /// cached and cold runs of the same workload must agree exactly.
    pub schedule_digest: u64,
}

/// Run the sweep; returns rows plus the rendered table, and writes
/// `BENCH_serving.json` when output is enabled.
pub fn run(cfg: &RunConfig) -> (Vec<ServingRow>, Table) {
    let mut rows = Vec::new();
    for design in [
        cfg.design(FpgaConfig::reap32_spgemm()),
        cfg.design(FpgaConfig::reap64_spgemm()),
        cfg.design(FpgaConfig::reap128_spgemm()),
    ] {
        for (ri, &ratio) in RATIOS.iter().enumerate() {
            let seed = cfg.seed ^ (0x5E87_1000 + ri as u64);
            let jobs = generate_workload(&WorkloadSpec::poisson(seed, N_JOBS, RATE_HZ, ratio));
            for cached in [false, true] {
                let mut scfg = ServingConfig::new(design.clone());
                scfg.use_cache = cached;
                scfg.strict = true;
                let rep = run_serving(&scfg, &jobs).expect("serving run");
                let cpu_s: f64 = rep.log.batches.iter().map(|b| b.cpu_s).sum();
                let fpga_s: f64 = rep.log.batches.iter().map(|b| b.fpga_s).sum();
                rows.push(ServingRow {
                    config: design.name.to_string(),
                    repeat_ratio: ratio,
                    cached,
                    arrived: rep.log.arrived,
                    admitted: rep.log.admitted,
                    rejected: rep.log.rejected,
                    queued: rep.log.queued,
                    p50_s: rep.p50_s,
                    p95_s: rep.p95_s,
                    p99_s: rep.p99_s,
                    mean_s: rep.mean_s,
                    jobs_per_s: rep.jobs_per_s,
                    queue_depth_mean: rep.queue_depth_mean,
                    queue_depth_max: rep.queue_depth_max,
                    hits: rep.hits,
                    misses: rep.misses,
                    hit_rate: rep.hit_rate,
                    cpu_s,
                    fpga_s,
                    cycles: rep.cycles,
                    cycles_serial: rep.cycles_serial,
                    cycles_db: rep.cycles_db,
                    prefetch_hidden: rep.prefetch_hidden_cycles,
                    waves: rep.waves,
                    schedule_digest: rep.schedule_digest,
                });
            }
        }
    }
    write_bench_json(cfg, &rows);

    let mut table = Table::new(
        "Serving — arrivals, admission, schedule cache (per design × repeat ratio)",
        &[
            "config", "ratio", "mode", "adm", "rej", "p50(us)", "p95(us)", "p99(us)",
            "mean(us)", "jobs/s", "hit%",
        ],
    );
    for r in &rows {
        table.row(vec![
            r.config.clone(),
            format!("{:.1}", r.repeat_ratio),
            if r.cached { "cached" } else { "cold" }.to_string(),
            r.admitted.to_string(),
            r.rejected.to_string(),
            format!("{:.1}", r.p50_s * 1e6),
            format!("{:.1}", r.p95_s * 1e6),
            format!("{:.1}", r.p99_s * 1e6),
            format!("{:.1}", r.mean_s * 1e6),
            format!("{:.0}", r.jobs_per_s),
            format!("{:.0}%", r.hit_rate * 100.0),
        ]);
    }
    (rows, table)
}

/// The serving headline: at the high repeat ratio on the wide designs,
/// the cached run must replay bit-identical schedules (equal digests and
/// cycles — caching changes *when*, never *what*) with a nonzero hit rate
/// and strictly lower mean latency than the cold baseline.
pub fn headline_holds(rows: &[ServingRow]) -> bool {
    ["REAP-64", "REAP-128"].iter().all(|&config| {
        let at = |cached: bool| {
            rows.iter().find(|r| {
                r.config == config && r.repeat_ratio == RATIOS[2] && r.cached == cached
            })
        };
        match (at(false), at(true)) {
            (Some(cold), Some(hot)) => {
                hot.schedule_digest == cold.schedule_digest
                    && hot.cycles == cold.cycles
                    && hot.hit_rate > 0.0
                    && hot.mean_s < cold.mean_s
            }
            _ => false,
        }
    })
}

use super::json::{escape, num};

/// Write `BENCH_serving.json`: one record per (design × ratio × mode) so
/// the online path's latency and cycle trajectory is diffable across PRs
/// alongside the other `BENCH_*.json` files.
fn write_bench_json(cfg: &RunConfig, rows: &[ServingRow]) {
    let Some(dir) = &cfg.csv_dir else {
        return;
    };
    let mut out = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"workload\": \"poisson-{}-r{:.1}\", \"config\": \"{}\", \"mode\": \"{}\", \
             \"cpu_s\": {}, \"fpga_s\": {}, \"p50_s\": {}, \"p95_s\": {}, \"p99_s\": {}, \
             \"mean_s\": {}, \"jobs_per_s\": {}, \"hit_rate\": {:.6}, \"admitted\": {}, \
             \"rejected\": {}, \"queued\": {}, \"waves\": {}, \"cycles_serial\": {}, \
             \"cycles_db\": {}, \"prefetch_hidden_cycles\": {}}}{}\n",
            N_JOBS,
            r.repeat_ratio,
            escape(&r.config),
            if r.cached { "cached" } else { "cold" },
            num(r.cpu_s),
            num(r.fpga_s),
            num(r.p50_s),
            num(r.p95_s),
            num(r.p99_s),
            num(r.mean_s),
            num(r.jobs_per_s),
            r.hit_rate,
            r.admitted,
            r.rejected,
            r.queued,
            r.waves,
            r.cycles_serial,
            r.cycles_db,
            r.prefetch_hidden,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    if let Err(e) = std::fs::create_dir_all(dir)
        .and_then(|()| std::fs::write(dir.join("BENCH_serving.json"), out))
    {
        eprintln!("warning: could not write BENCH_serving.json: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn cache_wins_latency_with_bit_identical_replay() {
        let mut cfg = RunConfig::quick();
        let dir = std::env::temp_dir().join(format!("reap-serving-{}", std::process::id()));
        cfg.csv_dir = Some(dir.clone());
        let (rows, table) = run(&cfg);
        assert_eq!(rows.len(), 18); // 3 designs × 3 ratios × 2 modes
        assert_eq!(table.len(), 18);
        assert!(headline_holds(&rows), "cached replay must win the wide designs: {rows:?}");
        // cached and cold runs of one workload agree on everything but time
        for pair in rows.chunks(2) {
            let (cold, hot) = (&pair[0], &pair[1]);
            assert!(!cold.cached && hot.cached);
            assert_eq!(cold.schedule_digest, hot.schedule_digest, "{}", cold.config);
            assert_eq!(cold.cycles, hot.cycles, "{}", cold.config);
            assert_eq!(cold.admitted, hot.admitted, "{}", cold.config);
            assert!(cold.p50_s <= cold.p95_s && cold.p95_s <= cold.p99_s);
            if cold.repeat_ratio == 0.0 {
                assert_eq!(hot.hits, 0, "fresh-only traffic can never hit");
            }
        }
        let text = std::fs::read_to_string(dir.join("BENCH_serving.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 18);
        assert!(arr[0].get("p99_s").unwrap().as_f64().is_some());
        assert!(arr[0].get("cycles_serial").unwrap().as_usize().is_some());
        assert!(arr[0].get("hit_rate").unwrap().as_f64().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
