//! Batch throughput: many small SpGEMM jobs, shared waves vs N serial
//! runs (the multi-tenant scenario of the production north-star — no
//! paper figure corresponds; EXPERIMENTS.md §Batch-throughput documents
//! the methodology).
//!
//! For each design point the harness runs the same J-job workload twice —
//! once through [`ReapBatch`] (shared, job-tagged waves) and once as J
//! independent [`ReapSpgemm`] runs — and reports simulated pipeline
//! occupancy, cycles and end-to-end time. Batching must win occupancy on
//! the wide (64/128) designs: that is the headline the CI asserts.

use crate::coordinator::{ReapBatch, ReapSpgemm};
use crate::fpga::FpgaConfig;
use crate::sparse::gen::{self, Family};
use crate::sparse::Csr;
use crate::util::table::Table;

use super::report::RunConfig;

/// One (design point × execution mode) comparison row.
#[derive(Clone, Debug)]
pub struct BatchRow {
    pub config: String,
    pub jobs: usize,
    /// Simulated pipeline occupancy, batched / serial.
    pub batch_occupancy: f64,
    pub serial_occupancy: f64,
    /// Simulated FPGA cycles, batched / summed serial.
    pub batch_cycles: u64,
    pub serial_cycles: u64,
    /// End-to-end seconds under per-wave pipelining.
    pub batch_total_s: f64,
    pub serial_total_s: f64,
    /// Shared waves vs summed single-job waves.
    pub batch_waves: u64,
    pub serial_waves: u64,
    /// Measured CPU preprocessing seconds (batched pass).
    pub batch_cpu_s: f64,
    /// Simulated FPGA seconds (batched pass).
    pub batch_fpga_s: f64,
    /// Batched cycles on the serial (depth-1) DRAM channel.
    pub batch_cycles_serial: u64,
    /// Batched cycles on the double-buffered (depth-2) channel.
    pub batch_cycles_db: u64,
    /// Frontend cycles depth 2 hid under compute (batched pass).
    pub batch_prefetch_hidden: u64,
    /// Summed serial-mode cycles at depth 1 / depth 2.
    pub serial_cycles_serial: u64,
    pub serial_cycles_db: u64,
}

/// The many-small-jobs workload: J jobs whose individual chunk counts
/// sit well below the widest design's pipeline count, mixed across
/// pattern families (tenants are heterogeneous).
pub fn small_job_suite(cfg: &RunConfig) -> Vec<(Csr, Csr)> {
    let n_jobs = 24usize;
    (0..n_jobs)
        .map(|j| {
            let n = (28 + (j * 11) % 57).min(cfg.max_rows.max(8));
            let nnz = n * (4 + j % 4);
            let family = match j % 3 {
                0 => Family::RandomUniform,
                1 => Family::PowerLaw,
                _ => Family::BandedFem,
            };
            let seed = cfg.seed ^ (0xBA7C0 + j as u64);
            (
                gen::generate(family, n, nnz, seed),
                gen::generate(Family::RandomUniform, n, nnz, seed + 1),
            )
        })
        .collect()
}

/// Run the comparison; returns rows plus the rendered table, and writes
/// `BENCH_batch.json` when output is enabled.
pub fn run(cfg: &RunConfig) -> (Vec<BatchRow>, Table) {
    let jobs = small_job_suite(cfg);
    let mut rows = Vec::new();
    for design in [
        cfg.design(FpgaConfig::reap32_spgemm()),
        cfg.design(FpgaConfig::reap64_spgemm()),
        cfg.design(FpgaConfig::reap128_spgemm()),
    ] {
        let batch = ReapBatch::new(design.clone()).strict(true).run(&jobs).expect("batch run");
        let mut serial_busy = 0u64;
        let mut serial_slots = 0u64;
        let mut serial_cycles = 0u64;
        let mut serial_total_s = 0.0f64;
        let mut serial_waves = 0u64;
        let mut serial_cycles_serial = 0u64;
        let mut serial_cycles_db = 0u64;
        for (a, b) in &jobs {
            let rep = ReapSpgemm::new(design.clone()).strict(true).run(a, b).expect("serial run");
            serial_busy += rep.fpga_sim.busy_pipeline_cycles;
            serial_slots +=
                rep.fpga_sim.busy_pipeline_cycles + rep.fpga_sim.idle_pipeline_cycles;
            serial_cycles += rep.fpga_sim.cycles;
            serial_total_s += rep.total_s;
            serial_waves += rep.fpga_sim.waves;
            serial_cycles_serial += rep.fpga_sim_serial.cycles;
            serial_cycles_db += rep.fpga_sim_db.cycles;
        }
        rows.push(BatchRow {
            config: design.name.to_string(),
            jobs: jobs.len(),
            batch_occupancy: batch.fpga_sim.pipeline_utilization(),
            serial_occupancy: if serial_slots == 0 {
                0.0
            } else {
                serial_busy as f64 / serial_slots as f64
            },
            batch_cycles: batch.fpga_sim.cycles,
            serial_cycles,
            batch_total_s: batch.total_s,
            serial_total_s,
            batch_waves: batch.fpga_sim.waves,
            serial_waves,
            batch_cpu_s: batch.cpu_preprocess_s,
            batch_fpga_s: batch.fpga_s,
            batch_cycles_serial: batch.fpga_sim_serial.cycles,
            batch_cycles_db: batch.fpga_sim_db.cycles,
            batch_prefetch_hidden: batch.fpga_sim_db.prefetch_hidden_cycles,
            serial_cycles_serial,
            serial_cycles_db,
        });
    }
    write_bench_json(cfg, &rows);

    let mut table = Table::new(
        "Batch throughput — J small SpGEMMs, shared waves vs serial",
        &[
            "config", "jobs", "occ(batch)", "occ(serial)", "cycles(batch)",
            "cycles(serial)", "waves(batch)", "waves(serial)", "speedup",
        ],
    );
    for r in &rows {
        table.row(vec![
            r.config.clone(),
            r.jobs.to_string(),
            format!("{:.1}%", r.batch_occupancy * 100.0),
            format!("{:.1}%", r.serial_occupancy * 100.0),
            r.batch_cycles.to_string(),
            r.serial_cycles.to_string(),
            r.batch_waves.to_string(),
            r.serial_waves.to_string(),
            format!("{:.2}x", r.serial_total_s / r.batch_total_s.max(1e-12)),
        ]);
    }
    (rows, table)
}

/// The multi-tenant headline: on the wide designs (64/128 pipelines) the
/// shared-wave schedule must raise simulated pipeline occupancy *and*
/// lower simulated cycles versus running the jobs serially.
pub fn headline_holds(rows: &[BatchRow]) -> bool {
    rows.iter()
        .filter(|r| r.config != "REAP-32")
        .all(|r| r.batch_occupancy > r.serial_occupancy && r.batch_cycles < r.serial_cycles)
}

use super::json::{escape, num};

/// Write `BENCH_batch.json`: two records per design point (batched and
/// serial mode) so the perf trajectory of the multi-tenant path is
/// diffable across PRs alongside the other `BENCH_*.json` files.
fn write_bench_json(cfg: &RunConfig, rows: &[BatchRow]) {
    let Some(dir) = &cfg.csv_dir else {
        return;
    };
    let mut out = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"workload\": \"many-small-{}\", \"config\": \"{}\", \"mode\": \"batched\", \
             \"cpu_s\": {}, \"fpga_s\": {}, \"total_s\": {}, \"waves\": {}, \
             \"occupancy\": {:.6}, \"cycles_serial\": {}, \"cycles_db\": {}, \
             \"prefetch_hidden_cycles\": {}}},\n",
            r.jobs,
            escape(&r.config),
            num(r.batch_cpu_s),
            num(r.batch_fpga_s),
            num(r.batch_total_s),
            r.batch_waves,
            r.batch_occupancy,
            r.batch_cycles_serial,
            r.batch_cycles_db,
            r.batch_prefetch_hidden,
        ));
        out.push_str(&format!(
            "  {{\"workload\": \"many-small-{}\", \"config\": \"{}\", \"mode\": \"serial\", \
             \"cpu_s\": 0, \"fpga_s\": 0, \"total_s\": {}, \"waves\": {}, \
             \"occupancy\": {:.6}, \"cycles_serial\": {}, \"cycles_db\": {}, \
             \"prefetch_hidden_cycles\": {}}}{}\n",
            r.jobs,
            escape(&r.config),
            num(r.serial_total_s),
            r.serial_waves,
            r.serial_occupancy,
            r.serial_cycles_serial,
            r.serial_cycles_db,
            r.serial_cycles_serial - r.serial_cycles_db,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    if let Err(e) = std::fs::create_dir_all(dir)
        .and_then(|()| std::fs::write(dir.join("BENCH_batch.json"), out))
    {
        eprintln!("warning: could not write BENCH_batch.json: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn batching_wins_occupancy_on_wide_designs() {
        let mut cfg = RunConfig::quick();
        let dir = std::env::temp_dir().join(format!("reap-batch-{}", std::process::id()));
        cfg.csv_dir = Some(dir.clone());
        let (rows, table) = run(&cfg);
        assert_eq!(rows.len(), 3);
        assert_eq!(table.len(), 3);
        assert!(
            headline_holds(&rows),
            "shared waves must beat serial occupancy/cycles on 64/128: {rows:?}"
        );
        let text = std::fs::read_to_string(dir.join("BENCH_batch.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 6); // 3 designs × 2 modes
        assert!(arr[0].get("occupancy").unwrap().as_f64().is_some());
        assert!(arr[0].get("cycles_serial").unwrap().as_usize().is_some());
        // acceptance headline: the double-buffered channel strictly beats
        // the serial one for the batched pass on the wide designs
        for r in &rows {
            assert_eq!(
                r.batch_cycles_db + r.batch_prefetch_hidden,
                r.batch_cycles_serial,
                "{}: hidden cycles must equal the depth-1 gap",
                r.config
            );
            if r.config != "REAP-32" {
                assert!(
                    r.batch_cycles_db < r.batch_cycles_serial,
                    "{}: {} !< {}",
                    r.config,
                    r.batch_cycles_db,
                    r.batch_cycles_serial
                );
                assert!(r.batch_prefetch_hidden > 0, "{}", r.config);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
