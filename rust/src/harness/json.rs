//! Machine-readable perf results: `BENCH_spgemm.json` / `BENCH_cholesky.json`.
//!
//! One flat JSON array of per-(matrix, design-point) records so the perf
//! trajectory is diffable across PRs without parsing ASCII tables. The
//! format is deliberately tiny — parse it back with [`crate::util::json`].

use std::path::Path;

use anyhow::Result;

/// One benchmark record: a matrix × FPGA-design measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Matrix identifier (suite id + name).
    pub matrix: String,
    /// Design-point name (e.g. `REAP-32`).
    pub config: String,
    /// Measured CPU preprocessing/symbolic seconds.
    pub cpu_s: f64,
    /// Simulated FPGA seconds.
    pub fpga_s: f64,
    /// End-to-end seconds under per-wave pipelined overlap.
    pub total_s: f64,
    /// Scheduling waves (SpGEMM/SpMV) or columns (Cholesky).
    pub waves: u64,
    /// Simulated FPGA cycles on the serial (depth-1) DRAM channel.
    pub cycles_serial: u64,
    /// Simulated FPGA cycles on the double-buffered (depth-2) channel.
    pub cycles_db: u64,
    /// Frontend cycles the depth-2 channel hid under compute
    /// (`cycles_db + prefetch_hidden_cycles == cycles_serial`).
    pub prefetch_hidden_cycles: u64,
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
/// Shared with the other hand-rolled writers in this crate (`batch`).
pub(crate) fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render one JSON number: `{:e}` for finite values, `0` for non-finite
/// (JSON has no NaN/inf). Shared by every hand-rolled BENCH writer.
pub(crate) fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:e}")
    } else {
        "0".to_string()
    }
}

/// Render records as a JSON array (stable field order, one record per line).
pub fn render_bench(records: &[BenchRecord]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"matrix\": \"{}\", \"config\": \"{}\", \"cpu_s\": {}, \
             \"fpga_s\": {}, \"total_s\": {}, \"waves\": {}, \
             \"cycles_serial\": {}, \"cycles_db\": {}, \
             \"prefetch_hidden_cycles\": {}}}{}\n",
            escape(&r.matrix),
            escape(&r.config),
            num(r.cpu_s),
            num(r.fpga_s),
            num(r.total_s),
            r.waves,
            r.cycles_serial,
            r.cycles_db,
            r.prefetch_hidden_cycles,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    out
}

/// Write records as JSON to `path` (creating parent directories).
pub fn write_bench(path: &Path, records: &[BenchRecord]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, render_bench(records))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn sample() -> Vec<BenchRecord> {
        vec![
            BenchRecord {
                matrix: "wb \"edu\"".into(),
                config: "REAP-32".into(),
                cpu_s: 1.5e-3,
                fpga_s: 2.5e-3,
                total_s: 3.0e-3,
                waves: 42,
                cycles_serial: 1000,
                cycles_db: 900,
                prefetch_hidden_cycles: 100,
            },
            BenchRecord {
                matrix: "m2".into(),
                config: "REAP-64".into(),
                cpu_s: 0.0,
                fpga_s: 1.0,
                total_s: 1.0,
                waves: 0,
                cycles_serial: 0,
                cycles_db: 0,
                prefetch_hidden_cycles: 0,
            },
        ]
    }

    #[test]
    fn renders_parseable_json() {
        let text = render_bench(&sample());
        let j = Json::parse(&text).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("matrix").unwrap().as_str(), Some("wb \"edu\""));
        assert_eq!(arr[0].get("config").unwrap().as_str(), Some("REAP-32"));
        assert!((arr[0].get("cpu_s").unwrap().as_f64().unwrap() - 1.5e-3).abs() < 1e-12);
        assert_eq!(arr[1].get("waves").unwrap().as_usize(), Some(0));
        assert_eq!(arr[0].get("cycles_serial").unwrap().as_usize(), Some(1000));
        assert_eq!(arr[0].get("cycles_db").unwrap().as_usize(), Some(900));
        assert_eq!(arr[0].get("prefetch_hidden_cycles").unwrap().as_usize(), Some(100));
    }

    #[test]
    fn empty_record_list_is_empty_array() {
        let j = Json::parse(&render_bench(&[])).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 0);
    }

    #[test]
    fn write_roundtrip() {
        let dir = std::env::temp_dir().join(format!("reap-json-{}", std::process::id()));
        let path = dir.join("BENCH_test.json");
        write_bench(&path, &sample()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}
