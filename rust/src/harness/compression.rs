//! Compressed + mixed-precision RIR streams: bytes-per-nnz and end-to-end
//! cycle pricing per [`StreamEncoding`] (no paper figure; EXPERIMENTS.md
//! §Compression documents the methodology).
//!
//! The workloads are **bandwidth-bound by construction**: wide rectangular
//! SpMM (`nrows ≪ ncols`, k = 8 dense right-hand sides) where the dense
//! panel load dominates the cycle count, so a smaller wire format converts
//! directly into fewer cycles. For each design point × matrix × encoding
//! the harness runs [`ReapSpmm`] under the negotiated encoding and reports
//! simulated input traffic (normalized to bytes per nonzero of A), the
//! serial (depth-1) and double-buffered (depth-2) channel cycles, and the
//! worst value error of a **real wire round-trip** of A's RIR stream —
//! serialized with [`layout::serialize_stream_encoded`], decoded with
//! [`layout::try_deserialize`], compared element-wise against the f32
//! reference. Bitmap metadata compression is exact (zero error); the
//! Q1.15 fixed-point value lanes must stay within the per-bundle bound
//! [`layout::fx_max_abs_error`] derives.
//!
//! The headline CI asserts: on the wide (64/128) designs every compressed
//! encoding moves strictly fewer DRAM bytes **and** retires in strictly
//! fewer cycles than raw on *both* channels — bytes are cycles now.

use crate::coordinator::ReapSpmm;
use crate::fpga::FpgaConfig;
use crate::rir::bundle::Payload;
use crate::rir::encode::BundleStream;
use crate::rir::layout::{self, StreamEncoding};
use crate::sparse::{gen, Csr, Val};
use crate::util::table::Table;

use super::report::RunConfig;

/// One (design point × matrix × encoding) pricing row.
#[derive(Clone, Debug)]
pub struct CompressionRow {
    pub config: String,
    pub matrix: String,
    /// Encoding token (`raw | bitmap | fx32 | bitmap+fx32`).
    pub encoding: String,
    /// Nonzeros of A.
    pub nnz: usize,
    /// Simulated DRAM bytes read (A stream + dense panel, encoded).
    pub bytes_read: u64,
    /// `bytes_read / nnz` — the normalized traffic metric the
    /// EXPERIMENTS.md table reports.
    pub bytes_per_nnz: f64,
    /// Cycles at the run's configured channel depth.
    pub cycles: u64,
    /// Cycles on the serial depth-1 channel.
    pub cycles_serial: u64,
    /// Cycles on the double-buffered depth-2 channel.
    pub cycles_db: u64,
    /// Frontend cycles depth 2 hid under compute.
    pub prefetch_hidden: u64,
    pub fpga_s: f64,
    pub total_s: f64,
    /// Max |decoded − reference| over a real wire round-trip of A's RIR
    /// stream under this encoding (exactly 0 for raw and bitmap).
    pub max_abs_err: f64,
    /// The documented worst-case bound for the lossy lanes (max over
    /// bundles of [`layout::fx_max_abs_error`]; 0 for lossless encodings).
    pub err_bound: f64,
}

/// The bandwidth-bound workloads: two wide rectangular matrices whose
/// dense-panel load dominates the wave pipeline (~8 and ~16 nnz per row
/// over thousands of columns). `max_rows` caps the row count as usual.
pub fn workloads(cfg: &RunConfig) -> Vec<(&'static str, Csr)> {
    let r1 = cfg.max_rows.clamp(16, 64);
    let r2 = cfg.max_rows.clamp(16, 96);
    vec![
        ("wide-8pr", gen::random_uniform(r1, 4800, r1 * 8, cfg.seed ^ 0xC0DE)),
        ("wide-16pr", gen::random_uniform(r2, 6400, r2 * 16, cfg.seed ^ 0xFACE)),
    ]
}

/// Worst value error (and the documented bound) of serializing A's RIR
/// stream under `enc` and decoding it back — the decoders expand and strip
/// the compression flags, so the comparison is element-wise against the
/// original f32 values in bundle order.
fn stream_roundtrip_err(a: &Csr, bundle_size: usize, enc: StreamEncoding) -> (f64, f64) {
    let s = BundleStream::from_csr(a, bundle_size);
    let words = layout::serialize_stream_encoded(&s, enc, false);
    let decoded = layout::try_deserialize(&words).expect("encoded stream must round-trip");
    assert_eq!(decoded.len(), s.n_bundles(), "bundle count must survive the wire");
    let mut err = 0f64;
    let mut bound = 0f64;
    for (b, d) in s.iter().zip(&decoded) {
        if enc.fx() && !b.vals.is_empty() {
            let scale = b.vals.iter().fold(0f32, |m, &v| m.max(v.abs()));
            bound = bound.max(layout::fx_max_abs_error(scale));
        }
        match &d.payload {
            Payload::Data { values, .. } => {
                for (&v, &w) in b.vals.iter().zip(values) {
                    err = err.max((f64::from(v) - f64::from(w)).abs());
                }
            }
            Payload::Schedule { .. } => {}
        }
    }
    (err, bound)
}

/// Run the pricing sweep; returns rows plus the rendered table, and writes
/// `BENCH_compression.json` when output is enabled.
pub fn run(cfg: &RunConfig) -> (Vec<CompressionRow>, Table) {
    const K: usize = 8; // = vector_lanes on every preset: one full block
    let encodings = [
        StreamEncoding::Raw,
        StreamEncoding::Bitmap,
        StreamEncoding::Fx,
        StreamEncoding::BitmapFx,
    ];
    let mut rows = Vec::new();
    for design in [
        cfg.design(FpgaConfig::reap32_spgemm()),
        cfg.design(FpgaConfig::reap64_spgemm()),
        cfg.design(FpgaConfig::reap128_spgemm()),
    ] {
        for (mname, a) in workloads(cfg) {
            let x: Vec<Val> = (0..a.ncols * K)
                .map(|i| (((i as u64).wrapping_mul(2654435761) % 31) as f32 - 15.0) * 0.0625)
                .collect();
            for enc in encodings {
                let dp = FpgaConfig { encoding: enc, ..design.clone() };
                let rep = ReapSpmm::new(dp.clone()).strict(true).run(&a, &x, K).expect("spmm run");
                let (max_abs_err, err_bound) = stream_roundtrip_err(&a, dp.bundle_size, enc);
                rows.push(CompressionRow {
                    config: design.name.to_string(),
                    matrix: mname.to_string(),
                    encoding: enc.to_string(),
                    nnz: a.nnz(),
                    bytes_read: rep.fpga_sim.bytes_read,
                    bytes_per_nnz: rep.fpga_sim.bytes_read as f64 / a.nnz() as f64,
                    cycles: rep.fpga_sim.cycles,
                    cycles_serial: rep.fpga_sim_serial.cycles,
                    cycles_db: rep.fpga_sim_db.cycles,
                    prefetch_hidden: rep.fpga_sim_db.prefetch_hidden_cycles,
                    fpga_s: rep.fpga_s,
                    total_s: rep.total_s,
                    max_abs_err,
                    err_bound,
                });
            }
        }
    }
    write_bench_json(cfg, &rows);

    let mut table = Table::new(
        "Compressed RIR streams — encoded wire size priced end-to-end (SpMM, k=8)",
        &[
            "config", "matrix", "encoding", "B/nnz", "cycles(d1)", "cycles(d2)", "MB-read",
            "max|err|",
        ],
    );
    for r in &rows {
        table.row(vec![
            r.config.clone(),
            r.matrix.clone(),
            r.encoding.clone(),
            format!("{:.2}", r.bytes_per_nnz),
            r.cycles_serial.to_string(),
            r.cycles_db.to_string(),
            format!("{:.3}", r.bytes_read as f64 / 1e6),
            format!("{:.1e}", r.max_abs_err),
        ]);
    }
    (rows, table)
}

/// The compression headline: every encoding obeys its error contract
/// (lossless encodings exactly zero, fixed-point within the documented
/// per-bundle bound), and on the wide (64/128) designs every compressed
/// encoding moves strictly fewer DRAM bytes and costs strictly fewer
/// cycles than raw on **both** the serial and double-buffered channels.
pub fn headline_holds(rows: &[CompressionRow]) -> bool {
    for r in rows {
        let lossless = r.encoding == "raw" || r.encoding == "bitmap";
        if lossless && r.max_abs_err != 0.0 {
            return false;
        }
        if !lossless && r.max_abs_err > r.err_bound {
            return false;
        }
    }
    for raw in rows.iter().filter(|r| r.encoding == "raw" && r.config != "REAP-32") {
        let wins = rows
            .iter()
            .filter(|r| {
                r.config == raw.config && r.matrix == raw.matrix && r.encoding != "raw"
            })
            .all(|r| {
                r.bytes_read < raw.bytes_read
                    && r.cycles_serial < raw.cycles_serial
                    && r.cycles_db < raw.cycles_db
            });
        if !wins {
            return false;
        }
    }
    true
}

use super::json::{escape, num};

/// Write `BENCH_compression.json`: one record per (design point, matrix,
/// encoding) alongside the other `BENCH_*.json` trajectory files. The
/// perf-regression gate sums `cycles_serial` and `cycles_db` across these
/// records, so a pricing regression in any encoding fails CI.
fn write_bench_json(cfg: &RunConfig, rows: &[CompressionRow]) {
    let Some(dir) = &cfg.csv_dir else {
        return;
    };
    let mut out = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"workload\": \"compression-{}\", \"config\": \"{}\", \"encoding\": \"{}\", \
             \"nnz\": {}, \"bytes_read\": {}, \"bytes_per_nnz\": {}, \
             \"cycles_serial\": {}, \"cycles_db\": {}, \"prefetch_hidden_cycles\": {}, \
             \"max_abs_err\": {}, \"err_bound\": {}, \"fpga_s\": {}, \"total_s\": {}}}{}\n",
            escape(&r.matrix),
            escape(&r.config),
            escape(&r.encoding),
            r.nnz,
            r.bytes_read,
            num(r.bytes_per_nnz),
            r.cycles_serial,
            r.cycles_db,
            r.prefetch_hidden,
            num(r.max_abs_err),
            num(r.err_bound),
            num(r.fpga_s),
            num(r.total_s),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    if let Err(e) = std::fs::create_dir_all(dir)
        .and_then(|()| std::fs::write(dir.join("BENCH_compression.json"), out))
    {
        eprintln!("warning: could not write BENCH_compression.json: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn compressed_streams_win_bytes_and_cycles_on_wide_designs() {
        let mut cfg = RunConfig::quick();
        let dir = std::env::temp_dir().join(format!("reap-compression-{}", std::process::id()));
        cfg.csv_dir = Some(dir.clone());
        let (rows, table) = run(&cfg);
        assert_eq!(rows.len(), 24); // 3 designs × 2 matrices × 4 encodings
        assert_eq!(table.len(), 24);
        assert!(
            headline_holds(&rows),
            "compressed encodings must strictly win bytes AND cycles on 64/128: {rows:?}"
        );
        for r in &rows {
            // the wire round-trip error contract, row by row
            match r.encoding.as_str() {
                "raw" | "bitmap" => {
                    assert_eq!(r.max_abs_err, 0.0, "{} {} {}", r.config, r.matrix, r.encoding);
                    assert_eq!(r.err_bound, 0.0, "{} {} {}", r.config, r.matrix, r.encoding);
                }
                _ => {
                    assert!(r.err_bound > 0.0, "{} {}", r.config, r.matrix);
                    assert!(
                        r.max_abs_err <= r.err_bound,
                        "{} {} {}: {} > bound {}",
                        r.config,
                        r.matrix,
                        r.encoding,
                        r.max_abs_err,
                        r.err_bound
                    );
                }
            }
            // the depth ledger stays exact under every encoding
            assert_eq!(
                r.cycles_db + r.prefetch_hidden,
                r.cycles_serial,
                "{} {} {}: hidden cycles must equal the depth-1 gap",
                r.config,
                r.matrix,
                r.encoding
            );
        }
        let text = std::fs::read_to_string(dir.join("BENCH_compression.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 24);
        assert!(arr[0].get("bytes_per_nnz").unwrap().as_f64().is_some());
        assert!(arr[0].get("cycles_serial").unwrap().as_usize().is_some());
        assert!(arr[0].get("cycles_db").unwrap().as_usize().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
