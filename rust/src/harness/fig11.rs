//! Fig 11: percentage of time in CPU symbolic analysis vs FPGA computation
//! for REAP-32 sparse Cholesky.
//!
//! Paper shape: "FPGA execution time significantly dominates the CPU
//! execution time for Cholesky" — all the numeric work is on the FPGA,
//! the CPU does only (non-FP) symbolic analysis.

use crate::coordinator::{overlap, ReapCholesky};
use crate::fpga::FpgaConfig;
use crate::util::table::{pct, Table};

use super::report::RunConfig;
use super::suite::cholesky_suite;

/// One matrix row of the figure.
#[derive(Clone, Debug)]
pub struct Fig11Row {
    pub id: String,
    pub name: String,
    pub cpu_pct: f64,
    pub fpga_pct: f64,
    /// End-to-end seconds under per-column pipelined overlap.
    pub total_s: f64,
    /// Serial (no-overlap) seconds: cpu symbolic + fpga.
    pub serial_s: f64,
}

/// Run the figure; also dumps `BENCH_cholesky_fig11.json` when output is
/// enabled.
pub fn run(cfg: &RunConfig) -> (Vec<Fig11Row>, Table) {
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for spec in cholesky_suite() {
        let lower = spec.instantiate_spd(cfg.max_rows, cfg.seed);
        let rep = ReapCholesky::new(cfg.design(FpgaConfig::reap32_cholesky()))
            .strict(true)
            .run(&lower)
            .unwrap();
        let cpu_frac = overlap::cpu_fraction(rep.cpu_symbolic_s, rep.fpga_s);
        let id = spec.cholesky_id.unwrap().to_string();
        records.push(super::json::BenchRecord {
            matrix: format!("{} {}", id, spec.name),
            config: "REAP-32".to_string(),
            cpu_s: rep.cpu_symbolic_s,
            fpga_s: rep.fpga_s,
            total_s: rep.total_s,
            waves: rep.fpga_sim.waves,
            cycles_serial: rep.fpga_sim_serial.cycles,
            cycles_db: rep.fpga_sim_db.cycles,
            prefetch_hidden_cycles: rep.fpga_sim_db.prefetch_hidden_cycles,
        });
        rows.push(Fig11Row {
            id,
            name: spec.name.to_string(),
            cpu_pct: cpu_frac,
            fpga_pct: 1.0 - cpu_frac,
            total_s: rep.total_s,
            serial_s: rep.cpu_symbolic_s + rep.fpga_s,
        });
    }
    cfg.dump_bench_json("BENCH_cholesky_fig11", &records).expect("BENCH_cholesky_fig11.json");
    let mut table = Table::new(
        "Fig 11 — REAP-32 Cholesky time breakdown (CPU symbolic vs FPGA)",
        &["id", "matrix", "CPU %", "FPGA %", "overlapped(ms)", "serial(ms)"],
    );
    for r in &rows {
        table.row(vec![
            r.id.clone(),
            r.name.clone(),
            pct(r.cpu_pct),
            pct(r.fpga_pct),
            format!("{:.3}", r.total_s * 1e3),
            format!("{:.3}", r.serial_s * 1e3),
        ]);
    }
    (rows, table)
}

/// Paper's claim: the FPGA dominates on (at least almost) every matrix.
pub fn headline_holds(rows: &[Fig11Row]) -> bool {
    let dominated = rows.iter().filter(|r| r.fpga_pct > 0.5).count();
    dominated * 10 >= rows.len() * 8 // ≥ 80% of the suite
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_sums_to_one() {
        let mut cfg = RunConfig::quick();
        cfg.max_rows = 300;
        let (rows, _) = run(&cfg);
        assert_eq!(rows.len(), 8);
        for r in &rows {
            assert!((r.cpu_pct + r.fpga_pct - 1.0).abs() < 1e-9);
            assert!(r.total_s <= r.serial_s + 1e-9);
        }
    }
}
