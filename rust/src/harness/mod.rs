//! Benchmark harness: one module per table/figure of the paper's
//! evaluation (§V). Every module exposes `run(&RunConfig)` returning the
//! raw rows plus a rendered [`crate::util::table::Table`], and a
//! `headline_holds` predicate encoding the paper's qualitative claim so
//! tests and EXPERIMENTS.md can assert the reproduced *shape*.
//!
//! | module | paper artifact |
//! |---|---|
//! | [`tables`] | Table I (suite), Table II (platforms) |
//! | [`fig6`] | SpGEMM speedups vs CPU |
//! | [`fig7`] | SpGEMM CPU/FPGA breakdown |
//! | [`fig8`] | GFLOPS per FP unit + area/frequency scaling |
//! | [`fig9`] | sensitivity to sparsity |
//! | [`fig10`] | Cholesky speedups vs CHOLMOD |
//! | [`fig11`] | Cholesky CPU/FPGA breakdown |
//! | [`hls_cmp`] | §V-C HLS preprocessing benefit |
//! | [`batch`] | multi-tenant batch throughput (no paper figure) |
//! | [`spmm`] | SpMM multi-vector vs k serial SpMVs (no paper figure) |
//! | [`reliability`] | checksummed-stream fault sweep (no paper figure) |
//! | [`compression`] | encoded-stream pricing: bytes-per-nnz vs cycles (no paper figure) |
//! | [`serving`] | online serving: admission, latency percentiles, schedule cache (no paper figure) |
//! | [`scaling`] | CPU-pass thread scaling: static bands vs work-stealing grains (no paper figure) |

pub mod batch;
pub mod compression;
pub mod fig10;
pub mod fig11;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod hls_cmp;
pub mod json;
pub mod reliability;
pub mod report;
pub mod scaling;
pub mod serving;
pub mod spmm;
pub mod suite;
pub mod tables;

pub use json::BenchRecord;
pub use report::RunConfig;
