//! §V-C: REAP with OpenCL HLS — preprocessing benefit under an HLS
//! toolchain.
//!
//! Paper: "the version of REAP with HLS outperforms the HLS version
//! without any CPU preprocessing for all benchmarks and with a geometric
//! mean of 16% and 35% for SpGEMM and Cholesky, respectively".

use crate::fpga::hls::{compare_cholesky_hls, compare_spgemm_hls};
use crate::symbolic::CholeskySymbolic;
use crate::util::stats::geomean;
use crate::util::table::{pct, Table};

use super::report::RunConfig;
use super::suite::{cholesky_suite, spgemm_suite};

/// Per-kernel results: (id, gain) per matrix plus the geomean.
#[derive(Clone, Debug)]
pub struct HlsReport {
    pub spgemm_gains: Vec<(String, f64)>,
    pub cholesky_gains: Vec<(String, f64)>,
    pub spgemm_geomean: f64,
    pub cholesky_geomean: f64,
}

/// Run the comparison over both suites.
pub fn run(cfg: &RunConfig) -> (HlsReport, Table) {
    let mut spgemm_gains = Vec::new();
    for spec in spgemm_suite() {
        let a = spec.instantiate(cfg.max_rows, cfg.seed);
        let cmp = compare_spgemm_hls(&a);
        spgemm_gains.push((spec.spgemm_id.unwrap().to_string(), cmp.preprocessing_gain()));
    }
    let mut cholesky_gains = Vec::new();
    for spec in cholesky_suite() {
        let lower = spec.instantiate_spd(cfg.max_rows, cfg.seed);
        let sym = CholeskySymbolic::analyze(&lower, 32);
        let cmp = compare_cholesky_hls(&sym);
        cholesky_gains.push((spec.cholesky_id.unwrap().to_string(), cmp.preprocessing_gain()));
    }
    let gm = |v: &[(String, f64)]| {
        geomean(&v.iter().map(|(_, g)| 1.0 + g).collect::<Vec<_>>()).map(|g| g - 1.0)
    };
    let report = HlsReport {
        spgemm_geomean: gm(&spgemm_gains).unwrap_or(0.0),
        cholesky_geomean: gm(&cholesky_gains).unwrap_or(0.0),
        spgemm_gains,
        cholesky_gains,
    };

    let mut table = Table::new(
        "§V-C — HLS preprocessing benefit (REAP-HLS vs plain HLS)",
        &["kernel", "matrix", "gain"],
    );
    for (id, g) in &report.spgemm_gains {
        table.row(vec!["SpGEMM".into(), id.clone(), pct(*g)]);
    }
    for (id, g) in &report.cholesky_gains {
        table.row(vec!["Cholesky".into(), id.clone(), pct(*g)]);
    }
    table.row(vec!["SpGEMM".into(), "geomean".into(), pct(report.spgemm_geomean)]);
    table.row(vec![
        "Cholesky".into(),
        "geomean".into(),
        pct(report.cholesky_geomean),
    ]);
    (report, table)
}

/// Paper's claim: preprocessing helps every benchmark, and helps Cholesky
/// more than SpGEMM (35% vs 16%).
pub fn headline_holds(r: &HlsReport) -> bool {
    r.spgemm_gains.iter().all(|(_, g)| *g > 0.0)
        && r.cholesky_gains.iter().all(|(_, g)| *g > 0.0)
        && r.cholesky_geomean > r.spgemm_geomean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gains_positive_everywhere() {
        let mut cfg = RunConfig::quick();
        cfg.max_rows = 300;
        let (rep, table) = run(&cfg);
        assert_eq!(rep.spgemm_gains.len(), 20);
        assert_eq!(rep.cholesky_gains.len(), 8);
        assert!(table.len() >= 30);
        assert!(rep.spgemm_gains.iter().all(|(_, g)| *g > 0.0));
        assert!(rep.cholesky_gains.iter().all(|(_, g)| *g > 0.0));
    }
}
