//! Fig 6: SpGEMM speedup of REAP designs and multi-core CPU versions
//! relative to Intel MKL (stand-in) on a single core.
//!
//! Paper's headline shapes: REAP-32 beats CPU-1 on *all* matrices, geomean
//! ≈ 3.2×; REAP-32 beats CPU-2 on most; REAP-64 beats CPU-16 on about
//! half; REAP-128 beats CPU-16 on all but ~3.

use crate::coordinator::ReapSpgemm;
use crate::fpga::FpgaConfig;
use crate::util::stats::geomean;
use crate::util::table::{speedup, Table};

use super::json::BenchRecord;
use super::report::{measure_spgemm_cpu, RunConfig};
use super::suite::spgemm_suite;

/// One matrix row of the figure.
#[derive(Clone, Debug)]
pub struct Fig6Row {
    pub id: String,
    pub name: String,
    pub cpu1_s: f64,
    /// Speedups vs CPU-1, keyed like the paper's series.
    pub cpu2: f64,
    pub cpu16: f64,
    pub reap32: f64,
    pub reap64: f64,
    pub reap128: f64,
}

/// Run the figure; returns rows plus the rendered table. Speedups use the
/// coordinators' per-wave pipelined `total_s`; when output is enabled the
/// underlying (cpu, fpga, total) triples land in `BENCH_spgemm.json`.
pub fn run(cfg: &RunConfig) -> (Vec<Fig6Row>, Table) {
    let mut rows = Vec::new();
    let mut records: Vec<BenchRecord> = Vec::new();
    for spec in spgemm_suite() {
        let a = spec.instantiate(cfg.max_rows, cfg.seed);
        // paper protocol: C = A^2
        let cpu1 = measure_spgemm_cpu(cfg, &a, &a, 1).min_s;
        let cpu2 = measure_spgemm_cpu(cfg, &a, &a, 2).min_s;
        let cpu16 = measure_spgemm_cpu(cfg, &a, &a, 16).min_s;
        let r32 = ReapSpgemm::new(cfg.design(FpgaConfig::reap32_spgemm()))
            .strict(true)
            .run(&a, &a)
            .unwrap();
        let r64 = ReapSpgemm::new(cfg.design(FpgaConfig::reap64_spgemm()))
            .strict(true)
            .run(&a, &a)
            .unwrap();
        let r128 = ReapSpgemm::new(cfg.design(FpgaConfig::reap128_spgemm()))
            .strict(true)
            .run(&a, &a)
            .unwrap();
        let id = spec.spgemm_id.unwrap().to_string();
        let matrix = format!("{} {}", id, spec.name);
        for (config, rep) in [("REAP-32", &r32), ("REAP-64", &r64), ("REAP-128", &r128)] {
            records.push(BenchRecord {
                matrix: matrix.clone(),
                config: config.to_string(),
                cpu_s: rep.cpu_preprocess_s,
                fpga_s: rep.fpga_s,
                total_s: rep.total_s,
                waves: rep.fpga_sim.waves,
                cycles_serial: rep.fpga_sim_serial.cycles,
                cycles_db: rep.fpga_sim_db.cycles,
                prefetch_hidden_cycles: rep.fpga_sim_db.prefetch_hidden_cycles,
            });
        }
        rows.push(Fig6Row {
            id,
            name: spec.name.to_string(),
            cpu1_s: cpu1,
            cpu2: cpu1 / cpu2,
            cpu16: cpu1 / cpu16,
            reap32: cpu1 / r32.total_s,
            reap64: cpu1 / r64.total_s,
            reap128: cpu1 / r128.total_s,
        });
    }
    cfg.dump_bench_json("BENCH_spgemm", &records).expect("BENCH_spgemm.json");

    let mut table = Table::new(
        "Fig 6 — SpGEMM speedup vs MKL-class CPU-1 (C = A^2)",
        &["id", "matrix", "CPU-2", "CPU-16", "REAP-32", "REAP-64", "REAP-128"],
    );
    for r in &rows {
        table.row(vec![
            r.id.clone(),
            r.name.clone(),
            speedup(r.cpu2),
            speedup(r.cpu16),
            speedup(r.reap32),
            speedup(r.reap64),
            speedup(r.reap128),
        ]);
    }
    let gm = |f: fn(&Fig6Row) -> f64| {
        geomean(&rows.iter().map(f).collect::<Vec<_>>()).unwrap_or(0.0)
    };
    table.row(vec![
        "GM".into(),
        "geomean".into(),
        speedup(gm(|r| r.cpu2)),
        speedup(gm(|r| r.cpu16)),
        speedup(gm(|r| r.reap32)),
        speedup(gm(|r| r.reap64)),
        speedup(gm(|r| r.reap128)),
    ]);
    (rows, table)
}

/// Headline checks the paper makes about this figure (used by tests and
/// EXPERIMENTS.md): REAP-32 > CPU-1 everywhere, and geomeans ordered
/// REAP-128 > REAP-64 > REAP-32 > 1.
pub fn headline_holds(rows: &[Fig6Row]) -> bool {
    let all_beat_cpu1 = rows.iter().all(|r| r.reap32 > 1.0);
    let gm32 = geomean(&rows.iter().map(|r| r.reap32).collect::<Vec<_>>()).unwrap_or(0.0);
    let gm64 = geomean(&rows.iter().map(|r| r.reap64).collect::<Vec<_>>()).unwrap_or(0.0);
    let gm128 = geomean(&rows.iter().map(|r| r.reap128).collect::<Vec<_>>()).unwrap_or(0.0);
    all_beat_cpu1 && gm32 > 1.0 && gm64 > gm32 && gm128 > gm64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_produces_full_suite_and_bench_json() {
        let mut cfg = RunConfig::quick();
        let dir = std::env::temp_dir().join(format!("reap-fig6-{}", std::process::id()));
        cfg.csv_dir = Some(dir.clone());
        let (rows, table) = run(&cfg);
        assert_eq!(rows.len(), 20);
        assert_eq!(table.len(), 21); // + geomean row
        for r in &rows {
            assert!(r.cpu1_s > 0.0);
            assert!(r.reap32.is_finite() && r.reap32 > 0.0);
        }
        let text = std::fs::read_to_string(dir.join("BENCH_spgemm.json")).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 60); // 20 matrices × 3 designs
        // serial vs double-buffered cycles ride every record; on the wide
        // designs the prefetch is a strict aggregate win (the acceptance
        // headline for the unified wave engine)
        let mut serial_sum = 0u64;
        let mut db_sum = 0u64;
        for rec in arr {
            let serial = rec.get("cycles_serial").unwrap().as_usize().unwrap() as u64;
            let db = rec.get("cycles_db").unwrap().as_usize().unwrap() as u64;
            let hidden =
                rec.get("prefetch_hidden_cycles").unwrap().as_usize().unwrap() as u64;
            assert_eq!(db + hidden, serial, "hidden cycles must equal the depth-1 gap");
            if rec.get("config").unwrap().as_str() != Some("REAP-32") {
                serial_sum += serial;
                db_sum += db;
            }
        }
        assert!(
            db_sum < serial_sum,
            "double buffering must strictly win on REAP-64/128: {db_sum} !< {serial_sum}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
