//! Tables I and II of the paper, rendered from this build's registry and
//! design points.

use crate::fpga::{DramConfig, FpgaConfig};
use crate::util::table::{count, Table};

use super::report::RunConfig;
use super::suite::TABLE1;

/// Table I: the matrix suite with this run's instantiated clones.
pub fn table1(cfg: &RunConfig) -> Table {
    let mut t = Table::new(
        "Table I — SuiteSparse matrices and their synthetic clones",
        &["name", "SpGEMM", "Cholesky", "rows", "NNZ (density)", "family", "clone rows", "clone NNZ"],
    );
    for spec in TABLE1 {
        let (rows, _) = spec.scaled(cfg.max_rows);
        let clone = spec.instantiate(cfg.max_rows, cfg.seed);
        t.row(vec![
            spec.name.into(),
            spec.spgemm_id.unwrap_or("-").into(),
            spec.cholesky_id.unwrap_or("-").into(),
            count(spec.rows),
            format!("{}({:.3}%)", count(spec.nnz), spec.density() * 100.0),
            spec.family.to_string(),
            count(rows),
            count(clone.nnz()),
        ]);
    }
    t
}

/// Table II: platform configuration (paper's, plus this build's stand-ins).
pub fn table2() -> Table {
    let mut t = Table::new("Table II — platform configuration", &["platform", "configuration"]);
    t.row(vec![
        "CPU (paper)".into(),
        "Intel Xeon 6130, 16 cores, 2.1 GHz, 32 GB DDR4-2666".into(),
    ]);
    t.row(vec![
        "CPU (this run)".into(),
        format!("{} hardware threads (measured baselines)", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)),
    ]);
    t.row(vec![
        "FPGA (paper)".into(),
        "DE5net Arria-10: 1150K LEs, 67 Mbit on-chip, 8 GB DDR3-933, 1518 DSPs".into(),
    ]);
    for c in [FpgaConfig::reap32_spgemm(), FpgaConfig::reap64_spgemm(), FpgaConfig::reap128_spgemm()] {
        t.row(vec![
            format!("{} (sim)", c.name),
            format!(
                "{} pipelines @ {} MHz, bundle {}, DRAM {}/{} GB/s r/w",
                c.pipelines, c.freq_mhz, c.bundle_size, c.dram.read_gbps, c.dram.write_gbps
            ),
        ]);
    }
    let single = DramConfig::single_core();
    let peak = DramConfig::sixteen_core_peak();
    t.row(vec![
        "DRAM caps".into(),
        format!(
            "single-core {} GB/s; 16-core peak {}/{} GB/s r/w (pmbw-measured in the paper)",
            single.read_gbps, peak.read_gbps, peak.write_gbps
        ),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_all_rows() {
        let t = table1(&RunConfig::quick());
        assert_eq!(t.len(), 24);
    }

    #[test]
    fn table2_mentions_design_points() {
        let t = table2();
        let s = t.render();
        assert!(s.contains("REAP-32"));
        assert!(s.contains("REAP-128"));
        assert!(s.contains("147"));
    }
}
