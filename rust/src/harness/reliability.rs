//! Reliability: fault-rate sweep over the checksummed RIR stream path
//! and the engine's wave-retry model (no paper figure corresponds;
//! EXPERIMENTS.md §Reliability documents the methodology).
//!
//! Two studies per fault rate, both seed-deterministic:
//!
//! * **Detection** — serialized RIR streams are corrupted with per-word
//!   bit flips ([`FaultInjector`]); each corrupted stream is decoded
//!   twice, once from the checksummed form
//!   ([`serialize_stream_checksummed`]) and once from the plain form.
//!   A corruption is *silent* when the decoder returns `Ok` with a
//!   matrix that differs from the original — the checksummed path must
//!   have zero silent rows at every rate (that is the headline the CI
//!   asserts); the plain columns show what the CRC word buys.
//! * **Survival** — the multi-tenant batch workload
//!   ([`super::batch::small_job_suite`]) runs through
//!   [`ReapBatch::with_faults`]: detected wave corruption costs
//!   full-serial replays ([`SimStats::retry_cycles`], exact ledger
//!   `cycles == baseline + retry_cycles`), and a wave that exhausts
//!   [`FpgaConfig::max_wave_retries`]
//!   fails only the tenants riding it. At rate 1.0 every wave exhausts
//!   its budget and every job is reported failed — graceful degradation,
//!   not a panic or a whole-batch abort.

use crate::coordinator::ReapBatch;
use crate::fpga::FpgaConfig;
use crate::reliability::{FaultConfig, FaultInjector};
use crate::rir::decode::try_words_to_csr;
use crate::rir::layout::{serialize_stream, serialize_stream_checksummed};
use crate::rir::BundleStream;
use crate::sparse::gen::{self, Family};
use crate::util::table::Table;

use super::report::RunConfig;

/// Fault rates swept: clean baseline, rare, moderate, heavy, total loss.
pub const FAULT_RATES: [f64; 5] = [0.0, 0.01, 0.05, 0.2, 1.0];

/// Streams per rate in the detection study.
const DETECTION_STREAMS: usize = 16;

/// One fault-rate row of the sweep.
#[derive(Clone, Debug)]
pub struct ReliabilityRow {
    /// Per-word bit-flip / per-fetch corruption probability.
    pub fault_rate: f64,
    /// Streams corrupted-and-decoded in the detection study.
    pub streams: usize,
    /// Streams the injector actually damaged (≥ 1 bit flipped).
    pub corrupted: usize,
    /// Damaged checksummed streams the decoder rejected.
    pub detected: usize,
    /// Damaged checksummed streams decoded `Ok` to a *different* matrix
    /// — silent corruption. Must be 0 at every rate.
    pub silent: usize,
    /// Same two counters for the plain (no-CRC) wire form.
    pub detected_nochk: usize,
    pub silent_nochk: usize,
    /// Tenants in the survival batch.
    pub jobs: usize,
    /// Tenants whose waves exhausted the retry budget.
    pub failed_jobs: usize,
    /// Simulated batch cycles under this fault rate.
    pub cycles: u64,
    /// Replay cycles charged by the engine.
    pub retry_cycles: u64,
    /// The same batch at fault rate 0 (sweep-invariant).
    pub baseline_cycles: u64,
}

/// Small single-matrix streams for the detection study, mixed across
/// pattern families like the batch tenants.
fn detection_streams(cfg: &RunConfig) -> Vec<(crate::sparse::Csr, BundleStream)> {
    (0..DETECTION_STREAMS)
        .map(|i| {
            let n = (20 + (i * 7) % 40).min(cfg.max_rows.max(8));
            let nnz = n * (3 + i % 4);
            let family = match i % 3 {
                0 => Family::RandomUniform,
                1 => Family::PowerLaw,
                _ => Family::BandedFem,
            };
            let m = gen::generate(family, n, nnz, cfg.seed ^ (0xFA11 + i as u64));
            let s = BundleStream::from_csr(&m, 16);
            (m, s)
        })
        .collect()
}

/// Run the sweep; returns rows plus the rendered table, and writes
/// `BENCH_reliability.json` when output is enabled.
pub fn run(cfg: &RunConfig) -> (Vec<ReliabilityRow>, Table) {
    let streams = detection_streams(cfg);
    let jobs = super::batch::small_job_suite(cfg);
    let design = cfg.design(FpgaConfig::reap64_spgemm());
    let baseline = ReapBatch::new(design.clone()).strict(true).run(&jobs).expect("baseline batch");

    let mut rows = Vec::new();
    for (ri, &rate) in FAULT_RATES.iter().enumerate() {
        // ---- detection: checksummed vs plain wire form, same damage ----
        let injector = FaultInjector::new(cfg.seed ^ 0xC4C, FaultConfig::bit_flips(rate));
        let (mut corrupted, mut detected, mut silent) = (0usize, 0usize, 0usize);
        let (mut detected_nochk, mut silent_nochk) = (0usize, 0usize);
        for (i, (m, s)) in streams.iter().enumerate() {
            // one injector stream id per (rate, matrix); both wire forms
            // are damaged under the same id (the plain form is shorter,
            // so its damage is a deterministic variant, not a copy)
            let id = (ri * DETECTION_STREAMS + i) as u64;
            let mut chk = serialize_stream_checksummed(s);
            let report = injector.inject(id, &mut chk);
            let mut plain = serialize_stream(s);
            injector.inject(id, &mut plain);
            if !report.corrupted() {
                continue;
            }
            corrupted += 1;
            match try_words_to_csr(&chk, m.nrows, m.ncols) {
                Err(_) => detected += 1,
                Ok(d) if d != *m => silent += 1,
                Ok(_) => detected += 1, // damage landed but stayed invisible
            }
            match try_words_to_csr(&plain, m.nrows, m.ncols) {
                Err(_) => detected_nochk += 1,
                Ok(d) if d != *m => silent_nochk += 1,
                Ok(_) => detected_nochk += 1,
            }
        }

        // ---- survival: the batched workload on a lossy link ----
        let rep = if rate == 0.0 {
            baseline.clone()
        } else {
            ReapBatch::new(design.clone())
                .strict(true)
                .with_faults(rate, cfg.seed ^ 0xFA17)
                .run(&jobs)
                .expect("faulty batch")
        };

        rows.push(ReliabilityRow {
            fault_rate: rate,
            streams: streams.len(),
            corrupted,
            detected,
            silent,
            detected_nochk,
            silent_nochk,
            jobs: jobs.len(),
            failed_jobs: rep.failed_jobs.len(),
            cycles: rep.fpga_sim.cycles,
            retry_cycles: rep.fpga_sim.retry_cycles,
            baseline_cycles: baseline.fpga_sim.cycles,
        });
    }
    write_bench_json(cfg, &rows);

    let mut table = Table::new(
        "Reliability — checksummed detection + wave retry under stream faults",
        &[
            "fault_rate", "corrupted", "detected", "silent", "silent(no-crc)",
            "retry_cycles", "overhead", "failed_jobs",
        ],
    );
    for r in &rows {
        table.row(vec![
            format!("{:.2}", r.fault_rate),
            format!("{}/{}", r.corrupted, r.streams),
            r.detected.to_string(),
            r.silent.to_string(),
            r.silent_nochk.to_string(),
            r.retry_cycles.to_string(),
            format!("{:.1}%", 100.0 * r.retry_cycles as f64 / r.baseline_cycles.max(1) as f64),
            format!("{}/{}", r.failed_jobs, r.jobs),
        ]);
    }
    (rows, table)
}

/// The reliability headline the CI asserts:
///
/// 1. the rate-0 row is pristine — nothing corrupted, nothing retried,
///    cycles bit-identical to the fault-free baseline;
/// 2. at every rate the checksummed path has **zero silent corruptions**
///    and the retry ledger is exact
///    (`cycles == baseline_cycles + retry_cycles`);
/// 3. at rate 1.0 degradation is graceful and total: every tenant is
///    reported failed (rather than the run aborting), with damage at
///    higher rates never below lower ones.
pub fn headline_holds(rows: &[ReliabilityRow]) -> bool {
    let Some(first) = rows.first() else {
        return false;
    };
    let Some(last) = rows.last() else {
        return false;
    };
    let clean_baseline = first.fault_rate == 0.0
        && first.corrupted == 0
        && first.retry_cycles == 0
        && first.failed_jobs == 0
        && first.cycles == first.baseline_cycles;
    let exact_everywhere = rows.iter().all(|r| {
        r.silent == 0
            && r.detected == r.corrupted
            && r.cycles == r.baseline_cycles + r.retry_cycles
    });
    let total_loss_is_graceful = last.fault_rate == 1.0 && last.failed_jobs == last.jobs;
    let monotone_damage = rows.windows(2).all(|w| {
        w[0].retry_cycles <= w[1].retry_cycles && w[0].failed_jobs <= w[1].failed_jobs
    });
    clean_baseline && exact_everywhere && total_loss_is_graceful && monotone_damage
}

use super::json::{escape, num};

/// Write `BENCH_reliability.json`: one record per fault rate, diffable
/// across PRs alongside the other `BENCH_*.json` files.
fn write_bench_json(cfg: &RunConfig, rows: &[ReliabilityRow]) {
    let Some(dir) = &cfg.csv_dir else {
        return;
    };
    let mut out = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"workload\": \"reliability\", \"config\": \"{}\", \"fault_rate\": {}, \
             \"streams\": {}, \"corrupted\": {}, \"detected\": {}, \"silent\": {}, \
             \"detected_nochk\": {}, \"silent_nochk\": {}, \"jobs\": {}, \
             \"failed_jobs\": {}, \"cycles\": {}, \"retry_cycles\": {}, \
             \"baseline_cycles\": {}}}{}\n",
            escape("REAP-64"),
            num(r.fault_rate),
            r.streams,
            r.corrupted,
            r.detected,
            r.silent,
            r.detected_nochk,
            r.silent_nochk,
            r.jobs,
            r.failed_jobs,
            r.cycles,
            r.retry_cycles,
            r.baseline_cycles,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    if let Err(e) = std::fs::create_dir_all(dir)
        .and_then(|()| std::fs::write(dir.join("BENCH_reliability.json"), out))
    {
        eprintln!("warning: could not write BENCH_reliability.json: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn sweep_headline_and_json_artifact() {
        let mut cfg = RunConfig::quick();
        let dir = std::env::temp_dir().join(format!("reap-rel-{}", std::process::id()));
        cfg.csv_dir = Some(dir.clone());
        let (rows, table) = run(&cfg);
        assert_eq!(rows.len(), FAULT_RATES.len());
        assert_eq!(table.len(), FAULT_RATES.len());
        assert!(headline_holds(&rows), "reliability headline must hold: {rows:?}");
        // the lossy rows actually exercise the retry path
        assert!(rows.last().unwrap().retry_cycles > 0);
        assert!(rows.iter().skip(1).any(|r| r.corrupted > 0));

        let text = std::fs::read_to_string(dir.join("BENCH_reliability.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), FAULT_RATES.len());
        assert!(arr[0].get("fault_rate").unwrap().as_f64().is_some());
        assert!(arr[0].get("retry_cycles").unwrap().as_usize().is_some());
        assert_eq!(
            arr.last().unwrap().get("failed_jobs").unwrap().as_usize().unwrap(),
            rows.last().unwrap().jobs
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
