//! SpMM multi-vector throughput: `C = A·X` with k dense right-hand sides
//! through one wave schedule, versus k serial SpMV runs (no paper figure
//! corresponds; EXPERIMENTS.md §SpMM documents the methodology).
//!
//! For each design point and each k ∈ {4, 8} the harness runs the same
//! (matrix, panel) workload twice — once through
//! [`ReapSpmm`] (one schedule, k-wide vector lanes, one
//! replay per column block) and once as k independent
//! [`ReapSpmv`] runs — and reports simulated cycles, DRAM
//! traffic and end-to-end time. SpMM must win cycles *and* read traffic
//! on the wide (64/128) designs: that is the headline the CI asserts.
//! The numeric results are checked bit-identical between the two modes on
//! every row (`max_abs_err` must be exactly zero).

use crate::coordinator::{ReapSpmm, ReapSpmv};
use crate::fpga::FpgaConfig;
use crate::sparse::gen::{self, Family};
use crate::sparse::{Csr, Val};
use crate::util::table::Table;

use super::report::RunConfig;

/// One (design point × k) comparison row.
#[derive(Clone, Debug)]
pub struct SpmmRow {
    pub config: String,
    /// Right-hand-side column count.
    pub k: usize,
    /// Simulated FPGA cycles, SpMM / k summed SpMV runs.
    pub spmm_cycles: u64,
    pub serial_cycles: u64,
    /// Simulated DRAM bytes read, SpMM / k summed SpMV runs.
    pub spmm_bytes_read: u64,
    pub serial_bytes_read: u64,
    /// End-to-end seconds under per-wave pipelining.
    pub spmm_total_s: f64,
    pub serial_total_s: f64,
    /// Measured CPU preprocessing seconds: spent once for SpMM, once per
    /// SpMV run (k schedule passes) on the serial side — the very cost
    /// the shared schedule amortizes.
    pub spmm_cpu_s: f64,
    pub serial_cpu_s: f64,
    /// Simulated FPGA seconds, SpMM / k summed SpMV runs.
    pub spmm_fpga_s: f64,
    pub serial_fpga_s: f64,
    /// Simulated waves (SpMM, summed over column blocks).
    pub spmm_waves: u64,
    /// SpMM cycles on the serial (depth-1) DRAM channel.
    pub spmm_cycles_serial: u64,
    /// SpMM cycles on the double-buffered (depth-2) channel (later
    /// blocks' panel loads prefetch under the previous block's compute).
    pub spmm_cycles_db: u64,
    /// Frontend cycles depth 2 hid under compute (SpMM pass).
    pub spmm_prefetch_hidden: u64,
    /// Max |SpMM − SpMV| over all outputs — bit-identity means exactly 0.
    pub max_abs_err: f64,
}

/// The SpMM workload: a banded-FEM clone (the suite's most common family)
/// plus a deterministic dense panel wide enough for both k values.
pub fn workload(cfg: &RunConfig, k: usize) -> (Csr, Vec<Val>) {
    let n = cfg.max_rows.clamp(64, 1200);
    let a = gen::generate(Family::BandedFem, n, n * 8, cfg.seed ^ 0x59A44);
    let x: Vec<Val> = (0..a.ncols * k)
        .map(|i| (((i as u64).wrapping_mul(2654435761) % 31) as f32 - 15.0) * 0.0625)
        .collect();
    (a, x)
}

/// Run the comparison; returns rows plus the rendered table, and writes
/// `BENCH_spmm.json` when output is enabled.
pub fn run(cfg: &RunConfig) -> (Vec<SpmmRow>, Table) {
    let mut rows = Vec::new();
    for design in [
        cfg.design(FpgaConfig::reap32_spgemm()),
        cfg.design(FpgaConfig::reap64_spgemm()),
        cfg.design(FpgaConfig::reap128_spgemm()),
    ] {
        for k in [4usize, 8] {
            let (a, x) = workload(cfg, k);
            let spmm =
                ReapSpmm::new(design.clone()).strict(true).run(&a, &x, k).expect("spmm run");

            let mut serial_cycles = 0u64;
            let mut serial_bytes = 0u64;
            let mut serial_total_s = 0.0f64;
            let mut serial_cpu_s = 0.0f64;
            let mut serial_fpga_s = 0.0f64;
            let mut max_abs_err = 0.0f64;
            for j in 0..k {
                let xj: Vec<Val> = x.iter().skip(j).step_by(k).copied().collect();
                let rep =
                    ReapSpmv::new(design.clone()).strict(true).run(&a, &xj).expect("spmv run");
                serial_cycles += rep.fpga_sim.cycles;
                serial_bytes += rep.fpga_sim.bytes_read;
                serial_total_s += rep.total_s;
                serial_cpu_s += rep.cpu_preprocess_s;
                serial_fpga_s += rep.fpga_s;
                for i in 0..a.nrows {
                    max_abs_err =
                        max_abs_err.max((spmm.c[i * k + j] - rep.y[i]).abs() as f64);
                }
            }

            rows.push(SpmmRow {
                config: design.name.to_string(),
                k,
                spmm_cycles: spmm.fpga_sim.cycles,
                serial_cycles,
                spmm_bytes_read: spmm.fpga_sim.bytes_read,
                serial_bytes_read: serial_bytes,
                spmm_total_s: spmm.total_s,
                serial_total_s,
                spmm_cpu_s: spmm.cpu_preprocess_s,
                serial_cpu_s,
                spmm_fpga_s: spmm.fpga_s,
                serial_fpga_s,
                spmm_waves: spmm.fpga_sim.waves,
                spmm_cycles_serial: spmm.fpga_sim_serial.cycles,
                spmm_cycles_db: spmm.fpga_sim_db.cycles,
                spmm_prefetch_hidden: spmm.fpga_sim_db.prefetch_hidden_cycles,
                max_abs_err,
            });
        }
    }
    write_bench_json(cfg, &rows);

    let mut table = Table::new(
        "SpMM multi-vector — one schedule, k-wide lanes vs k serial SpMVs",
        &[
            "config", "k", "cycles(spmm)", "cycles(serial)", "MB-read(spmm)",
            "MB-read(serial)", "speedup", "max|err|",
        ],
    );
    for r in &rows {
        table.row(vec![
            r.config.clone(),
            r.k.to_string(),
            r.spmm_cycles.to_string(),
            r.serial_cycles.to_string(),
            format!("{:.3}", r.spmm_bytes_read as f64 / 1e6),
            format!("{:.3}", r.serial_bytes_read as f64 / 1e6),
            format!("{:.2}x", r.serial_total_s / r.spmm_total_s.max(1e-12)),
            format!("{:.1e}", r.max_abs_err),
        ]);
    }
    (rows, table)
}

/// The SpMM headline: on the wide designs (64/128 pipelines) one schedule
/// with k-wide vector lanes must cost strictly fewer simulated cycles and
/// strictly fewer DRAM read bytes than k serial SpMV runs, for every k —
/// and the numeric results must be bit-identical (zero error) everywhere.
pub fn headline_holds(rows: &[SpmmRow]) -> bool {
    rows.iter().all(|r| r.max_abs_err == 0.0)
        && rows
            .iter()
            .filter(|r| r.config != "REAP-32")
            .all(|r| {
                r.spmm_cycles < r.serial_cycles && r.spmm_bytes_read < r.serial_bytes_read
            })
}

use super::json::{escape, num};

/// Write `BENCH_spmm.json`: two records per (design point, k) — `spmm`
/// and `serial` mode — alongside the other `BENCH_*.json` trajectory
/// files (`bytes_read` is the amortization the other files do not carry).
fn write_bench_json(cfg: &RunConfig, rows: &[SpmmRow]) {
    let Some(dir) = &cfg.csv_dir else {
        return;
    };
    let mut out = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"workload\": \"spmm-k{}\", \"config\": \"{}\", \"mode\": \"spmm\", \
             \"cpu_s\": {}, \"fpga_s\": {}, \"total_s\": {}, \"waves\": {}, \
             \"bytes_read\": {}, \"cycles_serial\": {}, \"cycles_db\": {}, \
             \"prefetch_hidden_cycles\": {}}},\n",
            r.k,
            escape(&r.config),
            num(r.spmm_cpu_s),
            num(r.spmm_fpga_s),
            num(r.spmm_total_s),
            r.spmm_waves,
            r.spmm_bytes_read,
            r.spmm_cycles_serial,
            r.spmm_cycles_db,
            r.spmm_prefetch_hidden,
        ));
        out.push_str(&format!(
            "  {{\"workload\": \"spmm-k{}\", \"config\": \"{}\", \"mode\": \"serial\", \
             \"cpu_s\": {}, \"fpga_s\": {}, \"total_s\": {}, \"waves\": 0, \
             \"bytes_read\": {}}}{}\n",
            r.k,
            escape(&r.config),
            num(r.serial_cpu_s),
            num(r.serial_fpga_s),
            num(r.serial_total_s),
            r.serial_bytes_read,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    if let Err(e) = std::fs::create_dir_all(dir)
        .and_then(|()| std::fs::write(dir.join("BENCH_spmm.json"), out))
    {
        eprintln!("warning: could not write BENCH_spmm.json: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn spmm_wins_cycles_and_traffic_on_wide_designs() {
        let mut cfg = RunConfig::quick();
        let dir = std::env::temp_dir().join(format!("reap-spmm-{}", std::process::id()));
        cfg.csv_dir = Some(dir.clone());
        let (rows, table) = run(&cfg);
        assert_eq!(rows.len(), 6); // 3 designs × k ∈ {4, 8}
        assert_eq!(table.len(), 6);
        assert!(
            headline_holds(&rows),
            "one schedule + vector lanes must beat k serial SpMVs on 64/128: {rows:?}"
        );
        let text = std::fs::read_to_string(dir.join("BENCH_spmm.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 12); // 6 rows × 2 modes
        assert!(arr[0].get("bytes_read").unwrap().as_usize().is_some());
        assert!(arr[0].get("cycles_serial").unwrap().as_usize().is_some());
        // acceptance headline: depth-2 prefetch strictly beats the serial
        // channel on the wide designs (the panel loads hide, at minimum)
        for r in &rows {
            assert_eq!(
                r.spmm_cycles_db + r.spmm_prefetch_hidden,
                r.spmm_cycles_serial,
                "{} k {}: hidden cycles must equal the depth-1 gap",
                r.config,
                r.k
            );
            if r.config != "REAP-32" {
                assert!(
                    r.spmm_cycles_db < r.spmm_cycles_serial,
                    "{} k {}: {} !< {}",
                    r.config,
                    r.k,
                    r.spmm_cycles_db,
                    r.spmm_cycles_serial
                );
                assert!(r.spmm_prefetch_hidden > 0, "{} k {}", r.config, r.k);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
