//! Fig 8: (left) GFLOPS normalized per FP unit for REAP vs CPU;
//! (right) frequency and logic utilization as pipelines scale 2 → 128.
//!
//! Paper shapes: per-FPU GFLOPS higher for REAP at every matched unit
//! count and scaling better with more units; frequency drops only
//! 280 → 220 MHz while logic grows 8× over the 2 → 128 sweep.

use crate::coordinator::ReapSpgemm;
use crate::fpga::{cpu_fp_units, AreaModel, FpgaConfig};
use crate::kernels::spgemm::spgemm_flops;
use crate::util::stats::{quartet, Quartet};
use crate::util::table::{f2, pct, Table};

use super::report::{measure_spgemm_cpu, RunConfig};
use super::suite::spgemm_suite;

/// The left panel: one series per design/thread-count.
#[derive(Clone, Debug)]
pub struct GflopsSeries {
    pub label: String,
    pub fp_units: usize,
    /// Per-matrix GFLOPS per FP unit.
    pub per_fpu: Vec<f64>,
    pub summary: Quartet,
}

/// Run both panels.
pub fn run(cfg: &RunConfig) -> (Vec<GflopsSeries>, Table, Table) {
    // ---- left: GFLOPS per FP unit across the suite ----
    let mut reap: Vec<(FpgaConfig, Vec<f64>)> = vec![
        (FpgaConfig::reap32_spgemm(), Vec::new()),
        (FpgaConfig::reap64_spgemm(), Vec::new()),
        (FpgaConfig::reap128_spgemm(), Vec::new()),
    ];
    let threads = [1usize, 2, 4, 8, 16];
    let mut cpu: Vec<(usize, Vec<f64>)> = threads.iter().map(|&t| (t, Vec::new())).collect();

    for spec in spgemm_suite() {
        let a = spec.instantiate(cfg.max_rows, cfg.seed);
        let flops = spgemm_flops(&a, &a) as f64;
        for (fcfg, series) in reap.iter_mut() {
            let rep = ReapSpgemm::new(fcfg.clone()).strict(true).run(&a, &a).unwrap();
            series.push(flops / rep.fpga_s / 1e9 / fcfg.fp_units() as f64);
        }
        for (t, series) in cpu.iter_mut() {
            let m = measure_spgemm_cpu(cfg, &a, &a, *t);
            series.push(flops / m.min_s / 1e9 / cpu_fp_units(*t) as f64);
        }
    }

    let mut series = Vec::new();
    for (fcfg, per_fpu) in reap {
        series.push(GflopsSeries {
            label: fcfg.name.to_string(),
            fp_units: fcfg.fp_units(),
            summary: quartet(&per_fpu).unwrap(),
            per_fpu,
        });
    }
    for (t, per_fpu) in cpu {
        series.push(GflopsSeries {
            label: format!("CPU-{t}"),
            fp_units: cpu_fp_units(t),
            summary: quartet(&per_fpu).unwrap(),
            per_fpu,
        });
    }

    let mut left = Table::new(
        "Fig 8 (left) — GFLOPS per FP unit (median/geomean/p25/p75)",
        &["series", "FP units", "p25", "median", "geomean", "p75"],
    );
    for s in &series {
        left.row(vec![
            s.label.clone(),
            s.fp_units.to_string(),
            f2(s.summary.p25),
            f2(s.summary.median),
            f2(s.summary.geomean),
            f2(s.summary.p75),
        ]);
    }

    // ---- right: frequency + logic utilization vs pipeline count ----
    let mut right = Table::new(
        "Fig 8 (right) — frequency and logic utilization vs pipelines",
        &["pipelines", "freq (MHz)", "logic util"],
    );
    for p in [2usize, 4, 8, 16, 32, 64, 128] {
        right.row(vec![
            p.to_string(),
            f2(AreaModel::freq_mhz(p)),
            pct(AreaModel::logic_utilization(p)),
        ]);
    }

    (series, left, right)
}

/// Paper's left-panel claim: for equal FP-unit counts REAP achieves higher
/// per-unit GFLOPS (REAP-32 ≙ CPU-2: 32 units; REAP-128 vs CPU-16 is the
/// half-units case and must still win per unit).
pub fn headline_holds(series: &[GflopsSeries]) -> bool {
    let get = |label: &str| series.iter().find(|s| s.label == label);
    match (get("REAP-32"), get("CPU-2"), get("REAP-128"), get("CPU-16")) {
        (Some(r32), Some(c2), Some(r128), Some(c16)) => {
            r32.summary.geomean > c2.summary.geomean
                && r128.summary.geomean > c16.summary.geomean
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_all_series() {
        let (series, left, right) = run(&RunConfig::quick());
        assert_eq!(series.len(), 3 + 5);
        assert_eq!(left.len(), 8);
        assert_eq!(right.len(), 7);
        for s in &series {
            assert_eq!(s.per_fpu.len(), 20);
            assert!(s.summary.geomean > 0.0, "{}", s.label);
        }
    }
}
