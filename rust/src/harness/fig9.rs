//! Fig 9: sensitivity to sparsity — REAP speedup vs matrix density
//! (log-scale x), for SpGEMM and Cholesky.
//!
//! The paper plots the *evaluation-suite matrices* against their density
//! and draws the CPU-crossover ("The dashed line shows where the CPU
//! version beats the REAP. CPU beats REAP only for the case where the
//! matrix is relatively denser"; "REAP favors sparse matrices"). This
//! harness reproduces that scatter from the Table-I clones and adds a
//! controlled synthetic density sweep (fixed n, rising density) that
//! isolates the dense-end crossover.

use crate::coordinator::{ReapCholesky, ReapSpgemm};
use crate::fpga::FpgaConfig;
use crate::kernels::cholesky::cholesky_numeric;
use crate::sparse::{gen, ops};
use crate::symbolic::symbolic_factor;
use crate::util::table::{speedup, Table};
use crate::util::timer::measure_budgeted;

use super::report::{measure_spgemm_cpu, RunConfig};
use super::suite::{cholesky_suite, spgemm_suite};

/// One scatter point (suite matrix or synthetic).
#[derive(Clone, Debug)]
pub struct Fig9Point {
    pub label: String,
    pub density: f64,
    /// REAP-32 speedup vs CPU-1 (SpGEMM for S-points, Cholesky for C-).
    pub speedup: f64,
    pub kernel: &'static str,
}

/// Synthetic dense-end sweep grid (fractions; degree stays ≥ 5 at the
/// sparse end so points measure the algorithm, not fixed-cost noise).
pub fn density_grid() -> Vec<f64> {
    vec![3e-3, 1e-2, 3e-2, 1e-1, 2e-1, 3e-1]
}

/// Run the suite scatter plus the synthetic crossover sweep.
pub fn run(cfg: &RunConfig) -> (Vec<Fig9Point>, Table) {
    let mut points = Vec::new();

    // ---- suite scatter: SpGEMM ----
    for spec in spgemm_suite() {
        let a = spec.instantiate(cfg.max_rows, cfg.seed);
        let cpu1 = measure_spgemm_cpu(cfg, &a, &a, 1).min_s;
        let rep = ReapSpgemm::new(FpgaConfig::reap32_spgemm()).strict(true).run(&a, &a).unwrap();
        points.push(Fig9Point {
            label: spec.spgemm_id.unwrap().to_string(),
            density: a.density(),
            speedup: cpu1 / rep.total_s,
            kernel: "SpGEMM",
        });
    }
    // ---- suite scatter: Cholesky ----
    for spec in cholesky_suite() {
        let lower = spec.instantiate_spd(cfg.max_rows, cfg.seed);
        let pattern = symbolic_factor(&lower);
        let cpu = measure_budgeted(cfg.budget_s, 2, || {
            cholesky_numeric(&lower, &pattern).expect("SPD")
        })
        .min_s;
        let rep =
            ReapCholesky::new(FpgaConfig::reap32_cholesky()).strict(true).run(&lower).unwrap();
        let density = 2.0 * lower.nnz() as f64 / (lower.nrows as f64 * lower.nrows as f64);
        points.push(Fig9Point {
            label: spec.cholesky_id.unwrap().to_string(),
            density,
            speedup: cpu / rep.total_s,
            kernel: "Cholesky",
        });
    }
    // ---- synthetic dense-end sweep (SpGEMM) ----
    let n = cfg.max_rows.min(1200);
    for (i, &d) in density_grid().iter().enumerate() {
        let nnz = (((n * n) as f64 * d) as usize).clamp(5 * n, n * n);
        let a = gen::random_uniform(n, n, nnz, cfg.seed + 1000 + i as u64);
        let cpu1 = measure_spgemm_cpu(cfg, &a, &a, 1).min_s;
        let rep = ReapSpgemm::new(FpgaConfig::reap32_spgemm()).strict(true).run(&a, &a).unwrap();
        points.push(Fig9Point {
            label: format!("sweep{i}"),
            density: a.density(),
            speedup: cpu1 / rep.total_s,
            kernel: "SpGEMM-sweep",
        });
        // Cholesky side of the sweep
        let lower = ops::make_spd(&a).lower_triangle();
        let pattern = symbolic_factor(&lower);
        let cpu = measure_budgeted(cfg.budget_s, 2, || {
            cholesky_numeric(&lower, &pattern).expect("SPD")
        })
        .min_s;
        let repc =
            ReapCholesky::new(FpgaConfig::reap32_cholesky()).strict(true).run(&lower).unwrap();
        points.push(Fig9Point {
            label: format!("sweep{i}"),
            density: a.density(),
            speedup: cpu / repc.total_s,
            kernel: "Cholesky-sweep",
        });
    }

    let mut sorted: Vec<&Fig9Point> = points.iter().collect();
    sorted.sort_by(|a, b| a.density.partial_cmp(&b.density).unwrap());
    let mut table = Table::new(
        "Fig 9 — REAP-32 speedup vs density (suite scatter + synthetic sweep)",
        &["point", "kernel", "density", "speedup", "winner"],
    );
    for p in sorted {
        table.row(vec![
            p.label.clone(),
            p.kernel.into(),
            format!("{:.4}%", p.density * 100.0),
            speedup(p.speedup),
            if p.speedup < 1.0 { "CPU".into() } else { "REAP".into() },
        ]);
    }
    (points, table)
}

/// Paper's dense-end claim: within the controlled sweep, the CPU overtakes
/// REAP only at the dense end (speedup at the densest point is below the
/// sweep's sparse-side maximum, and any CPU win happens at higher density
/// than every REAP win's density median).
pub fn headline_holds(points: &[Fig9Point]) -> bool {
    let sweep: Vec<&Fig9Point> =
        points.iter().filter(|p| p.kernel == "SpGEMM-sweep").collect();
    if sweep.len() < 3 {
        return false;
    }
    let densest = sweep
        .iter()
        .max_by(|a, b| a.density.partial_cmp(&b.density).unwrap())
        .unwrap();
    let best = sweep
        .iter()
        .map(|p| p.speedup)
        .fold(f64::MIN, f64::max);
    // dense end degrades from the peak, and the peak favors REAP
    densest.speedup < best && best > 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_covers_suites_and_sweep() {
        let mut cfg = RunConfig::quick();
        cfg.max_rows = 250;
        let (points, table) = run(&cfg);
        let s = points.iter().filter(|p| p.kernel == "SpGEMM").count();
        let c = points.iter().filter(|p| p.kernel == "Cholesky").count();
        let sw = points.iter().filter(|p| p.kernel == "SpGEMM-sweep").count();
        assert_eq!(s, 20);
        assert_eq!(c, 8);
        assert_eq!(sw, density_grid().len());
        assert_eq!(table.len(), points.len());
        for p in &points {
            assert!(p.speedup.is_finite() && p.speedup > 0.0, "{}", p.label);
        }
    }
}
