//! Fig 10: sparse Cholesky speedup of REAP designs vs CHOLMOD (stand-in)
//! on a single core.
//!
//! Paper shapes: REAP-32 wins on all but one benchmark (geomean 1.18×);
//! REAP-64 wins everywhere (geomean 1.85×). Per the paper's protocol the
//! elimination-tree build is excluded from both sides and CHOLMOD runs
//! numeric-only; REAP's side includes its remaining symbolic work (the
//! Fig-11 breakdown).

use crate::coordinator::ReapCholesky;
use crate::fpga::FpgaConfig;
use crate::kernels::cholesky::cholesky_numeric;
use crate::symbolic::{elimination_tree, symbolic_factor};
use crate::util::stats::geomean;
use crate::util::table::{speedup, Table};
use crate::util::timer::{measure_budgeted, Timer};

use super::report::RunConfig;
use super::suite::cholesky_suite;

/// One matrix row of the figure.
#[derive(Clone, Debug)]
pub struct Fig10Row {
    pub id: String,
    pub name: String,
    pub cholmod_s: f64,
    pub reap32: f64,
    pub reap64: f64,
}

/// Run the figure; also dumps `BENCH_cholesky.json` when output is
/// enabled.
pub fn run(cfg: &RunConfig) -> (Vec<Fig10Row>, Table) {
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for spec in cholesky_suite() {
        let lower = spec.instantiate_spd(cfg.max_rows, cfg.seed);
        // CHOLMOD stand-in: numeric phase only, over a prebuilt pattern
        let pattern = symbolic_factor(&lower);
        let cpu = measure_budgeted(cfg.budget_s, 2, || {
            cholesky_numeric(&lower, &pattern).expect("suite matrices are SPD")
        })
        .min_s;
        // etree build time is excluded from REAP's symbolic side too
        let t = Timer::start();
        let _ = elimination_tree(&lower);
        let etree_s = t.elapsed_s();

        let id = spec.cholesky_id.unwrap().to_string();
        let mut speedup_of = |fcfg: FpgaConfig, config: &str| {
            let rep = ReapCholesky::new(cfg.design(fcfg)).strict(true).run(&lower).unwrap();
            records.push(super::json::BenchRecord {
                matrix: format!("{} {}", id, spec.name),
                config: config.to_string(),
                cpu_s: rep.cpu_symbolic_s,
                fpga_s: rep.fpga_s,
                total_s: rep.total_s,
                waves: rep.fpga_sim.waves,
                cycles_serial: rep.fpga_sim_serial.cycles,
                cycles_db: rep.fpga_sim_db.cycles,
                prefetch_hidden_cycles: rep.fpga_sim_db.prefetch_hidden_cycles,
            });
            let reap_total =
                (rep.cpu_symbolic_s - etree_s).max(0.0) + rep.fpga_s;
            cpu / reap_total
        };
        let reap32 = speedup_of(FpgaConfig::reap32_cholesky(), "REAP-32");
        let reap64 = speedup_of(FpgaConfig::reap64_cholesky(), "REAP-64");
        rows.push(Fig10Row {
            id,
            name: spec.name.to_string(),
            cholmod_s: cpu,
            reap32,
            reap64,
        });
    }
    cfg.dump_bench_json("BENCH_cholesky", &records).expect("BENCH_cholesky.json");

    let mut table = Table::new(
        "Fig 10 — Cholesky speedup vs CHOLMOD-class CPU-1 (numeric phase)",
        &["id", "matrix", "REAP-32", "REAP-64"],
    );
    for r in &rows {
        table.row(vec![
            r.id.clone(),
            r.name.clone(),
            speedup(r.reap32),
            speedup(r.reap64),
        ]);
    }
    let gm32 = geomean(&rows.iter().map(|r| r.reap32).collect::<Vec<_>>()).unwrap_or(0.0);
    let gm64 = geomean(&rows.iter().map(|r| r.reap64).collect::<Vec<_>>()).unwrap_or(0.0);
    table.row(vec!["GM".into(), "geomean".into(), speedup(gm32), speedup(gm64)]);
    (rows, table)
}

/// Paper's claims: REAP-64 wins everywhere and improves on REAP-32.
pub fn headline_holds(rows: &[Fig10Row]) -> bool {
    let gm32 = geomean(&rows.iter().map(|r| r.reap32).collect::<Vec<_>>()).unwrap_or(0.0);
    let gm64 = geomean(&rows.iter().map(|r| r.reap64).collect::<Vec<_>>()).unwrap_or(0.0);
    rows.iter().all(|r| r.reap64 > 1.0) && gm64 > gm32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_covers_suite() {
        let mut cfg = RunConfig::quick();
        cfg.max_rows = 300;
        let (rows, table) = run(&cfg);
        assert_eq!(rows.len(), 8);
        assert_eq!(table.len(), 9);
        for r in &rows {
            assert!(r.cholmod_s > 0.0);
            assert!(r.reap32.is_finite() && r.reap64.is_finite());
        }
    }
}
