//! The Table-I matrix suite (paper §V), as synthetic clones.
//!
//! Each SuiteSparse matrix is matched on row count, nnz and pattern family
//! (see `sparse::gen`); the clone preserves the mean row degree when
//! scaled down (`--scale`), which is what drives bundle occupancy, flop
//! density and pipeline load balance in REAP. The real matrices drop in
//! via Matrix-Market files when available (`sparse::mm`).

use crate::sparse::gen::{self, Family};
use crate::sparse::{ops, Csc, Csr};

/// One Table-I row.
#[derive(Clone, Copy, Debug)]
pub struct MatrixSpec {
    /// SuiteSparse name (for reporting).
    pub name: &'static str,
    /// SpGEMM benchmark id (S1..S20) if part of the SpGEMM suite.
    pub spgemm_id: Option<&'static str>,
    /// Cholesky benchmark id (C1..C8) if part of the Cholesky suite.
    pub cholesky_id: Option<&'static str>,
    /// Rows (= cols; all suite matrices are square).
    pub rows: usize,
    /// Nonzeros of the original matrix.
    pub nnz: usize,
    /// Synthetic pattern family standing in for the original.
    pub family: Family,
}

/// Table I, in paper order. Families follow the application domain:
/// `bcsstk*`/`cant`/`consph`/`offshore`/`filter3D`/`Pre_poisson`/`gyro`/
/// `cbuckle`/`bcsstk36` are FEM/structural (banded), `cage12`/`m133-b3`/
/// `poission3Da`/`2cubes_sphere`/`cop20K`/`ns3Da` random-ish scatter,
/// `mbeacxc`/`descriptor_xingo6u`/`g7jac060sc`/`TSOPF*` economic/power
/// networks (power-law), `pdb1HYs`/`rma10`/`mario_002` clustered blocks.
pub const TABLE1: &[MatrixSpec] = &[
    MatrixSpec { name: "mario_002", spgemm_id: Some("S1"), cholesky_id: None, rows: 389_000, nnz: 2_100_000, family: Family::BlockRandom },
    MatrixSpec { name: "m133-b3", spgemm_id: Some("S2"), cholesky_id: None, rows: 200_000, nnz: 800_000, family: Family::RandomUniform },
    MatrixSpec { name: "filter3D", spgemm_id: Some("S3"), cholesky_id: None, rows: 106_000, nnz: 2_700_000, family: Family::BandedFem },
    MatrixSpec { name: "cop20K", spgemm_id: Some("S4"), cholesky_id: None, rows: 121_000, nnz: 2_600_000, family: Family::RandomUniform },
    MatrixSpec { name: "offshore", spgemm_id: Some("S5"), cholesky_id: None, rows: 259_000, nnz: 4_200_000, family: Family::BandedFem },
    MatrixSpec { name: "poission3Da", spgemm_id: Some("S6"), cholesky_id: None, rows: 13_000, nnz: 352_000, family: Family::RandomUniform },
    MatrixSpec { name: "cage12", spgemm_id: Some("S7"), cholesky_id: None, rows: 130_000, nnz: 2_000_000, family: Family::RandomUniform },
    MatrixSpec { name: "2cubes_sphere", spgemm_id: Some("S8"), cholesky_id: None, rows: 101_000, nnz: 1_640_000, family: Family::BandedFem },
    MatrixSpec { name: "bcsstk13", spgemm_id: Some("S9"), cholesky_id: Some("C2"), rows: 2_000, nnz: 83_000, family: Family::BandedFem },
    MatrixSpec { name: "bcsstk17", spgemm_id: Some("S10"), cholesky_id: Some("C3"), rows: 10_000, nnz: 428_000, family: Family::BandedFem },
    MatrixSpec { name: "cant", spgemm_id: Some("S11"), cholesky_id: Some("C4"), rows: 62_000, nnz: 4_000_000, family: Family::BandedFem },
    MatrixSpec { name: "consph", spgemm_id: Some("S12"), cholesky_id: None, rows: 83_000, nnz: 6_000_000, family: Family::BandedFem },
    MatrixSpec { name: "mbeacxc", spgemm_id: Some("S13"), cholesky_id: None, rows: 496, nnz: 49_000, family: Family::PowerLaw },
    MatrixSpec { name: "pdb1HYs", spgemm_id: Some("S14"), cholesky_id: None, rows: 36_000, nnz: 4_300_000, family: Family::BlockRandom },
    MatrixSpec { name: "rma10", spgemm_id: Some("S15"), cholesky_id: None, rows: 46_000, nnz: 2_300_000, family: Family::BlockRandom },
    MatrixSpec { name: "descriptor_xingo6u", spgemm_id: Some("S16"), cholesky_id: None, rows: 20_000, nnz: 73_000, family: Family::PowerLaw },
    MatrixSpec { name: "g7jac060sc", spgemm_id: Some("S17"), cholesky_id: None, rows: 17_000, nnz: 203_000, family: Family::PowerLaw },
    MatrixSpec { name: "ns3Da", spgemm_id: Some("S18"), cholesky_id: None, rows: 20_000, nnz: 1_600_000, family: Family::RandomUniform },
    MatrixSpec { name: "TSOPF_RS_b162_c3", spgemm_id: Some("S19"), cholesky_id: None, rows: 15_000, nnz: 610_000, family: Family::PowerLaw },
    MatrixSpec { name: "cbuckle", spgemm_id: Some("S20"), cholesky_id: Some("C6"), rows: 13_000, nnz: 676_000, family: Family::BandedFem },
    MatrixSpec { name: "Pre_poisson", spgemm_id: None, cholesky_id: Some("C1"), rows: 12_000, nnz: 715_000, family: Family::BandedFem },
    MatrixSpec { name: "gyro", spgemm_id: None, cholesky_id: Some("C5"), rows: 17_000, nnz: 1_000_000, family: Family::BandedFem },
    MatrixSpec { name: "bcsstk18", spgemm_id: None, cholesky_id: Some("C7"), rows: 11_000, nnz: 80_000, family: Family::BandedFem },
    MatrixSpec { name: "bcsstk36", spgemm_id: None, cholesky_id: Some("C8"), rows: 23_000, nnz: 1_100_000, family: Family::BandedFem },
];

impl MatrixSpec {
    /// Density of the original matrix.
    pub fn density(&self) -> f64 {
        self.nnz as f64 / (self.rows as f64 * self.rows as f64)
    }

    /// Scaled dimensions: rows capped at `max_rows`, nnz scaled to keep
    /// the mean row degree (bundle occupancy ≈ invariant).
    pub fn scaled(&self, max_rows: usize) -> (usize, usize) {
        if self.rows <= max_rows {
            return (self.rows, self.nnz);
        }
        let s = max_rows as f64 / self.rows as f64;
        let nnz = ((self.nnz as f64) * s) as usize;
        (max_rows, nnz.max(max_rows))
    }

    /// Instantiate the SpGEMM-side clone (general square matrix).
    pub fn instantiate(&self, max_rows: usize, seed: u64) -> Csr {
        let (rows, nnz) = self.scaled(max_rows);
        gen::generate(self.family, rows, nnz, seed ^ fxhash(self.name))
    }

    /// Instantiate the Cholesky-side clone (SPD, lower triangle).
    pub fn instantiate_spd(&self, max_rows: usize, seed: u64) -> Csc {
        let (rows, nnz) = self.scaled(max_rows);
        let base = gen::generate(self.family, rows, nnz, seed ^ fxhash(self.name));
        ops::make_spd(&base).lower_triangle()
    }
}

/// The SpGEMM subset (S1..S20), in id order.
pub fn spgemm_suite() -> Vec<&'static MatrixSpec> {
    let mut v: Vec<_> = TABLE1.iter().filter(|m| m.spgemm_id.is_some()).collect();
    v.sort_by_key(|m| {
        m.spgemm_id.unwrap()[1..].parse::<usize>().expect("S-id")
    });
    v
}

/// The Cholesky subset (C1..C8), in id order.
pub fn cholesky_suite() -> Vec<&'static MatrixSpec> {
    let mut v: Vec<_> = TABLE1.iter().filter(|m| m.cholesky_id.is_some()).collect();
    v.sort_by_key(|m| {
        m.cholesky_id.unwrap()[1..].parse::<usize>().expect("C-id")
    });
    v
}

/// Stable tiny hash for per-matrix seed derivation.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_sizes_match_paper() {
        assert_eq!(TABLE1.len(), 24);
        assert_eq!(spgemm_suite().len(), 20);
        assert_eq!(cholesky_suite().len(), 8);
    }

    #[test]
    fn ids_are_in_order_and_unique() {
        let s: Vec<_> = spgemm_suite().iter().map(|m| m.spgemm_id.unwrap()).collect();
        for (i, id) in s.iter().enumerate() {
            assert_eq!(*id, format!("S{}", i + 1));
        }
        let c: Vec<_> = cholesky_suite().iter().map(|m| m.cholesky_id.unwrap()).collect();
        for (i, id) in c.iter().enumerate() {
            assert_eq!(*id, format!("C{}", i + 1));
        }
    }

    #[test]
    fn scaling_preserves_mean_degree() {
        let spec = &TABLE1[0]; // mario_002: 389K rows
        let (rows, nnz) = spec.scaled(4000);
        assert_eq!(rows, 4000);
        let degree_orig = spec.nnz as f64 / spec.rows as f64;
        let degree_scaled = nnz as f64 / rows as f64;
        assert!((degree_orig - degree_scaled).abs() / degree_orig < 0.05);
    }

    #[test]
    fn small_matrices_not_scaled() {
        let spec = TABLE1.iter().find(|m| m.name == "mbeacxc").unwrap();
        assert_eq!(spec.scaled(4000), (496, 49_000));
    }

    #[test]
    fn instantiation_deterministic_and_plausible() {
        let spec = TABLE1.iter().find(|m| m.name == "bcsstk13").unwrap();
        let a = spec.instantiate(4000, 1);
        let b = spec.instantiate(4000, 1);
        assert_eq!(a, b);
        a.validate().unwrap();
        assert_eq!(a.nrows, 2000);
        let ratio = a.nnz() as f64 / 83_000.0;
        assert!((0.4..2.5).contains(&ratio), "nnz {} vs 83k", a.nnz());
    }

    #[test]
    fn spd_clones_factorize() {
        let spec = TABLE1.iter().find(|m| m.name == "bcsstk18").unwrap();
        let lower = spec.instantiate_spd(300, 2);
        let f = crate::kernels::cholesky::cholesky(&lower);
        assert!(f.is_ok());
    }
}
