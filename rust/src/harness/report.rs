//! Shared harness plumbing: run configuration, CSV output, and the
//! measured-CPU helpers every figure uses.

use std::path::PathBuf;

use crate::sparse::Csr;
use crate::util::table::Table;
use crate::util::timer::{measure_budgeted, Measurement};

/// Configuration shared by all figure harnesses.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Cap on instantiated matrix rows (Table-I clones scale down to this;
    /// `--full` lifts it to the paper's original sizes).
    pub max_rows: usize,
    /// Base seed for matrix instantiation.
    pub seed: u64,
    /// Per-measurement time budget, seconds.
    pub budget_s: f64,
    /// Directory for CSV dumps (`results/` by default; None disables).
    pub csv_dir: Option<PathBuf>,
    /// DRAM stream-frontend buffer depth applied to every design point
    /// the harness runs (1 = serial baseline, 2 = double-buffered
    /// prefetch; `--dram-depth`). The `BENCH_*.json` records always carry
    /// both depth-1 and depth-2 cycles side by side regardless.
    pub dram_buffer_depth: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            max_rows: 2000,
            seed: 0x5EA9, // "REAP"
            budget_s: 0.2,
            csv_dir: Some(PathBuf::from("results")),
            dram_buffer_depth: 1,
        }
    }
}

impl RunConfig {
    /// Quick configuration for tests.
    pub fn quick() -> Self {
        RunConfig { max_rows: 400, budget_s: 0.02, csv_dir: None, ..Default::default() }
    }

    /// A design point with this run's DRAM channel depth applied.
    pub fn design(&self, base: crate::fpga::FpgaConfig) -> crate::fpga::FpgaConfig {
        crate::fpga::FpgaConfig { dram_buffer_depth: self.dram_buffer_depth, ..base }
    }

    /// Write a table as `<csv_dir>/<name>.csv` when CSV output is enabled.
    pub fn dump_csv(&self, name: &str, table: &Table) -> anyhow::Result<()> {
        if let Some(dir) = &self.csv_dir {
            table.write_csv(dir.join(format!("{name}.csv")).to_str().unwrap())?;
        }
        Ok(())
    }

    /// Write benchmark records as `<csv_dir>/<name>.json` when output is
    /// enabled (the `BENCH_*.json` perf-trajectory files).
    pub fn dump_bench_json(
        &self,
        name: &str,
        records: &[super::json::BenchRecord],
    ) -> anyhow::Result<()> {
        if let Some(dir) = &self.csv_dir {
            super::json::write_bench(&dir.join(format!("{name}.json")), records)?;
        }
        Ok(())
    }
}

/// Parallel-scaling model for the CPU-N baselines when the host has fewer
/// than N cores (this evaluation image exposes a single core; the paper's
/// Xeon 6130 has 16).
///
/// SpGEMM on multicore is memory-bandwidth-bound: Amdahl with a high
/// parallel fraction, capped by the DRAM read-bandwidth ratio of Table II
/// (147 GB/s peak vs 14 GB/s single-core ≈ 10.5×, derated to ~6.5×
/// sustained — consistent with Fig 6 where CPU-16 lands a single-digit
/// factor over CPU-1 and REAP-64 splits the suite with it).
pub fn cpu_scaling_model(threads: usize) -> f64 {
    let n = threads.max(1) as f64;
    let p = 0.93; // parallel fraction
    let amdahl = 1.0 / ((1.0 - p) + p / n);
    let bw_cap = 6.5;
    amdahl.min(bw_cap)
}

/// Measure (or measure + model) the CPU-N SpGEMM baseline.
///
/// With enough host cores the multithreaded kernel is measured directly;
/// otherwise the measured single-thread time is scaled by
/// [`cpu_scaling_model`] (substitution documented in DESIGN.md §6).
pub fn measure_spgemm_cpu(cfg: &RunConfig, a: &Csr, b: &Csr, threads: usize) -> Measurement {
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if threads <= 1 || host >= threads {
        return measure_budgeted(cfg.budget_s, 2, || {
            if threads <= 1 {
                crate::kernels::spgemm(a, b)
            } else {
                crate::kernels::spgemm_parallel(a, b, threads)
            }
        });
    }
    let m1 = measure_budgeted(cfg.budget_s, 2, || crate::kernels::spgemm(a, b));
    let s = cpu_scaling_model(threads);
    Measurement {
        min_s: m1.min_s / s,
        median_s: m1.median_s / s,
        mean_s: m1.mean_s / s,
        reps: m1.reps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn default_config_sane() {
        let c = RunConfig::default();
        assert!(c.max_rows >= 1000);
        assert!(c.budget_s > 0.0);
    }

    #[test]
    fn cpu_measurement_runs() {
        let cfg = RunConfig::quick();
        let a = gen::random_uniform(50, 50, 300, 1);
        let m = measure_spgemm_cpu(&cfg, &a, &a, 1);
        assert!(m.min_s > 0.0);
        let m2 = measure_spgemm_cpu(&cfg, &a, &a, 2);
        assert!(m2.min_s > 0.0);
    }

    #[test]
    fn scaling_model_monotone_and_capped() {
        assert_eq!(cpu_scaling_model(1), 1.0);
        let s2 = cpu_scaling_model(2);
        let s16 = cpu_scaling_model(16);
        assert!(s2 > 1.5 && s2 < 2.0, "S(2)={s2}");
        assert!(s16 > s2);
        assert!(s16 <= 6.5, "bandwidth cap: S(16)={s16}");
        assert!(cpu_scaling_model(64) <= 6.5);
    }
}
