//! CPU-pass thread-scaling under skew: static contiguous bands vs the
//! deterministic work-stealing grains of ARCHITECTURE.md §10 (no paper
//! figure; EXPERIMENTS.md §Scaling documents the methodology).
//!
//! For each pass with a retired static partitioner — the SpGEMM wave
//! schedule, the batch wave schedule and the scheduled numeric replay —
//! the harness measures both executors at 1/2/4/8 workers on a uniform
//! matrix (balanced rows: static partitioning's best case) and on the
//! [`gen::zipf_adversarial`] family (giant scattered rows: its worst
//! case). The bundle encode and the parallel Cholesky symbolic phase have
//! no static twin anymore, so they report the stealing executor alone,
//! scaled against their own single-worker time.
//!
//! The headline: work-stealing never loses to static bands on the uniform
//! input (within measurement tolerance), and is strictly faster on the
//! adversarial input once ≥ 4 workers are available — the skew cliff the
//! tentpole exists to erase. Every timed pass produces output bit-identical
//! to its serial run (asserted here, pinned exhaustively in
//! `prop_invariants`).

use crate::coordinator::batch::{numeric_batch, numeric_batch_static_bands};
use crate::coordinator::spgemm::{numeric_scheduled, numeric_scheduled_static_bands};
use crate::rir::encode::BundleStream;
use crate::rir::schedule::{
    self, schedule_spgemm_batch_static_bands, schedule_spgemm_batch_with_threads,
    schedule_spgemm_static_bands, schedule_spgemm_with_threads,
};
use crate::sparse::gen::{self, Family};
use crate::sparse::Csr;
use crate::symbolic::symbolic_factor_with_threads;
use crate::util::table::Table;
use crate::util::timer::measure_budgeted;

use super::json::BenchRecord;
use super::report::RunConfig;

/// Worker counts the sweep measures.
pub const THREADS: [usize; 4] = [1, 2, 4, 8];

/// One (family × pass × thread-count) measurement.
#[derive(Clone, Debug)]
pub struct ScalingRow {
    /// Input family (`random-uniform` or `zipf-adversarial`).
    pub family: String,
    /// CPU pass (`schedule`, `batch-schedule`, `numeric`, `encode`,
    /// `symbolic`).
    pub pass: String,
    /// Worker count.
    pub threads: usize,
    /// Static-band seconds (min over reps); None for passes whose static
    /// predecessor was retired without a bench twin (encode, symbolic).
    pub static_s: Option<f64>,
    /// Work-stealing seconds (min over reps).
    pub steal_s: f64,
}

/// The two benched families: static partitioning's best case and the
/// adversarial case built for it.
fn families() -> [Family; 2] {
    [Family::RandomUniform, Family::ZipfAdversarial]
}

fn workload(cfg: &RunConfig, fam: Family) -> Csr {
    let n = cfg.max_rows.clamp(64, 1600);
    gen::generate(fam, n, n * 8, cfg.seed ^ 0x5CA1)
}

/// Run the sweep; returns rows plus the rendered table, and writes
/// `BENCH_scaling.json` when output is enabled.
pub fn run(cfg: &RunConfig) -> (Vec<ScalingRow>, Table) {
    let mut rows = Vec::new();
    let pipelines = 32;
    let bundle = 32;
    for fam in families() {
        let a = workload(cfg, fam);
        let b = workload(cfg, fam);
        let jobs = vec![(a.clone(), b.clone()), (b.clone(), a.clone())];
        let s = schedule_spgemm_with_threads(&a, &b, pipelines, bundle, 1);
        let lower = crate::sparse::ops::make_spd(&a).lower_triangle();

        // bit-identity spot checks alongside the timing (the property suite
        // pins these exhaustively; a bench that times a wrong answer is
        // worthless)
        let c1 = numeric_scheduled(&a, &b, &s, 1);
        assert_eq!(numeric_scheduled(&a, &b, &s, 8), c1, "{fam}: numeric drifted");
        assert_eq!(
            schedule_spgemm_with_threads(&a, &b, pipelines, bundle, 8).waves,
            s.waves,
            "{fam}: schedule drifted"
        );

        for t in THREADS {
            rows.push(ScalingRow {
                family: fam.to_string(),
                pass: "schedule".into(),
                threads: t,
                static_s: Some(
                    measure_budgeted(cfg.budget_s, 2, || {
                        schedule_spgemm_static_bands(&a, &b, pipelines, bundle, t)
                    })
                    .min_s,
                ),
                steal_s: measure_budgeted(cfg.budget_s, 2, || {
                    schedule_spgemm_with_threads(&a, &b, pipelines, bundle, t)
                })
                .min_s,
            });
            rows.push(ScalingRow {
                family: fam.to_string(),
                pass: "batch-schedule".into(),
                threads: t,
                static_s: Some(
                    measure_budgeted(cfg.budget_s, 2, || {
                        schedule_spgemm_batch_static_bands(&jobs, pipelines, bundle, t)
                    })
                    .min_s,
                ),
                steal_s: measure_budgeted(cfg.budget_s, 2, || {
                    schedule_spgemm_batch_with_threads(&jobs, pipelines, bundle, t)
                })
                .min_s,
            });
            rows.push(ScalingRow {
                family: fam.to_string(),
                pass: "numeric".into(),
                threads: t,
                static_s: Some(
                    measure_budgeted(cfg.budget_s, 2, || {
                        numeric_scheduled_static_bands(&a, &b, &s, t)
                    })
                    .min_s,
                ),
                steal_s: measure_budgeted(cfg.budget_s, 2, || numeric_scheduled(&a, &b, &s, t))
                    .min_s,
            });
            rows.push(ScalingRow {
                family: fam.to_string(),
                pass: "encode".into(),
                threads: t,
                static_s: None,
                steal_s: measure_budgeted(cfg.budget_s, 2, || {
                    BundleStream::from_csr_with_threads(&a, bundle, t)
                })
                .min_s,
            });
            rows.push(ScalingRow {
                family: fam.to_string(),
                pass: "symbolic".into(),
                threads: t,
                static_s: None,
                steal_s: measure_budgeted(cfg.budget_s, 2, || {
                    symbolic_factor_with_threads(&lower, t)
                })
                .min_s,
            });
        }
        // keep the batch executors exercised bitwise too
        let bs = schedule::schedule_spgemm_batch(&jobs, pipelines, bundle);
        assert_eq!(
            numeric_batch_static_bands(&jobs, &bs, 4),
            numeric_batch(&jobs, &bs, 1),
            "{fam}: batch numeric drifted"
        );
    }
    write_bench_json(cfg, &rows);

    let mut table = Table::new(
        "CPU pass scaling — static bands vs deterministic work-stealing grains",
        &["family", "pass", "threads", "static(ms)", "steal(ms)", "static/steal"],
    );
    for r in &rows {
        table.row(vec![
            r.family.clone(),
            r.pass.clone(),
            r.threads.to_string(),
            r.static_s.map_or_else(|| "-".into(), |s| format!("{:.3}", s * 1e3)),
            format!("{:.3}", r.steal_s * 1e3),
            r.static_s
                .map_or_else(|| "-".into(), |s| format!("{:.2}x", s / r.steal_s.max(1e-12))),
        ]);
    }
    (rows, table)
}

/// The scaling headline: on the balanced uniform family work-stealing never
/// loses to static bands beyond measurement tolerance (10% + a 50µs noise
/// floor), and on the Zipf-adversarial family it is strictly faster
/// wherever ≥ 4 workers meet a static pair — skew is exactly the load the
/// stealing executor redistributes and static bands cannot.
pub fn headline_holds(rows: &[ScalingRow]) -> bool {
    let uniform = Family::RandomUniform.to_string();
    let skewed = Family::ZipfAdversarial.to_string();
    let uniform_ok = rows
        .iter()
        .filter(|r| r.family == uniform)
        .filter_map(|r| r.static_s.map(|s| (s, r.steal_s)))
        .all(|(stat, steal)| steal <= stat * 1.10 + 50e-6);
    let skew_ok = rows
        .iter()
        .filter(|r| r.family == skewed && r.threads >= 4)
        .filter_map(|r| r.static_s.map(|s| (s, r.steal_s)))
        .all(|(stat, steal)| steal < stat);
    uniform_ok && skew_ok
}

/// Write `BENCH_scaling.json`: one record per (family, pass, mode,
/// threads) so `check_regression.py` gates the summed CPU seconds like
/// every other `BENCH_*.json` trajectory file.
fn write_bench_json(cfg: &RunConfig, rows: &[ScalingRow]) {
    let mut records = Vec::new();
    for r in rows {
        if let Some(stat) = r.static_s {
            records.push(BenchRecord {
                matrix: r.family.clone(),
                config: format!("{}/static/t{}", r.pass, r.threads),
                cpu_s: stat,
                fpga_s: 0.0,
                total_s: stat,
                waves: 0,
                cycles_serial: 0,
                cycles_db: 0,
                prefetch_hidden_cycles: 0,
            });
        }
        records.push(BenchRecord {
            matrix: r.family.clone(),
            config: format!("{}/steal/t{}", r.pass, r.threads),
            cpu_s: r.steal_s,
            fpga_s: 0.0,
            total_s: r.steal_s,
            waves: 0,
            cycles_serial: 0,
            cycles_db: 0,
            prefetch_hidden_cycles: 0,
        });
    }
    if let Err(e) = cfg.dump_bench_json("BENCH_scaling", &records) {
        eprintln!("warning: could not write BENCH_scaling.json: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn sweep_shape_and_json_are_complete() {
        let mut cfg = RunConfig::quick();
        cfg.budget_s = 0.005;
        let dir = std::env::temp_dir().join(format!("reap-scaling-{}", std::process::id()));
        cfg.csv_dir = Some(dir.clone());
        let (rows, table) = run(&cfg);
        // 2 families × 5 passes × 4 thread counts
        assert_eq!(rows.len(), 2 * 5 * 4);
        assert_eq!(table.len(), rows.len());
        assert!(rows.iter().all(|r| r.steal_s > 0.0));
        // the three static/steal pairs carry both sides everywhere
        for pass in ["schedule", "batch-schedule", "numeric"] {
            assert!(
                rows.iter().filter(|r| r.pass == pass).all(|r| r.static_s.is_some()),
                "{pass} missing static side"
            );
        }
        for pass in ["encode", "symbolic"] {
            assert!(rows.iter().filter(|r| r.pass == pass).all(|r| r.static_s.is_none()));
        }
        let text = std::fs::read_to_string(dir.join("BENCH_scaling.json")).unwrap();
        let arr_len = Json::parse(&text).unwrap().as_arr().unwrap().len();
        // pairs contribute 2 records, steal-only passes 1
        assert_eq!(arr_len, 2 * 4 * (3 * 2 + 2));
        // timing-shape assertions only — the headline itself depends on the
        // host's real core count, so CI asserts it on the bench runner, not
        // here (a 1-core container serializes every worker)
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn headline_logic_reads_rows_correctly() {
        let mk = |family: &str, threads: usize, stat: Option<f64>, steal: f64| ScalingRow {
            family: family.into(),
            pass: "schedule".into(),
            threads,
            static_s: stat,
            steal_s: steal,
        };
        // stealing matches static on uniform, wins on skew at 4+
        assert!(headline_holds(&[
            mk("random-uniform", 4, Some(1.0e-3), 1.0e-3),
            mk("zipf-adversarial", 4, Some(2.0e-3), 1.0e-3),
            mk("zipf-adversarial", 2, Some(2.0e-3), 3.0e-3), // t<4: unconstrained
        ]));
        // stealing loses badly on uniform -> headline fails
        assert!(!headline_holds(&[mk("random-uniform", 4, Some(1.0e-3), 2.0e-3)]));
        // stealing not strictly faster on skew at 4 threads -> fails
        assert!(!headline_holds(&[mk("zipf-adversarial", 8, Some(1.0e-3), 1.0e-3)]));
    }
}
