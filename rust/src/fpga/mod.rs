//! Transaction-level, cycle-accounted model of the REAP FPGA designs.
//!
//! The paper evaluates via "trace-driven simulation with our in-house
//! cycle-accurate SystemC simulator … cycle counts and FPGA frequencies
//! extracted from the RTL implementation synthesized by Quartus 16.1" plus
//! "a queuing model where the data transfers are not allowed to exceed the
//! bandwidth set in the design" (§V). This module is that simulator,
//! rebuilt in Rust with the paper's published design points:
//!
//! * [`config`] — design-point presets (REAP-32/64/128, Table II DRAM
//!   bandwidths, unit latencies) and the area/frequency scaling model of
//!   Fig 8 (right).
//! * [`dram`] — the bandwidth-capped DRAM queuing model.
//! * [`spgemm_sim`] — the five-module SpGEMM datapath of Fig 1 (input
//!   controller → match+multiply (CAM) → sort → merge → output controller).
//! * [`cholesky_sim`] — the column-parallel Cholesky datapath of Fig 5
//!   (dot-product PEs with CAMs + div/sqrt PEs), with idle-cycle tracking.
//! * [`hls`] — the §V-C OpenCL-HLS derating model (with/without CPU
//!   preprocessing).
//! * [`stats`] — cycle/traffic/utilization accounting shared by all sims.

pub mod cholesky_sim;
pub mod config;
pub mod dram;
pub mod hls;
pub mod spgemm_sim;
pub mod spmv_sim;
pub mod stats;

pub use config::{cpu_fp_units, AreaModel, DramConfig, FpgaConfig};
pub use stats::SimStats;
