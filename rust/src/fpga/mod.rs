//! Transaction-level, cycle-accounted model of the REAP FPGA designs.
//!
//! The paper evaluates via "trace-driven simulation with our in-house
//! cycle-accurate SystemC simulator … cycle counts and FPGA frequencies
//! extracted from the RTL implementation synthesized by Quartus 16.1" plus
//! "a queuing model where the data transfers are not allowed to exceed the
//! bandwidth set in the design" (§V). This module is that simulator,
//! rebuilt in Rust with the paper's published design points:
//!
//! * [`config`] — design-point presets (REAP-32/64/128, Table II DRAM
//!   bandwidths, unit latencies) and the area/frequency scaling model of
//!   Fig 8 (right).
//! * [`dram`] — the bandwidth-capped DRAM queuing model.
//! * [`engine`] — the unified wave engine: every simulator emits
//!   [`WaveCost`] sequences and one `execute_waves` loop owns the
//!   DRAM/compute overlap, including the double-buffered stream prefetch
//!   selected by [`FpgaConfig::dram_buffer_depth`] and the
//!   checksum-failure detect-and-replay model (per-wave [`WaveFault`]s,
//!   retries charged to [`SimStats::retry_cycles`], bounded by
//!   [`FpgaConfig::max_wave_retries`]).
//! * [`spgemm_sim`] — the five-module SpGEMM datapath of Fig 1 (input
//!   controller → match+multiply (CAM) → sort → merge → output controller),
//!   plus the multi-tenant batched variant with per-job attribution.
//! * [`cholesky_sim`] — the column-parallel Cholesky datapath of Fig 5
//!   (dot-product PEs with CAMs + div/sqrt PEs), with idle-cycle tracking.
//! * [`spmv_sim`] / [`spmm_sim`] — the SpMV extension datapath and its
//!   SpMM widening ([`FpgaConfig::vector_lanes`] MAC lanes per PE, one
//!   column block per schedule replay).
//! * [`hls`] — the §V-C OpenCL-HLS derating model (with/without CPU
//!   preprocessing).
//! * [`stats`] — cycle/traffic/utilization accounting shared by all sims.
//!
//! Every simulator exposes a per-wave (or per-column) cycle trace next to
//! its aggregate [`SimStats`]; the coordinators feed those traces into
//! [`crate::coordinator::overlap::pipelined_total`], which expects the CPU
//! and FPGA traces of a run to have equal length (see
//! `ARCHITECTURE.md` §"Simulator contracts").

pub mod cholesky_sim;
pub mod config;
pub mod dram;
pub mod engine;
pub mod hls;
pub mod spgemm_sim;
pub mod spmm_sim;
pub mod spmv_sim;
pub mod stats;

pub use config::{cpu_fp_units, AreaModel, ConfigError, DramConfig, FpgaConfig};
pub use engine::{
    execute_waves, execute_waves_at_depth, execute_waves_with_faults, DramChannel, WaveCost,
    WaveFault, WaveKind,
};
pub use stats::SimStats;
