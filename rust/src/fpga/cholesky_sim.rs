//! Cycle model of the Cholesky datapath (paper Fig 5).
//!
//! Columns of L are computed **sequentially** (the data dependency the
//! paper highlights); within a column, pipelines compute one nonzero row
//! each, in waves of `pipelines`. Each pipeline:
//!
//! 1. receives the broadcast of row k of L and the RA bundle of column k
//!    of A (input controller);
//! 2. fetches its own row r of L from FPGA DRAM using the RL metadata
//!    triple (start/end addresses supplied by the CPU);
//! 3. runs the dot-product PE: CAM index matching at one element/cycle,
//!    `dot_multipliers` multipliers, an adder tree;
//! 4. runs the div/sqrt PE — every pipeline computes the diagonal
//!    redundantly "to make the computation of each pipeline completely
//!    independent" (§III-B).
//!
//! Idle pipeline-cycles are tracked: the paper observes "as we increase
//! the number of pipelines, the idle cycles increase almost linearly",
//! which the `idle_grows_with_pipelines` test reproduces.
//!
//! Cholesky does **not** participate in the negotiated stream compression
//! ([`FpgaConfig::encoding`] is ignored here). The RA/RL streams are baked
//! raw at [`CholeskySymbolic::analyze`] time — the CPU measures their word
//! extents once and the RL metadata triples carry absolute DRAM addresses
//! into that raw layout — and, unlike the multiply kernels, every column's
//! L rows are *re-read* by later dependent columns, so a lossy value lane
//! would compound quantization error through the factorization chain
//! instead of bounding it per element. Keeping this datapath raw preserves
//! the dependent-stream semantics the retry model relies on.


use crate::symbolic::CholeskySymbolic;

use super::config::FpgaConfig;
use super::engine::{execute_waves, Occupancy, WaveCost, WaveKind};
use super::spgemm_sim::Style;
use super::stats::SimStats;

/// Result of simulating one Cholesky factorization.
#[derive(Clone, Debug)]
pub struct CholeskySimResult {
    pub stats: SimStats,
    /// Cycles per column (diagnostics; shows the dependency serialization).
    pub column_cycles: Vec<u64>,
    /// Engine cost sequence, one [`WaveCost`] per column (the engine's
    /// "wave" is a column here; a column's inner pipeline waves are
    /// pre-aggregated into its compute/occupancy fields).
    pub costs: Vec<WaveCost>,
}

/// Intersection size of two ascending index slices (dot-product length).
fn intersect_len(a: &[u32], b: &[u32]) -> u64 {
    let (mut i, mut j, mut n) = (0usize, 0usize, 0u64);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

/// Simulate the numeric factorization over a completed symbolic analysis.
pub fn simulate_cholesky(
    sym: &CholeskySymbolic,
    cfg: &FpgaConfig,
    style: Style,
) -> CholeskySimResult {
    let n = sym.pattern.n;
    let p = cfg.pipelines as u64;
    let m = cfg.dot_multipliers as u64;
    let mut costs = Vec::with_capacity(n);

    // adder-tree reduction latency for an m-wide multiplier bank
    let tree = (64 - (m.max(1) - 1).leading_zeros()) as u64 * cfg.add_latency;

    // RA/RL stream bytes per column, from the flat word streams
    let ra_bytes: Vec<u64> = (0..n).map(|k| sym.ra_col_bytes(k)).collect();
    let rl_bytes: Vec<u64> = (0..n).map(|k| sym.rl_col_bytes(k)).collect();

    // Raw (no RL metadata) HLS must discover where row r of L lives by
    // itself: L is only available column-major, so each row gather becomes
    // a pointer walk with halved effective element rate plus per-row setup
    // (the address arithmetic the CPU's RL triples otherwise provide).
    // Calibrated against the paper's §V-C Cholesky geomean (35%).
    let (indirection, stream_denom) = match style {
        Style::HlsRaw => (24u64, 2u64),
        _ => (0, 1),
    };

    // Cross-column pipelining: while column k's div/sqrt units drain (a
    // fixed `tree + div` tail after their last input), the input controller
    // already broadcasts column k+1's row and RA bundle — those reads
    // depend only on columns < k's stored values, not on the draining
    // divisions. Hand-coded style only; the paper's HLS toolchain could
    // not express this overlap (§V-C).
    let mut prev_tail: u64 = 0;

    for k in 0..n {
        let col_rows = sym.pattern.col_rows(k); // diagonal first
        let nk = col_rows.len() as u64;
        // row k of L restricted to columns < k (the broadcast operand)
        let row_k = sym.storage.row_cols(k);
        let row_k_head = &row_k[..row_k.len() - 1]; // strip trailing diagonal
        let len_k = row_k_head.len() as u64;

        // diagonal dot product — computed redundantly by every pipeline
        let diag_matches = len_k;
        let diag_dot = len_k.max(diag_matches.div_ceil(m.max(1))) + tree;

        // broadcast of row k + RA bundle of column k (input controller)
        let broadcast = 2 + len_k + ra_bytes[k] / 8;

        let mut wave_sum: u64 = 0;
        let mut col_busy: u64 = 0;
        let mut col_idle: u64 = 0;
        let mut row_bytes_total: u64 = 0;
        let mut matches_total: u64 = 0;
        // waves of `pipelines` rows; first row is the diagonal itself
        for wave in col_rows.chunks(cfg.pipelines) {
            let mut wave_max: u64 = 0;
            for &r in wave {
                let r = r as usize;
                let row_r = sym.storage.row_cols(r);
                // row r of L entries with column < k (already computed)
                let cut = row_r.partition_point(|&c| (c as usize) < k);
                let row_r_head = &row_r[..cut];
                let matches = intersect_len(row_r_head, row_k_head);
                matches_total += matches;
                let stream = row_r_head.len() as u64 * stream_denom + indirection;
                let mults = matches.div_ceil(m.max(1));
                let dot = stream.max(mults) + tree;
                let final_op = if r == k { cfg.sqrt_latency } else { cfg.div_latency };
                let pe = if style == Style::HandCoded {
                    // Fig 5(c): the PE is "a pipeline of processing
                    // elements" — the redundant diagonal dot and the row
                    // dot run in separate units concurrently (independent
                    // operands: the broadcast vs the private row), then
                    // feed the div/sqrt PE.
                    diag_dot.max(dot) + final_op
                } else {
                    // HLS serializes match and multiply phases
                    diag_dot + stream + matches + tree + final_op
                };
                wave_max = wave_max.max(pe);
                row_bytes_total += row_r_head.len() as u64 * 8;
            }
            wave_sum += wave_max;
            let active = wave.len() as u64;
            col_busy += active * wave_max;
            col_idle += (p - active) * wave_max;
        }
        // the broadcast (row k + RA bundle) is the column's frontend
        // setup — at depth >= 2 the input controller streams it while the
        // previous column's div/sqrt units drain; the hand-coded design
        // additionally overlaps it with the previous column's fixed tail
        // even on the serial channel (the `prev_tail` credit)
        let mut setup = broadcast;
        if style == Style::HandCoded {
            let credit = prev_tail.min(broadcast);
            setup -= credit;
            prev_tail = tree + cfg.div_latency;
        }

        // DRAM: broadcast row + per-pipeline L rows + RA + RL reads;
        // column result write-back (stays in FPGA DRAM for later columns).
        let read_bytes = len_k * 8 + row_bytes_total + ra_bytes[k] + rl_bytes[k];
        debug_assert_eq!(read_bytes % 4, 0, "RIR streams are word-aligned");
        costs.push(WaveCost {
            kind: WaveKind::Compute,
            stream_words: read_bytes / 4,
            setup_cycles: setup,
            compute_cycles: wave_sum,
            writeback_words: nk * 2,
            // column k+1's L-row fetches include entries column k writes
            // back — a RAW dependency through DRAM the channel must not
            // prefetch across, so Cholesky's stream stays serial at every
            // depth (the hand-coded `prev_tail` credit above remains the
            // only cross-column overlap)
            dependent_stream: true,
            occupancy: Occupancy::Fixed { busy: col_busy, idle: col_idle },
            // useful flops: 2/mult-add per match (row dots), plus the
            // diagonal dot once (2*len_k), one sqrt, nk-1 divides
            flops: 2 * matches_total + 2 * len_k + 1 + (nk - 1),
            waves: nk.div_ceil(p),
        });
    }

    let engine = execute_waves(&costs, cfg);
    CholeskySimResult { stats: engine.stats, column_cycles: engine.item_cycles, costs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn sym(n: usize, nnz: usize, seed: u64) -> CholeskySymbolic {
        let spd = gen::spd(gen::Family::BandedFem, n, nnz, seed);
        CholeskySymbolic::analyze(&spd.lower_triangle(), 32)
    }

    #[test]
    fn produces_nonzero_work() {
        let s = sym(60, 400, 1);
        let r = simulate_cholesky(&s, &FpgaConfig::reap32_cholesky(), Style::HandCoded);
        assert!(r.stats.cycles > 0);
        assert!(r.stats.flops > 0);
        assert_eq!(r.column_cycles.len(), 60);
        assert_eq!(r.stats.cycles, r.column_cycles.iter().sum::<u64>());
    }

    #[test]
    fn idle_grows_with_pipelines() {
        // paper: "as we increase the number of pipelines … the idle cycles
        // increase almost linearly"
        let s = sym(80, 600, 2);
        let mut prev_idle = 0u64;
        for pipes in [8usize, 16, 32, 64] {
            let mut cfg = FpgaConfig::reap32_cholesky();
            cfg.pipelines = pipes;
            let r = simulate_cholesky(&s, &cfg, Style::HandCoded);
            assert!(
                r.stats.idle_pipeline_cycles > prev_idle,
                "idle cycles must grow with pipeline count"
            );
            prev_idle = r.stats.idle_pipeline_cycles;
        }
    }

    #[test]
    fn diminishing_returns_from_more_pipelines() {
        // dependencies serialize columns: 64 pipelines help less than 2x
        // over 32 (paper: "adding more resources is not going to help")
        let s = sym(100, 900, 3);
        let mut c32 = FpgaConfig::reap32_cholesky();
        c32.dram = crate::fpga::DramConfig::sixteen_core_peak();
        let mut c64 = c32.clone();
        c64.pipelines = 64;
        let r32 = simulate_cholesky(&s, &c32, Style::HandCoded);
        let r64 = simulate_cholesky(&s, &c64, Style::HandCoded);
        assert!(r64.stats.cycles <= r32.stats.cycles);
        let speedup = r32.stats.cycles as f64 / r64.stats.cycles as f64;
        assert!(speedup < 2.0, "Cholesky cannot scale linearly: {speedup}");
    }

    #[test]
    fn hls_slower_and_raw_slowest() {
        let s = sym(50, 350, 4);
        let cfg = FpgaConfig::reap32_cholesky();
        let hand = simulate_cholesky(&s, &cfg, Style::HandCoded);
        let hls = simulate_cholesky(&s, &cfg, Style::HlsPreprocessed);
        let raw = simulate_cholesky(&s, &cfg, Style::HlsRaw);
        assert!(hls.stats.cycles >= hand.stats.cycles);
        assert!(raw.stats.cycles > hls.stats.cycles);
    }

    #[test]
    fn intersect_len_cases() {
        assert_eq!(intersect_len(&[], &[]), 0);
        assert_eq!(intersect_len(&[1, 2, 3], &[2, 3, 4]), 2);
        assert_eq!(intersect_len(&[1, 5, 9], &[2, 6, 10]), 0);
        assert_eq!(intersect_len(&[1, 2, 3], &[1, 2, 3]), 3);
    }

    #[test]
    fn flops_close_to_cpu_flop_model() {
        // sim flops and the analytic kernel flop model agree on order
        let spd = gen::spd(gen::Family::BandedFem, 64, 500, 5);
        let s = CholeskySymbolic::analyze(&spd.lower_triangle(), 32);
        let r = simulate_cholesky(&s, &FpgaConfig::reap32_cholesky(), Style::HandCoded);
        assert!(r.stats.flops > s.pattern.nnz() as u64); // at least 1/elem
    }
}
