//! The DRAM queuing model: "we use a queuing model where the data
//! transfers are not allowed to exceed the bandwidth set in the design"
//! (§V). Transfers are charged in cycles at the configured sustained
//! bandwidth; a transfer phase overlaps with compute, so a wave costs
//! `max(compute_cycles, dram_cycles)` — the streaming pipeline the RIR
//! layout makes possible.

use super::config::FpgaConfig;

/// Per-execution DRAM accounting.
#[derive(Clone, Debug, Default)]
pub struct DramModel {
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl DramModel {
    /// Cycles to read `bytes` at the configured cap (ceiling).
    pub fn read_cycles(cfg: &FpgaConfig, bytes: u64) -> u64 {
        cycles_for(bytes, cfg.read_bytes_per_cycle())
    }

    /// Cycles to write `bytes` at the configured cap (ceiling).
    pub fn write_cycles(cfg: &FpgaConfig, bytes: u64) -> u64 {
        cycles_for(bytes, cfg.write_bytes_per_cycle())
    }

    /// Charge a read and return its cycle cost.
    pub fn read(&mut self, cfg: &FpgaConfig, bytes: u64) -> u64 {
        self.bytes_read += bytes;
        Self::read_cycles(cfg, bytes)
    }

    /// Charge a write and return its cycle cost.
    pub fn write(&mut self, cfg: &FpgaConfig, bytes: u64) -> u64 {
        self.bytes_written += bytes;
        Self::write_cycles(cfg, bytes)
    }
}

fn cycles_for(bytes: u64, bytes_per_cycle: f64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    assert!(bytes_per_cycle > 0.0, "bandwidth must be positive");
    (bytes as f64 / bytes_per_cycle).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_cost_matches_bandwidth() {
        let cfg = FpgaConfig::reap32_spgemm(); // 56 B/cycle read
        assert_eq!(DramModel::read_cycles(&cfg, 0), 0);
        assert_eq!(DramModel::read_cycles(&cfg, 56), 1);
        assert_eq!(DramModel::read_cycles(&cfg, 57), 2);
        assert_eq!(DramModel::read_cycles(&cfg, 5600), 100);
    }

    #[test]
    fn asymmetric_read_write() {
        let cfg = FpgaConfig::reap64_spgemm(); // 147 / 73 GB/s @250MHz
        let r = DramModel::read_cycles(&cfg, 1_000_000);
        let w = DramModel::write_cycles(&cfg, 1_000_000);
        assert!(w > r, "write bandwidth is lower, cycles must be higher");
    }

    #[test]
    fn accounting_accumulates() {
        let cfg = FpgaConfig::reap32_spgemm();
        let mut d = DramModel::default();
        d.read(&cfg, 100);
        d.read(&cfg, 50);
        d.write(&cfg, 30);
        assert_eq!(d.bytes_read, 150);
        assert_eq!(d.bytes_written, 30);
    }
}
