//! The DRAM queuing model: "we use a queuing model where the data
//! transfers are not allowed to exceed the bandwidth set in the design"
//! (§V). Transfers are charged in cycles at the configured sustained
//! bandwidth; a transfer phase overlaps with compute, so a wave costs
//! `max(compute_cycles, dram_cycles)` — the streaming pipeline the RIR
//! layout makes possible.

use super::config::FpgaConfig;

/// Per-execution DRAM accounting.
#[derive(Clone, Debug, Default)]
pub struct DramModel {
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl DramModel {
    /// Cycles to read `bytes` at the configured cap (ceiling).
    pub fn read_cycles(cfg: &FpgaConfig, bytes: u64) -> u64 {
        cycles_for(bytes, cfg.read_bytes_per_cycle())
    }

    /// Cycles to write `bytes` at the configured cap (ceiling).
    pub fn write_cycles(cfg: &FpgaConfig, bytes: u64) -> u64 {
        cycles_for(bytes, cfg.write_bytes_per_cycle())
    }

    /// Charge a read and return its cycle cost.
    pub fn read(&mut self, cfg: &FpgaConfig, bytes: u64) -> u64 {
        self.bytes_read += bytes;
        Self::read_cycles(cfg, bytes)
    }

    /// Charge a write and return its cycle cost.
    pub fn write(&mut self, cfg: &FpgaConfig, bytes: u64) -> u64 {
        self.bytes_written += bytes;
        Self::write_cycles(cfg, bytes)
    }
}

/// Fixed-point fractional bits for the bandwidth denominator: bandwidth
/// is quantized to 1/65536 byte/cycle, far below any model's resolution.
const BPC_FRAC_BITS: u32 = 16;

/// Integer ceiling division over a fixed-point bytes-per-cycle.
///
/// The previous float formulation `(bytes as f64 / bpc).ceil() as u64`
/// silently lost precision once `bytes` exceeded 2^53 (multi-GB batched
/// streams summed over a run make that reachable): `2^53 + 1` as f64
/// rounds to `2^53`, undercounting a cycle. All arithmetic here is exact
/// in u128 — `bytes << 16` fits comfortably for any u64 byte count.
fn cycles_for(bytes: u64, bytes_per_cycle: f64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    assert!(bytes_per_cycle > 0.0, "bandwidth must be positive");
    let bpc_fp = (bytes_per_cycle * (1u64 << BPC_FRAC_BITS) as f64).round() as u128;
    assert!(bpc_fp > 0, "bandwidth underflows the fixed-point resolution");
    let num = (bytes as u128) << BPC_FRAC_BITS;
    u64::try_from(num.div_ceil(bpc_fp)).expect("cycle count exceeds u64")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_cost_matches_bandwidth() {
        let cfg = FpgaConfig::reap32_spgemm(); // 56 B/cycle read
        assert_eq!(DramModel::read_cycles(&cfg, 0), 0);
        assert_eq!(DramModel::read_cycles(&cfg, 56), 1);
        assert_eq!(DramModel::read_cycles(&cfg, 57), 2);
        assert_eq!(DramModel::read_cycles(&cfg, 5600), 100);
    }

    #[test]
    fn asymmetric_read_write() {
        let cfg = FpgaConfig::reap64_spgemm(); // 147 / 73 GB/s @250MHz
        let r = DramModel::read_cycles(&cfg, 1_000_000);
        let w = DramModel::write_cycles(&cfg, 1_000_000);
        assert!(w > r, "write bandwidth is lower, cycles must be higher");
    }

    #[test]
    fn precision_boundary_above_2_pow_53() {
        // 2^53 + 1 is not representable in f64: the old float path
        // computed ceil((2^53) / 1.0) and dropped a cycle
        let bytes = (1u64 << 53) + 1;
        assert_eq!(cycles_for(bytes, 1.0), bytes);
        // ... and at realistic bandwidth the exact quotient is preserved
        let bpc = 56.0; // REAP-32 read
        let expect = ((bytes as u128) * 65536).div_ceil(56 * 65536) as u64;
        assert_eq!(cycles_for(bytes, bpc), expect);
        // whole-range sanity: u64::MAX must not overflow or panic
        let top = cycles_for(u64::MAX, bpc);
        assert_eq!(top, ((u64::MAX as u128) * 65536).div_ceil(56 * 65536) as u64);
    }

    #[test]
    fn matches_float_model_below_the_boundary() {
        // for exactly-representable bandwidths and small byte counts the
        // fixed-point result equals the old float ceiling
        for bpc in [1.0f64, 56.0, 292.0, 588.0] {
            for bytes in [1u64, 55, 56, 57, 1000, 5600, 123_457, 1 << 30] {
                let float = (bytes as f64 / bpc).ceil() as u64;
                assert_eq!(cycles_for(bytes, bpc), float, "bytes {bytes} bpc {bpc}");
            }
        }
    }

    #[test]
    fn fractional_bandwidth_rounds_up_cycles() {
        // 668.18… B/cycle (REAP-128 read at 220 MHz): one extra byte past
        // a cycle boundary must cost a full extra cycle
        let bpc = 147.0e9 / 220.0e6;
        assert_eq!(cycles_for(668, bpc), 1);
        assert_eq!(cycles_for(669, bpc), 2);
    }

    #[test]
    fn accounting_accumulates() {
        let cfg = FpgaConfig::reap32_spgemm();
        let mut d = DramModel::default();
        d.read(&cfg, 100);
        d.read(&cfg, 50);
        d.write(&cfg, 30);
        assert_eq!(d.bytes_read, 150);
        assert_eq!(d.bytes_written, 30);
    }
}
