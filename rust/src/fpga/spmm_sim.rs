//! Cycle model of the SpMM extension datapath — `C = A · X` with a dense
//! `k`-column right-hand-side panel.
//!
//! The design is the SpMV datapath (`spmv_sim`) with each pipeline PE
//! widened to [`FpgaConfig::vector_lanes`] parallel MAC lanes: one
//! streamed A element gathers one contiguous X-panel row segment and feeds
//! every lane in the same cycle, so a column block as wide as the lanes
//! runs at the **same stream rate as a single SpMV** while doing
//! `lanes ×` the flops. Columns beyond the lane width replay the wave
//! schedule once per column block — the schedule itself is built once on
//! the CPU and reused (the Sparse Stream Semantic Registers argument:
//! amortize one stream schedule over many dense right-hand sides).
//!
//! Versus `k` independent SpMV runs, the model charges:
//!
//! * the A row-bundle stream `ceil(k / lanes)` times instead of `k` times
//!   (both cycles and DRAM bytes — the headline amortization), and
//! * **more** panel bytes than `k` raw x-vector loads: the panel streams
//!   in the RIR dense-panel layout (`rir::layout::dense_panel_words` —
//!   2 header words per chunk plus a lane-index word per element, so
//!   `(2·⌈kb/bs⌉ + 2·kb)` words per panel row per block versus `k` raw
//!   words for `k` x-loads, roughly 2× at the default geometry). The
//!   A-stream saving dominates that overhead by construction — the
//!   strict cycle/byte win is asserted, not assumed, in the tests below
//!   and by `harness::spmm::headline_holds` (`reap bench spmm`) for
//!   k ∈ {4, 8} on REAP-64/128.
//!
//! The per-wave accounting itself is `spmv_sim::row_stream_wave_cost` —
//! the *same function* the SpMV simulator uses (`kb == 1`), so the two
//! models the comparison races cannot drift apart; the resulting
//! [`WaveCost`] sequence is priced by the unified engine
//! ([`crate::fpga::engine`]), where a depth ≥ 2 DRAM channel prefetches
//! the next block's panel under the current block's compute.

use crate::rir::layout::encoded_dense_panel_words;
use crate::rir::schedule::SpgemmSchedule;
use crate::sparse::Csr;

use super::config::FpgaConfig;
use super::engine::{execute_waves, WaveCost, WaveKind};
use super::spgemm_sim::Style;
use super::spmv_sim::row_stream_wave_cost;
use super::stats::SimStats;

/// Result of simulating one SpMM execution.
#[derive(Clone, Debug)]
pub struct SpmmSimResult {
    pub stats: SimStats,
    /// Number of column blocks (`ceil(k / vector_lanes)`); the wave
    /// schedule replays once per block.
    pub n_blocks: usize,
    /// Cycles of the per-block dense-panel loads, summed (each block's
    /// panel streams into on-chip RAM before its first wave — and, at
    /// `dram_buffer_depth >= 2`, *under* the previous block's compute,
    /// which can drive this to zero).
    pub panel_load_cycles: u64,
    /// Cycle count per replayed wave, block-major:
    /// `n_blocks × schedule.n_waves()` entries, and
    /// `panel_load_cycles + Σ wave_cycles == stats.cycles` at every depth.
    pub wave_cycles: Vec<u64>,
    /// Engine cost sequence (each block: one panel [`WaveKind::Load`]
    /// followed by the block's waves).
    pub costs: Vec<WaveCost>,
}

/// Simulate `C = A X` with `k` dense right-hand-side columns over the
/// chunk schedule (the same SpGEMM-scheduler wave structure SpMV reuses;
/// the B-stream list is ignored — the panel lives on-chip per block).
/// The per-wave DRAM/compute overlap is owned by [`crate::fpga::engine`].
pub fn simulate_spmm(
    a: &Csr,
    schedule: &SpgemmSchedule,
    cfg: &FpgaConfig,
    style: Style,
    k: usize,
) -> SpmmSimResult {
    assert!(k > 0, "SpMM needs at least one right-hand-side column");
    let lanes = cfg.vector_lanes.max(1);
    let n_blocks = k.div_ceil(lanes);
    let mut costs = Vec::with_capacity(n_blocks * (schedule.waves.len() + 1));

    for blk in 0..n_blocks {
        let kb = (k - blk * lanes).min(lanes) as u64;

        // per-block panel load into on-chip RAM (cf. spmv_sim's x load).
        // Each block streams its own kb-wide sub-panel in the RIR
        // dense-panel layout — byte-for-byte the segment
        // `encode_csr_with_panel` produces for a kb-column panel. Note
        // for k > lanes this is NOT a slice of one full-k segment (the
        // header count differs once k spans multiple bundles); the model
        // assumes the CPU encodes one sub-panel per block, which bounds
        // the on-chip panel RAM at `lanes` columns per buffer — at
        // `dram_buffer_depth >= 2` the next block's panel prefetches into
        // the channel's spare buffer while the current one is in use, so
        // depth-2 designs carry two such panel buffers (the standard
        // double-buffering RAM cost, ~2 × lanes × nrows words, well
        // inside the Arria-10's 67 Mbit for the suite's sizes). The panel
        // is a real RIR segment, so it is priced at its encoded size under
        // the negotiated `cfg.encoding` — contiguous lane chains compress
        // especially well under bitmap index sections.
        costs.push(WaveCost::load(
            encoded_dense_panel_words(a.ncols, kb as usize, cfg.bundle_size, cfg.encoding) as u64,
        ));

        // replay the wave schedule with kb-wide lanes — the shared
        // accounting the SpMV model runs with kb == 1
        for wave in &schedule.waves {
            costs.push(row_stream_wave_cost(a, wave, cfg, style, kb));
        }
    }

    let engine = execute_waves(&costs, cfg);
    let mut panel_load_cycles = 0u64;
    let mut wave_cycles = Vec::with_capacity(n_blocks * schedule.waves.len());
    for (c, &cy) in costs.iter().zip(&engine.item_cycles) {
        match c.kind {
            WaveKind::Load => panel_load_cycles += cy,
            WaveKind::Compute => wave_cycles.push(cy),
        }
    }
    SpmmSimResult { stats: engine.stats, n_blocks, panel_load_cycles, wave_cycles, costs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::spmv_sim::simulate_spmv;
    use crate::rir::schedule::schedule_spgemm;
    use crate::sparse::gen;

    fn schedule_for(a: &Csr, cfg: &FpgaConfig) -> SpgemmSchedule {
        schedule_spgemm(a, &Csr::new(a.ncols, a.ncols), cfg.pipelines, cfg.bundle_size)
    }

    #[test]
    fn conservation_laws() {
        let a = gen::random_uniform(400, 400, 6000, 3);
        let cfg = FpgaConfig::reap64_spgemm();
        let s = schedule_for(&a, &cfg);
        for k in [1usize, 4, 8, 20] {
            let r = simulate_spmm(&a, &s, &cfg, Style::HandCoded, k);
            assert_eq!(r.stats.flops, 2 * 6000 * k as u64, "k {k}");
            assert_eq!(r.n_blocks, k.div_ceil(cfg.vector_lanes), "k {k}");
            assert_eq!(r.wave_cycles.len(), r.n_blocks * s.n_waves(), "k {k}");
            assert_eq!(
                r.panel_load_cycles + r.wave_cycles.iter().sum::<u64>(),
                r.stats.cycles,
                "k {k}: wave log + panel loads must sum to total"
            );
            assert_eq!(
                r.stats.compute_bound_cycles + r.stats.dram_bound_cycles,
                r.stats.cycles
            );
            assert_eq!(
                r.stats.busy_pipeline_cycles + r.stats.idle_pipeline_cycles,
                cfg.pipelines as u64 * (r.stats.cycles - r.panel_load_cycles)
            );
        }
    }

    #[test]
    fn beats_k_independent_spmvs_on_wide_designs() {
        // the acceptance headline: strictly fewer cycles AND fewer DRAM
        // bytes than k serial SpMV runs, for k in {4, 8}, on REAP-64/128
        let a = gen::banded_fem(600, 5400, 7);
        for cfg in [FpgaConfig::reap64_spgemm(), FpgaConfig::reap128_spgemm()] {
            let s = schedule_for(&a, &cfg);
            let spmv = simulate_spmv(&a, &s, &cfg, Style::HandCoded);
            for k in [4usize, 8] {
                let spmm = simulate_spmm(&a, &s, &cfg, Style::HandCoded, k);
                let serial_cycles = spmv.stats.cycles * k as u64;
                assert!(
                    spmm.stats.cycles < serial_cycles,
                    "{} k {k}: {} !< {}",
                    cfg.name,
                    spmm.stats.cycles,
                    serial_cycles
                );
                assert!(
                    spmm.stats.bytes_read < spmv.stats.bytes_read * k as u64,
                    "{} k {k}: A stream must amortize",
                    cfg.name
                );
                // same useful work
                assert_eq!(spmm.stats.flops, spmv.stats.flops * k as u64);
            }
        }
    }

    #[test]
    fn blocks_scale_past_the_lane_width() {
        let a = gen::random_uniform(200, 200, 2400, 11);
        let cfg = FpgaConfig::reap64_spgemm();
        let s = schedule_for(&a, &cfg);
        let one = simulate_spmm(&a, &s, &cfg, Style::HandCoded, cfg.vector_lanes);
        let two = simulate_spmm(&a, &s, &cfg, Style::HandCoded, 2 * cfg.vector_lanes);
        assert_eq!(two.n_blocks, 2 * one.n_blocks);
        // a second block re-streams A: more cycles, but less than 2x+1
        // blocks' worth of serial SpMV (the panel amortizes within blocks)
        assert!(two.stats.cycles > one.stats.cycles);
        assert_eq!(two.stats.flops, 2 * one.stats.flops);
    }

    #[test]
    fn panel_traffic_is_one_sub_panel_encode_per_block() {
        // the panel bytes the model charges are exactly the dense-panel
        // segments of one kb-wide sub-panel encode per block — pinned
        // for a multi-block k (8 + 8 + 4) where this is NOT the same as
        // one full-k segment's bytes
        let a = gen::random_uniform(150, 150, 1800, 17);
        let cfg = FpgaConfig::reap64_spgemm();
        let s = schedule_for(&a, &cfg);
        let k = 2 * cfg.vector_lanes + 4;
        let r = simulate_spmm(&a, &s, &cfg, Style::HandCoded, k);
        let a_stream_bytes = r.n_blocks as u64 * (s.a_words * 4) as u64;
        let panel_bytes: usize = [cfg.vector_lanes, cfg.vector_lanes, 4]
            .iter()
            .map(|&kb| crate::rir::layout::dense_panel_bytes(a.ncols, kb, cfg.bundle_size))
            .sum();
        assert_eq!(r.n_blocks, 3);
        assert_eq!(r.stats.bytes_read, a_stream_bytes + panel_bytes as u64);
    }

    #[test]
    fn hls_raw_slower() {
        let a = gen::random_uniform(300, 300, 4000, 13);
        let cfg = FpgaConfig::reap32_spgemm();
        let s = schedule_for(&a, &cfg);
        let hand = simulate_spmm(&a, &s, &cfg, Style::HandCoded, 8);
        let raw = simulate_spmm(&a, &s, &cfg, Style::HlsRaw, 8);
        assert!(raw.stats.cycles > hand.stats.cycles);
    }

    #[test]
    fn compressed_encodings_win_on_panel_dominated_workloads() {
        use crate::rir::layout::StreamEncoding;
        // wide rectangular A: the dense panel dominates the traffic, so
        // encoded panels translate directly into cycle wins (the
        // `reap bench compression` headline shape)
        let a = gen::random_uniform(64, 4800, 512, 23);
        for base in [FpgaConfig::reap64_spgemm(), FpgaConfig::reap128_spgemm()] {
            let s = schedule_for(&a, &base);
            let raw = simulate_spmm(&a, &s, &base, Style::HandCoded, 8);
            for enc in [StreamEncoding::Bitmap, StreamEncoding::Fx, StreamEncoding::BitmapFx] {
                let cfg = FpgaConfig { encoding: enc, ..base.clone() };
                let r = simulate_spmm(&a, &s, &cfg, Style::HandCoded, 8);
                assert!(
                    r.stats.bytes_read < raw.stats.bytes_read,
                    "{} {enc}: bytes must shrink",
                    base.name
                );
                assert!(
                    r.stats.cycles < raw.stats.cycles,
                    "{} {enc}: {} !< {}",
                    base.name,
                    r.stats.cycles,
                    raw.stats.cycles
                );
                assert_eq!(r.stats.flops, raw.stats.flops, "same useful work");
                assert_eq!(r.stats.bytes_written, raw.stats.bytes_written, "raw writeback");
            }
        }
    }

    #[test]
    fn empty_matrix_costs_only_panel_loads() {
        let a = Csr::new(100, 100);
        let cfg = FpgaConfig::reap32_spgemm();
        let s = schedule_for(&a, &cfg);
        let r = simulate_spmm(&a, &s, &cfg, Style::HandCoded, 8);
        assert_eq!(r.stats.waves, 0);
        assert_eq!(r.stats.cycles, r.panel_load_cycles);
        assert_eq!(
            r.stats.bytes_read as usize,
            crate::rir::layout::dense_panel_bytes(100, 8, cfg.bundle_size)
        );
    }
}
