//! The unified wave engine: one execution loop owns the DRAM/compute
//! overlap semantics for **all** cycle models (SpGEMM, batched SpGEMM,
//! SpMV, SpMM, Cholesky).
//!
//! Each simulator describes its run as a sequence of [`WaveCost`]s —
//! stream words in, setup + compute cycles, writeback words out — and
//! [`execute_waves`] turns that description into per-wave cycle deltas and
//! an aggregate [`SimStats`]. The payoff is twofold: the five models
//! cannot drift apart in their overlap accounting (they no longer have
//! any), and the DRAM frontend becomes a real, configurable component —
//! the [`DramChannel`] with buffer depth
//! [`FpgaConfig::dram_buffer_depth`]:
//!
//! * **depth 1** (single-buffered, the pre-refactor behavior): wave *k*'s
//!   stream cannot begin until wave *k−1* retired; within the wave the
//!   stream, compute and writeback overlap (the datapath consumes the
//!   stream as it arrives), so the wave costs
//!   `max(setup + compute, dram)` — bit-identical to the hand-rolled
//!   accounting every simulator used to carry.
//! * **depth 2** (double-buffered prefetch): the channel fetches wave
//!   *k+1*'s RIR/B-stream into the spare buffer — and the input
//!   controller loads the spare CAM bank / bundle headers
//!   ([`WaveCost::setup_cycles`]) — while wave *k* computes. Frontend
//!   work that lands under a previous wave's compute is counted in
//!   [`SimStats::prefetch_hidden_cycles`].
//! * **depth d** generalizes: the channel runs up to `d − 1` waves ahead
//!   of the compute backend.
//!
//! The engine maintains the invariant (tested here and in
//! `tests/engine_golden.rs`):
//!
//! ```text
//! cycles(depth d) + prefetch_hidden_cycles(depth d) == cycles(depth 1)
//! ```
//!
//! so deeper buffering is monotonically non-slower, and the hidden-cycle
//! counter is exactly the cycles the prefetch bought. DRAM traffic
//! (bytes read/written) is depth-invariant by construction.
//!
//! # Fault detection and replay
//!
//! The streamed RIR words may carry a per-bundle CRC32
//! ([`crate::rir::bundle::BundleFlags::CHECKSUM`]); the input controller
//! verifies each bundle before committing it to a CAM bank, and a
//! mismatch aborts the wave and triggers a re-fetch.
//! [`execute_waves_with_faults`] models this: each wave may carry a
//! [`WaveFault`] saying how many times its stream had to be replayed.
//! Every replay re-runs the wave at its full serial (depth-1) cost — the
//! corrupted fetch cannot overlap the *next* wave because the wave never
//! retired — and is charged to [`SimStats::retry_cycles`], so the ledger
//! is exact at every depth:
//!
//! ```text
//! cycles(faults) == cycles(no faults) + retry_cycles
//! ```
//!
//! DRAM *traffic* stays fault-invariant (the re-fetched bytes are not
//! added to `bytes_read`): the counters model useful data movement, and
//! keeping them fault-free preserves the batch partition laws and the
//! depth-invariance of traffic. Time is charged; traffic is not. A wave
//! whose retries exhausted [`FpgaConfig::max_wave_retries`] is reported
//! in [`EngineResult::failed_waves`] so callers (the batch coordinator)
//! can fail just the affected jobs instead of the whole run.

use crate::rir::layout::WORD_BYTES;

use super::config::FpgaConfig;
use super::dram::DramModel;
use super::stats::SimStats;

/// What a sequence item represents to the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WaveKind {
    /// A scheduling wave: occupies pipelines, counts toward
    /// [`SimStats::waves`], retires in at least one cycle.
    Compute,
    /// A pure DRAM stream with no compute behind it (the SpMV x-vector
    /// load, SpMM's per-block dense-panel loads): holds no pipelines,
    /// counts no wave, and may cost zero cycles when empty. At depth ≥ 2
    /// a `Load` prefetches under the preceding waves' compute like any
    /// other stream.
    Load,
}

/// Pipeline-occupancy accounting for one wave.
///
/// The wave-granular models (SpGEMM, batch, SpMV, SpMM) charge
/// busy/idle proportionally to the wave's cycle delta; the Cholesky model
/// tracks busy/idle at sub-column (inner-wave) granularity and hands the
/// engine precomputed totals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Occupancy {
    /// `active` pipelines are busy for the wave's whole cycle delta, the
    /// remaining `cfg.pipelines − active` are idle.
    ActivePipelines(u64),
    /// Fixed pipeline-cycle totals, independent of the wave's delta.
    Fixed { busy: u64, idle: u64 },
}

/// Cost description of one wave, emitted by a simulator and consumed by
/// [`execute_waves`]. All DRAM traffic is in RIR words
/// ([`WORD_BYTES`]-byte); the engine converts to bytes against the
/// design's bandwidth caps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WaveCost {
    pub kind: WaveKind,
    /// RIR words streamed from DRAM for this wave (A chunks + B/RA/RL
    /// segments).
    pub stream_words: u64,
    /// Frontend setup cycles — CAM/bundle-header loading that a depth ≥ 2
    /// channel performs on the spare buffer while the previous wave
    /// computes. At depth 1 they serialize ahead of `compute_cycles`
    /// (`setup + compute` is exactly the pre-refactor per-wave compute).
    pub setup_cycles: u64,
    /// Backend compute occupancy (max over pipelines), excluding setup.
    pub compute_cycles: u64,
    /// RIR words written back to DRAM.
    pub writeback_words: u64,
    /// The stream reads data the *previous* wave's writeback produces
    /// (Cholesky: column *k+1*'s L-row fetches include the entries column
    /// *k* writes back), so the channel must not prefetch it — the fetch
    /// serializes behind the previous wave's retire at every depth,
    /// keeping the RAW dependency through DRAM intact. False for all
    /// stream-level workloads (their waves read only CPU-produced RIR).
    pub dependent_stream: bool,
    /// Busy/idle pipeline-cycle accounting.
    pub occupancy: Occupancy,
    /// Useful FP operations this wave performs.
    pub flops: u64,
    /// Scheduling waves this item adds to [`SimStats::waves`] (1 for a
    /// normal wave, 0 for a `Load`, `⌈nk/p⌉` for a Cholesky column).
    pub waves: u64,
}

impl WaveCost {
    /// A pure DRAM load of `stream_words` (no compute, no pipelines).
    pub fn load(stream_words: u64) -> Self {
        WaveCost {
            kind: WaveKind::Load,
            stream_words,
            setup_cycles: 0,
            compute_cycles: 0,
            writeback_words: 0,
            dependent_stream: false,
            occupancy: Occupancy::Fixed { busy: 0, idle: 0 },
            flops: 0,
            waves: 0,
        }
    }

    /// The wave's cost under the serial (depth-1) channel:
    /// `max(setup + compute, dram)`, at least 1 cycle for a compute wave.
    pub fn serial_cycles(&self, cfg: &FpgaConfig) -> u64 {
        let dram_cy = self.dram_cycles(cfg);
        let cy = (self.setup_cycles + self.compute_cycles).max(dram_cy);
        match self.kind {
            WaveKind::Compute => cy.max(1),
            WaveKind::Load => cy,
        }
    }

    /// DRAM channel occupancy of this wave: `max(read, write)` cycles at
    /// the design's bandwidth caps (reads and writes ride separate
    /// directions of the interface, so they overlap each other).
    pub fn dram_cycles(&self, cfg: &FpgaConfig) -> u64 {
        let read = DramModel::read_cycles(cfg, words_to_bytes(self.stream_words));
        let write = DramModel::write_cycles(cfg, words_to_bytes(self.writeback_words));
        read.max(write)
    }
}

/// Stream-fault outcome of one wave, drawn by
/// [`crate::reliability::draw_wave_faults`] (or constructed directly in
/// tests) and consumed by [`execute_waves_with_faults`].
///
/// `retries` is the number of times the wave's stream was re-fetched and
/// replayed after a checksum mismatch — at most
/// [`FpgaConfig::max_wave_retries`]. `failed` marks a wave whose
/// corruption persisted past the retry budget; the engine still charges
/// its retries and advances (the hardware drops the wave's partials and
/// moves on), reporting the index in [`EngineResult::failed_waves`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WaveFault {
    /// Replays charged to this wave (each at full serial cost).
    pub retries: u64,
    /// The wave exhausted its retry budget and produced no usable result.
    pub failed: bool,
}

/// Exact word→byte widening (a word count that cannot be carried in bytes
/// must abort, not wrap).
fn words_to_bytes(words: u64) -> u64 {
    words
        .checked_mul(WORD_BYTES as u64)
        .expect("stream word count exceeds u64 byte accounting range")
}

/// The DRAM stream frontend: fetches wave payloads in order, running up
/// to `depth − 1` waves ahead of the compute backend (depth 1 = no
/// prefetch, today's serial behavior; depth 2 = double buffering).
#[derive(Clone, Debug)]
pub struct DramChannel {
    depth: usize,
    fetch_done: u64,
}

impl DramChannel {
    /// A channel with `depth` wave buffers. Zero is rejected by
    /// [`FpgaConfig::validate`]; the constructor enforces it too.
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1, "dram_buffer_depth must be >= 1 (see FpgaConfig::validate)");
        DramChannel { depth, fetch_done: 0 }
    }

    /// Buffer depth in waves.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Admit the next wave's fetch of `dram_cy` channel-cycles.
    /// `slot_free_at` is the retire time of the wave whose buffer slot
    /// this fetch reuses (wave `k − depth`; 0 when no such wave exists).
    /// Returns `(fetch_start, fetch_done)`.
    fn fetch(&mut self, dram_cy: u64, slot_free_at: u64) -> (u64, u64) {
        let start = self.fetch_done.max(slot_free_at);
        self.fetch_done = start + dram_cy;
        (start, self.fetch_done)
    }
}

/// Result of one engine execution.
#[derive(Clone, Debug)]
pub struct EngineResult {
    /// Aggregate statistics (including `prefetch_hidden_cycles`).
    pub stats: SimStats,
    /// Per-item cycle deltas (`finish[k] − finish[k−1]`), parallel to the
    /// input cost sequence; they sum to `stats.cycles` at every depth.
    pub item_cycles: Vec<u64>,
    /// Indices of waves whose stream corruption survived every retry
    /// ([`WaveFault::failed`]); empty on the fault-free paths.
    pub failed_waves: Vec<usize>,
}

/// Execute a wave sequence on the design's channel depth
/// ([`FpgaConfig::dram_buffer_depth`]).
pub fn execute_waves(costs: &[WaveCost], cfg: &FpgaConfig) -> EngineResult {
    execute_waves_at_depth(costs, cfg, cfg.dram_buffer_depth)
}

/// Execute a wave sequence at an explicit channel depth (used by the
/// coordinators and harnesses to report serial vs double-buffered cycles
/// side by side from one simulated cost sequence).
///
/// Timing recurrence (`finish[<0] = 0`):
///
/// ```text
/// fetch_start[k] = max(fetch_done[k-1], finish[k-depth])   // slot reuse
/// fetch_done[k]  = fetch_start[k] + dram[k]
/// setup_done[k]  = fetch_start[k] + setup[k]               // spare bank
/// finish[k]      = max( max(setup_done[k], finish[k-1]) + compute[k],
///                       fetch_done[k] )                    // streaming
/// ```
///
/// (compute waves additionally retire no faster than one cycle). At depth
/// 1 the slot constraint forces `fetch_start[k] = finish[k-1]`, which
/// collapses the recurrence to `finish[k] = finish[k-1] +
/// max(setup + compute, dram)` — exactly the serial per-wave model every
/// simulator used before the refactor.
pub fn execute_waves_at_depth(costs: &[WaveCost], cfg: &FpgaConfig, depth: usize) -> EngineResult {
    execute_waves_with_faults(costs, cfg, depth, None)
}

/// Execute a wave sequence with per-wave stream-fault outcomes.
///
/// `faults`, when present, must be parallel to `costs`. Each wave is
/// first timed exactly as on the fault-free path; its
/// [`WaveFault::retries`] replays are then appended at the wave's full
/// serial cost and charged to [`SimStats::retry_cycles`] (see the module
/// docs for the exact ledger law and why DRAM traffic stays
/// fault-invariant). `faults == None` — and equally a slice of
/// all-default [`WaveFault`]s — is bit-identical to
/// [`execute_waves_at_depth`].
pub fn execute_waves_with_faults(
    costs: &[WaveCost],
    cfg: &FpgaConfig,
    depth: usize,
    faults: Option<&[WaveFault]>,
) -> EngineResult {
    if let Some(f) = faults {
        assert_eq!(f.len(), costs.len(), "engine: fault slice must be parallel to the cost slice");
    }
    let p = cfg.pipelines as u64;
    let mut channel = DramChannel::new(depth);
    let mut stats = SimStats::default();
    let mut item_cycles = Vec::with_capacity(costs.len());
    // finish times of every retired item (the slot constraint looks back
    // `depth` items)
    let mut dones: Vec<u64> = Vec::with_capacity(costs.len());
    let mut failed_waves = Vec::new();
    let mut finish: u64 = 0;

    for (k, c) in costs.iter().enumerate() {
        let dram_cy = c.dram_cycles(cfg);
        let mut slot_free_at = if k >= depth { dones[k - depth] } else { 0 };
        if c.dependent_stream {
            // RAW through DRAM: the stream reads the previous wave's
            // writeback, so it cannot start before that wave retires —
            // such items gain nothing from prefetch at any depth
            slot_free_at = slot_free_at.max(finish);
        }
        let (fetch_start, fetch_done) = channel.fetch(dram_cy, slot_free_at);
        let setup_done = fetch_start + c.setup_cycles;
        let compute_done = setup_done.max(finish) + c.compute_cycles;
        let mut fin = compute_done.max(fetch_done);
        if c.kind == WaveKind::Compute {
            fin = fin.max(finish + 1);
        }
        let delta0 = fin - finish;
        let serial = c.serial_cycles(cfg);
        debug_assert!(
            delta0 <= serial,
            "engine: wave {k} delta {delta0} exceeds its serial cost {serial}"
        );
        // Replays: each re-runs the wave at its full serial cost and
        // cannot overlap anything (the wave never retired, so nothing
        // downstream can start). The fetch/retire recurrence below stays
        // on the fault-free timeline — every wave after the replay shifts
        // uniformly — which is what makes the retry ledger exact at every
        // depth: cycles(faults) == cycles(no faults) + retry_cycles.
        let fault = faults.map_or(WaveFault::default(), |f| f[k]);
        debug_assert!(
            fault.retries <= cfg.max_wave_retries as u64,
            "engine: wave {k} carries {} retries, over FpgaConfig::max_wave_retries = {}",
            fault.retries,
            cfg.max_wave_retries
        );
        let retry_cy = fault.retries * serial;
        let delta = delta0 + retry_cy;
        stats.prefetch_hidden_cycles += serial.saturating_sub(delta0);
        stats.retry_cycles += retry_cy;
        stats.cycles += delta;
        if c.setup_cycles + c.compute_cycles >= dram_cy {
            stats.compute_bound_cycles += delta;
        } else {
            stats.dram_bound_cycles += delta;
        }
        match c.occupancy {
            Occupancy::ActivePipelines(active) => {
                // replays re-occupy the same pipelines, so busy/idle are
                // charged over the full (retry-inclusive) delta
                let idle = p
                    .checked_sub(active)
                    .expect("wave overfilled: more active pipelines than the design has");
                stats.busy_pipeline_cycles += active * delta;
                stats.idle_pipeline_cycles += idle * delta;
            }
            Occupancy::Fixed { busy, idle } => {
                stats.busy_pipeline_cycles += busy;
                stats.idle_pipeline_cycles += idle;
            }
        }
        // traffic/flops/waves are fault-invariant: the counters model
        // useful data movement and work (see the module docs)
        stats.bytes_read += words_to_bytes(c.stream_words);
        stats.bytes_written += words_to_bytes(c.writeback_words);
        stats.flops += c.flops;
        stats.waves += c.waves;
        if fault.failed {
            failed_waves.push(k);
        }
        item_cycles.push(delta);
        dones.push(fin);
        finish = fin;
    }

    EngineResult { stats, item_cycles, failed_waves }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_at(depth: usize) -> FpgaConfig {
        // REAP-32: 56 read-bytes/cycle, 56 write-bytes/cycle
        FpgaConfig { dram_buffer_depth: depth, ..FpgaConfig::reap32_spgemm() }
    }

    fn wave(setup: u64, compute: u64, stream_words: u64, writeback_words: u64) -> WaveCost {
        WaveCost {
            kind: WaveKind::Compute,
            stream_words,
            setup_cycles: setup,
            compute_cycles: compute,
            writeback_words,
            dependent_stream: false,
            occupancy: Occupancy::ActivePipelines(4),
            flops: 10,
            waves: 1,
        }
    }

    #[test]
    fn depth1_matches_the_serial_per_wave_model() {
        let cfg = cfg_at(1);
        let costs = vec![
            wave(32, 500, 1400, 100), // 1400 words = 5600 B = 100 read cycles
            wave(16, 40, 14_000, 0),  // dram-bound: 1000 read cycles
            WaveCost::load(700),      // 50 cycles, pure stream
            wave(0, 0, 0, 0),         // degenerate wave still takes 1 cycle
        ];
        let r = execute_waves(&costs, &cfg);
        let serial: Vec<u64> = costs.iter().map(|c| c.serial_cycles(&cfg)).collect();
        assert_eq!(r.item_cycles, serial);
        assert_eq!(serial, vec![532, 1000, 50, 1]);
        assert_eq!(r.stats.cycles, 532 + 1000 + 50 + 1);
        assert_eq!(r.stats.prefetch_hidden_cycles, 0, "depth 1 hides nothing");
        assert_eq!(r.stats.waves, 3);
        assert_eq!(r.stats.compute_bound_cycles, 532 + 1);
        assert_eq!(r.stats.dram_bound_cycles, 1000 + 50);
        assert_eq!(r.stats.bytes_read, (1400 + 14_000 + 700) * 4);
        assert_eq!(r.stats.bytes_written, 100 * 4);
        assert_eq!(r.stats.flops, 30);
    }

    #[test]
    fn depth2_hides_setup_under_previous_compute() {
        // two compute-bound waves: depth 2 loads wave 1's CAM while wave 0
        // computes, saving exactly wave 1's setup cycles
        let costs = vec![wave(32, 500, 140, 0), wave(32, 500, 140, 0)];
        let d1 = execute_waves(&costs, &cfg_at(1));
        let d2 = execute_waves(&costs, &cfg_at(2));
        assert_eq!(d1.stats.cycles, 2 * 532);
        assert_eq!(d2.stats.cycles, 532 + 500);
        assert_eq!(d2.stats.prefetch_hidden_cycles, 32);
        assert_eq!(d2.item_cycles, vec![532, 500]);
    }

    #[test]
    fn depth2_hides_a_load_entirely() {
        // a panel load between two long compute waves disappears at depth 2
        let costs = vec![wave(0, 1000, 0, 0), WaveCost::load(1400), wave(0, 1000, 0, 0)];
        let d1 = execute_waves(&costs, &cfg_at(1));
        let d2 = execute_waves(&costs, &cfg_at(2));
        assert_eq!(d1.stats.cycles, 1000 + 100 + 1000);
        assert_eq!(d2.stats.cycles, 2000, "the 100-cycle load is fully hidden");
        assert_eq!(d2.stats.prefetch_hidden_cycles, 100);
        assert_eq!(d2.item_cycles, vec![1000, 0, 1000]);
    }

    #[test]
    fn single_wave_gains_nothing_from_prefetch() {
        for costs in [vec![wave(32, 500, 14_000, 0)], vec![WaveCost::load(1400)]] {
            let d1 = execute_waves(&costs, &cfg_at(1));
            let d2 = execute_waves(&costs, &cfg_at(2));
            assert_eq!(d1.stats, d2.stats, "no previous wave to hide under");
        }
    }

    #[test]
    fn hidden_cycles_account_exactly_for_the_depth1_gap() {
        // mixed compute/dram-bound sequence, several depths
        let costs: Vec<WaveCost> = (0..24)
            .map(|i| match i % 4 {
                0 => wave(32, 800, 2800, 50),
                1 => wave(8, 30, 28_000, 0), // dram-bound
                2 => WaveCost::load(7000),
                _ => wave(64, 300, 140, 2000),
            })
            .collect();
        let d1 = execute_waves(&costs, &cfg_at(1));
        assert_eq!(d1.stats.prefetch_hidden_cycles, 0);
        let mut prev_cycles = d1.stats.cycles;
        for depth in [2usize, 3, 4, 8] {
            let r = execute_waves(&costs, &cfg_at(depth));
            assert!(
                r.stats.cycles <= prev_cycles,
                "depth {depth} must be monotonically non-slower"
            );
            assert_eq!(
                r.stats.cycles + r.stats.prefetch_hidden_cycles,
                d1.stats.cycles,
                "depth {depth}: hidden cycles must equal the depth-1 gap"
            );
            assert_eq!(r.stats.bytes_read, d1.stats.bytes_read, "traffic is depth-invariant");
            assert_eq!(r.stats.bytes_written, d1.stats.bytes_written);
            assert_eq!(r.stats.flops, d1.stats.flops);
            assert_eq!(r.stats.waves, d1.stats.waves);
            assert_eq!(r.stats.cycles, r.item_cycles.iter().sum::<u64>());
            assert_eq!(
                r.stats.compute_bound_cycles + r.stats.dram_bound_cycles,
                r.stats.cycles
            );
            prev_cycles = r.stats.cycles;
        }
    }

    #[test]
    fn slot_constraint_limits_lookahead() {
        // one enormous compute wave followed by many dram waves: depth 2
        // may run only one fetch ahead, deeper channels run further
        let mut costs = vec![wave(0, 100_000, 0, 0)];
        for _ in 0..8 {
            costs.push(wave(0, 1, 14_000, 0)); // 1000 dram cycles each
        }
        let d1 = execute_waves(&costs, &cfg_at(1)).stats.cycles;
        let d2 = execute_waves(&costs, &cfg_at(2)).stats.cycles;
        let d4 = execute_waves(&costs, &cfg_at(4)).stats.cycles;
        let d9 = execute_waves(&costs, &cfg_at(9)).stats.cycles;
        assert_eq!(d1, 100_000 + 8 * 1000);
        assert!(d4 < d2, "a deeper buffer must hide more of the fetch backlog");
        assert!(d9 < d4);
        // with every fetch prefetched under the big wave, each dram wave
        // retires in its 1-cycle compute
        assert_eq!(d9, 100_000 + 8);
    }

    #[test]
    fn dependent_stream_never_prefetches() {
        // a RAW-dependent stream (Cholesky columns) serializes behind the
        // previous wave at every depth: depth 2 == depth 1 exactly
        let mut dependent = wave(16, 400, 14_000, 200);
        dependent.dependent_stream = true;
        let costs = vec![wave(0, 1000, 0, 0), dependent, dependent];
        let d1 = execute_waves(&costs, &cfg_at(1));
        let d2 = execute_waves(&costs, &cfg_at(2));
        assert_eq!(d1.stats, d2.stats);
        assert_eq!(d2.stats.prefetch_hidden_cycles, 0);
        // ... while an independent stream of the same shape does win
        let mut independent = costs.clone();
        for c in &mut independent {
            c.dependent_stream = false;
        }
        let free = execute_waves(&independent, &cfg_at(2));
        assert!(free.stats.cycles < d2.stats.cycles);
    }

    #[test]
    fn empty_sequence_is_empty() {
        let r = execute_waves(&[], &cfg_at(2));
        assert_eq!(r.stats, SimStats::default());
        assert!(r.item_cycles.is_empty());
    }

    #[test]
    fn fixed_occupancy_is_charged_verbatim() {
        let mut c = wave(0, 10, 0, 0);
        c.occupancy = Occupancy::Fixed { busy: 77, idle: 23 };
        let r = execute_waves(&[c], &cfg_at(1));
        assert_eq!(r.stats.busy_pipeline_cycles, 77);
        assert_eq!(r.stats.idle_pipeline_cycles, 23);
    }

    #[test]
    #[should_panic(expected = "dram_buffer_depth must be >= 1")]
    fn zero_depth_channel_rejected() {
        let _ = DramChannel::new(0);
    }

    fn mixed_costs() -> Vec<WaveCost> {
        (0..12)
            .map(|i| match i % 4 {
                0 => wave(32, 800, 2800, 50),
                1 => wave(8, 30, 28_000, 0), // dram-bound
                2 => WaveCost::load(7000),
                _ => wave(64, 300, 140, 2000),
            })
            .collect()
    }

    #[test]
    fn zero_faults_are_bit_identical_to_the_plain_path() {
        let costs = mixed_costs();
        for depth in [1usize, 2, 3] {
            let cfg = cfg_at(depth);
            let plain = execute_waves(&costs, &cfg);
            let none = execute_waves_with_faults(&costs, &cfg, depth, None);
            let zeros = vec![WaveFault::default(); costs.len()];
            let zeroed = execute_waves_with_faults(&costs, &cfg, depth, Some(&zeros));
            assert_eq!(plain.stats, none.stats);
            assert_eq!(plain.stats, zeroed.stats);
            assert_eq!(plain.item_cycles, zeroed.item_cycles);
            assert_eq!(plain.stats.retry_cycles, 0);
            assert!(plain.failed_waves.is_empty() && zeroed.failed_waves.is_empty());
        }
    }

    #[test]
    fn retry_ledger_is_exact_at_every_depth() {
        let costs = mixed_costs();
        let mut faults = vec![WaveFault::default(); costs.len()];
        faults[1] = WaveFault { retries: 2, failed: false };
        faults[5] = WaveFault { retries: 1, failed: false };
        faults[10] = WaveFault { retries: 3, failed: true };
        for depth in [1usize, 2, 3] {
            let cfg = cfg_at(depth);
            let base = execute_waves(&costs, &cfg);
            let r = execute_waves_with_faults(&costs, &cfg, depth, Some(&faults));
            let expected_retry: u64 = faults
                .iter()
                .zip(&costs)
                .map(|(f, c)| f.retries * c.serial_cycles(&cfg))
                .sum();
            assert_eq!(r.stats.retry_cycles, expected_retry);
            assert_eq!(
                r.stats.cycles,
                base.stats.cycles + expected_retry,
                "depth {depth}: cycles(faults) must equal cycles(no faults) + retry_cycles"
            );
            // traffic, flops and waves are fault-invariant
            assert_eq!(r.stats.bytes_read, base.stats.bytes_read);
            assert_eq!(r.stats.bytes_written, base.stats.bytes_written);
            assert_eq!(r.stats.flops, base.stats.flops);
            assert_eq!(r.stats.waves, base.stats.waves);
            // the hidden-cycle counter still measures only prefetch wins
            assert_eq!(r.stats.prefetch_hidden_cycles, base.stats.prefetch_hidden_cycles);
            // bound split and per-item deltas stay internally consistent
            assert_eq!(
                r.stats.compute_bound_cycles + r.stats.dram_bound_cycles,
                r.stats.cycles
            );
            assert_eq!(r.stats.cycles, r.item_cycles.iter().sum::<u64>());
            assert_eq!(r.failed_waves, vec![10]);
        }
    }

    #[test]
    fn depth_ledger_still_holds_under_faults() {
        // cycles(d) + hidden(d) == cycles(1) when both runs carry the
        // same fault slice (retries are depth-invariant serial charges)
        let costs = mixed_costs();
        let faults: Vec<WaveFault> = (0..costs.len())
            .map(|k| WaveFault { retries: (k % 3) as u64, failed: k == 7 })
            .collect();
        let d1 = execute_waves_with_faults(&costs, &cfg_at(1), 1, Some(&faults));
        for depth in [2usize, 3, 4] {
            let r = execute_waves_with_faults(&costs, &cfg_at(depth), depth, Some(&faults));
            assert_eq!(r.stats.cycles + r.stats.prefetch_hidden_cycles, d1.stats.cycles);
            assert_eq!(r.stats.retry_cycles, d1.stats.retry_cycles);
            assert_eq!(r.failed_waves, vec![7]);
        }
    }

    #[test]
    #[should_panic(expected = "fault slice must be parallel")]
    fn mismatched_fault_slice_rejected() {
        let costs = vec![wave(0, 10, 0, 0)];
        let _ = execute_waves_with_faults(&costs, &cfg_at(1), 1, Some(&[]));
    }
}
