//! Cycle/traffic/utilization accounting shared by the simulators.

use super::config::FpgaConfig;

/// Aggregate statistics of one simulated execution.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimStats {
    /// Total cycles (compute and DRAM overlapped per wave: a wave costs
    /// `max(compute, dram)` cycles, per the paper's streaming design).
    pub cycles: u64,
    /// Cycles where the bound was compute (pipelines), summed over waves.
    pub compute_bound_cycles: u64,
    /// Cycles where the bound was the DRAM bandwidth cap.
    pub dram_bound_cycles: u64,
    /// Pipeline-cycles spent idle (no assignment or waiting on a wave).
    pub idle_pipeline_cycles: u64,
    /// Pipeline-cycles spent busy.
    pub busy_pipeline_cycles: u64,
    /// Bytes read from DRAM.
    pub bytes_read: u64,
    /// Bytes written to DRAM.
    pub bytes_written: u64,
    /// Useful FP operations performed (2 × multiplies for SpGEMM; dot,
    /// div, sqrt ops for Cholesky).
    pub flops: u64,
    /// Scheduling waves executed.
    pub waves: u64,
    /// Frontend cycles (DRAM stream fetch + CAM/bundle setup) the
    /// double-buffered channel hid under earlier waves' compute
    /// ([`crate::fpga::engine`]). Always 0 at `dram_buffer_depth == 1`;
    /// at any depth, `cycles + prefetch_hidden_cycles` equals the
    /// depth-1 cycle count.
    pub prefetch_hidden_cycles: u64,
    /// Cycles spent re-fetching and replaying waves whose stream failed
    /// checksum verification ([`crate::fpga::engine`]'s detect-and-replay
    /// model). Each retry re-runs the wave at its full serial cost, so
    /// the ledger is exact: `cycles` under faults equals the fault-free
    /// cycle count plus `retry_cycles`, and at `fault_rate == 0` this is
    /// always 0 with `cycles` bit-identical to the baseline.
    pub retry_cycles: u64,
}

impl SimStats {
    /// Wall-clock seconds at the design's frequency.
    pub fn seconds(&self, cfg: &FpgaConfig) -> f64 {
        self.cycles as f64 / cfg.hz()
    }

    /// Delivered GFLOP/s at the design's frequency.
    pub fn gflops(&self, cfg: &FpgaConfig) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.flops as f64 / self.seconds(cfg) / 1e9
    }

    /// GFLOP/s per FP unit — the Fig-8 (left) normalization.
    pub fn gflops_per_fpu(&self, cfg: &FpgaConfig) -> f64 {
        self.gflops(cfg) / cfg.fp_units() as f64
    }

    /// Fraction of pipeline-cycles spent busy.
    pub fn pipeline_utilization(&self) -> f64 {
        let total = self.busy_pipeline_cycles + self.idle_pipeline_cycles;
        if total == 0 {
            return 0.0;
        }
        self.busy_pipeline_cycles as f64 / total as f64
    }

    /// Fraction of waves bounded by DRAM bandwidth rather than compute.
    pub fn dram_bound_fraction(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.dram_bound_cycles as f64 / self.cycles as f64
    }

    /// Effective DRAM read bandwidth achieved, GB/s.
    pub fn achieved_read_gbps(&self, cfg: &FpgaConfig) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.bytes_read as f64 / self.seconds(cfg) / 1e9
    }

    /// Merge another stats block (e.g. per-phase accumulation).
    pub fn merge(&mut self, other: &SimStats) {
        self.cycles += other.cycles;
        self.compute_bound_cycles += other.compute_bound_cycles;
        self.dram_bound_cycles += other.dram_bound_cycles;
        self.idle_pipeline_cycles += other.idle_pipeline_cycles;
        self.busy_pipeline_cycles += other.busy_pipeline_cycles;
        self.bytes_read += other.bytes_read;
        self.bytes_written += other.bytes_written;
        self.flops += other.flops;
        self.waves += other.waves;
        self.prefetch_hidden_cycles += other.prefetch_hidden_cycles;
        self.retry_cycles += other.retry_cycles;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seconds_and_gflops() {
        let cfg = FpgaConfig::reap32_spgemm(); // 250 MHz
        let s = SimStats { cycles: 250_000_000, flops: 1_000_000_000, ..Default::default() };
        assert!((s.seconds(&cfg) - 1.0).abs() < 1e-12);
        assert!((s.gflops(&cfg) - 1.0).abs() < 1e-12);
        assert!((s.gflops_per_fpu(&cfg) - 1.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_bounds() {
        let s = SimStats {
            busy_pipeline_cycles: 75,
            idle_pipeline_cycles: 25,
            ..Default::default()
        };
        assert!((s.pipeline_utilization() - 0.75).abs() < 1e-12);
        assert_eq!(SimStats::default().pipeline_utilization(), 0.0);
    }

    #[test]
    fn merge_adds_fields() {
        let mut a = SimStats { cycles: 10, flops: 5, waves: 1, ..Default::default() };
        let b = SimStats {
            cycles: 7,
            flops: 2,
            waves: 2,
            bytes_read: 3,
            prefetch_hidden_cycles: 4,
            retry_cycles: 6,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.cycles, 17);
        assert_eq!(a.flops, 7);
        assert_eq!(a.waves, 3);
        assert_eq!(a.bytes_read, 3);
        assert_eq!(a.prefetch_hidden_cycles, 4);
        assert_eq!(a.retry_cycles, 6);
    }
}
