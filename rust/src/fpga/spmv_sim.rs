//! Cycle model of the SpMV extension datapath.
//!
//! Same organization as the SpGEMM design minus the CAM/sort/merge chain:
//! x is loaded once into FPGA on-chip memory (the Arria-10's 67 Mbit
//! easily holds the suite's vectors); row bundles stream from DRAM; each
//! pipeline's PE gathers x at one element/cycle and accumulates with an
//! adder tree, so the datapath runs at RIR stream rate — the extension
//! inherits exactly the property the paper engineered for SpGEMM.

use crate::rir::layout::encoded_data_bundle_words;
use crate::rir::schedule::{SpgemmSchedule, Wave};
use crate::sparse::Csr;

use super::config::FpgaConfig;
use super::engine::{execute_waves, Occupancy, WaveCost, WaveKind};
use super::spgemm_sim::Style;
use super::stats::SimStats;

/// Result of simulating one SpMV execution.
#[derive(Clone, Debug)]
pub struct SpmvSimResult {
    pub stats: SimStats,
    /// Cycles of the one-time x-vector load (before the first wave).
    pub x_load_cycles: u64,
    /// Cycle count per wave; `x_load_cycles + Σ wave_cycles == cycles`
    /// at every channel depth.
    pub wave_cycles: Vec<u64>,
    /// Engine cost sequence (item 0 is the x-vector [`WaveKind::Load`]).
    pub costs: Vec<WaveCost>,
}

/// Simulate `y = A x` over the chunk schedule (the SpGEMM scheduler's wave
/// structure is reused — assignments are row chunks; the B-stream list is
/// ignored because x lives on-chip). The per-wave DRAM/compute overlap is
/// owned by [`crate::fpga::engine`].
pub fn simulate_spmv(
    a: &Csr,
    schedule: &SpgemmSchedule,
    cfg: &FpgaConfig,
    style: Style,
) -> SpmvSimResult {
    let mut costs = Vec::with_capacity(schedule.waves.len() + 1);
    // one-time x load into on-chip RAM (a word per dense element; the
    // dense x vector is CPU-resident data, not an RIR stream, so the
    // negotiated encoding does not apply to it)
    costs.push(WaveCost::load(a.ncols as u64));
    for wave in &schedule.waves {
        costs.push(row_stream_wave_cost(a, wave, cfg, style, 1));
    }
    let engine = execute_waves(&costs, cfg);
    let x_load_cycles = engine.item_cycles[0];
    let wave_cycles = engine.item_cycles[1..].to_vec();
    SpmvSimResult { stats: engine.stats, x_load_cycles, wave_cycles, costs }
}

/// Cost of one wave of the row-streaming datapath with `kb` parallel MAC
/// lanes per PE — **`kb == 1` is exactly the SpMV datapath**, and the
/// SpMM model (`super::spmm_sim`) calls this same function with its
/// column-block width, so the two models cannot drift apart (the
/// SpMM-beats-k-SpMVs comparison depends on that lockstep).
///
/// Per assignment the chunk streams at 1 element/cycle
/// (gather + multiply + accumulate across all `kb` lanes in the same
/// cycle when stages are pipelined; HLS serializes the gather and the
/// per-lane MACs); the writeback is `kb` dense values per finished row.
/// The 2-cycle bundle-header decode is the wave's frontend setup (hidden
/// by a depth ≥ 2 channel).
///
/// The A-row stream is priced at its **encoded** wire size
/// ([`crate::rir::layout::encoded_data_bundle_words`] per assignment under
/// `cfg.encoding`), and non-raw encodings add the expander fill latency
/// ([`StreamEncoding::expansion_cycles`](crate::rir::layout::StreamEncoding::expansion_cycles))
/// to the wave's setup — the expanders are fully pipelined, so the
/// element rate (and thus `compute_cycles`) is unchanged. Writeback stays
/// raw f32 words: compression is negotiated for the input RIR streams
/// only, so kernel outputs keep full f32 precision.
pub(crate) fn row_stream_wave_cost(
    a: &Csr,
    wave: &Wave,
    cfg: &FpgaConfig,
    style: Style,
    kb: u64,
) -> WaveCost {
    let fill = cfg.mult_latency + cfg.add_latency * 6; // adder tree drain
    let indirection = match style {
        Style::HlsRaw => 6u64,
        _ => 0,
    };
    let mut max_pipe: u64 = 0;
    let mut elems_total: u64 = 0;
    let mut rows_done: u64 = 0;
    for asg in &wave.assignments {
        let elems = asg.len as u64;
        let pipe = if style.pipelined_stages() {
            2 + elems + indirection
        } else {
            2 + elems * (1 + kb) + indirection // HLS: gather, then kb MACs
        };
        max_pipe = max_pipe.max(pipe + fill);
        elems_total += elems;
        rows_done += u64::from(asg.last_chunk);
    }
    let in_words: u64 = wave
        .assignments
        .iter()
        .map(|asg| encoded_data_bundle_words(asg.a_cols(a), cfg.encoding) as u64)
        .sum();
    let setup = if wave.assignments.is_empty() { 0 } else { 2 + cfg.encoding.expansion_cycles() };
    WaveCost {
        kind: WaveKind::Compute,
        stream_words: in_words,
        setup_cycles: setup,
        compute_cycles: max_pipe.saturating_sub(2),
        writeback_words: rows_done * kb,
        dependent_stream: false,
        occupancy: Occupancy::ActivePipelines(wave.assignments.len() as u64),
        flops: 2 * elems_total * kb,
        waves: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::schedule::schedule_spgemm;
    use crate::sparse::gen;

    fn sim(n: usize, nnz: usize, style: Style) -> SpmvSimResult {
        let a = gen::random_uniform(n, n, nnz, 3);
        let cfg = FpgaConfig::reap32_spgemm();
        // schedule against an empty B surrogate (b_rows unused by SpMV)
        let s = schedule_spgemm(&a, &Csr::new(n, n), cfg.pipelines, cfg.bundle_size);
        simulate_spmv(&a, &s, &cfg, style)
    }

    #[test]
    fn produces_consistent_work() {
        let r = sim(500, 6000, Style::HandCoded);
        assert_eq!(r.stats.flops, 2 * 6000);
        assert!(r.stats.cycles > 0);
        assert_eq!(
            r.stats.compute_bound_cycles + r.stats.dram_bound_cycles,
            r.stats.cycles
        );
        assert_eq!(r.wave_cycles.len() as u64, r.stats.waves);
        assert_eq!(
            r.x_load_cycles + r.wave_cycles.iter().sum::<u64>(),
            r.stats.cycles,
            "wave log + x load must sum to total"
        );
    }

    #[test]
    fn hls_raw_slower() {
        let hand = sim(500, 6000, Style::HandCoded);
        let raw = sim(500, 6000, Style::HlsRaw);
        assert!(raw.stats.cycles > hand.stats.cycles);
    }

    #[test]
    fn empty_matrix_costs_only_x_load() {
        let a = Csr::new(100, 100);
        let cfg = FpgaConfig::reap32_spgemm();
        let s = schedule_spgemm(&a, &Csr::new(100, 100), cfg.pipelines, cfg.bundle_size);
        let r = simulate_spmv(&a, &s, &cfg, Style::HandCoded);
        assert_eq!(r.stats.waves, 0);
        assert_eq!(r.stats.bytes_read, 400);
    }

    #[test]
    fn encoded_streams_shrink_reads_but_not_writebacks() {
        use crate::rir::layout::StreamEncoding;
        let a = gen::random_uniform(300, 300, 4000, 7);
        let mut cfg = FpgaConfig::reap32_spgemm();
        let s = schedule_spgemm(&a, &Csr::new(300, 300), cfg.pipelines, cfg.bundle_size);
        let raw = simulate_spmv(&a, &s, &cfg, Style::HandCoded);
        cfg.encoding = StreamEncoding::Fx;
        let fx = simulate_spmv(&a, &s, &cfg, Style::HandCoded);
        assert!(fx.stats.bytes_read < raw.stats.bytes_read, "fx packs 2 values per word");
        assert_eq!(fx.stats.flops, raw.stats.flops, "same useful work");
        assert_eq!(fx.stats.bytes_written, raw.stats.bytes_written, "writeback stays raw");
        assert_eq!(fx.stats.waves, raw.stats.waves);
        // bitmap never loses: scattered random rows fall back to raw form
        cfg.encoding = StreamEncoding::Bitmap;
        let bm = simulate_spmv(&a, &s, &cfg, Style::HandCoded);
        assert!(bm.stats.bytes_read <= raw.stats.bytes_read);
    }
}
