//! FPGA design-point configuration (paper §V, Table II, Fig 8-right).

use std::fmt;

/// Typed validation failure for an [`FpgaConfig`].
///
/// Every variant is a zero-valued geometry field that would otherwise
/// surface far downstream as a division by zero, an empty schedule, or a
/// `checked_sub` underflow inside the wave engine — the coordinators
/// reject the configuration up front instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// `pipelines == 0`: no datapath to schedule waves onto.
    ZeroPipelines,
    /// `bundle_size == 0`: rows could never be split into RIR chunks —
    /// the schedulers' chunk enumeration would divide by zero.
    ZeroBundleSize,
    /// `vector_lanes == 0`: the SpMM column-block width would be empty.
    ZeroVectorLanes,
    /// `dram_buffer_depth == 0`: the stream frontend needs at least the
    /// single (serial) wave buffer.
    ZeroDramBufferDepth,
    /// `max_wave_retries == 0`: the detect-and-replay path needs at least
    /// one re-fetch attempt before a wave may be declared failed.
    ZeroMaxWaveRetries,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::ZeroPipelines => {
                write!(f, "invalid FpgaConfig: pipelines must be >= 1")
            }
            ConfigError::ZeroBundleSize => {
                write!(f, "invalid FpgaConfig: bundle_size must be >= 1")
            }
            ConfigError::ZeroVectorLanes => {
                write!(f, "invalid FpgaConfig: vector_lanes must be >= 1")
            }
            ConfigError::ZeroDramBufferDepth => {
                write!(f, "invalid FpgaConfig: dram_buffer_depth must be >= 1")
            }
            ConfigError::ZeroMaxWaveRetries => {
                write!(f, "invalid FpgaConfig: max_wave_retries must be >= 1")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

/// DRAM bandwidth configuration (the paper's queuing-model cap).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DramConfig {
    /// Sustained read bandwidth, GB/s.
    pub read_gbps: f64,
    /// Sustained write bandwidth, GB/s.
    pub write_gbps: f64,
}

impl DramConfig {
    /// REAP-32 cap: "matches that available on a single-core CPU, which is
    /// 14 GB/s on our machine for both reads and writes" (§V-A).
    pub fn single_core() -> Self {
        DramConfig { read_gbps: 14.0, write_gbps: 14.0 }
    }

    /// REAP-64/128 cap: "the peak measured memory bandwidth (147 GB/s for
    /// reads and 73 GB/s for writes) for our CPU" (§V-A).
    pub fn sixteen_core_peak() -> Self {
        DramConfig { read_gbps: 147.0, write_gbps: 73.0 }
    }
}

/// One REAP design point: pipeline count, frequency, sizing, latencies.
///
/// Unit latencies reflect Intel Arria-10 single-precision FP IP blocks
/// (the "dedicated hardware … from the DSP units" of §IV): fully pipelined
/// (initiation interval 1) with multi-cycle result latency; division and
/// square root are the long-latency IPs.
#[derive(Clone, Debug, PartialEq)]
pub struct FpgaConfig {
    pub name: &'static str,
    /// Replicated vertical pipelines (Fig 1 / Fig 5).
    pub pipelines: usize,
    /// Clock, MHz (paper: 250 MHz @32/64, 220 @128, 238 @ Cholesky-64).
    pub freq_mhz: f64,
    /// RIR bundle size = CAM entries (paper design parameter: 32).
    pub bundle_size: usize,
    /// Multipliers inside each Cholesky dot-product PE (8 in REAP-32,
    /// 16 in REAP-64; SpGEMM pipelines have one multiplier each).
    pub dot_multipliers: usize,
    /// Parallel MAC lanes per SpMV/SpMM pipeline PE: one streamed matrix
    /// element feeds up to this many dense right-hand-side columns in the
    /// same cycle, so an SpMM column block of this width runs at the same
    /// stream rate as a single SpMV (the amortization
    /// `fpga::spmm_sim` models). Sized like the Cholesky
    /// dot-product PEs — 8 multipliers fit comfortably per pipeline on the
    /// Arria-10 design points.
    pub vector_lanes: usize,
    /// Wave buffers in the DRAM stream frontend
    /// ([`crate::fpga::engine::DramChannel`]): 1 = single-buffered (wave
    /// *k+1*'s stream waits for wave *k* to retire — the serial baseline),
    /// 2 = double-buffered prefetch (wave *k+1*'s RIR/B-stream and CAM
    /// setup fetch under wave *k*'s compute). Higher depths prefetch
    /// further ahead. Must be ≥ 1 ([`FpgaConfig::validate`]).
    pub dram_buffer_depth: usize,
    /// Detect-and-replay bound: how many times the engine re-fetches and
    /// replays a wave whose stream failed checksum verification before
    /// declaring the wave (and the jobs scheduled on it) failed
    /// ([`crate::fpga::engine::execute_waves_with_faults`]). Each retry
    /// costs the wave's full serial cycle count, charged to
    /// [`super::SimStats::retry_cycles`]. Must be ≥ 1
    /// ([`FpgaConfig::validate`]); irrelevant at fault rate 0.
    pub max_wave_retries: usize,
    /// Negotiated RIR stream encoding (`--encoding`, ARCHITECTURE.md §3.4):
    /// bitmap index sections and/or fixed-point value lanes. The simulators
    /// price every A/B/panel stream at its encoded size
    /// ([`crate::rir::layout::encoded_data_bundle_words`]) and charge the
    /// expander fill latency
    /// ([`crate::rir::layout::StreamEncoding::expansion_cycles`]) to each
    /// wave's setup. `Raw` is bit-identical to the pre-compression model.
    /// Cholesky streams do not participate (see `fpga::cholesky_sim`).
    pub encoding: crate::rir::layout::StreamEncoding,
    pub dram: DramConfig,
    /// FP multiply pipeline latency, cycles.
    pub mult_latency: u64,
    /// FP add (accumulate) latency, cycles.
    pub add_latency: u64,
    /// FP divide latency, cycles (Arria-10 FP div IP ≈ 28 stages).
    pub div_latency: u64,
    /// FP square-root latency, cycles.
    pub sqrt_latency: u64,
}

impl FpgaConfig {
    /// REAP-32 for SpGEMM: "32 pipelines … 250 MHz … RIR bundle and CAM
    /// size of 32. The DRAM bandwidth … matches … a single-core CPU".
    pub fn reap32_spgemm() -> Self {
        FpgaConfig {
            name: "REAP-32",
            pipelines: 32,
            freq_mhz: 250.0,
            bundle_size: 32,
            dot_multipliers: 1,
            vector_lanes: 8,
            dram_buffer_depth: 1,
            max_wave_retries: 3,
            encoding: crate::rir::layout::StreamEncoding::Raw,
            dram: DramConfig::single_core(),
            mult_latency: 5,
            add_latency: 4,
            div_latency: 28,
            sqrt_latency: 28,
        }
    }

    /// REAP-64 for SpGEMM: 64 pipelines, 250 MHz, 16-core DRAM bandwidth.
    pub fn reap64_spgemm() -> Self {
        FpgaConfig {
            pipelines: 64,
            dram: DramConfig::sixteen_core_peak(),
            name: "REAP-64",
            ..Self::reap32_spgemm()
        }
    }

    /// REAP-128 for SpGEMM: 128 pipelines, 220 MHz, same bandwidth as -64.
    pub fn reap128_spgemm() -> Self {
        FpgaConfig {
            pipelines: 128,
            freq_mhz: 220.0,
            dram: DramConfig::sixteen_core_peak(),
            name: "REAP-128",
            ..Self::reap32_spgemm()
        }
    }

    /// REAP-32 for Cholesky: 32 pipelines @250 MHz, 8 multipliers per
    /// dot-product PE, single-core DRAM bandwidth (§V-B).
    pub fn reap32_cholesky() -> Self {
        FpgaConfig {
            dot_multipliers: 8,
            name: "REAP-32",
            ..Self::reap32_spgemm()
        }
    }

    /// REAP-64 for Cholesky: 64 pipelines @238 MHz, 16 multipliers per PE,
    /// 16-core DRAM bandwidth (§V-B).
    pub fn reap64_cholesky() -> Self {
        FpgaConfig {
            pipelines: 64,
            freq_mhz: 238.0,
            dot_multipliers: 16,
            dram: DramConfig::sixteen_core_peak(),
            name: "REAP-64",
            ..Self::reap32_spgemm()
        }
    }

    /// Reject geometry that would divide by zero or underflow downstream
    /// (every coordinator validates before running; the simulators assume
    /// a validated configuration).
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.pipelines == 0 {
            return Err(ConfigError::ZeroPipelines);
        }
        if self.bundle_size == 0 {
            return Err(ConfigError::ZeroBundleSize);
        }
        if self.vector_lanes == 0 {
            return Err(ConfigError::ZeroVectorLanes);
        }
        if self.dram_buffer_depth == 0 {
            return Err(ConfigError::ZeroDramBufferDepth);
        }
        if self.max_wave_retries == 0 {
            return Err(ConfigError::ZeroMaxWaveRetries);
        }
        Ok(())
    }

    /// Cycles per second.
    pub fn hz(&self) -> f64 {
        self.freq_mhz * 1e6
    }

    /// DRAM read bytes per cycle at this clock.
    pub fn read_bytes_per_cycle(&self) -> f64 {
        self.dram.read_gbps * 1e9 / self.hz()
    }

    /// DRAM write bytes per cycle at this clock.
    pub fn write_bytes_per_cycle(&self) -> f64 {
        self.dram.write_gbps * 1e9 / self.hz()
    }

    /// FP mult/add unit count — the paper's Fig-8 normalization for REAP
    /// (each SpGEMM pipeline: 1 multiplier + 1 merge adder counts as one
    /// multiply/add unit; each Cholesky pipeline: `dot_multipliers`).
    pub fn fp_units(&self) -> usize {
        self.pipelines * self.dot_multipliers
    }
}

/// FP mult/add units of an n-thread CPU baseline, for the Fig-8
/// normalization. Xeon 6130 (Table II): 2×AVX-512 FMA ports = 16 f32
/// multiply/add lanes per core — this is how "CPU-2 effectively has the
/// same number of floating point multiply/add units as the REAP-32" (§V-A)
/// comes out: 2 × 16 = 32.
pub fn cpu_fp_units(threads: usize) -> usize {
    threads * 16
}

/// Area/frequency scaling model of Fig 8 (right), calibrated to the
/// paper's reported endpoints: 280 MHz and small utilization at 2
/// pipelines → 220 MHz and 8× the logic at 128 pipelines, with 250 MHz at
/// the 32/64-pipeline design points.
#[derive(Clone, Copy, Debug)]
pub struct AreaModel;

impl AreaModel {
    /// Predicted clock frequency (MHz) for a pipeline count.
    ///
    /// Piecewise linear in log2(pipelines) through the paper's synthesized
    /// points (2, 280), (32, 250), (64, 250), (128, 220).
    pub fn freq_mhz(pipelines: usize) -> f64 {
        let p = (pipelines.max(1)) as f64;
        let x = p.log2();
        // anchors in (log2 p, MHz)
        let pts = [(1.0, 280.0), (5.0, 250.0), (6.0, 250.0), (7.0, 220.0)];
        if x <= pts[0].0 {
            return pts[0].1;
        }
        for w in pts.windows(2) {
            let ((x0, y0), (x1, y1)) = (w[0], w[1]);
            if x <= x1 {
                return y0 + (y1 - y0) * (x - x0) / (x1 - x0);
            }
        }
        pts[3].1
    }

    /// Predicted logic utilization (fraction of the Arria-10's 1150K LEs)
    /// for a pipeline count.
    ///
    /// "While the number of pipelines changed from 2 to 128, the logic
    /// utilization has increased only 8×" — sublinear growth ≈ p^0.5
    /// (each doubling costs √2×), anchored at 10% for 2 pipelines so 128
    /// pipelines lands at 80%.
    pub fn logic_utilization(pipelines: usize) -> f64 {
        let p = pipelines.max(1) as f64;
        (0.10 * (p / 2.0).sqrt()).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_design_points() {
        let c32 = FpgaConfig::reap32_spgemm();
        assert_eq!(c32.pipelines, 32);
        assert_eq!(c32.freq_mhz, 250.0);
        assert_eq!(c32.bundle_size, 32);
        assert_eq!(c32.dram, DramConfig::single_core());

        let c128 = FpgaConfig::reap128_spgemm();
        assert_eq!(c128.freq_mhz, 220.0);
        assert_eq!(c128.dram, DramConfig::sixteen_core_peak());

        let ch64 = FpgaConfig::reap64_cholesky();
        assert_eq!(ch64.dot_multipliers, 16);
        assert_eq!(ch64.freq_mhz, 238.0);

        // every design point carries the 8-wide SpMM vector lanes, the
        // serial (depth-1) DRAM frontend and the 3-retry replay bound as
        // its published baseline
        for c in [c32, c128, ch64] {
            assert_eq!(c.vector_lanes, 8);
            assert_eq!(c.dram_buffer_depth, 1);
            assert_eq!(c.max_wave_retries, 3);
            assert_eq!(c.encoding, crate::rir::layout::StreamEncoding::Raw);
            assert_eq!(c.validate(), Ok(()));
        }
    }

    #[test]
    fn validate_rejects_zero_pipelines() {
        let cfg = FpgaConfig { pipelines: 0, ..FpgaConfig::reap32_spgemm() };
        assert_eq!(cfg.validate(), Err(ConfigError::ZeroPipelines));
    }

    #[test]
    fn validate_rejects_zero_bundle_size() {
        let cfg = FpgaConfig { bundle_size: 0, ..FpgaConfig::reap32_spgemm() };
        assert_eq!(cfg.validate(), Err(ConfigError::ZeroBundleSize));
        let msg = ConfigError::ZeroBundleSize.to_string();
        assert!(msg.contains("bundle_size"), "{msg}");
    }

    #[test]
    fn validate_rejects_zero_vector_lanes() {
        let cfg = FpgaConfig { vector_lanes: 0, ..FpgaConfig::reap32_spgemm() };
        assert_eq!(cfg.validate(), Err(ConfigError::ZeroVectorLanes));
    }

    #[test]
    fn validate_rejects_zero_dram_buffer_depth() {
        let cfg = FpgaConfig { dram_buffer_depth: 0, ..FpgaConfig::reap32_spgemm() };
        assert_eq!(cfg.validate(), Err(ConfigError::ZeroDramBufferDepth));
    }

    #[test]
    fn validate_rejects_zero_max_wave_retries() {
        let cfg = FpgaConfig { max_wave_retries: 0, ..FpgaConfig::reap32_spgemm() };
        assert_eq!(cfg.validate(), Err(ConfigError::ZeroMaxWaveRetries));
        let msg = ConfigError::ZeroMaxWaveRetries.to_string();
        assert!(msg.contains("max_wave_retries"), "{msg}");
    }

    #[test]
    fn config_error_displays_the_offending_field() {
        let msg = ConfigError::ZeroDramBufferDepth.to_string();
        assert!(msg.contains("dram_buffer_depth"), "{msg}");
        // the typed error converts into the coordinators' anyhow chain
        let _: anyhow::Error = ConfigError::ZeroPipelines.into();
    }

    #[test]
    fn bandwidth_per_cycle_sane() {
        let c = FpgaConfig::reap32_spgemm();
        // 14 GB/s at 250 MHz = 56 bytes/cycle
        assert!((c.read_bytes_per_cycle() - 56.0).abs() < 1e-9);
        let c = FpgaConfig::reap64_spgemm();
        assert!((c.read_bytes_per_cycle() - 588.0).abs() < 1e-9);
    }

    #[test]
    fn fp_unit_equivalences_from_the_paper() {
        // "CPU-2 effectively has the same number of floating point
        // multiply/add units as the REAP-32"
        assert_eq!(cpu_fp_units(2), FpgaConfig::reap32_spgemm().fp_units());
        // "REAP-64 … 1/4 … of the number of floating-point multiply/add
        // units than CPU-16"
        assert_eq!(FpgaConfig::reap64_spgemm().fp_units() * 4, cpu_fp_units(16));
        // "REAP-128 … half of the number of floating-point units compared
        // to a 16-core CPU"
        assert_eq!(FpgaConfig::reap128_spgemm().fp_units() * 2, cpu_fp_units(16));
    }

    #[test]
    fn area_model_hits_anchors() {
        assert_eq!(AreaModel::freq_mhz(2), 280.0);
        assert_eq!(AreaModel::freq_mhz(32), 250.0);
        assert_eq!(AreaModel::freq_mhz(128), 220.0);
        let f64p = AreaModel::freq_mhz(64);
        assert!(f64p <= 250.0 && f64p >= 220.0);
        // 8x growth from 2 to 128
        let ratio = AreaModel::logic_utilization(128) / AreaModel::logic_utilization(2);
        assert!((ratio - 8.0).abs() < 1e-9);
        assert!(AreaModel::logic_utilization(2) > 0.0);
        assert!(AreaModel::logic_utilization(128) <= 1.0);
    }

    #[test]
    fn freq_monotone_nonincreasing() {
        let mut prev = f64::INFINITY;
        for p in [2usize, 4, 8, 16, 32, 64, 128] {
            let f = AreaModel::freq_mhz(p);
            assert!(f <= prev, "freq must not increase with pipelines");
            prev = f;
        }
    }
}
