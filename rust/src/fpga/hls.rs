//! §V-C: REAP with OpenCL HLS designs.
//!
//! The paper ports the idea to an Intel PAC card with OpenCL 1.0 and finds
//! (a) HLS designs are "significantly slower than the hand-coded designs",
//! and (b) HLS **with** RIR preprocessing beats HLS **without** it by a
//! geomean of 16% (SpGEMM) and 35% (Cholesky). This module packages the
//! two HLS operating points (built from the same simulators with the
//! [`Style`] derating) and the comparison the
//! benchmark harness prints.

use crate::rir::schedule::schedule_spgemm;
use crate::sparse::Csr;
use crate::symbolic::CholeskySymbolic;

use super::cholesky_sim::simulate_cholesky;
use super::config::FpgaConfig;
use super::spgemm_sim::{simulate_spgemm, Style};

/// HLS comparison for one SpGEMM workload.
#[derive(Clone, Copy, Debug)]
pub struct HlsComparison {
    /// Cycles with RIR preprocessing (REAP-style HLS).
    pub preprocessed_cycles: u64,
    /// Cycles reading raw CSR (plain HLS).
    pub raw_cycles: u64,
}

impl HlsComparison {
    /// Relative benefit of preprocessing: raw/preprocessed − 1
    /// (the paper reports 16% SpGEMM, 35% Cholesky geomeans).
    pub fn preprocessing_gain(&self) -> f64 {
        self.raw_cycles as f64 / self.preprocessed_cycles as f64 - 1.0
    }

    /// Wall-clock seconds of the two variants at the HLS-derated clock.
    pub fn seconds(&self, cfg: &FpgaConfig) -> (f64, f64) {
        let hz = cfg.hz() * Style::HlsPreprocessed.freq_derate();
        (self.preprocessed_cycles as f64 / hz, self.raw_cycles as f64 / hz)
    }
}

/// PAC-card HLS configuration: same Arria-10 family as Table II but fewer
/// pipelines (OpenCL replicates compute units less densely) and the
/// toolchain's lower clock is applied via the style derate inside the sim.
pub fn hls_config() -> FpgaConfig {
    FpgaConfig {
        name: "HLS-PAC",
        pipelines: 16,
        ..FpgaConfig::reap32_spgemm()
    }
}

/// Compare HLS-with-RIR vs HLS-raw on SpGEMM (C = A·A).
pub fn compare_spgemm_hls(a: &Csr) -> HlsComparison {
    let cfg = hls_config();
    let schedule = schedule_spgemm(a, a, cfg.pipelines, cfg.bundle_size);
    let pre = simulate_spgemm(a, a, &schedule, &cfg, Style::HlsPreprocessed);
    let raw = simulate_spgemm(a, a, &schedule, &cfg, Style::HlsRaw);
    HlsComparison {
        preprocessed_cycles: pre.stats.cycles,
        raw_cycles: raw.stats.cycles,
    }
}

/// Compare HLS-with-RIR vs HLS-raw on Cholesky.
pub fn compare_cholesky_hls(sym: &CholeskySymbolic) -> HlsComparison {
    let cfg = FpgaConfig { dot_multipliers: 8, ..hls_config() };
    let pre = simulate_cholesky(sym, &cfg, Style::HlsPreprocessed);
    let raw = simulate_cholesky(sym, &cfg, Style::HlsRaw);
    HlsComparison {
        preprocessed_cycles: pre.stats.cycles,
        raw_cycles: raw.stats.cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn preprocessing_always_helps_spgemm() {
        for seed in 0..4u64 {
            let a = gen::random_uniform(150, 150, 2000, seed);
            let cmp = compare_spgemm_hls(&a);
            assert!(
                cmp.preprocessing_gain() > 0.0,
                "seed {seed}: gain {}",
                cmp.preprocessing_gain()
            );
        }
    }

    #[test]
    fn preprocessing_always_helps_cholesky() {
        for seed in 0..3u64 {
            let spd = gen::spd(gen::Family::BandedFem, 60, 400, seed);
            let sym = CholeskySymbolic::analyze(&spd.lower_triangle(), 32);
            let cmp = compare_cholesky_hls(&sym);
            assert!(cmp.preprocessing_gain() > 0.0);
        }
    }

    #[test]
    fn gain_in_plausible_range() {
        // paper geomeans are 16% / 35%; any single matrix should land
        // within a loose band around that
        let a = gen::banded_fem(200, 3000, 7);
        let g = compare_spgemm_hls(&a).preprocessing_gain();
        assert!((0.02..3.0).contains(&g), "gain {g} out of band");
    }
}
