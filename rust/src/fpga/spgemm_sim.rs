//! Cycle model of the SpGEMM datapath (paper Fig 1).
//!
//! Each wave of the RIR schedule runs the five-module pipeline:
//!
//! 1. **input controller** loads each pipeline's CAM with its A-chunk
//!    (1 entry/cycle) and broadcasts the wave's B-row bundles;
//! 2. **match + multiply**: every streamed B element is CAM-matched in one
//!    cycle; matches enqueue to the (initiation-interval-1) multiplier;
//! 3. **sort**: shift-register insertion sorter, one partial product per
//!    cycle;
//! 4. **merge**: compare-with-head accumulator, one partial product per
//!    cycle;
//! 5. **output controller** drains merged results to DRAM.
//!
//! All stages are pipelined, so a pipeline's wave cost is the *maximum* of
//! its stage occupancies plus the fill latency — in the hand-coded design
//! the broadcast stream rate dominates (that is the paper's point: with
//! RIR the datapath runs at stream rate). The §V-C HLS variant instead
//! *serializes* the stages and, without CPU preprocessing, pays an
//! indirection penalty per B-row gather.
//!
//! Both A-chunk bundles and B-row chains are priced at their **encoded**
//! wire size under [`FpgaConfig::encoding`]
//! ([`crate::rir::layout::encoded_data_bundle_words`] /
//! [`crate::rir::layout::encoded_chain_words`]); non-raw encodings add the
//! pipelined expander's fill latency to the wave's setup while the
//! post-expander element rate — and thus every stage occupancy — is
//! unchanged. Merged output writes back as raw (col, f32) pairs:
//! compression is negotiated for the input RIR streams only.

use crate::rir::layout::WORD_BYTES;
use crate::rir::schedule::{BatchSchedule, SpgemmSchedule};
use crate::sparse::Csr;

use super::config::FpgaConfig;
use super::engine::{
    execute_waves, execute_waves_with_faults, Occupancy, WaveCost, WaveFault, WaveKind,
};
use super::stats::SimStats;

/// Checked widening for wave accounting: a count that cannot be carried
/// exactly must abort the run, not wrap (oversized batched inputs made
/// the silent `as` casts reachable).
#[inline]
fn acc_u64(v: usize, what: &str) -> u64 {
    u64::try_from(v).unwrap_or_else(|_| panic!("{what} ({v}) exceeds u64 accounting range"))
}

/// Datapath style: hand-coded Verilog (the REAP prototype) or the OpenCL
/// HLS variant of §V-C.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Style {
    /// Hand-coded RTL: fully pipelined stages.
    HandCoded,
    /// HLS with RIR preprocessing: correct dataflow but the toolchain
    /// serializes stage groups ("the HLS designs tend to be a lot slower").
    HlsPreprocessed,
    /// HLS reading raw CSR: additionally pays per-row indirection
    /// (pointer-chase + unaligned gather) on every B-row access.
    HlsRaw,
}

impl Style {
    /// HLS clocks lower than hand-tuned RTL on the same device. Applied
    /// when converting cycles to seconds (see `fpga::hls`).
    pub fn freq_derate(self) -> f64 {
        match self {
            Style::HandCoded => 1.0,
            Style::HlsPreprocessed | Style::HlsRaw => 0.6,
        }
    }

    /// Extra cycles per B-row access for raw-CSR indirection (row-pointer
    /// lookup + short-burst setup — the irregularity REAP eliminates).
    /// Calibrated so the suite geomean of the preprocessing benefit lands
    /// near the paper's §V-C numbers (16% SpGEMM).
    fn indirection_cycles_per_row(self) -> u64 {
        match self {
            Style::HlsRaw => 6,
            _ => 0,
        }
    }

    pub(crate) fn pipelined_stages(self) -> bool {
        matches!(self, Style::HandCoded)
    }
}

/// Result of simulating one SpGEMM execution.
#[derive(Clone, Debug)]
pub struct SpgemmSimResult {
    pub stats: SimStats,
    /// Cycle count per wave (diagnostics / ablation; drives the overlap
    /// pipeline). Sums to `stats.cycles` at every channel depth.
    pub wave_cycles: Vec<u64>,
    /// Per-wave cost description handed to the engine — re-execute with
    /// [`crate::fpga::engine::execute_waves_at_depth`] to compare channel
    /// depths without re-walking the matrices.
    pub costs: Vec<WaveCost>,
}

/// Simulate `C = A × B` on the configured design over a prebuilt schedule.
///
/// `b` supplies row lengths and column patterns; values are not consulted
/// (the numeric result comes from the XLA artifact path or the CPU
/// reference — the simulator is a timing model, like the paper's). The
/// per-wave DRAM/compute overlap — serial at `dram_buffer_depth == 1`,
/// prefetched at depth ≥ 2 — is owned by [`crate::fpga::engine`]; this
/// function only describes each wave's cost.
pub fn simulate_spgemm(
    a: &Csr,
    b: &Csr,
    schedule: &SpgemmSchedule,
    cfg: &FpgaConfig,
    style: Style,
) -> SpgemmSimResult {
    let costs = spgemm_wave_costs(a, b, schedule, cfg, style);
    let engine = execute_waves(&costs, cfg);
    SpgemmSimResult { stats: engine.stats, wave_cycles: engine.item_cycles, costs }
}

/// Describe every wave of a single-job SpGEMM schedule as a [`WaveCost`].
fn spgemm_wave_costs(
    a: &Csr,
    b: &Csr,
    schedule: &SpgemmSchedule,
    cfg: &FpgaConfig,
    style: Style,
) -> Vec<WaveCost> {
    let mut costs = Vec::with_capacity(schedule.waves.len());

    // scratch for merged-output counting (stamped SPA over B's columns)
    let mut stamp = vec![u32::MAX; b.ncols];
    let mut tick = 0u32;

    // pipeline fill latency: match(1) + mult + sort(1) + merge/add
    let fill = 2 + cfg.mult_latency + cfg.add_latency;

    for wave in &schedule.waves {
        // ---- B broadcast stream occupancy (shared by all pipelines) ----
        let mut stream_cycles: u64 = 0;
        let mut b_words: u64 = 0;
        for &r in &wave.b_rows {
            let nnz = acc_u64(b.row_nnz(r as usize), "B-row nnz");
            let chunks = nnz.div_ceil(schedule.bundle_size as u64).max(1);
            stream_cycles += 2 * chunks + nnz; // header + 1 elem/cycle
            stream_cycles += style.indirection_cycles_per_row();
            b_words += acc_u64(
                crate::rir::layout::encoded_chain_words(
                    b.row_cols(r as usize),
                    schedule.bundle_size,
                    cfg.encoding,
                ),
                "B-row chain words",
            );
        }

        // ---- per-pipeline occupancy ----
        let mut max_pipe: u64 = 0;
        let mut max_body: u64 = 0;
        let mut products_total: u64 = 0;
        let mut merged_total: u64 = 0;
        let mut a_words: u64 = 0;
        for asg in &wave.assignments {
            let cam_load = acc_u64(asg.len, "CAM chunk length");
            let mut products: u64 = 0;
            tick = tick.wrapping_add(1);
            let mut merged: u64 = 0;
            for &c in asg.a_cols(a) {
                // single fused pass: product count from the row extent,
                // merged count from the stamp (perf iteration 4)
                let row = b.row_cols(c as usize);
                products += acc_u64(row.len(), "B-row product count");
                for &bc in row {
                    merged += u64::from(stamp[bc as usize] != tick);
                    stamp[bc as usize] = tick;
                }
            }
            products_total += products;
            merged_total += merged;
            a_words += acc_u64(
                crate::rir::layout::encoded_data_bundle_words(asg.a_cols(a), cfg.encoding),
                "A bundle words",
            );
            let body = if style.pipelined_stages() {
                // stages overlap; stream rate dominates (products ≤ stream)
                stream_cycles.max(products) + fill
            } else {
                // HLS: stage groups serialize — match/mult then sort then
                // merge drain back-to-back
                stream_cycles + 2 * products + fill
            };
            max_body = max_body.max(body);
            max_pipe = max_pipe.max(cam_load + body);
        }

        // frontend/backend split: the backend floor is the slowest
        // pipeline's post-CAM work (a depth-2 channel cannot retire the
        // wave faster than that, whichever pipe its CAM rode in on); the
        // CAM-load remainder of the critical pipe is the setup a depth-2
        // channel loads into the spare bank under the previous wave. The
        // expander fill for a non-raw encoding rides with the frontend
        // (and so is likewise hidden at depth ≥ 2); at Raw it is zero and
        // `setup + compute == max_pipe` keeps depth 1 bit-identical.
        debug_assert!(max_pipe >= max_body);
        let expansion =
            if wave.assignments.is_empty() { 0 } else { cfg.encoding.expansion_cycles() };
        costs.push(WaveCost {
            kind: WaveKind::Compute,
            stream_words: a_words + b_words,
            setup_cycles: max_pipe - max_body + expansion,
            compute_cycles: max_body,
            writeback_words: merged_total * 2, // (col, val)
            dependent_stream: false,
            occupancy: Occupancy::ActivePipelines(acc_u64(
                wave.assignments.len(),
                "active pipelines",
            )),
            flops: 2 * products_total, // multiply + merge-add
            waves: 1,
        });
    }
    costs
}

/// Per-job attribution within a batched simulation: exact integer shares
/// of the shared-wave accounting (no proportional rounding — every field
/// is a sum the job's own assignments/segments generated).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JobSimStats {
    /// Pipeline-cycles the job's assignments occupied.
    pub busy_pipeline_cycles: u64,
    /// Shared waves in which the job held at least one pipeline.
    pub waves: u64,
    /// Useful FP operations (multiply + merge-add) the job performed.
    pub flops: u64,
    /// DRAM bytes read for the job (its A chunks + its B segments).
    pub bytes_read: u64,
    /// DRAM bytes written for the job's merged output.
    pub bytes_written: u64,
    /// The job rode at least one wave whose stream corruption survived
    /// every retry ([`WaveFault::failed`]): its output is unusable and the
    /// coordinator reports it failed, without failing the rest of the
    /// batch. Always `false` on the fault-free path.
    pub failed: bool,
    /// Cycle (within the batched execution, at the executed depth) at
    /// which the job's first shared wave starts — the prefix sum of
    /// [`BatchSimResult::wave_cycles`] before it. `0` for a job that
    /// rides no wave (`waves == 0`).
    pub enqueue_cycle: u64,
    /// Cycle at which the job's last shared wave finishes. The serving
    /// layer derives per-job completion latency from this instead of
    /// re-walking wave indices. `0` for a job that rides no wave; the
    /// maximum over jobs of a non-empty batch equals
    /// [`SimStats::cycles`](crate::fpga::SimStats::cycles).
    pub complete_cycle: u64,
}

/// Result of simulating one batched (multi-tenant) SpGEMM execution.
#[derive(Clone, Debug)]
pub struct BatchSimResult {
    /// Aggregate statistics over the shared waves.
    pub stats: SimStats,
    /// Cycle count per shared wave (drives the overlap pipeline).
    pub wave_cycles: Vec<u64>,
    /// Per-job attribution, indexed by job id.
    pub job_stats: Vec<JobSimStats>,
    /// Per-wave cost description handed to the engine (aggregate only —
    /// per-job attribution always follows the executed depth's deltas).
    pub costs: Vec<WaveCost>,
    /// Shared waves whose retries were exhausted
    /// ([`crate::fpga::engine::EngineResult::failed_waves`]); empty
    /// without fault injection.
    pub failed_waves: Vec<usize>,
}

/// Simulate N independent jobs `C_j = A_j × B_j` sharing the design's
/// pipelines over a prebuilt [`BatchSchedule`].
///
/// Per-pipeline occupancy keeps the [`simulate_spgemm`] model —
/// `cam + max(stream, products) + fill` — but the stream a pipeline races
/// is its **own tenant's segment**: the job tags let the input controller
/// keep one stream cursor per job-run and broadcast each segment to just
/// its pipeline group, concurrently (the single-tenant design's one
/// broadcast bus consumes 1 elem/cycle and cannot exploit the 64/128
/// designs' DRAM bandwidth; per-tenant lanes can — the aggregate is still
/// capped by the wave's `max(compute, dram)` queuing model, which charges
/// every segment's bytes). A single-job batch degenerates to exactly the
/// single-tenant model: one segment, one lane, identical numbers.
///
/// What batching buys is fewer, fuller waves: a wave costs its *slowest
/// tenant*, not the sum of tenants, and idle pipeline-cycles collapse
/// (measured by `stats.pipeline_utilization()`).
pub fn simulate_spgemm_batch(
    jobs: &[(Csr, Csr)],
    schedule: &BatchSchedule,
    cfg: &FpgaConfig,
    style: Style,
) -> BatchSimResult {
    simulate_spgemm_batch_with_faults(jobs, schedule, cfg, style, None)
}

/// [`simulate_spgemm_batch`] with per-wave stream-fault outcomes (drawn
/// by [`crate::reliability::draw_wave_faults`]).
///
/// Retries are charged to [`SimStats::retry_cycles`] by the engine; a
/// wave that exhausts [`FpgaConfig::max_wave_retries`] fails **only the
/// jobs riding it** — each such job's [`JobSimStats::failed`] is set and
/// the wave index lands in [`BatchSimResult::failed_waves`], while every
/// other job's results stay exactly as simulated. `faults == None` is
/// bit-identical to [`simulate_spgemm_batch`].
pub fn simulate_spgemm_batch_with_faults(
    jobs: &[(Csr, Csr)],
    schedule: &BatchSchedule,
    cfg: &FpgaConfig,
    style: Style,
    faults: Option<&[WaveFault]>,
) -> BatchSimResult {
    assert_eq!(jobs.len(), schedule.n_jobs, "job list does not match schedule");
    let mut costs = Vec::with_capacity(schedule.waves.len());
    let mut job_stats = vec![JobSimStats::default(); jobs.len()];
    // per wave: (job, pipelines held) runs, for post-engine attribution
    let mut wave_runs: Vec<Vec<(usize, u64)>> = Vec::with_capacity(schedule.waves.len());

    // one stamp scratch over the widest output column space; ticks are
    // unique per assignment, so jobs can never alias each other's stamps
    let max_ncols = jobs.iter().map(|(_, b)| b.ncols).max().unwrap_or(0);
    let mut stamp = vec![u32::MAX; max_ncols];
    let mut tick = 0u32;

    let fill = 2 + cfg.mult_latency + cfg.add_latency;

    for wave in &schedule.waves {
        // ---- B streams: one concurrent lane per tenant segment ----
        let mut seg_streams: Vec<u64> = Vec::with_capacity(wave.segments.len());
        let mut b_words: u64 = 0;
        for seg in &wave.segments {
            let b = &jobs[seg.job as usize].1;
            let mut seg_stream: u64 = 0;
            let mut seg_words: u64 = 0;
            for &r in &seg.b_rows {
                let nnz = acc_u64(b.row_nnz(r as usize), "B-row nnz");
                let chunks = nnz.div_ceil(schedule.bundle_size as u64).max(1);
                seg_stream += 2 * chunks + nnz; // header + 1 elem/cycle
                seg_stream += style.indirection_cycles_per_row();
                seg_words += acc_u64(
                    crate::rir::layout::encoded_chain_words(
                        b.row_cols(r as usize),
                        schedule.bundle_size,
                        cfg.encoding,
                    ),
                    "B-row chain words",
                );
            }
            seg_streams.push(seg_stream);
            job_stats[seg.job as usize].bytes_read += seg_words * WORD_BYTES as u64;
            b_words += seg_words;
        }

        // ---- per-pipeline occupancy + per-job work; assignments are
        // job-major, so the run index walks `segments` in lockstep ----
        let mut max_pipe: u64 = 0;
        let mut max_body: u64 = 0;
        let mut products_total: u64 = 0;
        let mut merged_total: u64 = 0;
        let mut a_words: u64 = 0;
        let mut run_counts = vec![0u64; wave.segments.len()];
        let mut run_idx = 0usize;
        let mut prev_job: Option<u32> = None;
        for (j, asg) in wave.assignments.iter() {
            let ji = *j as usize;
            if let Some(prev) = prev_job {
                if prev != *j {
                    run_idx += 1;
                }
            }
            prev_job = Some(*j);
            // hard assert (not debug): the fields are pub, and a skewed
            // wave would silently misattribute tenant stats in release
            assert_eq!(wave.segments[run_idx].job, *j, "segment/run skew in batch wave");
            run_counts[run_idx] += 1;
            let stream_cycles = seg_streams[run_idx];
            let (a, b) = &jobs[ji];
            let cam_load = acc_u64(asg.len, "CAM chunk length");
            let mut products: u64 = 0;
            tick = tick.wrapping_add(1);
            let mut merged: u64 = 0;
            for &c in asg.a_cols(a) {
                let row = b.row_cols(c as usize);
                products += acc_u64(row.len(), "B-row product count");
                for &bc in row {
                    merged += u64::from(stamp[bc as usize] != tick);
                    stamp[bc as usize] = tick;
                }
            }
            products_total += products;
            merged_total += merged;
            let chunk_words = acc_u64(
                crate::rir::layout::encoded_data_bundle_words(asg.a_cols(a), cfg.encoding),
                "A bundle words",
            );
            a_words += chunk_words;
            let js = &mut job_stats[ji];
            js.flops += 2 * products;
            js.bytes_read += chunk_words * WORD_BYTES as u64;
            js.bytes_written += merged * 2 * WORD_BYTES as u64;
            let body = if style.pipelined_stages() {
                stream_cycles.max(products) + fill
            } else {
                stream_cycles + 2 * products + fill
            };
            max_body = max_body.max(body);
            max_pipe = max_pipe.max(cam_load + body);
        }

        // ---- cost description, exactly the single-job model (same
        // backend-floor frontend/backend split and expander-fill setup
        // term as `spgemm_wave_costs`) ----
        debug_assert!(max_pipe >= max_body);
        let expansion =
            if wave.assignments.is_empty() { 0 } else { cfg.encoding.expansion_cycles() };
        costs.push(WaveCost {
            kind: WaveKind::Compute,
            stream_words: a_words + b_words,
            setup_cycles: max_pipe - max_body + expansion,
            compute_cycles: max_body,
            writeback_words: merged_total * 2,
            dependent_stream: false,
            occupancy: Occupancy::ActivePipelines(acc_u64(
                wave.assignments.len(),
                "active pipelines",
            )),
            flops: 2 * products_total,
            waves: 1,
        });
        wave_runs.push(
            wave.segments
                .iter()
                .zip(&run_counts)
                .map(|(seg, &n_asg)| (seg.job as usize, n_asg))
                .collect(),
        );
    }

    let engine = execute_waves_with_faults(&costs, cfg, cfg.dram_buffer_depth, faults);
    // `item_cycles` sum to `stats.cycles` at every depth, so the running
    // prefix is an exact enqueue/complete timestamp pair per job
    let mut wave_start = 0u64;
    for (runs, &wave_cy) in wave_runs.iter().zip(&engine.item_cycles) {
        let wave_end = wave_start + wave_cy;
        for &(job, n_asg) in runs {
            let js = &mut job_stats[job];
            if js.waves == 0 {
                js.enqueue_cycle = wave_start;
            }
            js.waves += 1;
            js.busy_pipeline_cycles += n_asg * wave_cy;
            js.complete_cycle = wave_end;
        }
        wave_start = wave_end;
    }
    // graceful degradation: a dead wave kills only the tenants riding it
    for &w in &engine.failed_waves {
        for &(job, _) in &wave_runs[w] {
            job_stats[job].failed = true;
        }
    }
    BatchSimResult {
        stats: engine.stats,
        wave_cycles: engine.item_cycles,
        job_stats,
        costs,
        failed_waves: engine.failed_waves,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::schedule::schedule_spgemm;
    use crate::sparse::gen;

    fn sim(n: usize, nnz: usize, cfg: &FpgaConfig, style: Style) -> SpgemmSimResult {
        let a = gen::random_uniform(n, n, nnz, 11);
        let s = schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
        simulate_spgemm(&a, &a, &s, cfg, style)
    }

    #[test]
    fn produces_nonzero_work() {
        let r = sim(200, 3000, &FpgaConfig::reap32_spgemm(), Style::HandCoded);
        assert!(r.stats.cycles > 0);
        assert!(r.stats.flops > 0);
        assert!(r.stats.bytes_read > 0);
        assert!(r.stats.bytes_written > 0);
        assert_eq!(usize::try_from(r.stats.waves).unwrap(), r.wave_cycles.len());
        assert_eq!(
            r.stats.cycles,
            r.wave_cycles.iter().sum::<u64>(),
            "wave log must sum to total"
        );
    }

    #[test]
    fn flops_match_analytic_count() {
        let a = gen::random_uniform(100, 100, 1500, 3);
        let cfg = FpgaConfig::reap32_spgemm();
        let s = schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
        let r = simulate_spgemm(&a, &a, &s, &cfg, Style::HandCoded);
        assert_eq!(r.stats.flops as usize, crate::kernels::spgemm::spgemm_flops(&a, &a));
    }

    #[test]
    fn more_pipelines_fewer_cycles() {
        let a = gen::random_uniform(400, 400, 12000, 5);
        let c32 = FpgaConfig::reap32_spgemm();
        let c128 = FpgaConfig::reap128_spgemm();
        let s32 = schedule_spgemm(&a, &a, c32.pipelines, c32.bundle_size);
        let s128 = schedule_spgemm(&a, &a, c128.pipelines, c128.bundle_size);
        let r32 = simulate_spgemm(&a, &a, &s32, &c32, Style::HandCoded);
        let r128 = simulate_spgemm(&a, &a, &s128, &c128, Style::HandCoded);
        assert!(
            r128.stats.cycles < r32.stats.cycles,
            "128 pipelines w/ 10x bandwidth must beat 32: {} vs {}",
            r128.stats.cycles,
            r32.stats.cycles
        );
    }

    #[test]
    fn hls_slower_than_handcoded_and_raw_slowest() {
        let cfg = FpgaConfig::reap32_spgemm();
        let hand = sim(150, 2500, &cfg, Style::HandCoded);
        let hls = sim(150, 2500, &cfg, Style::HlsPreprocessed);
        let raw = sim(150, 2500, &cfg, Style::HlsRaw);
        assert!(hls.stats.cycles > hand.stats.cycles);
        assert!(raw.stats.cycles > hls.stats.cycles);
    }

    #[test]
    fn bandwidth_cap_binds_on_bandwidth_starved_config() {
        // Same design, bandwidth crushed 100x -> DRAM must become the bound
        let mut starved = FpgaConfig::reap32_spgemm();
        starved.dram.read_gbps = 0.14;
        starved.dram.write_gbps = 0.14;
        let fast = sim(200, 4000, &FpgaConfig::reap32_spgemm(), Style::HandCoded);
        let slow = sim(200, 4000, &starved, Style::HandCoded);
        assert!(slow.stats.cycles > fast.stats.cycles * 5);
        assert!(slow.stats.dram_bound_fraction() > 0.9);
    }

    #[test]
    fn idle_cycles_appear_when_rows_scarce() {
        // 8 rows on 32 pipelines -> most pipelines idle
        let r = sim(8, 60, &FpgaConfig::reap32_spgemm(), Style::HandCoded);
        assert!(r.stats.idle_pipeline_cycles > 0);
        assert!(r.stats.pipeline_utilization() < 0.5);
    }

    #[test]
    fn empty_matrix_costs_nothing() {
        let a = Csr::new(10, 10);
        let cfg = FpgaConfig::reap32_spgemm();
        let s = schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
        let r = simulate_spgemm(&a, &a, &s, &cfg, Style::HandCoded);
        assert_eq!(r.stats.cycles, 0);
    }

    // ---- batched (multi-tenant) simulation ----

    use crate::rir::schedule::schedule_spgemm_batch;

    fn mk_jobs(n_jobs: usize, n: usize, nnz: usize, seed: u64) -> Vec<(Csr, Csr)> {
        (0..n_jobs)
            .map(|j| {
                let s = seed + j as u64 * 10;
                (
                    gen::random_uniform(n, n, nnz, s),
                    gen::random_uniform(n, n, nnz, s + 1),
                )
            })
            .collect()
    }

    #[test]
    fn batch_sim_conservation_laws() {
        let jobs = mk_jobs(5, 40, 300, 21);
        let cfg = FpgaConfig::reap64_spgemm();
        let s = schedule_spgemm_batch(&jobs, cfg.pipelines, cfg.bundle_size);
        let r = simulate_spgemm_batch(&jobs, &s, &cfg, Style::HandCoded);
        assert_eq!(r.stats.cycles, r.wave_cycles.iter().sum::<u64>());
        assert_eq!(usize::try_from(r.stats.waves).unwrap(), s.n_waves());
        assert_eq!(
            r.stats.busy_pipeline_cycles + r.stats.idle_pipeline_cycles,
            cfg.pipelines as u64 * r.stats.cycles
        );
        // per-job attribution partitions the aggregate exactly
        assert_eq!(
            r.job_stats.iter().map(|j| j.flops).sum::<u64>(),
            r.stats.flops
        );
        assert_eq!(
            r.job_stats.iter().map(|j| j.busy_pipeline_cycles).sum::<u64>(),
            r.stats.busy_pipeline_cycles
        );
        assert_eq!(
            r.job_stats.iter().map(|j| j.bytes_read).sum::<u64>(),
            r.stats.bytes_read
        );
        assert_eq!(
            r.job_stats.iter().map(|j| j.bytes_written).sum::<u64>(),
            r.stats.bytes_written
        );
        // traffic matches the schedule's word accounting on the read side
        assert_eq!(usize::try_from(r.stats.bytes_read).unwrap(), s.input_bytes());
        // per-job flops equal each job's analytic count
        for (j, (a, b)) in jobs.iter().enumerate() {
            assert_eq!(
                usize::try_from(r.job_stats[j].flops).unwrap(),
                crate::kernels::spgemm::spgemm_flops(a, b),
                "job {j}"
            );
        }
    }

    #[test]
    fn per_job_timestamps_are_wave_prefix_sums() {
        let jobs = mk_jobs(6, 35, 250, 27);
        let cfg = FpgaConfig::reap32_spgemm();
        let s = schedule_spgemm_batch(&jobs, cfg.pipelines, cfg.bundle_size);
        let r = simulate_spgemm_batch(&jobs, &s, &cfg, Style::HandCoded);
        let mut ends = Vec::with_capacity(r.wave_cycles.len());
        let mut acc = 0u64;
        for &c in &r.wave_cycles {
            acc += c;
            ends.push(acc);
        }
        for (j, js) in r.job_stats.iter().enumerate() {
            let riding: Vec<usize> = s
                .waves
                .iter()
                .enumerate()
                .filter(|(_, w)| w.segments.iter().any(|seg| seg.job as usize == j))
                .map(|(i, _)| i)
                .collect();
            assert!(!riding.is_empty(), "job {j} rides no wave");
            let first = riding[0];
            let last = *riding.last().unwrap();
            let expect_enq = if first == 0 { 0 } else { ends[first - 1] };
            assert_eq!(js.enqueue_cycle, expect_enq, "job {j} enqueue");
            assert_eq!(js.complete_cycle, ends[last], "job {j} complete");
            assert!(js.enqueue_cycle < js.complete_cycle, "job {j} window must be nonempty");
        }
        let max_complete = r.job_stats.iter().map(|js| js.complete_cycle).max().unwrap();
        assert_eq!(max_complete, r.stats.cycles, "last completion is the batch end");
    }

    #[test]
    fn batching_small_jobs_beats_serial_occupancy() {
        // many small jobs: alone each underfills a 64-wide design
        let jobs = mk_jobs(12, 30, 180, 31);
        let cfg = FpgaConfig::reap64_spgemm();
        let s = schedule_spgemm_batch(&jobs, cfg.pipelines, cfg.bundle_size);
        let batch = simulate_spgemm_batch(&jobs, &s, &cfg, Style::HandCoded);

        let mut serial_busy = 0u64;
        let mut serial_total = 0u64;
        let mut serial_cycles = 0u64;
        for (a, b) in &jobs {
            let solo = schedule_spgemm(a, b, cfg.pipelines, cfg.bundle_size);
            let r = simulate_spgemm(a, b, &solo, &cfg, Style::HandCoded);
            serial_busy += r.stats.busy_pipeline_cycles;
            serial_total += r.stats.busy_pipeline_cycles + r.stats.idle_pipeline_cycles;
            serial_cycles += r.stats.cycles;
        }
        let serial_occ = serial_busy as f64 / serial_total as f64;
        assert!(
            batch.stats.pipeline_utilization() > serial_occ,
            "batched occupancy {:.3} must beat serial {:.3}",
            batch.stats.pipeline_utilization(),
            serial_occ
        );
        assert!(
            batch.stats.cycles < serial_cycles,
            "shared waves must cost fewer cycles: {} vs {}",
            batch.stats.cycles,
            serial_cycles
        );
    }

    #[test]
    fn batch_faults_charge_retries_and_fail_only_riding_jobs() {
        let jobs = mk_jobs(5, 40, 300, 21);
        let cfg = FpgaConfig::reap64_spgemm();
        let s = schedule_spgemm_batch(&jobs, cfg.pipelines, cfg.bundle_size);
        let base = simulate_spgemm_batch(&jobs, &s, &cfg, Style::HandCoded);
        assert!(base.failed_waves.is_empty());
        assert!(base.job_stats.iter().all(|j| !j.failed));
        assert_eq!(base.stats.retry_cycles, 0);

        // None and all-default faults are bit-identical to the plain path
        let zeros = vec![WaveFault::default(); s.n_waves()];
        let rz =
            simulate_spgemm_batch_with_faults(&jobs, &s, &cfg, Style::HandCoded, Some(&zeros));
        assert_eq!(rz.stats, base.stats);
        assert_eq!(rz.wave_cycles, base.wave_cycles);

        // retry one wave, fail another: the ledger is exact and only the
        // failed wave's tenants are marked
        assert!(s.n_waves() >= 2, "suite must span at least two waves");
        let mut faults = zeros;
        faults[0].retries = 2;
        let last = faults.len() - 1;
        faults[last] = WaveFault { retries: 1, failed: true };
        let rf =
            simulate_spgemm_batch_with_faults(&jobs, &s, &cfg, Style::HandCoded, Some(&faults));
        assert!(rf.stats.retry_cycles > 0);
        assert_eq!(rf.stats.cycles, base.stats.cycles + rf.stats.retry_cycles);
        assert_eq!(rf.stats.bytes_read, base.stats.bytes_read, "traffic is fault-invariant");
        assert_eq!(rf.stats.flops, base.stats.flops);
        assert_eq!(rf.failed_waves, vec![last]);
        let riding: Vec<usize> =
            s.waves[last].segments.iter().map(|seg| seg.job as usize).collect();
        for (j, js) in rf.job_stats.iter().enumerate() {
            assert_eq!(js.failed, riding.contains(&j), "job {j}");
        }
        assert!(
            rf.job_stats.iter().any(|j| !j.failed),
            "a single dead wave must not take down every tenant"
        );
    }

    #[test]
    fn encoded_streams_price_both_operands_and_match_batch_partition() {
        use crate::rir::layout::StreamEncoding;
        let a = gen::random_uniform(80, 80, 1200, 51);
        let b = gen::random_uniform(80, 80, 1200, 52);
        let base = FpgaConfig::reap32_spgemm();
        let s = schedule_spgemm(&a, &b, base.pipelines, base.bundle_size);
        let raw = simulate_spgemm(&a, &b, &s, &base, Style::HandCoded);
        for enc in [StreamEncoding::Bitmap, StreamEncoding::Fx, StreamEncoding::BitmapFx] {
            let cfg = FpgaConfig { encoding: enc, ..base.clone() };
            let r = simulate_spgemm(&a, &b, &s, &cfg, Style::HandCoded);
            // compression touches only the read side of the ledger
            assert!(
                r.stats.bytes_read <= raw.stats.bytes_read,
                "{enc}: encoded reads must never exceed raw"
            );
            assert_eq!(r.stats.bytes_written, raw.stats.bytes_written, "{enc}: writeback raw");
            assert_eq!(r.stats.flops, raw.stats.flops, "{enc}: same useful work");
            assert_eq!(r.stats.waves, raw.stats.waves, "{enc}: same schedule");
            if enc.fx() {
                // ~15 nnz/row: packed value lanes always beat one word/value
                assert!(r.stats.bytes_read < raw.stats.bytes_read, "{enc}: fx must shrink");
            }
            // the batch path prices streams through the same helpers, so a
            // single-job batch stays bit-identical at every encoding
            let jobs = vec![(a.clone(), b.clone())];
            let bs = schedule_spgemm_batch(&jobs, cfg.pipelines, cfg.bundle_size);
            let rb = simulate_spgemm_batch(&jobs, &bs, &cfg, Style::HandCoded);
            let solo = schedule_spgemm(&a, &b, cfg.pipelines, cfg.bundle_size);
            let rs = simulate_spgemm(&a, &b, &solo, &cfg, Style::HandCoded);
            assert_eq!(rb.stats, rs.stats, "{enc}: single-job batch == plain sim");
            assert_eq!(
                rb.job_stats[0].bytes_read,
                rb.stats.bytes_read,
                "{enc}: one tenant owns every encoded byte"
            );
        }
    }

    #[test]
    fn single_job_batch_matches_plain_sim() {
        let a = gen::random_uniform(60, 60, 700, 41);
        let b = gen::random_uniform(60, 60, 700, 42);
        let cfg = FpgaConfig::reap32_spgemm();
        let jobs = vec![(a.clone(), b.clone())];
        let bs = schedule_spgemm_batch(&jobs, cfg.pipelines, cfg.bundle_size);
        let solo = schedule_spgemm(&a, &b, cfg.pipelines, cfg.bundle_size);
        let rb = simulate_spgemm_batch(&jobs, &bs, &cfg, Style::HandCoded);
        let rs = simulate_spgemm(&a, &b, &solo, &cfg, Style::HandCoded);
        assert_eq!(rb.stats, rs.stats);
        assert_eq!(rb.wave_cycles, rs.wave_cycles);
    }
}
