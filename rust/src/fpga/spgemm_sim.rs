//! Cycle model of the SpGEMM datapath (paper Fig 1).
//!
//! Each wave of the RIR schedule runs the five-module pipeline:
//!
//! 1. **input controller** loads each pipeline's CAM with its A-chunk
//!    (1 entry/cycle) and broadcasts the wave's B-row bundles;
//! 2. **match + multiply**: every streamed B element is CAM-matched in one
//!    cycle; matches enqueue to the (initiation-interval-1) multiplier;
//! 3. **sort**: shift-register insertion sorter, one partial product per
//!    cycle;
//! 4. **merge**: compare-with-head accumulator, one partial product per
//!    cycle;
//! 5. **output controller** drains merged results to DRAM.
//!
//! All stages are pipelined, so a pipeline's wave cost is the *maximum* of
//! its stage occupancies plus the fill latency — in the hand-coded design
//! the broadcast stream rate dominates (that is the paper's point: with
//! RIR the datapath runs at stream rate). The §V-C HLS variant instead
//! *serializes* the stages and, without CPU preprocessing, pays an
//! indirection penalty per B-row gather.

use crate::rir::schedule::SpgemmSchedule;
use crate::rir::layout::WORD_BYTES;
use crate::sparse::Csr;

use super::config::FpgaConfig;
use super::dram::DramModel;
use super::stats::SimStats;

/// Datapath style: hand-coded Verilog (the REAP prototype) or the OpenCL
/// HLS variant of §V-C.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Style {
    /// Hand-coded RTL: fully pipelined stages.
    HandCoded,
    /// HLS with RIR preprocessing: correct dataflow but the toolchain
    /// serializes stage groups ("the HLS designs tend to be a lot slower").
    HlsPreprocessed,
    /// HLS reading raw CSR: additionally pays per-row indirection
    /// (pointer-chase + unaligned gather) on every B-row access.
    HlsRaw,
}

impl Style {
    /// HLS clocks lower than hand-tuned RTL on the same device. Applied
    /// when converting cycles to seconds (see `fpga::hls`).
    pub fn freq_derate(self) -> f64 {
        match self {
            Style::HandCoded => 1.0,
            Style::HlsPreprocessed | Style::HlsRaw => 0.6,
        }
    }

    /// Extra cycles per B-row access for raw-CSR indirection (row-pointer
    /// lookup + short-burst setup — the irregularity REAP eliminates).
    /// Calibrated so the suite geomean of the preprocessing benefit lands
    /// near the paper's §V-C numbers (16% SpGEMM).
    fn indirection_cycles_per_row(self) -> u64 {
        match self {
            Style::HlsRaw => 6,
            _ => 0,
        }
    }

    pub(crate) fn pipelined_stages(self) -> bool {
        matches!(self, Style::HandCoded)
    }
}

/// Result of simulating one SpGEMM execution.
#[derive(Clone, Debug)]
pub struct SpgemmSimResult {
    pub stats: SimStats,
    /// Cycle count per wave (diagnostics / ablation).
    pub wave_cycles: Vec<u64>,
}

/// Simulate `C = A × B` on the configured design over a prebuilt schedule.
///
/// `b` supplies row lengths and column patterns; values are not consulted
/// (the numeric result comes from the XLA artifact path or the CPU
/// reference — the simulator is a timing model, like the paper's).
pub fn simulate_spgemm(
    a: &Csr,
    b: &Csr,
    schedule: &SpgemmSchedule,
    cfg: &FpgaConfig,
    style: Style,
) -> SpgemmSimResult {
    let p = cfg.pipelines;
    let mut stats = SimStats::default();
    let mut dram = DramModel::default();
    let mut wave_cycles_log = Vec::with_capacity(schedule.waves.len());

    // scratch for merged-output counting (stamped SPA over B's columns)
    let mut stamp = vec![u32::MAX; b.ncols];
    let mut tick = 0u32;

    // pipeline fill latency: match(1) + mult + sort(1) + merge/add
    let fill = 2 + cfg.mult_latency + cfg.add_latency;

    for wave in &schedule.waves {
        // ---- B broadcast stream occupancy (shared by all pipelines) ----
        let mut stream_cycles: u64 = 0;
        let mut b_elems: u64 = 0;
        for &r in &wave.b_rows {
            let nnz = b.row_nnz(r as usize) as u64;
            let chunks = nnz.div_ceil(schedule.bundle_size as u64).max(1);
            stream_cycles += 2 * chunks + nnz; // header + 1 elem/cycle
            b_elems += nnz;
            stream_cycles += style.indirection_cycles_per_row();
        }

        // ---- per-pipeline occupancy ----
        let mut max_pipe: u64 = 0;
        let mut products_total: u64 = 0;
        let mut merged_total: u64 = 0;
        for asg in &wave.assignments {
            let cam_load = asg.len as u64;
            let mut products: u64 = 0;
            tick = tick.wrapping_add(1);
            let mut merged: u64 = 0;
            for &c in asg.a_cols(a) {
                // single fused pass: product count from the row extent,
                // merged count from the stamp (perf iteration 4)
                let row = b.row_cols(c as usize);
                products += row.len() as u64;
                for &bc in row {
                    merged += u64::from(stamp[bc as usize] != tick);
                    stamp[bc as usize] = tick;
                }
            }
            products_total += products;
            merged_total += merged;
            let pipe = if style.pipelined_stages() {
                // stages overlap; stream rate dominates (products ≤ stream)
                cam_load + stream_cycles.max(products) + fill
            } else {
                // HLS: stage groups serialize — match/mult then sort then
                // merge drain back-to-back
                cam_load + stream_cycles + 2 * products + fill
            };
            max_pipe = max_pipe.max(pipe);
        }

        // ---- DRAM traffic for this wave ----
        let a_bytes: u64 = wave
            .assignments
            .iter()
            .map(|asg| (2 + 2 * asg.len) as u64 * WORD_BYTES as u64)
            .sum();
        let mut b_bytes: u64 = 0;
        for &r in &wave.b_rows {
            let nnz = b.row_nnz(r as usize) as u64;
            let chunks = nnz.div_ceil(schedule.bundle_size as u64).max(1);
            b_bytes += (2 * chunks + 2 * nnz) * WORD_BYTES as u64;
        }
        let out_bytes = merged_total * 2 * WORD_BYTES as u64; // (col, val)
        let read_cycles = dram.read(cfg, a_bytes + b_bytes);
        let write_cycles = dram.write(cfg, out_bytes);

        // ---- wave cost: compute and DRAM overlap ----
        let compute = max_pipe;
        let dram_cy = read_cycles.max(write_cycles);
        let wave_cy = compute.max(dram_cy).max(1);
        if compute >= dram_cy {
            stats.compute_bound_cycles += wave_cy;
        } else {
            stats.dram_bound_cycles += wave_cy;
        }
        stats.cycles += wave_cy;
        stats.waves += 1;
        let active = wave.assignments.len() as u64;
        stats.busy_pipeline_cycles += active * wave_cy;
        stats.idle_pipeline_cycles += (p as u64 - active) * wave_cy;
        stats.flops += 2 * products_total; // multiply + merge-add
        let _ = b_elems;
        wave_cycles_log.push(wave_cy);
    }

    stats.bytes_read = dram.bytes_read;
    stats.bytes_written = dram.bytes_written;
    let _ = a;
    SpgemmSimResult { stats, wave_cycles: wave_cycles_log }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::schedule::schedule_spgemm;
    use crate::sparse::gen;

    fn sim(n: usize, nnz: usize, cfg: &FpgaConfig, style: Style) -> SpgemmSimResult {
        let a = gen::random_uniform(n, n, nnz, 11);
        let s = schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
        simulate_spgemm(&a, &a, &s, cfg, style)
    }

    #[test]
    fn produces_nonzero_work() {
        let r = sim(200, 3000, &FpgaConfig::reap32_spgemm(), Style::HandCoded);
        assert!(r.stats.cycles > 0);
        assert!(r.stats.flops > 0);
        assert!(r.stats.bytes_read > 0);
        assert!(r.stats.bytes_written > 0);
        assert_eq!(r.stats.waves as usize, r.wave_cycles.len());
        assert_eq!(
            r.stats.cycles,
            r.wave_cycles.iter().sum::<u64>(),
            "wave log must sum to total"
        );
    }

    #[test]
    fn flops_match_analytic_count() {
        let a = gen::random_uniform(100, 100, 1500, 3);
        let cfg = FpgaConfig::reap32_spgemm();
        let s = schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
        let r = simulate_spgemm(&a, &a, &s, &cfg, Style::HandCoded);
        assert_eq!(r.stats.flops as usize, crate::kernels::spgemm::spgemm_flops(&a, &a));
    }

    #[test]
    fn more_pipelines_fewer_cycles() {
        let a = gen::random_uniform(400, 400, 12000, 5);
        let c32 = FpgaConfig::reap32_spgemm();
        let c128 = FpgaConfig::reap128_spgemm();
        let s32 = schedule_spgemm(&a, &a, c32.pipelines, c32.bundle_size);
        let s128 = schedule_spgemm(&a, &a, c128.pipelines, c128.bundle_size);
        let r32 = simulate_spgemm(&a, &a, &s32, &c32, Style::HandCoded);
        let r128 = simulate_spgemm(&a, &a, &s128, &c128, Style::HandCoded);
        assert!(
            r128.stats.cycles < r32.stats.cycles,
            "128 pipelines w/ 10x bandwidth must beat 32: {} vs {}",
            r128.stats.cycles,
            r32.stats.cycles
        );
    }

    #[test]
    fn hls_slower_than_handcoded_and_raw_slowest() {
        let cfg = FpgaConfig::reap32_spgemm();
        let hand = sim(150, 2500, &cfg, Style::HandCoded);
        let hls = sim(150, 2500, &cfg, Style::HlsPreprocessed);
        let raw = sim(150, 2500, &cfg, Style::HlsRaw);
        assert!(hls.stats.cycles > hand.stats.cycles);
        assert!(raw.stats.cycles > hls.stats.cycles);
    }

    #[test]
    fn bandwidth_cap_binds_on_bandwidth_starved_config() {
        // Same design, bandwidth crushed 100x -> DRAM must become the bound
        let mut starved = FpgaConfig::reap32_spgemm();
        starved.dram.read_gbps = 0.14;
        starved.dram.write_gbps = 0.14;
        let fast = sim(200, 4000, &FpgaConfig::reap32_spgemm(), Style::HandCoded);
        let slow = sim(200, 4000, &starved, Style::HandCoded);
        assert!(slow.stats.cycles > fast.stats.cycles * 5);
        assert!(slow.stats.dram_bound_fraction() > 0.9);
    }

    #[test]
    fn idle_cycles_appear_when_rows_scarce() {
        // 8 rows on 32 pipelines -> most pipelines idle
        let r = sim(8, 60, &FpgaConfig::reap32_spgemm(), Style::HandCoded);
        assert!(r.stats.idle_pipeline_cycles > 0);
        assert!(r.stats.pipeline_utilization() < 0.5);
    }

    #[test]
    fn empty_matrix_costs_nothing() {
        let a = Csr::new(10, 10);
        let cfg = FpgaConfig::reap32_spgemm();
        let s = schedule_spgemm(&a, &a, cfg.pipelines, cfg.bundle_size);
        let r = simulate_spgemm(&a, &a, &s, &cfg, Style::HandCoded);
        assert_eq!(r.stats.cycles, 0);
    }
}
