//! Fault injection for the RIR stream path and the wave-retry model.
//!
//! The DRAM link between the CPU encoder and the FPGA input controller
//! can corrupt the serialized RIR words (bit flips) or mangle the stream
//! shape (truncation, duplication, reordering). This module provides the
//! two halves of the reliability story:
//!
//! * [`FaultInjector`] — a seed-deterministic corruptor of serialized
//!   stream words, used by the reliability harness
//!   ([`crate::harness::reliability`]) and the property tests to measure
//!   what the checksummed wire format ([`crate::rir::bundle::BundleFlags::CHECKSUM`])
//!   detects and what the `try_*` decoders survive.
//! * [`draw_wave_faults`] — a seed-deterministic draw of per-wave
//!   [`WaveFault`] outcomes at a given corruption rate, consumed by
//!   [`crate::fpga::engine::execute_waves_with_faults`] (each detected
//!   corruption costs one full-serial replay, bounded by
//!   [`crate::fpga::FpgaConfig::max_wave_retries`]).
//!
//! Everything here is driven by [`Pcg64`] streams, so a `(seed, stream)`
//! pair reproduces the exact same corruption bit-for-bit — experiments
//! stay replayable, and the engine's retry ledger can be asserted
//! exactly.
//!
//! The `fuzz_decode_*` free functions are the shared drivers behind the
//! `fuzz/` crate's libFuzzer targets *and* the in-tree corpus-replay test
//! (`rust/tests/fuzz_corpus.rs`), so the corpus exercises the identical
//! code path on stable toolchains.

use crate::fpga::engine::WaveFault;
use crate::rir::decode::{try_words_panel_to_dense, try_words_segment_to_csr, try_words_to_csr};
use crate::util::rng::Pcg64;

/// Per-word corruption rates for a [`FaultInjector`]. All rates are
/// probabilities in `[0, 1]` applied independently per serialized word
/// (truncation is drawn once per stream).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultConfig {
    /// Probability that a word has one uniformly chosen bit flipped.
    pub bit_flip_rate: f64,
    /// Probability that the stream is cut at a uniformly chosen point
    /// (drawn once per `inject` call).
    pub truncate_rate: f64,
    /// Probability that a word is emitted twice.
    pub duplicate_rate: f64,
    /// Probability that a word is swapped with its successor.
    pub reorder_rate: f64,
}

impl FaultConfig {
    /// Bit flips only — the corruption mode the CRC32 word is designed to
    /// catch (single-bit detection is guaranteed; see ARCHITECTURE.md §3).
    pub fn bit_flips(rate: f64) -> Self {
        FaultConfig { bit_flip_rate: rate, ..Default::default() }
    }

    /// All four corruption modes at one shared rate.
    pub fn all(rate: f64) -> Self {
        FaultConfig {
            bit_flip_rate: rate,
            truncate_rate: rate,
            duplicate_rate: rate,
            reorder_rate: rate,
        }
    }
}

/// What one [`FaultInjector::inject`] call actually did to the stream.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultReport {
    /// Words that had a bit flipped.
    pub bit_flips: u64,
    /// Words dropped off the tail by truncation.
    pub truncated_words: u64,
    /// Words emitted twice.
    pub duplicated_words: u64,
    /// Adjacent swaps applied.
    pub reordered_swaps: u64,
}

impl FaultReport {
    /// Did any corruption land on the stream?
    pub fn corrupted(&self) -> bool {
        self.bit_flips + self.truncated_words + self.duplicated_words + self.reordered_swaps > 0
    }
}

/// Seed-deterministic corruptor of serialized RIR stream words.
///
/// The injector itself is immutable; each [`inject`](Self::inject) call
/// derives its randomness from `Pcg64::with_stream(seed, stream)`, so
/// corrupting stream 7 is independent of — and unaffected by — whether
/// streams 0–6 were corrupted first.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    seed: u64,
    cfg: FaultConfig,
}

impl FaultInjector {
    /// An injector applying `cfg`'s rates under `seed`.
    pub fn new(seed: u64, cfg: FaultConfig) -> Self {
        FaultInjector { seed, cfg }
    }

    /// The injector's rate configuration.
    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Corrupt `words` in place, deterministically for `(seed, stream)`.
    ///
    /// Order of operations: bit flips (in place), duplication (rebuild),
    /// adjacent reordering, truncation last — so a truncated stream can
    /// still carry flipped or duplicated words in its surviving prefix.
    pub fn inject(&self, stream: u64, words: &mut Vec<u32>) -> FaultReport {
        let mut rng = Pcg64::with_stream(self.seed, stream);
        let mut report = FaultReport::default();

        if self.cfg.bit_flip_rate > 0.0 {
            for w in words.iter_mut() {
                if rng.chance(self.cfg.bit_flip_rate) {
                    *w ^= 1u32 << rng.next_below(32);
                    report.bit_flips += 1;
                }
            }
        }

        if self.cfg.duplicate_rate > 0.0 && !words.is_empty() {
            let mut out = Vec::with_capacity(words.len());
            for &w in words.iter() {
                out.push(w);
                if rng.chance(self.cfg.duplicate_rate) {
                    out.push(w);
                    report.duplicated_words += 1;
                }
            }
            *words = out;
        }

        if self.cfg.reorder_rate > 0.0 && words.len() >= 2 {
            for i in 0..words.len() - 1 {
                if rng.chance(self.cfg.reorder_rate) {
                    words.swap(i, i + 1);
                    report.reordered_swaps += 1;
                }
            }
        }

        if self.cfg.truncate_rate > 0.0 && !words.is_empty() && rng.chance(self.cfg.truncate_rate) {
            let keep = rng.next_below(words.len() as u64) as usize;
            report.truncated_words = (words.len() - keep) as u64;
            words.truncate(keep);
        }

        report
    }
}

/// Draw per-wave stream-fault outcomes for an `n_waves`-wave run.
///
/// Models the input controller's detect-and-replay loop: each fetch of a
/// wave's stream is independently corrupted with probability
/// `fault_rate`; the controller re-fetches until a clean copy arrives or
/// `max_retries` replays are spent, after which the wave is marked
/// [`WaveFault::failed`]. Each wave draws from its own
/// `Pcg64::with_stream(seed, wave_index)`, so the outcome of wave *k* is
/// invariant to how many waves surround it.
///
/// `fault_rate == 0.0` returns all-default faults (bit-identical engine
/// timing); `fault_rate == 1.0` deterministically exhausts every wave's
/// budget (every draw fails), which the harness uses as its
/// graceful-degradation endpoint.
pub fn draw_wave_faults(
    seed: u64,
    n_waves: usize,
    fault_rate: f64,
    max_retries: usize,
) -> Vec<WaveFault> {
    let max = max_retries as u64;
    (0..n_waves)
        .map(|k| {
            let mut rng = Pcg64::with_stream(seed, k as u64);
            let mut failures: u64 = 0;
            while failures <= max && rng.chance(fault_rate) {
                failures += 1;
            }
            WaveFault { retries: failures.min(max), failed: failures > max }
        })
        .collect()
}

/// Reinterpret fuzzer bytes as RIR stream words (little-endian, tail
/// bytes dropped).
pub fn words_from_bytes(data: &[u8]) -> Vec<u32> {
    data.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

// Caps keep the fuzz drivers from allocating huge dense outputs for tiny
// inputs (a 16-byte input must not ask for a gigabyte panel).
const FUZZ_DIM_CAP: u64 = 4096;
const FUZZ_PANEL_CAP: u64 = 64;

/// Fuzz driver: `try_words_to_csr` must return, never panic, on any
/// byte string. The first word seeds the decode dimensions.
pub fn fuzz_decode_stream(data: &[u8]) {
    let words = words_from_bytes(data);
    let nrows = words.first().map_or(8, |&w| (w as u64 % FUZZ_DIM_CAP) as usize);
    let ncols = words.get(1).map_or(8, |&w| (w as u64 % FUZZ_DIM_CAP) as usize);
    let _ = try_words_to_csr(&words, nrows, ncols);
}

/// Fuzz driver for `try_words_segment_to_csr` (per-tenant extraction).
pub fn fuzz_decode_segment(data: &[u8]) {
    let words = words_from_bytes(data);
    let lo = words.first().map_or(0, |&w| (w as u64 % FUZZ_DIM_CAP) as usize);
    let hi = words.get(1).map_or(4, |&w| (w as u64 % FUZZ_DIM_CAP) as usize);
    let nrows = words.get(2).map_or(8, |&w| (w as u64 % FUZZ_DIM_CAP) as usize);
    let ncols = words.get(3).map_or(8, |&w| (w as u64 % FUZZ_DIM_CAP) as usize);
    let _ = try_words_segment_to_csr(&words, lo, hi, nrows, ncols);
}

/// Fuzz driver for `try_words_panel_to_dense` (SpMM dense panels).
pub fn fuzz_decode_panel(data: &[u8]) {
    let words = words_from_bytes(data);
    let lo = words.first().map_or(0, |&w| (w as u64 % FUZZ_DIM_CAP) as usize);
    let hi = words.get(1).map_or(4, |&w| (w as u64 % FUZZ_DIM_CAP) as usize);
    let nrows = words.get(2).map_or(8, |&w| (w as u64 % FUZZ_DIM_CAP) as usize);
    let k = words.get(3).map_or(4, |&w| (w as u64 % FUZZ_PANEL_CAP) as usize);
    let _ = try_words_panel_to_dense(&words, lo, hi, nrows, k);
}

/// Fuzz driver for the static stream auditor: [`crate::analysis::audit_stream`]
/// must return a diagnostic list, never panic, on any byte string. It walks
/// the same wire layouts the decoders accept, so it shares their corpus.
pub fn fuzz_lint_stream(data: &[u8]) {
    let _ = crate::analysis::audit_stream(&words_from_bytes(data));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_words() -> Vec<u32> {
        (0..64u32).map(|i| i.wrapping_mul(0x9e37_79b9) ^ 0x5EA9).collect()
    }

    #[test]
    fn injection_is_deterministic_per_seed_and_stream() {
        let inj = FaultInjector::new(42, FaultConfig::all(0.3));
        let mut a = sample_words();
        let mut b = sample_words();
        let ra = inj.inject(7, &mut a);
        let rb = inj.inject(7, &mut b);
        assert_eq!(a, b);
        assert_eq!(ra, rb);
        assert!(ra.corrupted(), "rate 0.3 over 64 words virtually always lands");

        // distinct streams diverge; distinct seeds diverge
        let mut c = sample_words();
        inj.inject(8, &mut c);
        assert_ne!(a, c);
        let mut d = sample_words();
        FaultInjector::new(43, FaultConfig::all(0.3)).inject(7, &mut d);
        assert_ne!(a, d);
    }

    #[test]
    fn zero_rates_are_a_noop() {
        let inj = FaultInjector::new(1, FaultConfig::default());
        let mut w = sample_words();
        let r = inj.inject(0, &mut w);
        assert_eq!(w, sample_words());
        assert_eq!(r, FaultReport::default());
        assert!(!r.corrupted());
    }

    #[test]
    fn report_counts_match_the_damage() {
        // bit flips only: the word count is preserved, exactly
        // `bit_flips` words differ
        let inj = FaultInjector::new(9, FaultConfig::bit_flips(0.25));
        let mut w = sample_words();
        let r = inj.inject(0, &mut w);
        assert_eq!(w.len(), sample_words().len());
        let differing = w.iter().zip(sample_words()).filter(|(a, b)| **a != *b).count() as u64;
        assert_eq!(differing, r.bit_flips);
        assert!(r.bit_flips > 0);
        assert_eq!(r.truncated_words + r.duplicated_words + r.reordered_swaps, 0);

        // duplication grows the stream by exactly the duplicated count
        let inj = FaultInjector::new(9, FaultConfig { duplicate_rate: 0.25, ..Default::default() });
        let mut w = sample_words();
        let r = inj.inject(0, &mut w);
        assert_eq!(w.len() as u64, sample_words().len() as u64 + r.duplicated_words);

        // truncation shrinks it by exactly the truncated count
        let inj = FaultInjector::new(9, FaultConfig { truncate_rate: 1.0, ..Default::default() });
        let mut w = sample_words();
        let r = inj.inject(0, &mut w);
        assert_eq!(w.len() as u64, sample_words().len() as u64 - r.truncated_words);
        assert!(r.truncated_words > 0, "truncate_rate 1.0 always cuts");
    }

    #[test]
    fn wave_fault_draws_are_deterministic_and_rate_extremes_are_exact() {
        let a = draw_wave_faults(5, 32, 0.4, 3);
        let b = draw_wave_faults(5, 32, 0.4, 3);
        assert_eq!(a, b);
        assert!(a.iter().all(|f| f.retries <= 3));
        assert!(a.iter().any(|f| f.retries > 0), "rate 0.4 over 32 waves lands");

        // per-wave independence: a shorter run is a prefix of a longer one
        let short = draw_wave_faults(5, 8, 0.4, 3);
        assert_eq!(&a[..8], &short[..]);

        // rate 0 → all default; rate 1 → every wave exhausts its budget
        assert!(draw_wave_faults(5, 16, 0.0, 3).iter().all(|f| *f == WaveFault::default()));
        for f in draw_wave_faults(5, 16, 1.0, 3) {
            assert_eq!(f, WaveFault { retries: 3, failed: true });
        }
    }

    #[test]
    fn fuzz_drivers_survive_arbitrary_and_corrupted_bytes() {
        // hand-picked shapes plus injector-corrupted valid streams: the
        // drivers must simply return
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![0xff; 3],
            vec![0x11; 256],
            (0..255u8).collect(),
        ];
        for c in &cases {
            fuzz_decode_stream(c);
            fuzz_decode_segment(c);
            fuzz_decode_panel(c);
        }
        let inj = FaultInjector::new(77, FaultConfig::all(0.2));
        for stream in 0..16u64 {
            let mut words = sample_words();
            inj.inject(stream, &mut words);
            let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
            fuzz_decode_stream(&bytes);
            fuzz_decode_segment(&bytes);
            fuzz_decode_panel(&bytes);
        }
    }
}
