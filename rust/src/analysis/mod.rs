//! Static analysis of the CPU→FPGA contract — `reap lint`.
//!
//! REAP's premise is that the CPU's scheduling pass hands the FPGA
//! *correct-by-construction* work: wave schedules that respect pipeline
//! capacity, RIR streams whose byte accounting matches their flags, and
//! [`WaveCost`](crate::fpga::WaveCost) sequences free of
//! prefetch-past-RAW hazards. A violation of any of those invariants does
//! not crash the simulator — it silently produces wrong cycles or wrong
//! numerics. This module is the borrow-checker for that contract: four
//! pure verification passes that audit an artifact *before* it is
//! simulated (or, for the serving pass, a run log after it drains),
//! sharing one [`Diagnostic`] spine.
//!
//! * [`audit_spgemm_schedule`] / [`audit_batch_schedule`]
//!   ([`schedule`]) — structural invariants of
//!   [`SpgemmSchedule`](crate::rir::schedule::SpgemmSchedule) and
//!   [`BatchSchedule`](crate::rir::schedule::BatchSchedule): exact chunk
//!   coverage of the CSR, wave capacity, B-stream unions, job-tag
//!   partitioning, traffic accounting and the CPU-trace length contract.
//! * [`audit_stream`] ([`stream`]) — walks serialized RIR words with the
//!   same [`crate::rir::layout`] extent/section walkers the decoders use,
//!   cross-checking flag legality, CRC trailers, sectioned-payload byte
//!   accounting and end-of-stream marking **without decoding values**.
//!   Total over arbitrary input (it is a fuzz target).
//! * [`audit_wave_costs`] ([`wave`]) — static hazards in a
//!   [`WaveCost`](crate::fpga::WaveCost) sequence: over-capacity
//!   occupancy, a `dependent_stream` whose producer emitted no writeback,
//!   prefetch-past-RAW exposure at buffer depth ≥ 2, zero-occupancy /
//!   zero-wave anomalies, and the engine's depth ledger law.
//! * [`audit_serving`] ([`serving`]) — the serving runtime's admission
//!   contract over a completed
//!   [`ServingLog`](crate::serving::ServingLog): every admitted job met
//!   its latency budget at admission time, batch/job timelines are
//!   causal and monotone, and arrivals are conserved across
//!   admitted/rejected/queued.
//!
//! Every coordinator runs the schedule and wave-cost audits on its own
//! artifacts under `debug_assertions`; release builds opt in per run via
//! the coordinators' `strict` flag, failing with a typed
//! [`AnalysisError`]. The `reap lint` CLI subcommand runs all passes on
//! any workload/design/encoding combination and renders the diagnostics
//! human-readable or as JSON ([`render_human`] / [`render_json`]).
//! ARCHITECTURE.md §8 catalogues the invariant set pass by pass.

pub mod schedule;
pub mod serving;
pub mod stream;
pub mod wave;

pub use schedule::{audit_batch_schedule, audit_spgemm_schedule};
pub use serving::audit_serving;
pub use stream::audit_stream;
pub use wave::audit_wave_costs;

use std::fmt;

/// Which verification pass produced a diagnostic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pass {
    /// Schedule structure ([`audit_spgemm_schedule`], [`audit_batch_schedule`]).
    Schedule,
    /// Serialized RIR stream words ([`audit_stream`]).
    Stream,
    /// Wave-cost sequences ([`audit_wave_costs`]).
    WaveCost,
    /// Serving-runtime admission logs ([`audit_serving`]).
    Serving,
}

impl Pass {
    /// Stable lowercase name (the JSON `pass` field).
    pub fn name(self) -> &'static str {
        match self {
            Pass::Schedule => "schedule",
            Pass::Stream => "stream",
            Pass::WaveCost => "wave-cost",
            Pass::Serving => "serving",
        }
    }
}

/// Severity of a diagnostic.
///
/// `Error` marks a contract violation that makes simulation or decoding
/// unsound (the coordinators refuse to run on it); `Warning` marks a
/// legal-but-suspect artifact (e.g. a bitmap section that does not pay
/// for itself) that `reap lint` reports but the coordinators tolerate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    /// Stable lowercase name (the JSON `severity` field).
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding of a verification pass.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    pub pass: Pass,
    pub severity: Severity,
    /// Where in the artifact ("wave 3, slot 2", "bundle 7", "item 12").
    pub location: String,
    /// Human-readable statement of the violated invariant.
    pub message: String,
    /// Stable machine-readable code (one of [`codes`]), the key mutation
    /// tests and CI assert on.
    pub code: &'static str,
}

impl Diagnostic {
    /// An error-severity diagnostic.
    pub fn error(pass: Pass, code: &'static str, location: String, message: String) -> Self {
        Diagnostic { pass, severity: Severity::Error, location, message, code }
    }

    /// A warning-severity diagnostic.
    pub fn warning(pass: Pass, code: &'static str, location: String, message: String) -> Self {
        Diagnostic { pass, severity: Severity::Warning, location, message, code }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{}] {}: {}: {}",
            self.severity.name(),
            self.code,
            self.pass.name(),
            self.location,
            self.message
        )
    }
}

/// Stable diagnostic codes, one constant per invariant. Codes are part of
/// the tool's interface: CI greps them, the mutation tests
/// (`tests/analysis_mutations.rs`) pin them, and ARCHITECTURE.md §8
/// documents them — never reuse or renumber.
pub mod codes {
    /// Schedule geometry is unusable (`pipelines == 0` or `bundle_size == 0`).
    pub const SCH_CONFIG: &str = "SCH-CONFIG";
    /// A wave carries more assignments than the design has pipelines.
    pub const SCH_WAVE_OVERFULL: &str = "SCH-WAVE-OVERFULL";
    /// A wave carries no assignments at all (the scheduler never emits one).
    pub const SCH_WAVE_EMPTY: &str = "SCH-WAVE-EMPTY";
    /// A chunk length outside `1..=bundle_size`.
    pub const SCH_CHUNK_LEN: &str = "SCH-CHUNK-LEN";
    /// The same `(row, chunk)` assigned more than once.
    pub const SCH_CHUNK_DUP: &str = "SCH-CHUNK-DUP";
    /// A chunk whose row/ordinal/extent does not exist in the source CSR.
    pub const SCH_CHUNK_RANGE: &str = "SCH-CHUNK-RANGE";
    /// A `last_chunk` flag on the wrong chunk ordinal.
    pub const SCH_LAST_CHUNK: &str = "SCH-LAST-CHUNK";
    /// A `(row, chunk)` of the source CSR that no wave covers.
    pub const SCH_COVERAGE: &str = "SCH-COVERAGE";
    /// A wave's B-row stream is not the sorted, deduped union of its
    /// assignments' A columns (or indexes past B).
    pub const SCH_B_ROWS: &str = "SCH-B-ROWS";
    /// `a_words`/`b_words` disagree with the recomputed traffic.
    pub const SCH_WORDS: &str = "SCH-WORDS";
    /// The per-wave CPU trace breaks the `overlap` length/value contract.
    pub const SCH_TRACE: &str = "SCH-TRACE";
    /// A batch assignment tagged with a job id outside `0..n_jobs`.
    pub const SCH_JOB_TAG: &str = "SCH-JOB-TAG";
    /// A job's chunks, extracted in wave order, are not its single-job
    /// chunk sequence (the `decompose()` invariant).
    pub const SCH_JOB_ORDER: &str = "SCH-JOB-ORDER";
    /// Batch wave segments do not mirror the wave's job runs.
    pub const SCH_SEGMENT: &str = "SCH-SEGMENT";

    /// The stream ends mid-header or mid-payload.
    pub const STR_TRUNCATED: &str = "STR-TRUNCATED";
    /// A checksummed bundle whose CRC32 trailer does not verify.
    pub const STR_CRC: &str = "STR-CRC";
    /// An illegal flag combination (compression or panel flags on a
    /// metadata-only bundle, a compression flag on an empty bundle).
    pub const STR_FLAGS: &str = "STR-FLAGS";
    /// A bitmap section whose set bits disagree with the declared element
    /// count, or that reconstructs an index past `u32`.
    pub const STR_BITMAP: &str = "STR-BITMAP";
    /// A sectioned payload whose index-section size disagrees with the
    /// canonical accounting for its decoded indices.
    pub const STR_SECTION_WORDS: &str = "STR-SECTION-WORDS";
    /// A bitmap section at least as large as the raw indices it replaces —
    /// legal to decode, but the encoder's negotiation would never emit it.
    pub const STR_BITMAP_WASTE: &str = "STR-BITMAP-WASTE";
    /// A fixed-point scale word that is not a finite f32.
    pub const STR_FX_SCALE: &str = "STR-FX-SCALE";
    /// Distinct indices within a data bundle not strictly ascending.
    pub const STR_INDEX_ORDER: &str = "STR-INDEX-ORDER";
    /// End-of-stream marking is inconsistent (a segment boundary exists
    /// but the final bundle does not terminate the stream, or no bundle
    /// carries the flag at all).
    pub const STR_EOS: &str = "STR-EOS";

    /// The [`FpgaConfig`](crate::fpga::FpgaConfig) handed to the wave
    /// audit fails its own validation — no cost sequence is meaningful
    /// against it.
    pub const WAV_CONFIG: &str = "WAV-CONFIG";
    /// A wave occupying more pipelines than the design has (the engine
    /// would abort on it).
    pub const WAV_OVERFULL: &str = "WAV-OVERFULL";
    /// A `dependent_stream` item whose immediate producer emitted no
    /// writeback — there is nothing in DRAM for the RAW edge to read.
    pub const WAV_DEP_NO_PRODUCER: &str = "WAV-DEP-NO-PRODUCER";
    /// At buffer depth ≥ 2, an independent stream directly following a
    /// dependent producer's writeback — its prefetch can race the RAW
    /// data it may be reading.
    pub const WAV_PREFETCH_RAW: &str = "WAV-PREFETCH-RAW";
    /// A pure `Load` item carrying compute, occupancy, flops or waves.
    pub const WAV_LOAD: &str = "WAV-LOAD";
    /// A compute item with compute cycles but zero active pipelines.
    pub const WAV_ZERO_OCC: &str = "WAV-ZERO-OCC";
    /// A compute item contributing zero scheduling waves.
    pub const WAV_ZERO_WAVES: &str = "WAV-ZERO-WAVES";
    /// A word count too large for the engine's byte accounting.
    pub const WAV_WORDS_OVERFLOW: &str = "WAV-WORDS-OVERFLOW";
    /// The engine's depth ledger (`cycles(d) + hidden(d) == cycles(1)`,
    /// depth-invariant traffic/flops/waves) fails on this sequence.
    pub const WAV_LEDGER: &str = "WAV-LEDGER";

    /// An admitted job whose age at its window close already exceeded the
    /// latency budget — the controller must have shed it.
    pub const SRV_BUDGET: &str = "SRV-BUDGET";
    /// A batch or job timeline that is not causal (batch starts before its
    /// window closes, a job completes before its batch starts, or window
    /// closes go backwards).
    pub const SRV_TIMELINE: &str = "SRV-TIMELINE";
    /// Arrival conservation broken: `admitted + rejected + queued` does
    /// not account for every arrival, or the batches do not carry exactly
    /// the admitted jobs.
    pub const SRV_CONSERVE: &str = "SRV-CONSERVE";
    /// A batch record with no jobs — legal but the simulator never closes
    /// an empty wave into a batch.
    pub const SRV_EMPTY: &str = "SRV-EMPTY";
}

/// Typed failure carrying every diagnostic of a failed audit — the error
/// the coordinators return in `strict` mode (and debug builds).
#[derive(Clone, Debug)]
pub struct AnalysisError {
    pub diagnostics: Vec<Diagnostic>,
}

impl fmt::Display for AnalysisError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let errors = count_severity(&self.diagnostics, Severity::Error);
        writeln!(f, "static analysis failed with {errors} error(s):")?;
        for d in &self.diagnostics {
            writeln!(f, "  {d}")?;
        }
        Ok(())
    }
}

impl std::error::Error for AnalysisError {}

/// Number of diagnostics at `severity`.
pub fn count_severity(diags: &[Diagnostic], severity: Severity) -> usize {
    diags.iter().filter(|d| d.severity == severity).count()
}

/// Fail with a typed [`AnalysisError`] if any **error**-severity
/// diagnostic is present (warnings alone pass — the coordinators tolerate
/// suspect-but-legal artifacts; `reap lint` still reports them).
pub fn ensure_clean(diags: Vec<Diagnostic>) -> Result<(), AnalysisError> {
    if count_severity(&diags, Severity::Error) > 0 {
        Err(AnalysisError { diagnostics: diags })
    } else {
        Ok(())
    }
}

/// Render diagnostics for a terminal, one line each, plus a summary line.
pub fn render_human(diags: &[Diagnostic]) -> String {
    let mut out = String::new();
    for d in diags {
        out.push_str(&d.to_string());
        out.push('\n');
    }
    let errors = count_severity(diags, Severity::Error);
    let warnings = count_severity(diags, Severity::Warning);
    out.push_str(&format!("{errors} error(s), {warnings} warning(s)\n"));
    out
}

/// Render diagnostics as one machine-readable JSON object:
/// `{"diagnostics": [...], "errors": N, "warnings": N}`.
pub fn render_json(diags: &[Diagnostic]) -> String {
    let mut out = String::from("{\"diagnostics\": [");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!(
            "{{\"pass\": \"{}\", \"severity\": \"{}\", \"code\": \"{}\", \
             \"location\": \"{}\", \"message\": \"{}\"}}",
            d.pass.name(),
            d.severity.name(),
            json_escape(d.code),
            json_escape(&d.location),
            json_escape(&d.message)
        ));
    }
    out.push_str(&format!(
        "], \"errors\": {}, \"warnings\": {}}}",
        count_severity(diags, Severity::Error),
        count_severity(diags, Severity::Warning)
    ));
    out
}

/// Minimal JSON string escaping (quotes, backslashes, control bytes) —
/// local so the analysis layer stays independent of the bench harness.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Diagnostic> {
        vec![
            Diagnostic::error(
                Pass::Schedule,
                codes::SCH_CHUNK_DUP,
                "wave 3, slot 2".into(),
                "chunk (7, 0) already assigned".into(),
            ),
            Diagnostic::warning(
                Pass::Stream,
                codes::STR_BITMAP_WASTE,
                "bundle 5".into(),
                "bitmap section (9 words) not below 4 raw index words".into(),
            ),
        ]
    }

    #[test]
    fn severity_counting_and_gate() {
        let diags = sample();
        assert_eq!(count_severity(&diags, Severity::Error), 1);
        assert_eq!(count_severity(&diags, Severity::Warning), 1);
        let err = ensure_clean(diags).unwrap_err();
        assert_eq!(err.diagnostics.len(), 2);
        let msg = err.to_string();
        assert!(msg.contains("SCH-CHUNK-DUP"), "{msg}");
        // warnings alone pass the gate
        let warn_only = vec![sample().pop().unwrap()];
        assert!(ensure_clean(warn_only).is_ok());
        assert!(ensure_clean(Vec::new()).is_ok());
    }

    #[test]
    fn human_rendering_is_one_line_per_diagnostic() {
        let text = render_human(&sample());
        assert!(text.contains("error[SCH-CHUNK-DUP]"), "{text}");
        assert!(text.contains("warning[STR-BITMAP-WASTE]"), "{text}");
        assert!(text.contains("schedule: wave 3, slot 2"), "{text}");
        assert!(text.ends_with("1 error(s), 1 warning(s)\n"), "{text}");
    }

    #[test]
    fn json_rendering_parses_back() {
        use crate::util::json::Json;
        let text = render_json(&sample());
        let j = Json::parse(&text).expect("diagnostics JSON parses");
        assert_eq!(j.get("errors").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("warnings").and_then(|v| v.as_usize()), Some(1));
        let arr = j.get("diagnostics").and_then(|v| v.as_arr()).expect("array");
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("code").and_then(|v| v.as_str()), Some("SCH-CHUNK-DUP"));
        assert_eq!(arr[1].get("severity").and_then(|v| v.as_str()), Some("warning"));
    }

    #[test]
    fn empty_report_is_clean() {
        assert_eq!(render_human(&[]), "0 error(s), 0 warning(s)\n");
        let j = crate::util::json::Json::parse(&render_json(&[])).unwrap();
        assert_eq!(j.get("errors").and_then(|v| v.as_usize()), Some(0));
    }
}
