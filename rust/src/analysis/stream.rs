//! StreamAudit — wire-level verification of serialized RIR words.
//!
//! Walks a stream with the same [`crate::rir::layout`] extent and section
//! walkers the decoders use, but never decodes a value: it checks flag
//! legality, CRC trailers, sectioned-payload word accounting against the
//! canonical `encoded_*_words` formulas, in-bundle index order and
//! end-of-stream marking. Mid-stream `END_OF_STREAM` flags are **legal**
//! — the job encoder terminates every job segment with one — and a stream
//! with no terminator at all is only a warning (wave-level row streams
//! concatenate and deliberately carry none).
//!
//! Total over arbitrary input — this is the `lint_stream` fuzz target's
//! entry point, so every path must return diagnostics, never panic.

use crate::rir::layout::{
    bitmap_index_words, bundle_extent, expand_sectioned_payload, fx_value_words, verify_bundle_crc,
    BundleExtent,
};

use super::{codes, Diagnostic, Pass};

fn err(code: &'static str, location: String, message: String) -> Diagnostic {
    Diagnostic::error(Pass::Stream, code, location, message)
}

fn warn(code: &'static str, location: String, message: String) -> Diagnostic {
    Diagnostic::warning(Pass::Stream, code, location, message)
}

/// Audit a serialized RIR stream (any encoder's output, or arbitrary
/// words). Returns every violation found; an empty stream is clean.
pub fn audit_stream(words: &[u32]) -> Vec<Diagnostic> {
    let mut d = Vec::new();
    let mut p = 0usize;
    let mut bundle = 0usize;
    let mut segment_terminators = 0usize;
    let mut last_flags = None;
    while p < words.len() {
        let ext = match bundle_extent(words, p, bundle) {
            Ok(e) => e,
            Err(e) => {
                // sizing failed — there is no way to resynchronize, so
                // report the cut and stop
                let loc = format!("bundle {bundle} (word {p})");
                d.push(err(codes::STR_TRUNCATED, loc, e.to_string()));
                return d;
            }
        };
        let loc = format!("bundle {bundle}");
        if let Err(e) = verify_bundle_crc(words, p, &ext, bundle) {
            d.push(err(codes::STR_CRC, loc.clone(), e.to_string()));
        }
        check_flags(&mut d, &ext, &loc);
        if !ext.flags.metadata_only() {
            check_data_payload(&mut d, &words[p + 2..p + 2 + ext.payload_words], &ext, bundle);
        }
        p += ext.total_words;
        bundle += 1;
        if ext.flags.end_of_stream() && p < words.len() {
            segment_terminators += 1; // legal: a job-segment boundary
        }
        last_flags = Some(ext.flags);
    }
    if let Some(f) = last_flags {
        if !f.end_of_stream() {
            if segment_terminators > 0 {
                d.push(err(
                    codes::STR_EOS,
                    format!("bundle {}", bundle - 1),
                    format!(
                        "stream carries {segment_terminators} segment terminator(s) but its \
                         final bundle is not END_OF_STREAM"
                    ),
                ));
            } else {
                d.push(warn(
                    codes::STR_EOS,
                    format!("bundle {}", bundle - 1),
                    "no bundle carries END_OF_STREAM (legal only for wave-level row streams)"
                        .into(),
                ));
            }
        }
    }
    d
}

/// Flag-combination legality: schedule (metadata-only) bundles carry raw
/// triples — compression or panel flags on them are corruption — and the
/// compression flags are meaningless on an empty bundle (the encoder's
/// negotiation never sets them there).
fn check_flags(d: &mut Vec<Diagnostic>, ext: &BundleExtent, loc: &str) {
    let f = ext.flags;
    if f.metadata_only() && (f.bitmap() || f.fixed_point() || f.dense_panel()) {
        d.push(err(
            codes::STR_FLAGS,
            loc.into(),
            format!("metadata-only bundle carries data-bundle flags ({:#04x})", f.0),
        ));
    }
    if !f.metadata_only() && ext.count == 0 && f.sectioned() {
        d.push(err(
            codes::STR_FLAGS,
            loc.into(),
            format!("compression flags ({:#04x}) on an empty bundle", f.0),
        ));
    }
}

/// Data-bundle payload checks: sectioned bundles must expand cleanly, the
/// bitmap index section must match the canonical word accounting for the
/// indices it encodes (and actually pay for itself), the fixed-point
/// scale must be finite, and distinct indices should be ascending.
fn check_data_payload(
    d: &mut Vec<Diagnostic>,
    payload: &[u32],
    ext: &BundleExtent,
    bundle: usize,
) {
    let f = ext.flags;
    let count = ext.count;
    let loc = format!("bundle {bundle}");
    let cols: Vec<u32> = if f.sectioned() {
        if count == 0 {
            return; // already reported by check_flags
        }
        let pairs = match expand_sectioned_payload(payload, count, f, bundle) {
            Ok(pairs) => pairs,
            Err(e) => {
                d.push(err(codes::STR_BITMAP, loc, e.to_string()));
                return;
            }
        };
        let cols: Vec<u32> = pairs.iter().step_by(2).copied().collect();
        let val_words = if f.fixed_point() { fx_value_words(count) } else { count };
        if f.bitmap() {
            let idx_words = ext.payload_words - val_words;
            // the decoded indices are ascending and non-empty, so the
            // canonical accounting always exists for them
            match bitmap_index_words(&cols) {
                Some(canon) if canon == idx_words => {}
                canon => d.push(err(
                    codes::STR_SECTION_WORDS,
                    loc.clone(),
                    format!(
                        "bitmap index section is {idx_words} word(s) but the canonical \
                         accounting for its {count} indices is {canon:?}"
                    ),
                )),
            }
            if idx_words >= count {
                d.push(warn(
                    codes::STR_BITMAP_WASTE,
                    loc.clone(),
                    format!(
                        "bitmap index section ({idx_words} word(s)) does not beat the \
                         {count} raw index words it replaces — the encoder's negotiation \
                         never picks it"
                    ),
                ));
            }
        }
        if f.fixed_point() {
            let scale = f32::from_bits(payload[ext.payload_words - val_words]);
            if !scale.is_finite() {
                d.push(err(
                    codes::STR_FX_SCALE,
                    loc.clone(),
                    format!("fixed-point scale word decodes to {scale}"),
                ));
            }
        }
        cols
    } else {
        payload.iter().step_by(2).copied().collect()
    };
    if cols.windows(2).any(|w| w[0] >= w[1]) {
        d.push(warn(
            codes::STR_INDEX_ORDER,
            format!("bundle {bundle}"),
            "distinct indices are not strictly ascending within the bundle".into(),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::layout::{
        serialize_stream, serialize_stream_checksummed, serialize_stream_encoded, StreamEncoding,
    };
    use crate::rir::{BundleFlags, BundleStream};
    use crate::sparse::gen;

    fn stream(seed: u64) -> BundleStream {
        let a = gen::random_uniform(60, 60, 900, seed);
        BundleStream::from_csr(&a, 32)
    }

    #[test]
    fn clean_on_every_encoder_output() {
        let s = stream(1);
        for enc in [
            StreamEncoding::Raw,
            StreamEncoding::Bitmap,
            StreamEncoding::Fx,
            StreamEncoding::BitmapFx,
        ] {
            for checksummed in [false, true] {
                let words = serialize_stream_encoded(&s, enc, checksummed);
                let diags = audit_stream(&words);
                assert!(diags.is_empty(), "{enc} checksummed={checksummed}: {diags:?}");
            }
        }
        assert!(audit_stream(&serialize_stream(&s)).is_empty());
        assert!(audit_stream(&serialize_stream_checksummed(&s)).is_empty());
        assert!(audit_stream(&[]).is_empty(), "empty stream is clean");
    }

    #[test]
    fn clean_on_banded_bitmap_wins() {
        // banded rows are where the bitmap section actually engages
        let a = gen::banded_fem(80, 1200, 3);
        let s = BundleStream::from_csr(&a, 32);
        let words = serialize_stream_encoded(&s, StreamEncoding::BitmapFx, true);
        let diags = audit_stream(&words);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn clean_on_job_segmented_streams_with_mid_stream_eos() {
        let a = gen::random_uniform(20, 20, 150, 5);
        let b = gen::random_uniform(25, 25, 200, 6);
        let mut s = BundleStream::new();
        s.encode_csr_jobs(&[&a, &b], 16);
        let words = serialize_stream(&s);
        let diags = audit_stream(&words);
        assert!(diags.is_empty(), "job segment terminators are legal: {diags:?}");
    }

    #[test]
    fn wave_row_streams_warn_about_missing_terminator_only() {
        let a = gen::random_uniform(30, 30, 250, 7);
        let mut s = BundleStream::new();
        s.encode_csr_rows(&a, &[0, 3, 7], 16);
        let diags = audit_stream(&serialize_stream(&s));
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, codes::STR_EOS);
        assert_eq!(diags[0].severity, crate::analysis::Severity::Warning);
    }

    #[test]
    fn truncation_is_reported_and_stops_the_walk() {
        let words = serialize_stream(&stream(2));
        let cut = &words[..words.len() - 1];
        let diags = audit_stream(cut);
        assert!(diags.iter().any(|d| d.code == codes::STR_TRUNCATED), "{diags:?}");
    }

    #[test]
    fn crc_flip_is_reported() {
        let mut words = serialize_stream_checksummed(&stream(3));
        words[2] ^= 1; // first payload word of bundle 0
        let diags = audit_stream(&words);
        assert!(diags.iter().any(|d| d.code == codes::STR_CRC), "{diags:?}");
    }

    #[test]
    fn metadata_only_with_compression_flags_is_reported() {
        // hand-built: count = 1, METADATA_ONLY|BITMAP|END_OF_STREAM, one
        // raw triple as payload
        let flags = BundleFlags::METADATA_ONLY | BundleFlags::BITMAP | BundleFlags::END_OF_STREAM;
        let words = [(1u32 << 8) | flags as u32, 0, 7, 10, 20];
        let diags = audit_stream(&words);
        assert!(diags.iter().any(|d| d.code == codes::STR_FLAGS), "{diags:?}");
    }

    #[test]
    fn arbitrary_words_never_panic() {
        // a few shapes that historically trip walkers; the fuzz target
        // explores much further
        let cases: Vec<Vec<u32>> = vec![
            vec![u32::MAX],
            vec![u32::MAX; 8],
            vec![(3 << 8) | 0x20, 0, 0, u32::MAX, 1, 2, 3],
            vec![(2 << 8) | 0x60, 9, 5, 1, 0x8000_0001, 0xffff_ffff],
            vec![0; 16],
        ];
        for words in cases {
            let _ = audit_stream(&words);
        }
    }
}
