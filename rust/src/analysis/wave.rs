//! WaveCostAudit — static hazards in a [`WaveCost`] sequence.
//!
//! The engine ([`crate::fpga::engine`]) prices whatever sequence it is
//! handed; a malformed one either aborts it (over-capacity occupancy,
//! word counts past the byte-accounting range) or silently prices
//! nonsense (a RAW edge with no producer writeback, a `Load` smuggling
//! compute). This pass rejects those shapes *before* execution, then —
//! only on an error-free sequence — cross-checks the engine's own depth
//! ledger (`cycles(d) + prefetch_hidden_cycles(d) == cycles(1)`, with
//! depth-invariant traffic/flops/waves) by executing the sequence at
//! depths 1 and 2. The ledger run never fires on shipped simulators; it
//! exists so a future engine regression surfaces as a typed
//! [`Diagnostic`] instead of a skewed benchmark.

use crate::fpga::engine::{execute_waves_at_depth, Occupancy, WaveKind};
use crate::fpga::{FpgaConfig, WaveCost};
use crate::rir::layout::WORD_BYTES;

use super::{codes, count_severity, Diagnostic, Pass, Severity};

fn err(code: &'static str, location: String, message: String) -> Diagnostic {
    Diagnostic::error(Pass::WaveCost, code, location, message)
}

fn warn(code: &'static str, location: String, message: String) -> Diagnostic {
    Diagnostic::warning(Pass::WaveCost, code, location, message)
}

/// Largest per-item word count the engine can widen to bytes without
/// leaving `u64`.
const WORD_LIMIT: u64 = u64::MAX / WORD_BYTES as u64;

/// Audit a wave-cost sequence against `cfg`. Returns every violation
/// found; an empty sequence is clean.
pub fn audit_wave_costs(costs: &[WaveCost], cfg: &FpgaConfig) -> Vec<Diagnostic> {
    let mut d = Vec::new();
    if let Err(e) = cfg.validate() {
        d.push(err(codes::WAV_CONFIG, "config".into(), e.to_string()));
        return d;
    }
    let p = cfg.pipelines as u64;
    for (k, c) in costs.iter().enumerate() {
        let loc = format!("item {k}");
        if let Occupancy::ActivePipelines(active) = c.occupancy {
            if active > p {
                d.push(err(
                    codes::WAV_OVERFULL,
                    loc.clone(),
                    format!("{active} active pipelines on a {p}-pipeline design"),
                ));
            }
        }
        if c.stream_words > WORD_LIMIT || c.writeback_words > WORD_LIMIT {
            d.push(err(
                codes::WAV_WORDS_OVERFLOW,
                loc.clone(),
                format!(
                    "stream ({}) or writeback ({}) word count exceeds the engine's \
                     byte-accounting range",
                    c.stream_words, c.writeback_words
                ),
            ));
        }
        if c.setup_cycles.checked_add(c.compute_cycles).is_none() {
            d.push(err(
                codes::WAV_WORDS_OVERFLOW,
                loc.clone(),
                format!(
                    "setup ({}) + compute ({}) cycles overflow the serial-cost sum",
                    c.setup_cycles, c.compute_cycles
                ),
            ));
        }
        match c.kind {
            WaveKind::Load => {
                let busy = match c.occupancy {
                    Occupancy::ActivePipelines(n) => n,
                    Occupancy::Fixed { busy, .. } => busy,
                };
                if c.compute_cycles > 0 || c.flops > 0 || c.waves > 0 || busy > 0 {
                    d.push(err(
                        codes::WAV_LOAD,
                        loc.clone(),
                        format!(
                            "pure Load carries compute ({} cycles, {} flops, {} waves, \
                             {busy} busy pipelines)",
                            c.compute_cycles, c.flops, c.waves
                        ),
                    ));
                }
            }
            WaveKind::Compute => {
                if c.waves == 0 {
                    d.push(err(
                        codes::WAV_ZERO_WAVES,
                        loc.clone(),
                        "compute item contributes zero scheduling waves".into(),
                    ));
                }
                if c.compute_cycles > 0 && c.occupancy == Occupancy::ActivePipelines(0) {
                    d.push(err(
                        codes::WAV_ZERO_OCC,
                        loc.clone(),
                        format!(
                            "{} compute cycles charged with zero active pipelines",
                            c.compute_cycles
                        ),
                    ));
                }
            }
        }
        if c.dependent_stream && k > 0 && costs[k - 1].writeback_words == 0 {
            d.push(err(
                codes::WAV_DEP_NO_PRODUCER,
                loc.clone(),
                format!("dependent stream but item {} wrote nothing back to DRAM", k - 1),
            ));
        }
        if cfg.dram_buffer_depth >= 2
            && k > 0
            && !c.dependent_stream
            && c.stream_words > 0
            && costs[k - 1].dependent_stream
            && costs[k - 1].writeback_words > 0
        {
            d.push(warn(
                codes::WAV_PREFETCH_RAW,
                loc,
                format!(
                    "independent stream directly after dependent producer item {}: a depth-{} \
                     channel prefetches it past the producer's writeback",
                    k - 1,
                    cfg.dram_buffer_depth
                ),
            ));
        }
    }
    if count_severity(&d, Severity::Error) == 0 {
        check_depth_ledger(costs, cfg, &mut d);
    }
    d
}

/// Re-execute the sequence at depths 1 and 2 and verify the engine's
/// ledger law. Only called on an error-free sequence (the per-item checks
/// above rule out every input the engine aborts on); aggregate-overflow
/// shapes are rejected here first so the re-execution itself stays total.
fn check_depth_ledger(costs: &[WaveCost], cfg: &FpgaConfig, d: &mut Vec<Diagnostic>) {
    // aggregate guards: every counter the engine accumulates must fit u64
    let totals = costs.iter().try_fold((0u64, 0u64, 0u64, 0u64, 0u64), |acc, c| {
        let serial = acc.0.checked_add(c.serial_cycles(cfg))?;
        let read = acc.1.checked_add(c.stream_words.checked_mul(WORD_BYTES as u64)?)?;
        let written = acc.2.checked_add(c.writeback_words.checked_mul(WORD_BYTES as u64)?)?;
        let flops = acc.3.checked_add(c.flops)?;
        let waves = acc.4.checked_add(c.waves)?;
        Some((serial, read, written, flops, waves))
    });
    let pipeline_cycles = totals.and_then(|t| (cfg.pipelines as u64).checked_mul(t.0));
    if pipeline_cycles.is_none() {
        d.push(err(
            codes::WAV_WORDS_OVERFLOW,
            "sequence".into(),
            "aggregate cycle/traffic counters overflow u64 — the ledger cannot be checked".into(),
        ));
        return;
    }
    let d1 = execute_waves_at_depth(costs, cfg, 1);
    let d2 = execute_waves_at_depth(costs, cfg, 2);
    if d2.stats.cycles + d2.stats.prefetch_hidden_cycles != d1.stats.cycles {
        d.push(err(
            codes::WAV_LEDGER,
            "sequence".into(),
            format!(
                "cycles(2) {} + hidden(2) {} != cycles(1) {}",
                d2.stats.cycles, d2.stats.prefetch_hidden_cycles, d1.stats.cycles
            ),
        ));
    }
    if (d2.stats.bytes_read, d2.stats.bytes_written, d2.stats.flops, d2.stats.waves)
        != (d1.stats.bytes_read, d1.stats.bytes_written, d1.stats.flops, d1.stats.waves)
    {
        d.push(err(
            codes::WAV_LEDGER,
            "sequence".into(),
            "DRAM traffic, flops or waves vary with channel depth".into(),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::cholesky_sim::simulate_cholesky;
    use crate::fpga::spgemm_sim::{simulate_spgemm, simulate_spgemm_batch, Style};
    use crate::fpga::spmm_sim::simulate_spmm;
    use crate::fpga::spmv_sim::simulate_spmv;
    use crate::rir::schedule::{schedule_spgemm, schedule_spgemm_batch};
    use crate::sparse::gen;
    use crate::symbolic::CholeskySymbolic;

    fn wave(compute: u64, active: u64) -> WaveCost {
        WaveCost {
            kind: WaveKind::Compute,
            stream_words: 64,
            setup_cycles: 2,
            compute_cycles: compute,
            writeback_words: 8,
            dependent_stream: false,
            occupancy: Occupancy::ActivePipelines(active),
            flops: 10,
            waves: 1,
        }
    }

    #[test]
    fn clean_on_every_simulator_cost_sequence() {
        let a = gen::random_uniform(120, 120, 1600, 3);
        let b = gen::random_uniform(120, 120, 1600, 4);
        for cfg in [FpgaConfig::reap32_spgemm(), FpgaConfig::reap64_spgemm()] {
            let s = schedule_spgemm(&a, &b, cfg.pipelines, cfg.bundle_size);
            let gemm = simulate_spgemm(&a, &b, &s, &cfg, Style::HandCoded);
            assert!(audit_wave_costs(&gemm.costs, &cfg).is_empty(), "{}: spgemm", cfg.name);
            let spmv = simulate_spmv(&a, &s, &cfg, Style::HandCoded);
            assert!(audit_wave_costs(&spmv.costs, &cfg).is_empty(), "{}: spmv", cfg.name);
            let spmm = simulate_spmm(&a, &s, &cfg, Style::HandCoded, 8);
            assert!(audit_wave_costs(&spmm.costs, &cfg).is_empty(), "{}: spmm", cfg.name);
        }
        let jobs = vec![
            (gen::random_uniform(40, 40, 300, 5), gen::random_uniform(40, 40, 300, 6)),
            (gen::random_uniform(70, 70, 800, 7), gen::random_uniform(70, 70, 800, 8)),
        ];
        let cfg = FpgaConfig::reap64_spgemm();
        let bs = schedule_spgemm_batch(&jobs, cfg.pipelines, cfg.bundle_size);
        let batch = simulate_spgemm_batch(&jobs, &bs, &cfg, Style::HandCoded);
        assert!(audit_wave_costs(&batch.costs, &cfg).is_empty(), "batch");
    }

    #[test]
    fn clean_on_cholesky_including_column_zero_dependence() {
        // every Cholesky column carries dependent_stream — the audit must
        // not demand a producer for column 0, and columns > 0 always have
        // one (nk >= 1 puts at least two writeback words on each column)
        let spd = gen::spd(gen::Family::BandedFem, 80, 700, 5);
        let sym = CholeskySymbolic::analyze(&spd.lower_triangle(), 32);
        for cfg in [FpgaConfig::reap32_cholesky(), FpgaConfig::reap64_cholesky()] {
            for style in [Style::HandCoded, Style::HlsPreprocessed, Style::HlsRaw] {
                let r = simulate_cholesky(&sym, &cfg, style);
                assert!(r.costs[0].dependent_stream, "premise: columns are dependent");
                let diags = audit_wave_costs(&r.costs, &cfg);
                assert!(diags.is_empty(), "{}: {diags:?}", cfg.name);
            }
        }
    }

    #[test]
    fn empty_sequence_is_clean() {
        assert!(audit_wave_costs(&[], &FpgaConfig::reap32_spgemm()).is_empty());
    }

    #[test]
    fn invalid_config_is_the_only_diagnostic() {
        let cfg = FpgaConfig { pipelines: 0, ..FpgaConfig::reap32_spgemm() };
        let diags = audit_wave_costs(&[wave(10, 4)], &cfg);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, codes::WAV_CONFIG);
    }

    #[test]
    fn overfull_wave_is_rejected_before_the_engine_would_abort() {
        let cfg = FpgaConfig::reap32_spgemm();
        let diags = audit_wave_costs(&[wave(10, cfg.pipelines as u64 + 1)], &cfg);
        assert!(diags.iter().any(|d| d.code == codes::WAV_OVERFULL), "{diags:?}");
    }

    #[test]
    fn word_count_overflow_is_rejected_before_the_engine_would_abort() {
        let cfg = FpgaConfig::reap32_spgemm();
        let mut c = wave(10, 4);
        c.stream_words = u64::MAX / 2;
        let diags = audit_wave_costs(&[c], &cfg);
        assert!(diags.iter().any(|d| d.code == codes::WAV_WORDS_OVERFLOW), "{diags:?}");
    }

    #[test]
    fn dependent_stream_needs_a_producer_writeback() {
        let cfg = FpgaConfig::reap32_spgemm();
        let mut dep = wave(10, 4);
        dep.dependent_stream = true;
        // item 0 may be dependent (Cholesky column 0) — clean
        assert!(audit_wave_costs(&[dep, wave(10, 4)], &cfg).is_empty());
        // a producer that wrote nothing back breaks the RAW edge
        let diags = audit_wave_costs(&[WaveCost::load(100), dep], &cfg);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, codes::WAV_DEP_NO_PRODUCER);
    }

    #[test]
    fn prefetch_past_raw_warns_only_at_depth_two() {
        let mut dep = wave(10, 4);
        dep.dependent_stream = true;
        let costs = [dep, wave(10, 4)];
        let serial = FpgaConfig { dram_buffer_depth: 1, ..FpgaConfig::reap32_spgemm() };
        assert!(audit_wave_costs(&costs, &serial).is_empty());
        let buffered = FpgaConfig { dram_buffer_depth: 2, ..FpgaConfig::reap32_spgemm() };
        let diags = audit_wave_costs(&costs, &buffered);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, codes::WAV_PREFETCH_RAW);
        assert_eq!(diags[0].severity, Severity::Warning);
    }

    #[test]
    fn load_smuggling_compute_is_rejected() {
        let cfg = FpgaConfig::reap32_spgemm();
        let mut load = WaveCost::load(500);
        load.flops = 1;
        let diags = audit_wave_costs(&[load], &cfg);
        assert!(diags.iter().any(|d| d.code == codes::WAV_LOAD), "{diags:?}");
    }

    #[test]
    fn zero_wave_and_zero_occupancy_anomalies_are_rejected() {
        let cfg = FpgaConfig::reap32_spgemm();
        let mut no_waves = wave(10, 4);
        no_waves.waves = 0;
        let diags = audit_wave_costs(&[no_waves], &cfg);
        assert!(diags.iter().any(|d| d.code == codes::WAV_ZERO_WAVES), "{diags:?}");
        let ghost = wave(10, 0); // computes on zero pipelines
        let diags = audit_wave_costs(&[ghost], &cfg);
        assert!(diags.iter().any(|d| d.code == codes::WAV_ZERO_OCC), "{diags:?}");
        // an idle compute wave (engine's 1-cycle retire) is legal
        let mut idle = wave(0, 0);
        idle.stream_words = 0;
        idle.writeback_words = 0;
        assert!(audit_wave_costs(&[idle], &cfg).is_empty());
    }
}
