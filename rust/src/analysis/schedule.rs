//! ScheduleAudit — structural invariants of
//! [`SpgemmSchedule`](crate::rir::schedule::SpgemmSchedule) and
//! [`BatchSchedule`](crate::rir::schedule::BatchSchedule).
//!
//! The audit recomputes what the scheduler promises from the source
//! matrices alone and diffs the schedule against it: every `(row, chunk)`
//! of the CSR assigned exactly once with its canonical extent, at most
//! `pipelines` assignments per wave, every wave's `b_rows` the sorted
//! deduped union of its A columns, the A/B word accounting, the per-wave
//! CPU trace contract from `overlap`, and — for batches — job-tag
//! partitioning, run/segment mirroring and the `decompose()` order
//! invariant. Pure: no simulation, no mutation, total over corrupt input
//! (a malformed extent is reported, never sliced).

use std::collections::HashSet;

use crate::rir::schedule::{row_stream_words, Assignment, BatchSchedule, SpgemmSchedule};
use crate::sparse::{Csr, Idx};

use super::{codes, Diagnostic, Pass};

fn err(code: &'static str, location: String, message: String) -> Diagnostic {
    Diagnostic::error(Pass::Schedule, code, location, message)
}

fn warn(code: &'static str, location: String, message: String) -> Diagnostic {
    Diagnostic::warning(Pass::Schedule, code, location, message)
}

/// Audit a single-job SpGEMM schedule against its source matrices.
/// Returns every violation found (empty = clean).
pub fn audit_spgemm_schedule(a: &Csr, b: &Csr, s: &SpgemmSchedule) -> Vec<Diagnostic> {
    let mut d = Vec::new();
    if s.pipelines == 0 || s.bundle_size == 0 {
        d.push(err(
            codes::SCH_CONFIG,
            "schedule".into(),
            format!(
                "unusable geometry: pipelines = {}, bundle_size = {}",
                s.pipelines, s.bundle_size
            ),
        ));
        return d;
    }
    if a.ncols != b.nrows {
        d.push(err(
            codes::SCH_CONFIG,
            "schedule".into(),
            format!(
                "inner dimensions disagree: A is {}x{}, B is {}x{}",
                a.nrows, a.ncols, b.nrows, b.ncols
            ),
        ));
        return d;
    }

    let bs = s.bundle_size;
    let mut seen: HashSet<(Idx, u32)> = HashSet::new();
    let mut a_words = 0usize;
    let mut b_words = 0usize;
    // word accounting is only meaningful while every extent priced so far
    // was valid; a bad extent suppresses the SCH-WORDS comparison
    let mut words_ok = true;

    for (wid, wave) in s.waves.iter().enumerate() {
        if wave.assignments.is_empty() {
            d.push(warn(
                codes::SCH_WAVE_EMPTY,
                format!("wave {wid}"),
                "wave has no assignments (the scheduler never emits one)".into(),
            ));
        }
        if wave.assignments.len() > s.pipelines {
            d.push(err(
                codes::SCH_WAVE_OVERFULL,
                format!("wave {wid}"),
                format!(
                    "{} assignments exceed the design's {} pipelines",
                    wave.assignments.len(),
                    s.pipelines
                ),
            ));
        }
        let mut union: Vec<Idx> = Vec::new();
        for (slot, asg) in wave.assignments.iter().enumerate() {
            let loc = format!("wave {wid}, slot {slot}");
            if !check_chunk(a, bs, asg, &loc, &mut d) {
                words_ok = false;
                continue;
            }
            if !seen.insert((asg.a_row, asg.chunk)) {
                d.push(err(
                    codes::SCH_CHUNK_DUP,
                    loc,
                    format!("chunk ({}, {}) is already assigned", asg.a_row, asg.chunk),
                ));
            }
            a_words += 2 + 2 * asg.len;
            union.extend_from_slice(asg.a_cols(a));
        }
        union.sort_unstable();
        union.dedup();
        if wave.b_rows != union {
            d.push(err(
                codes::SCH_B_ROWS,
                format!("wave {wid}"),
                format!(
                    "b_rows is not the sorted deduped union of the wave's A columns \
                     ({} stored vs {} expected entries)",
                    wave.b_rows.len(),
                    union.len()
                ),
            ));
        }
        for &r in &wave.b_rows {
            if (r as usize) < b.nrows {
                b_words += row_stream_words(b.row_nnz(r as usize), bs);
            } else {
                d.push(err(
                    codes::SCH_B_ROWS,
                    format!("wave {wid}"),
                    format!("b_row {r} out of range for B with {} rows", b.nrows),
                ));
                words_ok = false;
            }
        }
    }

    coverage(
        &mut d,
        (0..a.nrows).map(|i| a.row_nnz(i).div_ceil(bs)),
        |row, chunk| seen.contains(&(row as Idx, chunk as u32)),
        "schedule",
    );

    if words_ok {
        if s.a_words != a_words {
            d.push(err(
                codes::SCH_WORDS,
                "schedule".into(),
                format!("a_words = {} but the assignments account for {a_words}", s.a_words),
            ));
        }
        if s.b_words != b_words {
            d.push(err(
                codes::SCH_WORDS,
                "schedule".into(),
                format!("b_words = {} but the wave B-streams account for {b_words}", s.b_words),
            ));
        }
    }

    trace_contract(&mut d, s.prep_cpu_s, &s.wave_cpu_s, s.waves.len());
    d
}

/// Audit a multi-tenant batch schedule against its job list.
pub fn audit_batch_schedule(jobs: &[(Csr, Csr)], s: &BatchSchedule) -> Vec<Diagnostic> {
    let mut d = Vec::new();
    if s.pipelines == 0 || s.bundle_size == 0 {
        d.push(err(
            codes::SCH_CONFIG,
            "batch schedule".into(),
            format!(
                "unusable geometry: pipelines = {}, bundle_size = {}",
                s.pipelines, s.bundle_size
            ),
        ));
        return d;
    }
    if s.n_jobs != jobs.len() {
        d.push(err(
            codes::SCH_CONFIG,
            "batch schedule".into(),
            format!("schedule is for {} job(s) but {} were provided", s.n_jobs, jobs.len()),
        ));
        return d;
    }
    for (j, (a, b)) in jobs.iter().enumerate() {
        if a.ncols != b.nrows {
            d.push(err(
                codes::SCH_CONFIG,
                format!("job {j}"),
                format!(
                    "inner dimensions disagree: A is {}x{}, B is {}x{}",
                    a.nrows, a.ncols, b.nrows, b.ncols
                ),
            ));
            return d;
        }
    }

    let bs = s.bundle_size;
    let mut seen: HashSet<(u32, Idx, u32)> = HashSet::new();
    let mut per_job: Vec<Vec<Assignment>> = vec![Vec::new(); s.n_jobs];
    let mut a_words = 0usize;
    let mut b_words = 0usize;
    let mut words_ok = true;

    for (wid, wave) in s.waves.iter().enumerate() {
        if wave.assignments.is_empty() {
            d.push(warn(
                codes::SCH_WAVE_EMPTY,
                format!("wave {wid}"),
                "wave has no assignments (the scheduler never emits one)".into(),
            ));
        }
        if wave.assignments.len() > s.pipelines {
            d.push(err(
                codes::SCH_WAVE_OVERFULL,
                format!("wave {wid}"),
                format!(
                    "{} assignments exceed the design's {} pipelines",
                    wave.assignments.len(),
                    s.pipelines
                ),
            ));
        }
        // per-assignment checks; collect the wave's valid-tag runs
        let mut runs: Vec<(u32, Vec<&Assignment>)> = Vec::new();
        for (slot, (tag, asg)) in wave.assignments.iter().enumerate() {
            let loc = format!("wave {wid}, slot {slot}");
            if *tag as usize >= s.n_jobs {
                d.push(err(
                    codes::SCH_JOB_TAG,
                    loc,
                    format!("job tag {tag} out of range for {} job(s)", s.n_jobs),
                ));
                words_ok = false;
                continue;
            }
            match runs.last_mut() {
                Some((t, run)) if *t == *tag => run.push(asg),
                _ => runs.push((*tag, vec![asg])),
            }
            let a = &jobs[*tag as usize].0;
            if !check_chunk(a, bs, asg, &loc, &mut d) {
                words_ok = false;
                continue;
            }
            if !seen.insert((*tag, asg.a_row, asg.chunk)) {
                d.push(err(
                    codes::SCH_CHUNK_DUP,
                    loc,
                    format!(
                        "job {} chunk ({}, {}) is already assigned",
                        tag, asg.a_row, asg.chunk
                    ),
                ));
            }
            a_words += 2 + 2 * asg.len;
            per_job[*tag as usize].push(*asg);
        }
        // assignments are job-major, so runs must be job-ascending —
        // a job split across non-adjacent runs breaks decompose()
        if runs.windows(2).any(|w| w[0].0 >= w[1].0) {
            d.push(err(
                codes::SCH_JOB_ORDER,
                format!("wave {wid}"),
                "job runs are not in ascending job-major order".into(),
            ));
        }
        // segments mirror the run order exactly
        if wave.segments.len() != runs.len() {
            d.push(err(
                codes::SCH_SEGMENT,
                format!("wave {wid}"),
                format!(
                    "{} B-stream segment(s) for {} job run(s)",
                    wave.segments.len(),
                    runs.len()
                ),
            ));
            words_ok = false;
            continue;
        }
        for (sid, (seg, (tag, run))) in wave.segments.iter().zip(&runs).enumerate() {
            let loc = format!("wave {wid}, segment {sid}");
            if seg.job != *tag {
                d.push(err(
                    codes::SCH_SEGMENT,
                    loc,
                    format!("segment is for job {} but the run is job {tag}", seg.job),
                ));
                words_ok = false;
                continue;
            }
            let (a, b) = &jobs[*tag as usize];
            let mut union: Vec<Idx> = Vec::new();
            for asg in run {
                if asg.start + asg.len <= a.cols.len() {
                    union.extend_from_slice(asg.a_cols(a));
                }
            }
            union.sort_unstable();
            union.dedup();
            if seg.b_rows != union {
                d.push(err(
                    codes::SCH_B_ROWS,
                    loc.clone(),
                    format!(
                        "segment b_rows is not the sorted deduped union of job {}'s \
                         A columns this wave ({} stored vs {} expected entries)",
                        tag,
                        seg.b_rows.len(),
                        union.len()
                    ),
                ));
            }
            for &r in &seg.b_rows {
                if (r as usize) < b.nrows {
                    b_words += row_stream_words(b.row_nnz(r as usize), bs);
                } else {
                    d.push(err(
                        codes::SCH_B_ROWS,
                        loc.clone(),
                        format!("b_row {r} out of range for job {tag}'s B with {} rows", b.nrows),
                    ));
                    words_ok = false;
                }
            }
        }
    }

    for (j, (a, _)) in jobs.iter().enumerate() {
        coverage(
            &mut d,
            (0..a.nrows).map(|i| a.row_nnz(i).div_ceil(bs)),
            |row, chunk| seen.contains(&(j as u32, row as Idx, chunk as u32)),
            &format!("job {j}"),
        );
        // decompose() invariant: extracting the job's chunks in wave order
        // must yield its canonical single-job chunk sequence; only check
        // the order when the chunk multiset is right (coverage/duplication
        // problems are already reported above)
        let canonical = canonical_chunks(a, bs);
        let got: Vec<(Idx, u32)> = per_job[j].iter().map(|c| (c.a_row, c.chunk)).collect();
        if got != canonical {
            let mut got_sorted = got.clone();
            got_sorted.sort_unstable();
            if got_sorted == canonical {
                d.push(err(
                    codes::SCH_JOB_ORDER,
                    format!("job {j}"),
                    "chunks extracted in wave order are not in the single-job \
                     schedule order (decompose() would replay out of order)"
                        .into(),
                ));
            }
        }
    }

    if words_ok {
        if s.a_words != a_words {
            d.push(err(
                codes::SCH_WORDS,
                "batch schedule".into(),
                format!("a_words = {} but the assignments account for {a_words}", s.a_words),
            ));
        }
        if s.b_words != b_words {
            d.push(err(
                codes::SCH_WORDS,
                "batch schedule".into(),
                format!("b_words = {} but the wave segments account for {b_words}", s.b_words),
            ));
        }
    }

    trace_contract(&mut d, s.prep_cpu_s, &s.wave_cpu_s, s.waves.len());
    d
}

/// Validate one assignment against its source CSR; returns true when the
/// extent is canonical (safe to slice, price and union).
fn check_chunk(
    a: &Csr,
    bs: usize,
    asg: &Assignment,
    loc: &str,
    d: &mut Vec<Diagnostic>,
) -> bool {
    let row = asg.a_row as usize;
    if row >= a.nrows {
        d.push(err(
            codes::SCH_CHUNK_RANGE,
            loc.into(),
            format!("a_row {} out of range for A with {} rows", asg.a_row, a.nrows),
        ));
        return false;
    }
    if asg.len == 0 || asg.len > bs {
        d.push(err(
            codes::SCH_CHUNK_LEN,
            loc.into(),
            format!("chunk len {} outside 1..={bs}", asg.len),
        ));
        return false;
    }
    let nnz = a.row_nnz(row);
    let nchunks = nnz.div_ceil(bs);
    let ci = asg.chunk as usize;
    if ci >= nchunks {
        d.push(err(
            codes::SCH_CHUNK_RANGE,
            loc.into(),
            format!("row {row} has {nchunks} chunk(s) but the ordinal is {ci}"),
        ));
        return false;
    }
    let exp_start = a.row_ptr[row] + ci * bs;
    let exp_len = ((ci + 1) * bs).min(nnz) - ci * bs;
    if asg.start != exp_start || asg.len != exp_len {
        d.push(err(
            codes::SCH_CHUNK_RANGE,
            loc.into(),
            format!(
                "extent (start {}, len {}) does not match the CSR's \
                 (start {exp_start}, len {exp_len}) for (row {row}, chunk {ci})",
                asg.start, asg.len
            ),
        ));
        return false;
    }
    if asg.last_chunk != (ci + 1 == nchunks) {
        d.push(err(
            codes::SCH_LAST_CHUNK,
            loc.into(),
            format!(
                "last_chunk = {} but chunk {ci} of {nchunks} {} the row's final chunk",
                asg.last_chunk,
                if ci + 1 == nchunks { "is" } else { "is not" }
            ),
        ));
        // the extent itself is still canonical — keep it in the accounting
    }
    true
}

/// The canonical `(row, chunk)` enumeration of a CSR at a bundle size —
/// exactly the scheduler's prologue order.
fn canonical_chunks(a: &Csr, bs: usize) -> Vec<(Idx, u32)> {
    let mut out = Vec::new();
    for i in 0..a.nrows {
        for ci in 0..a.row_nnz(i).div_ceil(bs) {
            out.push((i as Idx, ci as u32));
        }
    }
    out
}

/// Report uncovered `(row, chunk)` pairs as one summary diagnostic (a
/// wholesale corruption would otherwise flood the report).
fn coverage(
    d: &mut Vec<Diagnostic>,
    chunks_per_row: impl Iterator<Item = usize>,
    covered: impl Fn(usize, usize) -> bool,
    what: &str,
) {
    let mut missing = 0usize;
    let mut first: Option<(usize, usize)> = None;
    for (row, nchunks) in chunks_per_row.enumerate() {
        for chunk in 0..nchunks {
            if !covered(row, chunk) {
                missing += 1;
                first.get_or_insert((row, chunk));
            }
        }
    }
    if let Some((row, chunk)) = first {
        d.push(err(
            codes::SCH_COVERAGE,
            what.into(),
            format!(
                "{missing} (row, chunk) pair(s) of A are assigned to no wave \
                 (first missing: ({row}, {chunk}))"
            ),
        ));
    }
}

/// The `overlap` contract: one finite non-negative CPU cost per wave.
fn trace_contract(d: &mut Vec<Diagnostic>, prep_cpu_s: f64, wave_cpu_s: &[f64], n_waves: usize) {
    if wave_cpu_s.len() != n_waves {
        d.push(err(
            codes::SCH_TRACE,
            "cpu trace".into(),
            format!("{} wave_cpu_s entries for {n_waves} wave(s)", wave_cpu_s.len()),
        ));
    }
    if !prep_cpu_s.is_finite() || prep_cpu_s < 0.0 {
        d.push(err(
            codes::SCH_TRACE,
            "cpu trace".into(),
            format!("prep_cpu_s = {prep_cpu_s} is not a finite non-negative duration"),
        ));
    }
    for (i, &t) in wave_cpu_s.iter().enumerate() {
        if !t.is_finite() || t < 0.0 {
            d.push(err(
                codes::SCH_TRACE,
                format!("cpu trace, wave {i}"),
                format!("wave_cpu_s = {t} is not a finite non-negative duration"),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::schedule::{schedule_spgemm, schedule_spgemm_batch};
    use crate::sparse::gen;

    fn mk(n: usize, nnz: usize, seed: u64) -> Csr {
        gen::random_uniform(n, n, nnz, seed)
    }

    #[test]
    fn clean_on_generated_schedules() {
        for (family, n, nnz) in [
            (gen::Family::RandomUniform, 60, 900),
            (gen::Family::PowerLaw, 80, 1600),
            (gen::Family::BandedFem, 50, 400),
        ] {
            let a = gen::generate(family, n, nnz, 3);
            let b = gen::generate(family, n, nnz, 4);
            for (p, bs) in [(1usize, 32usize), (8, 16), (64, 8)] {
                let s = schedule_spgemm(&a, &b, p, bs);
                let diags = audit_spgemm_schedule(&a, &b, &s);
                assert!(diags.is_empty(), "{family:?} p={p} bs={bs}: {diags:?}");
            }
        }
    }

    #[test]
    fn clean_on_empty_and_rectangular_inputs() {
        let a = Csr::new(10, 20);
        let b = Csr::new(20, 5);
        let s = schedule_spgemm(&a, &b, 4, 32);
        assert!(audit_spgemm_schedule(&a, &b, &s).is_empty());
        // one long row split across several chunks and waves
        let a = gen::random_uniform(1, 300, 150, 9);
        let b = mk(300, 900, 10);
        let s = schedule_spgemm(&a, &b, 2, 32);
        assert!(audit_spgemm_schedule(&a, &b, &s).is_empty());
    }

    #[test]
    fn clean_on_batch_schedules_including_empty_jobs() {
        let mut jobs: Vec<(Csr, Csr)> = (0..4)
            .map(|j| (mk(30, 200, 20 + j), mk(30, 200, 30 + j)))
            .collect();
        jobs.push((Csr::new(5, 7), Csr::new(7, 3)));
        for p in [4usize, 32, 128] {
            let s = schedule_spgemm_batch(&jobs, p, 16);
            let diags = audit_batch_schedule(&jobs, &s);
            assert!(diags.is_empty(), "p={p}: {diags:?}");
        }
    }

    #[test]
    fn flags_schedule_against_wrong_matrix() {
        // auditing job 0's schedule against job 1's matrices must light up:
        // the chunk extents and unions cannot match a different CSR
        let a0 = mk(40, 500, 1);
        let b0 = mk(40, 500, 2);
        let a1 = mk(40, 500, 5);
        let s = schedule_spgemm(&a0, &b0, 8, 16);
        let diags = audit_spgemm_schedule(&a1, &b0, &s);
        assert!(!diags.is_empty(), "cross-matrix audit must not be clean");
    }

    #[test]
    fn flags_zero_geometry() {
        let a = mk(10, 40, 1);
        let mut s = schedule_spgemm(&a, &a, 4, 16);
        s.pipelines = 0;
        let diags = audit_spgemm_schedule(&a, &a, &s);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code, codes::SCH_CONFIG);
    }

    #[test]
    fn flags_nonfinite_trace() {
        let a = mk(10, 60, 2);
        let mut s = schedule_spgemm(&a, &a, 4, 16);
        s.wave_cpu_s[0] = f64::NAN;
        let diags = audit_spgemm_schedule(&a, &a, &s);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, codes::SCH_TRACE);
    }
}
