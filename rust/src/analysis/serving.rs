//! Verification pass over a completed serving run — the admission
//! contract, audited.
//!
//! The serving loop ([`crate::serving::run_serving`]) promises three
//! things about the [`ServingLog`] it emits, and this pass re-checks all
//! of them from the log alone (no access to the simulator state that
//! produced it):
//!
//! * **Budget** ([`codes::SRV_BUDGET`]) — an admitted job's age at the
//!   window close that admitted it never exceeds the latency budget. The
//!   shed rule rejects any job whose age *plus* its service estimate
//!   busts the budget, so age alone over budget means the controller
//!   admitted a job it was required to shed.
//! * **Timeline** ([`codes::SRV_TIMELINE`]) — causality: window closes
//!   are non-decreasing across batches, a batch starts no earlier than
//!   its window close, a job arrives no later than the close that admits
//!   it and completes no earlier than its batch starts.
//! * **Conservation** ([`codes::SRV_CONSERVE`]) — every arrival is
//!   accounted for: `admitted + rejected + queued == arrived`, and the
//!   batch records carry exactly `admitted` jobs in total.
//!
//! An empty batch record ([`codes::SRV_EMPTY`]) is a warning: harmless to
//! replay, but the event loop never emits one, so its presence means the
//! log was not produced by the loop.

use super::{codes, Diagnostic, Pass};
use crate::serving::ServingLog;

/// Absolute slack for floating-point timeline/budget comparisons: the
/// loop computes timestamps by summation, so exact equality is legitimate
/// but representable-rounding noise must not trip the audit.
const EPS_S: f64 = 1e-12;

/// Audit a serving run's log against the admission contract. Pure and
/// total; returns every violation found (empty means clean).
pub fn audit_serving(log: &ServingLog) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let err = |code, location: String, message: String| {
        Diagnostic::error(Pass::Serving, code, location, message)
    };

    let mut in_batches = 0usize;
    let mut prev_close = f64::NEG_INFINITY;
    for (bi, batch) in log.batches.iter().enumerate() {
        let loc = || format!("batch {bi}");
        if batch.jobs.is_empty() {
            diags.push(Diagnostic::warning(
                Pass::Serving,
                codes::SRV_EMPTY,
                loc(),
                "batch record carries no jobs (the event loop never emits one)".into(),
            ));
        }
        if batch.window_close_s < prev_close - EPS_S {
            diags.push(err(
                codes::SRV_TIMELINE,
                loc(),
                format!(
                    "window close {:.3e}s precedes the previous batch's close {prev_close:.3e}s",
                    batch.window_close_s
                ),
            ));
        }
        prev_close = prev_close.max(batch.window_close_s);
        if batch.start_s < batch.window_close_s - EPS_S {
            diags.push(err(
                codes::SRV_TIMELINE,
                loc(),
                format!(
                    "batch starts at {:.3e}s, before its window closed at {:.3e}s",
                    batch.start_s, batch.window_close_s
                ),
            ));
        }
        for job in &batch.jobs {
            in_batches += 1;
            let jloc = || format!("batch {bi}, job {}", job.id);
            let age = batch.window_close_s - job.arrival_s;
            if age < -EPS_S {
                diags.push(err(
                    codes::SRV_TIMELINE,
                    jloc(),
                    format!(
                        "admitted before arriving: arrival {:.3e}s is after the window \
                         close {:.3e}s",
                        job.arrival_s, batch.window_close_s
                    ),
                ));
            }
            if age > log.latency_budget_s + EPS_S {
                diags.push(err(
                    codes::SRV_BUDGET,
                    jloc(),
                    format!(
                        "admitted with age {age:.3e}s over the {:.3e}s latency budget — \
                         the controller must have shed it",
                        log.latency_budget_s
                    ),
                ));
            }
            if job.complete_s < batch.start_s - EPS_S {
                diags.push(err(
                    codes::SRV_TIMELINE,
                    jloc(),
                    format!(
                        "completes at {:.3e}s, before its batch started at {:.3e}s",
                        job.complete_s, batch.start_s
                    ),
                ));
            }
        }
    }

    if in_batches != log.admitted {
        diags.push(err(
            codes::SRV_CONSERVE,
            "log".into(),
            format!(
                "batches carry {in_batches} job(s) but the log claims {} admitted",
                log.admitted
            ),
        ));
    }
    let accounted = log.admitted + log.rejected + log.queued;
    if accounted != log.arrived {
        diags.push(err(
            codes::SRV_CONSERVE,
            "log".into(),
            format!(
                "{} arrived but admitted {} + rejected {} + queued {} = {accounted}",
                log.arrived, log.admitted, log.rejected, log.queued
            ),
        ));
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{count_severity, ensure_clean, Severity};
    use crate::serving::{BatchRecord, JobRecord};

    fn clean_log() -> ServingLog {
        ServingLog {
            latency_budget_s: 2e-3,
            arrived: 3,
            admitted: 2,
            rejected: 1,
            queued: 0,
            batches: vec![BatchRecord {
                window_close_s: 2e-4,
                start_s: 2e-4,
                cpu_s: 1e-5,
                fpga_s: 2e-5,
                jobs: vec![
                    JobRecord { id: 0, arrival_s: 5e-5, complete_s: 2.6e-4, cached: false },
                    JobRecord { id: 1, arrival_s: 1e-4, complete_s: 2.7e-4, cached: true },
                ],
            }],
        }
    }

    #[test]
    fn clean_log_passes() {
        let diags = audit_serving(&clean_log());
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn budget_violation_is_flagged() {
        let mut log = clean_log();
        // age the first job past the budget at its window close
        log.batches[0].jobs[0].arrival_s = -3e-3;
        let diags = audit_serving(&log);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, codes::SRV_BUDGET);
        assert!(ensure_clean(diags).is_err());
    }

    #[test]
    fn timeline_violations_are_flagged() {
        let mut log = clean_log();
        log.batches[0].start_s = 1e-4; // before the window close
        log.batches[0].jobs[1].complete_s = 5e-5; // before the batch start
        let diags = audit_serving(&log);
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(diags.iter().all(|d| d.code == codes::SRV_TIMELINE));

        let mut log = clean_log();
        log.batches[0].jobs[0].arrival_s = 3e-4; // admitted before arriving
        let diags = audit_serving(&log);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, codes::SRV_TIMELINE);

        let mut log = clean_log();
        let mut earlier = log.batches[0].clone();
        earlier.window_close_s = 1e-4;
        earlier.start_s = 1e-4;
        log.batches.push(earlier); // closes go backwards
        log.arrived = 5;
        log.admitted = 4;
        let diags = audit_serving(&log);
        assert!(diags.iter().any(|d| d.code == codes::SRV_TIMELINE), "{diags:?}");
    }

    #[test]
    fn conservation_violations_are_flagged() {
        let mut log = clean_log();
        log.admitted = 3; // batches only carry 2
        let diags = audit_serving(&log);
        assert_eq!(diags.len(), 2, "{diags:?}"); // count mismatch + arrival sum
        assert!(diags.iter().all(|d| d.code == codes::SRV_CONSERVE));

        let mut log = clean_log();
        log.queued = 7;
        let diags = audit_serving(&log);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, codes::SRV_CONSERVE);
    }

    #[test]
    fn empty_batch_is_a_warning_only() {
        let mut log = clean_log();
        log.batches.push(BatchRecord {
            window_close_s: 4e-4,
            start_s: 4e-4,
            cpu_s: 0.0,
            fpga_s: 0.0,
            jobs: Vec::new(),
        });
        let diags = audit_serving(&log);
        assert_eq!(count_severity(&diags, Severity::Error), 0, "{diags:?}");
        assert_eq!(count_severity(&diags, Severity::Warning), 1);
        assert_eq!(diags[0].code, codes::SRV_EMPTY);
        assert!(ensure_clean(diags).is_ok(), "warnings alone pass the gate");
    }
}
