//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Deterministic, seed-reported randomized testing: a property is run over
//! `cases` generated inputs; on failure the framework retries with shrunk
//! sizes and reports the seed + case index so the exact failure reproduces
//! with `PROP_SEED=<seed> cargo test`.

use crate::util::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of generated cases.
    pub cases: usize,
    /// Base seed; override with env `PROP_SEED` to replay.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config { cases: 64, seed }
    }
}

/// A size hint passed to generators: starts small, grows with case index so
/// early failures are small failures (poor man's shrinking).
#[derive(Clone, Copy, Debug)]
pub struct Size(pub usize);

/// Run `prop` over `cfg.cases` cases. The property receives a seeded RNG
/// and a growing size hint; it should panic (assert) on violation.
pub fn check<F: FnMut(&mut Pcg64, Size)>(name: &str, cfg: Config, mut prop: F) {
    for case in 0..cfg.cases {
        // size ramps 4 .. 4+cases (generators scale as they see fit)
        let size = Size(4 + case);
        let mut rng = Pcg64::with_stream(cfg.seed, case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, size)
        }));
        if let Err(payload) = result {
            eprintln!(
                "property `{name}` failed at case {case}/{} (size {}, seed {:#x}).\n\
                 reproduce with: PROP_SEED={} cargo test",
                cfg.cases, size.0, cfg.seed, cfg.seed
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Convenience: run with default config.
pub fn quickcheck<F: FnMut(&mut Pcg64, Size)>(name: &str, prop: F) {
    check(name, Config::default(), prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        quickcheck("addition commutes", |rng, _| {
            let a = rng.next_below(1000) as i64;
            let b = rng.next_below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports() {
        let r = std::panic::catch_unwind(|| {
            check(
                "always fails",
                Config { cases: 3, seed: 1 },
                |_, _| panic!("boom"),
            )
        });
        assert!(r.is_err());
    }

    #[test]
    fn cases_all_run_and_sizes_grow() {
        let mut sizes = Vec::new();
        check("sizes", Config { cases: 5, seed: 2 }, |_, s| sizes.push(s.0));
        assert_eq!(sizes.len(), 5);
        assert!(sizes.windows(2).all(|w| w[0] < w[1]));
    }
}
