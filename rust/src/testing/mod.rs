//! In-tree testing support: a mini property-testing framework
//! ([`prop`]) used by unit tests and the `prop_invariants` integration
//! suite (the offline image has no proptest crate).

pub mod prop;

pub use prop::{check, quickcheck, Config, Size};
