//! Matrix Market (.mtx) I/O.
//!
//! The paper's evaluation uses SuiteSparse matrices, which are distributed
//! in this format; with network access the real Table-I matrices can be
//! dropped into `data/` and every harness accepts `--mtx <path>` instead of
//! a synthetic clone. Supports the `matrix coordinate
//! real|integer|pattern general|symmetric` subset (what SuiteSparse uses).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::{Coo, Csr, Val};

/// Read a Matrix Market coordinate file into COO.
pub fn read_coo(path: &Path) -> Result<Coo> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_coo_from(std::io::BufReader::new(f))
}

/// Read from any buffered reader (unit-testable without touching disk).
pub fn read_coo_from<R: BufRead>(reader: R) -> Result<Coo> {
    let mut lines = reader.lines();
    let header = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                if !l.trim().is_empty() {
                    break l;
                }
            }
            None => bail!("empty MatrixMarket file"),
        }
    };
    let h: Vec<String> = header.split_whitespace().map(|t| t.to_ascii_lowercase()).collect();
    ensure!(
        h.len() >= 5 && h[0] == "%%matrixmarket" && h[1] == "matrix",
        "not a MatrixMarket matrix header: {header}"
    );
    ensure!(h[2] == "coordinate", "only coordinate format supported, got {}", h[2]);
    let field = h[3].as_str();
    ensure!(
        matches!(field, "real" | "integer" | "pattern"),
        "unsupported field type {field}"
    );
    let symmetry = h[4].as_str();
    ensure!(
        matches!(symmetry, "general" | "symmetric"),
        "unsupported symmetry {symmetry}"
    );

    // skip comments, read size line
    let size_line = loop {
        let l = lines.next().context("missing size line")??;
        let t = l.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        break l;
    };
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>().context("bad size line"))
        .collect::<Result<_>>()?;
    ensure!(dims.len() == 3, "size line needs 3 fields: {size_line}");
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);

    let mut coo = Coo::new(nrows, ncols);
    let mut seen = 0usize;
    for l in lines {
        let l = l?;
        let t = l.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it.next().context("missing row")?.parse()?;
        let c: usize = it.next().context("missing col")?.parse()?;
        ensure!(r >= 1 && c >= 1 && r <= nrows && c <= ncols, "entry ({r},{c}) out of bounds");
        let v: Val = match field {
            "pattern" => 1.0,
            _ => it.next().context("missing value")?.parse::<f64>()? as Val,
        };
        coo.push(r - 1, c - 1, v);
        if symmetry == "symmetric" && r != c {
            coo.push(c - 1, r - 1, v);
        }
        seen += 1;
    }
    ensure!(seen == nnz, "expected {nnz} entries, found {seen}");
    Ok(coo)
}

/// Read straight to CSR.
pub fn read_csr(path: &Path) -> Result<Csr> {
    Ok(read_coo(path)?.to_csr())
}

/// Write CSR as a `general real` coordinate file.
pub fn write_csr(path: &Path, m: &Csr) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by reap (REAP reproduction)")?;
    writeln!(w, "{} {} {}", m.nrows, m.ncols, m.nnz())?;
    for i in 0..m.nrows {
        for (c, v) in m.row_cols(i).iter().zip(m.row_vals(i)) {
            writeln!(w, "{} {} {}", i + 1, *c + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const SAMPLE: &str = "%%MatrixMarket matrix coordinate real general\n\
        % comment\n\
        3 3 3\n\
        1 1 2.5\n\
        2 3 -1\n\
        3 1 4\n";

    #[test]
    fn parses_general_real() {
        let coo = read_coo_from(Cursor::new(SAMPLE)).unwrap();
        let csr = coo.to_csr();
        assert_eq!(csr.nrows, 3);
        assert_eq!(csr.get(0, 0), 2.5);
        assert_eq!(csr.get(1, 2), -1.0);
        assert_eq!(csr.get(2, 0), 4.0);
    }

    #[test]
    fn parses_symmetric_mirrors_offdiag() {
        let s = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 3\n2 1 5\n";
        let csr = read_coo_from(Cursor::new(s)).unwrap().to_csr();
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.get(0, 1), 5.0);
        assert_eq!(csr.get(1, 0), 5.0);
    }

    #[test]
    fn parses_pattern_as_ones() {
        let s = "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n2 2\n";
        let csr = read_coo_from(Cursor::new(s)).unwrap().to_csr();
        assert_eq!(csr.get(1, 1), 1.0);
    }

    #[test]
    fn rejects_array_format() {
        let s = "%%MatrixMarket matrix array real general\n2 2\n1\n2\n3\n4\n";
        assert!(read_coo_from(Cursor::new(s)).is_err());
    }

    #[test]
    fn rejects_wrong_count() {
        let s = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1\n";
        assert!(read_coo_from(Cursor::new(s)).is_err());
    }

    #[test]
    fn rejects_out_of_bounds_entry() {
        let s = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1\n";
        assert!(read_coo_from(Cursor::new(s)).is_err());
    }

    #[test]
    fn write_read_roundtrip() {
        let m = crate::sparse::gen::random_uniform(10, 8, 30, 42);
        let dir = std::env::temp_dir().join("reap_mm_test");
        let path = dir.join("m.mtx");
        write_csr(&path, &m).unwrap();
        let back = read_csr(&path).unwrap();
        assert_eq!(back, m);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
