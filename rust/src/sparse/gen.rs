//! Deterministic synthetic sparse-matrix generators.
//!
//! The evaluation image has no network access, so the 24 SuiteSparse
//! matrices of Table I are replaced by synthetic clones matched on the
//! properties that actually drive REAP's behaviour: dimension, nnz
//! (density), row-length distribution and pattern family. Each generator
//! corresponds to an application domain present in the suite:
//!
//! * [`random_uniform`] — Erdős–Rényi-style scatter (e.g. `cage12`, DNA
//!   electrophoresis; `m133-b3` simplicial complexes).
//! * [`banded_fem`] — banded + local-stencil patterns of FEM stiffness
//!   matrices (`bcsstk*`, `cant`, `consph`, `offshore`, `filter3D`, …).
//! * [`power_law`] — skewed degree distributions of network/economic
//!   matrices (`mbeacxc`, `descriptor_xingo6u`, circuit matrices). The
//!   skew stresses REAP's big-row splitting.
//! * [`block_random`] — clustered blocks (supernodal-ish patterns of
//!   `pdb1HYs`, `rma10`).
//! * [`zipf_adversarial`] — deliberately hostile Zipf row lengths (steeper
//!   exponent than [`power_law`], giant head rows scattered at random
//!   positions). Built for the `reap bench scaling` harness: static
//!   contiguous band partitions assign whole giant rows to one worker,
//!   which is exactly the imbalance work-stealing grains erase.
//!
//! All generators are seeded ([`Pcg64`]) and allocate exact-size CSR
//! directly where possible; they are used by tests, examples, and the
//! Table-I suite in `harness::suite`.

use crate::util::Pcg64;

use super::{ops, Coo, Csc, Csr, Idx, Val};

/// Pattern family — recorded in the Table-I clone registry so the harness
/// can report which family stood in for which SuiteSparse matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    RandomUniform,
    BandedFem,
    PowerLaw,
    BlockRandom,
    ZipfAdversarial,
}

impl std::fmt::Display for Family {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Family::RandomUniform => "random-uniform",
            Family::BandedFem => "banded-fem",
            Family::PowerLaw => "power-law",
            Family::BlockRandom => "block-random",
            Family::ZipfAdversarial => "zipf-adversarial",
        };
        write!(f, "{s}")
    }
}

/// Generate by family with a target nnz.
pub fn generate(family: Family, n: usize, target_nnz: usize, seed: u64) -> Csr {
    match family {
        Family::RandomUniform => random_uniform(n, n, target_nnz, seed),
        Family::BandedFem => banded_fem(n, target_nnz, seed),
        Family::PowerLaw => power_law(n, target_nnz, seed),
        Family::BlockRandom => block_random(n, target_nnz, seed),
        Family::ZipfAdversarial => zipf_adversarial(n, target_nnz, 1.6, seed),
    }
}

/// Uniform random matrix with exactly `min(target_nnz, nrows*ncols)`
/// nonzeros, spread evenly across rows (±1).
pub fn random_uniform(nrows: usize, ncols: usize, target_nnz: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::with_stream(seed, 0x5eed_0001);
    let total = target_nnz.min(nrows.saturating_mul(ncols));
    let base = if nrows == 0 { 0 } else { total / nrows };
    let extra = if nrows == 0 { 0 } else { total % nrows };
    let mut row_ptr = vec![0usize; nrows + 1];
    let mut cols: Vec<Idx> = Vec::with_capacity(total);
    let mut vals: Vec<Val> = Vec::with_capacity(total);
    for i in 0..nrows {
        let k = (base + usize::from(i < extra)).min(ncols);
        for c in rng.sample_distinct(ncols, k) {
            cols.push(c as Idx);
            vals.push(rng.signed_unit_f32());
        }
        row_ptr[i + 1] = cols.len();
    }
    Csr { nrows, ncols, row_ptr, cols, vals }
}

/// FEM-style banded matrix: a tridiagonal-ish core plus a few local stencil
/// neighbours within a bandwidth proportional to the target density, plus
/// sparse long-range couplings (multi-physics links). Symmetric pattern.
pub fn banded_fem(n: usize, target_nnz: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::with_stream(seed, 0x5eed_0002);
    let per_row = (target_nnz / n.max(1)).max(1);
    // Keep ~90% of entries within the band, 10% long-range.
    let band_per_row = ((per_row as f64 * 0.9) as usize).max(1);
    let far_per_row = per_row - band_per_row.min(per_row);
    let half_band = (band_per_row * 2).max(2).min(n.saturating_sub(1).max(1));
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, 2.0 + rng.next_f32());
        let lo = i.saturating_sub(half_band);
        let hi = (i + half_band + 1).min(n);
        // sample band neighbours below the diagonal; mirror for symmetry
        let mut placed = 0usize;
        let mut guard = 0usize;
        while placed < band_per_row / 2 + 1 && guard < 8 * band_per_row + 8 {
            guard += 1;
            let j = rng.range(lo, hi);
            if j < i {
                let v = rng.signed_unit_f32();
                coo.push(i, j, v);
                coo.push(j, i, v);
                placed += 1;
            }
        }
        for _ in 0..far_per_row / 2 {
            let j = rng.range(0, n);
            if j != i {
                let v = rng.signed_unit_f32() * 0.1;
                coo.push(i, j, v);
                coo.push(j, i, v);
            }
        }
    }
    coo.to_csr()
}

/// Power-law (Zipf-ish) row degrees: a few very heavy rows, a long tail of
/// light rows. Exercises RIR bundle splitting and pipeline load imbalance.
pub fn power_law(n: usize, target_nnz: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::with_stream(seed, 0x5eed_0003);
    // degrees ∝ rank^(-alpha), normalized to target_nnz
    let alpha = 1.2f64;
    let weights: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).powf(-alpha)).collect();
    let wsum: f64 = weights.iter().sum();
    // randomize which rows are heavy
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let mut degrees = vec![0usize; n];
    for (rank, &row) in perm.iter().enumerate() {
        let d = (weights[rank] / wsum * target_nnz as f64).round() as usize;
        degrees[row] = d.clamp(1, n);
    }
    let mut row_ptr = vec![0usize; n + 1];
    let mut cols: Vec<Idx> = Vec::new();
    let mut vals: Vec<Val> = Vec::new();
    for i in 0..n {
        for c in rng.sample_distinct(n, degrees[i]) {
            cols.push(c as Idx);
            vals.push(rng.signed_unit_f32());
        }
        row_ptr[i + 1] = cols.len();
    }
    Csr { nrows: n, ncols: n, row_ptr, cols, vals }
}

/// Adversarial Zipf row lengths: `len(rank) ∝ rank^(-alpha)` with a steep
/// exponent, heavy ranks scattered to random row positions. With
/// `alpha = 1.6` the head row alone carries a double-digit percentage of
/// all nonzeros, so any contiguous static partition of rows (or of the
/// waves built from them) hands one worker several times the mean load —
/// the scaling bench uses this family to expose that cliff. Fully
/// seed-deterministic (dedicated Pcg64 stream `0x5eed_0005`).
pub fn zipf_adversarial(n: usize, target_nnz: usize, alpha: f64, seed: u64) -> Csr {
    assert!(alpha > 0.0, "zipf exponent must be positive");
    let mut rng = Pcg64::with_stream(seed, 0x5eed_0005);
    let weights: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).powf(-alpha)).collect();
    let wsum: f64 = weights.iter().sum();
    // scatter the heavy ranks: rank r's length lands on a random row, so
    // consecutive giant rows don't end up adjacent (adjacency would let a
    // contiguous partition get "lucky" and keep them in one band anyway).
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let mut degrees = vec![0usize; n];
    for (rank, &row) in perm.iter().enumerate() {
        let d = (weights[rank] / wsum * target_nnz as f64).round() as usize;
        degrees[row] = d.clamp(1, n);
    }
    let mut row_ptr = vec![0usize; n + 1];
    let mut cols: Vec<Idx> = Vec::new();
    let mut vals: Vec<Val> = Vec::new();
    for i in 0..n {
        for c in rng.sample_distinct(n, degrees[i]) {
            cols.push(c as Idx);
            vals.push(rng.signed_unit_f32());
        }
        row_ptr[i + 1] = cols.len();
    }
    Csr { nrows: n, ncols: n, row_ptr, cols, vals }
}

/// Clustered blocks: dense-ish square blocks along the diagonal plus random
/// inter-block couplings (protein / multi-body patterns).
pub fn block_random(n: usize, target_nnz: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::with_stream(seed, 0x5eed_0004);
    let block = ((n as f64).sqrt() as usize).clamp(4, 64).min(n.max(1));
    let nblocks = n.div_ceil(block);
    // Spend ~70% of nnz inside diagonal blocks, 30% across.
    let in_block_total = target_nnz * 7 / 10;
    let cross_total = target_nnz - in_block_total;
    let per_block = in_block_total / nblocks.max(1);
    let mut coo = Coo::new(n, n);
    for b in 0..nblocks {
        let lo = b * block;
        let hi = ((b + 1) * block).min(n);
        let size = hi - lo;
        let cap = size * size;
        let k = per_block.min(cap);
        for idx in rng.sample_distinct(cap, k) {
            let (r, c) = (lo + idx / size, lo + idx % size);
            coo.push(r, c, rng.signed_unit_f32());
        }
    }
    for _ in 0..cross_total {
        let r = rng.range(0, n);
        let c = rng.range(0, n);
        coo.push(r, c, rng.signed_unit_f32() * 0.2);
    }
    coo.to_csr()
}

/// An SPD matrix with the pattern of the given family — the Cholesky-side
/// generator (see `ops::make_spd` for the construction).
pub fn spd(family: Family, n: usize, target_nnz: usize, seed: u64) -> Csc {
    let base = generate(family, n, target_nnz, seed);
    ops::make_spd(&base)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_uniform_hits_exact_nnz() {
        let m = random_uniform(100, 100, 500, 1);
        assert_eq!(m.nnz(), 500);
        m.validate().unwrap();
    }

    #[test]
    fn random_uniform_caps_at_dense() {
        let m = random_uniform(4, 4, 100, 1);
        assert_eq!(m.nnz(), 16);
        m.validate().unwrap();
    }

    const ALL_FAMILIES: [Family; 5] = [
        Family::RandomUniform,
        Family::BandedFem,
        Family::PowerLaw,
        Family::BlockRandom,
        Family::ZipfAdversarial,
    ];

    #[test]
    fn generators_are_deterministic() {
        for fam in ALL_FAMILIES {
            let a = generate(fam, 80, 400, 7);
            let b = generate(fam, 80, 400, 7);
            assert_eq!(a, b, "{fam} not deterministic");
            let c = generate(fam, 80, 400, 8);
            assert_ne!(a, c, "{fam} ignores seed");
        }
    }

    #[test]
    fn nnz_within_tolerance_of_target() {
        for fam in ALL_FAMILIES {
            let target = 2000;
            let m = generate(fam, 200, target, 3);
            m.validate().unwrap();
            let ratio = m.nnz() as f64 / target as f64;
            assert!(
                (0.5..2.0).contains(&ratio),
                "{fam}: nnz {} vs target {target}",
                m.nnz()
            );
        }
    }

    #[test]
    fn banded_fem_is_symmetric_pattern() {
        let m = banded_fem(60, 500, 5);
        let t = m.transpose();
        // structural symmetry: same pattern both ways
        for i in 0..m.nrows {
            assert_eq!(m.row_cols(i), t.row_cols(i), "row {i}");
        }
    }

    #[test]
    fn power_law_has_skew() {
        let m = power_law(300, 6000, 11);
        let mut lens: Vec<usize> = (0..m.nrows).map(|i| m.row_nnz(i)).collect();
        lens.sort_unstable();
        let max = *lens.last().unwrap();
        let med = lens[lens.len() / 2];
        assert!(max >= med * 5, "expected heavy tail: max={max} med={med}");
    }

    #[test]
    fn zipf_adversarial_is_more_skewed_than_power_law() {
        let n = 300;
        let nnz = 6000;
        let head_share = |m: &Csr| {
            let max = (0..m.nrows).map(|i| m.row_nnz(i)).max().unwrap();
            max as f64 / m.nnz() as f64
        };
        let zipf = zipf_adversarial(n, nnz, 1.6, 11);
        zipf.validate().unwrap();
        let pl = power_law(n, nnz, 11);
        assert!(
            head_share(&zipf) > head_share(&pl),
            "zipf head {:.3} should beat power-law head {:.3}",
            head_share(&zipf),
            head_share(&pl)
        );
        // the head row carries a macroscopic fraction of all nonzeros
        assert!(head_share(&zipf) > 0.05, "head share {:.3}", head_share(&zipf));
    }

    #[test]
    fn zipf_adversarial_every_row_nonempty() {
        let m = zipf_adversarial(120, 1500, 1.6, 3);
        m.validate().unwrap();
        assert!((0..m.nrows).all(|i| m.row_nnz(i) >= 1));
    }

    #[test]
    fn spd_generator_is_factorizable() {
        use crate::sparse::Dense;
        let a = spd(Family::BandedFem, 24, 100, 9);
        let d = Dense::from_csr(&a.to_csr());
        let _ = d.cholesky(); // panics if not SPD
    }
}
