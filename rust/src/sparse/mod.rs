//! Sparse-matrix substrate: storage formats, conversions, I/O and synthetic
//! generators.
//!
//! REAP consumes matrices in the standard formats (the paper stresses that
//! keeping CSR/CSC/COO as the external interface aids portability and data
//! curation); everything downstream — RIR encoding, the CPU baselines, the
//! FPGA simulator — is built on the types here.
//!
//! * [`coo::Coo`] — coordinate triplets (assembly / I/O format).
//! * [`csr::Csr`] — compressed sparse row (the SpGEMM input format).
//! * [`csc::Csc`] — compressed sparse column (the Cholesky input format).
//! * [`dense::Dense`] — small dense matrices, used only as test oracles.
//! * [`mm`] — Matrix Market (.mtx) read/write, for external matrices.
//! * [`gen`] — deterministic synthetic generators standing in for the
//!   SuiteSparse collection (see DESIGN.md §6 Substitutions).

pub mod coo;
pub mod csc;
pub mod csr;
pub mod dense;
pub mod gen;
pub mod mm;
pub mod ops;

pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use dense::Dense;

/// Index type used throughout. `u32` halves memory traffic vs `usize` on the
/// hot paths (matching the 4-byte indices the paper's FPGA streams) while
/// still covering every matrix in the evaluation suite.
pub type Idx = u32;

/// Scalar type: single precision, matching the paper's FPGA DSP blocks
/// (the Arria-10 IP has no double-precision FP units).
pub type Val = f32;
