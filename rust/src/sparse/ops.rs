//! Structural operations shared by the generators and the Cholesky path:
//! symmetrization, SPD construction, pattern utilities.

use super::{Coo, Csc, Csr, Val};

/// Symmetrize a pattern: `B = A + A^T` (values summed where both exist).
pub fn symmetrize(a: &Csr) -> Csr {
    assert_eq!(a.nrows, a.ncols, "symmetrize needs a square matrix");
    let t = a.transpose();
    add(a, &t)
}

/// Sparse add `A + B` (same shape), merging sorted rows.
pub fn add(a: &Csr, b: &Csr) -> Csr {
    assert_eq!((a.nrows, a.ncols), (b.nrows, b.ncols));
    let mut row_ptr = vec![0usize; a.nrows + 1];
    let mut cols = Vec::with_capacity(a.nnz() + b.nnz());
    let mut vals = Vec::with_capacity(a.nnz() + b.nnz());
    for i in 0..a.nrows {
        let (ac, av) = (a.row_cols(i), a.row_vals(i));
        let (bc, bv) = (b.row_cols(i), b.row_vals(i));
        let (mut p, mut q) = (0usize, 0usize);
        while p < ac.len() || q < bc.len() {
            match (ac.get(p), bc.get(q)) {
                (Some(&ca), Some(&cb)) if ca == cb => {
                    cols.push(ca);
                    vals.push(av[p] + bv[q]);
                    p += 1;
                    q += 1;
                }
                (Some(&ca), Some(&cb)) if ca < cb => {
                    cols.push(ca);
                    vals.push(av[p]);
                    p += 1;
                }
                (Some(_), Some(_)) | (None, Some(_)) => {
                    cols.push(bc[q]);
                    vals.push(bv[q]);
                    q += 1;
                }
                (Some(&ca), None) => {
                    cols.push(ca);
                    vals.push(av[p]);
                    p += 1;
                }
                (None, None) => unreachable!(),
            }
        }
        row_ptr[i + 1] = cols.len();
    }
    Csr { nrows: a.nrows, ncols: a.ncols, row_ptr, cols, vals }
}

/// Make a symmetric positive-definite matrix from an arbitrary square
/// pattern: `S = (A + A^T)/2` scaled to unit off-diagonal magnitude, then a
/// diagonal shift making it strictly diagonally dominant (hence SPD).
///
/// This mirrors how SPD test problems are conventionally manufactured and
/// preserves the sparsity pattern, which is what drives both CHOLMOD's and
/// REAP's behaviour.
pub fn make_spd(a: &Csr) -> Csc {
    assert_eq!(a.nrows, a.ncols);
    let sym = symmetrize(a);
    let n = sym.nrows;
    // Row sums of |off-diagonal| for the dominance shift.
    let mut coo = Coo::new(n, n);
    let mut absum = vec![0f64; n];
    for i in 0..n {
        for (c, v) in sym.row_cols(i).iter().zip(sym.row_vals(i)) {
            let j = *c as usize;
            if j != i {
                // clamp magnitudes so the shift stays modest
                let w = (*v).clamp(-1.0, 1.0);
                let w = if w == 0.0 { 0.5 } else { w };
                coo.push(i, j, w);
                absum[i] += w.abs() as f64;
            }
        }
    }
    for i in 0..n {
        coo.push(i, i, (absum[i] + 1.0) as Val);
    }
    coo.to_csr().to_csc()
}

/// Drop entries with |v| <= tol (pattern pruning used by tests).
pub fn drop_tol(a: &Csr, tol: Val) -> Csr {
    let mut row_ptr = vec![0usize; a.nrows + 1];
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for i in 0..a.nrows {
        for (c, v) in a.row_cols(i).iter().zip(a.row_vals(i)) {
            if v.abs() > tol {
                cols.push(*c);
                vals.push(*v);
            }
        }
        row_ptr[i + 1] = cols.len();
    }
    Csr { nrows: a.nrows, ncols: a.ncols, row_ptr, cols, vals }
}

/// Is the matrix structurally and numerically symmetric (within tol)?
pub fn is_symmetric(a: &Csr, tol: Val) -> bool {
    if a.nrows != a.ncols {
        return false;
    }
    let t = a.transpose();
    a.frob_diff(&t) <= tol as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Dense;

    fn asym() -> Csr {
        Dense::from_rows(3, 3, &[0.0, 2.0, 0.0, 0.0, 1.0, 0.0, 4.0, 0.0, 0.0]).to_csr()
    }

    #[test]
    fn add_matches_dense() {
        let a = asym();
        let b = a.transpose();
        let s = add(&a, &b);
        let expect = Dense::from_rows(3, 3, &[0.0, 2.0, 4.0, 2.0, 2.0, 0.0, 4.0, 0.0, 0.0]);
        assert!(Dense::from_csr(&s).max_abs_diff(&expect) < 1e-6);
    }

    #[test]
    fn symmetrize_is_symmetric() {
        let s = symmetrize(&asym());
        assert!(is_symmetric(&s, 0.0));
    }

    #[test]
    fn make_spd_factorizes() {
        let spd = make_spd(&asym());
        let d = Dense::from_csr(&spd.to_csr());
        let l = d.cholesky(); // panics if not SPD
        // L L^T == A
        let mut lt = Dense::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                lt[(i, j)] = l[(j, i)];
            }
        }
        assert!(l.matmul(&lt).max_abs_diff(&d) < 1e-4);
    }

    #[test]
    fn drop_tol_prunes() {
        let a = Dense::from_rows(2, 2, &[0.5, 0.0, 0.05, 2.0]).to_csr();
        let p = drop_tol(&a, 0.1);
        assert_eq!(p.nnz(), 2);
        assert_eq!(p.get(1, 0), 0.0);
    }

    #[test]
    fn is_symmetric_negative_case() {
        assert!(!is_symmetric(&asym(), 1e-9));
    }
}
