//! Small dense matrices — test oracles only (never on a hot path).
//!
//! The unit/property tests check every sparse kernel against the
//! corresponding dense computation on small instances; this module is that
//! dense side.

use super::{Csr, Val};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    pub nrows: usize,
    pub ncols: usize,
    pub data: Vec<Val>,
}

impl Dense {
    /// Zero matrix.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Dense { nrows, ncols, data: vec![0.0; nrows * ncols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Dense::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From a row-major slice.
    pub fn from_rows(nrows: usize, ncols: usize, data: &[Val]) -> Self {
        assert_eq!(data.len(), nrows * ncols);
        Dense { nrows, ncols, data: data.to_vec() }
    }

    /// Dense × dense (naive; oracle only).
    pub fn matmul(&self, other: &Dense) -> Dense {
        assert_eq!(self.ncols, other.nrows);
        let mut out = Dense::zeros(self.nrows, other.ncols);
        for i in 0..self.nrows {
            for k in 0..self.ncols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.ncols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Dense Cholesky (lower), f64 accumulation; panics on non-SPD.
    pub fn cholesky(&self) -> Dense {
        assert_eq!(self.nrows, self.ncols);
        let n = self.nrows;
        let mut l = vec![0f64; n * n];
        for j in 0..n {
            let mut d = self[(j, j)] as f64;
            for k in 0..j {
                d -= l[j * n + k] * l[j * n + k];
            }
            assert!(d > 0.0, "matrix not positive definite at column {j} (d={d})");
            let djj = d.sqrt();
            l[j * n + j] = djj;
            for i in (j + 1)..n {
                let mut s = self[(i, j)] as f64;
                for k in 0..j {
                    s -= l[i * n + k] * l[j * n + k];
                }
                l[i * n + j] = s / djj;
            }
        }
        Dense { nrows: n, ncols: n, data: l.into_iter().map(|x| x as Val).collect() }
    }

    /// Convert to CSR, dropping exact zeros.
    pub fn to_csr(&self) -> Csr {
        let mut row_ptr = vec![0usize; self.nrows + 1];
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..self.nrows {
            for j in 0..self.ncols {
                let v = self[(i, j)];
                if v != 0.0 {
                    cols.push(j as super::Idx);
                    vals.push(v);
                }
            }
            row_ptr[i + 1] = cols.len();
        }
        Csr { nrows: self.nrows, ncols: self.ncols, row_ptr, cols, vals }
    }

    /// From CSR (densify).
    pub fn from_csr(m: &Csr) -> Dense {
        let mut out = Dense::zeros(m.nrows, m.ncols);
        for i in 0..m.nrows {
            for (c, v) in m.row_cols(i).iter().zip(m.row_vals(i)) {
                out[(i, *c as usize)] = *v;
            }
        }
        out
    }

    /// Max absolute elementwise difference.
    pub fn max_abs_diff(&self, other: &Dense) -> f64 {
        assert_eq!((self.nrows, self.ncols), (other.nrows, other.ncols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max)
    }

    /// Matrix–vector product (oracle for triangular-solve tests).
    pub fn matvec(&self, x: &[Val]) -> Vec<Val> {
        assert_eq!(x.len(), self.ncols);
        (0..self.nrows)
            .map(|i| {
                (0..self.ncols)
                    .map(|j| (self[(i, j)] as f64) * (x[j] as f64))
                    .sum::<f64>() as Val
            })
            .collect()
    }
}

impl std::ops::Index<(usize, usize)> for Dense {
    type Output = Val;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &Val {
        &self.data[i * self.ncols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Dense {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut Val {
        &mut self.data[i * self.ncols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Dense::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.matmul(&Dense::eye(2)), a);
        assert_eq!(Dense::eye(2).matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Dense::from_rows(2, 3, &[1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
        let b = Dense::from_rows(3, 2, &[1.0, 2.0, 0.0, 1.0, 4.0, 0.0]);
        let c = a.matmul(&b);
        assert_eq!(c, Dense::from_rows(2, 2, &[9.0, 2.0, 0.0, 3.0]));
    }

    #[test]
    fn cholesky_recovers_known_factor() {
        // L = [[2,0],[1,3]]; A = L L^T = [[4,2],[2,10]]
        let a = Dense::from_rows(2, 2, &[4.0, 2.0, 2.0, 10.0]);
        let l = a.cholesky();
        assert!(l.max_abs_diff(&Dense::from_rows(2, 2, &[2.0, 0.0, 1.0, 3.0])) < 1e-6);
    }

    #[test]
    #[should_panic(expected = "positive definite")]
    fn cholesky_rejects_indefinite() {
        Dense::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]).cholesky();
    }

    #[test]
    fn csr_roundtrip() {
        let a = Dense::from_rows(2, 3, &[1.0, 0.0, 2.0, 0.0, 0.0, 3.0]);
        let csr = a.to_csr();
        assert_eq!(csr.nnz(), 3);
        assert_eq!(Dense::from_csr(&csr), a);
    }

    #[test]
    fn matvec_known() {
        let a = Dense::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }
}
