//! Compressed Sparse Column — the Cholesky-side format (CHOLMOD's native
//! layout; the paper's Fig 2(b) shows its RIR translation).

use anyhow::{ensure, Result};

use super::{Csr, Idx, Val};

/// CSC matrix: `col_ptr[j]..col_ptr[j+1]` indexes the (sorted) row/value
/// pairs of column `j`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Csc {
    pub nrows: usize,
    pub ncols: usize,
    pub col_ptr: Vec<usize>,
    pub rows: Vec<Idx>,
    pub vals: Vec<Val>,
}

impl Csc {
    /// Empty matrix.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Csc { nrows, ncols, col_ptr: vec![0; ncols + 1], rows: Vec::new(), vals: Vec::new() }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Row indices of column `j`.
    #[inline]
    pub fn col_rows(&self, j: usize) -> &[Idx] {
        &self.rows[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// Values of column `j`.
    #[inline]
    pub fn col_vals(&self, j: usize) -> &[Val] {
        &self.vals[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// Number of nonzeros in column `j`.
    #[inline]
    pub fn col_nnz(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// Element lookup by binary search within the column.
    pub fn get(&self, i: usize, j: usize) -> Val {
        match self.col_rows(j).binary_search(&(i as Idx)) {
            Ok(k) => self.col_vals(j)[k],
            Err(_) => 0.0,
        }
    }

    /// Validate invariants (mirror of [`Csr::validate`]).
    pub fn validate(&self) -> Result<()> {
        ensure!(self.col_ptr.len() == self.ncols + 1, "col_ptr length");
        ensure!(self.col_ptr[0] == 0, "col_ptr[0] != 0");
        ensure!(*self.col_ptr.last().unwrap() == self.rows.len(), "col_ptr end");
        ensure!(self.rows.len() == self.vals.len(), "rows/vals length mismatch");
        for j in 0..self.ncols {
            ensure!(self.col_ptr[j] <= self.col_ptr[j + 1], "col_ptr not monotone at {j}");
            let rows = self.col_rows(j);
            for w in rows.windows(2) {
                ensure!(w[0] < w[1], "column {j} rows not strictly ascending");
            }
            if let Some(&last) = rows.last() {
                ensure!((last as usize) < self.nrows, "column {j} row out of bounds");
            }
        }
        Ok(())
    }

    /// Convert to CSR (counting-sort transpose of the storage).
    pub fn to_csr(&self) -> Csr {
        let nnz = self.nnz();
        let mut row_ptr = vec![0usize; self.nrows + 1];
        for &r in &self.rows {
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..self.nrows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut cols = vec![0 as Idx; nnz];
        let mut vals = vec![0 as Val; nnz];
        let mut next = row_ptr.clone();
        for j in 0..self.ncols {
            for (r, v) in self.col_rows(j).iter().zip(self.col_vals(j)) {
                let dst = next[*r as usize];
                cols[dst] = j as Idx;
                vals[dst] = *v;
                next[*r as usize] += 1;
            }
        }
        Csr { nrows: self.nrows, ncols: self.ncols, row_ptr, cols, vals }
    }

    /// The strictly-lower-triangular part including the diagonal, as CSC
    /// (what sparse Cholesky factorizations store for SPD inputs).
    pub fn lower_triangle(&self) -> Csc {
        let mut out = Csc::new(self.nrows, self.ncols);
        let mut col_ptr = vec![0usize; self.ncols + 1];
        for j in 0..self.ncols {
            for &r in self.col_rows(j) {
                if r as usize >= j {
                    col_ptr[j + 1] += 1;
                }
            }
        }
        for j in 0..self.ncols {
            col_ptr[j + 1] += col_ptr[j];
        }
        let nnz = col_ptr[self.ncols];
        let mut rows = vec![0 as Idx; nnz];
        let mut vals = vec![0 as Val; nnz];
        let mut k = 0usize;
        for j in 0..self.ncols {
            for (r, v) in self.col_rows(j).iter().zip(self.col_vals(j)) {
                if *r as usize >= j {
                    rows[k] = *r;
                    vals[k] = *v;
                    k += 1;
                }
            }
        }
        out.col_ptr = col_ptr;
        out.rows = rows;
        out.vals = vals;
        out
    }

    /// Diagonal entries (0 where structurally absent).
    pub fn diagonal(&self) -> Vec<Val> {
        (0..self.ncols.min(self.nrows)).map(|j| self.get(j, j)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csc {
        // [4 1 0]
        // [1 5 2]
        // [0 2 6]   (symmetric, SPD-ish)
        let csr = Csr::from_parts(
            3,
            3,
            vec![0, 2, 5, 7],
            vec![0, 1, 0, 1, 2, 1, 2],
            vec![4.0, 1.0, 1.0, 5.0, 2.0, 2.0, 6.0],
        )
        .unwrap();
        csr.to_csc()
    }

    #[test]
    fn accessors() {
        let m = sample();
        assert_eq!(m.nnz(), 7);
        assert_eq!(m.col_nnz(1), 3);
        assert_eq!(m.col_rows(0), &[0, 1]);
        assert_eq!(m.get(2, 1), 2.0);
        assert_eq!(m.get(0, 2), 0.0);
        assert_eq!(m.diagonal(), vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn csr_roundtrip() {
        let m = sample();
        assert_eq!(m.to_csr().to_csc(), m);
    }

    #[test]
    fn lower_triangle_keeps_diag_and_below() {
        let m = sample();
        let l = m.lower_triangle();
        assert_eq!(l.nnz(), 5); // 3 diag + 2 below
        assert_eq!(l.get(1, 0), 1.0);
        assert_eq!(l.get(0, 1), 0.0);
        assert_eq!(l.get(1, 1), 5.0);
        l.validate().unwrap();
    }

    #[test]
    fn validate_rejects_unsorted_rows() {
        let m = Csc {
            nrows: 3,
            ncols: 1,
            col_ptr: vec![0, 2],
            rows: vec![2, 0],
            vals: vec![1.0, 1.0],
        };
        assert!(m.validate().is_err());
    }

    #[test]
    fn empty_column_handling() {
        let m = Csc::new(4, 4);
        m.validate().unwrap();
        assert_eq!(m.col_nnz(2), 0);
        assert_eq!(m.to_csr().nnz(), 0);
    }
}
