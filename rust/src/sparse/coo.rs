//! Coordinate (triplet) format — the assembly and interchange format.

use anyhow::{ensure, Result};

use super::{Csr, Idx, Val};

/// A sparse matrix as unordered `(row, col, value)` triplets.
///
/// Duplicates are allowed at assembly time and are summed on conversion to
/// CSR (the standard finite-element assembly semantics, same as
/// `scipy.sparse.coo_matrix` and CHOLMOD's triplet form).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<Idx>,
    pub cols: Vec<Idx>,
    pub vals: Vec<Val>,
}

impl Coo {
    /// Empty matrix of the given shape.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo { nrows, ncols, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    /// Number of stored triplets (including duplicates and explicit zeros).
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Append one triplet.
    pub fn push(&mut self, r: usize, c: usize, v: Val) {
        debug_assert!(r < self.nrows && c < self.ncols, "({r},{c}) out of bounds");
        self.rows.push(r as Idx);
        self.cols.push(c as Idx);
        self.vals.push(v);
    }

    /// Validate structural invariants (bounds, parallel array lengths).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.rows.len() == self.cols.len() && self.cols.len() == self.vals.len(),
            "triplet arrays disagree: {} rows, {} cols, {} vals",
            self.rows.len(),
            self.cols.len(),
            self.vals.len()
        );
        for (&r, &c) in self.rows.iter().zip(&self.cols) {
            ensure!(
                (r as usize) < self.nrows && (c as usize) < self.ncols,
                "triplet ({r},{c}) out of bounds for {}x{}",
                self.nrows,
                self.ncols
            );
        }
        Ok(())
    }

    /// Convert to CSR, summing duplicate coordinates.
    ///
    /// Two-pass counting sort: O(nnz + nrows), no comparison sort involved —
    /// this is the same strategy CHOLMOD/ SuiteSparse use for triplet→CSC.
    pub fn to_csr(&self) -> Csr {
        let nnz = self.nnz();
        // Pass 1: row counts -> row_ptr.
        let mut row_ptr = vec![0usize; self.nrows + 1];
        for &r in &self.rows {
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..self.nrows {
            row_ptr[i + 1] += row_ptr[i];
        }
        // Pass 2: scatter into place.
        let mut cols = vec![0 as Idx; nnz];
        let mut vals = vec![0 as Val; nnz];
        let mut next = row_ptr.clone();
        for i in 0..nnz {
            let r = self.rows[i] as usize;
            let dst = next[r];
            cols[dst] = self.cols[i];
            vals[dst] = self.vals[i];
            next[r] += 1;
        }
        let mut csr = Csr { nrows: self.nrows, ncols: self.ncols, row_ptr, cols, vals };
        csr.sort_rows_and_sum_duplicates();
        csr
    }

    /// Transpose (swap row/col arrays; O(1) plus clone).
    pub fn transpose(&self) -> Coo {
        Coo {
            nrows: self.ncols,
            ncols: self.nrows,
            rows: self.cols.clone(),
            cols: self.rows.clone(),
            vals: self.vals.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_to_csr() {
        let coo = Coo::new(3, 4);
        let csr = coo.to_csr();
        assert_eq!(csr.nrows, 3);
        assert_eq!(csr.ncols, 4);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.row_ptr, vec![0, 0, 0, 0]);
    }

    #[test]
    fn duplicates_are_summed() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 1, 2.0);
        coo.push(0, 1, 3.0);
        coo.push(1, 0, 1.0);
        let csr = coo.to_csr();
        assert_eq!(csr.nnz(), 2);
        assert_eq!(csr.get(0, 1), 5.0);
        assert_eq!(csr.get(1, 0), 1.0);
        assert_eq!(csr.get(0, 0), 0.0);
    }

    #[test]
    fn unsorted_input_sorted_output() {
        let mut coo = Coo::new(1, 5);
        for &c in &[4usize, 0, 3, 1] {
            coo.push(0, c, c as Val);
        }
        let csr = coo.to_csr();
        assert_eq!(csr.cols, vec![0, 1, 3, 4]);
        assert_eq!(csr.vals, vec![0.0, 1.0, 3.0, 4.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut coo = Coo::new(2, 3);
        coo.push(0, 2, 7.0);
        coo.push(1, 0, -1.0);
        let t = coo.transpose();
        assert_eq!(t.nrows, 3);
        assert_eq!(t.ncols, 2);
        assert_eq!(t.transpose(), coo);
    }

    #[test]
    fn validate_catches_out_of_bounds() {
        let mut coo = Coo::new(2, 2);
        coo.rows.push(5);
        coo.cols.push(0);
        coo.vals.push(1.0);
        assert!(coo.validate().is_err());
    }

    #[test]
    fn validate_catches_length_mismatch() {
        let mut coo = Coo::new(2, 2);
        coo.rows.push(0);
        assert!(coo.validate().is_err());
    }
}
