//! Compressed Sparse Row — the primary input format for SpGEMM (the format
//! whose indirection pattern the paper's Fig 2/3 walks through).

use anyhow::{ensure, Result};

use super::{Coo, Csc, Idx, Val};

/// CSR matrix: `row_ptr[i]..row_ptr[i+1]` indexes the (sorted) column/value
/// pairs of row `i`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    pub row_ptr: Vec<usize>,
    pub cols: Vec<Idx>,
    pub vals: Vec<Val>,
}

impl Csr {
    /// Empty matrix.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Csr { nrows, ncols, row_ptr: vec![0; nrows + 1], cols: Vec::new(), vals: Vec::new() }
    }

    /// Build directly from parts (validated).
    pub fn from_parts(
        nrows: usize,
        ncols: usize,
        row_ptr: Vec<usize>,
        cols: Vec<Idx>,
        vals: Vec<Val>,
    ) -> Result<Self> {
        let m = Csr { nrows, ncols, row_ptr, cols, vals };
        m.validate()?;
        Ok(m)
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Density = nnz / (nrows*ncols); 0 for degenerate shapes.
    pub fn density(&self) -> f64 {
        if self.nrows == 0 || self.ncols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.nrows as f64 * self.ncols as f64)
    }

    /// Column indices of row `i`.
    #[inline]
    pub fn row_cols(&self, i: usize) -> &[Idx] {
        &self.cols[self.row_ptr[i]..self.row_ptr[i + 1]]
    }

    /// Values of row `i`.
    #[inline]
    pub fn row_vals(&self, i: usize) -> &[Val] {
        &self.vals[self.row_ptr[i]..self.row_ptr[i + 1]]
    }

    /// Number of nonzeros in row `i`.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.row_ptr[i + 1] - self.row_ptr[i]
    }

    /// Element lookup by binary search (the indirection chain from the
    /// paper's §II: row_ptr → col scan → value). O(log nnz(row)).
    pub fn get(&self, i: usize, j: usize) -> Val {
        let cols = self.row_cols(i);
        match cols.binary_search(&(j as Idx)) {
            Ok(k) => self.row_vals(i)[k],
            Err(_) => 0.0,
        }
    }

    /// Validate invariants: monotone `row_ptr`, in-bounds sorted strict
    /// columns per row, parallel array lengths.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.row_ptr.len() == self.nrows + 1, "row_ptr length");
        ensure!(self.row_ptr[0] == 0, "row_ptr[0] != 0");
        ensure!(*self.row_ptr.last().unwrap() == self.cols.len(), "row_ptr end");
        ensure!(self.cols.len() == self.vals.len(), "cols/vals length mismatch");
        // check the pointer array fully before any slicing
        for i in 0..self.nrows {
            ensure!(self.row_ptr[i] <= self.row_ptr[i + 1], "row_ptr not monotone at {i}");
            ensure!(self.row_ptr[i + 1] <= self.cols.len(), "row_ptr[{}] exceeds nnz", i + 1);
        }
        for i in 0..self.nrows {
            let cols = self.row_cols(i);
            for w in cols.windows(2) {
                ensure!(w[0] < w[1], "row {i} columns not strictly ascending");
            }
            if let Some(&last) = cols.last() {
                ensure!((last as usize) < self.ncols, "row {i} column out of bounds");
            }
        }
        Ok(())
    }

    /// Sort each row by column and sum duplicate columns, in place.
    /// Used by the COO conversion; idempotent on valid matrices.
    pub(crate) fn sort_rows_and_sum_duplicates(&mut self) {
        let mut new_cols = Vec::with_capacity(self.cols.len());
        let mut new_vals = Vec::with_capacity(self.vals.len());
        let mut new_ptr = vec![0usize; self.nrows + 1];
        let mut scratch: Vec<(Idx, Val)> = Vec::new();
        for i in 0..self.nrows {
            scratch.clear();
            scratch.extend(
                self.row_cols(i)
                    .iter()
                    .copied()
                    .zip(self.row_vals(i).iter().copied()),
            );
            scratch.sort_unstable_by_key(|&(c, _)| c);
            let mut k = 0;
            while k < scratch.len() {
                let (c, mut v) = scratch[k];
                let mut j = k + 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                new_cols.push(c);
                new_vals.push(v);
                k = j;
            }
            new_ptr[i + 1] = new_cols.len();
        }
        self.cols = new_cols;
        self.vals = new_vals;
        self.row_ptr = new_ptr;
    }

    /// Convert to COO triplets.
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.nrows, self.ncols);
        for i in 0..self.nrows {
            for (c, v) in self.row_cols(i).iter().zip(self.row_vals(i)) {
                coo.rows.push(i as Idx);
                coo.cols.push(*c);
                coo.vals.push(*v);
            }
        }
        coo
    }

    /// Convert to CSC (counting-sort transpose of the storage; O(nnz + n)).
    pub fn to_csc(&self) -> Csc {
        let nnz = self.nnz();
        let mut col_ptr = vec![0usize; self.ncols + 1];
        for &c in &self.cols {
            col_ptr[c as usize + 1] += 1;
        }
        for j in 0..self.ncols {
            col_ptr[j + 1] += col_ptr[j];
        }
        let mut rows = vec![0 as Idx; nnz];
        let mut vals = vec![0 as Val; nnz];
        let mut next = col_ptr.clone();
        for i in 0..self.nrows {
            for (c, v) in self.row_cols(i).iter().zip(self.row_vals(i)) {
                let dst = next[*c as usize];
                rows[dst] = i as Idx;
                vals[dst] = *v;
                next[*c as usize] += 1;
            }
        }
        Csc { nrows: self.nrows, ncols: self.ncols, col_ptr, rows, vals }
    }

    /// Transpose via CSC reinterpretation.
    pub fn transpose(&self) -> Csr {
        let csc = self.to_csc();
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            row_ptr: csc.col_ptr,
            cols: csc.rows,
            vals: csc.vals,
        }
    }

    /// Maximum row nnz (drives RIR bundle splitting and sim occupancy).
    pub fn max_row_nnz(&self) -> usize {
        (0..self.nrows).map(|i| self.row_nnz(i)).max().unwrap_or(0)
    }

    /// Frobenius-norm difference vs another matrix of the same shape
    /// (test/verification helper; tolerates different sparsity patterns).
    pub fn frob_diff(&self, other: &Csr) -> f64 {
        assert_eq!((self.nrows, self.ncols), (other.nrows, other.ncols));
        let mut acc = 0f64;
        for i in 0..self.nrows {
            // merge-walk the two sorted rows
            let (ac, av) = (self.row_cols(i), self.row_vals(i));
            let (bc, bv) = (other.row_cols(i), other.row_vals(i));
            let (mut p, mut q) = (0usize, 0usize);
            while p < ac.len() || q < bc.len() {
                let d = match (ac.get(p), bc.get(q)) {
                    (Some(&ca), Some(&cb)) if ca == cb => {
                        let d = (av[p] - bv[q]) as f64;
                        p += 1;
                        q += 1;
                        d
                    }
                    (Some(&ca), Some(&cb)) if ca < cb => {
                        let d = av[p] as f64;
                        p += 1;
                        d
                    }
                    (Some(_), Some(_)) | (None, Some(_)) => {
                        let d = bv[q] as f64;
                        q += 1;
                        d
                    }
                    (Some(_), None) => {
                        let d = av[p] as f64;
                        p += 1;
                        d
                    }
                    (None, None) => unreachable!(),
                };
                acc += d * d;
            }
        }
        acc.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [1 0 2]
        // [0 0 0]
        // [3 4 0]
        Csr::from_parts(3, 3, vec![0, 2, 2, 4], vec![0, 2, 0, 1], vec![1.0, 2.0, 3.0, 4.0])
            .unwrap()
    }

    #[test]
    fn accessors() {
        let m = sample();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(1), 0);
        assert_eq!(m.row_cols(2), &[0, 1]);
        assert_eq!(m.get(0, 2), 2.0);
        assert_eq!(m.get(1, 1), 0.0);
        assert!((m.density() - 4.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn csc_roundtrip() {
        let m = sample();
        let csc = m.to_csc();
        assert_eq!(csc.col_ptr, vec![0, 2, 3, 4]);
        let back = csc.to_csr();
        assert_eq!(back, m);
    }

    #[test]
    fn transpose_twice_is_identity() {
        let m = sample();
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_moves_entries() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.get(2, 0), 2.0);
        assert_eq!(t.get(1, 2), 4.0);
    }

    #[test]
    fn validate_rejects_unsorted() {
        let m = Csr {
            nrows: 1,
            ncols: 3,
            row_ptr: vec![0, 2],
            cols: vec![2, 0],
            vals: vec![1.0, 1.0],
        };
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_ptr() {
        let m = Csr { nrows: 2, ncols: 2, row_ptr: vec![0, 3, 1], cols: vec![0], vals: vec![1.0] };
        assert!(m.validate().is_err());
    }

    #[test]
    fn frob_diff_zero_on_equal_and_positive_on_diff() {
        let m = sample();
        assert_eq!(m.frob_diff(&m), 0.0);
        let mut n = m.clone();
        n.vals[0] += 3.0;
        assert!((m.frob_diff(&n) - 3.0).abs() < 1e-6);
        // different patterns
        let z = Csr::new(3, 3);
        let total: f64 = m.vals.iter().map(|&v| (v as f64) * (v as f64)).sum();
        assert!((m.frob_diff(&z) - total.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn max_row_nnz_works() {
        assert_eq!(sample().max_row_nnz(), 2);
        assert_eq!(Csr::new(2, 2).max_row_nnz(), 0);
    }
}
