//! CPU/FPGA overlap accounting (paper §V-A).
//!
//! "REAP overlaps the reformatting on the CPU and the computation on the
//! FPGA after the initial round. In the initial round, the FPGA is idle
//! while CPU reformats the data. Figure 6 shows the overall time taking
//! into account both the CPU and the FPGA time."
//!
//! With the CPU pass costing `t_cpu` spread over `rounds` scheduling
//! rounds and the FPGA costing `t_fpga`, the end-to-end time is the first
//! (unoverlapped) CPU round plus the longer of the remaining CPU work and
//! the FPGA work.

/// End-to-end REAP time under round-granular overlap.
pub fn overlapped_total(t_cpu: f64, t_fpga: f64, rounds: u64) -> f64 {
    let rounds = rounds.max(1) as f64;
    let first = t_cpu / rounds;
    first + (t_cpu - first).max(t_fpga)
}

/// Fraction of the (non-overlapped) work attributable to the CPU —
/// the quantity plotted in Figs 7 and 11 ("the sum of the two should add
/// up to 100%").
pub fn cpu_fraction(t_cpu: f64, t_fpga: f64) -> f64 {
    if t_cpu + t_fpga == 0.0 {
        return 0.0;
    }
    t_cpu / (t_cpu + t_fpga)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fpga_dominated_hides_cpu() {
        // huge FPGA time: total = first CPU round + FPGA
        let t = overlapped_total(1.0, 100.0, 10);
        assert!((t - 100.1).abs() < 1e-9);
    }

    #[test]
    fn cpu_dominated_is_cpu_time() {
        let t = overlapped_total(100.0, 1.0, 10);
        assert!((t - 100.0).abs() < 1e-9);
    }

    #[test]
    fn single_round_is_serial() {
        let t = overlapped_total(2.0, 3.0, 1);
        assert!((t - 5.0).abs() < 1e-9);
    }

    #[test]
    fn bounded_by_serial_and_by_max() {
        for &(c, f, r) in &[(1.0, 2.0, 4u64), (5.0, 0.5, 16), (0.0, 1.0, 2)] {
            let t = overlapped_total(c, f, r);
            assert!(t <= c + f + 1e-12, "never worse than serial");
            assert!(t >= c.max(f) - 1e-12, "never better than the max");
        }
    }

    #[test]
    fn cpu_fraction_bounds() {
        assert_eq!(cpu_fraction(0.0, 0.0), 0.0);
        assert!((cpu_fraction(1.0, 3.0) - 0.25).abs() < 1e-12);
        assert_eq!(cpu_fraction(2.0, 0.0), 1.0);
    }
}
