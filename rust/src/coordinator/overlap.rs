//! CPU/FPGA overlap accounting (paper §V-A).
//!
//! "REAP overlaps the reformatting on the CPU and the computation on the
//! FPGA after the initial round. In the initial round, the FPGA is idle
//! while CPU reformats the data. Figure 6 shows the overall time taking
//! into account both the CPU and the FPGA time."
//!
//! Two models live here:
//!
//! * [`pipelined_total`] — the per-wave double-buffered pipeline the
//!   coordinators use: wave *k*'s CPU scheduling overlaps wave *k−1*'s
//!   FPGA compute, driven by measured per-wave CPU costs and simulated
//!   per-wave FPGA times (EXPERIMENTS.md §Perf).
//! * [`overlapped_total`] — the legacy scalar approximation (total CPU
//!   time amortized over `rounds` equal rounds), kept for sensitivity
//!   studies that have no per-wave trace.
//!
//! **Equal-length trace contract:** every coordinator hands
//! [`pipelined_total`] exactly one CPU cost and one FPGA cost per wave —
//! two non-empty traces of different lengths mean mis-wired
//! instrumentation. Under debug assertions (and therefore in every test
//! build) the skew is a hard error; release builds compute a well-defined
//! result and log a warning (`tests/integration_batch.rs` and
//! `tests/integration_spmm.rs` pin the contract for all five
//! coordinators). Coordinators that replay waves with no new CPU work
//! (SpMM's later column blocks) pad the CPU side with zeros to keep the
//! traces aligned.

/// End-to-end time of the per-wave CPU→FPGA pipeline.
///
/// The CPU produces waves in order (`cpu_wave_s[k]` each); the FPGA starts
/// wave *k* once the CPU has finished producing it **and** the FPGA has
/// finished wave *k−1* (double buffering: one wave in flight on each side).
/// Equivalently:
///
/// ```text
/// cpu_done[k]  = cpu_done[k-1] + cpu_wave_s[k]
/// fpga_done[k] = max(fpga_done[k-1], cpu_done[k]) + fpga_wave_s[k]
/// total        = fpga_done[last]
/// ```
///
/// Boundary behavior, all exercised in the unit tests:
/// * no waves at all → `0.0` (the caller adds any serial prologue);
/// * a one-sided trace (the other empty) is a pure CPU-only or FPGA-only
///   phase and is accepted silently;
/// * two *non-empty* traces of different lengths mean a coordinator
///   mis-wired its per-wave instrumentation — every coordinator produces
///   one CPU cost and one FPGA cost per wave. Under debug assertions
///   (so in every `cargo test` run) this is a **hard error**: a trace
///   contract violation must fail the test that produced it, not scroll
///   past as a log line. Release builds keep computing (the shorter side
///   contributes zero for its missing waves) and log a warning so an
///   aggregate production run completes;
/// * a single wave degenerates to the serial sum `c₀ + f₀`;
/// * all-zero CPU costs degenerate to the FPGA total (and vice versa).
///
/// The result is bounded below by `max(Σcpu, Σfpga)` and above by
/// `Σcpu + Σfpga`.
pub fn pipelined_total(cpu_wave_s: &[f64], fpga_wave_s: &[f64]) -> f64 {
    if cpu_wave_s.len() != fpga_wave_s.len()
        && !cpu_wave_s.is_empty()
        && !fpga_wave_s.is_empty()
    {
        let msg = format!(
            "pipelined_total: mismatched wave traces (cpu {} vs fpga {}) — \
             a coordinator is mis-wiring its per-wave instrumentation",
            cpu_wave_s.len(),
            fpga_wave_s.len()
        );
        if cfg!(debug_assertions) {
            panic!("{msg}");
        }
        eprintln!("warning: {msg}");
    }
    let n = cpu_wave_s.len().max(fpga_wave_s.len());
    let mut cpu_done = 0.0f64;
    let mut fpga_done = 0.0f64;
    for k in 0..n {
        cpu_done += cpu_wave_s.get(k).copied().unwrap_or(0.0);
        let f = fpga_wave_s.get(k).copied().unwrap_or(0.0);
        fpga_done = fpga_done.max(cpu_done) + f;
    }
    fpga_done
}

/// End-to-end REAP time under round-granular overlap (legacy scalar model).
///
/// `t_cpu` is spread over `rounds` equal rounds; the first round cannot
/// overlap, the remainder races the FPGA. Conventions at the boundaries:
/// `rounds == 0` is treated as `rounds == 1` (there is always at least the
/// initial, unoverlapped round), so 0 and 1 intentionally coincide;
/// `t_cpu == 0` yields exactly `t_fpga` (nothing to overlap); both zero
/// yields `0`.
pub fn overlapped_total(t_cpu: f64, t_fpga: f64, rounds: u64) -> f64 {
    let rounds = rounds.max(1) as f64;
    let first = t_cpu / rounds;
    first + (t_cpu - first).max(t_fpga)
}

/// Fraction of the (non-overlapped) work attributable to the CPU —
/// the quantity plotted in Figs 7 and 11 ("the sum of the two should add
/// up to 100%").
pub fn cpu_fraction(t_cpu: f64, t_fpga: f64) -> f64 {
    if t_cpu + t_fpga == 0.0 {
        return 0.0;
    }
    t_cpu / (t_cpu + t_fpga)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fpga_dominated_hides_cpu() {
        // huge FPGA time: total = first CPU round + FPGA
        let t = overlapped_total(1.0, 100.0, 10);
        assert!((t - 100.1).abs() < 1e-9);
    }

    #[test]
    fn cpu_dominated_is_cpu_time() {
        let t = overlapped_total(100.0, 1.0, 10);
        assert!((t - 100.0).abs() < 1e-9);
    }

    #[test]
    fn single_round_is_serial() {
        let t = overlapped_total(2.0, 3.0, 1);
        assert!((t - 5.0).abs() < 1e-9);
    }

    #[test]
    fn zero_rounds_treated_as_one() {
        assert_eq!(overlapped_total(2.0, 3.0, 0), overlapped_total(2.0, 3.0, 1));
    }

    #[test]
    fn zero_cpu_is_fpga_only() {
        assert_eq!(overlapped_total(0.0, 3.0, 4), 3.0);
        assert_eq!(overlapped_total(0.0, 3.0, 0), 3.0);
        assert_eq!(overlapped_total(0.0, 0.0, 7), 0.0);
    }

    #[test]
    fn bounded_by_serial_and_by_max() {
        for &(c, f, r) in &[(1.0, 2.0, 4u64), (5.0, 0.5, 16), (0.0, 1.0, 2)] {
            let t = overlapped_total(c, f, r);
            assert!(t <= c + f + 1e-12, "never worse than serial");
            assert!(t >= c.max(f) - 1e-12, "never better than the max");
        }
    }

    #[test]
    fn cpu_fraction_bounds() {
        assert_eq!(cpu_fraction(0.0, 0.0), 0.0);
        assert!((cpu_fraction(1.0, 3.0) - 0.25).abs() < 1e-12);
        assert_eq!(cpu_fraction(2.0, 0.0), 1.0);
    }

    // ---- per-wave pipeline ----

    #[test]
    fn empty_schedule_costs_nothing() {
        assert_eq!(pipelined_total(&[], &[]), 0.0);
    }

    #[test]
    fn single_wave_is_serial() {
        assert!((pipelined_total(&[2.0], &[3.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn fpga_dominated_pays_only_first_cpu_wave() {
        // CPU waves hide entirely behind the (longer) FPGA waves after the
        // first: total = c0 + sum(f)
        let c = [0.1, 0.1, 0.1, 0.1];
        let f = [1.0, 1.0, 1.0, 1.0];
        assert!((pipelined_total(&c, &f) - 4.1).abs() < 1e-12);
    }

    #[test]
    fn cpu_dominated_pays_only_last_fpga_wave() {
        // FPGA waves hide behind CPU production: total = sum(c) + f_last
        let c = [1.0, 1.0, 1.0];
        let f = [0.2, 0.2, 0.2];
        assert!((pipelined_total(&c, &f) - 3.2).abs() < 1e-12);
    }

    #[test]
    fn one_sided_traces_are_phases_not_skew() {
        // degenerate one-sided traces are legitimate CPU-only/FPGA-only
        // phases and never trip the trace contract
        assert_eq!(pipelined_total(&[], &[2.0, 3.0]), 5.0);
        assert_eq!(pipelined_total(&[2.0, 3.0], &[]), 5.0);
    }

    #[test]
    #[should_panic(expected = "mismatched wave traces")]
    fn mismatched_fpga_longer_is_a_hard_error_in_debug() {
        let _ = pipelined_total(&[1.0], &[0.5, 0.5, 0.5]);
    }

    #[test]
    #[should_panic(expected = "mismatched wave traces")]
    fn mismatched_cpu_longer_is_a_hard_error_in_debug() {
        let _ = pipelined_total(&[1.0, 1.0], &[0.1]);
    }

    #[test]
    fn bounded_by_sums() {
        let cases: &[(&[f64], &[f64])] = &[
            (&[1.0, 0.5, 2.0], &[0.7, 0.7, 0.7]),
            (&[0.0, 0.0], &[1.0, 1.0]),
            (&[3.0], &[0.0]),
            (&[0.2, 0.9, 0.1, 0.4], &[0.5, 0.1, 0.8, 0.2]),
        ];
        for (c, f) in cases {
            let t = pipelined_total(c, f);
            let (sc, sf) = (c.iter().sum::<f64>(), f.iter().sum::<f64>());
            assert!(t <= sc + sf + 1e-12, "≤ serial: {t} vs {sc}+{sf}");
            assert!(t >= sc.max(sf) - 1e-12, "≥ max side: {t}");
        }
    }

    #[test]
    fn pipelining_beats_the_scalar_model_on_skewed_waves() {
        // one huge FPGA wave first: the scalar model can only amortize,
        // the per-wave pipeline hides all later CPU work behind it
        let c = [0.1, 0.4, 0.4, 0.4];
        let f = [2.0, 0.01, 0.01, 0.01];
        let per_wave = pipelined_total(&c, &f);
        let scalar = overlapped_total(c.iter().sum(), f.iter().sum(), c.len() as u64);
        assert!(per_wave <= scalar + 1e-12);
    }
}
