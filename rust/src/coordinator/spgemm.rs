//! REAP SpGEMM orchestration (paper §III-A).
//!
//! The coordinator runs the CPU pass (RIR scheduling, timed), obtains the
//! numeric result by streaming the schedule through the bundle datapath —
//! either the AOT XLA artifact or the bit-equivalent in-process path — and
//! obtains the FPGA timing from the cycle simulator. The two execution
//! modes follow the *same* bundle/wave order, so they produce identical
//! floating-point results wherever tiles don't reorder additions.

use anyhow::{Context, Result};

use crate::fpga::engine::execute_waves_at_depth;
use crate::fpga::spgemm_sim::{simulate_spgemm, Style};
use crate::fpga::{FpgaConfig, SimStats};
use crate::kernels::spgemm_parallel::{flop_balanced_ranges, stitch_bands, Band, SpaScratch};
use crate::rir::schedule::{schedule_spgemm, SpgemmSchedule};
use crate::runtime::{SpgemmWaveIo, XlaRuntime};
use crate::sparse::{Csr, Idx, Val};
use crate::util::{grains, preprocess_threads};

use super::overlap::pipelined_total;
use super::ExecMode;

/// SpGEMM coordinator for one FPGA design point.
pub struct ReapSpgemm<'rt> {
    pub cfg: FpgaConfig,
    pub mode: ExecMode,
    pub runtime: Option<&'rt XlaRuntime>,
    /// Run the static audits ([`crate::analysis`]) on this run's schedule
    /// and wave costs even in release builds, failing with a typed
    /// [`crate::analysis::AnalysisError`]. Debug builds always audit.
    pub strict: bool,
}

/// Outcome of one REAP SpGEMM execution.
#[derive(Clone, Debug)]
pub struct ReapSpgemmReport {
    /// The product C = A × B.
    pub c: Csr,
    /// Measured CPU preprocessing (RIR scheduling) seconds — the
    /// chunk-enumeration prologue plus every wave's scheduling cost.
    pub cpu_preprocess_s: f64,
    /// Simulated FPGA statistics (at the configured
    /// [`FpgaConfig::dram_buffer_depth`]).
    pub fpga_sim: SimStats,
    /// The same run re-executed on the serial depth-1 channel (the
    /// pre-refactor baseline) — reported side by side in `BENCH_*.json`.
    pub fpga_sim_serial: SimStats,
    /// The same run on the double-buffered depth-2 channel (wave *k+1*'s
    /// stream prefetches under wave *k*'s compute).
    pub fpga_sim_db: SimStats,
    /// Simulated FPGA seconds at the design's clock.
    pub fpga_s: f64,
    /// End-to-end seconds under per-wave double-buffered CPU/FPGA
    /// pipelining: wave *k*'s CPU scheduling overlaps wave *k−1*'s FPGA
    /// compute (paper §V-A), driven by measured per-wave CPU timestamps
    /// and simulated per-wave FPGA cycles.
    pub total_s: f64,
    /// The negotiated stream encoding the simulation priced
    /// ([`FpgaConfig::encoding`], e.g. `"raw"` or `"bitmap+fx32"`).
    pub encoding: String,
}

impl<'rt> ReapSpgemm<'rt> {
    /// Coordinator with the in-process numeric path.
    pub fn new(cfg: FpgaConfig) -> Self {
        ReapSpgemm { cfg, mode: ExecMode::Rust, runtime: None, strict: false }
    }

    /// Coordinator executing numerics through the XLA artifacts.
    pub fn with_runtime(cfg: FpgaConfig, rt: &'rt XlaRuntime) -> Self {
        ReapSpgemm { cfg, mode: ExecMode::Xla, runtime: Some(rt), strict: false }
    }

    /// Enable (or disable) release-build static audits for this run.
    pub fn strict(mut self, on: bool) -> Self {
        self.strict = on;
        self
    }

    /// True when this run audits its artifacts (always in debug builds).
    fn audits(&self) -> bool {
        cfg!(debug_assertions) || self.strict
    }

    /// Run the full REAP flow for `C = A × B`.
    pub fn run(&self, a: &Csr, b: &Csr) -> Result<ReapSpgemmReport> {
        self.cfg.validate()?;
        // ---- CPU pass (measured, per-wave timestamps) ----
        let schedule = schedule_spgemm(a, b, self.cfg.pipelines, self.cfg.bundle_size);
        if self.audits() {
            let diags = crate::analysis::audit_spgemm_schedule(a, b, &schedule);
            crate::analysis::ensure_clean(diags)?;
        }
        let cpu_preprocess_s = schedule.cpu_total_s();

        // ---- numeric result via the scheduled bundle dataflow ----
        let c = match self.mode {
            ExecMode::Rust => numeric_scheduled(a, b, &schedule, preprocess_threads()),
            ExecMode::Xla => {
                let rt = self.runtime.context("XLA mode requires a runtime")?;
                numeric_xla(a, b, &schedule, rt)?
            }
        };

        // ---- FPGA timing from the cycle model ----
        let sim = simulate_spgemm(a, b, &schedule, &self.cfg, Style::HandCoded);
        if self.audits() {
            let diags = crate::analysis::audit_wave_costs(&sim.costs, &self.cfg);
            crate::analysis::ensure_clean(diags)?;
        }
        let fpga_s = sim.stats.seconds(&self.cfg);

        // ---- per-wave pipelined overlap: the enumeration prologue is
        // serial, then wave k's CPU scheduling hides behind wave k-1's
        // FPGA compute ----
        let hz = self.cfg.hz();
        let fpga_wave_s: Vec<f64> = sim.wave_cycles.iter().map(|&cy| cy as f64 / hz).collect();
        let total_s =
            schedule.prep_cpu_s + pipelined_total(&schedule.wave_cpu_s, &fpga_wave_s);

        // serial vs double-buffered channel, from the same cost sequence
        // (reusing the primary stats when the configured depth matches)
        let depth_stats = |d: usize| {
            if self.cfg.dram_buffer_depth == d {
                sim.stats.clone()
            } else {
                execute_waves_at_depth(&sim.costs, &self.cfg, d).stats
            }
        };
        let fpga_sim_serial = depth_stats(1);
        let fpga_sim_db = depth_stats(2);

        Ok(ReapSpgemmReport {
            c,
            cpu_preprocess_s,
            fpga_sim: sim.stats,
            fpga_sim_serial,
            fpga_sim_db,
            fpga_s,
            total_s,
            encoding: self.cfg.encoding.to_string(),
        })
    }
}

/// In-process numeric path: identical wave/chunk/stream ordering to the
/// hardware dataflow (and to the XLA path), accumulated with stamped SPAs.
///
/// Parallelized over A-row grains claimed through the deterministic
/// work-stealing executor ([`crate::util::grains`]): a row's chunks
/// appear in schedule order within its grain, so each grain performs
/// exactly the serial path's FP operations for its rows, and the
/// grain-ordered band stitch makes the output **bit-identical** to the
/// serial path for every thread count and grain size (property-tested in
/// `tests/prop_invariants.rs`).
pub fn numeric_scheduled(a: &Csr, b: &Csr, schedule: &SpgemmSchedule, nthreads: usize) -> Csr {
    let nthreads = nthreads.max(1);
    numeric_scheduled_with_grain(a, b, schedule, nthreads, grains::default_grain(a.nrows, nthreads))
}

/// [`numeric_scheduled`] with an explicit row-grain size (the grain-size
/// invariance knob for the property suite).
pub fn numeric_scheduled_with_grain(
    a: &Csr,
    b: &Csr,
    schedule: &SpgemmSchedule,
    nthreads: usize,
    grain: usize,
) -> Csr {
    let nthreads = nthreads.max(1);
    if nthreads == 1 || a.nrows < 2 * nthreads {
        let mut scratch = SpaScratch::new();
        scratch.ensure(b.ncols);
        // a full-range band's row_ptr is already global — no stitch needed
        let band = numeric_band(a, b, schedule, 0, a.nrows, &mut scratch);
        return Csr {
            nrows: a.nrows,
            ncols: b.ncols,
            row_ptr: band.row_ptr,
            cols: band.cols,
            vals: band.vals,
        };
    }

    let n_grains = grains::grain_count(a.nrows, grain);
    let bands: Vec<Band> = grains::run_grains_with(
        a.nrows,
        grain,
        nthreads,
        || {
            let mut s = SpaScratch::new();
            s.ensure(b.ncols);
            s
        },
        |scratch, _g, lo, hi| numeric_band(a, b, schedule, lo, hi, scratch),
    );
    let bounds: Vec<usize> =
        (0..=n_grains).map(|g| (g * grain).min(a.nrows)).collect();
    stitch_bands(a.nrows, b.ncols, &bounds, bands)
}

/// Static flop-balanced predecessor of [`numeric_scheduled`]: one
/// contiguous row band per worker, no stealing. Kept callable for the
/// `reap bench scaling` side-by-side; output is bit-identical.
pub fn numeric_scheduled_static_bands(
    a: &Csr,
    b: &Csr,
    schedule: &SpgemmSchedule,
    nthreads: usize,
) -> Csr {
    let nthreads = nthreads.max(1);
    if nthreads == 1 || a.nrows < 2 * nthreads {
        return numeric_scheduled_with_grain(a, b, schedule, 1, a.nrows.max(1));
    }

    let bounds = flop_balanced_ranges(a, b, nthreads);
    let nbands = bounds.len() - 1;
    let mut scratches: Vec<SpaScratch> = (0..nbands)
        .map(|_| {
            let mut s = SpaScratch::new();
            s.ensure(b.ncols);
            s
        })
        .collect();

    let bands: Vec<Band> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nbands);
        for (w, scratch) in scratches.iter_mut().enumerate() {
            let (lo, hi) = (bounds[w], bounds[w + 1]);
            let a_ref = &*a;
            let b_ref = &*b;
            handles.push(
                scope.spawn(move || numeric_band(a_ref, b_ref, schedule, lo, hi, scratch)),
            );
        }
        handles.into_iter().map(|h| h.join().expect("numeric worker panicked")).collect()
    });

    stitch_bands(a.nrows, b.ncols, &bounds, bands)
}

/// Compute output rows `[lo, hi)` by replaying the schedule's assignments
/// that fall in the band, in schedule order.
fn numeric_band(
    a: &Csr,
    b: &Csr,
    schedule: &SpgemmSchedule,
    lo: usize,
    hi: usize,
    scratch: &mut SpaScratch,
) -> Band {
    let mut row_ptr = vec![0usize; hi - lo + 1];
    let mut cols: Vec<Idx> = Vec::new();
    let mut vals: Vec<Val> = Vec::new();
    let mut in_row = false;
    let mut last_done = 0usize; // band-local rows < this are final

    for wave in &schedule.waves {
        // chunks are enumerated in ascending row order, so a wave's rows
        // are an ascending run — skip whole waves outside the band rather
        // than filtering assignment by assignment (keeps per-worker scan
        // cost near O(waves + own band) instead of O(total chunks))
        match (wave.assignments.first(), wave.assignments.last()) {
            (Some(first), Some(last))
                if (last.a_row as usize) < lo || (first.a_row as usize) >= hi =>
            {
                continue;
            }
            (None, _) => continue,
            _ => {}
        }
        for asg in &wave.assignments {
            let row = asg.a_row as usize;
            if row < lo || row >= hi {
                continue;
            }
            if !in_row {
                scratch.begin_row();
                in_row = true;
            }
            for (&ca, &va) in asg.a_cols(a).iter().zip(asg.a_vals(a)) {
                let r = ca as usize;
                for (&cb, &vb) in b.row_cols(r).iter().zip(b.row_vals(r)) {
                    scratch.add(cb, va * vb);
                }
            }
            if asg.last_chunk {
                // drain the merged row (the merge unit's sorted emission)
                scratch.drain_row(&mut cols, &mut vals);
                let li = row - lo;
                // empty rows between the previous emitted row and this one
                for rr in last_done..=li {
                    row_ptr[rr + 1] = if rr == li { cols.len() } else { row_ptr[rr] };
                }
                row_ptr[li + 1] = cols.len();
                last_done = li + 1;
                in_row = false;
            }
        }
    }
    for rr in last_done..hi - lo {
        row_ptr[rr + 1] = row_ptr[rr];
    }
    Band { row_ptr, cols, vals }
}

/// XLA numeric path: stream the same schedule through the AOT
/// `spgemm_bundle` artifact, tiling the output column space.
fn numeric_xla(a: &Csr, b: &Csr, schedule: &SpgemmSchedule, rt: &XlaRuntime) -> Result<Csr> {
    let mut io = SpgemmWaveIo::new(rt)?;
    let tile_w = io.tile_w;
    let bundle = io.bundle;

    let mut row_ptr = vec![0usize; a.nrows + 1];
    let mut cols: Vec<Idx> = Vec::new();
    let mut vals: Vec<Val> = Vec::new();

    // per-row accumulation over active tiles
    let ntiles = b.ncols.div_ceil(tile_w).max(1);
    let mut tile_acc: Vec<Vec<f32>> = Vec::new(); // parallel to active_tiles
    let mut active_tiles: Vec<usize> = Vec::new();
    let mut tile_stamp = vec![u32::MAX; ntiles];
    let mut tick = 0u32;
    let mut last_done_row = 0usize;

    for wave in &schedule.waves {
        for asg in &wave.assignments {
            // discover tiles this chunk touches
            for &ca in asg.a_cols(a) {
                for &cb in b.row_cols(ca as usize) {
                    let tile = cb as usize / tile_w;
                    if tile_stamp[tile] != tick {
                        tile_stamp[tile] = tick;
                        active_tiles.push(tile);
                        tile_acc.push(vec![0.0; tile_w]);
                    }
                }
            }
            // B rows of this chunk may exceed one bundle: process chunk
            // pairs; slot i carries the ci-th sub-chunk of its B row
            let max_chunks = asg
                .a_cols(a)
                .iter()
                .map(|&c| b.row_nnz(c as usize).div_ceil(bundle).max(1))
                .max()
                .unwrap_or(1);
            for (t_idx, &tile) in active_tiles.iter().enumerate() {
                let tile_start = (tile * tile_w) as u32;
                io.clear();
                let mut staged: usize = 0;
                for ci in 0..max_chunks {
                    let mut b_rows: Vec<(&[Idx], &[Val])> = Vec::with_capacity(asg.len);
                    for &ca in asg.a_cols(a) {
                        let r = ca as usize;
                        let bc = b.row_cols(r);
                        let bv = b.row_vals(r);
                        let lo = (ci * bundle).min(bc.len());
                        let hi = ((ci + 1) * bundle).min(bc.len());
                        b_rows.push((&bc[lo..hi], &bv[lo..hi]));
                    }
                    io.push_step(tile_start, asg.a_vals(a), &b_rows)?;
                    staged += 1;
                    if io.is_full() || ci + 1 == max_chunks {
                        let outs = io.execute(rt)?;
                        debug_assert_eq!(outs.len(), staged);
                        for out in &outs {
                            for (w, &v) in out.iter().enumerate() {
                                tile_acc[t_idx][w] += v;
                            }
                        }
                        io.clear();
                        staged = 0;
                    }
                }
            }
            if asg.last_chunk {
                // drain the row: ascending tiles, ascending offsets
                let mut order: Vec<usize> = (0..active_tiles.len()).collect();
                order.sort_unstable_by_key(|&i| active_tiles[i]);
                for i in order {
                    let base = active_tiles[i] * tile_w;
                    for (w, &v) in tile_acc[i].iter().enumerate() {
                        let col = base + w;
                        if v != 0.0 && col < b.ncols {
                            cols.push(col as Idx);
                            vals.push(v);
                        }
                    }
                }
                let row = asg.a_row as usize;
                for rr in last_done_row..=row {
                    row_ptr[rr + 1] = if rr == row { cols.len() } else { row_ptr[rr] };
                }
                row_ptr[row + 1] = cols.len();
                last_done_row = row + 1;
                active_tiles.clear();
                tile_acc.clear();
                tick = tick.wrapping_add(1);
            }
        }
    }
    for rr in last_done_row..a.nrows {
        row_ptr[rr + 1] = row_ptr[rr];
    }
    Ok(Csr { nrows: a.nrows, ncols: b.ncols, row_ptr, cols, vals })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::spgemm;
    use crate::sparse::gen;

    #[test]
    fn rust_mode_matches_baseline_exactly() {
        for seed in 0..4u64 {
            let a = gen::power_law(80, 1200, seed);
            let b = gen::random_uniform(80, 80, 900, seed + 10);
            let coord = ReapSpgemm::new(FpgaConfig::reap32_spgemm());
            let rep = coord.run(&a, &b).unwrap();
            rep.c.validate().unwrap();
            let expect = spgemm(&a, &b);
            assert_eq!(rep.c, expect, "seed {seed}");
            assert!(rep.fpga_s > 0.0);
            assert!(rep.total_s >= rep.fpga_s);
        }
    }

    #[test]
    fn handles_empty_and_singleton_rows() {
        // row 0 empty, row 1 singleton
        let mut a = Csr::new(3, 3);
        a.row_ptr = vec![0, 0, 1, 1];
        a.cols = vec![2];
        a.vals = vec![5.0];
        let b = gen::random_uniform(3, 3, 6, 1);
        let coord = ReapSpgemm::new(FpgaConfig::reap32_spgemm());
        let rep = coord.run(&a, &b).unwrap();
        assert_eq!(rep.c, spgemm(&a, &b));
    }

    #[test]
    fn big_rows_split_across_waves_still_correct() {
        // 100-nnz rows with bundle 32 -> 4 chunks per row
        let a = gen::random_uniform(6, 300, 600, 2);
        let b = gen::random_uniform(300, 50, 3000, 3);
        let coord = ReapSpgemm::new(FpgaConfig::reap32_spgemm());
        let rep = coord.run(&a, &b).unwrap();
        assert_eq!(rep.c, spgemm(&a, &b));
    }

    #[test]
    fn report_times_are_consistent() {
        let a = gen::banded_fem(100, 900, 4);
        let coord = ReapSpgemm::new(FpgaConfig::reap32_spgemm());
        let rep = coord.run(&a, &a).unwrap();
        assert!(rep.cpu_preprocess_s >= 0.0);
        let serial = rep.cpu_preprocess_s + rep.fpga_s;
        assert!(rep.total_s <= serial + 1e-9);
        assert!(rep.total_s >= rep.cpu_preprocess_s.max(rep.fpga_s) - 1e-9);
    }

    #[test]
    fn rejects_invalid_config() {
        let a = gen::random_uniform(20, 20, 60, 1);
        for bad in [
            FpgaConfig { pipelines: 0, ..FpgaConfig::reap32_spgemm() },
            FpgaConfig { vector_lanes: 0, ..FpgaConfig::reap32_spgemm() },
            FpgaConfig { dram_buffer_depth: 0, ..FpgaConfig::reap32_spgemm() },
        ] {
            assert!(ReapSpgemm::new(bad).run(&a, &a).is_err());
        }
    }

    #[test]
    fn report_carries_serial_and_double_buffered_stats() {
        let a = gen::power_law(200, 3600, 9);
        let rep = ReapSpgemm::new(FpgaConfig::reap64_spgemm()).run(&a, &a).unwrap();
        // the default depth is 1, so the primary stats ARE the serial ones
        assert_eq!(rep.fpga_sim, rep.fpga_sim_serial);
        assert_eq!(rep.fpga_sim_serial.prefetch_hidden_cycles, 0);
        // double buffering hides the per-wave CAM setup on this multi-wave
        // run: strictly fewer cycles, identical traffic
        assert!(rep.fpga_sim_db.cycles < rep.fpga_sim_serial.cycles);
        assert!(rep.fpga_sim_db.prefetch_hidden_cycles > 0);
        assert_eq!(
            rep.fpga_sim_db.cycles + rep.fpga_sim_db.prefetch_hidden_cycles,
            rep.fpga_sim_serial.cycles
        );
        assert_eq!(rep.fpga_sim_db.bytes_read, rep.fpga_sim_serial.bytes_read);
        assert_eq!(rep.fpga_sim_db.bytes_written, rep.fpga_sim_serial.bytes_written);
        // running the coordinator AT depth 2 makes the prefetch primary
        let cfg2 = FpgaConfig { dram_buffer_depth: 2, ..FpgaConfig::reap64_spgemm() };
        let rep2 = ReapSpgemm::new(cfg2).run(&a, &a).unwrap();
        assert_eq!(rep2.fpga_sim, rep.fpga_sim_db);
    }

    #[test]
    fn parallel_numeric_bit_identical_to_serial() {
        use crate::rir::schedule::schedule_spgemm_with_threads;
        for seed in 0..3u64 {
            let a = gen::power_law(150, 3000, seed);
            let b = gen::random_uniform(150, 150, 2200, seed + 20);
            let s = schedule_spgemm_with_threads(&a, &b, 32, 32, 1);
            let serial = numeric_scheduled(&a, &b, &s, 1);
            for t in [2usize, 4, 8] {
                assert_eq!(numeric_scheduled(&a, &b, &s, t), serial, "threads={t}");
                assert_eq!(
                    numeric_scheduled_static_bands(&a, &b, &s, t),
                    serial,
                    "static threads={t}"
                );
                for grain in [1usize, 4, 1 << 20] {
                    assert_eq!(
                        numeric_scheduled_with_grain(&a, &b, &s, t, grain),
                        serial,
                        "threads={t} grain={grain}"
                    );
                }
            }
            assert_eq!(serial, spgemm(&a, &b), "seed {seed}");
        }
    }
}
