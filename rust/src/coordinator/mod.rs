//! The REAP coordinator — Layer 3, the paper's CPU role plus overall
//! orchestration.
//!
//! For each kernel the coordinator runs the full synergistic flow:
//!
//! 1. **CPU pass** (measured wall-clock): RIR encoding + scheduling for
//!    SpGEMM ([`spgemm`]), symbolic analysis + RL metadata for Cholesky
//!    ([`cholesky`]);
//! 2. **FPGA pass**: the numeric result — through the AOT XLA artifacts
//!    ([`ExecMode::Xla`], request path identical to the paper's FPGA
//!    dataflow) or the bit-equivalent in-process path ([`ExecMode::Rust`],
//!    used for large benchmark sweeps) — and the *timing* from the cycle
//!    simulator;
//! 3. **overlap accounting** ([`overlap`]): per-wave double-buffered
//!    pipelining — wave *k*'s CPU reformatting overlaps wave *k−1*'s FPGA
//!    compute, from measured per-wave CPU timestamps and simulated
//!    per-wave FPGA cycles;
//! 4. **verification** ([`verify`]): results checked against the measured
//!    CPU baselines.
//!
//! The multi-tenant path ([`batch`]) runs the same flow over N
//! independent SpGEMM jobs packed into shared, job-tagged waves — the
//! many-small-jobs shape of production traffic. The multi-vector path
//! ([`spmm`]) amortizes one SpMV wave schedule over `k` dense right-hand
//! sides, replaying it once per column block of the design's vector
//! lanes.
//!
//! Every coordinator obeys the same per-wave trace contract: it hands
//! [`overlap::pipelined_total`] one measured CPU cost and one simulated
//! FPGA cost **per wave**, equal-length traces (pinned in
//! `tests/integration_batch.rs`; see `ARCHITECTURE.md` §"Simulator
//! contracts").

pub mod batch;
pub mod cholesky;
pub mod overlap;
pub mod spgemm;
pub mod spmm;
pub mod spmv;
pub mod verify;

pub use batch::{ReapBatch, ReapBatchReport};
pub use cholesky::{ReapCholesky, ReapCholeskyReport};
pub use spgemm::{ReapSpgemm, ReapSpgemmReport};
pub use spmm::{ReapSpmm, ReapSpmmReport};
pub use spmv::{ReapSpmv, ReapSpmvReport};

/// How the numeric phase executes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Bit-equivalent in-process arithmetic ordered exactly like the
    /// bundle dataflow (default for large sweeps; the simulator still
    /// provides the FPGA timing).
    #[default]
    Rust,
    /// Execute the AOT-compiled XLA artifacts via PJRT — the full
    /// three-layer request path.
    Xla,
}
