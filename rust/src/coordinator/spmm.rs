//! REAP SpMM orchestration — sparse × dense multi-vector through the
//! synergistic flow, amortizing **one** CPU scheduling pass over all `k`
//! right-hand-side columns.
//!
//! The CPU builds the SpMV wave schedule once (measured, per wave); the
//! FPGA replays it once per column block of [`FpgaConfig::vector_lanes`]
//! columns ([`crate::fpga::spmm_sim`]). Only the first replay races the
//! CPU's wave production — every later block's waves pipeline against a
//! zero CPU cost, which is exactly how the per-wave overlap trace is
//! constructed (padded with zeros to the simulator's block-major trace
//! length, preserving the equal-length trace contract of
//! [`super::overlap::pipelined_total`]).

use anyhow::{ensure, Result};

use crate::fpga::engine::execute_waves_at_depth;
use crate::fpga::spgemm_sim::Style;
use crate::fpga::spmm_sim::simulate_spmm;
use crate::fpga::{FpgaConfig, SimStats};
use crate::rir::schedule::{schedule_spgemm, SpgemmSchedule};
use crate::sparse::{Csr, Val};
use crate::util::preprocess_threads;

use super::overlap::pipelined_total;

/// SpMM coordinator for one FPGA design point (in-process numerics; the
/// XLA request path remains per-vector through [`super::ReapSpmv`]).
///
/// ```
/// use reap::coordinator::ReapSpmm;
/// use reap::fpga::FpgaConfig;
/// use reap::sparse::gen;
///
/// let a = gen::random_uniform(32, 32, 200, 7);
/// let k = 4;
/// let x: Vec<f32> = (0..a.ncols * k).map(|i| (i % 5) as f32 - 2.0).collect();
/// let rep = ReapSpmm::new(FpgaConfig::reap64_spgemm()).run(&a, &x, k).unwrap();
/// // every column is bit-identical to an independent SpMV
/// for j in 0..k {
///     let xj: Vec<f32> = (0..a.ncols).map(|r| x[r * k + j]).collect();
///     let yj = reap::kernels::spmv(&a, &xj);
///     for i in 0..a.nrows {
///         assert_eq!(rep.c[i * k + j], yj[i]);
///     }
/// }
/// ```
pub struct ReapSpmm {
    pub cfg: FpgaConfig,
    /// Run the static audits ([`crate::analysis`]) on this run's schedule
    /// and wave costs even in release builds, failing with a typed
    /// [`crate::analysis::AnalysisError`]. Debug builds always audit.
    pub strict: bool,
}

/// Outcome of one REAP SpMM execution.
#[derive(Clone, Debug)]
pub struct ReapSpmmReport {
    /// Row-major `a.nrows × k` dense result — column `j` is bit-identical
    /// to [`crate::kernels::spmv::spmv`] with column `j` of X.
    pub c: Vec<Val>,
    /// Right-hand-side column count.
    pub k: usize,
    /// Column blocks the FPGA replayed the schedule for.
    pub n_blocks: usize,
    /// Measured CPU preprocessing seconds — spent **once**, not per block.
    pub cpu_preprocess_s: f64,
    /// Simulated FPGA statistics (at the configured channel depth).
    pub fpga_sim: SimStats,
    /// The same run on the serial depth-1 channel.
    pub fpga_sim_serial: SimStats,
    /// The same run on the double-buffered depth-2 channel (block *b+1*'s
    /// dense-panel load prefetches under block *b*'s compute).
    pub fpga_sim_db: SimStats,
    pub fpga_s: f64,
    pub total_s: f64,
    /// The negotiated stream encoding the simulation priced
    /// ([`FpgaConfig::encoding`]).
    pub encoding: String,
}

impl ReapSpmm {
    pub fn new(cfg: FpgaConfig) -> Self {
        ReapSpmm { cfg, strict: false }
    }

    /// Enable (or disable) release-build static audits for this run.
    pub fn strict(mut self, on: bool) -> Self {
        self.strict = on;
        self
    }

    /// True when this run audits its artifacts (always in debug builds).
    fn audits(&self) -> bool {
        cfg!(debug_assertions) || self.strict
    }

    /// Run `C = A X` where `x` is row-major `a.ncols × k`.
    pub fn run(&self, a: &Csr, x: &[Val], k: usize) -> Result<ReapSpmmReport> {
        self.cfg.validate()?;
        ensure!(x.len() == a.ncols * k, "X panel shape mismatch");
        ensure!(k > 0, "SpMM needs at least one right-hand-side column");

        // CPU pass, once: the SpMV chunk schedule (empty B surrogate — the
        // panel lives on-chip per block)
        let b_surrogate = Csr::new(a.ncols, a.ncols);
        let schedule = schedule_spgemm(a, &b_surrogate, self.cfg.pipelines, self.cfg.bundle_size);
        if self.audits() {
            let diags = crate::analysis::audit_spgemm_schedule(a, &b_surrogate, &schedule);
            crate::analysis::ensure_clean(diags)?;
        }
        let cpu_preprocess_s = schedule.cpu_total_s();

        let c = numeric_spmm(a, x, k, &schedule, preprocess_threads());

        let sim = simulate_spmm(a, &schedule, &self.cfg, Style::HandCoded, k);
        if self.audits() {
            let diags = crate::analysis::audit_wave_costs(&sim.costs, &self.cfg);
            crate::analysis::ensure_clean(diags)?;
        }
        let fpga_s = sim.stats.seconds(&self.cfg);

        // per-wave pipelining: the CPU produces each wave once (block 0);
        // replays for blocks 1.. cost the CPU nothing, so their trace
        // entries are zero. Panel loads and the chunk-enumeration prologue
        // serialize ahead of the wave pipeline.
        let hz = self.cfg.hz();
        let fpga_wave_s: Vec<f64> = sim.wave_cycles.iter().map(|&cy| cy as f64 / hz).collect();
        let mut cpu_wave_s = Vec::with_capacity(fpga_wave_s.len());
        cpu_wave_s.extend_from_slice(&schedule.wave_cpu_s);
        cpu_wave_s.resize(fpga_wave_s.len(), 0.0);
        let total_s = schedule.prep_cpu_s
            + sim.panel_load_cycles as f64 / hz
            + pipelined_total(&cpu_wave_s, &fpga_wave_s);

        let depth_stats = |d: usize| {
            if self.cfg.dram_buffer_depth == d {
                sim.stats.clone()
            } else {
                execute_waves_at_depth(&sim.costs, &self.cfg, d).stats
            }
        };
        let fpga_sim_serial = depth_stats(1);
        let fpga_sim_db = depth_stats(2);

        Ok(ReapSpmmReport {
            c,
            k,
            n_blocks: sim.n_blocks,
            cpu_preprocess_s,
            fpga_sim: sim.stats,
            fpga_sim_serial,
            fpga_sim_db,
            fpga_s,
            total_s,
            encoding: self.cfg.encoding.to_string(),
        })
    }
}

/// Execute the SpMM numerics by replaying the schedule once per column
/// block, in chunk order — per column this performs exactly the
/// floating-point sequence of the SpMV coordinator's in-process path
/// (f64 accumulation over the row's elements in CSR order), so every
/// column is bit-identical to an independent SpMV for every thread count
/// and block width.
///
/// Column blocks are the work items: grains of whole blocks are claimed
/// through the deterministic work-stealing executor
/// ([`crate::util::grains`]); each worker fills block-major buffers it
/// owns, and the (cheap, deterministic) scatter into the row-major
/// result happens after the join in grain order — blocks write disjoint
/// column ranges, so the result is identical to the serial path for
/// every thread count and grain size. The block width is
/// [`FpgaConfig::vector_lanes`]-agnostic here — any width yields the
/// same bits.
pub fn numeric_spmm(
    a: &Csr,
    x: &[Val],
    k: usize,
    schedule: &SpgemmSchedule,
    nthreads: usize,
) -> Vec<Val> {
    // one column block per grain: blocks are few and uniform enough that
    // finer grains would only add claim traffic
    numeric_spmm_with_grain(a, x, k, schedule, nthreads, 1)
}

/// [`numeric_spmm`] with an explicit block-grain size (the grain-size
/// invariance knob for the property suite).
pub fn numeric_spmm_with_grain(
    a: &Csr,
    x: &[Val],
    k: usize,
    schedule: &SpgemmSchedule,
    nthreads: usize,
    grain: usize,
) -> Vec<Val> {
    assert_eq!(x.len(), a.ncols * k, "X panel shape mismatch");
    if k == 0 {
        return Vec::new();
    }
    let block = crate::kernels::spmm::DEFAULT_COL_BLOCK.min(k);
    let n_blocks = k.div_ceil(block);
    let mut c = vec![0 as Val; a.nrows * k];

    let nthreads = nthreads.clamp(1, n_blocks);
    if nthreads <= 1 || n_blocks < 2 {
        let mut buf = vec![0 as Val; a.nrows * block];
        for blk in 0..n_blocks {
            let j0 = blk * block;
            let j1 = (j0 + block).min(k);
            numeric_block(a, x, k, schedule, j0, j1, &mut buf);
            scatter_block(&buf, k, j0, j1, &mut c);
        }
        return c;
    }

    let grain_outs: Vec<Vec<(usize, usize, Vec<Val>)>> = crate::util::grains::run_grains(
        n_blocks,
        grain,
        nthreads,
        |_g, b_lo, b_hi| {
            let mut outs = Vec::with_capacity(b_hi - b_lo);
            for blk in b_lo..b_hi {
                let j0 = blk * block;
                let j1 = (j0 + block).min(k);
                let mut buf = vec![0 as Val; a.nrows * block];
                numeric_block(a, x, k, schedule, j0, j1, &mut buf);
                outs.push((j0, j1, buf));
            }
            outs
        },
    );
    for (j0, j1, buf) in grain_outs.into_iter().flatten() {
        scatter_block(&buf, k, j0, j1, &mut c);
    }
    c
}

/// Replay the schedule for columns `[j0, j1)` of the panel into a
/// block-major buffer (`buf[i * block_stride + t]` is row `i`, block lane
/// `t`; the stride is `buf.len() / a.nrows`, fixed by the caller).
fn numeric_block(
    a: &Csr,
    x: &[Val],
    k: usize,
    schedule: &SpgemmSchedule,
    j0: usize,
    j1: usize,
    buf: &mut [Val],
) {
    let kb = j1 - j0;
    let stride = if a.nrows == 0 { kb.max(1) } else { buf.len() / a.nrows };
    let mut acc = vec![0f64; kb];
    for wave in &schedule.waves {
        for asg in &wave.assignments {
            for (&col, &v) in asg.a_cols(a).iter().zip(asg.a_vals(a)) {
                let xrow = &x[col as usize * k + j0..col as usize * k + j1];
                for (t, &xv) in xrow.iter().enumerate() {
                    acc[t] += (v as f64) * (xv as f64);
                }
            }
            if asg.last_chunk {
                let row = asg.a_row as usize;
                for (t, a_t) in acc.iter_mut().enumerate() {
                    buf[row * stride + t] = *a_t as Val;
                    *a_t = 0.0;
                }
            }
        }
    }
}

/// Copy a block-major buffer's columns `[j0, j1)` into the row-major
/// result (rows that the schedule never touched stay zero in both).
fn scatter_block(buf: &[Val], k: usize, j0: usize, j1: usize, c: &mut [Val]) {
    let kb = j1 - j0;
    if kb == 0 {
        return;
    }
    let nrows = c.len() / k.max(1);
    let stride = if nrows == 0 { kb } else { buf.len() / nrows };
    for i in 0..nrows {
        c[i * k + j0..i * k + j1].copy_from_slice(&buf[i * stride..i * stride + kb]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ReapSpmv;
    use crate::kernels::spmm::spmm;
    use crate::sparse::gen;

    fn panel(ncols: usize, k: usize, seed: u64) -> Vec<Val> {
        (0..ncols * k)
            .map(|i| (((i as u64).wrapping_mul(seed + 11) % 23) as f32 - 11.0) * 0.125)
            .collect()
    }

    #[test]
    fn bit_identical_to_k_spmv_coordinator_runs() {
        let a = gen::power_law(120, 2000, 3);
        let cfg = FpgaConfig::reap64_spgemm();
        for k in [1usize, 4, 8, 13] {
            let x = panel(a.ncols, k, 3);
            let rep = ReapSpmm::new(cfg.clone()).run(&a, &x, k).unwrap();
            assert_eq!(rep.k, k);
            for j in 0..k {
                let xj: Vec<Val> = x.iter().skip(j).step_by(k).copied().collect();
                let solo = ReapSpmv::new(cfg.clone()).run(&a, &xj).unwrap();
                for i in 0..a.nrows {
                    assert_eq!(rep.c[i * k + j], solo.y[i], "k {k} col {j} row {i}");
                }
            }
            // and to the CPU reference kernel
            assert_eq!(rep.c, spmm(&a, &x, k), "k {k} vs kernel");
        }
    }

    #[test]
    fn numeric_thread_invariant() {
        let a = gen::random_uniform(90, 110, 1400, 9);
        let k = 20usize; // several column blocks
        let x = panel(a.ncols, k, 9);
        let cfg = FpgaConfig::reap32_spgemm();
        let s = schedule_spgemm(&a, &Csr::new(a.ncols, a.ncols), cfg.pipelines, cfg.bundle_size);
        let base = numeric_spmm(&a, &x, k, &s, 1);
        for t in [2usize, 4, 8] {
            assert_eq!(numeric_spmm(&a, &x, k, &s, t), base, "threads {t}");
            for grain in [1usize, 4, 1 << 20] {
                assert_eq!(
                    numeric_spmm_with_grain(&a, &x, k, &s, t, grain),
                    base,
                    "threads {t} grain {grain}"
                );
            }
        }
        assert_eq!(base, spmm(&a, &x, k));
    }

    #[test]
    fn report_times_consistent() {
        let a = gen::banded_fem(200, 1800, 5);
        let k = 8usize;
        let x = panel(a.ncols, k, 5);
        let rep = ReapSpmm::new(FpgaConfig::reap128_spgemm()).run(&a, &x, k).unwrap();
        assert!(rep.cpu_preprocess_s >= 0.0);
        assert!(rep.fpga_s > 0.0);
        assert!(rep.total_s >= rep.fpga_s);
        assert!(rep.total_s <= rep.cpu_preprocess_s + rep.fpga_s + 1e-9);
        assert_eq!(rep.n_blocks, 1);
    }

    #[test]
    fn handles_empty_and_oversized_rows() {
        // rows: empty, 90-nnz (splits across bundles), empty, singleton
        let mut a = Csr::new(4, 100);
        a.cols = (0..90).chain([13]).collect();
        a.vals = (0..91).map(|i| (i as f32) * 0.5 - 20.0).collect();
        a.row_ptr = vec![0, 0, 90, 90, 91];
        a.validate().unwrap();
        let k = 4usize;
        let x = panel(a.ncols, k, 21);
        let rep = ReapSpmm::new(FpgaConfig::reap32_spgemm()).run(&a, &x, k).unwrap();
        assert_eq!(rep.c, spmm(&a, &x, k));
        assert_eq!(&rep.c[0..k], &vec![0.0; k][..], "empty row stays zero");
    }

    #[test]
    fn rejects_bad_shapes() {
        let a = gen::random_uniform(10, 10, 30, 1);
        assert!(ReapSpmm::new(FpgaConfig::reap32_spgemm()).run(&a, &[0.0; 10], 2).is_err());
        assert!(ReapSpmm::new(FpgaConfig::reap32_spgemm()).run(&a, &[], 0).is_err());
    }
}
