//! Multi-tenant batched SpGEMM orchestration.
//!
//! The paper's wave schedule assumes one large matrix; the production
//! north-star is the opposite shape — heavy traffic of many *small*
//! SpGEMMs, each of which alone cannot fill a 64/128-pipeline design. The
//! batch coordinator packs wave entries from N independent jobs into
//! shared, job-tagged waves ([`crate::rir::schedule::BatchSchedule`]),
//! streams per-job RIR segments through one arena, attributes simulated
//! cycles/occupancy per tenant, and drives the whole batch through the
//! same per-wave CPU/FPGA pipelining as the single-job coordinators.
//!
//! The load-bearing invariant (property-tested): a batched run of N jobs
//! is **bit-identical** to N independent scheduled runs — batching
//! regroups waves, it never reorders a job's chunks or its floating-point
//! accumulation.

use anyhow::{ensure, Result};

use crate::fpga::engine::{execute_waves_with_faults, WaveFault};
use crate::fpga::spgemm_sim::{simulate_spgemm_batch_with_faults, JobSimStats, Style};
use crate::fpga::{FpgaConfig, SimStats};
use crate::reliability::draw_wave_faults;
use crate::kernels::spgemm_parallel::SpaScratch;
use crate::rir::encode::chain_bundle_count_csr;
use crate::rir::layout::WORD_BYTES;
use crate::rir::schedule::{schedule_spgemm_batch, Assignment, BatchSchedule};
use crate::sparse::{Csr, Val};
use crate::util::preprocess_threads;

use super::overlap::pipelined_total;

/// Batched SpGEMM coordinator for one FPGA design point (in-process
/// numerics; the XLA request path remains single-job).
///
/// ```
/// use reap::coordinator::ReapBatch;
/// use reap::fpga::FpgaConfig;
/// use reap::sparse::gen;
///
/// let jobs: Vec<_> = (0..3u64)
///     .map(|j| (
///         gen::random_uniform(20, 20, 80, j),
///         gen::random_uniform(20, 20, 80, 100 + j),
///     ))
///     .collect();
/// let rep = ReapBatch::new(FpgaConfig::reap64_spgemm()).run(&jobs).unwrap();
/// // each tenant's product is bit-identical to an independent run
/// assert_eq!(rep.outputs.len(), 3);
/// assert_eq!(rep.outputs[0], reap::kernels::spgemm(&jobs[0].0, &jobs[0].1));
/// ```
pub struct ReapBatch {
    pub cfg: FpgaConfig,
    /// Probability that one fetch of a wave's stream arrives corrupted
    /// (modeled on the simulated-time side only — numeric outputs are
    /// still computed for every job). `0.0` (the default) disables fault
    /// injection entirely and is bit-identical to the pre-fault model.
    pub wave_fault_rate: f64,
    /// Seed for the per-wave fault draw
    /// ([`crate::reliability::draw_wave_faults`]); irrelevant at rate 0.
    pub fault_seed: u64,
    /// Run the static audits ([`crate::analysis`]) on this run's schedule
    /// and wave costs even in release builds, failing with a typed
    /// [`crate::analysis::AnalysisError`]. Debug builds always audit.
    pub strict: bool,
}

/// Outcome of one batched REAP SpGEMM execution.
#[derive(Clone, Debug)]
pub struct ReapBatchReport {
    /// Per-job products `C_j = A_j × B_j`, indexed by job id —
    /// bit-identical to running each job through [`super::ReapSpgemm`].
    pub outputs: Vec<Csr>,
    /// Measured CPU preprocessing seconds for the whole batch (shared
    /// chunk enumeration + shared-wave building).
    pub cpu_preprocess_s: f64,
    /// Aggregate simulated FPGA statistics over the shared waves (at the
    /// configured channel depth).
    pub fpga_sim: SimStats,
    /// The same shared-wave run on the serial depth-1 channel.
    pub fpga_sim_serial: SimStats,
    /// The same run on the double-buffered depth-2 channel.
    pub fpga_sim_db: SimStats,
    /// Per-job simulated attribution (cycles held, flops, traffic, plus
    /// the enqueue/complete cycle stamps behind [`Self::job_enqueue_s`]).
    pub job_sim: Vec<JobSimStats>,
    /// Per-job start-of-service seconds within the FPGA phase: when the
    /// job's first shared wave begins, at the design clock. Indexed by
    /// job id; `0.0` for a job riding no wave.
    pub job_enqueue_s: Vec<f64>,
    /// Per-job completion seconds within the FPGA phase: when the job's
    /// last shared wave finishes. The serving layer
    /// ([`crate::serving`]) adds these to its batch start time to get
    /// per-job latency — no re-derivation from wave indices. The maximum
    /// over jobs of a non-empty batch equals [`Self::fpga_s`].
    pub job_complete_s: Vec<f64>,
    /// Bytes of each job's A-side RIR stream segment in the shared arena.
    pub a_stream_bytes: Vec<usize>,
    /// Simulated FPGA seconds at the design's clock.
    pub fpga_s: f64,
    /// End-to-end seconds under per-wave CPU/FPGA pipelining.
    pub total_s: f64,
    /// Jobs whose waves exhausted [`FpgaConfig::max_wave_retries`] under
    /// the configured [`ReapBatch::wave_fault_rate`]: their simulated
    /// output never landed, and a production deployment would rerun just
    /// these. Ascending job ids; always empty at fault rate 0.
    pub failed_jobs: Vec<usize>,
    /// The negotiated stream encoding the simulation priced
    /// ([`FpgaConfig::encoding`]). [`Self::a_stream_bytes`] stays the raw
    /// arena segment size — it describes the CPU-side arena layout, not
    /// the priced wire traffic.
    pub encoding: String,
}

impl ReapBatch {
    pub fn new(cfg: FpgaConfig) -> Self {
        ReapBatch { cfg, wave_fault_rate: 0.0, fault_seed: 0, strict: false }
    }

    /// Enable (or disable) release-build static audits for this run.
    pub fn strict(mut self, on: bool) -> Self {
        self.strict = on;
        self
    }

    /// True when this run audits its artifacts (always in debug builds).
    fn audits(&self) -> bool {
        cfg!(debug_assertions) || self.strict
    }

    /// Enable seed-deterministic stream-fault injection at `rate` per
    /// wave fetch (see [`Self::wave_fault_rate`]).
    pub fn with_faults(mut self, rate: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&rate), "wave_fault_rate must be in [0, 1], got {rate}");
        self.wave_fault_rate = rate;
        self.fault_seed = seed;
        self
    }

    /// Run the full batched flow for N independent jobs.
    pub fn run(&self, jobs: &[(Csr, Csr)]) -> Result<ReapBatchReport> {
        self.cfg.validate()?;
        for (j, (a, b)) in jobs.iter().enumerate() {
            ensure!(a.ncols == b.nrows, "job {j}: inner dimensions disagree");
        }

        // ---- CPU pass: shared-wave schedule (measured per wave) ----
        let schedule =
            schedule_spgemm_batch(jobs, self.cfg.pipelines, self.cfg.bundle_size);
        if self.audits() {
            let diags = crate::analysis::audit_batch_schedule(jobs, &schedule);
            crate::analysis::ensure_clean(diags)?;
        }
        let cpu_preprocess_s = schedule.cpu_total_s();

        // ---- per-tenant A-stream byte accounting: each job's segment of
        // the shared RIR arena is 2 header words per bundle + 2 words per
        // element, so the bytes are computable in O(nrows) without
        // materializing the arena (contract-tested against the real
        // `BundleStream::encode_csr_jobs` segments) ----
        let a_stream_bytes: Vec<usize> = jobs
            .iter()
            .map(|(a, _)| {
                (2 * chain_bundle_count_csr(a, self.cfg.bundle_size) + 2 * a.nnz())
                    * WORD_BYTES
            })
            .collect();

        // ---- numeric results via per-job schedule replay ----
        let outputs = numeric_batch(jobs, &schedule, preprocess_threads());

        // ---- FPGA timing + per-job attribution from the cycle model,
        // with the configured stream-fault draw (None at rate 0 keeps the
        // fault-free path bit-identical) ----
        let faults: Option<Vec<WaveFault>> = (self.wave_fault_rate > 0.0).then(|| {
            draw_wave_faults(
                self.fault_seed,
                schedule.n_waves(),
                self.wave_fault_rate,
                self.cfg.max_wave_retries,
            )
        });
        let sim = simulate_spgemm_batch_with_faults(
            jobs,
            &schedule,
            &self.cfg,
            Style::HandCoded,
            faults.as_deref(),
        );
        if self.audits() {
            let diags = crate::analysis::audit_wave_costs(&sim.costs, &self.cfg);
            crate::analysis::ensure_clean(diags)?;
        }
        let fpga_s = sim.stats.seconds(&self.cfg);

        // ---- per-wave pipelined overlap, identical to the single-job
        // coordinator: the shared enumeration prologue serializes, then
        // wave k's CPU scheduling hides behind wave k-1's FPGA compute ----
        let hz = self.cfg.hz();
        let fpga_wave_s: Vec<f64> =
            sim.wave_cycles.iter().map(|&cy| cy as f64 / hz).collect();
        let total_s =
            schedule.prep_cpu_s + pipelined_total(&schedule.wave_cpu_s, &fpga_wave_s);

        let depth_stats = |d: usize| {
            if self.cfg.dram_buffer_depth == d {
                sim.stats.clone()
            } else {
                // re-execute under the *same* fault draw, so the serial
                // vs double-buffered comparison isolates the channel depth
                execute_waves_with_faults(&sim.costs, &self.cfg, d, faults.as_deref()).stats
            }
        };
        let fpga_sim_serial = depth_stats(1);
        let fpga_sim_db = depth_stats(2);

        let failed_jobs: Vec<usize> = sim
            .job_stats
            .iter()
            .enumerate()
            .filter_map(|(j, js)| js.failed.then_some(j))
            .collect();

        let job_enqueue_s: Vec<f64> =
            sim.job_stats.iter().map(|js| js.enqueue_cycle as f64 / hz).collect();
        let job_complete_s: Vec<f64> =
            sim.job_stats.iter().map(|js| js.complete_cycle as f64 / hz).collect();

        Ok(ReapBatchReport {
            outputs,
            cpu_preprocess_s,
            fpga_sim: sim.stats,
            fpga_sim_serial,
            fpga_sim_db,
            job_sim: sim.job_stats,
            job_enqueue_s,
            job_complete_s,
            a_stream_bytes,
            fpga_s,
            total_s,
            failed_jobs,
            encoding: self.cfg.encoding.to_string(),
        })
    }
}

/// Execute every job's numeric SpGEMM by replaying its assignments from
/// the shared-wave schedule, in schedule order.
///
/// Each job's replay performs exactly the floating-point operations of
/// the single-job scheduled path ([`super::spgemm::numeric_scheduled`])
/// in exactly the same order — batching only interleaves *which* job a
/// pipeline serves per wave — so the outputs are bit-identical to N
/// independent runs for every thread count and grain size (jobs are
/// data-independent; grains of whole jobs are claimed through the
/// work-stealing executor, [`crate::util::grains`]).
pub fn numeric_batch(
    jobs: &[(Csr, Csr)],
    schedule: &BatchSchedule,
    nthreads: usize,
) -> Vec<Csr> {
    let nthreads = nthreads.max(1);
    // one job per grain: job costs are the coarsest (and most skewed)
    // unit this pass has, so stealing wants them individually claimable
    numeric_batch_with_grain(jobs, schedule, nthreads, 1)
}

/// [`numeric_batch`] with an explicit job-grain size (the grain-size
/// invariance knob for the property suite).
pub fn numeric_batch_with_grain(
    jobs: &[(Csr, Csr)],
    schedule: &BatchSchedule,
    nthreads: usize,
    grain: usize,
) -> Vec<Csr> {
    assert_eq!(jobs.len(), schedule.n_jobs, "job list does not match schedule");
    let per_job = schedule.per_job_assignments();

    let nthreads = nthreads.clamp(1, jobs.len().max(1));
    if nthreads <= 1 || jobs.len() < 2 {
        let mut scratch = SpaScratch::new();
        return jobs
            .iter()
            .zip(&per_job)
            .map(|((a, b), asgs)| numeric_one(a, b, asgs, &mut scratch))
            .collect();
    }

    let per_job = &per_job;
    let grain_outputs: Vec<Vec<Csr>> = crate::util::grains::run_grains_with(
        jobs.len(),
        grain,
        nthreads,
        SpaScratch::new,
        |scratch, _g, lo, hi| {
            (lo..hi)
                .map(|j| numeric_one(&jobs[j].0, &jobs[j].1, &per_job[j], scratch))
                .collect::<Vec<Csr>>()
        },
    );
    grain_outputs.into_iter().flatten().collect()
}

/// Static job-banded predecessor of [`numeric_batch`]: contiguous job
/// ranges balanced by estimated flops, one per worker, no stealing. Kept
/// callable for the `reap bench scaling` side-by-side; bit-identical
/// output.
pub fn numeric_batch_static_bands(
    jobs: &[(Csr, Csr)],
    schedule: &BatchSchedule,
    nthreads: usize,
) -> Vec<Csr> {
    assert_eq!(jobs.len(), schedule.n_jobs, "job list does not match schedule");
    let per_job = schedule.per_job_assignments();

    let nthreads = nthreads.clamp(1, jobs.len().max(1));
    if nthreads <= 1 || jobs.len() < 2 {
        let mut scratch = SpaScratch::new();
        return jobs
            .iter()
            .zip(&per_job)
            .map(|((a, b), asgs)| numeric_one(a, b, asgs, &mut scratch))
            .collect();
    }

    // contiguous job bands balanced by estimated flops
    let costs: Vec<usize> = jobs
        .iter()
        .map(|(a, b)| {
            a.cols
                .iter()
                .map(|&c| b.row_nnz(c as usize))
                .sum::<usize>()
                .max(1)
        })
        .collect();
    let bounds = balanced_job_bounds(&costs, nthreads);

    let band_outputs: Vec<Vec<Csr>> = std::thread::scope(|scope| {
        let per_job = &per_job;
        let handles: Vec<_> = bounds
            .windows(2)
            .map(|w| {
                let (lo, hi) = (w[0], w[1]);
                scope.spawn(move || {
                    let mut scratch = SpaScratch::new();
                    (lo..hi)
                        .map(|j| {
                            numeric_one(&jobs[j].0, &jobs[j].1, &per_job[j], &mut scratch)
                        })
                        .collect::<Vec<Csr>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("batch numeric worker panicked"))
            .collect()
    });
    band_outputs.into_iter().flatten().collect()
}

/// Replay one job's assignments (already in schedule order) with a
/// stamped SPA — the single-job `numeric_band` over the full row range.
fn numeric_one(a: &Csr, b: &Csr, asgs: &[Assignment], scratch: &mut SpaScratch) -> Csr {
    scratch.ensure(b.ncols);
    let mut row_ptr = vec![0usize; a.nrows + 1];
    let mut cols = Vec::new();
    let mut vals: Vec<Val> = Vec::new();
    let mut in_row = false;
    let mut last_done = 0usize;
    for asg in asgs {
        let row = asg.a_row as usize;
        if !in_row {
            scratch.begin_row();
            in_row = true;
        }
        for (&ca, &va) in asg.a_cols(a).iter().zip(asg.a_vals(a)) {
            let r = ca as usize;
            for (&cb, &vb) in b.row_cols(r).iter().zip(b.row_vals(r)) {
                scratch.add(cb, va * vb);
            }
        }
        if asg.last_chunk {
            scratch.drain_row(&mut cols, &mut vals);
            for rr in last_done..row {
                row_ptr[rr + 1] = row_ptr[rr];
            }
            row_ptr[row + 1] = cols.len();
            last_done = row + 1;
            in_row = false;
        }
    }
    for rr in last_done..a.nrows {
        row_ptr[rr + 1] = row_ptr[rr];
    }
    Csr { nrows: a.nrows, ncols: b.ncols, row_ptr, cols, vals }
}

/// Split `0..costs.len()` into ≤ `nthreads` contiguous ranges of roughly
/// equal total cost. Boundaries ascend strictly; first 0, last `len`.
fn balanced_job_bounds(costs: &[usize], nthreads: usize) -> Vec<usize> {
    let n = costs.len();
    let total: usize = costs.iter().sum();
    let mut bounds = vec![0usize];
    let mut prefix = 0usize;
    let mut i = 0usize;
    for k in 1..nthreads {
        let target = total * k / nthreads;
        while i < n && prefix < target {
            prefix += costs[i];
            i += 1;
        }
        if i > *bounds.last().unwrap() && i < n {
            bounds.push(i);
        }
    }
    bounds.push(n);
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::spgemm;
    use crate::sparse::gen;

    fn mk_jobs(n_jobs: usize, n: usize, nnz: usize, seed: u64) -> Vec<(Csr, Csr)> {
        (0..n_jobs)
            .map(|j| {
                let s = seed + j as u64 * 10;
                (
                    gen::power_law(n, nnz, s),
                    gen::random_uniform(n, n, nnz, s + 1),
                )
            })
            .collect()
    }

    #[test]
    fn batch_outputs_match_independent_runs() {
        let mut jobs = mk_jobs(5, 30, 250, 100);
        jobs.push((Csr::new(4, 6), Csr::new(6, 3))); // empty tenant
        let coord = ReapBatch::new(FpgaConfig::reap64_spgemm());
        let rep = coord.run(&jobs).unwrap();
        assert_eq!(rep.outputs.len(), jobs.len());
        for (j, (a, b)) in jobs.iter().enumerate() {
            rep.outputs[j].validate().unwrap();
            assert_eq!(rep.outputs[j], spgemm(a, b), "job {j}");
            let solo = super::super::ReapSpgemm::new(FpgaConfig::reap64_spgemm())
                .run(a, b)
                .unwrap();
            assert_eq!(rep.outputs[j], solo.c, "job {j} vs single-job coordinator");
        }
        assert_eq!(rep.job_sim.len(), jobs.len());
        assert_eq!(rep.a_stream_bytes.len(), jobs.len());
        assert!(rep.fpga_s > 0.0);
        assert!(rep.total_s >= rep.fpga_s);
    }

    #[test]
    fn numeric_batch_thread_invariant() {
        let jobs = mk_jobs(7, 25, 200, 200);
        let s = schedule_spgemm_batch(&jobs, 32, 16);
        let base = numeric_batch(&jobs, &s, 1);
        for t in [2usize, 4, 8, 16] {
            assert_eq!(numeric_batch(&jobs, &s, t), base, "threads={t}");
            assert_eq!(numeric_batch_static_bands(&jobs, &s, t), base, "static threads={t}");
            for grain in [1usize, 4, 1 << 20] {
                assert_eq!(
                    numeric_batch_with_grain(&jobs, &s, t, grain),
                    base,
                    "threads={t} grain={grain}"
                );
            }
        }
        for (j, (a, b)) in jobs.iter().enumerate() {
            assert_eq!(base[j], spgemm(a, b), "job {j}");
        }
    }

    #[test]
    fn report_times_consistent() {
        let jobs = mk_jobs(4, 40, 300, 300);
        let rep = ReapBatch::new(FpgaConfig::reap128_spgemm()).run(&jobs).unwrap();
        assert!(rep.cpu_preprocess_s >= 0.0);
        assert!(rep.total_s <= rep.cpu_preprocess_s + rep.fpga_s + 1e-9);
        assert!(rep.total_s >= rep.cpu_preprocess_s.max(rep.fpga_s) - 1e-9);
        // per-tenant stream accounting covers every job
        assert!(rep.a_stream_bytes.iter().all(|&bytes| bytes > 0));
    }

    #[test]
    fn per_job_latency_stamps_cover_the_fpga_phase() {
        let jobs = mk_jobs(6, 30, 220, 700);
        let cfg = FpgaConfig::reap64_spgemm();
        let rep = ReapBatch::new(cfg.clone()).run(&jobs).unwrap();
        assert_eq!(rep.job_enqueue_s.len(), jobs.len());
        assert_eq!(rep.job_complete_s.len(), jobs.len());
        let hz = cfg.hz();
        for j in 0..jobs.len() {
            assert!(rep.job_enqueue_s[j] < rep.job_complete_s[j], "job {j}");
            assert_eq!(rep.job_enqueue_s[j], rep.job_sim[j].enqueue_cycle as f64 / hz);
            assert_eq!(rep.job_complete_s[j], rep.job_sim[j].complete_cycle as f64 / hz);
        }
        let last = rep.job_complete_s.iter().cloned().fold(0.0f64, f64::max);
        assert_eq!(last, rep.fpga_s, "last completion is the FPGA phase end");
    }

    #[test]
    fn a_stream_bytes_match_real_arena_segments() {
        // the coordinator's O(nrows) arithmetic must agree with the bytes
        // the actual job-segmented RIR encode produces
        let mut jobs = mk_jobs(4, 22, 140, 400);
        jobs.push((Csr::new(3, 5), Csr::new(5, 2)));
        let cfg = FpgaConfig::reap32_spgemm();
        let rep = ReapBatch::new(cfg.clone()).run(&jobs).unwrap();
        let a_refs: Vec<&Csr> = jobs.iter().map(|(a, _)| a).collect();
        let mut arena = crate::rir::BundleStream::new();
        let bounds = arena.encode_csr_jobs(&a_refs, cfg.bundle_size);
        for j in 0..jobs.len() {
            assert_eq!(
                rep.a_stream_bytes[j],
                crate::rir::layout::segment_arena_bytes(&arena, bounds[j], bounds[j + 1]),
                "job {j}"
            );
        }
    }

    #[test]
    fn balanced_job_bounds_partition() {
        let costs = [5usize, 1, 1, 9, 2, 2, 2, 4];
        for t in [1usize, 2, 3, 8, 20] {
            let b = balanced_job_bounds(&costs, t);
            assert_eq!(*b.first().unwrap(), 0);
            assert_eq!(*b.last().unwrap(), costs.len());
            assert!(b.windows(2).all(|w| w[0] < w[1]));
            assert!(b.len() <= t + 1);
        }
    }

    #[test]
    fn fault_injection_charges_time_never_outputs() {
        let jobs = mk_jobs(4, 30, 200, 500);
        let cfg = FpgaConfig::reap64_spgemm();
        let base = ReapBatch::new(cfg.clone()).run(&jobs).unwrap();
        assert!(base.failed_jobs.is_empty());
        assert_eq!(base.fpga_sim.retry_cycles, 0);

        // the builder at rate 0 is bit-identical to the default
        let z = ReapBatch::new(cfg.clone()).with_faults(0.0, 99).run(&jobs).unwrap();
        assert_eq!(z.fpga_sim, base.fpga_sim);
        assert!(z.failed_jobs.is_empty());

        // a lossy link costs retry cycles — exactly — and leaves the
        // numeric products untouched; the depth comparison rides the same
        // draw, so its ledger holds too
        let f = ReapBatch::new(cfg.clone()).with_faults(0.5, 7).run(&jobs).unwrap();
        assert_eq!(f.fpga_sim.cycles, base.fpga_sim.cycles + f.fpga_sim.retry_cycles);
        assert_eq!(f.fpga_sim.bytes_read, base.fpga_sim.bytes_read);
        assert_eq!(f.outputs, base.outputs);
        assert_eq!(f.fpga_sim_serial.retry_cycles, f.fpga_sim.retry_cycles);
        assert_eq!(
            f.fpga_sim_serial.cycles,
            base.fpga_sim_serial.cycles + f.fpga_sim_serial.retry_cycles
        );

        // same seed, same draw: the whole report's fault story replays
        let f2 = ReapBatch::new(cfg.clone()).with_faults(0.5, 7).run(&jobs).unwrap();
        assert_eq!(f2.fpga_sim, f.fpga_sim);
        assert_eq!(f2.failed_jobs, f.failed_jobs);

        // rate 1.0 exhausts every wave's retry budget: graceful
        // degradation reports every tenant failed, deterministically
        let all = ReapBatch::new(cfg).with_faults(1.0, 1).run(&jobs).unwrap();
        assert_eq!(all.failed_jobs, (0..jobs.len()).collect::<Vec<_>>());
        assert!(all.fpga_sim.retry_cycles > 0, "rate 1.0 always exhausts the budget");
        assert_eq!(all.fpga_sim.cycles, base.fpga_sim.cycles + all.fpga_sim.retry_cycles);
    }

    #[test]
    fn empty_batch_is_empty() {
        let jobs: Vec<(Csr, Csr)> = Vec::new();
        let rep = ReapBatch::new(FpgaConfig::reap32_spgemm()).run(&jobs).unwrap();
        assert!(rep.outputs.is_empty());
        assert_eq!(rep.fpga_sim.cycles, 0);
        assert_eq!(rep.fpga_s, 0.0);
    }
}
