//! Result verification: REAP outputs vs the measured CPU baselines.

use crate::sparse::{Csc, Csr};

/// Outcome of a verification.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Verification {
    /// Frobenius norm of the difference.
    pub frob_diff: f64,
    /// Frobenius norm of the reference (for relative error).
    pub frob_ref: f64,
}

impl Verification {
    /// Relative error (0 when the reference is zero and diff is zero).
    pub fn relative(&self) -> f64 {
        if self.frob_ref == 0.0 {
            return if self.frob_diff == 0.0 { 0.0 } else { f64::INFINITY };
        }
        self.frob_diff / self.frob_ref
    }

    /// Accept within a relative tolerance.
    pub fn ok(&self, rel_tol: f64) -> bool {
        self.relative() <= rel_tol
    }
}

/// Compare two CSR matrices (same shape; patterns may differ).
pub fn verify_csr(got: &Csr, reference: &Csr) -> Verification {
    let zero = Csr::new(reference.nrows, reference.ncols);
    Verification {
        frob_diff: got.frob_diff(reference),
        frob_ref: reference.frob_diff(&zero),
    }
}

/// Compare two CSC matrices.
pub fn verify_csc(got: &Csc, reference: &Csc) -> Verification {
    verify_csr(&got.to_csr(), &reference.to_csr())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn identical_matrices_verify() {
        let m = gen::random_uniform(20, 20, 80, 1);
        let v = verify_csr(&m, &m);
        assert_eq!(v.frob_diff, 0.0);
        assert!(v.ok(0.0));
    }

    #[test]
    fn perturbed_matrices_fail_tight_tolerance() {
        let m = gen::random_uniform(20, 20, 80, 2);
        let mut p = m.clone();
        p.vals[0] += 1.0;
        let v = verify_csr(&p, &m);
        assert!(v.frob_diff >= 1.0);
        assert!(!v.ok(1e-9));
        assert!(v.ok(1e9));
    }

    #[test]
    fn zero_reference_edge() {
        let z = Csr::new(4, 4);
        assert_eq!(verify_csr(&z, &z).relative(), 0.0);
        let mut nz = Csr::new(4, 4);
        nz.row_ptr = vec![0, 1, 1, 1, 1];
        nz.cols = vec![0];
        nz.vals = vec![1.0];
        assert_eq!(verify_csr(&nz, &z).relative(), f64::INFINITY);
    }
}
