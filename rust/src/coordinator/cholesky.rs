//! REAP Cholesky orchestration (paper §III-B).
//!
//! CPU pass: elimination tree, symbolic pattern of L, RA data bundles and
//! RL metadata bundles (measured). FPGA pass: left-looking column updates —
//! through the AOT `cholesky_dot`/`cholesky_update` artifacts, or the
//! in-process equivalent — plus timing from the cycle simulator. L lives in
//! row-major storage (the FPGA-memory layout the RL triples address).

use anyhow::{ensure, Context, Result};

use crate::fpga::cholesky_sim::simulate_cholesky;
use crate::fpga::engine::execute_waves_at_depth;
use crate::fpga::spgemm_sim::Style;
use crate::fpga::{FpgaConfig, SimStats};
use crate::kernels::cholesky::{cholesky_numeric, CholeskyFactor};
use crate::runtime::{CholeskyStepIo, XlaRuntime};
use crate::sparse::{Csc, Val};
use crate::symbolic::CholeskySymbolic;

use super::overlap::pipelined_total;
use super::ExecMode;

/// Cholesky coordinator for one FPGA design point.
pub struct ReapCholesky<'rt> {
    pub cfg: FpgaConfig,
    pub mode: ExecMode,
    pub runtime: Option<&'rt XlaRuntime>,
    /// Run the static wave-cost audit ([`crate::analysis`]) on this run
    /// even in release builds, failing with a typed
    /// [`crate::analysis::AnalysisError`]. Debug builds always audit.
    /// (Cholesky has no chunk schedule — the symbolic pass owns the column
    /// order — so only the wave-cost pass applies.)
    pub strict: bool,
}

/// Outcome of one REAP Cholesky execution.
#[derive(Clone, Debug)]
pub struct ReapCholeskyReport {
    /// The factor L (CSC, diagonal-first columns).
    pub factor: CholeskyFactor,
    /// Measured CPU symbolic-analysis seconds (etree + pattern + bundles).
    pub cpu_symbolic_s: f64,
    /// Simulated FPGA statistics (at the configured channel depth).
    pub fpga_sim: SimStats,
    /// The same run on the serial depth-1 channel.
    pub fpga_sim_serial: SimStats,
    /// The same run on the double-buffered depth-2 channel. Cholesky's
    /// column stream is `dependent_stream` (column *k+1* reads column
    /// *k*'s writeback), so this equals the serial stats today — reported
    /// anyway so the `BENCH_*.json` schema is uniform across workloads.
    pub fpga_sim_db: SimStats,
    /// Simulated FPGA seconds.
    pub fpga_s: f64,
    /// End-to-end seconds. The global analysis (etree + pattern + storage
    /// map) *produces* the schedule and cannot overlap the numeric phase;
    /// the per-column RA/RL stream encoding pipelines against the FPGA's
    /// column processing (column *k*'s encode overlaps column *k−1*'s
    /// compute), mirroring the SpGEMM per-wave model.
    pub total_s: f64,
}

impl<'rt> ReapCholesky<'rt> {
    /// Coordinator with the in-process numeric path.
    pub fn new(cfg: FpgaConfig) -> Self {
        ReapCholesky { cfg, mode: ExecMode::Rust, runtime: None, strict: false }
    }

    /// Coordinator executing numerics through the XLA artifacts.
    pub fn with_runtime(cfg: FpgaConfig, rt: &'rt XlaRuntime) -> Self {
        ReapCholesky { cfg, mode: ExecMode::Xla, runtime: Some(rt), strict: false }
    }

    /// Enable (or disable) release-build static audits for this run.
    pub fn strict(mut self, on: bool) -> Self {
        self.strict = on;
        self
    }

    /// True when this run audits its artifacts (always in debug builds).
    fn audits(&self) -> bool {
        cfg!(debug_assertions) || self.strict
    }

    /// Factorize the SPD matrix whose lower triangle is `a_lower`.
    pub fn run(&self, a_lower: &Csc) -> Result<ReapCholeskyReport> {
        self.cfg.validate()?;
        // ---- CPU pass (measured): symbolic analysis + RIR/RL bundles ----
        let sym = CholeskySymbolic::analyze(a_lower, self.cfg.bundle_size);
        let cpu_symbolic_s = sym.analysis_s + sym.encode_s;

        // ---- numeric phase ----
        let factor = match self.mode {
            ExecMode::Rust => cholesky_numeric(a_lower, &sym.pattern)?,
            ExecMode::Xla => {
                let rt = self.runtime.context("XLA mode requires a runtime")?;
                numeric_xla(a_lower, &sym, rt)?
            }
        };

        // ---- FPGA timing ----
        let sim = simulate_cholesky(&sym, &self.cfg, Style::HandCoded);
        if self.audits() {
            let diags = crate::analysis::audit_wave_costs(&sim.costs, &self.cfg);
            crate::analysis::ensure_clean(diags)?;
        }
        let fpga_s = sim.stats.seconds(&self.cfg);

        // ---- per-column pipelined overlap: the analysis serializes, then
        // column k's stream encode hides behind column k-1's compute ----
        let hz = self.cfg.hz();
        let fpga_col_s: Vec<f64> = sim.column_cycles.iter().map(|&cy| cy as f64 / hz).collect();
        let total_s = sym.analysis_s + pipelined_total(&sym.encode_col_s(), &fpga_col_s);

        let depth_stats = |d: usize| {
            if self.cfg.dram_buffer_depth == d {
                sim.stats.clone()
            } else {
                execute_waves_at_depth(&sim.costs, &self.cfg, d).stats
            }
        };
        let fpga_sim_serial = depth_stats(1);
        let fpga_sim_db = depth_stats(2);

        Ok(ReapCholeskyReport {
            factor,
            cpu_symbolic_s,
            fpga_sim: sim.stats,
            fpga_sim_serial,
            fpga_sim_db,
            fpga_s,
            total_s,
        })
    }
}

/// Left-looking factorization through the AOT artifacts.
///
/// L is kept in the row-major storage map (as in FPGA memory). For each
/// column k: dots of every candidate row r against row k accumulate over
/// bundle-chunk pairs via `cholesky_dot`; the division/sqrt finalize runs
/// through `cholesky_update` with an empty broadcast (the coordinator owns
/// only the partial-dot summation — merge work, its L3 role).
fn numeric_xla(a_lower: &Csc, sym: &CholeskySymbolic, rt: &XlaRuntime) -> Result<CholeskyFactor> {
    let n = sym.pattern.n;
    let mut io = CholeskyStepIo::new(rt)?;
    let bundle = io.bundle;
    let pipes = io.pipes;

    // L values in row-major storage order
    let storage = &sym.storage;
    let mut lvals: Vec<Val> = vec![0.0; storage.len()];
    // slot of column j within row r = binary search in the row's col list
    let slot_of = |r: usize, j: usize, storage: &crate::symbolic::LStorageMap| -> usize {
        let cols = storage.row_cols(r);
        storage.row_ptr[r] + cols.binary_search(&(j as u32)).expect("pattern slot")
    };

    for k in 0..n {
        let col_rows = sym.pattern.col_rows(k); // diag first
        ensure!(col_rows[0] as usize == k, "pattern must be diagonal-first");

        // row k head: columns < k and their (already computed) values
        let k_cols_all = storage.row_cols(k);
        let k_head_len = k_cols_all.len() - 1; // strip diagonal
        let k_cols = &k_cols_all[..k_head_len];
        let k_vals: Vec<Val> =
            (0..k_head_len).map(|i| lvals[storage.row_ptr[k] + i]).collect();
        let k_chunks = k_head_len.div_ceil(bundle).max(1);

        // diagonal dot: row k against itself
        let mut diag_dot = 0f64;
        for ck in 0..k_chunks {
            let (klo, khi) = (ck * bundle, ((ck + 1) * bundle).min(k_head_len));
            if klo >= khi {
                continue;
            }
            io.clear();
            io.set_rowk(&k_cols[klo..khi], &k_vals[klo..khi])?;
            io.set_rowr(0, &k_cols[klo..khi], &k_vals[klo..khi])?;
            // exploit orthogonality of distinct chunks of the same sorted
            // row: cross-chunk intersections are empty, so only the
            // diagonal chunk pairs contribute
            let dots = io.execute_dot(rt)?;
            diag_dot += dots[0] as f64;
        }
        let a_kk = a_lower.get(k, k);

        // off-diagonal rows in batches of `pipes`
        let off_rows = &col_rows[1..];
        let mut new_offdiag: Vec<(usize, f32)> = Vec::with_capacity(off_rows.len());
        let mut l_kk: f32 = (a_kk as f64 - diag_dot).max(0.0).sqrt() as f32;
        let mut first_batch = true;
        if off_rows.is_empty() {
            // still need the hardware sqrt for the diagonal
            io.clear();
            io.set_a(&[], (a_kk as f64 - diag_dot) as f32)?;
            let (_, lkk) = io.execute_update(rt)?;
            l_kk = lkk;
        }
        for batch in off_rows.chunks(pipes) {
            // accumulate dots over chunk pairs
            let mut dots = vec![0f64; batch.len()];
            for ck in 0..k_chunks {
                let (klo, khi) = (ck * bundle, ((ck + 1) * bundle).min(k_head_len));
                let max_r_chunks = batch
                    .iter()
                    .map(|&r| {
                        let cols = storage.row_cols(r as usize);
                        let cut = cols.partition_point(|&c| (c as usize) < k);
                        cut.div_ceil(bundle).max(1)
                    })
                    .max()
                    .unwrap_or(1);
                for cr in 0..max_r_chunks {
                    io.clear();
                    if klo < khi {
                        io.set_rowk(&k_cols[klo..khi], &k_vals[klo..khi])?;
                    }
                    let mut any = false;
                    for (p, &r) in batch.iter().enumerate() {
                        let r = r as usize;
                        let cols = storage.row_cols(r);
                        let cut = cols.partition_point(|&c| (c as usize) < k);
                        let (rlo, rhi) = ((cr * bundle).min(cut), ((cr + 1) * bundle).min(cut));
                        if rlo < rhi {
                            let vals: Vec<Val> = (rlo..rhi)
                                .map(|i| lvals[storage.row_ptr[r] + i])
                                .collect();
                            io.set_rowr(p, &cols[rlo..rhi], &vals)?;
                            any = true;
                        }
                    }
                    if any && klo < khi {
                        let d = io.execute_dot(rt)?;
                        for (p, dp) in dots.iter_mut().enumerate() {
                            *dp += d[p] as f64;
                        }
                    }
                }
            }
            // finalize on the "div/sqrt PE": av = A(r,k) - dot, ad = d
            io.clear();
            let av: Vec<f32> = batch
                .iter()
                .zip(&dots)
                .map(|(&r, &d)| (a_lower.get(r as usize, k) as f64 - d) as f32)
                .collect();
            io.set_a(&av, (a_kk as f64 - diag_dot) as f32)?;
            let (out, lkk) = io.execute_update(rt)?;
            ensure!(lkk.is_finite() && lkk > 0.0, "non-SPD pivot at column {k}");
            if first_batch {
                l_kk = lkk;
                first_batch = false;
            }
            for (p, &r) in batch.iter().enumerate() {
                new_offdiag.push((r as usize, out[p]));
            }
        }

        // write back into row-major L storage
        lvals[slot_of(k, k, storage)] = l_kk;
        for (r, v) in new_offdiag {
            lvals[slot_of(r, k, storage)] = v;
        }
    }

    // convert row-major storage to the CSC factor layout
    let pattern = sym.pattern.clone();
    let mut vals = vec![0f32; pattern.nnz()];
    let mut next: Vec<usize> = pattern.col_ptr.clone();
    for r in 0..n {
        for (i, &j) in storage.row_cols(r).iter().enumerate() {
            // rows within a column arrive in ascending r (we scan r in
            // order), matching the pattern's diagonal-first-then-ascending
            // layout
            let dst = &mut next[j as usize];
            vals[*dst] = lvals[storage.row_ptr[r] + i];
            *dst += 1;
        }
    }
    let l = Csc {
        nrows: n,
        ncols: n,
        col_ptr: pattern.col_ptr.clone(),
        rows: pattern.rows.clone(),
        vals,
    };
    Ok(CholeskyFactor { l, pattern })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, Dense};

    #[test]
    fn rust_mode_matches_direct_factorization() {
        for seed in 0..3u64 {
            let spd = gen::spd(gen::Family::BandedFem, 40, 250, seed);
            let lower = spd.lower_triangle();
            let coord = ReapCholesky::new(FpgaConfig::reap32_cholesky());
            let rep = coord.run(&lower).unwrap();
            let expect = Dense::from_csr(&spd.to_csr()).cholesky();
            let got = Dense::from_csr(&rep.factor.l.to_csr());
            assert!(got.max_abs_diff(&expect) < 1e-3, "seed {seed}");
            assert!(rep.fpga_s > 0.0);
            // per-column pipelining: never worse than serial, never better
            // than either side alone
            assert!(rep.total_s <= rep.cpu_symbolic_s + rep.fpga_s + 1e-9);
            assert!(rep.total_s >= rep.cpu_symbolic_s.max(rep.fpga_s) - 1e-9);
        }
    }

    #[test]
    fn rejects_indefinite_input() {
        let mut coo = crate::sparse::Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(1, 0, 2.0);
        coo.push(0, 1, 2.0);
        coo.push(1, 1, 1.0);
        let lower = coo.to_csr().to_csc().lower_triangle();
        let coord = ReapCholesky::new(FpgaConfig::reap32_cholesky());
        assert!(coord.run(&lower).is_err());
    }
}
