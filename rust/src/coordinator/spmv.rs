//! REAP SpMV orchestration — the future-work extension through the same
//! synergistic flow: CPU pass (RIR chunking, measured) → FPGA numeric
//! (XLA artifact or in-process, identical chunk ordering) → cycle-model
//! timing → overlap accounting.

use anyhow::{Context, Result};

use crate::fpga::engine::execute_waves_at_depth;
use crate::fpga::spgemm_sim::Style;
use crate::fpga::spmv_sim::simulate_spmv;
use crate::fpga::{FpgaConfig, SimStats};
use crate::rir::schedule::{schedule_spgemm, SpgemmSchedule};
use crate::runtime::{SpmvWaveIo, XlaRuntime};
use crate::sparse::{Csr, Val};

use super::overlap::pipelined_total;
use super::ExecMode;

/// SpMV coordinator for one FPGA design point.
pub struct ReapSpmv<'rt> {
    pub cfg: FpgaConfig,
    pub mode: ExecMode,
    pub runtime: Option<&'rt XlaRuntime>,
    /// Run the static audits ([`crate::analysis`]) on this run's schedule
    /// and wave costs even in release builds, failing with a typed
    /// [`crate::analysis::AnalysisError`]. Debug builds always audit.
    pub strict: bool,
}

/// Outcome of one REAP SpMV execution.
#[derive(Clone, Debug)]
pub struct ReapSpmvReport {
    pub y: Vec<Val>,
    pub cpu_preprocess_s: f64,
    /// Simulated FPGA statistics (at the configured channel depth).
    pub fpga_sim: SimStats,
    /// The same run on the serial depth-1 channel.
    pub fpga_sim_serial: SimStats,
    /// The same run on the double-buffered depth-2 channel.
    pub fpga_sim_db: SimStats,
    pub fpga_s: f64,
    pub total_s: f64,
    /// The negotiated stream encoding the simulation priced
    /// ([`FpgaConfig::encoding`]).
    pub encoding: String,
}

impl<'rt> ReapSpmv<'rt> {
    /// Coordinator with the in-process numeric path.
    pub fn new(cfg: FpgaConfig) -> Self {
        ReapSpmv { cfg, mode: ExecMode::Rust, runtime: None, strict: false }
    }

    /// Coordinator executing numerics through the XLA artifacts.
    pub fn with_runtime(cfg: FpgaConfig, rt: &'rt XlaRuntime) -> Self {
        ReapSpmv { cfg, mode: ExecMode::Xla, runtime: Some(rt), strict: false }
    }

    /// Enable (or disable) release-build static audits for this run.
    pub fn strict(mut self, on: bool) -> Self {
        self.strict = on;
        self
    }

    /// True when this run audits its artifacts (always in debug builds).
    fn audits(&self) -> bool {
        cfg!(debug_assertions) || self.strict
    }

    /// Run y = A x.
    pub fn run(&self, a: &Csr, x: &[Val]) -> Result<ReapSpmvReport> {
        self.cfg.validate()?;
        // CPU pass: chunk rows into bundles (the SpGEMM scheduler's wave
        // structure, with an empty B surrogate — x lives on-chip)
        let b_surrogate = Csr::new(a.ncols, a.ncols);
        let schedule = schedule_spgemm(a, &b_surrogate, self.cfg.pipelines, self.cfg.bundle_size);
        if self.audits() {
            let diags = crate::analysis::audit_spgemm_schedule(a, &b_surrogate, &schedule);
            crate::analysis::ensure_clean(diags)?;
        }
        let cpu_preprocess_s = schedule.cpu_total_s();

        let y = match self.mode {
            ExecMode::Rust => numeric_rust(a, x, &schedule),
            ExecMode::Xla => {
                let rt = self.runtime.context("XLA mode requires a runtime")?;
                numeric_xla(a, x, &schedule, rt)?
            }
        };

        let sim = simulate_spmv(a, &schedule, &self.cfg, Style::HandCoded);
        if self.audits() {
            let diags = crate::analysis::audit_wave_costs(&sim.costs, &self.cfg);
            crate::analysis::ensure_clean(diags)?;
        }
        let fpga_s = sim.stats.seconds(&self.cfg);

        // per-wave pipelining; the chunk-enumeration prologue and the
        // one-time x-vector load serialize ahead of the wave pipeline
        let hz = self.cfg.hz();
        let fpga_wave_s: Vec<f64> = sim.wave_cycles.iter().map(|&cy| cy as f64 / hz).collect();
        let total_s = schedule.prep_cpu_s
            + sim.x_load_cycles as f64 / hz
            + pipelined_total(&schedule.wave_cpu_s, &fpga_wave_s);
        let depth_stats = |d: usize| {
            if self.cfg.dram_buffer_depth == d {
                sim.stats.clone()
            } else {
                execute_waves_at_depth(&sim.costs, &self.cfg, d).stats
            }
        };
        let fpga_sim_serial = depth_stats(1);
        let fpga_sim_db = depth_stats(2);
        Ok(ReapSpmvReport {
            y,
            cpu_preprocess_s,
            fpga_sim: sim.stats,
            fpga_sim_serial,
            fpga_sim_db,
            fpga_s,
            total_s,
            encoding: self.cfg.encoding.to_string(),
        })
    }
}

/// In-process numeric path in schedule (chunk) order.
fn numeric_rust(a: &Csr, x: &[Val], schedule: &SpgemmSchedule) -> Vec<Val> {
    let mut y = vec![0 as Val; a.nrows];
    let mut acc = 0f64;
    for wave in &schedule.waves {
        for asg in &wave.assignments {
            for (&c, &v) in asg.a_cols(a).iter().zip(asg.a_vals(a)) {
                acc += (v as f64) * (x[c as usize] as f64);
            }
            if asg.last_chunk {
                y[asg.a_row as usize] = acc as Val;
                acc = 0.0;
            }
        }
    }
    y
}

/// XLA path: stream the same chunks through the `spmv_bundle` artifact,
/// tiling x; partial sums accumulate per row (the coordinator's merge
/// role).
fn numeric_xla(a: &Csr, x: &[Val], schedule: &SpgemmSchedule, rt: &XlaRuntime) -> Result<Vec<Val>> {
    let mut io = SpmvWaveIo::new(rt)?;
    let tile_w = io.tile_w;
    let mut y = vec![0f64; a.nrows];

    // staged step -> destination row, so batches can span rows/waves
    let mut dest: Vec<usize> = Vec::with_capacity(io.batch);
    let mut flush = |io: &mut SpmvWaveIo, dest: &mut Vec<usize>, y: &mut [f64]| -> Result<()> {
        if io.steps() == 0 {
            return Ok(());
        }
        let parts = io.execute(rt)?;
        for (p, &row) in parts.iter().zip(dest.iter()) {
            y[row] += *p as f64;
        }
        io.clear();
        dest.clear();
        Ok(())
    };

    for wave in &schedule.waves {
        for asg in &wave.assignments {
            // split the chunk by x tile: each (chunk ∩ tile) is one step
            let cols = asg.a_cols(a);
            let vals = asg.a_vals(a);
            let mut lo = 0usize;
            while lo < cols.len() {
                let tile = cols[lo] as usize / tile_w;
                let tile_start = tile * tile_w;
                let hi = lo + cols[lo..].partition_point(|&c| (c as usize) < tile_start + tile_w);
                let x_lo = tile_start.min(x.len());
                let x_hi = (tile_start + tile_w).min(x.len());
                io.push_step(tile_start as u32, &cols[lo..hi], &vals[lo..hi], &x[x_lo..x_hi])?;
                dest.push(asg.a_row as usize);
                if io.is_full() {
                    flush(&mut io, &mut dest, &mut y)?;
                }
                lo = hi;
            }
        }
    }
    flush(&mut io, &mut dest, &mut y)?;
    Ok(y.into_iter().map(|v| v as Val).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::spmv::spmv;
    use crate::sparse::gen;

    #[test]
    fn rust_mode_matches_baseline() {
        for seed in 0..4u64 {
            let a = gen::power_law(150, 2500, seed);
            let x: Vec<f32> = (0..150).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
            let rep = ReapSpmv::new(FpgaConfig::reap32_spgemm()).run(&a, &x).unwrap();
            let want = spmv(&a, &x);
            let err = rep
                .y
                .iter()
                .zip(&want)
                .map(|(g, w)| (g - w).abs())
                .fold(0f32, f32::max);
            assert!(err < 1e-3, "seed {seed}: err {err}");
            assert!(rep.fpga_s > 0.0);
        }
    }

    #[test]
    fn handles_empty_rows_and_big_rows() {
        let a = gen::random_uniform(4, 300, 500, 1); // rows of ~125 nnz
        let x: Vec<f32> = (0..300).map(|i| (i as f32 * 0.01).cos()).collect();
        let rep = ReapSpmv::new(FpgaConfig::reap32_spgemm()).run(&a, &x).unwrap();
        let want = spmv(&a, &x);
        for (g, w) in rep.y.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3);
        }
    }
}
