//! ASCII table + CSV rendering for harness reports.
//!
//! Every figure/table reproduction prints the same rows the paper plots;
//! [`Table`] renders them aligned for the terminal and as CSV for plotting.

use std::fmt::Write as _;

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = widths[i].saturating_sub(cells[i].len());
                // Right-align numeric-looking cells, left-align the rest.
                let numeric = cells[i]
                    .chars()
                    .all(|ch| ch.is_ascii_digit() || ".-+exX%".contains(ch));
                if numeric && !cells[i].is_empty() {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(&cells[i]);
                } else {
                    line.push_str(&cells[i]);
                    line.push_str(&" ".repeat(pad));
                }
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV (RFC-4180 quoting for cells containing commas/quotes).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write CSV to `path` (creating parent directories).
    pub fn write_csv(&self, path: &str) -> anyhow::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Format a float with 2 decimals (the harness default).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a float with 3 decimals.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a ratio as a speedup like `3.21x`.
pub fn speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format a fraction as a percentage like `42.7%`.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Human format for nonzero counts: `2.10M`, `83.0K`.
pub fn count(n: usize) -> String {
    let n = n as f64;
    if n >= 1e6 {
        format!("{:.2}M", n / 1e6)
    } else if n >= 1e3 {
        format!("{:.1}K", n / 1e3)
    } else {
        format!("{n:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["aa".into(), "1.00".into()]);
        t.row(vec!["bbbb".into(), "12.50".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("name"));
        // numeric column right-aligned: "1.00" padded to width of "12.50"
        assert!(s.contains(" 1.00"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_quotes_specials() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(f2(1.005), "1.00"); // bankers-ish rounding via format!
        assert_eq!(speedup(3.2), "3.20x");
        assert_eq!(pct(0.427), "42.7%");
        assert_eq!(count(2_100_000), "2.10M");
        assert_eq!(count(83_000), "83.0K");
        assert_eq!(count(496), "496");
    }
}
