//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports the subset the `reap` binary needs: positional arguments,
//! `--flag`, `--key value` / `--key=value`, typed accessors with defaults,
//! and strict rejection of unknown options so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: positionals + options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    positionals: Vec<String>,
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
}

/// Declaration of an accepted option (for usage/validation).
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

impl Args {
    /// Parse raw arguments (excluding argv\[0\]) against the accepted specs.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, specs: &[OptSpec]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .with_context(|| format!("unknown option --{name}"))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .with_context(|| format!("--{name} requires a value"))?,
                    };
                    args.options.entry(name).or_default().push(val);
                } else {
                    if inline_val.is_some() {
                        bail!("--{name} does not take a value");
                    }
                    args.flags.push(name);
                }
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    /// All positional arguments.
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Positional at index `i`.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    /// Is the boolean flag present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Last occurrence of `--name value`, as a string.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All occurrences of `--name value`.
    pub fn get_all(&self, name: &str) -> &[String] {
        self.options.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Typed accessor with default.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("invalid value for --{name}: {e}")),
        }
    }
}

/// Render a usage block for a subcommand.
pub fn usage(cmd: &str, summary: &str, specs: &[OptSpec]) -> String {
    let mut out = format!("usage: reap {cmd} [options]\n  {summary}\n");
    if !specs.is_empty() {
        out.push_str("options:\n");
        for s in specs {
            let val = if s.takes_value { " <v>" } else { "" };
            out.push_str(&format!("  --{}{:<12} {}\n", s.name, val, s.help));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "n", takes_value: true, help: "size" },
            OptSpec { name: "full", takes_value: false, help: "full scale" },
            OptSpec { name: "out", takes_value: true, help: "output path" },
        ]
    }

    fn parse(toks: &[&str]) -> Result<Args> {
        Args::parse(toks.iter().map(|s| s.to_string()), &specs())
    }

    #[test]
    fn positionals_and_options() {
        let a = parse(&["fig6", "--n", "100", "--full", "extra"]).unwrap();
        assert_eq!(a.positional(0), Some("fig6"));
        assert_eq!(a.positional(1), Some("extra"));
        assert_eq!(a.get("n"), Some("100"));
        assert!(a.flag("full"));
        assert!(!a.flag("n"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["--n=42"]).unwrap();
        assert_eq!(a.get_parsed::<usize>("n", 0).unwrap(), 42);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["--bogus"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["--n"]).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parse(&["--full=yes"]).is_err());
    }

    #[test]
    fn typed_default_and_parse_error() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.get_parsed::<usize>("n", 7).unwrap(), 7);
        let b = parse(&["--n", "xyz"]).unwrap();
        assert!(b.get_parsed::<usize>("n", 0).is_err());
    }

    #[test]
    fn repeated_options_accumulate() {
        let a = parse(&["--out", "a.csv", "--out", "b.csv"]).unwrap();
        assert_eq!(a.get("out"), Some("b.csv"));
        assert_eq!(a.get_all("out"), &["a.csv".to_string(), "b.csv".to_string()]);
    }
}
