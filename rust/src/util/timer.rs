//! Wall-clock measurement for the CPU baselines and the bench harness.
//!
//! The paper compares *measured* CPU library time against *simulated* FPGA
//! time; [`Timer`] provides the measured side, with warmup + repetition
//! handling that a criterion-style harness would normally supply.

use std::time::{Duration, Instant};

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed duration since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

/// Result of a repeated measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Minimum over repetitions (the conventional "true cost" estimator for
    /// a deterministic kernel: noise is strictly additive).
    pub min_s: f64,
    /// Median over repetitions.
    pub median_s: f64,
    /// Mean over repetitions.
    pub mean_s: f64,
    /// Number of timed repetitions.
    pub reps: usize,
}

/// Measure `f` with `warmup` untimed runs then `reps` timed runs.
///
/// `f` must be self-contained (re-create its outputs each call); its result
/// is returned through a black-box sink so the optimizer cannot elide work.
pub fn measure<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Measurement {
    assert!(reps > 0);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        black_box(f());
        times.push(t.elapsed_s());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Measurement {
        min_s: times[0],
        median_s: times[times.len() / 2],
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        reps,
    }
}

/// Measure, choosing repetitions adaptively so total timed work is roughly
/// `budget_s` seconds (at least `min_reps`). Good default for benches whose
/// per-call cost spans microseconds to seconds across the matrix suite.
pub fn measure_budgeted<T>(budget_s: f64, min_reps: usize, mut f: impl FnMut() -> T) -> Measurement {
    let t = Timer::start();
    black_box(f()); // warmup + cost probe
    let once = t.elapsed_s().max(1e-9);
    let reps = ((budget_s / once).ceil() as usize).clamp(min_reps.max(1), 10_000);
    measure(0, reps, f)
}

/// Optimization barrier (stable-Rust equivalent of `std::hint::black_box`,
/// kept local so MSRV concerns never bite).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn measure_runs_expected_reps() {
        let mut calls = 0usize;
        let m = measure(2, 5, || {
            calls += 1;
            calls
        });
        assert_eq!(calls, 7);
        assert_eq!(m.reps, 5);
        assert!(m.min_s <= m.median_s && m.median_s <= m.mean_s * 5.0);
    }

    #[test]
    fn budgeted_reps_at_least_min() {
        let m = measure_budgeted(0.0, 3, || 1 + 1);
        assert!(m.reps >= 3);
    }
}
