//! Deterministic work-stealing executor for the CPU preprocessing passes.
//!
//! Every parallel CPU pass in REAP (wave scheduling, scheduled numerics,
//! batch numerics, SpMM column blocks, bundle encoding, Cholesky symbolic
//! rows) shares one execution shape: a list of `n_items` independent work
//! items is cut into fixed-size **grains** (contiguous index ranges), and
//! workers claim grains until none remain. Each worker starts on its own
//! contiguous *run* of grains (claimed through the run's atomic cursor)
//! and, once its run is drained, **steals** grains from the other runs in
//! a fixed victim order. Static banding — the scheme this module replaces
//! — pre-committed each thread to one contiguous band; a single
//! pathological band (one giant power-law row, one dense wave) then
//! serialized the whole pass. Stealing keeps every worker busy until the
//! global pool is empty.
//!
//! # Determinism contract
//!
//! Scheduling order is racy by design — *which worker* computes a grain
//! depends on timing. Output order is not: every grain's result is placed
//! into a slot indexed by its grain id, and [`run_grains`] returns the
//! slots in ascending grain order. The merged result is therefore a pure
//! function of `(n_items, grain)` and the work function — bit-identical
//! across thread counts. Call sites that are additionally invariant to
//! the grain *size* (true whenever per-item results do not depend on how
//! items are grouped — the case for all REAP passes) get full
//! thread-count **and** grain-size bit-identity, which the
//! `prop_invariants` suite pins.
//!
//! Work functions must not carry state across grains that affects
//! results: per-worker scratch (via [`run_grains_with`]) is for
//! *allocation reuse* only (stamped marker arrays, SPA accumulators),
//! never for value accumulation.

use std::sync::atomic::{AtomicUsize, Ordering};

/// One worker's initial claim: a contiguous range of grain ids. `next`
/// is the claim cursor; claims at or past `end` mean the run is drained.
struct Run {
    next: AtomicUsize,
    end: usize,
}

/// Number of grains covering `n_items` items at `grain` items per grain.
///
/// Zero items means zero grains; `grain` must be at least 1.
#[must_use]
pub fn grain_count(n_items: usize, grain: usize) -> usize {
    assert!(grain > 0, "grain size must be >= 1");
    n_items.div_ceil(grain)
}

/// Half-open item range `[lo, hi)` covered by grain `g`.
#[must_use]
pub fn grain_span(g: usize, grain: usize, n_items: usize) -> (usize, usize) {
    let lo = (g * grain).min(n_items);
    let hi = ((g + 1) * grain).min(n_items);
    (lo, hi)
}

/// Default grain size: about eight grains per worker, so stealing has
/// enough slack to rebalance a skewed tail without paying per-item
/// claim overhead. The choice only affects speed, never output — see
/// the determinism contract above.
#[must_use]
pub fn default_grain(n_items: usize, nthreads: usize) -> usize {
    n_items.div_ceil(nthreads.max(1).saturating_mul(8)).max(1)
}

/// Run `work` over every grain and return the per-grain results in
/// ascending grain order. `work` receives `(grain_id, lo, hi)` where
/// `[lo, hi)` is the grain's item range.
pub fn run_grains<T, F>(n_items: usize, grain: usize, nthreads: usize, work: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize, usize) -> T + Sync,
{
    run_grains_with(n_items, grain, nthreads, || (), |(), g, lo, hi| work(g, lo, hi))
}

/// [`run_grains`] with per-worker scratch state: `init` runs once per
/// worker (and once on the serial path) and the resulting state is passed
/// mutably to every grain that worker claims. Scratch is for allocation
/// reuse only; results must not depend on which grains shared a state.
pub fn run_grains_with<S, T, I, F>(
    n_items: usize,
    grain: usize,
    nthreads: usize,
    init: I,
    work: F,
) -> Vec<T>
where
    S: Send,
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, usize, usize) -> T + Sync,
{
    let n_grains = grain_count(n_items, grain);
    if n_grains == 0 {
        return Vec::new();
    }
    let nthreads = nthreads.clamp(1, n_grains);
    if nthreads <= 1 {
        let mut state = init();
        return (0..n_grains)
            .map(|g| {
                let (lo, hi) = grain_span(g, grain, n_items);
                work(&mut state, g, lo, hi)
            })
            .collect();
    }

    // Contiguous runs of grains, one per worker; the last run absorbs
    // the remainder. A worker claims from its own run first (cache-warm,
    // contention-free), then steals from the runs after it in cyclic
    // order — victim order only shapes timing, never output.
    let per = n_grains.div_ceil(nthreads);
    let runs: Vec<Run> = (0..nthreads)
        .map(|w| Run {
            next: AtomicUsize::new((w * per).min(n_grains)),
            end: ((w + 1) * per).min(n_grains),
        })
        .collect();
    let runs = &runs;
    let work = &work;
    let init = &init;

    let mut parts: Vec<Vec<(usize, T)>> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..nthreads)
            .map(|w| {
                s.spawn(move || {
                    let mut state = init();
                    let mut out: Vec<(usize, T)> = Vec::new();
                    for v in (w..w + nthreads).map(|i| i % nthreads) {
                        loop {
                            let g = runs[v].next.fetch_add(1, Ordering::Relaxed);
                            if g >= runs[v].end {
                                break;
                            }
                            let (lo, hi) = grain_span(g, grain, n_items);
                            out.push((g, work(&mut state, g, lo, hi)));
                        }
                    }
                    out
                })
            })
            .collect();
        parts = handles.into_iter().map(|h| h.join().expect("grain worker panicked")).collect();
    });

    // Grain-indexed slot merge: the only step that touches ordering.
    let mut slots: Vec<Option<T>> = (0..n_grains).map(|_| None).collect();
    for (g, t) in parts.into_iter().flatten() {
        debug_assert!(slots[g].is_none(), "grain {g} claimed twice");
        slots[g] = Some(t);
    }
    slots.into_iter().map(|s| s.expect("every grain claimed exactly once")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_tile_the_item_range() {
        for n_items in [0usize, 1, 7, 8, 9, 100] {
            for grain in [1usize, 3, 8, 1000] {
                let n = grain_count(n_items, grain);
                let mut next = 0;
                for g in 0..n {
                    let (lo, hi) = grain_span(g, grain, n_items);
                    assert_eq!(lo, next, "n_items {n_items} grain {grain} g {g}");
                    assert!(hi > lo);
                    next = hi;
                }
                assert_eq!(next, n_items);
            }
        }
    }

    #[test]
    fn results_in_grain_order_for_every_thread_count_and_grain() {
        let n_items = 97usize;
        let expect: Vec<(usize, usize)> = run_grains(n_items, 5, 1, |g, lo, hi| {
            assert!(lo < hi && g == lo / 5);
            (lo, hi)
        });
        for grain in [1usize, 4, 5, 17, 1000] {
            for nthreads in [1usize, 2, 3, 4, 8, 64] {
                let got = run_grains(n_items, grain, nthreads, |_g, lo, hi| (lo, hi));
                // flatten to item coverage: identical regardless of grain
                let cover: Vec<usize> = got.iter().flat_map(|&(lo, hi)| lo..hi).collect();
                assert_eq!(cover, (0..n_items).collect::<Vec<_>>(), "grain {grain} t {nthreads}");
                if grain == 5 && nthreads > 1 {
                    assert_eq!(got, expect);
                }
            }
        }
    }

    #[test]
    fn scratch_state_is_reused_not_observable() {
        // per-worker scratch may be dirty from a previous grain; results
        // must come out identical as long as the work function re-stamps
        let per_grain = |scratch: &mut Vec<usize>, g: usize, lo: usize, hi: usize| {
            scratch.clear(); // correct use: reset before use
            scratch.extend(lo..hi);
            (g, scratch.iter().sum::<usize>())
        };
        let serial = run_grains_with(1000, 7, 1, Vec::new, per_grain);
        for nthreads in [2usize, 4, 8] {
            let par = run_grains_with(1000, 7, nthreads, Vec::new, per_grain);
            assert_eq!(par, serial, "t {nthreads}");
        }
    }

    #[test]
    fn zero_items_yield_no_grains() {
        assert_eq!(grain_count(0, 4), 0);
        let got: Vec<usize> = run_grains(0, 4, 8, |g, _, _| g);
        assert!(got.is_empty());
    }

    #[test]
    #[should_panic(expected = "grain size must be >= 1")]
    fn zero_grain_size_panics() {
        grain_count(10, 0);
    }

    #[test]
    fn thread_count_clamped_to_grain_count() {
        // more workers than grains: extra workers find empty runs and exit
        let got = run_grains(3, 1, 64, |g, lo, hi| (g, lo, hi));
        assert_eq!(got, vec![(0, 0, 1), (1, 1, 2), (2, 2, 3)]);
    }
}
