//! Summary statistics used by the benchmark harness (geometric mean,
//! percentiles, simple linear aggregates) — the quantities the paper
//! reports in Figs 6, 8 and 10.

/// Geometric mean of strictly positive values. Returns `None` on empty input
/// or any non-positive entry.
pub fn geomean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0 || !x.is_finite()) {
        return None;
    }
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    Some((log_sum / xs.len() as f64).exp())
}

/// Arithmetic mean; `None` on empty input.
pub fn mean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    Some(xs.iter().sum::<f64>() / xs.len() as f64)
}

/// Population standard deviation; `None` on empty input.
pub fn stddev(xs: &[f64]) -> Option<f64> {
    let m = mean(xs)?;
    Some((xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt())
}

/// Percentile by linear interpolation between order statistics
/// (`q` in `[0,100]`). `None` on empty input.
pub fn percentile(xs: &[f64], q: f64) -> Option<f64> {
    if xs.is_empty() || !(0.0..=100.0).contains(&q) {
        return None;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        Some(v[lo])
    } else {
        let frac = pos - lo as f64;
        Some(v[lo] * (1.0 - frac) + v[hi] * frac)
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> Option<f64> {
    percentile(xs, 50.0)
}

/// The `{p25, median, geomean, p75}` quartet reported in Fig 8 (left).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quartet {
    pub p25: f64,
    pub median: f64,
    pub geomean: f64,
    pub p75: f64,
}

/// Compute the Fig-8 quartet; `None` if the input is empty or non-positive.
pub fn quartet(xs: &[f64]) -> Option<Quartet> {
    Some(Quartet {
        p25: percentile(xs, 25.0)?,
        median: median(xs)?,
        geomean: geomean(xs)?,
        p75: percentile(xs, 75.0)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!(geomean(&[]).is_none());
        assert!(geomean(&[1.0, 0.0]).is_none());
        assert!(geomean(&[1.0, -2.0]).is_none());
        let g = geomean(&[2.0, 8.0]).unwrap();
        assert!((g - 4.0).abs() < 1e-12);
        let g1 = geomean(&[3.7]).unwrap();
        assert!((g1 - 3.7).abs() < 1e-12);
    }

    #[test]
    fn geomean_invariant_under_reorder() {
        let a = geomean(&[1.5, 2.5, 9.0, 0.25]).unwrap();
        let b = geomean(&[9.0, 0.25, 2.5, 1.5]).unwrap();
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), Some(1.0));
        assert_eq!(percentile(&xs, 100.0), Some(4.0));
        assert!((percentile(&xs, 50.0).unwrap() - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0).unwrap() - 1.75).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), Some(2.0));
        assert!((median(&[4.0, 1.0, 2.0, 3.0]).unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quartet_ordering() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let q = quartet(&xs).unwrap();
        assert!(q.p25 < q.median && q.median < q.p75);
        assert!(q.geomean < q.median); // geomean <= mean; skew pulls it low
    }

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), Some(5.0));
        assert!((stddev(&xs).unwrap() - 2.0).abs() < 1e-12);
    }
}
