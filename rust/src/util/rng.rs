//! Deterministic pseudo-random number generation.
//!
//! PCG-XSL-RR 128/64 (O'Neill, 2014): a small, fast, statistically strong
//! generator with a 128-bit state. Every synthetic matrix, property test and
//! workload in this crate is seeded, so all experiments are reproducible
//! bit-for-bit from the seed recorded in the harness output.

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream selector; distinct streams
    /// are independent even under equal seeds.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        let _ = rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        let _ = rng.next_u64();
        rng
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection to avoid
    /// modulo bias.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[-1, 1)` — the value distribution used for synthetic
    /// matrix entries (centered so accumulations don't drift).
    #[inline]
    pub fn signed_unit_f32(&mut self) -> f32 {
        self.next_f32() * 2.0 - 1.0
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (used by the power-law generator).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)`, ascending.
    ///
    /// Uses Floyd's algorithm so cost is `O(k)` expected regardless of `n`;
    /// this is on the matrix-generation hot path for very sparse rows of
    /// very wide matrices.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        if k == 0 {
            return Vec::new();
        }
        // For dense samples a shuffle prefix is cheaper than set probing.
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            let mut out = all[..k].to_vec();
            out.sort_unstable();
            return out;
        }
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below((j + 1) as u64) as usize;
            let pick = if chosen.insert(t) { t } else { j };
            if pick != t {
                chosen.insert(pick);
            }
            out.push(pick);
        }
        out.sort_unstable();
        out.dedup();
        debug_assert_eq!(out.len(), k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::with_stream(7, 1);
        let mut b = Pcg64::with_stream(7, 2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn next_below_is_in_range_and_hits_all_residues() {
        let mut rng = Pcg64::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.next_below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn unit_floats_in_range() {
        let mut rng = Pcg64::new(4);
        for _ in 0..1000 {
            let f = rng.next_f64();
            assert!((0.0..1.0).contains(&f));
            let g = rng.next_f32();
            assert!((0.0..1.0).contains(&g));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut rng = Pcg64::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn sample_distinct_correct_size_sorted_unique() {
        let mut rng = Pcg64::new(6);
        for &(n, k) in &[(10usize, 10usize), (100, 3), (1000, 50), (5, 0), (1, 1)] {
            let s = rng.sample_distinct(n, k);
            assert_eq!(s.len(), k, "n={n} k={k}");
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(7);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
