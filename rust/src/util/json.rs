//! Minimal JSON parser — just enough for `artifacts/manifest.json`
//! (serde is not in the offline crate cache).
//!
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null); numbers are kept as `f64`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at offset {}", p.pos);
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Typed accessors.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Number as f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Number as usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// Array access.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object access.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `json.at(&["entries", "spgemm_bundle", "file"])`.
    pub fn at(&self, path: &[&str]) -> Result<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur
                .get(key)
                .with_context(|| format!("missing key `{key}` in JSON path {path:?}"))?;
        }
        Ok(cur)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        match self.bump() {
            Some(x) if x == b => Ok(()),
            other => bail!("expected {:?} at {}, got {other:?}", b as char, self.pos),
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {other:?} at {}", self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => bail!("expected ',' or '}}', got {other:?} at {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => bail!("expected ',' or ']', got {other:?} at {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => bail!("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().context("truncated \\u escape")?;
                            code = code * 16
                                + (c as char).to_digit(16).context("bad \\u escape")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => bail!("bad escape {other:?}"),
                },
                Some(c) => out.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| format!("bad number `{text}`"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
            "format": "hlo-text",
            "entries": {
                "spgemm_bundle": {
                    "file": "spgemm_bundle.hlo.txt",
                    "params": {"batch": 16, "bundle": 32, "tile_w": 256},
                    "args": [{"shape": [16], "dtype": "int32"}]
                }
            }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.at(&["format"]).unwrap().as_str(), Some("hlo-text"));
        assert_eq!(
            j.at(&["entries", "spgemm_bundle", "params", "tile_w"]).unwrap().as_usize(),
            Some(256)
        );
        let args = j.at(&["entries", "spgemm_bundle", "args"]).unwrap().as_arr().unwrap();
        assert_eq!(args[0].get("dtype").unwrap().as_str(), Some("int32"));
    }

    #[test]
    fn scalars_and_arrays() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("[1, 2, 3]").unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn as_usize_rejects_non_integers() {
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    }
}
