//! Small self-contained utilities.
//!
//! The build image is offline and its crate cache only carries the `xla`
//! closure, so the conveniences that would normally come from `rand`,
//! `clap` or `criterion` live here instead: a deterministic PRNG
//! ([`rng::Pcg64`]), summary statistics ([`stats`]), a wall-clock
//! measurement helper ([`timer`]), a tiny CLI argument parser ([`cli`]) and
//! an ASCII/CSV table renderer ([`table`]).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

pub use rng::Pcg64;
pub use timer::Timer;
