//! Small self-contained utilities.
//!
//! The build image is offline and its crate cache only carries the `xla`
//! closure, so the conveniences that would normally come from `rand`,
//! `clap` or `criterion` live here instead: a deterministic PRNG
//! ([`rng::Pcg64`]), summary statistics ([`stats`]), a wall-clock
//! measurement helper ([`timer`]), a tiny CLI argument parser ([`cli`]),
//! an ASCII/CSV table renderer ([`table`]) and the deterministic
//! work-stealing executor behind every parallel CPU pass ([`grains`]).

pub mod cli;
pub mod grains;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

pub use rng::Pcg64;
pub use timer::Timer;

/// Worker count for the CPU preprocessing pool (scheduling, RIR encoding,
/// the scheduled numeric path). `REAP_CPU_THREADS` overrides; otherwise
/// the host parallelism, capped at 16 (the paper's Xeon 6130 core count —
/// beyond that the passes are memory-bound and extra workers only add
/// merge overhead).
pub fn preprocess_threads() -> usize {
    std::env::var("REAP_CPU_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .clamp(1, 16)
}
