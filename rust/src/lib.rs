//! # REAP — synergistic CPU–FPGA acceleration of sparse linear algebra
//!
//! Reproduction of Soltaniyeh, Martin, Nagarakatte, *"Synergistic CPU-FPGA
//! Acceleration of Sparse Linear Algebra"* (Rutgers DCS-TR-750, 2020).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** plays the role of REAP's CPU: it converts standard
//!   sparse formats into the RIR intermediate representation
//!   ([`rir`]), performs the Cholesky symbolic analysis ([`symbolic`]),
//!   schedules bundles onto pipelines, and hosts the cycle-level model of
//!   the FPGA ([`fpga`]) plus the measured CPU baselines ([`kernels`]).
//! * **L2/L1 (build-time Python)** express the FPGA datapath arithmetic as a
//!   JAX graph whose hot spot is a Pallas kernel; `make artifacts` lowers it
//!   once to HLO text under `artifacts/`.
//! * **[`runtime`]** loads those artifacts through the PJRT C API (the `xla`
//!   crate) and executes them from the coordinator's request path — Python
//!   never runs at request time.
//!
//! Four workloads ride the same CPU→RIR→FPGA flow: SpGEMM (the paper's
//! primary kernel, single-job and multi-tenant batched), sparse Cholesky,
//! SpMV, and SpMM (k dense right-hand sides over one SpMV wave schedule).
//! The headline entry points are [`rir::schedule::schedule_spgemm`] (the
//! CPU scheduling pass), [`coordinator::ReapBatch`] (multi-tenant shared
//! waves) and [`coordinator::ReapSpmm`] (multi-vector) — each carries a
//! runnable doctest. The [`serving`] module drives the same stack online:
//! a deterministic event loop with latency-budgeted admission control and
//! a fingerprint-keyed schedule cache that lets repeat sparsity patterns
//! skip the CPU pass.
//!
//! **`ARCHITECTURE.md`** (repo root) is the written spec: the dataflow,
//! the module map, the RIR wire format byte-for-byte, and the invariants
//! (wave monotonicity, bit-identical decompose/replay, thread-invariance)
//! every layer maintains — including the checksummed wire format and the
//! fault/retry model exercised by [`reliability`]. See `EXPERIMENTS.md`
//! for paper-vs-measured results and the per-figure methodology notes.
//!
//! The whole crate is written in safe Rust (`#![forbid(unsafe_code)]`,
//! guarded in CI), and [`analysis`] — `reap lint` — statically audits
//! every schedule, serialized RIR stream and wave-cost sequence the
//! coordinators produce.

#![forbid(unsafe_code)]

pub mod analysis;
pub mod coordinator;
pub mod fpga;
pub mod harness;
pub mod kernels;
pub mod reliability;
pub mod rir;
pub mod runtime;
pub mod serving;
pub mod sparse;
pub mod symbolic;
pub mod testing;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
