//! Online serving runtime: arrivals, admission control, and a
//! fingerprint-keyed schedule cache over the wave engine.
//!
//! REAP's offline story amortizes one CPU scheduling pass over many FPGA
//! executions of the same matrix. This module is the *online* version of
//! that bargain: multi-tenant SpGEMM/SpMV jobs arrive continuously under
//! a configurable process ([`arrival`]), a latency-budgeted admission
//! controller closes batching windows and packs shared-wave batches
//! ([`admission`]), and a sparsity-pattern fingerprint cache lets repeat
//! structures skip the scheduling pass entirely ([`cache`]) — with the
//! hard guarantee that a cache hit replays **bit-identically** to cold
//! scheduling, so caching changes *when* answers arrive, never *what*
//! they are.
//!
//! The event loop ([`sim`]) is a seed-deterministic discrete-event
//! simulation: every latency percentile, queue depth and cycle total it
//! reports is a pure function of the workload spec and the design point —
//! no wall clock, no thread-count sensitivity. Admitted batches pass
//! [`crate::analysis::audit_serving`] (plus the schedule and wave-cost
//! audits) before anything is priced.
//!
//! `reap bench serving` sweeps design points and repeat ratios and writes
//! `results/BENCH_serving.json`; ARCHITECTURE.md §9 specifies the event
//! loop, the admission contract and the cache-key definition.

pub mod admission;
pub mod arrival;
pub mod cache;
pub mod sim;

pub use admission::{close_window, AdmissionConfig, QueuedJob, WindowDecision};
pub use arrival::{generate_workload, ArrivalProcess, JobKind, ServingJob, WorkloadSpec};
pub use cache::{pattern_fingerprint, ScheduleCache};
pub use sim::{
    modeled_cold_cpu_s, percentile, run_serving, BatchRecord, JobRecord, ServingConfig, ServingLog,
    ServingReport, COLD_PASS_BASE_S, COLD_PASS_WORD_S, HIT_LOOKUP_S,
};
