//! The serving event loop: a seed-deterministic discrete-event simulation
//! of online tenants over the wave engine.
//!
//! Time advances in fixed batching windows
//! ([`AdmissionConfig::window_s`]). At each window close the loop pulls
//! every job that has arrived, lets the admission controller
//! ([`super::admission`]) shed dead-deadline jobs and pack a batch, then
//! services the batch: per-job schedules come from the fingerprint-keyed
//! [`ScheduleCache`] (or the cold CPU pass when caching is off), are
//! composed into one shared-wave [`BatchSchedule`] via
//! [`compose_batch`], audited, and priced by the cycle-exact batch
//! simulator. Per-job completion uses the simulator's enqueue/complete
//! stamps — the serving layer never re-derives latency from wave indices.
//!
//! Two modeling rules keep every number a pure function of the workload
//! spec (the determinism the test suite pins):
//!
//! * **No wall clock.** Cold scheduling is charged by
//!   [`modeled_cold_cpu_s`] — an affine model over the schedule's own
//!   word/chunk counts — and cache hits by [`HIT_LOOKUP_S`]; measured
//!   `prep_cpu_s`/`wave_cpu_s` samples are stripped and ignored.
//! * **Admission ignores backlog.** Batch membership depends only on the
//!   arrival trace and matrix structure, so cache on/off and any thread
//!   count compose identical batches; only *when* they finish differs.

use anyhow::Result;

use crate::coordinator::batch::numeric_batch;
use crate::fpga::spgemm_sim::{simulate_spgemm_batch, Style};
use crate::fpga::{execute_waves_at_depth, FpgaConfig};
use crate::rir::schedule::{
    compose_batch, schedule_spgemm_with_threads, BatchSchedule, SpgemmSchedule,
};
use crate::sparse::Csr;
use crate::util::preprocess_threads;

use super::admission::{close_window, AdmissionConfig, QueuedJob};
use super::arrival::ServingJob;
use super::cache::{fnv_mix, ScheduleCache, FNV_OFFSET};

/// Fixed base cost of one cold CPU scheduling pass (thread spawn,
/// prologue) in the deterministic service model.
pub const COLD_PASS_BASE_S: f64 = 2e-6;
/// Modeled cost per word/chunk unit of the cold pass.
pub const COLD_PASS_WORD_S: f64 = 1.25e-9;
/// Modeled cost of a cache hit: one fingerprint + key compare.
pub const HIT_LOOKUP_S: f64 = 150e-9;

/// The deterministic model of what a cold CPU scheduling pass costs —
/// an affine function of the schedule's own structure (streamed words,
/// chunks, waves), never of measured wall-clock time.
pub fn modeled_cold_cpu_s(s: &SpgemmSchedule) -> f64 {
    let units = s.a_words + s.b_words + 16 * s.n_chunks() + 8 * s.n_waves();
    COLD_PASS_BASE_S + units as f64 * COLD_PASS_WORD_S
}

/// Configuration of one serving run.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    pub fpga: FpgaConfig,
    pub admission: AdmissionConfig,
    /// Serve repeat patterns from the schedule cache. Off, every job pays
    /// the cold pass — the baseline the speedup sweep compares against.
    pub use_cache: bool,
    /// Fingerprint mask handed to [`ScheduleCache::with_mask`]
    /// (`u64::MAX` in production; narrowed in collision tests).
    pub cache_mask: u64,
    /// CPU workers for scheduling/numeric replay; `0` means the crate
    /// default ([`preprocess_threads`]). Results are identical for every
    /// value — pinned by `tests/integration_serving.rs`.
    pub threads: usize,
    /// Audit schedules, wave costs and the admission log even in release
    /// builds (debug builds always audit).
    pub strict: bool,
    /// Run the numeric replay per batch and fold the outputs into
    /// [`ServingReport::output_digest`] (tests; off in benches).
    pub verify_numerics: bool,
    /// Stop after this many windows even if jobs remain queued (they are
    /// reported in [`ServingLog::queued`]). `None` runs until drained.
    pub max_windows: Option<usize>,
}

impl ServingConfig {
    pub fn new(fpga: FpgaConfig) -> Self {
        ServingConfig {
            fpga,
            admission: AdmissionConfig::default(),
            use_cache: true,
            cache_mask: u64::MAX,
            threads: 0,
            strict: false,
            verify_numerics: false,
            max_windows: None,
        }
    }
}

/// One admitted job's timeline entry in the serving log.
#[derive(Clone, Debug, PartialEq)]
pub struct JobRecord {
    pub id: usize,
    pub arrival_s: f64,
    /// Completion time: batch start + modeled CPU phase + the job's
    /// simulated `complete_cycle` at the design clock.
    pub complete_s: f64,
    /// The job's schedule came from the cache.
    pub cached: bool,
}

/// One executed batch in the serving log.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchRecord {
    /// The window close that admitted this batch.
    pub window_close_s: f64,
    /// Service start: the window close or the device becoming free,
    /// whichever is later.
    pub start_s: f64,
    /// Modeled CPU phase (cold passes + hit lookups).
    pub cpu_s: f64,
    /// Simulated FPGA seconds at the configured channel depth.
    pub fpga_s: f64,
    pub jobs: Vec<JobRecord>,
}

/// The complete, auditable record of a serving run — what
/// [`crate::analysis::audit_serving`] checks.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServingLog {
    pub latency_budget_s: f64,
    /// Jobs whose arrival fell inside the simulated horizon.
    pub arrived: usize,
    pub admitted: usize,
    pub rejected: usize,
    /// Jobs still waiting when the run stopped (nonzero only under
    /// [`ServingConfig::max_windows`]).
    pub queued: usize,
    pub batches: Vec<BatchRecord>,
}

/// Everything `reap bench serving` reports per design point.
#[derive(Clone, Debug)]
pub struct ServingReport {
    pub log: ServingLog,
    /// Nearest-rank latency percentiles over admitted jobs (seconds).
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_s: f64,
    /// Admitted jobs over the span from first arrival to last completion.
    pub jobs_per_s: f64,
    /// Queue depth sampled after each window close.
    pub queue_depth_mean: f64,
    pub queue_depth_max: usize,
    pub hits: u64,
    pub misses: u64,
    pub collisions: u64,
    pub hit_rate: f64,
    /// Deterministic cycle totals summed over batches (configured depth,
    /// depth 1, depth 2) — the perf-gate currency of `BENCH_serving.json`.
    pub cycles: u64,
    pub cycles_serial: u64,
    pub cycles_db: u64,
    pub prefetch_hidden_cycles: u64,
    pub waves: u64,
    /// FNV digest of every composed [`BatchSchedule`]'s structure, in
    /// batch order. Equal digests ⇔ bit-identical schedule replay (the
    /// cache-on vs cold acceptance headline).
    pub schedule_digest: u64,
    /// FNV digest of the numeric outputs (`0` unless
    /// [`ServingConfig::verify_numerics`]).
    pub output_digest: u64,
    /// `(job id, latency)` per admitted job, in completion (batch, run)
    /// order — the exact values the determinism tests compare.
    pub latencies_s: Vec<(usize, f64)>,
}

/// Run the serving simulation over a workload trace (jobs must be
/// arrival-ordered, as [`generate_workload`](super::generate_workload)
/// produces them).
pub fn run_serving(cfg: &ServingConfig, jobs: &[ServingJob]) -> Result<ServingReport> {
    cfg.fpga.validate()?;
    assert!(
        jobs.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s),
        "serving jobs must be arrival-ordered"
    );
    assert!(
        jobs.iter().enumerate().all(|(i, j)| j.id == i),
        "serving job ids must be their trace positions"
    );
    let nthreads = if cfg.threads == 0 { preprocess_threads() } else { cfg.threads };
    let audits = cfg!(debug_assertions) || cfg.strict;
    let (pipelines, bundle_size) = (cfg.fpga.pipelines, cfg.fpga.bundle_size);
    let hz = cfg.fpga.hz();
    let mut cache = if cfg.use_cache {
        Some(ScheduleCache::with_mask(pipelines, bundle_size, cfg.cache_mask))
    } else {
        None
    };

    let mut log = ServingLog {
        latency_budget_s: cfg.admission.latency_budget_s,
        ..ServingLog::default()
    };
    let mut queue: Vec<usize> = Vec::new();
    let mut next_arrival = 0usize;
    let mut device_free_s = 0.0f64;
    let mut depth_samples: Vec<usize> = Vec::new();
    let (mut cycles, mut cycles_serial, mut cycles_db) = (0u64, 0u64, 0u64);
    let (mut prefetch_hidden, mut waves) = (0u64, 0u64);
    let mut schedule_digest = FNV_OFFSET;
    let mut output_digest = FNV_OFFSET;
    let mut latencies: Vec<(usize, f64)> = Vec::new();

    let mut window = 1usize;
    loop {
        let now = window as f64 * cfg.admission.window_s;
        while next_arrival < jobs.len() && jobs[next_arrival].arrival_s <= now {
            queue.push(next_arrival);
            next_arrival += 1;
        }

        let view: Vec<QueuedJob> = queue
            .iter()
            .map(|&ix| QueuedJob {
                id: jobs[ix].id,
                arrival_s: jobs[ix].arrival_s,
                est_service_s: cfg.admission.estimated_service_s(&jobs[ix].a, &jobs[ix].b),
            })
            .collect();
        let decision = close_window(&cfg.admission, now, &view);
        log.rejected += decision.rejected.len();
        queue.retain(|&ix| {
            !decision.admitted.contains(&jobs[ix].id) && !decision.rejected.contains(&jobs[ix].id)
        });

        if !decision.admitted.is_empty() {
            let admitted: Vec<&ServingJob> =
                decision.admitted.iter().map(|&id| &jobs[id]).collect();
            let mut singles = Vec::with_capacity(admitted.len());
            let mut cached_flags = Vec::with_capacity(admitted.len());
            let mut cpu_s = 0.0f64;
            for job in &admitted {
                let (single, hit) = match cache.as_mut() {
                    Some(c) => c.get_or_schedule(&job.a, &job.b, nthreads),
                    None => {
                        let mut s = schedule_spgemm_with_threads(
                            &job.a,
                            &job.b,
                            pipelines,
                            bundle_size,
                            nthreads,
                        );
                        s.prep_cpu_s = 0.0;
                        s.wave_cpu_s = vec![0.0; s.wave_cpu_s.len()];
                        (s, false)
                    }
                };
                cpu_s += if hit { HIT_LOOKUP_S } else { modeled_cold_cpu_s(&single) };
                cached_flags.push(hit);
                singles.push(single);
            }
            let schedule = compose_batch(&singles, pipelines, bundle_size);
            let pairs: Vec<(Csr, Csr)> =
                admitted.iter().map(|j| (j.a.clone(), j.b.clone())).collect();
            if audits {
                let diags = crate::analysis::audit_batch_schedule(&pairs, &schedule);
                crate::analysis::ensure_clean(diags)?;
            }
            let sim = simulate_spgemm_batch(&pairs, &schedule, &cfg.fpga, Style::HandCoded);
            if audits {
                let diags = crate::analysis::audit_wave_costs(&sim.costs, &cfg.fpga);
                crate::analysis::ensure_clean(diags)?;
            }
            let fpga_s = sim.stats.seconds(&cfg.fpga);
            cycles += sim.stats.cycles;
            waves += sim.stats.waves;
            let at_depth = |d: usize| {
                if cfg.fpga.dram_buffer_depth == d {
                    sim.stats.clone()
                } else {
                    execute_waves_at_depth(&sim.costs, &cfg.fpga, d).stats
                }
            };
            cycles_serial += at_depth(1).cycles;
            let db = at_depth(2);
            cycles_db += db.cycles;
            prefetch_hidden += db.prefetch_hidden_cycles;
            schedule_digest = digest_batch_schedule(schedule_digest, &schedule);

            if cfg.verify_numerics {
                for out in numeric_batch(&pairs, &schedule, nthreads) {
                    output_digest = digest_csr(output_digest, &out);
                }
            }

            let start_s = now.max(device_free_s);
            let records: Vec<JobRecord> = admitted
                .iter()
                .zip(&sim.job_stats)
                .zip(&cached_flags)
                .map(|((job, js), &cached)| {
                    let complete_s = start_s + cpu_s + js.complete_cycle as f64 / hz;
                    latencies.push((job.id, complete_s - job.arrival_s));
                    JobRecord { id: job.id, arrival_s: job.arrival_s, complete_s, cached }
                })
                .collect();
            device_free_s = start_s + cpu_s + fpga_s;
            log.admitted += records.len();
            log.batches.push(BatchRecord {
                window_close_s: now,
                start_s,
                cpu_s,
                fpga_s,
                jobs: records,
            });
        }

        depth_samples.push(queue.len());
        if next_arrival == jobs.len() && queue.is_empty() {
            break;
        }
        if cfg.max_windows.is_some_and(|m| window >= m) {
            break;
        }
        window += 1;
    }

    log.arrived = next_arrival;
    log.queued = queue.len();
    if audits {
        let diags = crate::analysis::audit_serving(&log);
        crate::analysis::ensure_clean(diags)?;
    }

    let mut sorted: Vec<f64> = latencies.iter().map(|&(_, l)| l).collect();
    sorted.sort_by(f64::total_cmp);
    let mean_s =
        if sorted.is_empty() { 0.0 } else { sorted.iter().sum::<f64>() / sorted.len() as f64 };
    let span = {
        let first = jobs.first().map(|j| j.arrival_s).unwrap_or(0.0);
        let last = log
            .batches
            .iter()
            .flat_map(|b| b.jobs.iter().map(|j| j.complete_s))
            .fold(first, f64::max);
        last - first
    };
    let (hits, misses, collisions, hit_rate) = match &cache {
        Some(c) => (c.hits(), c.misses(), c.collisions(), c.hit_rate()),
        None => (0, log.admitted as u64, 0, 0.0),
    };
    Ok(ServingReport {
        p50_s: percentile(&sorted, 50.0),
        p95_s: percentile(&sorted, 95.0),
        p99_s: percentile(&sorted, 99.0),
        mean_s,
        jobs_per_s: if span > 0.0 { log.admitted as f64 / span } else { 0.0 },
        queue_depth_mean: if depth_samples.is_empty() {
            0.0
        } else {
            depth_samples.iter().sum::<usize>() as f64 / depth_samples.len() as f64
        },
        queue_depth_max: depth_samples.iter().copied().max().unwrap_or(0),
        hits,
        misses,
        collisions,
        hit_rate,
        cycles,
        cycles_serial,
        cycles_db,
        prefetch_hidden_cycles: prefetch_hidden,
        waves,
        schedule_digest,
        output_digest: if cfg.verify_numerics { output_digest } else { 0 },
        latencies_s: latencies,
        log,
    })
}

/// Nearest-rank percentile over an ascending-sorted slice (`0.0` when
/// empty). Nearest-rank picks actual samples, so
/// `p50 ≤ p95 ≤ p99` holds by construction.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Fold a composed schedule's full structure into an FNV digest: equal
/// digests mean wave-for-wave, word-for-word identical schedules.
fn digest_batch_schedule(mut h: u64, s: &BatchSchedule) -> u64 {
    h = fnv_mix(h, s.pipelines as u64);
    h = fnv_mix(h, s.bundle_size as u64);
    h = fnv_mix(h, s.n_jobs as u64);
    h = fnv_mix(h, s.waves.len() as u64);
    for w in &s.waves {
        h = fnv_mix(h, w.assignments.len() as u64);
        for &(job, asg) in &w.assignments {
            h = fnv_mix(h, u64::from(job));
            h = fnv_mix(h, u64::from(asg.a_row));
            h = fnv_mix(h, u64::from(asg.chunk));
            h = fnv_mix(h, u64::from(asg.last_chunk));
            h = fnv_mix(h, asg.start as u64);
            h = fnv_mix(h, asg.len as u64);
        }
        for seg in &w.segments {
            h = fnv_mix(h, u64::from(seg.job));
            h = fnv_mix(h, seg.b_rows.len() as u64);
            for &r in &seg.b_rows {
                h = fnv_mix(h, u64::from(r));
            }
        }
    }
    h = fnv_mix(h, s.a_words as u64);
    fnv_mix(h, s.b_words as u64)
}

/// Fold a CSR's exact contents (values as IEEE bit patterns) into an FNV
/// digest — bitwise output identity, not approximate equality.
fn digest_csr(mut h: u64, c: &Csr) -> u64 {
    h = fnv_mix(h, c.nrows as u64);
    h = fnv_mix(h, c.ncols as u64);
    for &p in &c.row_ptr {
        h = fnv_mix(h, p as u64);
    }
    for &j in &c.cols {
        h = fnv_mix(h, u64::from(j));
    }
    for &v in &c.vals {
        h = fnv_mix(h, u64::from(v.to_bits()));
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::arrival::{generate_workload, WorkloadSpec};

    fn quick_cfg() -> ServingConfig {
        ServingConfig::new(FpgaConfig::reap64_spgemm())
    }

    #[test]
    fn drains_and_conserves() {
        let jobs = generate_workload(&WorkloadSpec::poisson(0x5EA9, 30, 30_000.0, 0.6));
        let rep = run_serving(&quick_cfg(), &jobs).unwrap();
        assert_eq!(rep.log.arrived, 30);
        assert_eq!(rep.log.admitted + rep.log.rejected + rep.log.queued, rep.log.arrived);
        assert_eq!(rep.log.queued, 0, "an unbounded run must drain");
        assert_eq!(rep.latencies_s.len(), rep.log.admitted);
        assert!(rep.p50_s <= rep.p95_s && rep.p95_s <= rep.p99_s);
        assert!(rep.log.admitted > 0, "a mild workload must admit jobs");
        assert!(rep.jobs_per_s > 0.0);
    }

    #[test]
    fn horizon_cutoff_reports_queued_jobs() {
        let mut cfg = quick_cfg();
        cfg.max_windows = Some(1);
        let jobs = generate_workload(&WorkloadSpec::poisson(11, 40, 1_000_000.0, 0.5));
        let rep = run_serving(&cfg, &jobs).unwrap();
        assert_eq!(rep.log.admitted + rep.log.rejected + rep.log.queued, rep.log.arrived);
        assert!(rep.log.queued > 0, "a 1-window horizon must strand arrivals");
    }

    #[test]
    fn tiny_budget_rejects_everything() {
        let mut cfg = quick_cfg();
        cfg.admission.latency_budget_s = 1e-9;
        let jobs = generate_workload(&WorkloadSpec::poisson(13, 12, 30_000.0, 0.5));
        let rep = run_serving(&cfg, &jobs).unwrap();
        assert_eq!(rep.log.admitted, 0);
        assert_eq!(rep.log.rejected, 12);
        assert_eq!(rep.p50_s, 0.0);
        assert!(rep.log.batches.is_empty());
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 95.0), 4.0);
        assert_eq!(percentile(&xs, 99.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
    }

    #[test]
    fn cache_changes_time_never_schedules() {
        let jobs = generate_workload(&WorkloadSpec::poisson(0x5EA9, 40, 30_000.0, 0.9));
        let mut on = quick_cfg();
        on.verify_numerics = true;
        let mut off = on.clone();
        off.use_cache = false;
        let r_on = run_serving(&on, &jobs).unwrap();
        let r_off = run_serving(&off, &jobs).unwrap();
        assert_eq!(r_on.schedule_digest, r_off.schedule_digest, "replay must be bit-identical");
        assert_eq!(r_on.output_digest, r_off.output_digest, "numerics must be bit-identical");
        assert_eq!(r_on.cycles, r_off.cycles);
        assert_eq!(r_on.log.admitted, r_off.log.admitted);
        assert!(r_on.hits > 0, "a 0.9 repeat ratio must hit");
        assert!(
            r_on.mean_s < r_off.mean_s,
            "hits must strictly lower mean latency: {} vs {}",
            r_on.mean_s,
            r_off.mean_s
        );
    }
}
