//! Latency-budgeted admission control and priority packing.
//!
//! The controller closes a batching window every
//! [`AdmissionConfig::window_s`] seconds. At each close it walks the
//! queue oldest-first (the priority packer's rule: age is priority, ties
//! broken by id — both deterministic) and, per job:
//!
//! 1. **Shed**: if the job's age plus its modeled service estimate
//!    already exceeds [`AdmissionConfig::latency_budget_s`], it cannot
//!    possibly meet its budget — reject it now rather than burn FPGA
//!    waves on a dead deadline.
//! 2. **Admit**: otherwise pack it into this window's batch, up to
//!    [`AdmissionConfig::max_batch_jobs`] jobs.
//! 3. **Defer**: jobs past the capacity cut stay queued for the next
//!    window (they age, which raises their priority).
//!
//! The admission contract (ARCHITECTURE.md §9): decisions depend only on
//! the clock, the queue and per-job *structural* estimates — never on
//! accelerator backlog or measured wall-clock times. That makes batch
//! membership a pure function of the arrival trace, so runs with the
//! schedule cache on and off compose identical batches (the bit-identical
//! replay the acceptance headline asserts) and every thread count sees
//! the same decisions.

use crate::sparse::Csr;

/// Admission-controller tuning for one serving run.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Batching-window cadence (seconds between window closes).
    pub window_s: f64,
    /// End-to-end latency budget each admitted job must plausibly meet.
    pub latency_budget_s: f64,
    /// Capacity cut of the priority packer, per window.
    pub max_batch_jobs: usize,
    /// Modeled service estimate: `est_base_s + est_per_nnz_s · nnz(A)+nnz(B)`.
    pub est_base_s: f64,
    pub est_per_nnz_s: f64,
}

impl Default for AdmissionConfig {
    /// Defaults sized for the harness workloads (tens-of-µs jobs): 200 µs
    /// windows, a 2 ms budget and 16-job batches.
    fn default() -> Self {
        AdmissionConfig {
            window_s: 200e-6,
            latency_budget_s: 2e-3,
            max_batch_jobs: 16,
            est_base_s: 2e-6,
            est_per_nnz_s: 2e-9,
        }
    }
}

impl AdmissionConfig {
    /// The deterministic per-job service estimate the shed rule uses —
    /// a structural affine model, independent of backlog and wall clock.
    pub fn estimated_service_s(&self, a: &Csr, b: &Csr) -> f64 {
        self.est_base_s + self.est_per_nnz_s * (a.nnz() + b.nnz()) as f64
    }
}

/// The queue view the controller decides over: id, arrival and the
/// precomputed structural estimate.
#[derive(Clone, Copy, Debug)]
pub struct QueuedJob {
    pub id: usize,
    pub arrival_s: f64,
    pub est_service_s: f64,
}

/// Outcome of one window close: job ids to run now and job ids shed.
/// Everything else stays queued.
#[derive(Clone, Debug, Default)]
pub struct WindowDecision {
    pub admitted: Vec<usize>,
    pub rejected: Vec<usize>,
}

/// Close one window at `now_s` over `queue` (must be sorted oldest
/// first; the caller maintains arrival order, which is also id order).
pub fn close_window(cfg: &AdmissionConfig, now_s: f64, queue: &[QueuedJob]) -> WindowDecision {
    debug_assert!(
        queue.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s),
        "queue must be oldest-first"
    );
    let mut decision = WindowDecision::default();
    for q in queue {
        let age = now_s - q.arrival_s;
        if age + q.est_service_s > cfg.latency_budget_s {
            decision.rejected.push(q.id);
        } else if decision.admitted.len() < cfg.max_batch_jobs {
            decision.admitted.push(q.id);
        }
    }
    decision
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: usize, arrival_s: f64, est: f64) -> QueuedJob {
        QueuedJob { id, arrival_s, est_service_s: est }
    }

    #[test]
    fn packs_oldest_first_up_to_capacity() {
        let cfg = AdmissionConfig { max_batch_jobs: 2, ..AdmissionConfig::default() };
        let queue = [q(0, 0.0, 1e-6), q(1, 1e-5, 1e-6), q(2, 2e-5, 1e-6)];
        let d = close_window(&cfg, 1e-4, &queue);
        assert_eq!(d.admitted, vec![0, 1], "capacity cut keeps the oldest");
        assert!(d.rejected.is_empty(), "job 2 stays queued, not shed");
    }

    #[test]
    fn sheds_jobs_that_cannot_meet_the_budget() {
        let cfg = AdmissionConfig { latency_budget_s: 1e-3, ..AdmissionConfig::default() };
        let queue = [
            q(0, 0.0, 1e-6),     // age 2 ms alone busts the 1 ms budget
            q(1, 1.95e-3, 2e-4), // age 50 µs + est 200 µs fits
            q(2, 1.99e-3, 2e-3), // estimate alone busts the budget
        ];
        let d = close_window(&cfg, 2e-3, &queue);
        assert_eq!(d.rejected, vec![0, 2]);
        assert_eq!(d.admitted, vec![1]);
    }

    #[test]
    fn estimate_is_structural_and_monotone_in_nnz() {
        use crate::sparse::gen;
        let cfg = AdmissionConfig::default();
        let small = gen::random_uniform(20, 20, 60, 1);
        let big = gen::random_uniform(40, 40, 400, 2);
        let e_small = cfg.estimated_service_s(&small, &small);
        let e_big = cfg.estimated_service_s(&big, &big);
        assert!(e_big > e_small);
        assert!(e_small > cfg.est_base_s);
    }
}
