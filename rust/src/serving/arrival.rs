//! Seed-deterministic tenant workloads for the serving simulation.
//!
//! A workload is a finite trace of [`ServingJob`]s: arrival timestamps
//! drawn from a configurable [`ArrivalProcess`] plus, per job, the tenant
//! and its operand matrices. Tenants own small *pattern pools* — the
//! production shape this module models is solvers and recommenders
//! resubmitting the same sparsity structure continuously — and
//! [`WorkloadSpec::repeat_ratio`] sets the probability that a job reuses
//! a pool pattern (a schedule-cache hit candidate) instead of presenting
//! a fresh, never-seen structure.
//!
//! Everything is a pure function of the spec: matrices regenerate from
//! seeds derived only from `(seed, tenant, pool index)` or
//! `(seed, job id)`, and every random draw comes from the crate's own
//! [`Pcg64`], so the same spec yields the same trace on every host,
//! thread count and run (pinned in `tests/integration_serving.rs`).

use crate::sparse::gen::{self, Family};
use crate::sparse::Csr;
use crate::util::rng::Pcg64;

/// Inter-arrival model for the workload trace.
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `rate_hz` jobs/sec (exponential gaps).
    Poisson { rate_hz: f64 },
    /// On/off bursts: `burst` jobs back-to-back at `rate_hz`, then an
    /// `idle_s` silence before the next burst.
    BurstyOnOff { rate_hz: f64, burst: usize, idle_s: f64 },
    /// Replay recorded inter-arrival gaps (cycled when the trace is
    /// shorter than the workload).
    Trace { inter_arrival_s: Vec<f64> },
}

/// What a tenant submits: a full SpGEMM (`C = A × B`) or an SpMV
/// (`y = A x`, modeled as SpGEMM against an n×1 operand so the whole
/// schedule/simulate/replay path is shared).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    Spgemm,
    Spmv,
}

/// One submitted job of the serving trace.
#[derive(Clone, Debug)]
pub struct ServingJob {
    /// Position in the trace (stable across runs; ids are arrival-ordered).
    pub id: usize,
    pub tenant: u32,
    pub kind: JobKind,
    /// Arrival timestamp, seconds from simulation start (non-decreasing).
    pub arrival_s: f64,
    pub a: Csr,
    pub b: Csr,
}

/// Deterministic description of a serving workload.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub seed: u64,
    pub n_jobs: usize,
    /// Number of tenants; even-numbered tenants submit SpGEMM, odd ones
    /// SpMV (a fixed rule keeps the trace a pure function of the spec).
    pub tenants: u32,
    /// Patterns in each tenant's pool (≥ 1).
    pub pool_per_tenant: usize,
    /// Probability in `[0, 1]` that a job resubmits a pool pattern.
    pub repeat_ratio: f64,
    /// Base matrix dimension; pool patterns span `dim .. 2·dim` rows.
    pub dim: usize,
    pub process: ArrivalProcess,
}

impl WorkloadSpec {
    /// A small Poisson workload with the crate's default seed layout —
    /// the starting point the harness and tests perturb.
    pub fn poisson(seed: u64, n_jobs: usize, rate_hz: f64, repeat_ratio: f64) -> Self {
        WorkloadSpec {
            seed,
            n_jobs,
            tenants: 3,
            pool_per_tenant: 4,
            repeat_ratio,
            dim: 30,
            process: ArrivalProcess::Poisson { rate_hz },
        }
    }
}

/// Generate the full arrival trace for `spec`. Arrival times are
/// non-decreasing and jobs are id-ordered; the result is bit-identical
/// across runs and thread counts.
pub fn generate_workload(spec: &WorkloadSpec) -> Vec<ServingJob> {
    assert!(spec.tenants > 0, "workload needs at least one tenant");
    assert!(spec.pool_per_tenant > 0, "pattern pools must be non-empty");
    assert!(
        (0.0..=1.0).contains(&spec.repeat_ratio),
        "repeat_ratio must be a probability, got {}",
        spec.repeat_ratio
    );
    let mut rng = Pcg64::new(spec.seed);
    let mut t = 0.0f64;
    let mut burst_pos = 0usize;
    (0..spec.n_jobs)
        .map(|id| {
            t += match &spec.process {
                ArrivalProcess::Poisson { rate_hz } => exp_gap(&mut rng, *rate_hz),
                ArrivalProcess::BurstyOnOff { rate_hz, burst, idle_s } => {
                    let gap = if burst_pos == 0 && id > 0 {
                        *idle_s + exp_gap(&mut rng, *rate_hz)
                    } else {
                        exp_gap(&mut rng, *rate_hz)
                    };
                    burst_pos = (burst_pos + 1) % (*burst).max(1);
                    gap
                }
                ArrivalProcess::Trace { inter_arrival_s } => {
                    assert!(!inter_arrival_s.is_empty(), "trace replay needs at least one gap");
                    inter_arrival_s[id % inter_arrival_s.len()].max(0.0)
                }
            };
            let tenant = rng.next_below(u64::from(spec.tenants)) as u32;
            let kind = if tenant % 2 == 0 { JobKind::Spgemm } else { JobKind::Spmv };
            let repeat = rng.chance(spec.repeat_ratio);
            let (a, b) = if repeat {
                let k = rng.next_below(spec.pool_per_tenant as u64) as usize;
                pool_matrices(spec, tenant, k, kind)
            } else {
                fresh_matrices(spec, tenant, id, kind)
            };
            ServingJob { id, tenant, kind, arrival_s: t, a, b }
        })
        .collect()
}

fn exp_gap(rng: &mut Pcg64, rate_hz: f64) -> f64 {
    assert!(rate_hz > 0.0, "arrival rate must be positive");
    // inverse-CDF exponential; next_f64 < 1.0 so the log argument is > 0
    -(1.0 - rng.next_f64()).ln() / rate_hz
}

/// Pattern `k` of tenant `t`'s pool — a pure function of the spec, so a
/// repeat submission regenerates the *same* matrices (same structure,
/// same values) and fingerprints identically to its first appearance.
fn pool_matrices(spec: &WorkloadSpec, tenant: u32, k: usize, kind: JobKind) -> (Csr, Csr) {
    let n = spec.dim + (tenant as usize * 13 + k * 29) % spec.dim.max(1);
    let nnz = n * (3 + k % 4);
    let seed = spec.seed ^ (0x5EED_0000 + (u64::from(tenant) << 8) + k as u64);
    operands(n, nnz, seed, (tenant as usize + k) % 3, kind)
}

/// A never-seen structure: the seed and dimension mix in the job id, so
/// fresh jobs fingerprint uniquely and always miss the schedule cache.
fn fresh_matrices(spec: &WorkloadSpec, tenant: u32, id: usize, kind: JobKind) -> (Csr, Csr) {
    let n = spec.dim + (id * 17 + 5) % spec.dim.max(1);
    let nnz = n * (3 + id % 4);
    let seed = spec.seed ^ 0x0F5E_7000_0000 ^ ((id as u64) << 8) ^ u64::from(tenant);
    operands(n, nnz, seed, id % 3, kind)
}

fn operands(n: usize, nnz: usize, seed: u64, family_ix: usize, kind: JobKind) -> (Csr, Csr) {
    let family = match family_ix {
        0 => Family::RandomUniform,
        1 => Family::PowerLaw,
        _ => Family::BandedFem,
    };
    let a = gen::generate(family, n, nnz, seed);
    let b = match kind {
        JobKind::Spgemm => gen::random_uniform(n, n, nnz, seed ^ 1),
        // SpMV: a dense-ish n×1 operand (one column), same streamed path
        JobKind::Spmv => gen::random_uniform(n, 1, n, seed ^ 2),
    };
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let spec = WorkloadSpec::poisson(0x5EA9, 40, 50_000.0, 0.7);
        let w1 = generate_workload(&spec);
        let w2 = generate_workload(&spec);
        assert_eq!(w1.len(), 40);
        for (j1, j2) in w1.iter().zip(&w2) {
            assert_eq!(j1.id, j2.id);
            assert_eq!(j1.tenant, j2.tenant);
            assert_eq!(j1.arrival_s, j2.arrival_s);
            assert_eq!(j1.a, j2.a);
            assert_eq!(j1.b, j2.b);
        }
        assert!(w1.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s));
        assert!(w1[0].arrival_s > 0.0);
        // different seeds give different traces
        let other = generate_workload(&WorkloadSpec { seed: 7, ..spec });
        assert!(w1.iter().zip(&other).any(|(x, y)| x.arrival_s != y.arrival_s));
    }

    #[test]
    fn repeat_ratio_extremes() {
        let all = generate_workload(&WorkloadSpec::poisson(3, 60, 10_000.0, 1.0));
        // with a full repeat ratio every job draws from a pool of at most
        // tenants × pool_per_tenant distinct structures
        let mut dims: Vec<usize> = all.iter().map(|j| j.a.nrows).collect();
        dims.sort_unstable();
        dims.dedup();
        assert!(dims.len() <= 12, "pool reuse must bound distinct shapes: {dims:?}");
        // odd tenants are SpMV: their B is a single column
        for j in &all {
            match j.kind {
                JobKind::Spgemm => assert_eq!(j.b.ncols, j.a.nrows),
                JobKind::Spmv => assert_eq!(j.b.ncols, 1),
            }
            assert_eq!(j.a.ncols, j.b.nrows, "operands must chain");
        }
    }

    #[test]
    fn bursty_and_trace_processes_advance_time() {
        let bursty = generate_workload(&WorkloadSpec {
            process: ArrivalProcess::BurstyOnOff { rate_hz: 100_000.0, burst: 5, idle_s: 1e-3 },
            ..WorkloadSpec::poisson(9, 20, 0.0, 0.5)
        });
        assert!(bursty.windows(2).all(|p| p[0].arrival_s < p[1].arrival_s));
        // idle gaps dominate the horizon: 3 gaps of 1 ms
        assert!(bursty.last().unwrap().arrival_s > 3e-3);

        let replay = generate_workload(&WorkloadSpec {
            process: ArrivalProcess::Trace { inter_arrival_s: vec![1e-4, 2e-4] },
            ..WorkloadSpec::poisson(9, 10, 0.0, 0.5)
        });
        let gaps: Vec<f64> = replay.windows(2).map(|p| p[1].arrival_s - p[0].arrival_s).collect();
        for (i, g) in gaps.iter().enumerate() {
            let expect = if i % 2 == 0 { 2e-4 } else { 1e-4 };
            assert!((g - expect).abs() < 1e-12, "gap {i}: {g} vs {expect}");
        }
    }
}
