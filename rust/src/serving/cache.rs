//! Fingerprint-keyed schedule cache: repeat sparsity patterns skip the
//! CPU scheduling pass.
//!
//! REAP's economics rest on the one-time CPU pass being amortized over
//! repeated FPGA executions; production serving traffic re-submits the
//! same matrices (same mesh, same graph snapshot) continuously. The cache
//! keys a single-job [`SpgemmSchedule`] by a 64-bit FNV-1a fingerprint of
//! the *structure* of both operands — `row_ptr`/`cols` of A and B plus
//! the design geometry — never the numeric values, which the replay reads
//! from the live matrices. A fingerprint match alone is not trusted:
//! every bucket entry stores the full pattern key and lookups compare it
//! exactly, so a hash collision between structurally different matrices
//! is detected and rejected (counted in [`ScheduleCache::collisions`]),
//! never served. [`ScheduleCache::with_mask`] narrows the fingerprint to
//! force collisions in tests.
//!
//! Cached schedules are stored (and cold schedules returned) with their
//! measured timing fields zeroed, so a hit replays **bit-identically** to
//! a cold schedule: same waves, same `b_rows`, same word pricing —
//! property-tested in `tests/prop_serving.rs`.

use std::collections::BTreeMap;

use crate::rir::schedule::{schedule_spgemm_with_threads, SpgemmSchedule};
use crate::sparse::{Csr, Idx};

pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold one word into an FNV-1a accumulator (shared by the fingerprint
/// and the serving report's schedule/output digests).
pub(crate) fn fnv_mix(h: u64, word: u64) -> u64 {
    (h ^ word).wrapping_mul(FNV_PRIME)
}

fn fnv_usizes(mut h: u64, words: &[usize]) -> u64 {
    h = fnv_mix(h, words.len() as u64);
    for &w in words {
        h = fnv_mix(h, w as u64);
    }
    h
}

fn fnv_idxs(mut h: u64, words: &[Idx]) -> u64 {
    h = fnv_mix(h, words.len() as u64);
    for &w in words {
        h = fnv_mix(h, u64::from(w));
    }
    h
}

/// The sparsity-pattern fingerprint: FNV-1a 64 over the dimensions,
/// `row_ptr` and `cols` arrays of both operands, then the design geometry
/// (`pipelines`, `bundle_size` — a schedule built for one design must
/// never hit on another). Values are deliberately excluded: two matrices
/// that differ only numerically share a schedule.
///
/// ARCHITECTURE.md §9 walks a worked example of this exact fold.
pub fn pattern_fingerprint(a: &Csr, b: &Csr, pipelines: usize, bundle_size: usize) -> u64 {
    let mut h = FNV_OFFSET;
    for dim in [a.nrows, a.ncols, b.nrows, b.ncols] {
        h = fnv_mix(h, dim as u64);
    }
    h = fnv_usizes(h, &a.row_ptr);
    h = fnv_idxs(h, &a.cols);
    h = fnv_usizes(h, &b.row_ptr);
    h = fnv_idxs(h, &b.cols);
    h = fnv_mix(h, pipelines as u64);
    h = fnv_mix(h, bundle_size as u64);
    h
}

/// The exact structure a fingerprint stands for; compared verbatim on
/// every lookup so collisions cannot alias two patterns.
#[derive(Clone, Debug, PartialEq, Eq)]
struct PatternKey {
    a_dims: (usize, usize),
    b_dims: (usize, usize),
    a_row_ptr: Vec<usize>,
    a_cols: Vec<Idx>,
    b_row_ptr: Vec<usize>,
    b_cols: Vec<Idx>,
}

impl PatternKey {
    fn of(a: &Csr, b: &Csr) -> Self {
        PatternKey {
            a_dims: (a.nrows, a.ncols),
            b_dims: (b.nrows, b.ncols),
            a_row_ptr: a.row_ptr.clone(),
            a_cols: a.cols.clone(),
            b_row_ptr: b.row_ptr.clone(),
            b_cols: b.cols.clone(),
        }
    }
}

struct Entry {
    key: PatternKey,
    schedule: SpgemmSchedule,
}

/// Schedule cache for one design point (`pipelines` × `bundle_size`).
///
/// Iteration-order free by construction: buckets live in a [`BTreeMap`]
/// and lookups scan one bucket in insertion order, so behavior never
/// depends on a randomly seeded hasher.
pub struct ScheduleCache {
    pipelines: usize,
    bundle_size: usize,
    mask: u64,
    buckets: BTreeMap<u64, Vec<Entry>>,
    hits: u64,
    misses: u64,
    collisions: u64,
}

impl ScheduleCache {
    /// Cache with the full 64-bit fingerprint.
    pub fn new(pipelines: usize, bundle_size: usize) -> Self {
        Self::with_mask(pipelines, bundle_size, u64::MAX)
    }

    /// Cache whose fingerprints are masked down to `mask` — `0` maps every
    /// pattern to one bucket, making collision rejection testable.
    pub fn with_mask(pipelines: usize, bundle_size: usize, mask: u64) -> Self {
        assert!(pipelines > 0 && bundle_size > 0, "zero-valued cache geometry");
        ScheduleCache {
            pipelines,
            bundle_size,
            mask,
            buckets: BTreeMap::new(),
            hits: 0,
            misses: 0,
            collisions: 0,
        }
    }

    /// Look the pattern up; on a hit return the cached schedule (timing
    /// fields zeroed), on a miss run the cold CPU pass on `nthreads`
    /// workers, cache it and return it. The `bool` is `true` on a hit.
    ///
    /// Both paths return timing-stripped schedules, so hit and cold
    /// results are bit-identical whenever the structures match.
    pub fn get_or_schedule(
        &mut self,
        a: &Csr,
        b: &Csr,
        nthreads: usize,
    ) -> (SpgemmSchedule, bool) {
        let fp = pattern_fingerprint(a, b, self.pipelines, self.bundle_size) & self.mask;
        let key = PatternKey::of(a, b);
        if let Some(bucket) = self.buckets.get(&fp) {
            if let Some(e) = bucket.iter().find(|e| e.key == key) {
                self.hits += 1;
                return (e.schedule.clone(), true);
            }
            // same (masked) fingerprint, different structure: a collision
            // is rejected, never served
            self.collisions += 1;
        }
        self.misses += 1;
        let cold = strip_timing(schedule_spgemm_with_threads(
            a,
            b,
            self.pipelines,
            self.bundle_size,
            nthreads,
        ));
        self.buckets.entry(fp).or_default().push(Entry { key, schedule: cold.clone() });
        (cold, false)
    }

    /// Lookups that returned a cached schedule.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that fell through to the cold CPU pass.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Lookups whose fingerprint matched an entry with a *different*
    /// structure (always rejected; nonzero only under a narrowed mask or
    /// an astronomically unlikely 64-bit collision).
    pub fn collisions(&self) -> u64 {
        self.collisions
    }

    /// Hits over total lookups (`0.0` before any lookup).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Number of cached patterns.
    pub fn len(&self) -> usize {
        self.buckets.values().map(Vec::len).sum()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Zero the measured timing fields: a cached schedule's CPU cost was paid
/// once, at insertion; the serving simulation charges its own
/// deterministic cost model instead of stale wall-clock samples.
fn strip_timing(mut s: SpgemmSchedule) -> SpgemmSchedule {
    s.prep_cpu_s = 0.0;
    s.wave_cpu_s = vec![0.0; s.wave_cpu_s.len()];
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn mats(seed: u64) -> (Csr, Csr) {
        (gen::random_uniform(30, 30, 150, seed), gen::random_uniform(30, 30, 150, seed + 1))
    }

    #[test]
    fn second_lookup_hits_and_replays_bitwise() {
        let (a, b) = mats(1);
        let mut cache = ScheduleCache::new(8, 16);
        let (cold, hit0) = cache.get_or_schedule(&a, &b, 1);
        assert!(!hit0);
        let (warm, hit1) = cache.get_or_schedule(&a, &b, 1);
        assert!(hit1);
        assert_eq!(warm.waves, cold.waves);
        assert_eq!(warm.a_words, cold.a_words);
        assert_eq!(warm.b_words, cold.b_words);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
        assert!((cache.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fingerprint_sees_structure_not_values() {
        let (a, b) = mats(2);
        let mut a2 = a.clone();
        for v in &mut a2.vals {
            *v *= 3.0;
        }
        assert_eq!(pattern_fingerprint(&a, &b, 8, 16), pattern_fingerprint(&a2, &b, 8, 16));
        let mut a3 = a.clone();
        a3.cols[0] = a3.cols[0].wrapping_add(1);
        assert_ne!(pattern_fingerprint(&a, &b, 8, 16), pattern_fingerprint(&a3, &b, 8, 16));
        // design geometry is part of the key
        assert_ne!(pattern_fingerprint(&a, &b, 8, 16), pattern_fingerprint(&a, &b, 64, 16));
    }

    /// Pins the worked fingerprint fold in ARCHITECTURE.md §9.3 — if the
    /// fold order or constants change, the doc must change with it.
    #[test]
    fn architecture_md_fingerprint_worked_example() {
        let a = Csr::from_parts(2, 2, vec![0, 1, 2], vec![1, 0], vec![1.0, 1.0]).unwrap();
        let b = Csr::from_parts(2, 2, vec![0, 2, 3], vec![0, 1, 1], vec![0.5, -2.0, 4.0]).unwrap();
        assert_eq!(pattern_fingerprint(&a, &b, 8, 16), 0x0e0f_cedb_1cd2_bd89);
    }

    #[test]
    fn masked_collisions_are_rejected() {
        let (a1, b1) = mats(3);
        let (a2, b2) = (gen::power_law(24, 120, 9), gen::random_uniform(24, 24, 120, 10));
        let mut cache = ScheduleCache::with_mask(8, 16, 0);
        let (_, h1) = cache.get_or_schedule(&a1, &b1, 1);
        let (s2, h2) = cache.get_or_schedule(&a2, &b2, 1);
        assert!(!h1 && !h2, "different structures must never hit");
        assert_eq!(cache.collisions(), 1, "mask 0 forces a fingerprint collision");
        // the colliding pattern still got its own correct schedule
        let solo = schedule_spgemm_with_threads(&a2, &b2, 8, 16, 1);
        assert_eq!(s2.waves, solo.waves);
        // and both patterns now hit independently
        assert!(cache.get_or_schedule(&a1, &b1, 1).1);
        assert!(cache.get_or_schedule(&a2, &b2, 1).1);
    }
}
