//! The RIR bundle type (paper Fig 2).

use crate::sparse::{Idx, Val};

/// The paper's design point: "In our SpGEMM design, we use an RIR bundle
/// size of 32" (§III-A, also the CAM size).
pub const DEFAULT_BUNDLE_SIZE: usize = 32;

/// Bundle metadata flags (carried in the metadata word of the DRAM layout).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BundleFlags(pub u8);

impl BundleFlags {
    /// Last bundle of a (possibly split) source row/column: "the RIR bundle
    /// also includes additional metadata to indicate the end of a row".
    pub const END_OF_ROW: u8 = 0b0000_0001;
    /// Metadata-only bundle: pure scheduling information, no matrix data
    /// ("RIR bundles can sometimes carry purely the scheduling
    /// information").
    pub const METADATA_ONLY: u8 = 0b0000_0010;
    /// Final bundle of the whole stream (lets the FPGA input controller
    /// terminate without a separate length channel).
    pub const END_OF_STREAM: u8 = 0b0000_0100;
    /// Dense-panel bundle (SpMM): the payload is one row of the dense
    /// right-hand-side block X — shared feature = X row index, distinct
    /// features = lane (column) indices `0..k`. The input controller
    /// routes these to the on-chip panel RAM instead of the CAMs, so the
    /// sparse decoders skip them exactly like metadata-only bundles.
    pub const DENSE_PANEL: u8 = 0b0000_1000;
    /// Checksummed bundle: one CRC32 word (IEEE 802.3 polynomial over the
    /// bundle's preceding words, metadata word included) follows the
    /// payload in the serialized layout. The input controller verifies it
    /// before committing the bundle to a CAM; a mismatch aborts the wave
    /// and triggers a re-fetch (ARCHITECTURE.md §3.3/§7).
    pub const CHECKSUM: u8 = 0b0001_0000;
    /// Bitmap-indexed bundle (SMASH-style hierarchical bitmap): the
    /// distinct-feature indices are carried as a two-level bitmap section
    /// instead of explicit index words, chosen per bundle by exact byte
    /// accounting (`rir::layout::bitmap_index_words`). Setting either
    /// compression flag switches the payload from interleaved
    /// `(index, value)` pairs to an index section followed by a value
    /// section (ARCHITECTURE.md §3.4). Never set on metadata-only bundles.
    pub const BITMAP: u8 = 0b0010_0000;
    /// Fixed-point value lane: the bundle's values are quantized to Q1.15
    /// against a per-bundle f32 scale word and packed two per 32-bit word
    /// (`rir::layout::fx_value_words`; worst-case error bound in
    /// `rir::layout::fx_max_abs_error`). Selected per stream; like
    /// [`Self::BITMAP`] it implies the sectioned payload layout. Never set
    /// on metadata-only bundles.
    pub const FIXED_POINT: u8 = 0b0100_0000;

    pub fn end_of_row(self) -> bool {
        self.0 & Self::END_OF_ROW != 0
    }
    pub fn metadata_only(self) -> bool {
        self.0 & Self::METADATA_ONLY != 0
    }
    pub fn end_of_stream(self) -> bool {
        self.0 & Self::END_OF_STREAM != 0
    }
    pub fn dense_panel(self) -> bool {
        self.0 & Self::DENSE_PANEL != 0
    }
    pub fn checksum(self) -> bool {
        self.0 & Self::CHECKSUM != 0
    }
    pub fn bitmap(self) -> bool {
        self.0 & Self::BITMAP != 0
    }
    pub fn fixed_point(self) -> bool {
        self.0 & Self::FIXED_POINT != 0
    }
    /// True when either compression flag selects the sectioned payload
    /// layout (index section then value section) over interleaved pairs.
    pub fn sectioned(self) -> bool {
        self.bitmap() || self.fixed_point()
    }
    pub fn with(self, bit: u8) -> Self {
        BundleFlags(self.0 | bit)
    }
    /// Copy with `bit` cleared (decoders strip compression flags after
    /// expanding the payload back to raw pairs).
    pub fn without(self, bit: u8) -> Self {
        BundleFlags(self.0 & !bit)
    }
}

/// Scheduling triple for Cholesky metadata bundles (paper Fig 4(c)): row
/// index `r` of a nonzero in column k of L, and the start/end addresses of
/// row `r` of L in the FPGA's memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RlTriple {
    pub row: Idx,
    pub start: u32,
    pub end: u32,
}

/// Bundle payload: matrix data or pure scheduling metadata.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// `(distinct feature, value)` pairs — column indices for CSR-derived
    /// bundles, row indices for CSC-derived bundles.
    Data { distinct: Vec<Idx>, values: Vec<Val> },
    /// Metadata-only scheduling payload (Cholesky `RL` bundles).
    Schedule { triples: Vec<RlTriple> },
}

/// One RIR bundle.
#[derive(Clone, Debug, PartialEq)]
pub struct Bundle {
    /// The shared feature all elements of the bundle have in common.
    pub shared: Idx,
    pub flags: BundleFlags,
    pub payload: Payload,
}

impl Bundle {
    /// Data bundle from parallel slices.
    pub fn data(shared: Idx, distinct: Vec<Idx>, values: Vec<Val>, flags: BundleFlags) -> Self {
        debug_assert_eq!(distinct.len(), values.len());
        Bundle { shared, flags, payload: Payload::Data { distinct, values } }
    }

    /// Metadata-only scheduling bundle.
    pub fn schedule(shared: Idx, triples: Vec<RlTriple>, flags: BundleFlags) -> Self {
        Bundle {
            shared,
            flags: flags.with(BundleFlags::METADATA_ONLY),
            payload: Payload::Schedule { triples },
        }
    }

    /// Number of distinct elements carried.
    pub fn len(&self) -> usize {
        match &self.payload {
            Payload::Data { distinct, .. } => distinct.len(),
            Payload::Schedule { triples } => triples.len(),
        }
    }

    /// True if the bundle carries nothing (legal: an empty row still emits
    /// one end-of-row bundle so the FPGA's row accounting stays in sync).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Data accessors (panic on metadata bundles — programming error).
    pub fn distinct(&self) -> &[Idx] {
        match &self.payload {
            Payload::Data { distinct, .. } => distinct,
            Payload::Schedule { .. } => panic!("distinct() on a metadata-only bundle"),
        }
    }

    /// Value slice of a data bundle.
    pub fn values(&self) -> &[Val] {
        match &self.payload {
            Payload::Data { values, .. } => values,
            Payload::Schedule { .. } => panic!("values() on a metadata-only bundle"),
        }
    }

    /// Triples of a metadata bundle.
    pub fn triples(&self) -> &[RlTriple] {
        match &self.payload {
            Payload::Schedule { triples } => triples,
            Payload::Data { .. } => panic!("triples() on a data bundle"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_compose() {
        let f = BundleFlags::default()
            .with(BundleFlags::END_OF_ROW)
            .with(BundleFlags::END_OF_STREAM);
        assert!(f.end_of_row());
        assert!(f.end_of_stream());
        assert!(!f.metadata_only());
        assert!(!f.dense_panel());
        assert!(!f.checksum());
        assert!(!f.bitmap());
        assert!(!f.fixed_point());
        assert!(!f.sectioned());
        assert!(f.with(BundleFlags::DENSE_PANEL).dense_panel());
        assert!(f.with(BundleFlags::CHECKSUM).checksum());
        assert!(f.with(BundleFlags::BITMAP).bitmap());
        assert!(f.with(BundleFlags::BITMAP).sectioned());
        assert!(f.with(BundleFlags::FIXED_POINT).fixed_point());
        assert!(f.with(BundleFlags::FIXED_POINT).sectioned());
        assert!(!f.with(BundleFlags::BITMAP).without(BundleFlags::BITMAP).bitmap());
        let both = f.with(BundleFlags::BITMAP).with(BundleFlags::FIXED_POINT);
        assert!(both.without(BundleFlags::BITMAP).fixed_point());
        assert!(both.without(BundleFlags::BITMAP).end_of_row());
    }

    #[test]
    fn data_bundle_accessors() {
        let b = Bundle::data(3, vec![1, 5], vec![0.5, -2.0], BundleFlags::default());
        assert_eq!(b.shared, 3);
        assert_eq!(b.len(), 2);
        assert_eq!(b.distinct(), &[1, 5]);
        assert_eq!(b.values(), &[0.5, -2.0]);
        assert!(!b.is_empty());
    }

    #[test]
    fn schedule_bundle_sets_flag() {
        let b = Bundle::schedule(
            2,
            vec![RlTriple { row: 4, start: 10, end: 14 }],
            BundleFlags::default(),
        );
        assert!(b.flags.metadata_only());
        assert_eq!(b.triples().len(), 1);
    }

    #[test]
    #[should_panic(expected = "metadata-only")]
    fn wrong_accessor_panics() {
        let b = Bundle::schedule(0, vec![], BundleFlags::default());
        let _ = b.distinct();
    }
}
