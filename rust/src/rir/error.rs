//! Typed errors for untrusted RIR stream bytes.
//!
//! The RIR stream is the CPU→FPGA contract; once it crosses a DRAM/PCIe
//! link it must be treated as untrusted input (flipped bits, truncated
//! DMA, reordered words). Every way a serialized stream can be malformed
//! maps to a variant here, and the `try_*` APIs in
//! [`layout`](super::layout) and [`decode`](super::decode) return these
//! instead of panicking. The legacy infallible entry points wrap the
//! `try_*` forms and convert to [`anyhow::Error`] for trusted in-process
//! streams.

use std::fmt;

/// Structured decode/verification error for RIR streams.
///
/// Word offsets and bundle indices refer to the serialized stream being
/// decoded (bundle indices count every bundle walked, including skipped
/// metadata/panel bundles).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RirError {
    /// Stream ends inside a 2-word bundle header.
    TruncatedHeader { word: usize },
    /// Stream ends inside a bundle payload (or its checksum word).
    TruncatedPayload { bundle: usize, need: usize, have: usize },
    /// Stored per-bundle CRC32 disagrees with the recomputed checksum.
    ChecksumMismatch { bundle: usize, stored: u32, computed: u32 },
    /// Requested bundle range `[lo, hi)` exceeds the stream.
    SegmentOutOfBounds { lo: usize, hi: usize, n_bundles: usize },
    /// A bundle for one row arrived while another row was still open.
    InterleavedRows { open: u32, found: u32 },
    /// Row index at or beyond the destination row count.
    RowOutOfBounds { row: u32, nrows: usize },
    /// Column index at or beyond the destination column count.
    ColumnOutOfBounds { col: u32, ncols: usize },
    /// A row chain closed twice, or chains arrived out of ascending order.
    RowOrder { row: u32 },
    /// Stream ended while a split row chain was still open.
    EndedMidRow { row: u32 },
    /// Panel decoder fed a bundle without the `DENSE_PANEL` flag.
    NotAPanelBundle { bundle: usize },
    /// Panel chains must arrive in ascending row order.
    PanelRowOrder { shared: u32, expected: usize },
    /// Panel row index at or beyond the panel height.
    PanelRowOutOfBounds { row: usize, nrows: usize },
    /// Panel lane indices must run `0..k` in order within a row chain.
    PanelLaneOrder { lane: u32, expected: usize },
    /// Panel row carried more than `k` lanes.
    PanelLaneOverflow { k: usize },
    /// Panel row chain closed with the wrong number of lanes.
    PanelRowWidth { row: usize, lanes: usize, k: usize },
    /// Panel segment ended while a row chain was still open.
    PanelEndedMidRow { row: usize },
    /// Panel segment didn't cover exactly `nrows` rows.
    PanelRowCount { rows: usize, nrows: usize },
    /// Non-empty segment decoded as a zero-width (`k == 0`) panel.
    PanelZeroWidthNonEmpty,
    /// A bitmap index section's set L1 bits disagree with the bundle
    /// header's declared element count.
    BitmapCountMismatch { bundle: usize, declared: usize, decoded: usize },
    /// A bitmap index section reconstructs an index beyond `u32::MAX`.
    BitmapIndexOverflow { bundle: usize },
    /// The assembled matrix failed CSR validation.
    InvalidCsr(String),
}

impl fmt::Display for RirError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RirError::TruncatedHeader { word } => {
                write!(f, "truncated bundle header at word {word}")
            }
            RirError::TruncatedPayload { bundle, need, have } => {
                write!(f, "truncated payload in bundle {bundle}: need {need} words, have {have}")
            }
            RirError::ChecksumMismatch { bundle, stored, computed } => write!(
                f,
                "checksum mismatch in bundle {bundle}: stored {stored:#010x}, computed {computed:#010x}"
            ),
            RirError::SegmentOutOfBounds { lo, hi, n_bundles } => {
                write!(f, "segment [{lo}, {hi}) out of bounds (stream has {n_bundles} bundles)")
            }
            RirError::InterleavedRows { open, found } => {
                write!(f, "bundle for row {found} interleaved into unfinished row {open}")
            }
            RirError::RowOutOfBounds { row, nrows } => {
                write!(f, "row {row} out of bounds (nrows {nrows})")
            }
            RirError::ColumnOutOfBounds { col, ncols } => {
                write!(f, "column {col} out of bounds (ncols {ncols})")
            }
            RirError::RowOrder { row } => {
                write!(f, "row {row} completed twice (or rows out of order)")
            }
            RirError::EndedMidRow { row } => write!(f, "stream ended mid-row {row}"),
            RirError::NotAPanelBundle { bundle } => {
                write!(f, "bundle {bundle} in panel segment lacks DENSE_PANEL")
            }
            RirError::PanelRowOrder { shared, expected } => {
                write!(f, "panel row {shared} out of order (expected {expected})")
            }
            RirError::PanelRowOutOfBounds { row, nrows } => {
                write!(f, "panel row {row} out of bounds (nrows {nrows})")
            }
            RirError::PanelLaneOrder { lane, expected } => {
                write!(f, "panel lane {lane} out of order (expected {expected})")
            }
            RirError::PanelLaneOverflow { k } => {
                write!(f, "panel lane exceeds width {k}")
            }
            RirError::PanelRowWidth { row, lanes, k } => {
                write!(f, "panel row {row} closed with {lanes} of {k} lanes")
            }
            RirError::PanelEndedMidRow { row } => {
                write!(f, "panel segment ended mid-row {row}")
            }
            RirError::PanelRowCount { rows, nrows } => {
                write!(f, "panel segment carried {rows} of {nrows} rows")
            }
            RirError::PanelZeroWidthNonEmpty => {
                write!(f, "zero-width panel cannot carry bundles")
            }
            RirError::BitmapCountMismatch { bundle, declared, decoded } => write!(
                f,
                "bitmap section of bundle {bundle} decodes {decoded} indices, header declares {declared}"
            ),
            RirError::BitmapIndexOverflow { bundle } => {
                write!(f, "bitmap section of bundle {bundle} reconstructs an index beyond u32")
            }
            RirError::InvalidCsr(why) => write!(f, "assembled CSR failed validation: {why}"),
        }
    }
}

impl std::error::Error for RirError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_stable_and_error_converts_to_anyhow() {
        let e = RirError::ChecksumMismatch { bundle: 3, stored: 0xdead_beef, computed: 1 };
        assert_eq!(
            e.to_string(),
            "checksum mismatch in bundle 3: stored 0xdeadbeef, computed 0x00000001"
        );
        let _: anyhow::Error = e.into();
        assert_eq!(
            RirError::TruncatedHeader { word: 9 }.to_string(),
            "truncated bundle header at word 9"
        );
        assert_eq!(
            RirError::BitmapCountMismatch { bundle: 2, declared: 5, decoded: 4 }.to_string(),
            "bitmap section of bundle 2 decodes 4 indices, header declares 5"
        );
        assert_eq!(
            RirError::BitmapIndexOverflow { bundle: 7 }.to_string(),
            "bitmap section of bundle 7 reconstructs an index beyond u32"
        );
    }
}
