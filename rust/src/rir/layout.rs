//! The DRAM word layout of a bundle stream (paper Fig 3(d) / §IV).
//!
//! The FPGA's read controller consumes bundles as a flat sequence of 32-bit
//! words: a metadata word (element count, flags), the shared-feature word,
//! then the distinct/value pairs. The write controller produces the same
//! layout in reverse order per §IV ("It reads the metadata first, shared
//! feature next, and finally the distinct elements").
//!
//! This module is both the wire format (serialize/deserialize, used by the
//! runtime tests and the `gen-stream` CLI) and the **byte accounting** the
//! DRAM bandwidth model charges for each bundle.
//!
//! Bundles whose [`BundleFlags::CHECKSUM`] bit is set carry one extra
//! CRC32 word after the payload (ARCHITECTURE.md §3.3): the IEEE 802.3
//! checksum of the bundle's preceding words — metadata word, shared word
//! and payload — over their little-endian byte serialization.
//! [`try_deserialize`] verifies it; [`serialize_stream_checksummed`]
//! produces the protected form of an arena stream.

use anyhow::Result;

use crate::sparse::{Idx, Val};

use super::bundle::{Bundle, BundleFlags, Payload, RlTriple};
use super::error::RirError;

/// Bytes per stream word (the design streams 32-bit index + 32-bit f32).
pub const WORD_BYTES: usize = 4;

/// Negotiated per-stream RIR encoding (`--encoding`, ARCHITECTURE.md §3.4).
///
/// * `Raw` — the Fig-3(d) interleaved `(index, value)` pair layout,
///   bit-identical to every pre-compression stream.
/// * `Bitmap` — SMASH-style two-level bitmap index sections, chosen **per
///   bundle** by exact byte accounting ([`bitmap_index_words`]); bundles
///   whose pattern does not compress stay raw, so the encoding is always
///   lossless and never larger than necessary.
/// * `Fx` — Q1.15 fixed-point value lanes packing two values per word
///   against a per-bundle scale word ([`fx_value_words`]), selected per
///   stream; lossy within the bound of [`fx_max_abs_error`].
/// * `BitmapFx` — both.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StreamEncoding {
    #[default]
    Raw,
    Bitmap,
    Fx,
    BitmapFx,
}

impl StreamEncoding {
    /// True when bitmap index sections are negotiated for this stream.
    pub fn bitmap(self) -> bool {
        matches!(self, StreamEncoding::Bitmap | StreamEncoding::BitmapFx)
    }

    /// True when fixed-point value lanes are negotiated for this stream.
    pub fn fx(self) -> bool {
        matches!(self, StreamEncoding::Fx | StreamEncoding::BitmapFx)
    }

    /// True for the uncompressed baseline.
    pub fn is_raw(self) -> bool {
        self == StreamEncoding::Raw
    }

    /// Parse a CLI token (`raw | bitmap | fx32 | bitmap+fx32`).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "raw" => StreamEncoding::Raw,
            "bitmap" => StreamEncoding::Bitmap,
            "fx32" => StreamEncoding::Fx,
            "bitmap+fx32" => StreamEncoding::BitmapFx,
            _ => return None,
        })
    }

    /// Per-wave frontend fill latency of the hardware expanders, in cycles.
    ///
    /// Each negotiated compression stage (bitmap expander, fixed-point
    /// de-quantizer) sits as one pipelined stage between the DRAM stream
    /// buffer and the CAM/panel path; being fully pipelined it costs only
    /// its fill latency — charged once per wave to `setup_cycles`, exactly
    /// like the CAM-load setup it extends, so at buffer depth ≥ 2 it hides
    /// under the previous wave's compute. Raw streams pay nothing and stay
    /// bit-identical to the pre-compression model.
    pub fn expansion_cycles(self) -> u64 {
        2 * u64::from(self.bitmap()) + 2 * u64::from(self.fx())
    }
}

impl std::fmt::Display for StreamEncoding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            StreamEncoding::Raw => "raw",
            StreamEncoding::Bitmap => "bitmap",
            StreamEncoding::Fx => "fx32",
            StreamEncoding::BitmapFx => "bitmap+fx32",
        })
    }
}

/// IEEE 802.3 CRC32 lookup table (reflected polynomial `0xEDB88320`).
static CRC32_TABLE: [u32; 256] = crc32_table();

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// IEEE 802.3 CRC32 of a word sequence, taken over the words'
/// little-endian byte serialization — the exact bytes the DRAM link
/// carries, so a software `crc32` of the raw stream buffer agrees with
/// the per-bundle words the FPGA input controller checks.
pub fn crc32_words(words: &[u32]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &w in words {
        for b in w.to_le_bytes() {
            crc = (crc >> 8) ^ CRC32_TABLE[((crc ^ b as u32) & 0xff) as usize];
        }
    }
    !crc
}

/// Number of 32-bit words a bundle occupies in DRAM.
///
/// metadata word + shared word + payload (2 words per data pair, 3 words
/// per schedule triple), plus one CRC32 word when the bundle is
/// checksummed.
pub fn bundle_words(b: &Bundle) -> usize {
    2 + match &b.payload {
        Payload::Data { distinct, .. } => 2 * distinct.len(),
        Payload::Schedule { triples } => 3 * triples.len(),
    } + usize::from(b.flags.checksum())
}

/// Bytes a bundle occupies in DRAM.
pub fn bundle_bytes(b: &Bundle) -> usize {
    bundle_words(b) * WORD_BYTES
}

/// Total bytes of a bundle stream.
pub fn stream_bytes(bundles: &[Bundle]) -> usize {
    bundles.iter().map(bundle_bytes).sum()
}

/// Serialize a bundle stream to the flat word layout.
pub fn serialize(bundles: &[Bundle]) -> Vec<u32> {
    let mut words = Vec::with_capacity(bundles.iter().map(bundle_words).sum());
    for b in bundles {
        let count = b.len() as u32;
        debug_assert!(count < (1 << 24), "bundle too large for metadata word");
        let start = words.len();
        let meta = (count << 8) | b.flags.0 as u32;
        words.push(meta);
        words.push(b.shared);
        match &b.payload {
            Payload::Data { distinct, values } => {
                for (&d, &v) in distinct.iter().zip(values) {
                    words.push(d);
                    words.push(v.to_bits());
                }
            }
            Payload::Schedule { triples } => {
                for t in triples {
                    words.push(t.row);
                    words.push(t.start);
                    words.push(t.end);
                }
            }
        }
        if b.flags.checksum() {
            let crc = crc32_words(&words[start..]);
            words.push(crc);
        }
    }
    words
}

/// Number of 32-bit words a [`BundleStream`](super::encode::BundleStream)
/// occupies in DRAM (all bundles are data bundles: 2 header words + 2 per
/// element, plus one CRC32 word per checksummed bundle — the encoders
/// never set [`BundleFlags::CHECKSUM`], so for encoder-produced arenas
/// this stays exactly `2·bundles + 2·elems`).
pub fn stream_arena_words(s: &super::encode::BundleStream) -> usize {
    2 * s.n_bundles() + 2 * s.n_elems() + s.flags.iter().filter(|f| f.checksum()).count()
}

/// Bytes a [`BundleStream`](super::encode::BundleStream) occupies in DRAM.
pub fn stream_arena_bytes(s: &super::encode::BundleStream) -> usize {
    stream_arena_words(s) * WORD_BYTES
}

/// Number of 32-bit words bundles `[lo, hi)` of a stream arena occupy in
/// DRAM — one job's segment of a multi-tenant stream (see
/// [`super::encode::BundleStream::encode_csr_jobs`]). Summing every job's
/// segment reproduces [`stream_arena_words`] exactly.
pub fn segment_arena_words(s: &super::encode::BundleStream, lo: usize, hi: usize) -> usize {
    assert!(lo <= hi && hi <= s.n_bundles(), "segment [{lo}, {hi}) out of bounds");
    2 * (hi - lo)
        + 2 * (s.off[hi] - s.off[lo])
        + s.flags[lo..hi].iter().filter(|f| f.checksum()).count()
}

/// Bytes bundles `[lo, hi)` of a stream arena occupy in DRAM.
pub fn segment_arena_bytes(s: &super::encode::BundleStream, lo: usize, hi: usize) -> usize {
    segment_arena_words(s, lo, hi) * WORD_BYTES
}

/// Number of 32-bit words the dense-panel segment of an SpMM stream
/// occupies in DRAM (see
/// [`BundleStream::encode_csr_with_panel`](super::encode::BundleStream::encode_csr_with_panel)):
/// one chain per panel row, `ceil(k / bundle_size)` bundles per chain at
/// 2 header words each, plus 2 words per element — the same data-bundle
/// layout as the sparse stream, `k` elements per row. Zero when `k == 0`
/// (a zero-width panel contributes no bundles). Cross-checked against the
/// real encoder in the tests below.
pub fn dense_panel_words(nrows: usize, k: usize, bundle_size: usize) -> usize {
    assert!(bundle_size > 0, "bundle_size must be positive");
    if k == 0 {
        return 0;
    }
    nrows * (2 * k.div_ceil(bundle_size) + 2 * k)
}

/// Bytes the dense-panel segment occupies in DRAM.
pub fn dense_panel_bytes(nrows: usize, k: usize, bundle_size: usize) -> usize {
    dense_panel_words(nrows, k, bundle_size) * WORD_BYTES
}

/// Serialize a flat bundle arena into the DRAM word layout — identical
/// output to [`serialize`] over the boxed form, with no per-bundle
/// indirection.
pub fn serialize_stream(s: &super::encode::BundleStream) -> Vec<u32> {
    let mut words = Vec::new();
    write_stream_words(s, &mut words);
    words
}

/// Append a flat bundle arena's word layout to `words` (reusable-buffer
/// variant of [`serialize_stream`]).
pub fn write_stream_words(s: &super::encode::BundleStream, words: &mut Vec<u32>) {
    words.reserve(stream_arena_words(s));
    for b in s.iter() {
        let count = b.cols.len() as u32;
        debug_assert!(count < (1 << 24), "bundle too large for metadata word");
        let start = words.len();
        words.push((count << 8) | b.flags.0 as u32);
        words.push(b.shared);
        for (&d, &v) in b.cols.iter().zip(b.vals) {
            words.push(d);
            words.push(v.to_bits());
        }
        if b.flags.checksum() {
            let crc = crc32_words(&words[start..]);
            words.push(crc);
        }
    }
}

/// Number of 32-bit words a [`BundleStream`](super::encode::BundleStream)
/// occupies in DRAM once every bundle is checksummed: the plain layout
/// plus exactly one CRC32 word per bundle.
pub fn checksummed_stream_words(s: &super::encode::BundleStream) -> usize {
    3 * s.n_bundles() + 2 * s.n_elems()
}

/// Serialize a flat bundle arena with [`BundleFlags::CHECKSUM`] forced on
/// every bundle: each bundle's header carries the flag and is followed by
/// its CRC32 word (the fault-protected wire form of ARCHITECTURE.md §3.3).
/// Output length is exactly [`checksummed_stream_words`].
pub fn serialize_stream_checksummed(s: &super::encode::BundleStream) -> Vec<u32> {
    let mut words = Vec::with_capacity(checksummed_stream_words(s));
    for b in s.iter() {
        let count = b.cols.len() as u32;
        debug_assert!(count < (1 << 24), "bundle too large for metadata word");
        let start = words.len();
        words.push((count << 8) | b.flags.with(BundleFlags::CHECKSUM).0 as u32);
        words.push(b.shared);
        for (&d, &v) in b.cols.iter().zip(b.vals) {
            words.push(d);
            words.push(v.to_bits());
        }
        let crc = crc32_words(&words[start..]);
        words.push(crc);
    }
    words
}

// ---------------------------------------------------------------------------
// Compressed encodings (ARCHITECTURE.md §3.4): bitmap index sections and
// fixed-point value lanes. When either compression flag is set on a data
// bundle, the interleaved pair payload is replaced by an **index section**
// followed by a **value section**; the CHECKSUM word (when present) still
// covers every preceding word of the encoded bundle.
// ---------------------------------------------------------------------------

/// Width in distinct features of one L1 bitmap word (one bit per feature).
const BITMAP_L1_SPAN: usize = 32;
/// Width in distinct features of one L0 bitmap *bit* — each L0 bit flags a
/// 32-feature block, so one L0 word covers `32 × 32 = 1024` features.
const BITMAP_L0_SPAN: usize = 32 * BITMAP_L1_SPAN;

/// Words of the two-level bitmap index section for `cols`, or `None` when
/// the section cannot represent them (empty, not strictly ascending, or a
/// span exceeding `u32::MAX` features).
///
/// Layout: `base` word (first index), `span` word (`last − first + 1`),
/// `ceil(span / 1024)` L0 words (bit `t` of the L0 sequence flags the
/// 32-feature block `[base + 32t, base + 32t + 32)` as occupied), then one
/// L1 word per **set** L0 bit in ascending block order (bit `o` of block
/// `t`'s L1 word flags index `base + 32t + o` as present). Cost is
/// therefore `2 + ceil(span/1024) + (#occupied 32-blocks)` words; the
/// encoder picks the bitmap form per bundle iff this is strictly below the
/// `count` raw index words it replaces.
pub fn bitmap_index_words(cols: &[Idx]) -> Option<usize> {
    let (&first, &last) = (cols.first()?, cols.last()?);
    if !cols.windows(2).all(|w| w[0] < w[1]) {
        return None;
    }
    let span = last as u64 - first as u64 + 1;
    if span > u32::MAX as u64 {
        return None;
    }
    let n_l0 = (span as usize).div_ceil(BITMAP_L0_SPAN);
    let mut blocks = 0usize;
    let mut prev = usize::MAX;
    for &c in cols {
        let t = ((c - first) as usize) / BITMAP_L1_SPAN;
        if t != prev {
            blocks += 1;
            prev = t;
        }
    }
    Some(2 + n_l0 + blocks)
}

/// The bitmap index words the encoder actually picks for this bundle under
/// `enc`: `Some` iff bitmaps are negotiated **and** strictly cheaper than
/// the `count` raw index words (exact per-bundle byte accounting).
fn chosen_bitmap_words(cols: &[Idx], enc: StreamEncoding) -> Option<usize> {
    if !enc.bitmap() {
        return None;
    }
    bitmap_index_words(cols).filter(|&w| w < cols.len())
}

/// Append the bitmap index section for `cols` (caller guarantees
/// [`bitmap_index_words`] is `Some`).
fn write_bitmap_section(cols: &[Idx], out: &mut Vec<u32>) {
    let first = cols[0];
    let span = (*cols.last().unwrap() as u64 - first as u64 + 1) as u32;
    out.push(first);
    out.push(span);
    let n_l0 = (span as usize).div_ceil(BITMAP_L0_SPAN);
    let l0_start = out.len();
    out.resize(l0_start + n_l0, 0);
    let mut i = 0usize;
    while i < cols.len() {
        let t = ((cols[i] - first) as usize) / BITMAP_L1_SPAN;
        out[l0_start + t / 32] |= 1 << (t % 32);
        let mut l1 = 0u32;
        while i < cols.len() && ((cols[i] - first) as usize) / BITMAP_L1_SPAN == t {
            l1 |= 1 << (((cols[i] - first) as usize) % BITMAP_L1_SPAN);
            i += 1;
        }
        out.push(l1);
    }
}

/// Words of the fixed-point value section for a `count`-element bundle:
/// one f32 scale word plus `ceil(count / 2)` packed Q1.15 words (empty
/// bundles carry no section at all).
pub fn fx_value_words(count: usize) -> usize {
    if count == 0 {
        0
    } else {
        1 + count.div_ceil(2)
    }
}

/// Worst-case absolute error of the Q1.15 fixed-point value lane against
/// the original f32 values, for a bundle whose scale word is `scale`.
///
/// Derivation: the encoder sets `scale = max|v|` over the bundle and
/// stores `q = round(v / scale · 32767)` (so `|q| ≤ 32767` always holds
/// and ±scale round-trips exactly); the decoder reconstructs
/// `v̂ = f32(q · scale / 32767)`. Rounding `q` costs at most half a
/// quantization step, `scale / (2 · 32767) = scale / 65534`; the final
/// f32 cast adds at most one half-ulp, ≤ `2⁻²⁴ · scale` since
/// `|v̂| ≤ scale`. (The intermediate f64 arithmetic contributes ~`2⁻⁵³`
/// relative — absorbed many times over by the `2⁻²⁴` term.) The bound
/// applies to finite inputs; a zero scale (all-zero bundle) decodes
/// exactly.
pub fn fx_max_abs_error(scale: f32) -> f64 {
    scale.abs() as f64 * (1.0 / 65534.0 + (2f64).powi(-24))
}

/// Quantize one value against a bundle scale (Q1.15, two's complement).
fn fx_quantize(v: Val, scale: f32) -> u16 {
    if scale == 0.0 {
        return 0;
    }
    let q = ((v as f64 / scale as f64) * 32767.0).round() as i32;
    (q.clamp(-32767, 32767) as i16) as u16
}

/// Dequantize one Q1.15 half-word against a bundle scale.
fn fx_dequantize(half: u16, scale: f32) -> Val {
    ((half as i16) as f64 * scale as f64 / 32767.0) as f32
}

/// Append the fixed-point value section for `vals` (non-empty): scale word
/// then packed pairs, even-index value in the low half-word, odd-index in
/// the high, odd trailing count leaving the high half zero.
fn write_fx_section(vals: &[Val], out: &mut Vec<u32>) {
    let scale = vals.iter().fold(0f32, |m, v| m.max(v.abs()));
    debug_assert!(scale.is_finite(), "fixed-point lanes require finite values");
    out.push(scale.to_bits());
    for pair in vals.chunks(2) {
        let lo = fx_quantize(pair[0], scale) as u32;
        let hi = if pair.len() == 2 { fx_quantize(pair[1], scale) as u32 } else { 0 };
        out.push(lo | (hi << 16));
    }
}

/// Header + payload words of one **non-checksummed** data bundle under
/// `enc`, from its actual distinct indices — the single source of truth
/// the simulators price streams with. Reduces exactly to `2 + 2·count`
/// (the raw interleaved layout) at [`StreamEncoding::Raw`], and whenever
/// neither compression form engages (no bitmap win, empty bundle).
pub fn encoded_data_bundle_words(cols: &[Idx], enc: StreamEncoding) -> usize {
    let c = cols.len();
    let bm = chosen_bitmap_words(cols, enc);
    let fx = enc.fx() && c > 0;
    if bm.is_none() && !fx {
        return 2 + 2 * c;
    }
    2 + bm.unwrap_or(c) + if fx { fx_value_words(c) } else { c }
}

/// Words of one bundle chain (a row/column split into `bundle_size`
/// chunks) under `enc`. An empty chain still emits one empty end-of-row
/// bundle (2 words), matching every streaming encoder. At
/// [`StreamEncoding::Raw`] this is exactly
/// `2·ceil(len/bundle_size).max(1) + 2·len` — the formula the simulators
/// charged before compression existed.
pub fn encoded_chain_words(cols: &[Idx], bundle_size: usize, enc: StreamEncoding) -> usize {
    assert!(bundle_size > 0, "bundle_size must be positive");
    if cols.is_empty() {
        return 2;
    }
    cols.chunks(bundle_size).map(|ch| encoded_data_bundle_words(ch, enc)).sum()
}

/// Words a [`BundleStream`](super::encode::BundleStream) arena occupies in
/// DRAM under `enc` (plus one CRC word per already-checksummed bundle).
/// Reduces exactly to [`stream_arena_words`] at [`StreamEncoding::Raw`].
pub fn encoded_stream_words(s: &super::encode::BundleStream, enc: StreamEncoding) -> usize {
    s.iter()
        .map(|b| encoded_data_bundle_words(b.cols, enc) + usize::from(b.flags.checksum()))
        .sum()
}

/// Words the SpMM dense-panel segment occupies under `enc`: the panel
/// encoder emits one chain of lane indices `0..k` per panel row, so every
/// row chain prices identically. Contiguous lane blocks compress well
/// under bitmaps (`2 + ceil(len/1024) + ceil(len/32)` vs `len` raw index
/// words per chunk). Reduces exactly to [`dense_panel_words`] at
/// [`StreamEncoding::Raw`].
pub fn encoded_dense_panel_words(
    nrows: usize,
    k: usize,
    bundle_size: usize,
    enc: StreamEncoding,
) -> usize {
    assert!(bundle_size > 0, "bundle_size must be positive");
    if k == 0 {
        return 0;
    }
    let lanes: Vec<Idx> = (0..k as Idx).collect();
    nrows * encoded_chain_words(&lanes, bundle_size, enc)
}

/// Append one data bundle in its encoded wire form: compression flags set
/// per the negotiated `enc` (bitmap only where it wins byte accounting,
/// fixed-point on every non-empty bundle), optional CRC32 trailer.
fn write_encoded_bundle(
    shared: Idx,
    flags: BundleFlags,
    cols: &[Idx],
    vals: &[Val],
    enc: StreamEncoding,
    checksummed: bool,
    out: &mut Vec<u32>,
) {
    debug_assert_eq!(cols.len(), vals.len());
    let count = cols.len() as u32;
    debug_assert!(count < (1 << 24), "bundle too large for metadata word");
    let bm = chosen_bitmap_words(cols, enc).is_some();
    let fx = enc.fx() && !cols.is_empty();
    let mut f = flags;
    if bm {
        f = f.with(BundleFlags::BITMAP);
    }
    if fx {
        f = f.with(BundleFlags::FIXED_POINT);
    }
    if checksummed {
        f = f.with(BundleFlags::CHECKSUM);
    }
    let start = out.len();
    out.push((count << 8) | f.0 as u32);
    out.push(shared);
    if !bm && !fx {
        for (&d, &v) in cols.iter().zip(vals) {
            out.push(d);
            out.push(v.to_bits());
        }
    } else {
        if bm {
            write_bitmap_section(cols, out);
        } else {
            out.extend_from_slice(cols);
        }
        if fx {
            write_fx_section(vals, out);
        } else {
            out.extend(vals.iter().map(|v| v.to_bits()));
        }
    }
    if f.checksum() {
        let crc = crc32_words(&out[start..]);
        out.push(crc);
    }
}

/// Serialize a flat bundle arena under a negotiated [`StreamEncoding`],
/// optionally checksumming every bundle. `(Raw, false)` is bit-identical
/// to [`serialize_stream`] and `(Raw, true)` to
/// [`serialize_stream_checksummed`]; output length is exactly
/// [`encoded_stream_words`] plus (when checksummed) one word per bundle.
pub fn serialize_stream_encoded(
    s: &super::encode::BundleStream,
    enc: StreamEncoding,
    checksummed: bool,
) -> Vec<u32> {
    let crc_words = if checksummed { s.n_bundles() } else { 0 };
    let mut words = Vec::with_capacity(encoded_stream_words(s, enc) + crc_words);
    for b in s.iter() {
        write_encoded_bundle(b.shared, b.flags, b.cols, b.vals, enc, checksummed, &mut words);
    }
    words
}

/// Streaming writer: encode a CSC matrix's bundle chains directly into the
/// flat word layout, one chain per column, recording words-per-column.
///
/// Functionally identical to `encode::csc_to_bundles` + [`serialize`] but
/// with no intermediate `Bundle` allocations — this is the actual Fig-3(d)
/// operation (the CPU writes bundles straight into the FPGA-visible DRAM
/// region) and it is on REAP's measured critical path (EXPERIMENTS.md
/// §Perf iteration 3).
pub fn write_csc_stream(
    m: &crate::sparse::Csc,
    bundle_size: usize,
    words: &mut Vec<u32>,
    col_words: &mut Vec<u32>,
) {
    assert!(bundle_size > 0);
    col_words.clear();
    col_words.reserve(m.ncols);
    for j in 0..m.ncols {
        let start = words.len();
        let rows = m.col_rows(j);
        let vals = m.col_vals(j);
        if rows.is_empty() {
            words.push(BundleFlags::END_OF_ROW as u32);
            words.push(j as u32);
        } else {
            let nchunks = rows.len().div_ceil(bundle_size);
            for ci in 0..nchunks {
                let lo = ci * bundle_size;
                let hi = ((ci + 1) * bundle_size).min(rows.len());
                let mut flags = 0u32;
                if ci + 1 == nchunks {
                    flags |= BundleFlags::END_OF_ROW as u32;
                }
                words.push((((hi - lo) as u32) << 8) | flags);
                words.push(j as u32);
                for k in lo..hi {
                    words.push(rows[k]);
                    words.push(vals[k].to_bits());
                }
            }
        }
        col_words.push((words.len() - start) as u32);
    }
    // terminal flag on the very last bundle header of the stream
    mark_last_header_end_of_stream(words);
}

/// Streaming writer for Cholesky RL metadata chains (one per column of L):
/// `(row, start, end)` triples pointing into the row-major L storage map.
pub fn write_rl_stream(
    pattern: &crate::symbolic::LPattern,
    storage: &crate::symbolic::LStorageMap,
    bundle_size: usize,
    words: &mut Vec<u32>,
    col_words: &mut Vec<u32>,
) {
    assert!(bundle_size > 0);
    col_words.clear();
    col_words.reserve(pattern.n);
    for k in 0..pattern.n {
        let start = words.len();
        let rows = pattern.col_rows(k);
        let nchunks = rows.len().div_ceil(bundle_size).max(1);
        for ci in 0..nchunks {
            let lo = ci * bundle_size;
            let hi = ((ci + 1) * bundle_size).min(rows.len());
            let mut flags = BundleFlags::METADATA_ONLY as u32;
            if ci + 1 == nchunks {
                flags |= BundleFlags::END_OF_ROW as u32;
            }
            words.push((((hi - lo) as u32) << 8) | flags);
            words.push(k as u32);
            for &r in &rows[lo..hi] {
                words.push(r);
                words.push(storage.row_ptr[r as usize] as u32);
                words.push(storage.row_ptr[r as usize + 1] as u32);
            }
        }
        col_words.push((words.len() - start) as u32);
    }
    mark_last_header_end_of_stream(words);
}

/// Parsed extent of one wire bundle starting at word `p`: everything the
/// walkers need to size, verify and step over it. The single source of
/// payload-sizing truth — `try_deserialize`, the `decode::WireCursor` and
/// [`mark_last_header_end_of_stream`] all use it, so the flag-dependent
/// layout (METADATA_ONLY triples, sectioned BITMAP / FIXED_POINT payloads,
/// trailing CHECKSUM word) cannot drift between them.
#[derive(Clone, Copy, Debug)]
pub(crate) struct BundleExtent {
    pub count: usize,
    pub flags: BundleFlags,
    pub shared: u32,
    /// Payload words between the shared word and the optional CRC word.
    pub payload_words: usize,
    /// Total words including the two header words and the CRC word.
    pub total_words: usize,
}

/// Size the bundle at `words[p..]` without decoding it. Total over
/// arbitrary input: every read is bounds-checked and sizing errors come
/// back as structured [`RirError`]s. Compression flags on metadata-only
/// bundles are ignored (schedule payloads are always raw triples — the
/// encoders never set them there, and treating them as sizing no-ops keeps
/// the walker total on fuzzed input).
pub(crate) fn bundle_extent(
    words: &[u32],
    p: usize,
    bundle: usize,
) -> std::result::Result<BundleExtent, RirError> {
    if p + 2 > words.len() {
        return Err(RirError::TruncatedHeader { word: p });
    }
    let meta = words[p];
    let shared = words[p + 1];
    let count = (meta >> 8) as usize;
    let flags = BundleFlags((meta & 0xff) as u8);
    let have = words.len() - (p + 2);
    let payload_words = if flags.metadata_only() {
        3 * count
    } else if flags.sectioned() {
        let idx_words = if flags.bitmap() {
            // the bitmap section self-describes its size: base + span
            // words, ceil(span/1024) L0 words, one L1 word per set L0 bit
            if have < 2 {
                return Err(RirError::TruncatedPayload { bundle, need: 2, have });
            }
            let span = words[p + 3] as usize;
            let n_l0 = span.div_ceil(BITMAP_L0_SPAN);
            if have < 2 + n_l0 {
                return Err(RirError::TruncatedPayload { bundle, need: 2 + n_l0, have });
            }
            let n_l1: usize =
                words[p + 4..p + 4 + n_l0].iter().map(|w| w.count_ones() as usize).sum();
            2 + n_l0 + n_l1
        } else {
            count
        };
        let val_words = if flags.fixed_point() { fx_value_words(count) } else { count };
        idx_words + val_words
    } else {
        2 * count
    };
    let need = payload_words + usize::from(flags.checksum());
    if need > have {
        return Err(RirError::TruncatedPayload { bundle, need, have });
    }
    Ok(BundleExtent {
        count,
        flags,
        shared,
        payload_words,
        total_words: 2 + payload_words + usize::from(flags.checksum()),
    })
}

/// Verify the CRC32 trailer of a checksummed bundle at `words[p..]`.
pub(crate) fn verify_bundle_crc(
    words: &[u32],
    p: usize,
    ext: &BundleExtent,
    bundle: usize,
) -> std::result::Result<(), RirError> {
    if ext.flags.checksum() {
        let stored = words[p + 2 + ext.payload_words];
        let computed = crc32_words(&words[p..p + 2 + ext.payload_words]);
        if stored != computed {
            return Err(RirError::ChecksumMismatch { bundle, stored, computed });
        }
    }
    Ok(())
}

/// Expand a sectioned (BITMAP and/or FIXED_POINT) data payload back into
/// raw interleaved `(index, value-bits)` pairs. `payload` is exactly the
/// [`BundleExtent::payload_words`] slice (header and CRC excluded), so
/// every in-bounds guarantee is already established; what remains to check
/// is bitmap integrity — the set L1 bits must reproduce exactly the
/// declared element count, and no reconstructed index may overflow `u32`.
pub(crate) fn expand_sectioned_payload(
    payload: &[u32],
    count: usize,
    flags: BundleFlags,
    bundle: usize,
) -> std::result::Result<Vec<u32>, RirError> {
    let mut cols: Vec<u32> = Vec::with_capacity(count);
    let mut q;
    if flags.bitmap() {
        let base = payload[0] as u64;
        let span = payload[1] as usize;
        let n_l0 = span.div_ceil(BITMAP_L0_SPAN);
        q = 2 + n_l0;
        for (wi, &l0w) in payload[2..2 + n_l0].iter().enumerate() {
            let mut bits = l0w;
            while bits != 0 {
                let bit = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let t = 32 * wi + bit;
                let mut l1 = payload[q];
                q += 1;
                while l1 != 0 {
                    let o = l1.trailing_zeros() as usize;
                    l1 &= l1 - 1;
                    let col = base + (BITMAP_L1_SPAN * t + o) as u64;
                    if col > u32::MAX as u64 {
                        return Err(RirError::BitmapIndexOverflow { bundle });
                    }
                    cols.push(col as u32);
                }
            }
        }
        if cols.len() != count {
            return Err(RirError::BitmapCountMismatch {
                bundle,
                declared: count,
                decoded: cols.len(),
            });
        }
    } else {
        cols.extend_from_slice(&payload[..count]);
        q = count;
    }
    let mut pairs = Vec::with_capacity(2 * count);
    if flags.fixed_point() && count > 0 {
        let scale = f32::from_bits(payload[q]);
        q += 1;
        for (i, &col) in cols.iter().enumerate() {
            let w = payload[q + i / 2];
            let half = (if i % 2 == 0 { w & 0xffff } else { w >> 16 }) as u16;
            pairs.push(col);
            pairs.push(fx_dequantize(half, scale).to_bits());
        }
    } else {
        for (i, &col) in cols.iter().enumerate() {
            pairs.push(col);
            pairs.push(payload[q + i]);
        }
    }
    Ok(pairs)
}

/// Walk the stream to its last bundle header and set `END_OF_STREAM`.
///
/// The header word participates in the per-bundle checksum, so a
/// checksummed last bundle has its CRC32 word recomputed after the flag
/// is set. Sizing goes through [`bundle_extent`], so checksummed,
/// metadata-only, bitmap and fixed-point bundles all step correctly.
fn mark_last_header_end_of_stream(words: &mut Vec<u32>) {
    let mut p = 0usize;
    let mut bundle = 0usize;
    let mut last = None;
    while p < words.len() {
        match bundle_extent(words, p, bundle) {
            Ok(ext) => {
                last = Some((p, ext.payload_words, ext.flags.checksum()));
                p += ext.total_words;
                bundle += 1;
            }
            Err(e) => {
                // only internally produced, well-formed streams reach here
                debug_assert!(false, "malformed internal stream: {e}");
                return;
            }
        }
    }
    if let Some((h, payload, checksummed)) = last {
        words[h] |= BundleFlags::END_OF_STREAM as u32;
        if checksummed {
            words[h + 2 + payload] = crc32_words(&words[h..h + 2 + payload]);
        }
    }
}

/// Deserialize a flat word stream back into bundles, verifying per-bundle
/// checksums — trusted-caller wrapper over [`try_deserialize`].
pub fn deserialize(words: &[u32]) -> Result<Vec<Bundle>> {
    Ok(try_deserialize(words)?)
}

/// Deserialize a flat word stream back into bundles.
///
/// Total over arbitrary input: truncation, undersized payloads, CRC32
/// mismatches and malformed bitmap sections come back as structured
/// [`RirError`]s; no input panics. Checksummed bundles keep their
/// `CHECKSUM` flag so re-serializing reproduces the protected wire form
/// bit-for-bit; BITMAP / FIXED_POINT bundles are expanded back to raw
/// pairs and their compression flags **stripped** (the in-memory `Bundle`
/// is always the raw form, so serialize∘deserialize is not the identity
/// on compressed streams — by design; compare decoded contents instead).
pub fn try_deserialize(words: &[u32]) -> std::result::Result<Vec<Bundle>, RirError> {
    let mut out = Vec::new();
    let mut p = 0usize;
    let mut bundle = 0usize;
    while p < words.len() {
        let ext = bundle_extent(words, p, bundle)?;
        verify_bundle_crc(words, p, &ext, bundle)?;
        let (count, flags, shared) = (ext.count, ext.flags, ext.shared);
        let payload = &words[p + 2..p + 2 + ext.payload_words];
        if flags.metadata_only() {
            let mut triples = Vec::with_capacity(count);
            for k in 0..count {
                triples.push(RlTriple {
                    row: payload[3 * k],
                    start: payload[3 * k + 1],
                    end: payload[3 * k + 2],
                });
            }
            // schedule() re-sets METADATA_ONLY; keep other flag bits
            out.push(Bundle::schedule(shared, triples, flags));
        } else {
            let pairs;
            let raw_pairs: &[u32] = if flags.sectioned() {
                pairs = expand_sectioned_payload(payload, count, flags, bundle)?;
                &pairs
            } else {
                payload
            };
            let mut distinct: Vec<Idx> = Vec::with_capacity(count);
            let mut values: Vec<Val> = Vec::with_capacity(count);
            for k in 0..count {
                distinct.push(raw_pairs[2 * k]);
                values.push(f32::from_bits(raw_pairs[2 * k + 1]));
            }
            let clean = flags.without(BundleFlags::BITMAP).without(BundleFlags::FIXED_POINT);
            out.push(Bundle::data(shared, distinct, values, clean));
        }
        p += ext.total_words;
        bundle += 1;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rir::encode::csr_to_bundles;
    use crate::sparse::gen;

    #[test]
    fn word_count_matches_serialized_length() {
        let m = gen::power_law(30, 500, 1);
        let bundles = csr_to_bundles(&m, 32);
        let words = serialize(&bundles);
        assert_eq!(words.len(), bundles.iter().map(bundle_words).sum::<usize>());
        assert_eq!(stream_bytes(&bundles), words.len() * WORD_BYTES);
    }

    #[test]
    fn stream_arena_serializes_identically() {
        let m = gen::power_law(30, 500, 4);
        for bs in [1usize, 8, 32] {
            let boxed = serialize(&csr_to_bundles(&m, bs));
            let arena = crate::rir::encode::BundleStream::from_csr(&m, bs);
            assert_eq!(serialize_stream(&arena), boxed, "bs {bs}");
            assert_eq!(stream_arena_words(&arena), boxed.len());
            assert_eq!(stream_arena_bytes(&arena), boxed.len() * WORD_BYTES);
        }
    }

    #[test]
    fn segment_words_partition_the_arena() {
        let m0 = gen::power_law(25, 300, 7);
        let m1 = gen::random_uniform(10, 10, 50, 8);
        let m2 = crate::sparse::Csr::new(0, 4);
        let mut s = crate::rir::encode::BundleStream::new();
        let bounds = s.encode_csr_jobs(&[&m0, &m1, &m2], 8);
        let total: usize = bounds
            .windows(2)
            .map(|w| segment_arena_words(&s, w[0], w[1]))
            .sum();
        assert_eq!(total, stream_arena_words(&s));
        assert_eq!(segment_arena_words(&s, bounds[2], bounds[3]), 0);
        // a segment's bytes equal the standalone encode's bytes
        let solo = crate::rir::encode::BundleStream::from_csr_with_threads(&m1, 8, 1);
        assert_eq!(
            segment_arena_bytes(&s, bounds[1], bounds[2]),
            stream_arena_bytes(&solo)
        );
    }

    #[test]
    fn dense_panel_words_match_real_encode() {
        let m = gen::power_law(20, 250, 9);
        for (k, bs) in [(4usize, 32usize), (8, 32), (7, 3), (0, 16)] {
            let x: Vec<f32> = (0..m.ncols * k).map(|i| i as f32 * 0.1).collect();
            let mut s = crate::rir::encode::BundleStream::new();
            let boundary = s.encode_csr_with_panel(&m, &x, k, bs);
            assert_eq!(
                segment_arena_words(&s, boundary, s.n_bundles()),
                dense_panel_words(m.ncols, k, bs),
                "k {k} bs {bs}"
            );
            // sparse prefix + panel segment partition the whole stream
            assert_eq!(
                segment_arena_words(&s, 0, boundary)
                    + segment_arena_words(&s, boundary, s.n_bundles()),
                stream_arena_words(&s)
            );
            // serialized length agrees with the arithmetic
            assert_eq!(serialize_stream(&s).len(), stream_arena_words(&s));
        }
    }

    /// Pins the word-layout formulas documented in ARCHITECTURE.md §"RIR
    /// wire format" — if this test moves, the spec must move with it.
    #[test]
    fn architecture_md_wire_format_accounting() {
        // data bundle: metadata word + shared word + 2 words per element
        let data = Bundle::data(7, vec![1, 2, 3], vec![0.5, 1.5, 2.5], BundleFlags::default());
        assert_eq!(bundle_words(&data), 2 + 2 * 3);
        // schedule (RL) bundle: metadata + shared + 3 words per triple
        let sched = Bundle::schedule(
            4,
            vec![RlTriple { row: 1, start: 0, end: 9 }; 2],
            BundleFlags::default(),
        );
        assert_eq!(bundle_words(&sched), 2 + 3 * 2);
        // metadata word packing: element count in bits 8.., flags in 0..8
        let words = serialize(std::slice::from_ref(&data));
        assert_eq!(words[0] >> 8, 3, "count field");
        assert_eq!(words[0] & 0xff, data.flags.0 as u32, "flags field");
        assert_eq!(words[1], 7, "shared-feature word");
        // value words are IEEE-754 bit patterns
        assert_eq!(words[3], 0.5f32.to_bits());
        // arena accounting: 2 words per bundle + 2 per element, 4 bytes/word
        let m = gen::power_law(15, 120, 2);
        let s = crate::rir::encode::BundleStream::from_csr(&m, 8);
        assert_eq!(stream_arena_words(&s), 2 * s.n_bundles() + 2 * s.n_elems());
        assert_eq!(stream_arena_bytes(&s), stream_arena_words(&s) * 4);
        assert_eq!(WORD_BYTES, 4);

        // §3.3 checksummed form: CHECKSUM flag bit, +1 CRC32 word per
        // bundle, checksum taken over the bundle's preceding words
        assert_eq!(BundleFlags::CHECKSUM, 0b0001_0000);
        let ck = Bundle::data(
            7,
            vec![1, 2, 3],
            vec![0.5, 1.5, 2.5],
            BundleFlags::default().with(BundleFlags::CHECKSUM),
        );
        assert_eq!(bundle_words(&ck), 2 + 2 * 3 + 1);
        let ckw = serialize(std::slice::from_ref(&ck));
        assert_eq!(ckw.len(), bundle_words(&ck));
        assert_eq!(ckw[0] & 0xff, BundleFlags::CHECKSUM as u32, "flags field");
        assert_eq!(*ckw.last().unwrap(), crc32_words(&ckw[..ckw.len() - 1]));
        let cks = serialize_stream_checksummed(&s);
        assert_eq!(cks.len(), checksummed_stream_words(&s));
        assert_eq!(checksummed_stream_words(&s), 3 * s.n_bundles() + 2 * s.n_elems());
    }

    /// The CRC32 is the IEEE 802.3 / zlib `crc32` of the words'
    /// little-endian bytes — values pinned against an independent
    /// implementation.
    #[test]
    fn crc32_matches_reference_vectors() {
        assert_eq!(crc32_words(&[]), 0);
        assert_eq!(crc32_words(&[0x0102_0304]), 0xe951_a406);
        assert_eq!(crc32_words(&[0, 0, 0, 0]), 0xecbb_4b55);
        assert_eq!(crc32_words(&[0xdead_beef, 0x00c0_ffee]), 0x9f1d_caf9);
        // a fully worked checksummed data bundle, header included
        let b = Bundle::data(
            7,
            vec![2, 5, 9],
            vec![0.5, 1.5, -2.0],
            BundleFlags::default().with(BundleFlags::END_OF_ROW).with(BundleFlags::CHECKSUM),
        );
        let w = serialize(std::slice::from_ref(&b));
        assert_eq!(w[0], 0x311);
        assert_eq!(*w.last().unwrap(), 0xb3a6_a5bc);
    }

    #[test]
    fn checksummed_stream_roundtrips_and_detects_corruption() {
        let m = gen::power_law(22, 260, 6);
        let s = crate::rir::encode::BundleStream::from_csr(&m, 8);
        let words = serialize_stream_checksummed(&s);
        // decode keeps CHECKSUM flags, so re-serializing is bit-identical
        let bundles = try_deserialize(&words).unwrap();
        assert!(bundles.iter().all(|b| b.flags.checksum()));
        assert_eq!(serialize(&bundles), words);
        // stripping the flags recovers the plain serialized form
        let plain: Vec<Bundle> = bundles
            .iter()
            .map(|b| Bundle {
                flags: BundleFlags(b.flags.0 & !BundleFlags::CHECKSUM),
                ..b.clone()
            })
            .collect();
        assert_eq!(serialize(&plain), serialize_stream(&s));
        // a corrupted shared-feature word is caught by the bundle's CRC
        let mut bad = words.clone();
        bad[1] ^= 1 << 17;
        match try_deserialize(&bad) {
            Err(RirError::ChecksumMismatch { bundle: 0, .. }) => {}
            other => panic!("expected checksum mismatch, got {other:?}"),
        }
        // dropping the CRC word of the last bundle truncates the stream
        let mut short = words;
        short.pop();
        assert!(matches!(
            try_deserialize(&short),
            Err(RirError::TruncatedPayload { .. })
        ));
    }

    #[test]
    fn end_of_stream_marker_recomputes_last_checksum() {
        // build a checksummed two-bundle stream by hand, then re-mark it
        let m = gen::random_uniform(6, 6, 18, 11);
        let s = crate::rir::encode::BundleStream::from_csr(&m, 4);
        let mut words = serialize_stream_checksummed(&s);
        super::mark_last_header_end_of_stream(&mut words);
        let bundles = try_deserialize(&words).expect("marker must keep checksums valid");
        assert!(bundles.last().unwrap().flags.end_of_stream());
    }

    #[test]
    fn roundtrip_data_stream() {
        let m = gen::random_uniform(12, 40, 150, 2);
        let bundles = csr_to_bundles(&m, 8);
        let words = serialize(&bundles);
        let back = deserialize(&words).unwrap();
        assert_eq!(back, bundles);
    }

    #[test]
    fn roundtrip_schedule_bundle() {
        let b = Bundle::schedule(
            5,
            vec![
                RlTriple { row: 1, start: 0, end: 9 },
                RlTriple { row: 7, start: 9, end: 12 },
            ],
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
        );
        let words = serialize(std::slice::from_ref(&b));
        assert_eq!(words.len(), 2 + 3 * 2);
        let back = deserialize(&words).unwrap();
        assert_eq!(back, vec![b]);
    }

    #[test]
    fn nan_values_survive_bit_roundtrip() {
        let b = Bundle::data(
            0,
            vec![1],
            vec![f32::NAN],
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
        );
        let back = deserialize(&serialize(std::slice::from_ref(&b))).unwrap();
        assert!(back[0].values()[0].is_nan());
    }

    #[test]
    fn truncated_stream_rejected() {
        let m = gen::random_uniform(3, 3, 6, 3);
        let mut words = serialize(&csr_to_bundles(&m, 32));
        words.pop();
        assert!(deserialize(&words).is_err());
    }

    #[test]
    fn empty_stream_is_empty() {
        assert_eq!(deserialize(&[]).unwrap(), Vec::<Bundle>::new());
    }

    #[test]
    fn stream_encoding_parse_display_and_expansion() {
        for enc in [
            StreamEncoding::Raw,
            StreamEncoding::Bitmap,
            StreamEncoding::Fx,
            StreamEncoding::BitmapFx,
        ] {
            assert_eq!(StreamEncoding::parse(&enc.to_string()), Some(enc));
        }
        assert_eq!(StreamEncoding::parse("fx"), None);
        assert_eq!(StreamEncoding::parse("Raw"), None);
        assert_eq!(StreamEncoding::default(), StreamEncoding::Raw);
        // expansion fill latencies are pinned: raw streams pay nothing
        assert_eq!(StreamEncoding::Raw.expansion_cycles(), 0);
        assert_eq!(StreamEncoding::Bitmap.expansion_cycles(), 2);
        assert_eq!(StreamEncoding::Fx.expansion_cycles(), 2);
        assert_eq!(StreamEncoding::BitmapFx.expansion_cycles(), 4);
    }

    /// Pins the worked byte-level examples documented in ARCHITECTURE.md
    /// §3.4 — if this test moves, the spec must move with it.
    #[test]
    fn architecture_md_compression_worked_examples() {
        assert_eq!(BundleFlags::BITMAP, 0b0010_0000);
        assert_eq!(BundleFlags::FIXED_POINT, 0b0100_0000);

        // -- bitmap index section --------------------------------------
        // cols [4,5,6,7, 36,37,38,39]: base 4, span 36, one L0 word with
        // bits 0 and 1 set (blocks [4,36) and [36,68) occupied), then one
        // L1 word per block with its low four bits set.
        let cols: Vec<Idx> = vec![4, 5, 6, 7, 36, 37, 38, 39];
        assert_eq!(bitmap_index_words(&cols), Some(5)); // 2 + 1 L0 + 2 L1
        let mut section = Vec::new();
        write_bitmap_section(&cols, &mut section);
        assert_eq!(section, vec![4, 36, 0x3, 0xF, 0xF]);
        // whole-bundle accounting: 2 header + 5 index + 8 value words = 15,
        // vs 2 + 2·8 = 18 raw — the encoder picks the bitmap form.
        assert_eq!(encoded_data_bundle_words(&cols, StreamEncoding::Bitmap), 15);
        assert_eq!(encoded_data_bundle_words(&cols, StreamEncoding::Raw), 18);
        let vals: Vec<Val> = (0..8).map(|i| i as f32 * 0.5).collect();
        let mut words = Vec::new();
        write_encoded_bundle(
            9,
            BundleFlags::default().with(BundleFlags::END_OF_ROW),
            &cols,
            &vals,
            StreamEncoding::Bitmap,
            false,
            &mut words,
        );
        assert_eq!(words.len(), 15);
        // metadata word: count 8 in bits 8.., END_OF_ROW | BITMAP below
        assert_eq!(words[0], 0x821);
        assert_eq!(words[1], 9, "shared-feature word");
        assert_eq!(&words[2..7], &[4, 36, 0x3, 0xF, 0xF]);
        assert_eq!(words[7], 0.0f32.to_bits(), "values follow the index section");
        let back = try_deserialize(&words).unwrap();
        assert_eq!(back[0].distinct(), &cols[..]);
        assert_eq!(back[0].values(), &vals[..], "bitmap-only is lossless");
        assert!(!back[0].flags.bitmap(), "decoder strips the flag");

        // -- fixed-point value section ---------------------------------
        // vals [0.5, -1.0, 0.25] at scale 1.0: q = [16384, -32767, 8192],
        // packed two per word (even index low, odd index high half).
        let mut fx = Vec::new();
        write_fx_section(&[0.5, -1.0, 0.25], &mut fx);
        assert_eq!(fx, vec![1.0f32.to_bits(), 0x8001_4000, 0x0000_2000]);
        assert_eq!(fx_value_words(3), 3); // scale word + 2 packed words
        assert_eq!(fx_value_words(0), 0, "empty bundles carry no section");
        // ±scale round-trips exactly; the others stay within the bound
        assert_eq!(fx_dequantize(0x8001, 1.0), -1.0);
        let bound = fx_max_abs_error(1.0);
        for (half, v) in [(0x4000u16, 0.5f64), (0x2000, 0.25)] {
            let err = (fx_dequantize(half, 1.0) as f64 - v).abs();
            assert!(err <= bound, "err {err} > bound {bound}");
        }
    }

    #[test]
    fn bitmap_index_words_edge_cases() {
        assert_eq!(bitmap_index_words(&[]), None, "empty");
        assert_eq!(bitmap_index_words(&[7, 7]), None, "not strictly ascending");
        assert_eq!(bitmap_index_words(&[9, 3]), None, "descending");
        assert_eq!(bitmap_index_words(&[0, u32::MAX]), None, "span overflows u32");
        assert_eq!(bitmap_index_words(&[5]), Some(4), "singleton: 2 + 1 L0 + 1 L1");
        // a singleton never wins over its 1 raw index word
        assert_eq!(encoded_data_bundle_words(&[5], StreamEncoding::Bitmap), 2 + 2);
        // widely scattered indices fall back to raw form too
        let scattered: Vec<Idx> = vec![3, 1000, 50_000];
        let bm = bitmap_index_words(&scattered).unwrap();
        assert!(bm > scattered.len(), "bitmap form loses: {bm} words vs 3 raw");
        assert_eq!(encoded_data_bundle_words(&scattered, StreamEncoding::Bitmap), 2 + 2 * 3);
    }

    #[test]
    fn fx_error_is_within_documented_bound_and_zero_scale_exact() {
        let vals: Vec<Val> =
            vec![0.0, 1e-3, -0.7, 123.456, -9999.25, 3.0e-39 /* subnormal */, 0.125];
        let scale = vals.iter().fold(0f32, |m, v| m.max(v.abs()));
        let bound = fx_max_abs_error(scale);
        for &v in &vals {
            let err = (fx_dequantize(fx_quantize(v, scale), scale) as f64 - v as f64).abs();
            assert!(err <= bound, "v {v}: err {err} > bound {bound}");
        }
        // all-zero bundle: scale 0, decodes exactly
        assert_eq!(fx_quantize(0.0, 0.0), 0);
        assert_eq!(fx_dequantize(0, 0.0), 0.0);
        assert_eq!(fx_max_abs_error(0.0), 0.0);
    }

    /// Every encoded serializer's output length must equal the accounting
    /// helpers' arithmetic, and the Raw encoding must stay bit-identical
    /// to the pre-compression serializers.
    #[test]
    fn encoded_accounting_matches_serialized_length() {
        let all = [
            StreamEncoding::Raw,
            StreamEncoding::Bitmap,
            StreamEncoding::Fx,
            StreamEncoding::BitmapFx,
        ];
        for (m, bs) in [
            (gen::power_law(30, 500, 1), 32usize),
            (gen::random_uniform(12, 40, 150, 2), 8),
            (gen::banded_fem(40, 300, 3), 16),
            (crate::sparse::Csr::new(0, 4), 32), // empty matrix
        ] {
            let s = crate::rir::encode::BundleStream::from_csr(&m, bs);
            for enc in all {
                for ck in [false, true] {
                    let words = serialize_stream_encoded(&s, enc, ck);
                    assert_eq!(
                        words.len(),
                        encoded_stream_words(&s, enc) + if ck { s.n_bundles() } else { 0 },
                        "enc {enc} ck {ck} bs {bs}"
                    );
                }
            }
            assert_eq!(
                serialize_stream_encoded(&s, StreamEncoding::Raw, false),
                serialize_stream(&s)
            );
            assert_eq!(
                serialize_stream_encoded(&s, StreamEncoding::Raw, true),
                serialize_stream_checksummed(&s)
            );
            assert_eq!(encoded_stream_words(&s, StreamEncoding::Raw), stream_arena_words(&s));
        }
    }

    /// Compressed streams decode back to the arena's exact structure —
    /// same bundles, same columns, compression flags stripped, values
    /// bit-identical except under fixed-point where the error stays within
    /// [`fx_max_abs_error`] of the per-bundle scale.
    #[test]
    fn encoded_streams_roundtrip_with_flags_stripped() {
        let m = gen::power_law(25, 400, 5);
        let s = crate::rir::encode::BundleStream::from_csr(&m, 8);
        for enc in [StreamEncoding::Bitmap, StreamEncoding::Fx, StreamEncoding::BitmapFx] {
            for ck in [false, true] {
                let words = serialize_stream_encoded(&s, enc, ck);
                let back = try_deserialize(&words).unwrap_or_else(|e| {
                    panic!("enc {enc} ck {ck}: {e}");
                });
                assert_eq!(back.len(), s.n_bundles());
                for (b, d) in s.iter().zip(&back) {
                    assert_eq!(d.shared, b.shared);
                    assert_eq!(d.distinct(), b.cols, "enc {enc}");
                    assert!(!d.flags.bitmap() && !d.flags.fixed_point(), "flags stripped");
                    assert_eq!(d.flags.checksum(), ck, "CHECKSUM kept iff protected");
                    assert_eq!(d.flags.end_of_row(), b.flags.end_of_row());
                    if enc.fx() {
                        let scale = b.vals.iter().fold(0f32, |mx, v| mx.max(v.abs()));
                        let bound = fx_max_abs_error(scale);
                        for (&v, &vhat) in b.vals.iter().zip(d.values()) {
                            let err = (vhat as f64 - v as f64).abs();
                            assert!(err <= bound, "enc {enc}: err {err} > bound {bound}");
                        }
                    } else {
                        assert_eq!(d.values(), b.vals, "bitmap-only is lossless");
                    }
                }
            }
        }
    }

    /// Satellite audit: exhaustive flag-composition accounting. For every
    /// combination of passthrough flags (END_OF_ROW / END_OF_STREAM /
    /// DENSE_PANEL) × CHECKSUM × encoding × payload shape, the wire walker
    /// ([`bundle_extent`]) must size the written bundle exactly, its CRC
    /// must verify, and the bundle must decode back losslessly (indices
    /// always; values except under fixed-point).
    #[test]
    fn exhaustive_flag_combination_accounting() {
        let encs = [
            StreamEncoding::Raw,
            StreamEncoding::Bitmap,
            StreamEncoding::Fx,
            StreamEncoding::BitmapFx,
        ];
        let compressible: Vec<Idx> = vec![4, 5, 6, 7, 36, 37, 38, 39];
        let scattered: Vec<Idx> = vec![3, 1000, 50_000];
        let shapes: [&[Idx]; 3] = [&compressible, &scattered, &[]];
        for base in 0u8..8 {
            let mut flags = BundleFlags::default();
            if base & 1 != 0 {
                flags = flags.with(BundleFlags::END_OF_ROW);
            }
            if base & 2 != 0 {
                flags = flags.with(BundleFlags::END_OF_STREAM);
            }
            if base & 4 != 0 {
                flags = flags.with(BundleFlags::DENSE_PANEL);
            }
            for ck in [false, true] {
                for enc in encs {
                    for cols in shapes {
                        let vals: Vec<Val> = (0..cols.len()).map(|i| i as f32 - 2.0).collect();
                        let mut words = Vec::new();
                        write_encoded_bundle(11, flags, cols, &vals, enc, ck, &mut words);
                        let ext = bundle_extent(&words, 0, 0)
                            .unwrap_or_else(|e| panic!("{flags:?} {enc} ck {ck}: {e}"));
                        assert_eq!(ext.total_words, words.len(), "{flags:?} {enc} ck {ck}");
                        assert_eq!(ext.count, cols.len());
                        assert_eq!(ext.flags.checksum(), ck);
                        verify_bundle_crc(&words, 0, &ext, 0).unwrap();
                        let back = try_deserialize(&words).unwrap();
                        assert_eq!(back.len(), 1);
                        assert_eq!(back[0].distinct(), cols);
                        assert_eq!(back[0].flags.end_of_row(), flags.end_of_row());
                        assert_eq!(back[0].flags.end_of_stream(), flags.end_of_stream());
                        assert_eq!(back[0].flags.dense_panel(), flags.dense_panel());
                        if !(enc.fx() && !cols.is_empty()) {
                            assert_eq!(back[0].values(), &vals[..]);
                        }
                        // truncating any suffix must error, never panic
                        for cut in 1..words.len() {
                            assert!(try_deserialize(&words[..cut]).is_err(), "cut {cut}");
                        }
                    }
                }
                // metadata-only bundles: triple payload regardless of flags
                let b = Bundle::schedule(
                    6,
                    vec![RlTriple { row: 2, start: 0, end: 5 }; 2],
                    if ck { flags.with(BundleFlags::CHECKSUM) } else { flags },
                );
                let words = serialize(std::slice::from_ref(&b));
                let ext = bundle_extent(&words, 0, 0).unwrap();
                assert_eq!(ext.total_words, words.len());
                assert_eq!(ext.payload_words, 3 * 2);
                verify_bundle_crc(&words, 0, &ext, 0).unwrap();
            }
        }
        // compression flags on a metadata-only header are sizing no-ops:
        // the payload is still raw triples (encoders never emit this, but
        // the walker must stay total on fuzzed input)
        let meta = (1u32 << 8)
            | (BundleFlags::METADATA_ONLY | BundleFlags::BITMAP | BundleFlags::FIXED_POINT) as u32;
        let words = vec![meta, 9, 4, 0, 7];
        let ext = bundle_extent(&words, 0, 0).unwrap();
        assert_eq!(ext.payload_words, 3);
        assert_eq!(ext.total_words, 5);
    }

    #[test]
    fn encoded_chain_and_dense_panel_accounting() {
        // chain accounting reduces to the raw formula at Raw
        let cols: Vec<Idx> = (0..37).map(|i| i * 3).collect();
        for bs in [1usize, 8, 32] {
            assert_eq!(
                encoded_chain_words(&cols, bs, StreamEncoding::Raw),
                2 * cols.len().div_ceil(bs) + 2 * cols.len()
            );
        }
        assert_eq!(encoded_chain_words(&[], 32, StreamEncoding::Raw), 2, "empty chain");
        assert_eq!(encoded_chain_words(&[], 32, StreamEncoding::BitmapFx), 2);
        // panel accounting reduces to dense_panel_words at Raw...
        for (nrows, k, bs) in [(20usize, 8usize, 32usize), (5, 7, 3), (9, 0, 16)] {
            assert_eq!(
                encoded_dense_panel_words(nrows, k, bs, StreamEncoding::Raw),
                dense_panel_words(nrows, k, bs)
            );
        }
        // ...and contiguous lane chains compress under bitmaps: lanes 0..8
        // cost 2 + (2+1+1) + 8 = 14 words per row vs 18 raw
        assert_eq!(encoded_dense_panel_words(10, 8, 32, StreamEncoding::Bitmap), 10 * 14);
        assert_eq!(encoded_dense_panel_words(10, 8, 32, StreamEncoding::Raw), 10 * 18);
        // fx packs 8 lane values into 1 scale + 4 words: 2 + 8 + 5 = 15
        assert_eq!(encoded_dense_panel_words(10, 8, 32, StreamEncoding::Fx), 10 * 15);
        // both: 2 + 4 + 5 = 11
        assert_eq!(encoded_dense_panel_words(10, 8, 32, StreamEncoding::BitmapFx), 10 * 11);
    }

    #[test]
    fn end_of_stream_marker_walks_encoded_checksummed_streams() {
        let m = gen::random_uniform(6, 60, 40, 13);
        let s = crate::rir::encode::BundleStream::from_csr(&m, 4);
        for enc in [StreamEncoding::Bitmap, StreamEncoding::Fx, StreamEncoding::BitmapFx] {
            let mut words = serialize_stream_encoded(&s, enc, true);
            super::mark_last_header_end_of_stream(&mut words);
            let bundles = try_deserialize(&words)
                .unwrap_or_else(|e| panic!("enc {enc}: marker broke the stream: {e}"));
            assert!(bundles.last().unwrap().flags.end_of_stream(), "enc {enc}");
        }
    }

    #[test]
    fn corrupted_compressed_streams_are_rejected_not_panicked() {
        let cols: Vec<Idx> = vec![4, 5, 6, 7, 36, 37, 38, 39];
        let vals: Vec<Val> = (0..8).map(|i| i as f32).collect();
        let mut words = Vec::new();
        write_encoded_bundle(
            0,
            BundleFlags::default(),
            &cols,
            &vals,
            StreamEncoding::Bitmap,
            false,
            &mut words,
        );
        // clearing an L1 bit makes the decoded count disagree with the
        // header; without a CRC the bitmap integrity check still catches it
        let mut bad = words.clone();
        bad[5] &= !1u32; // L1 word of block 0, drop index 4
        match try_deserialize(&bad) {
            Err(RirError::BitmapCountMismatch { bundle: 0, declared: 8, decoded: 7 }) => {}
            other => panic!("expected count mismatch, got {other:?}"),
        }
        // a base near u32::MAX whose expansion overflows is rejected
        let mut ovf = words.clone();
        ovf[2] = u32::MAX - 2; // base: first decoded cols fit, later ones overflow
        match try_deserialize(&ovf) {
            Err(RirError::BitmapIndexOverflow { bundle: 0 }) => {}
            other => panic!("expected index overflow, got {other:?}"),
        }
        // with a CRC, any of these flips is caught before expansion
        let mut ckw = Vec::new();
        write_encoded_bundle(
            0,
            BundleFlags::default(),
            &cols,
            &vals,
            StreamEncoding::Bitmap,
            true,
            &mut ckw,
        );
        let mut flipped = ckw.clone();
        flipped[5] &= !1u32;
        assert!(matches!(
            try_deserialize(&flipped),
            Err(RirError::ChecksumMismatch { bundle: 0, .. })
        ));
    }
}
